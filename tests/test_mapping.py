"""ILP mapping (paper §III-D, eqs. 3-7): exactness + constraint compliance."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.mapping import (MappingProblem, max_flow_assignment,
                                solve_mapping, solve_mapping_bruteforce,
                                solve_mapping_full_ilp, solve_mapping_greedy,
                                solve_mapping_reduced_ilp)


def _random_problem(rng, n_src, n_dest, m, n, density, fanout_slack):
    conn = rng.random((n_src, n_dest)) < density
    fanin = conn.sum(axis=1)
    if fanout_slack:
        fanout = np.maximum(fanin, 1)
    else:
        fanout = np.maximum((fanin * rng.uniform(0.3, 1.0, n_src)).astype(int), 1)
    return MappingProblem(n_dest=n_dest, n_engines=m, n_caps=n,
                          conn=conn, fanout=fanout)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_full_equals_reduced_equals_bruteforce(seed):
    rng = np.random.default_rng(seed)
    p = _random_problem(rng, n_src=4, n_dest=4, m=2, n=2,
                        density=0.6, fanout_slack=False)
    s_full = solve_mapping_full_ilp(p)
    s_red = solve_mapping_reduced_ilp(p)
    s_bf = solve_mapping_bruteforce(p)
    s_full.check(p)
    s_red.check(p)
    s_bf.check(p)
    assert s_full.n_assigned == s_bf.n_assigned
    assert s_red.n_assigned == s_bf.n_assigned


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_maxflow_exact_when_fanout_slack(seed):
    rng = np.random.default_rng(seed)
    p = _random_problem(rng, n_src=6, n_dest=8, m=3, n=2,
                        density=0.5, fanout_slack=True)
    s_mf = max_flow_assignment(p)
    s_ilp = solve_mapping_reduced_ilp(p)
    s_mf.check(p)
    assert s_mf.n_assigned == s_ilp.n_assigned == min(p.n_dest,
                                                      p.n_engines * p.n_caps)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_greedy_feasible_and_bounded(seed):
    rng = np.random.default_rng(seed)
    p = _random_problem(rng, n_src=5, n_dest=6, m=2, n=2,
                        density=0.5, fanout_slack=False)
    s_g = solve_mapping_greedy(p)
    s_g.check(p)                       # always feasible
    s_opt = solve_mapping_reduced_ilp(p)
    assert s_g.n_assigned <= s_opt.n_assigned


def test_capacity_binds():
    """More neurons than M*N capacitors -> exactly M*N assigned."""
    rng = np.random.default_rng(1)
    conn = np.ones((3, 10), dtype=bool)
    p = MappingProblem(n_dest=10, n_engines=2, n_caps=2, conn=conn,
                       fanout=np.full(3, 10))
    s = solve_mapping(p, method="reduced_ilp")
    s.check(p)
    assert s.n_assigned == 4
    assert s.objective == 6


def test_fanout_binds():
    """A source with fanout limit 2 caps its destinations' assignments."""
    conn = np.ones((1, 5), dtype=bool)
    p = MappingProblem(n_dest=5, n_engines=5, n_caps=5, conn=conn,
                       fanout=np.asarray([2]))
    s = solve_mapping(p, method="full_ilp")
    s.check(p)
    assert s.n_assigned == 2


def test_auto_method_selects_and_solves():
    rng = np.random.default_rng(2)
    w = rng.normal(size=(20, 30))
    w[np.abs(w) < 0.8] = 0
    p = MappingProblem.from_weights(w, n_engines=4, n_caps=8)
    s = solve_mapping(p)
    s.check(p)
    assert s.n_assigned == 30  # capacity 32 >= 30, fanout slack


def test_ilp_load_balances_rows():
    """The ILP objective (max assignments) with capacity constraints spreads
    neurons across engines — dispatch rows (B_i) stay near optimal."""
    rng = np.random.default_rng(3)
    w = (rng.random((8, 16)) < 0.9).astype(float)
    p = MappingProblem.from_weights(w, n_engines=4, n_caps=4)
    s = solve_mapping(p, method="reduced_ilp")
    s.check(p)
    loads = np.bincount(s.engine[s.engine >= 0], minlength=4)
    assert loads.max() <= 4
    assert s.n_assigned == 16
