"""Whisper-medium backbone: enc-dec 24+24L, d=1024, 16H (MHA), conv/mel
frontend STUBBED per assignment [arXiv:2212.04356; unverified]."""

import dataclasses

from repro.configs.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_encoder_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865, head_dim=64,
    decoder_ratio=4, cross_len=1500,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256, cross_len=8)
