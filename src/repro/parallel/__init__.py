from repro.parallel.sharding import (  # noqa: F401
    ShardingRules,
    TRAIN_RULES,
    DECODE_RULES,
    DECODE_RULES_SP,
    activate,
    active_mesh,
    logical_spec,
    named_sharding,
    shard,
)
from repro.parallel.decode import make_sp_attention, sp_cache_update  # noqa: F401
from repro.parallel.pipeline import pipeline_forward, sequential_reference  # noqa: F401
