"""Always-on async serving loop: arrival-driven continuous batching.

``run_bucketed`` drains a *closed* list of requests; production DVS traffic
from edge sensors is an open stream.  :class:`StreamServer` is the always-on
front end for that stream:

  * **Arrival queue with admission control.**  ``submit`` admits one request
    at the current clock time.  The queue is bounded (``queue_capacity``);
    an arrival that would overflow it is either rejected or sheds the oldest
    pending request of the most-backlogged tenant (``backpressure="reject" |
    "shed_oldest"``).  Requests longer than the policy's largest time bucket
    are rejected at admission with a per-request reason — or, with
    ``overlong="extend"``, grow the bucket grid geometrically (new jit
    trace, logged) instead.
  * **Multi-tenant model fabric.**  MENAGE's virtual neuron time-multiplexes
    many model neurons onto one physical engine; the server applies the same
    idea one level up and time-multiplexes many *models* onto one executor.
    Tenants live in a :class:`~repro.engine.registry.ModelRegistry` — each
    with its own packed weights, :class:`BucketPolicy`, noise config, and
    weighted-fair share — and ``submit(stream, model="name")`` routes to
    them.  Requests pin the (model, generation) they were admitted under, so
    a :meth:`swap` (hot-swap: drain the tenant's in-flight groups on the old
    weights, then atomically redirect new submits to the new ones) never
    loses or corrupts a request.  Between due groups the scheduler picks by
    weighted-fair virtual time, then deadline — one tenant's burst cannot
    starve another's deadlines.  A single ``StreamServer(packed, policy=p)``
    still works: it becomes a one-tenant registry behind the scenes.
  * **Deadline-aware batch formation.**  Pending requests group by (model,
    generation, time bucket).  A group dispatches the moment it can fill a
    ``max_batch`` chunk — or *earlier*, partially full, when the oldest
    member's deadline slack (deadline − now − estimated service time −
    ``dispatch_margin``) runs out.  This is the fix for the batch-formation
    stall of event-driven dispatch (Yik et al. 2025): a short request never
    waits for a bucket that might not fill.
  * **Bit-exact execution.**  A formed batch runs through the *same*
    :func:`repro.engine.serving.execute_plan` as the closed-list path —
    zero-pad into the policy bucket, ``run_batched`` / ``run_sharded``,
    slice each request back out — so every served result is bit-identical
    to ``run_bucketed``'s *on the packed model that was serving the tenant
    at dispatch time* and hence to the numpy oracle (tested,
    ``tests/test_stream_server.py``, ``tests/test_multitenant.py``).  The
    jit cache stays bounded by the sum of per-tenant ``policy.n_buckets``
    by construction (same-shape hot-swaps add no traces).
  * **Metrics.**  :class:`ServerMetrics` tracks queue depth,
    time-to-first-dispatch, end-to-end latency percentiles, deadline-miss
    rate, bucket fill ratio, and a per-model sub-table
    (:data:`PER_MODEL_KEYS`) — the ``BENCH_async_serving.json`` /
    ``BENCH_multitenant.json`` surface.
  * **Chaos-ready.**  Three production failure modes are first-class (the
    soak harness, :mod:`repro.engine.chaos` / ``benchmarks/soak_bench.py``,
    drives all of them): a ``chaos_hook`` may raise
    :class:`~repro.engine.sharded_run.DeviceLossError` at any dispatch
    boundary and the server recovers onto the shrunken mesh (elastic
    serving — no request is lost to hardware loss); an :class:`SLOPolicy`
    flips between extend-biased admission and shedding on the windowed
    deadline-miss rate; and ``noise=AnalogNoise(...)`` serves through one
    deterministic noisy device instance with periodic shadow probes
    against the clean model (the ``noise_agreement`` accuracy-under-noise
    metric).  Every scenario replays deterministically on a VirtualClock
    (tests/test_chaos.py).

Time is pluggable: the default :class:`WallClock` serves real traffic;
:class:`VirtualClock` + :func:`serve_trace` replay a time-stamped arrival
trace deterministically (the clock only moves between arrivals and at
deadline-trigger instants), which is what makes the scheduler's dispatch
decisions unit-testable and the benchmark reproducible.
"""

from __future__ import annotations

import collections
import dataclasses
import logging
import math
import time

import numpy as np

from repro.engine import batched_run as br
from repro.engine.registry import (DEFAULT_MODEL, ModelEntry, ModelRegistry,
                                   UnknownModelError)
from repro.engine.serving import (BatchPlan, BucketPolicy, RequestResult,
                                  execute_plan)
from repro.engine.sharded_run import DeviceLossError, shrink_mesh
from repro.engine.tracing import TIME_EDGES, FlightRecorder, Histogram

_log = logging.getLogger(__name__)


# ------------------------------------------------------------------- clocks

class WallClock:
    """Real time — the production configuration."""

    def now(self) -> float:
        return time.monotonic()


class VirtualClock:
    """Manually-advanced time for deterministic replay of arrival traces."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        assert dt >= 0.0, f"time cannot run backwards (dt={dt})"
        self._t += dt


# ----------------------------------------------------------------- requests

@dataclasses.dataclass(frozen=True)
class Request:
    """One admitted in-flight request, pinned to the (model, generation) it
    was admitted under — a hot-swap cannot change which weights serve it."""

    rid: int
    stream: np.ndarray          # [T_i, n_in]
    arrival_t: float
    deadline: float             # absolute; math.inf = best-effort
    t_pad: int                  # time bucket it was admitted into
    model: str = DEFAULT_MODEL
    generation: int = 1


@dataclasses.dataclass(frozen=True)
class Rejection:
    """Why a request never produced a result: ``queue_full`` (bounded-queue
    backpressure), ``shed`` (displaced by a newer arrival under
    ``backpressure="shed_oldest"``), or ``overlong`` (admission control).
    ``model`` is the tenant the request targeted (None when it never
    resolved to one)."""

    rid: int | None             # None when rejected before admission
    reason: str
    detail: str
    at: float
    model: str | None = None


# ------------------------------------------------------------------ metrics

# Always-on means unbounded time: per-request samples (latency, TTFD, fill)
# and the telemetry/rejection logs keep the most recent WINDOW entries, so a
# long-lived server reports sliding-window percentiles at O(1) memory
# instead of growing until OOM.  Counters are exact over the full lifetime.
METRICS_WINDOW = 10_000

# The ServerMetrics.snapshot() schema, locked by tests/test_serving.py AND
# by the docs/SERVING.md metrics table (tests/test_docs.py) so dashboards
# reading BENCH_async_serving.json / BENCH_soak.json don't silently break.
METRIC_KEYS = (
    "submitted", "admitted", "rejected", "shed", "completed",
    "deadline_misses", "deadline_miss_rate", "dispatches",
    "forced_dispatches", "policy_extensions", "queue_depth",
    "max_queue_depth", "bucket_fill_ratio", "p50_ttfd_s", "p99_ttfd_s",
    "p50_latency_s", "p99_latency_s", "recent_p50_ttfd_s",
    "recent_p99_ttfd_s", "recent_p50_latency_s", "recent_p99_latency_s",
    "device_losses", "slo_switches", "slo_shedding", "noise_probes",
    "noise_agreement", "models", "hot_swaps", "per_model")

# The per-tenant sub-table under snapshot()["per_model"], locked by
# tests/test_serving.py and the docs/SERVING.md per-model table
# (tests/test_docs.py) — the BENCH_multitenant.json isolation surface.
PER_MODEL_KEYS = (
    "submitted", "admitted", "rejected", "shed", "completed",
    "deadline_misses", "deadline_miss_rate", "dispatches", "hot_swaps",
    "p50_latency_s", "p99_latency_s", "recent_p50_latency_s",
    "recent_p99_latency_s")


def _pct(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


@dataclasses.dataclass
class ModelMetrics:
    """Per-tenant slice of the serving counters (``PER_MODEL_KEYS``).
    ``p50/p99_latency_s`` come from a lifetime cumulative histogram (exact
    over every completed request); the windowed deque percentiles survive
    as ``recent_*``."""

    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    shed: int = 0
    completed: int = 0
    deadline_misses: int = 0
    dispatches: int = 0
    hot_swaps: int = 0
    latency_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=METRICS_WINDOW))
    latency_hist: Histogram = dataclasses.field(
        default_factory=lambda: Histogram(TIME_EDGES))

    def observe_latency(self, dt: float) -> None:
        self.latency_s.append(dt)
        self.latency_hist.add(dt)

    def snapshot(self) -> dict:
        return {
            "submitted": self.submitted,
            "admitted": self.admitted,
            "rejected": self.rejected,
            "shed": self.shed,
            "completed": self.completed,
            "deadline_misses": self.deadline_misses,
            "deadline_miss_rate": (self.deadline_misses / self.completed
                                   if self.completed else 0.0),
            "dispatches": self.dispatches,
            "hot_swaps": self.hot_swaps,
            "p50_latency_s": self.latency_hist.percentile(50),
            "p99_latency_s": self.latency_hist.percentile(99),
            "recent_p50_latency_s": _pct(self.latency_s, 50),
            "recent_p99_latency_s": _pct(self.latency_s, 99),
        }


@dataclasses.dataclass
class ServerMetrics:
    """Serving-loop counters plus per-request latency samples.

    ``snapshot()`` reduces to the fixed ``METRIC_KEYS`` dict: queue depth
    (current/max), time-to-first-dispatch and end-to-end latency
    percentiles, deadline-miss rate over completed requests, the mean
    bucket fill ratio (requests per dispatch / padded batch rows — how much
    of each engine call was real work), and the ``per_model`` sub-table
    keyed by tenant name (each row is ``PER_MODEL_KEYS``).  Counters are
    lifetime-exact.  ``p50/p99_*`` percentiles come from lifetime
    cumulative :class:`~repro.engine.tracing.Histogram` s — a week-long
    soak's p99 reflects every request, not just the last
    ``METRICS_WINDOW``; the windowed sliding values are exported under
    explicit ``recent_*`` keys (and fill stays a windowed mean)."""

    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    shed: int = 0
    completed: int = 0
    deadline_misses: int = 0
    dispatches: int = 0
    forced_dispatches: int = 0      # deadline-triggered partial dispatches
    policy_extensions: int = 0
    queue_depth: int = 0
    max_queue_depth: int = 0
    device_losses: int = 0          # chaos/watchdog-reported mesh shrinks
    slo_switches: int = 0           # shed<->extend mode flips by the SLO loop
    slo_shedding: bool = False      # currently in degraded (shedding) mode
    noise_probes: int = 0           # requests shadow-checked vs clean model
    noise_disagreements: int = 0    # probes whose prediction flipped
    hot_swaps: int = 0              # registry generations installed live
    per_model: dict = dataclasses.field(default_factory=dict)
    ttfd_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=METRICS_WINDOW))
    latency_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=METRICS_WINDOW))
    fill: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=METRICS_WINDOW))
    ttfd_hist: Histogram = dataclasses.field(
        default_factory=lambda: Histogram(TIME_EDGES))
    latency_hist: Histogram = dataclasses.field(
        default_factory=lambda: Histogram(TIME_EDGES))

    def model(self, name: str) -> ModelMetrics:
        """The (auto-created) per-tenant counter row for ``name``."""
        mm = self.per_model.get(name)
        if mm is None:
            mm = self.per_model[name] = ModelMetrics()
        return mm

    def observe_ttfd(self, dt: float) -> None:
        self.ttfd_s.append(dt)
        self.ttfd_hist.add(dt)

    def observe_latency(self, dt: float) -> None:
        self.latency_s.append(dt)
        self.latency_hist.add(dt)

    def snapshot(self) -> dict:
        return {
            "submitted": self.submitted,
            "admitted": self.admitted,
            "rejected": self.rejected,
            "shed": self.shed,
            "completed": self.completed,
            "deadline_misses": self.deadline_misses,
            "deadline_miss_rate": (self.deadline_misses / self.completed
                                   if self.completed else 0.0),
            "dispatches": self.dispatches,
            "forced_dispatches": self.forced_dispatches,
            "policy_extensions": self.policy_extensions,
            "queue_depth": self.queue_depth,
            "max_queue_depth": self.max_queue_depth,
            "bucket_fill_ratio": (float(np.mean(self.fill))
                                  if self.fill else 0.0),
            "p50_ttfd_s": self.ttfd_hist.percentile(50),
            "p99_ttfd_s": self.ttfd_hist.percentile(99),
            "p50_latency_s": self.latency_hist.percentile(50),
            "p99_latency_s": self.latency_hist.percentile(99),
            "recent_p50_ttfd_s": _pct(self.ttfd_s, 50),
            "recent_p99_ttfd_s": _pct(self.ttfd_s, 99),
            "recent_p50_latency_s": _pct(self.latency_s, 50),
            "recent_p99_latency_s": _pct(self.latency_s, 99),
            "device_losses": self.device_losses,
            "slo_switches": self.slo_switches,
            "slo_shedding": int(self.slo_shedding),
            "noise_probes": self.noise_probes,
            # accuracy under analog noise: fraction of shadow-probed
            # requests whose prediction matched the clean model (1.0 when
            # probing is off — no evidence of degradation)
            "noise_agreement": ((self.noise_probes - self.noise_disagreements)
                                / self.noise_probes
                                if self.noise_probes else 1.0),
            "models": len(self.per_model),
            "hot_swaps": self.hot_swaps,
            "per_model": {name: mm.snapshot()
                          for name, mm in sorted(self.per_model.items())},
        }


# ------------------------------------------------------------------- server

_EWMA_ALPHA = 0.3


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """SLO-driven shed-vs-extend switching for an always-on server.

    The server normally runs *extend-biased*: whatever ``backpressure`` /
    ``overlong`` it was built with (typically admit-everything).  When the
    deadline-miss rate over the last ``window`` completed requests exceeds
    ``target_miss_rate``, it flips to *shed* mode — ``backpressure=
    "shed_oldest"`` (newest data wins; stale queued requests would miss
    anyway) and ``overlong="reject"`` (no mid-overload grid growth, which
    costs a jit trace at the worst possible moment).  Once the windowed
    rate drops below ``restore_factor * target_miss_rate``, the original
    policies are restored.  Mode flips are counted in the ``slo_switches``
    metric and the current mode is exported as ``slo_shedding`` — the
    measured, scenario-driven alternative to hand-tuning backpressure per
    deployment (cf. the bottleneck-modeling argument of arXiv 2511.21549).
    """

    target_miss_rate: float = 0.05
    window: int = 64
    min_samples: int = 16          # don't flap on the first few requests
    restore_factor: float = 0.5

    def __post_init__(self):
        assert 0.0 < self.target_miss_rate <= 1.0
        assert 0.0 <= self.restore_factor < 1.0
        assert 0 < self.min_samples <= self.window


class StreamServer:
    """The always-on continuous-batching loop (module docstring has the
    design).  Drive it with :meth:`submit` on arrival, :meth:`poll` when
    time passes (:meth:`next_deadline` says when that matters), and
    :meth:`flush` at shutdown; completed ``(rid, RequestResult)`` pairs
    come back from ``poll``/``flush``.

    ``model`` is either a single packed/mapped model (a one-tenant fabric
    with per-server ``policy``/``noise`` — the original API) or a
    :class:`~repro.engine.registry.ModelRegistry` (multi-tenant; policy and
    noise then live on the entries and the ``policy``/``noise`` kwargs must
    stay unset).  :meth:`swap` hot-swaps a tenant's weights live.
    """

    def __init__(self, model, *, policy: BucketPolicy | None = None,
                 mesh=None, clock=None,
                 queue_capacity: int = 256,
                 backpressure: str = "reject",
                 overlong: str = "reject",
                 default_slack: float = math.inf,
                 dispatch_margin: float = 0.0,
                 service_model=None,
                 max_events: int | None = None,
                 sn_capacity_rows: int | None = None,
                 with_stats: bool = False,
                 donate: bool | None = None,
                 noise=None, noise_key=0, noise_probe_every: int = 8,
                 slo: SLOPolicy | None = None,
                 chaos_hook=None, on_rejection=None, on_completion=None,
                 tracer: FlightRecorder | None = None):
        assert backpressure in ("reject", "shed_oldest"), backpressure
        assert overlong in ("reject", "extend"), overlong
        assert queue_capacity > 0
        assert noise_probe_every >= 0
        if isinstance(model, ModelRegistry):
            assert policy is None and noise is None, \
                "a multi-tenant server takes per-model policy/noise from " \
                "its registry entries, not from server kwargs"
            assert len(model) > 0, "registry has no models to serve"
            self.registry = model
        else:
            assert policy is not None, "single-model servers need a policy"
            self.registry = ModelRegistry()
            # serving-time analog noise: serve every request through one
            # deterministic noisy device instance (core/noise.perturb_packed);
            # every noise_probe_every-th dispatch is shadow-replayed through
            # the clean model to track prediction agreement (the
            # accuracy-under-noise metric).  0 disables probing.
            self.registry.register(DEFAULT_MODEL, model, policy=policy,
                                   noise=noise, noise_key=noise_key)
        self.noise_probe_every = noise_probe_every
        # SLO controller state: the configured backpressure/overlong are the
        # "extend-biased" baseline it restores to after a shed episode
        self.slo = slo
        self._slo_base = (backpressure, overlong)
        self._slo_misses: collections.deque = collections.deque(
            maxlen=slo.window if slo is not None else 1)
        # chaos_hook(dispatch_ordinal) runs at every dispatch boundary and
        # may raise DeviceLossError — the soak harness's failure injection,
        # mirroring train_loop's failure_hook
        self.chaos_hook = chaos_hook
        self.mesh = mesh
        self.clock = clock if clock is not None else WallClock()
        self.queue_capacity = queue_capacity
        self.backpressure = backpressure
        self.overlong = overlong
        self.default_slack = default_slack
        self.dispatch_margin = dispatch_margin
        # service_model(b_pad, t_pad) -> seconds: the scheduler's estimate of
        # one engine call on that bucket.  None = learn an EWMA from measured
        # wall seconds.  On a VirtualClock the model also *advances* the
        # clock per dispatch, turning the server into a deterministic
        # discrete-event simulation grounded in calibrated timings.
        self.service_model = service_model
        self.max_events = max_events
        self.sn_capacity_rows = sn_capacity_rows
        self.with_stats = with_stats
        # each dispatch uploads one padded bucket buffer; donating it lets
        # the jit recycle that allocation into the outputs, so an always-on
        # server never accumulates input copies across dispatches.  CPU XLA
        # has no donation, hence the backend-aware default.
        self.donate = br.should_donate(donate)
        # on_rejection(Rejection) fires synchronously for every rejection
        # as it happens — the delivery channel for transports that must
        # answer displaced clients (the socket layer's REJECT frames).
        # The `rejections` deque below is a bounded *metrics* window and
        # can overflow under sustained shedding; consumers that may not
        # lose a record subscribe here instead of scraping it.
        self.on_rejection = on_rejection
        # on_completion(rid, result) fires synchronously as each result
        # completes, with the clock already advanced past the service
        # period — observers (benchmarks, transports) read per-request
        # completion instants off self.now() without polling collect().
        self.on_completion = on_completion
        # tracer: a FlightRecorder (repro.engine.tracing) receiving a typed
        # span trace for every admitted request plus typed anomalies for
        # every fault.  All span times come off self.clock, so a
        # VirtualClock replay produces byte-identical dumps; None = tracing
        # off, with zero observable effect on served bits (tested).
        self.tracer = tracer
        if tracer is not None:
            tracer.attach_jit_probe()
        self.metrics = ServerMetrics()
        # execute_plan records / rejection log, last METRICS_WINDOW entries
        self.telemetry: collections.deque = \
            collections.deque(maxlen=METRICS_WINDOW)
        self.rejections: collections.deque = \
            collections.deque(maxlen=METRICS_WINDOW)
        # scheduler state.  Pending groups key by (model, generation,
        # t_pad): the generation pin is what makes hot-swap unable to
        # corrupt a queued request — its group still points at the entry it
        # was admitted under.  Runtime bucket policies are per tenant and
        # mutable (overlong=extend growth, mesh-shrink re-rounding);
        # registry entries keep the pristine configured policy.
        self._pending: dict[tuple[str, int, int],
                            collections.deque[Request]] = {}
        self._entries: dict[tuple[str, int], ModelEntry] = {}
        self._policies: dict[str, BucketPolicy] = {}
        self._n_pending = 0
        self._n_pending_by: dict[str, int] = {}
        self._completed: list[tuple[int, RequestResult]] = []
        self._next_rid = 0
        # per-(model, b_pad, t_pad) EWMA service estimates.  Keying by model
        # matters: tenants of very different sizes can share a bucket shape
        # but differ 10x in service time — a shared key would cross-pollute
        # both schedulers' deadline triggers.
        self._ewma: dict[tuple[str, int, int], float] = {}
        # weighted-fair virtual time per tenant: advanced by
        # service/weight on each dispatch, used to order due groups so a
        # flooding tenant cannot starve the others (see _due_order)
        self._vtime: dict[str, float] = {}
        self._vglobal = 0.0
        for name in self.registry.names():
            self.metrics.model(name)

    # -------------------------------------------------------------- tenants

    @property
    def packed(self) -> br.PackedModel:
        """The default tenant's serving weights (single-model API)."""
        return self.registry.get().packed

    @property
    def _clean_packed(self) -> br.PackedModel:
        return self.registry.get().clean

    @property
    def noise(self):
        return self.registry.get().noise

    @property
    def policy(self) -> BucketPolicy:
        """The default tenant's *runtime* bucket policy (single-model API:
        reflects overlong-extension growth and mesh re-rounding)."""
        return self._policy_for(self.registry.default)

    def _policy_for(self, name: str) -> BucketPolicy:
        p = self._policies.get(name)
        if p is None:
            p = self._policies[name] = self.registry.get(name).policy
        return p

    def _entry_for(self, key: tuple[str, int]) -> ModelEntry:
        entry = self._entries.get(key)
        if entry is None:
            entry = self._entries[key] = self.registry.get(key[0])
            assert entry.generation == key[1]
        return entry

    def swap(self, name: str, model, *, policy: BucketPolicy | None = None,
             noise=None, noise_key=0, weight: float | None = None,
             _inherit_noise: bool = True) -> ModelEntry:
        """Hot-swap tenant ``name`` onto new weights with zero lost
        requests: (1) drain — every group still pending on the old
        generation dispatches *now, on the old weights* (results land in
        the normal completion queue; collect them via :meth:`poll` /
        :meth:`collect`); (2) atomically install the new generation in the
        registry, so every later ``submit`` runs on the new weights; (3)
        drop only this tenant's EWMA calibration (it described the old
        weights).  Policy defaults to the tenant's current *runtime* policy
        — extension growth and mesh re-rounding survive the swap.  Noise
        config is inherited unless explicitly overridden."""
        self.registry.get(name)                 # raise before side effects
        # Drain on the old weights.  flush() pops everything completed so
        # far out of the completion queue (collect() rebinds the list, so
        # extend must run *after* flush returns); put it all back — swap()
        # must not eat results the caller has yet to collect().
        drained = self.flush(model=name)
        self._completed.extend(drained)
        new_policy = policy if policy is not None else self._policy_for(name)
        kw = {} if (_inherit_noise and noise is None) else \
            {"noise": noise, "noise_key": noise_key}
        entry = self.registry.swap(name, model, policy=new_policy,
                                   weight=weight, **kw)
        self._policies[name] = new_policy
        self._entries[(name, entry.generation)] = entry
        self.clear_service_estimates(name)
        self.metrics.hot_swaps += 1
        self.metrics.model(name).hot_swaps += 1
        if self.tracer is not None:
            # generation pin: in-flight work drained on the old weights
            self.tracer.anomaly("hot_swap_pin", t=self.now(), model=name,
                                generation=entry.generation,
                                drained=len(drained))
        _log.info("stream_server: hot-swapped model %r to generation %d "
                  "(drained on old weights; new submits redirected)",
                  name, entry.generation)
        return entry

    def clear_service_estimates(self, model: str | None = None) -> None:
        """Drop learned EWMA service times — for one tenant (its weights or
        calibration went stale) or all (``None``; the mesh changed under
        everyone)."""
        if model is None:
            self._ewma.clear()
        else:
            for k in [k for k in self._ewma if k[0] == model]:
                del self._ewma[k]

    # ------------------------------------------------------------ admission

    def now(self) -> float:
        return self.clock.now()

    @property
    def queue_depth(self) -> int:
        return self._n_pending

    def _reject(self, rid: int | None, reason: str, detail: str,
                model: str | None = None) -> None:
        rej = Rejection(rid=rid, reason=reason, detail=detail, at=self.now(),
                        model=model)
        self.rejections.append(rej)
        mm = self.metrics.model(model) if model is not None else None
        if reason == "shed":
            self.metrics.shed += 1
            if mm is not None:
                mm.shed += 1
        else:
            self.metrics.rejected += 1
            if mm is not None:
                mm.rejected += 1
        if self.tracer is not None:
            kind = "shed" if reason == "shed" else "reject"
            self.tracer.anomaly(kind, t=rej.at, rid=rid, reason=reason,
                                detail=detail, model=model)
            if rid is not None:
                # the admitted trace will never complete — park it in the
                # recorder's anomalous ring
                self.tracer.abort(rid, t=rej.at)
        if self.on_rejection is not None:
            self.on_rejection(rej)

    def _shed_oldest(self) -> None:
        """Backpressure by displacement: drop the oldest pending request of
        the tenant with the deepest backlog.  Shedding the *flooding*
        tenant's work (rather than the globally oldest request) is what
        keeps one tenant's burst from evicting everybody else's queue."""
        victim_name = max(
            (n for n, c in self._n_pending_by.items() if c > 0),
            key=lambda n: (self._n_pending_by[n], n))
        key = min((q[0].arrival_t, k) for k, q in self._pending.items()
                  if q and k[0] == victim_name)[1]
        victim = self._pending[key].popleft()
        self._n_pending -= 1
        self._n_pending_by[victim_name] -= 1
        self._reject(victim.rid, "shed",
                     f"displaced after {self.now() - victim.arrival_t:.3g}s "
                     f"in queue (capacity {self.queue_capacity})",
                     model=victim_name)

    def submit(self, stream, *, model: str | None = None,
               deadline: float | None = None,
               slack: float | None = None,
               arrival_t: float | None = None) -> int | None:
        """Admit one request for tenant ``model`` (None = the registry's
        default route) at the current clock time.  Returns its rid, or
        ``None`` if it was rejected (recorded in :attr:`rejections`).  The
        deadline is absolute; ``slack`` is relative to now; neither given
        falls back to ``default_slack``.  A group that reaches the tenant's
        ``max_batch`` dispatches immediately — collect results via
        :meth:`poll`.  An unregistered model name raises
        :class:`~repro.engine.registry.UnknownModelError` (a typed error
        transports map to a rejection frame).

        ``arrival_t`` back-dates the request's arrival for latency/TTFD
        accounting (≤ now): on a virtual clock a request that physically
        arrived while the executor was busy is only admitted once the
        engine call returns, but its latency still counts from when the
        sensor produced it."""
        entry = self.registry.get(model)    # raises UnknownModelError
        name = entry.name
        now = self.now()
        if arrival_t is None:
            arrival_t = now
        assert arrival_t <= now + 1e-9, \
            f"arrival_t {arrival_t} is in the future (now={now})"
        self.metrics.submitted += 1
        mm = self.metrics.model(name)
        mm.submitted += 1
        stream = np.asarray(stream, dtype=np.float32)
        # a real raise, not an assert: submit is the boundary where
        # external traffic enters, so the shape check must survive -O and
        # give transports a typed error to map to a rejection
        if stream.ndim != 2 or stream.shape[1] != entry.packed.n_in:
            raise ValueError(
                f"expected [T, {entry.packed.n_in}] for model {name!r}, "
                f"got {stream.shape}")
        t_len = stream.shape[0]
        if t_len == 0:
            self._reject(None, "empty", "zero-length spike train", model=name)
            return None
        policy = self._policy_for(name)
        needs_extend = not policy.fits(t_len)
        if needs_extend and self.overlong == "reject":
            self._reject(None, "overlong",
                         f"{t_len} steps > largest time bucket "
                         f"{policy.time_steps[-1]}", model=name)
            return None
        if self._n_pending >= self.queue_capacity:
            if self.backpressure == "reject":
                self._reject(None, "queue_full",
                             f"queue at capacity {self.queue_capacity}",
                             model=name)
                return None
            self._shed_oldest()
        # grid extension is a side effect (new jit trace) — apply it only
        # once the request is actually admitted
        if needs_extend:
            policy = policy.with_time_bucket(t_len)
            self._policies[name] = policy
            self.metrics.policy_extensions += 1
            if self.tracer is not None:
                self.tracer.anomaly("policy_extension", t=now, model=name,
                                    time_steps=list(policy.time_steps))
            _log.warning("stream_server: %d-step request extended model "
                         "%r's bucket grid to time_steps=%s (new jit trace)",
                         t_len, name, policy.time_steps)
        rid = self._next_rid
        self._next_rid += 1
        if deadline is None:
            s = self.default_slack if slack is None else slack
            deadline = arrival_t + s
        req = Request(rid=rid, stream=stream, arrival_t=arrival_t,
                      deadline=deadline, t_pad=policy.t_bucket(t_len),
                      model=name, generation=entry.generation)
        key = (name, entry.generation, req.t_pad)
        self._entries.setdefault((name, entry.generation), entry)
        if self._n_pending_by.get(name, 0) == 0:
            # fair-queueing catch-up: an idle tenant resumes at the fabric's
            # current virtual time instead of spending banked idle credit
            # monopolizing the executor
            self._vtime[name] = max(self._vtime.get(name, 0.0), self._vglobal)
        self._pending.setdefault(key, collections.deque()).append(req)
        self._n_pending += 1
        self._n_pending_by[name] = self._n_pending_by.get(name, 0) + 1
        self.metrics.admitted += 1
        mm.admitted += 1
        self.metrics.queue_depth = self._n_pending
        self.metrics.max_queue_depth = max(self.metrics.max_queue_depth,
                                           self._n_pending)
        if self.tracer is not None:
            self.tracer.start(rid, model=name, generation=entry.generation,
                              t=arrival_t)
            attrs = {"t_steps": int(t_len), "t_pad": int(req.t_pad),
                     "queue_depth": self._n_pending}
            if deadline != math.inf:
                attrs["deadline"] = float(deadline)
            self.tracer.span(rid, "admit", arrival_t, now, **attrs)
        if len(self._pending[key]) >= policy.max_batch:
            self._dispatch(key, policy.max_batch, forced=False)
        return rid

    # ----------------------------------------------------------- scheduling

    def _est_service(self, name: str, b_pad: int, t_pad: int) -> float:
        if self.service_model is not None:
            return float(self.service_model(b_pad, t_pad))
        return self._ewma.get((name, b_pad, t_pad), 0.0)

    def _trigger_time(self, key: tuple[str, int, int]) -> float:
        """When the group forces a (possibly partial) dispatch: its
        *tightest* member deadline minus the estimated service time for the
        batch we would form now, minus the safety margin.  (Tightest, not
        oldest: a best-effort ``inf``-deadline request admitted first must
        not mask a deadline behind it.  Groups stay below ``max_batch`` —
        full chunks dispatch at submit — so a forced dispatch always takes
        the whole group, tight member included.)"""
        name, _, t_pad = key
        q = self._pending[key]
        policy = self._policy_for(name)
        k = min(len(q), policy.max_batch)
        b_pad = policy.b_bucket(k)
        return (min(r.deadline for r in q)
                - self._est_service(name, b_pad, t_pad)
                - self.dispatch_margin)

    def next_deadline(self) -> float | None:
        """The earliest instant at which :meth:`poll` would force a partial
        dispatch — drivers advance their clock to ``min(next arrival,
        next_deadline())``.  ``None`` when nothing pending has a finite
        trigger."""
        triggers = [self._trigger_time(k) for k, q in self._pending.items()
                    if q]
        finite = [t for t in triggers if t != math.inf]
        return min(finite) if finite else None

    def poll(self) -> list[tuple[int, RequestResult]]:
        """Dispatch every group that is full or past its deadline trigger at
        the current clock time; return all newly completed results.  When
        several groups are due at once, the weighted-fair pick goes first:
        lowest tenant virtual time, then earliest trigger — a flooding
        tenant's backlog queues behind the quieter tenants' due work."""
        while True:
            now = self.now()
            due = []
            for key, q in self._pending.items():
                if not q:
                    continue
                # submit() dispatches a group the moment it reaches
                # max_batch, so pending groups are always partial — only
                # deadlines fire here
                assert len(q) < self._policy_for(key[0]).max_batch
                trig = self._trigger_time(key)
                if trig <= now:
                    due.append((self._vtime.get(key[0], 0.0), trig, key))
            if not due:
                break
            _, _, key = min(due)
            self._dispatch(key, len(self._pending[key]), forced=True)
            # a simulated service period may have advanced the clock past
            # further triggers — loop until nothing is due *now*
        return self.collect()

    def flush(self, model: str | None = None
              ) -> list[tuple[int, RequestResult]]:
        """Dispatch everything still pending (shutdown / end of trace /
        hot-swap drain when ``model`` names one tenant) and return all
        remaining completed results."""
        for key in sorted(self._pending):
            if model is not None and key[0] != model:
                continue
            q = self._pending[key]
            if q:
                assert len(q) < self._policy_for(key[0]).max_batch  # see poll
                self._dispatch(key, len(q), forced=False)
        return self.collect()

    def collect(self) -> list[tuple[int, RequestResult]]:
        """Completed ``(rid, result)`` pairs since the last collection."""
        done, self._completed = self._completed, []
        return done

    # ------------------------------------------------------------ execution

    def _recover_mesh(self, err: DeviceLossError) -> None:
        """Elastic recovery at a dispatch boundary: shrink the serving mesh
        to the survivors (the replicated PackedModel needs no state
        movement), re-round every tenant's batch buckets to the new shard
        count (time buckets — and hence every queued request's ``t_pad`` —
        are preserved), and drop service-time estimates measured on the
        dead topology, tenant by tenant.  The serving twin of the train
        loop's elastic restart."""
        if self.mesh is None:
            raise err   # no mesh to shrink — single-device loss is fatal
        old = self.mesh.size
        self.mesh = shrink_mesh(self.mesh, err.n_lost)   # raises if none left
        names = list(self.registry.names())
        names += [n for n in self._policies if n not in names]
        for name in names:
            p = self._policy_for(name)
            self._policies[name] = BucketPolicy.for_mesh(
                self.mesh.size, batch_sizes=p.batch_sizes,
                time_steps=p.time_steps)
            self.clear_service_estimates(name)
        self.metrics.device_losses += 1
        if self.tracer is not None:
            self.tracer.anomaly("device_loss", t=self.now(),
                                n_lost=err.n_lost, mesh_from=old,
                                mesh_to=self.mesh.size)
        _log.warning("stream_server: lost %d device(s) mid-serving; "
                     "recovered %d -> %d-way mesh, default batch buckets "
                     "now %s (new jit traces)", err.n_lost, old,
                     self.mesh.size, self.policy.batch_sizes)

    def _execute(self, packed, streams: list, plan: BatchPlan, *,
                 seq: int = 0, ts: float | None = None,
                 span_log: list | None = None):
        return execute_plan(
            packed, streams, plan,
            mesh=self.mesh, max_events=self.max_events,
            sn_capacity_rows=self.sn_capacity_rows,
            with_stats=self.with_stats, donate=self.donate,
            seq=seq, ts=ts, now=self.now, span_log=span_log)

    def _noise_probe(self, entry: ModelEntry, reqs, results, streams,
                     plan: BatchPlan) -> None:
        """Shadow-replay this dispatch through the tenant's clean
        (un-perturbed) model and count per-request prediction flips — the
        serving-time accuracy-under-noise signal.  Runs off the metrics
        clock (a measurement, not service work): no telemetry record, no
        EWMA update, no virtual-clock advance.  Each flip is recorded as a
        ``noise_disagreement`` anomaly on the (already completed) trace."""
        clean, _ = self._execute(entry.clean, streams, plan)
        m = self.metrics
        for req, res, ref in zip(reqs, results, clean):
            noisy_pred = int(res.out_spikes.sum(axis=0).argmax())
            clean_pred = int(ref.out_spikes.sum(axis=0).argmax())
            m.noise_probes += 1
            flipped = noisy_pred != clean_pred
            m.noise_disagreements += int(flipped)
            if flipped and self.tracer is not None:
                self.tracer.anomaly("noise_disagreement", t=self.now(),
                                    rid=req.rid, model=entry.name,
                                    noisy_pred=noisy_pred,
                                    clean_pred=clean_pred)

    def _slo_update(self) -> None:
        """Flip between extend-biased and shed mode on the windowed
        deadline-miss rate (see :class:`SLOPolicy`)."""
        if self.slo is None or len(self._slo_misses) < self.slo.min_samples:
            return
        rate = sum(self._slo_misses) / len(self._slo_misses)
        m = self.metrics
        if not m.slo_shedding and rate > self.slo.target_miss_rate:
            m.slo_shedding = True
            m.slo_switches += 1
            self.backpressure, self.overlong = "shed_oldest", "reject"
            _log.warning("stream_server: SLO breach (miss rate %.3f > "
                         "%.3f over %d reqs) — shedding", rate,
                         self.slo.target_miss_rate, len(self._slo_misses))
        elif m.slo_shedding and \
                rate < self.slo.restore_factor * self.slo.target_miss_rate:
            m.slo_shedding = False
            m.slo_switches += 1
            self.backpressure, self.overlong = self._slo_base
            _log.warning("stream_server: SLO recovered (miss rate %.3f) — "
                         "restoring backpressure=%s overlong=%s", rate,
                         *self._slo_base)

    def _dispatch(self, key: tuple[str, int, int], k: int,
                  forced: bool) -> None:
        name, gen, t_pad = key
        entry = self._entry_for((name, gen))
        q = self._pending[key]
        reqs = [q.popleft() for _ in range(k)]
        self._n_pending -= k
        self._n_pending_by[name] -= k
        streams = [r.stream for r in reqs]
        dispatch_t = self.now()
        tr = self.tracer
        # device loss surfaces at the dispatch boundary (from the chaos
        # hook here; from the runtime's watchdog in production); recovery
        # shrinks the mesh and retries the same requests — requests are
        # only lost to explicit shedding, never to hardware loss
        while True:
            b_pad = self._policy_for(name).b_bucket(k)
            plan = BatchPlan(indices=tuple(range(k)), b_pad=b_pad,
                             t_pad=t_pad)
            span_log = [] if tr is not None else None
            try:
                if self.chaos_hook is not None:
                    self.chaos_hook(self.metrics.dispatches)
                results, record = self._execute(
                    entry.packed, streams, plan,
                    seq=self.metrics.dispatches, ts=dispatch_t,
                    span_log=span_log)
                break
            except DeviceLossError as e:
                self._recover_mesh(e)
        self.telemetry.append(record)
        ekey = (name, b_pad, t_pad)
        prev = self._ewma.get(ekey)
        self._ewma[ekey] = record["seconds"] if prev is None else \
            _EWMA_ALPHA * record["seconds"] + (1 - _EWMA_ALPHA) * prev
        service = (float(self.service_model(b_pad, t_pad))
                   if self.service_model is not None
                   else float(record["seconds"]))
        if self.service_model is not None and hasattr(self.clock, "advance"):
            self.clock.advance(service)
        # weighted-fair accounting: this tenant consumed `service` seconds
        # of the shared executor at share `weight`
        v = self._vtime.get(name, self._vglobal) + service / entry.weight
        self._vtime[name] = v
        self._vglobal = v
        end_t = self.now()
        m = self.metrics
        mm = m.model(name)
        m.dispatches += 1
        mm.dispatches += 1
        m.forced_dispatches += int(forced)
        m.fill.append(k / b_pad)
        m.queue_depth = self._n_pending
        if tr is not None:
            # dispatch-level attrs shared by every member trace: the
            # deterministic slice of the telemetry record (``seconds`` is
            # wall-measured and would break byte-identical replays), the
            # scheduler's *why* (deadline-forced vs full bucket), and the
            # per-layer hardware roll-up sampled from the engine results.
            det = {kk: record[kk] for kk in
                   ("seq", "b_pad", "t_pad", "n_requests", "events",
                    "out_spikes")}
            det.update(model=name, generation=gen)
            why = "deadline" if forced else "full_bucket"
            grp_deadline = min(r.deadline for r in reqs)
            hw_layers: list[dict] = []
            if results and results[0].stats:
                for li in range(len(results[0].stats)):
                    hw_layers.append({
                        "layer": li,
                        "events": sum(int(r.stats[li].events.sum())
                                      for r in results),
                        "engine_ops": sum(int(r.stats[li].engine_ops.sum())
                                          for r in results),
                        "cycles": sum(int(r.stats[li].cycles.sum())
                                      for r in results),
                        "rows_touched": sum(
                            int(r.stats[li].rows_touched.sum())
                            for r in results),
                        "util_mean": float(np.mean(
                            [float(np.mean(r.util[li])) for r in results])),
                    })
                if results[0].spec is not None:
                    ereps = [r.energy() for r in results]
                    det["energy_j"] = float(sum(
                        er.dynamic_j + er.static_j for er in ereps))
                    det["tops_per_w"] = float(np.mean(
                        [er.tops_per_w for er in ereps]))
            tr.observe("service_s", end_t - dispatch_t)
            tr.observe("fill", k / b_pad)
        for req, res in zip(reqs, results):
            self._completed.append((req.rid, res))
            if self.on_completion is not None:
                self.on_completion(req.rid, res)
            m.completed += 1
            mm.completed += 1
            m.observe_ttfd(dispatch_t - req.arrival_t)
            m.observe_latency(end_t - req.arrival_t)
            mm.observe_latency(end_t - req.arrival_t)
            missed = end_t > req.deadline
            m.deadline_misses += int(missed)
            mm.deadline_misses += int(missed)
            self._slo_misses.append(missed)
            if tr is not None:
                tr.span(req.rid, "queue", req.arrival_t, dispatch_t)
                sched = {"why": why, "n_requests": k}
                if grp_deadline != math.inf:
                    sched["group_deadline"] = float(grp_deadline)
                tr.span(req.rid, "schedule", dispatch_t, dispatch_t, **sched)
                # lifecycle order: pad -> dispatch -> slice (the pad/slice
                # micro-spans come off execute_plan's span_log)
                for kind, s0, s1, attrs in span_log:
                    if kind == "pad":
                        tr.span(req.rid, kind, s0, s1, **attrs)
                tr.span(req.rid, "dispatch", dispatch_t, end_t, **det)
                for kind, s0, s1, attrs in span_log:
                    if kind != "pad":
                        tr.span(req.rid, kind, s0, s1, **attrs)
                for hw in hw_layers:
                    tr.span(req.rid, "hw", dispatch_t, end_t, **hw)
                tr.span(req.rid, "complete", end_t, end_t,
                        latency_s=end_t - req.arrival_t, missed=missed)
                if missed:
                    tr.anomaly("deadline_miss", t=end_t, rid=req.rid,
                               deadline=float(req.deadline),
                               late_s=end_t - req.deadline, model=name)
                tr.observe("ttfd_s", dispatch_t - req.arrival_t)
                tr.observe("latency_s", end_t - req.arrival_t)
                tr.complete(req.rid, end_t)
        if (entry.noise is not None and self.noise_probe_every
                and mm.dispatches % self.noise_probe_every == 0):
            self._noise_probe(entry, reqs, results, streams, plan)
        if not q:
            # GC: a drained group of a superseded generation releases its
            # pin on the old weights
            del self._pending[key]
            if gen != self.registry.get(name).generation and not any(
                    k[0] == name and k[1] == gen and self._pending[k]
                    for k in self._pending):
                self._entries.pop((name, gen), None)
        self._slo_update()


# ------------------------------------------------------------- trace driver

def serve_trace(server: StreamServer, trace, *, control=()):
    """Replay a time-stamped arrival trace through a :class:`StreamServer`
    on a :class:`VirtualClock`, firing deadline-triggered dispatches at the
    exact instants they become due between arrivals.

    ``trace``: iterable of ``(arrival_t, stream)``, ``(arrival_t, stream,
    deadline)``, or ``(arrival_t, stream, deadline, model)`` tuples,
    non-decreasing in ``arrival_t`` (absolute deadline; ``None`` = the
    server's ``default_slack``; ``model`` ``None`` = the default route).
    ``control`` is an optional list of ``(t, fn)`` pairs — ``fn(server)``
    runs at simulated time ``t``, interleaved with arrivals in time order;
    this is how a trace replays a mid-soak hot-swap
    (``lambda s: s.swap(...)``) deterministically.  When a simulated
    service period (``service_model``) runs past the next arrival, that
    request is admitted as soon as the executor frees up — back-dated to
    its true arrival for latency accounting, exactly like a
    single-threaded server draining a socket between engine calls.
    Remaining requests are flushed after the last arrival.  Returns
    ``(results, rids)``: a dict ``rid -> RequestResult`` and the
    per-trace-entry rid (``None`` where admission rejected the request).
    """
    clock = server.clock
    assert isinstance(clock, VirtualClock), \
        "serve_trace replays simulated time; build the server with a " \
        "VirtualClock (a WallClock server is driven by real arrivals instead)"
    results: dict[int, RequestResult] = {}
    rids: list[int | None] = []

    def drain(pairs):
        for rid, res in pairs:
            results[rid] = res

    def advance_to(t):
        """Run the clock forward to ``t``, firing deadline triggers at the
        exact instants they become due on the way."""
        while True:
            nd = server.next_deadline()
            if nd is None or nd > t:
                break
            clock.advance(max(0.0, nd - clock.now()))
            fired = server.poll()
            drain(fired)
            if not fired:
                break   # estimate moved the trigger; re-check next event
        clock.advance(max(0.0, t - clock.now()))

    control = sorted(control, key=lambda cf: cf[0])
    ci = 0
    prev_t = -math.inf
    for item in trace:
        if len(item) == 2:
            t_a, stream, deadline, model = (*item, None, None)
        elif len(item) == 3:
            t_a, stream, deadline, model = (*item, None)
        else:
            t_a, stream, deadline, model = item
        assert t_a >= prev_t, \
            f"trace arrivals must be non-decreasing ({t_a} < {prev_t})"
        prev_t = t_a
        while ci < len(control) and control[ci][0] <= t_a:
            t_c, fn = control[ci]
            ci += 1
            advance_to(t_c)
            fn(server)
            drain(server.collect())     # e.g. results drained by a hot-swap
        advance_to(t_a)
        rids.append(server.submit(stream, deadline=deadline, model=model,
                                  arrival_t=min(t_a, clock.now())))
        drain(server.poll())
    for t_c, fn in control[ci:]:
        advance_to(t_c)
        fn(server)
        drain(server.collect())
    drain(server.flush())
    return results, rids
