"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantized gradients for the data-parallel all-reduce path: 4x less
DCN/ICI traffic on the ``pod``/``data`` axes.  Error feedback (Seide et al.;
EF-SGD) accumulates the quantization residual locally so the compressed
update is unbiased over time — convergence-safe.

Used by engine/train_loop when ``CompressionConfig.enabled``: gradients are
compressed, (all-reduced in compressed form across pods in a real deployment;
here the compression happens before the pjit-visible reduction so the HLO
collective moves int8), then decompressed + residual-corrected.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    block: int = 256          # per-block scale granularity


def _leaf_compress(g: jax.Array, block: int):
    flat = g.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _leaf_decompress(q: jax.Array, scale: jax.Array, shape, size):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def compress_gradients(grads, residual, cfg: CompressionConfig):
    """(grads + residual) -> (compressed pytree, new residual)."""

    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = _leaf_compress(x, cfg.block)
        approx = _leaf_decompress(q, s, g.shape, g.size)
        return (q, s), x - approx

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = jax.tree.unflatten(tree, [o[0] for o in outs])
    new_res = jax.tree.unflatten(tree, [o[1] for o in outs])
    return comp, new_res


def decompress_gradients(comp, grads_like):
    """Inverse of :func:`compress_gradients`, cast back to each leaf's
    original dtype — decompression happens in float32 internally, and
    silently widening a bf16 gradient tree would break dtype-strict
    optimizer updates (and double the memory the compression saved)."""
    flat_c = jax.tree.leaves(comp, is_leaf=lambda x: isinstance(x, tuple))
    flat_g, tree = jax.tree.flatten(grads_like)
    outs = [_leaf_decompress(q, s, g.shape, g.size).astype(g.dtype)
            for (q, s), g in zip(flat_c, flat_g)]
    return jax.tree.unflatten(tree, outs)


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
