"""Per-layer weight bit-width search (mixed-precision operand path).

MENAGE's C2C-ladder MAC switches one ladder capacitor + SRAM bitline per
magnitude bit, so both the A-SYN footprint and the per-MAC energy scale
~linearly with the stored word width (see :func:`repro.core.energy.
energy_model`).  Dropping a layer from 8 to 4 bits halves its weight SRAM
and nearly halves its MAC energy — *if* the model still computes the same
thing.  This module finds, per layer, the narrowest supported width that
keeps the accelerator's output within an accuracy budget of the 8-bit
baseline.

The search is greedy and descends from 8 bits:

  1. Map + run the all-8-bit model on a probe spike train — the baseline
     output and the per-core dispatch statistics that price energy.
  2. Sensitivity probe: for each layer alone, drop it to the widest sub-8
     choice and measure output agreement against the baseline.  Layers are
     then visited least-sensitive first.
  3. For each layer in that order, walk the sub-8 choices downward and keep
     the narrowest width whose *whole-config* agreement stays at or above
     ``1 - budget``.  Every candidate is a real ``map_model`` + ``run`` —
     requantization changes which small weights collapse to zero, so the
     probe executes the config it scores, not an approximation.

Every step is scored by the analytical energy model (the acceptance
criterion is accuracy; energy strictly decreases with bits for fixed
dispatch work, which is what makes greedy descent safe).  Layer specs with
a pinned ``bits`` field are left untouched — the pin wins over the search,
exactly as it wins over ``map_model(quant_bits=...)``.

``PARETO_POINT_KEYS`` is the shared schema for accuracy/energy/throughput
Pareto points — ``benchmarks/precision_bench.py`` emits them and
``docs/PRECISION.md`` documents them; tests lock all three together.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.accelerator import MappedModel, RunResult, map_model, run
from repro.core.energy import FRAME_CYCLES, AcceleratorSpec, EnergyReport
from repro.core.layers import LayerSpec, as_layer_spec
from repro.core.lif import LIFParams
from repro.core.quant import SUPPORTED_BITS, check_bits

# one Pareto point per bit-width config; the bench artifact and the operator
# docs both follow this schema (locked by tests/test_docs.py /
# tests/test_precision.py)
PARETO_POINT_KEYS = (
    "config",             # label: "w8" / "w4" / "w2" / "mixed"
    "per_layer_bits",     # stored word width per layer (sign-magnitude)
    "agreement",          # fraction of probe output spikes == 8-bit baseline
    "weight_sram_bytes",  # A-SYN bytes physically allocated, all layers
    "energy_per_frame_j", # modeled total energy / time step on the probe
    "tops_per_w",         # modeled efficiency at this config
    "events_per_s",       # measured engine throughput (None when unmeasured)
)


def agreement(a: np.ndarray, b: np.ndarray) -> float:
    """Fraction of identical entries between two spike rasters."""
    a, b = np.asarray(a), np.asarray(b)
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch {a.shape} vs {b.shape}")
    return float((a == b).mean()) if a.size else 1.0


def energy_per_frame(report: EnergyReport, t_steps: int) -> float:
    """Modeled joules per sensor frame (time step) from a probe run."""
    return (report.dynamic_j + report.static_j) / max(int(t_steps), 1)


def pareto_point(config: str, per_layer_bits: "list[int]",
                 result: RunResult, mapped: MappedModel,
                 agreement_frac: float,
                 events_per_s: "float | None" = None) -> dict:
    """Build one Pareto point dict following :data:`PARETO_POINT_KEYS`."""
    t_steps = result.out_spikes.shape[0]
    point = {
        "config": config,
        "per_layer_bits": [int(b) for b in per_layer_bits],
        "agreement": float(agreement_frac),
        "weight_sram_bytes": int(sum(l.sram_bytes for l in mapped.layers)),
        "energy_per_frame_j": energy_per_frame(result.energy, t_steps),
        "tops_per_w": float(result.energy.tops_per_w),
        "events_per_s": None if events_per_s is None else float(events_per_s),
    }
    assert tuple(point) == PARETO_POINT_KEYS
    return point


@dataclasses.dataclass(frozen=True)
class SearchStep:
    """One candidate evaluated by the greedy search."""

    layer: int
    bits: int                 # candidate width tried for this layer
    agreement: float          # whole-config agreement vs 8-bit baseline
    energy_per_frame_j: float
    accepted: bool


@dataclasses.dataclass(frozen=True)
class PrecisionSearchResult:
    per_layer_bits: list[int]
    agreement: float                  # final config vs 8-bit baseline
    baseline_energy: EnergyReport     # all-8-bit probe run
    energy: EnergyReport              # final config probe run
    history: list[SearchStep]

    @property
    def energy_reduction(self) -> float:
        """(baseline - final) / baseline total modeled energy."""
        base = self.baseline_energy.dynamic_j + self.baseline_energy.static_j
        fin = self.energy.dynamic_j + self.energy.static_j
        return (base - fin) / base if base > 0 else 0.0


def search_bits(weights: "list[np.ndarray | LayerSpec]",
                spec: AcceleratorSpec,
                probe_spikes: np.ndarray, *,
                lif: LIFParams = LIFParams(),
                budget: float = 0.02,
                choices: "tuple[int, ...]" = (8, 4, 2),
                frame_cycles: "int | None" = FRAME_CYCLES,
                method: str = "auto",
                compress: bool = False) -> PrecisionSearchResult:
    """Greedy per-layer bit-width search under an accuracy budget.

    ``probe_spikes`` is a ``[T, n_in]`` spike train; agreement is measured
    on the accelerator's output raster against the all-8-bit baseline.
    ``budget`` is the tolerated disagreement fraction (0.02 = accept while
    >= 98% of output spikes match).  ``choices`` lists the candidate widths
    (must be a subset of :data:`repro.core.quant.SUPPORTED_BITS`; 8 must be
    included — it is the baseline).  Returns the chosen per-layer widths
    plus the full audit trail of evaluated candidates.
    """
    choices = tuple(sorted({check_bits(int(b)) for b in choices},
                           reverse=True))
    if choices[0] != 8:
        raise ValueError(f"choices must include the 8-bit baseline, got "
                         f"{choices} (supported: {SUPPORTED_BITS})")
    if not 0.0 <= budget < 1.0:
        raise ValueError(f"budget must be in [0, 1), got {budget}")
    probe = np.asarray(probe_spikes, dtype=np.float32)
    if probe.ndim != 2:
        raise ValueError(f"probe_spikes must be [T, n_in], got {probe.shape}")
    specs = [as_layer_spec(w) for w in weights]
    pinned = [ls.bits for ls in specs]   # spec pins win over the search
    n_layers = len(specs)
    t_steps = probe.shape[0]

    def evaluate(bits_list: "list[int]") -> tuple[MappedModel, RunResult]:
        mapped = map_model(specs, spec, lif=lif, quant_bits=list(bits_list),
                           method=method, compress=compress)
        return mapped, run(mapped, probe, frame_cycles=frame_cycles)

    base_bits = [8 if p is None else p for p in pinned]
    _, base_res = evaluate(base_bits)
    base_out = base_res.out_spikes
    floor = 1.0 - budget
    sub8 = [b for b in choices if b < 8]
    history: list[SearchStep] = []
    current = list(base_bits)
    cur_res = base_res
    cur_agree = 1.0

    if sub8:
        # sensitivity probe: each unpinned layer alone at the widest sub-8
        # width; least-sensitive layers get first claim on the budget
        sens: list[tuple[float, int]] = []
        for li in range(n_layers):
            if pinned[li] is not None:
                continue
            trial = list(base_bits)
            trial[li] = sub8[0]
            _, res = evaluate(trial)
            sens.append((1.0 - agreement(res.out_spikes, base_out), li))
        sens.sort()
        for _, li in sens:
            for b in sub8:
                if b >= current[li]:
                    continue
                trial = list(current)
                trial[li] = b
                _, res = evaluate(trial)
                a = agreement(res.out_spikes, base_out)
                ok = a >= floor
                history.append(SearchStep(
                    layer=li, bits=b, agreement=a,
                    energy_per_frame_j=energy_per_frame(res.energy, t_steps),
                    accepted=ok))
                if not ok:
                    break        # narrower widths only disagree more
                current, cur_res, cur_agree = trial, res, a
    return PrecisionSearchResult(
        per_layer_bits=current, agreement=cur_agree,
        baseline_energy=base_res.energy, energy=cur_res.energy,
        history=history)
