"""Chaos scenarios replay deterministically, and the server survives them.

The soak harness's failure scripts (engine/chaos.py) are only trustworthy
if they are reproducible: every scenario here runs twice on a VirtualClock
and must produce identical metrics and bit-identical spike outputs — no
wall-clock flakiness in tier 1.  Device-loss scenarios need >= 2 devices,
so they run in a spoofed-device subprocess (same pattern as
tests/test_sharded_engine.py).  The socket front end is exercised over a
real localhost connection: what a client reads off the wire must be
bit-exact against the single-device engine.
"""

import os
import socket
import subprocess
import sys

import numpy as np
import pytest

from repro.core.accelerator import map_model
from repro.core.energy import AcceleratorSpec
from repro.core.lif import LIFParams
from repro.engine import run_batched
from repro.engine.chaos import (ARRIVAL_MODES, SCENARIOS, ChaosScenario,
                                make_chaos_hook, run_scenario,
                                synth_arrival_trace)
from repro.engine.sharded_run import DeviceLossError

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SPEC = AcceleratorSpec("chaos-test", n_cores=3, n_engines=4, n_caps=8,
                       weight_mem_bytes=1 << 18)


def _model(rng, sizes=(14, 12, 6)):
    ws = []
    for i in range(len(sizes) - 1):
        w = rng.normal(0, 0.5, (sizes[i], sizes[i + 1])).astype(np.float32)
        w[rng.random(w.shape) > 0.6] = 0
        ws.append(w)
    return map_model(ws, SPEC, lif=LIFParams(beta=0.8, threshold=0.5))


# ---------------------------------------------------------- arrival synth

def test_arrival_modes_produce_valid_traces():
    """Every mode yields n non-decreasing (t, stream, deadline) tuples."""
    for mode in ARRIVAL_MODES:
        trace = synth_arrival_trace(20, 14, mode=mode, seed=3)
        assert len(trace) == 20
        times = [t for t, _, _ in trace]
        assert times == sorted(times)
        for t_a, stream, deadline in trace:
            assert stream.ndim == 2 and stream.shape[1] == 14
            assert deadline > t_a


def test_adversarial_trace_mixes_tight_and_loose_deadlines():
    """Floods carry quarter slack, famines full slack — the engineered
    worst case for batch formation actually shows up in the trace."""
    trace = synth_arrival_trace(24, 14, mode="adversarial", slack=0.4,
                                t_lo=3, t_hi=12, seed=0)
    slacks = {round(d - t, 6) for t, _, d in trace}
    assert slacks == {0.1, 0.4}
    lengths = {s.shape[0] for _, s, _ in trace}
    assert lengths == {3, 12}


def test_unknown_arrival_mode_rejected():
    with pytest.raises(ValueError, match="unknown arrival mode"):
        synth_arrival_trace(4, 14, mode="lunar")


def test_chaos_hook_fires_once_per_scripted_ordinal():
    hook = make_chaos_hook([(2, 1)])
    hook(0)
    hook(1)
    with pytest.raises(DeviceLossError) as ei:
        hook(2)
    assert ei.value.n_lost == 1
    hook(2)     # the recovery retry at the same ordinal proceeds


# ----------------------------------------------- deterministic replays

def _nonmesh_scenarios():
    return [s for s in SCENARIOS.values() if not s.needs_mesh]


def test_every_nonmesh_scenario_replays_deterministically(rng):
    """Two runs of the same scenario: identical metrics, bit-identical
    outputs — the property that makes soak logic tier-1 testable."""
    model = _model(rng)
    for sc in _nonmesh_scenarios():
        r1, rids1, m1 = run_scenario(model, sc)
        r2, rids2, m2 = run_scenario(model, sc)
        assert m1 == m2, f"{sc.name}: metrics differ between replays"
        assert rids1 == rids2
        assert r1.keys() == r2.keys()
        for rid in r1:
            assert np.array_equal(r1[rid].out_spikes, r2[rid].out_spikes), \
                f"{sc.name}: outputs differ for rid {rid}"


def test_scenarios_conserve_requests(rng):
    """completed + rejected + shed == submitted, chaos or not — no request
    silently vanishes."""
    model = _model(rng)
    for sc in _nonmesh_scenarios():
        _, _, m = run_scenario(model, sc)
        assert m["completed"] + m["rejected"] + m["shed"] == m["submitted"], \
            f"{sc.name}: request leak"
        assert m["scenario"] == sc.name
        assert m["makespan_s"] > 0.0


def test_baseline_scenario_is_bit_exact_vs_run_batched(rng):
    """A scenario-served request equals the same stream run alone through
    the single-device engine (padding/virtual-clock machinery is
    numerically invisible)."""
    model = _model(rng)
    packed = model.pack()
    sc = SCENARIOS["baseline"]
    results, rids, _ = run_scenario(packed, sc)
    trace = synth_arrival_trace(sc.n_requests, packed.n_in,
                                mode=sc.arrivals, rate=sc.rate,
                                slack=sc.slack, t_lo=sc.t_lo, t_hi=sc.t_hi,
                                seed=sc.seed)
    i = int(np.argmax([s.shape[0] for _, s, _ in trace]))
    assert rids[i] is not None
    alone = run_batched(packed, trace[i][1][None], with_stats=False)
    assert np.array_equal(results[rids[i]].out_spikes, alone.out_spikes[0])


def test_analog_noise_scenario_tracks_agreement(rng):
    """Serving through a noisy device instance populates the
    accuracy-under-noise metrics: every dispatch probed, agreement in
    [0, 1], and the perturbation actually changes some outputs."""
    model = _model(rng)
    _, _, m = run_scenario(model, SCENARIOS["analog_noise"])
    assert m["noise_probes"] == m["completed"] > 0
    assert 0.0 <= m["noise_agreement"] <= 1.0
    # the noisy instance must differ from the clean one somewhere
    clean, _, _ = run_scenario(model, SCENARIOS["baseline"])
    noisy, _, _ = run_scenario(
        model, ChaosScenario(name="noise-vs-clean", description="",
                             noise_sigma=0.05))
    diff = any(not np.array_equal(clean[r].out_spikes, noisy[r].out_spikes)
               for r in clean)
    assert diff, "5% analog noise changed no output at all"


def test_slo_scenario_flips_to_shedding(rng):
    """Overload with tight deadlines trips the SLO controller: at least
    one switch, shedding engaged, and sheds actually recorded."""
    model = _model(rng)
    _, _, m = run_scenario(model, SCENARIOS["slo_shed"])
    assert m["slo_switches"] >= 1
    assert m["shed"] + m["rejected"] > 0
    assert m["deadline_miss_rate"] > SCENARIOS["slo_shed"].slo.target_miss_rate


# ----------------------------------------------- device loss (spoofed mesh)

def _run(script: str, devices: int = 2) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    pre = (f'import os; os.environ["XLA_FLAGS"] = '
           f'"--xla_force_host_platform_device_count={devices}"\n')
    p = subprocess.run([sys.executable, "-c", pre + script],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=600)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}"
    return p.stdout


def test_device_loss_scenarios_recover_on_shrunken_mesh():
    """device_loss and blackout on a spoofed 2-device mesh: the scripted
    loss fires, the server recovers onto 1 device, every admitted request
    is still served, and both replays are deterministic."""
    out = _run("""
import numpy as np
from repro.core.accelerator import map_model
from repro.core.energy import AcceleratorSpec
from repro.core.lif import LIFParams
from repro.engine.chaos import SCENARIOS, run_scenario
from repro.engine.sharded_run import snn_serve_mesh

rng = np.random.default_rng(0)
ws = []
for a, b in [(14, 12), (12, 6)]:
    w = rng.normal(0, 0.5, (a, b)).astype(np.float32)
    w[rng.random(w.shape) > 0.6] = 0
    ws.append(w)
model = map_model(ws, AcceleratorSpec("t", n_cores=3, n_engines=4, n_caps=8,
                                      weight_mem_bytes=1 << 18),
                  lif=LIFParams(beta=0.8, threshold=0.5))
mesh = snn_serve_mesh(None)
assert mesh.size == 2
for name in ("device_loss", "blackout"):
    sc = SCENARIOS[name]
    r1, _, m1 = run_scenario(model, sc, mesh=mesh)
    r2, _, m2 = run_scenario(model, sc, mesh=mesh)
    assert m1 == m2, f"{name}: not deterministic"
    assert all(np.array_equal(r1[k].out_spikes, r2[k].out_spikes)
               for k in r1)
    assert m1["device_losses"] == len(sc.lose_devices), name
    assert (m1["mesh_size_start"], m1["mesh_size_end"]) == (2, 1), name
    assert m1["served_all_admitted"], f"{name}: lost admitted requests"
    print(name, "OK", m1["completed"], m1["noise_agreement"])
""")
    assert "device_loss OK" in out
    assert "blackout OK" in out


def test_losing_every_device_is_fatal():
    """Recovery needs survivors: shrinking past the last device raises
    instead of serving on nothing."""
    out = _run("""
import numpy as np
from repro.engine.sharded_run import DeviceLossError, shrink_mesh, \
    snn_serve_mesh

mesh = snn_serve_mesh(None)
small = shrink_mesh(mesh, 1)
assert small.size == 1 and small.axis_names == mesh.axis_names
try:
    shrink_mesh(small, 1)
except DeviceLossError as e:
    print("fatal OK", e.n_lost)
""")
    assert "fatal OK" in out


# ------------------------------------------------------------ live socket

def test_socket_server_round_trip_is_bit_exact(rng):
    """A real localhost connection through the ingest protocol: every
    request answered, results bit-exact vs run_batched, overlong requests
    rejected with a reason."""
    from repro.engine.serving import BucketPolicy
    from repro.launch.socket_serve import (SpikeClient, SpikeSocketServer,
                                           serving_thread)
    model = _model(rng)
    packed = model.pack()
    streams = [(rng.random((t, packed.n_in)) < 0.3).astype(np.float32)
               for t in (3, 5, 9, 4, 7, 9)]
    srv = SpikeSocketServer(
        packed, policy=BucketPolicy(batch_sizes=(2, 4), time_steps=(10,)),
        port=0, overlong="reject")
    host, port = srv.address
    with serving_thread(srv, max_requests=len(streams)):
        cli = SpikeClient(host, port)
        for s in streams:
            cli.send(s)
        overlong = cli.send(
            (rng.random((40, packed.n_in)) < 0.3).astype(np.float32))
        cli.recv_all()
        cli.close()
    assert len(cli.results) == len(streams)
    assert overlong in cli.rejections
    assert "overlong" in cli.rejections[overlong]
    for i, s in enumerate(streams):
        alone = run_batched(packed, s[None], with_stats=False)
        assert np.array_equal(cli.results[i], alone.out_spikes[0]), \
            f"socket result {i} != run_batched"
    assert srv.server.metrics.snapshot()["completed"] == len(streams)


def test_socket_malformed_request_rejected_server_survives(rng):
    """A protocol-valid REQUEST whose raster width disagrees with the
    model's n_in (or whose claimed T is absurd) must answer with a REJECT
    frame — not raise out of the event loop and kill serving for every
    other client.  A good request after the malformed ones still serves
    bit-exact."""
    from repro.engine.serving import BucketPolicy
    from repro.launch.socket_serve import (SpikeClient, SpikeSocketServer,
                                           serving_thread)
    model = _model(rng)
    packed = model.pack()
    srv = SpikeSocketServer(
        packed, policy=BucketPolicy(batch_sizes=(1,), time_steps=(10,)),
        port=0, max_request_steps=64)
    host, port = srv.address
    good = (rng.random((5, packed.n_in)) < 0.3).astype(np.float32)
    with serving_thread(srv, max_requests=1):
        cli = SpikeClient(host, port, timeout=60)
        bad_width = cli.send(
            (rng.random((5, packed.n_in + 3)) < 0.3).astype(np.float32))
        too_long = cli.send(
            (rng.random((65, packed.n_in)) < 0.3).astype(np.float32))
        ok = cli.send(good)
        cli.recv_all()
        cli.close()
    assert "bad_shape" in cli.rejections[bad_width]
    assert "overlong" in cli.rejections[too_long]
    alone = run_batched(packed, good[None], with_stats=False)
    assert np.array_equal(cli.results[ok], alone.out_spikes[0])


def test_socket_halfclose_drains_via_idle_flush(rng):
    """A client that sends one best-effort request and half-closes its
    write side (EOF at the server) still gets its result: EOF unregisters
    the read side, so the permanently-readable half-closed socket cannot
    busy-spin select() and starve the idle-flush path the pending request
    needs to dispatch."""
    from repro.engine.serving import BucketPolicy
    from repro.launch.socket_serve import (SpikeClient, SpikeSocketServer,
                                           serving_thread)
    model = _model(rng)
    packed = model.pack()
    srv = SpikeSocketServer(
        packed, policy=BucketPolicy(batch_sizes=(4,), time_steps=(10,)),
        port=0)
    host, port = srv.address
    s = (rng.random((6, packed.n_in)) < 0.3).astype(np.float32)
    with serving_thread(srv, max_requests=1, idle_flush_s=0.05):
        cli = SpikeClient(host, port, timeout=60)
        rid = cli.send(s)
        cli.sock.shutdown(socket.SHUT_WR)   # EOF at the server
        cli.recv_all()
        cli.close()
    alone = run_batched(packed, s[None], with_stats=False)
    assert np.array_equal(cli.results[rid], alone.out_spikes[0])


def test_socket_shed_rejections_delivered_from_outbox(rng):
    """A queued request displaced by shed_oldest backpressure after
    admission is answered with a REJECT frame via the server's rejection
    callback outbox, and the survivors still serve."""
    from repro.engine.serving import BucketPolicy
    from repro.launch.socket_serve import (SpikeClient, SpikeSocketServer,
                                           serving_thread)
    model = _model(rng)
    packed = model.pack()
    srv = SpikeSocketServer(
        packed, policy=BucketPolicy(batch_sizes=(4,), time_steps=(10,)),
        port=0, queue_capacity=2, backpressure="shed_oldest")
    host, port = srv.address
    streams = [(rng.random((4, packed.n_in)) < 0.3).astype(np.float32)
               for _ in range(3)]
    with serving_thread(srv, max_requests=2, idle_flush_s=0.2):
        cli = SpikeClient(host, port, timeout=60)
        rids = [cli.send(s) for s in streams]
        cli.recv_all()
        cli.close()
    assert "shed" in cli.rejections[rids[0]]
    assert set(cli.results) == {rids[1], rids[2]}
