"""Benchmark driver — one section per paper table/figure + the roofline
report.  Prints ``name,value,derived`` CSV lines (see each module)."""

from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import accuracy, energy, kernels_bench, mapping_bench, memory_util
    sections = [
        ("accuracy (Table I)", accuracy.main),
        ("energy (Table II)", lambda: energy.main(fast=False)),
        ("memory utilization (Figs 6-7)", memory_util.main),
        ("ILP mapping (SIII-D)", mapping_bench.main),
        ("Pallas kernels", kernels_bench.main),
    ]
    try:
        from benchmarks import roofline
        sections.append(("roofline (dry-run)", roofline.main))
    except Exception:
        pass

    failures = 0
    for name, fn in sections:
        print(f"# --- {name} ---")
        t0 = time.monotonic()
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"# {name}: {time.monotonic()-t0:.1f}s")
    if failures:
        sys.exit(f"{failures} benchmark sections failed")


if __name__ == "__main__":
    main()
