"""Multi-tenant serving fabric: registry, routing, hot-swap, fair share.

Contracts under test (engine/registry.py + the multi-tenant parts of
engine/stream_server.py and launch/socket_serve.py):

  * the registry is the single source of truth for named tenants — typed
    errors for unknown names, duplicate registration refused, hot-swap
    bumps the generation and inherits policy/noise/weight unless
    overridden;
  * routing is bit-exact: a request submitted under a model name is
    served by exactly that tenant's weights, identical to ``run_batched``
    on that model alone, regardless of how tenants interleave;
  * hot-swap loses nothing: requests admitted before ``swap()`` are
    served on the OLD weights (drained at the swap point), requests after
    on the NEW — under live traffic, with zero rejects and zero drops;
  * per-tenant isolation: EWMA service estimates key by model (and clear
    per tenant), backpressure sheds the flooding tenant's work, and a
    burst from one tenant does not starve another's deadlines;
  * the per-model metrics surface is schema-locked like every other
    operator surface, and the socket front end routes v2 frames, defaults
    v1 frames, answers ADMIN control frames, and isolates a corrupt
    connection from its neighbours.
"""

import math
import socket

import numpy as np
import pytest

from repro.core.accelerator import map_model
from repro.core.energy import AcceleratorSpec
from repro.core.lif import LIFParams
from repro.engine import (DEFAULT_MODEL, METRIC_KEYS, PER_MODEL_KEYS,
                          BucketPolicy, ModelRegistry, ServerMetrics,
                          StreamServer, UnknownModelError, VirtualClock,
                          run_batched, serve_trace, trace_count)
from repro.engine.chaos import SCENARIOS, run_scenario, swap_model_for

SPEC = AcceleratorSpec("tenant-test", n_cores=3, n_engines=4, n_caps=8,
                       weight_mem_bytes=1 << 18)


def _model(rng, sizes=(14, 12, 6)):
    ws = []
    for i in range(len(sizes) - 1):
        w = rng.normal(0, 0.5, (sizes[i], sizes[i + 1])).astype(np.float32)
        w[rng.random(w.shape) > 0.6] = 0
        ws.append(w)
    return map_model(ws, SPEC, lif=LIFParams(beta=0.8, threshold=0.7))


@pytest.fixture(scope="module")
def packed_a():
    return _model(np.random.default_rng(7)).pack()


@pytest.fixture(scope="module")
def packed_a2():
    """Same layer shapes as packed_a, different weights — a hot-swap
    payload that needs no new jit traces."""
    return _model(np.random.default_rng(8)).pack()


@pytest.fixture(scope="module")
def packed_b():
    return _model(np.random.default_rng(9), sizes=(11, 10, 5)).pack()


def _policy():
    return BucketPolicy(batch_sizes=(1, 2, 4), time_steps=(4, 8))


def _streams(rng, n_in, lengths, p=0.35):
    return [(rng.random((t, n_in)) < p).astype(np.float32) for t in lengths]


def _registry(packed_a, packed_b):
    reg = ModelRegistry()
    reg.register("alpha", packed_a, policy=_policy())
    reg.register("beta", packed_b, policy=_policy())
    return reg


def _ref(packed, stream):
    return run_batched(packed, stream[None],
                       with_stats=False).out_spikes[0][:stream.shape[0]]


# ---------------------------------------------------------------- registry

def test_registry_register_get_default(packed_a, packed_b):
    reg = _registry(packed_a, packed_b)
    assert len(reg) == 2 and set(reg.names()) == {"alpha", "beta"}
    assert "alpha" in reg and "gamma" not in reg
    assert reg.get("beta").packed is packed_b
    # first registration is the default route unless told otherwise
    assert reg.default == "alpha"
    assert reg.get().packed is packed_a
    assert reg.get(None).name == "alpha"
    reg2 = ModelRegistry(default="beta")
    reg2.register("alpha", packed_a, policy=_policy())
    reg2.register("beta", packed_b, policy=_policy())
    assert reg2.get().name == "beta"


def test_registry_unknown_model_error_is_typed_and_names_known(packed_a,
                                                               packed_b):
    reg = _registry(packed_a, packed_b)
    with pytest.raises(UnknownModelError) as ei:
        reg.get("gamma")
    assert ei.value.name == "gamma"
    assert set(ei.value.known) == {"alpha", "beta"}
    assert "gamma" in str(ei.value) and "alpha" in str(ei.value)
    with pytest.raises(UnknownModelError):
        ModelRegistry().get()           # empty registry has no default


def test_registry_refuses_duplicates_and_empty_names(packed_a):
    reg = ModelRegistry()
    reg.register("alpha", packed_a, policy=_policy())
    with pytest.raises(ValueError, match="swap"):
        reg.register("alpha", packed_a, policy=_policy())
    with pytest.raises(ValueError):
        reg.register("", packed_a, policy=_policy())
    with pytest.raises(ValueError):
        reg.register("x", packed_a, policy=_policy(), weight=0.0)


def test_registry_swap_bumps_generation_and_inherits(packed_a, packed_a2):
    reg = ModelRegistry()
    reg.register("alpha", packed_a, policy=_policy(), weight=2.5)
    e1 = reg.get("alpha")
    assert e1.generation == 1
    e2 = reg.swap("alpha", packed_a2)
    assert e2.generation == 2 and e2.packed is packed_a2
    assert e2.weight == 2.5                      # inherited
    assert e2.policy == e1.policy                # inherited
    assert reg.get("alpha") is e2                # atomically installed
    with pytest.raises(UnknownModelError):
        reg.swap("gamma", packed_a2)


def test_registry_packs_mapped_models(rng):
    """register() accepts a MappedModel and packs it — callers hold one
    object, the registry normalizes to the engine's PackedModel."""
    mapped = _model(rng)
    reg = ModelRegistry()
    entry = reg.register("m", mapped, policy=_policy())
    assert entry.packed.n_in == 14
    assert hasattr(entry.packed, "layers")       # PackedModel, not Mapped


# ----------------------------------------------------------------- routing

def test_routing_bit_exact_across_interleaved_tenants(rng, packed_a,
                                                      packed_b):
    """Interleaved submits to two tenants each serve on exactly their own
    weights — bit-identical to run_batched per model."""
    server = StreamServer(_registry(packed_a, packed_b),
                          clock=VirtualClock(), with_stats=False)
    sa = _streams(rng, 14, [3, 7, 5, 8])
    sb = _streams(rng, 11, [4, 6, 2, 8])
    rids = []
    for a, b in zip(sa, sb):
        rids.append(("alpha", a, server.submit(a, model="alpha")))
        rids.append(("beta", b, server.submit(b, model="beta")))
    done = dict(server.flush())
    assert len(done) == len(rids)
    for name, s, rid in rids:
        ref = _ref({"alpha": packed_a, "beta": packed_b}[name], s)
        assert np.array_equal(done[rid].out_spikes, ref), \
            f"{name} rid {rid} served on the wrong tenant's weights"
    snap = server.metrics.snapshot()
    assert snap["models"] == 2
    assert snap["per_model"]["alpha"]["completed"] == len(sa)
    assert snap["per_model"]["beta"]["completed"] == len(sb)


def test_submit_unknown_model_raises_before_side_effects(rng, packed_a,
                                                         packed_b):
    server = StreamServer(_registry(packed_a, packed_b),
                          clock=VirtualClock())
    with pytest.raises(UnknownModelError, match="gamma"):
        server.submit(_streams(rng, 14, [4])[0], model="gamma")
    snap = server.metrics.snapshot()
    assert snap["submitted"] == 0 and snap["rejected"] == 0


def test_bad_shape_error_names_the_tenant(rng, packed_a, packed_b):
    server = StreamServer(_registry(packed_a, packed_b),
                          clock=VirtualClock())
    with pytest.raises(ValueError, match="beta"):
        server.submit(_streams(rng, 14, [4])[0], model="beta")


def test_single_tenant_constructor_still_works(rng, packed_a):
    """The pre-registry constructor (packed + policy kwarg) builds a
    one-tenant fabric under the default route — the whole existing
    single-model surface is this path."""
    server = StreamServer(packed_a, policy=_policy(), clock=VirtualClock())
    assert server.registry.default == DEFAULT_MODEL
    assert server.packed is packed_a
    s = _streams(rng, 14, [5])[0]
    rid = server.submit(s)                       # no model name
    done = dict(server.flush())
    assert np.array_equal(done[rid].out_spikes, _ref(packed_a, s))


# ------------------------------------------------------ per-tenant isolation

def test_ewma_keyed_by_model_and_cleared_per_tenant(rng, packed_a,
                                                    packed_b):
    server = StreamServer(_registry(packed_a, packed_b),
                          clock=VirtualClock())
    for s in _streams(rng, 14, [3, 7]):
        server.submit(s, model="alpha")
    for s in _streams(rng, 11, [3, 7]):
        server.submit(s, model="beta")
    server.flush()
    names = {k[0] for k in server._ewma}
    assert names == {"alpha", "beta"}, \
        "service estimates must key by tenant, not just bucket shape"
    server.clear_service_estimates("alpha")
    assert {k[0] for k in server._ewma} == {"beta"}
    server.clear_service_estimates()             # and all at once
    assert server._ewma == {}


def test_shed_oldest_targets_the_flooding_tenant(rng, packed_a, packed_b):
    """Backpressure by displacement picks its victim from the tenant with
    the deepest backlog — a quiet tenant's lone request survives a
    neighbour's flood."""
    server = StreamServer(_registry(packed_a, packed_b),
                          clock=VirtualClock(), queue_capacity=4,
                          backpressure="shed_oldest",
                          service_model=lambda b, t: 0.001)
    quiet = server.submit(_streams(rng, 14, [5])[0], model="alpha")
    flood = [server.submit(s, model="beta")
             for s in _streams(rng, 11, [5] * 7)]
    done = dict(server.flush())
    assert quiet in done, "quiet tenant's request was evicted by the flood"
    shed = [r for r in server.rejections if r.reason == "shed"]
    assert shed and all(r.model == "beta" for r in shed), \
        f"shed victims must come from the flooding tenant: {shed}"
    snap = server.metrics.snapshot()
    assert snap["per_model"]["alpha"]["shed"] == 0
    assert snap["per_model"]["beta"]["shed"] == len(shed)
    assert flood.count(None) == 0                # shed displaces, not rejects


def test_burst_does_not_starve_other_tenants_deadlines(rng, packed_a,
                                                       packed_b):
    """Weighted-fair pick under contention: a best-effort flood from one
    tenant queued ahead of another tenant's deadline work must not push
    the latter past its slack."""
    server = StreamServer(_registry(packed_a, packed_b),
                          clock=VirtualClock(), queue_capacity=64,
                          service_model=lambda b, t: 0.004)
    trace = [(0.0, s, None, "beta") for s in _streams(rng, 11, [8] * 12)]
    trace += [(0.001 * (i + 1), s, 0.001 * (i + 1) + 0.05, "alpha")
              for i, s in enumerate(_streams(rng, 14, [4, 4, 4]))]
    trace.sort(key=lambda e: e[0])
    serve_trace(server, trace)
    snap = server.metrics.snapshot()
    alpha = snap["per_model"]["alpha"]
    assert alpha["completed"] == 3 and alpha["deadline_misses"] == 0, \
        f"flooded out of its deadlines: {alpha}"
    assert snap["per_model"]["beta"]["completed"] == 12


# ---------------------------------------------------------------- hot-swap

def test_hot_swap_under_live_traffic_is_bit_exact(rng, packed_a, packed_a2,
                                                  packed_b):
    """The swap drains in-flight work on the OLD weights and routes every
    later submit to the NEW — zero drops, zero rejects, every result
    bit-exact against the weights that were live when it was admitted.
    The other tenant is untouched throughout."""
    server = StreamServer(_registry(packed_a, packed_b),
                          clock=VirtualClock(), with_stats=False,
                          service_model=lambda b, t: 0.002)
    swap_t = 0.05
    pre = [(0.01 * i, s, None, "alpha")
           for i, s in enumerate(_streams(rng, 14, [3, 7, 5]))]
    post = [(swap_t + 0.01 * (i + 1), s, None, "alpha")
            for i, s in enumerate(_streams(rng, 14, [5, 3, 8]))]
    other = [(0.015 + 0.02 * i, s, None, "beta")
             for i, s in enumerate(_streams(rng, 11, [4, 6, 5]))]
    trace = sorted(pre + post + other, key=lambda e: e[0])
    control = [(swap_t, lambda srv: srv.swap("alpha", packed_a2))]
    results, rids = serve_trace(server, trace, control=control)
    assert None not in rids, "hot-swap dropped or rejected a request"
    assert len(results) == len(trace)
    for (t_a, s, _, name), rid in zip(trace, rids):
        if name == "beta":
            live = packed_b
        elif t_a < swap_t:
            live = packed_a               # admitted before the swap: drained
        else:
            live = packed_a2              # admitted after: new generation
        assert np.array_equal(results[rid].out_spikes, _ref(live, s)), \
            f"request at t={t_a} ({name}) served on the wrong generation"
    snap = server.metrics.snapshot()
    assert snap["rejected"] == 0 and snap["shed"] == 0
    assert snap["hot_swaps"] == 1
    assert snap["per_model"]["alpha"]["hot_swaps"] == 1
    assert snap["per_model"]["beta"]["hot_swaps"] == 0
    assert server.registry.get("alpha").generation == 2


def test_swap_does_not_eat_uncollected_results(rng, packed_a, packed_a2):
    """Results completed before the swap (but not yet collected) survive
    it — the drain must append to the completion queue, not replace it."""
    server = StreamServer(packed_a, policy=_policy(), clock=VirtualClock())
    early = [server.submit(s)
             for s in _streams(rng, 14, [4] * 4)]   # full group: dispatches
    late = server.submit(_streams(rng, 14, [6])[0])  # still pending
    server.swap(DEFAULT_MODEL, packed_a2)
    done = dict(server.collect())
    assert set(early + [late]) <= set(done), \
        "swap() lost results that completed before it ran"


def test_same_shape_swap_adds_no_jit_traces(rng, packed_a, packed_a2):
    """A swap to same-shaped weights reuses every compiled bucket — the
    whole point of bucketed serving is that weights are arguments, not
    constants."""
    server = StreamServer(packed_a, policy=_policy(), clock=VirtualClock())
    for s in _streams(rng, 14, [3, 7]):
        server.submit(s)
    server.flush()
    n0 = trace_count()
    server.swap(DEFAULT_MODEL, packed_a2)
    for s in _streams(rng, 14, [3, 7]):
        server.submit(s)
    server.flush()
    assert trace_count() == n0, \
        "hot-swap to same-shaped weights must not retrace"


# ------------------------------------------------------------ metrics schema

def test_per_model_metrics_schema_locked():
    """The per-tenant snapshot keys are the BENCH_multitenant.json and
    docs/SERVING.md surface — locked like METRIC_KEYS."""
    assert PER_MODEL_KEYS == (
        "submitted", "admitted", "rejected", "shed", "completed",
        "deadline_misses", "deadline_miss_rate", "dispatches", "hot_swaps",
        "p50_latency_s", "p99_latency_s", "recent_p50_latency_s",
        "recent_p99_latency_s")
    m = ServerMetrics()
    snap = m.model("x").snapshot()
    assert tuple(snap.keys()) == PER_MODEL_KEYS
    assert snap["deadline_miss_rate"] == 0.0
    full = m.snapshot()
    assert tuple(full.keys()) == METRIC_KEYS
    assert full["per_model"] == {"x": snap} and full["models"] == 1


# ----------------------------------------------------------- chaos scenario

def test_multi_tenant_scenario_gates(packed_a):
    """The soak scenario's promises: both tenants conserved (nothing lost),
    the mid-soak hot-swap fired, and the adversarial burst did not starve
    the steady tenant's deadlines."""
    sc = SCENARIOS["multi_tenant"]
    assert sc.tenants and sc.swap_tenant == "steady"
    _, _, m = run_scenario(packed_a, sc)
    assert m["hot_swaps"] == 1
    assert m["completed"] + m["rejected"] + m["shed"] == m["requests"]
    per = m["per_model"]
    assert set(per) == {t.name for t in sc.tenants}
    for name, mm in per.items():
        assert mm["submitted"] == \
            mm["admitted"] + mm["rejected"], f"{name} lost admissions"
        assert mm["admitted"] == mm["completed"] + mm["shed"], \
            f"{name} lost requests: {mm}"
    assert per["steady"]["hot_swaps"] == 1
    assert per["steady"]["deadline_miss_rate"] <= 0.05, \
        f"bursty tenant starved steady's deadlines: {per['steady']}"
    # the swap payload is deterministic — the bench re-derives it
    import jax
    l1 = jax.tree_util.tree_leaves(swap_model_for(packed_a, sc))
    l2 = jax.tree_util.tree_leaves(swap_model_for(packed_a, sc))
    assert len(l1) == len(l2)
    assert all(np.array_equal(a, b) for a, b in zip(l1, l2))


# ------------------------------------------------------------- live socket

def test_socket_routes_tenants_and_hot_swaps_via_admin(rng, packed_a,
                                                       packed_a2, packed_b):
    """End to end over a real connection: v2 frames route by name, a v1
    frame routes to the default tenant, ADMIN list enumerates the fabric,
    ADMIN swap installs new weights through the model factory, and every
    result is bit-exact against the weights live at admission."""
    from repro.launch.socket_serve import (SpikeClient, SpikeSocketServer,
                                           serving_thread)
    srv = SpikeSocketServer(_registry(packed_a, packed_b), port=0,
                            model_factory=lambda spec: packed_a2)
    host, port = srv.address
    sa = _streams(rng, 14, [3, 7, 5])
    sb = _streams(rng, 11, [4, 6])
    post = _streams(rng, 14, [5, 8])
    n_results = len(sa) + len(sb) + len(post)
    with serving_thread(srv, max_requests=n_results, idle_flush_s=0.05):
        cli = SpikeClient(host, port, timeout=60)
        pre_ids = [cli.send(s, model="alpha") for s in sa[:-1]]
        pre_ids.append(cli.send(sa[-1], version=1))   # v1 → default (alpha)
        b_ids = [cli.send(s, model="beta") for s in sb]
        lst = cli.admin({"op": "list"})
        unknown = cli.send(_streams(rng, 14, [4])[0], model="gamma")
        adm = cli.admin({"op": "swap", "model": "alpha"})
        post_ids = [cli.send(s, model="alpha") for s in post]
        cli.recv_all()
        cli.close()
    reply = cli.admin_replies[lst]
    assert reply["ok"] and reply["default"] == "alpha"
    assert set(reply["models"]) == {"alpha", "beta"}
    assert "unknown_model" in cli.rejections[unknown]
    assert "gamma" in cli.rejections[unknown]
    swap_reply = cli.admin_replies[adm]
    assert swap_reply["ok"] and swap_reply["generation"] == 2, swap_reply
    for req_id, s in zip(pre_ids, sa):
        assert np.array_equal(cli.results[req_id], _ref(packed_a, s)), \
            "pre-swap request not served on the old weights"
    for req_id, s in zip(b_ids, sb):
        assert np.array_equal(cli.results[req_id], _ref(packed_b, s))
    for req_id, s in zip(post_ids, post):
        assert np.array_equal(cli.results[req_id], _ref(packed_a2, s)), \
            "post-swap request not served on the new weights"
    snap = srv.server.metrics.snapshot()
    assert snap["hot_swaps"] == 1 and snap["completed"] == n_results


def test_socket_corrupt_frame_drops_only_that_connection(rng, packed_a,
                                                         packed_b):
    """Satellite contract: a corrupt frame poisons one connection's
    decoder, and only that connection dies — its buffer is reset and
    dropped, while a healthy neighbour keeps serving bit-exact."""
    from repro.launch.socket_serve import (SpikeClient, SpikeSocketServer,
                                           serving_thread)
    srv = SpikeSocketServer(_registry(packed_a, packed_b), port=0)
    host, port = srv.address
    good_streams = _streams(rng, 14, [5, 3])
    with serving_thread(srv, max_requests=len(good_streams),
                        idle_flush_s=0.05):
        bad = SpikeClient(host, port, timeout=60)
        good = SpikeClient(host, port, timeout=60)
        bad.sock.sendall(b"XX" + b"\x00" * 30)       # corrupt magic
        ids = [good.send(s, model="alpha") for s in good_streams]
        good.recv_all()
        # the offender is disconnected, not answered
        bad.sock.settimeout(30)
        assert bad.sock.recv(1 << 10) == b"", \
            "server kept a connection whose stream cannot resync"
        bad.close()
        good.close()
    for req_id, s in zip(ids, good_streams):
        assert np.array_equal(good.results[req_id], _ref(packed_a, s)), \
            "healthy connection corrupted by a neighbour's garbage"
