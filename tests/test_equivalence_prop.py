"""Property-based oracle-equivalence suite.

The contract: for ANY mappable layer stack — dense and conv, multi-round,
pruned, finite or unbounded MEM_E — ``run_batched`` reproduces the numpy
oracle ``run`` bit-exactly: output spikes, every :class:`DispatchStats`
field, MEM_S&N utilization, and overflow counts.

Cases are generated two ways:

  * hypothesis strategies (``test_prop_*``) — the fuzzing front line; they
    run wherever ``hypothesis`` is installed (CI tier-1) and skip in bare
    environments.  A falsified case is dumped, already shrunk, into
    ``tests/golden/equivalence/`` so it replays forever after.
  * a deterministic seeded sweep (``test_seeded_sweep``) — 48 fixed cases
    that run everywhere, hypothesis or not.

``tests/golden/equivalence/*.json`` fixtures (committed regressions +
recorded failures) replay through the exact same builder.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np
import pytest
from _equivalence import assert_oracle_engine_equivalent
from _hypothesis_compat import given, settings, st

from repro.core.accelerator import map_model
from repro.core.energy import AcceleratorSpec
from repro.core.layers import Conv2d, Dense, SumPool2d
from repro.core.lif import LIFParams

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden" / "equivalence"


# ----------------------------------------------------------- case -> model

def build_case(case: dict):
    """Deterministically build (mapped model, spikes [B, T, n_in]) from a
    JSON-serializable case descriptor."""
    rng = np.random.default_rng(case["seed"])
    spec = AcceleratorSpec("prop", n_cores=len(case["layers"]),
                           n_engines=case["n_engines"],
                           n_caps=case["n_caps"],
                           weight_mem_bytes=1 << 20)
    specs = []
    shape = tuple(case["in_shape"])            # (c, h, w); dense uses c*h*w
    for ld in case["layers"]:
        if ld["kind"] == "dense":
            n_in = int(np.prod(shape))
            w = rng.normal(0, 0.6, (n_in, ld["n_out"]))
            w[rng.random(w.shape) > ld["density"]] = 0
            # a dense layer must keep >=1 synapse or the stack goes silent
            if (w != 0).sum() == 0:
                w[0, 0] = 0.5
            specs.append(Dense(w=w.astype(np.float32)))
            shape = (ld["n_out"], 1, 1)
        elif ld["kind"] == "conv":
            k = rng.normal(0, 0.8,
                           (ld["c_out"], shape[0], ld["k"], ld["k"]))
            k[rng.random(k.shape) > ld["density"]] = 0
            if (k != 0).sum() == 0:
                k[0, 0, 0, 0] = 0.5
            conv = Conv2d(kernel=k.astype(np.float32), in_shape=shape,
                          stride=ld["stride"], padding=ld["padding"])
            specs.append(conv)
            shape = conv.out_shape
        elif ld["kind"] == "pool":
            pool = SumPool2d(shape, ld["pool"])
            specs.append(pool)
            shape = pool.out_shape
        else:
            raise ValueError(f"unknown layer kind {ld['kind']!r}")
    lif = LIFParams(beta=case["beta"], threshold=case["threshold"])
    quant_bits = case.get("quant_bits", 8)   # int or per-layer list (mixed)
    model = map_model(specs, spec, lif=lif, quant_bits=quant_bits,
                      compress=bool(case.get("compress", False)))
    n_in = specs[0].n_src
    spikes = (rng.random((case["batch"], case["t"], n_in))
              < case["p_spike"]).astype(np.float32)
    return model, spikes


def check_case(case: dict):
    """The property: batched engine == oracle, field for field, bit for
    bit, for every sample — including under a finite MEM_E depth."""
    model, spikes = build_case(case)
    assert_oracle_engine_equivalent(model, spikes,
                                    max_events=case.get("max_events"))


def _record_failure(case: dict):
    """Persist a falsified case as a replayable regression fixture.  Called
    on every shrink candidate, but the file is keyed by the case's layer-kind
    signature and overwritten each time — and hypothesis replays the minimal
    example last, so what survives is exactly the shrunk counterexample."""
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    sig = "-".join(ld["kind"] for ld in case["layers"])
    blob = json.dumps(case, sort_keys=True)
    (GOLDEN_DIR / f"failed_{sig}.json").write_text(blob + "\n")


def check_and_record(case: dict):
    try:
        check_case(case)
    except AssertionError:
        _record_failure(case)
        raise


# ------------------------------------------------------------- strategies

def _dense_case(seed, widths, density, batch, t, p_spike, max_events,
                engines, caps, beta=0.8, threshold=0.7, compress=False,
                quant_bits=8):
    return {"seed": seed, "in_shape": [widths[0], 1, 1],
            "layers": [{"kind": "dense", "n_out": n, "density": density}
                       for n in widths[1:]],
            "batch": batch, "t": t, "p_spike": p_spike,
            "max_events": max_events, "n_engines": engines, "n_caps": caps,
            "beta": beta, "threshold": threshold, "compress": compress,
            "quant_bits": quant_bits}


try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def dense_cases(draw):
        n_layers = draw(st.integers(1, 3))
        widths = [draw(st.integers(3, 20)) for _ in range(n_layers + 1)]
        # mixed-precision draws: uniform 8-bit, or one stored width per
        # layer — sub-8 layers route through the packed-operand kernel
        quant_bits = draw(st.one_of(
            st.just(8),
            st.lists(st.sampled_from([4, 8]),
                     min_size=n_layers, max_size=n_layers)))
        return _dense_case(
            seed=draw(st.integers(0, 2**16)),
            widths=widths,
            density=draw(st.floats(0.2, 1.0)),
            batch=draw(st.integers(1, 4)),
            t=draw(st.integers(1, 8)),
            p_spike=draw(st.floats(0.05, 0.8)),
            # None = unbounded; small = overflow exercised on layer 0+
            max_events=draw(st.one_of(st.none(), st.integers(0, 6))),
            engines=draw(st.integers(1, 4)),
            caps=draw(st.integers(2, 6)),      # widths>caps*engines => rounds
            beta=draw(st.sampled_from([0.5, 0.8, 0.9])),
            threshold=draw(st.sampled_from([0.4, 0.7, 1.0])),
            compress=draw(st.booleans()),
            quant_bits=quant_bits)

    @st.composite
    def conv_cases(draw):
        c = draw(st.integers(1, 2))
        h = draw(st.integers(4, 7))
        layers = []
        k = draw(st.integers(2, 3))
        stride = draw(st.integers(1, 2))
        padding = draw(st.integers(0, 1))
        layers.append({"kind": "conv", "c_out": draw(st.integers(1, 3)),
                       "k": k, "stride": stride, "padding": padding,
                       "density": draw(st.floats(0.3, 1.0))})
        oh = (h + 2 * padding - k) // stride + 1
        if oh >= 2 and draw(st.booleans()):   # pool needs a >=2px map
            layers.append({"kind": "pool", "pool": 2})
        if draw(st.booleans()):
            layers.append({"kind": "conv", "c_out": draw(st.integers(1, 2)),
                           "k": 2, "stride": 1, "padding": 1,
                           "density": draw(st.floats(0.3, 1.0))})
        layers.append({"kind": "dense", "n_out": draw(st.integers(2, 6)),
                       "density": draw(st.floats(0.4, 1.0))})
        quant_bits = draw(st.one_of(
            st.just(8),
            st.lists(st.sampled_from([4, 8]),
                     min_size=len(layers), max_size=len(layers))))
        return {"seed": draw(st.integers(0, 2**16)), "in_shape": [c, h, h],
                "layers": layers,
                "batch": draw(st.integers(1, 3)),
                "t": draw(st.integers(1, 6)),
                "p_spike": draw(st.floats(0.05, 0.6)),
                "max_events": draw(st.one_of(st.none(),
                                             st.integers(0, 10))),
                "n_engines": draw(st.integers(2, 4)),
                "n_caps": draw(st.integers(3, 8)),
                "beta": 0.8, "threshold": draw(st.sampled_from([0.5, 0.9])),
                "compress": draw(st.booleans()),
                "quant_bits": quant_bits}
else:                           # bare env: decorators below become skips
    def dense_cases():
        return None

    def conv_cases():
        return None


@settings(max_examples=120, deadline=None)
@given(case=dense_cases())
def test_prop_dense_stacks(case):
    """run == run_batched on random dense stacks (multi-round, pruned,
    MEM_E-capped)."""
    check_and_record(case)


@settings(max_examples=80, deadline=None)
@given(case=conv_cases())
def test_prop_conv_stacks(case):
    """run == run_batched on random conv/pool/dense stacks (shared-weight
    lowering, stride/padding, MEM_E-capped)."""
    check_and_record(case)


# --------------------------------------------- deterministic twin coverage

def _sweep_cases():
    cases = []
    for seed in range(16):
        cases.append(_dense_case(
            seed=seed, widths=[6 + seed % 7, 30, 5],   # 30 > engines*caps
            density=0.3 + 0.05 * (seed % 8), batch=2, t=5,
            p_spike=0.1 + 0.05 * (seed % 10),
            max_events=None if seed % 3 == 0 else seed % 5,
            engines=1 + seed % 3, caps=3 + seed % 4,
            compress=seed % 2 == 1))
    for seed in range(16):
        cases.append({
            "seed": 1000 + seed, "in_shape": [1 + seed % 2, 5 + seed % 3,
                                              5 + seed % 3],
            "layers": [
                {"kind": "conv", "c_out": 1 + seed % 3, "k": 2 + seed % 2,
                 "stride": 1 + seed % 2, "padding": seed % 2,
                 "density": 0.4 + 0.06 * (seed % 8)},
                {"kind": "pool", "pool": 2},
                {"kind": "dense", "n_out": 4, "density": 0.8}],
            "batch": 2, "t": 4, "p_spike": 0.25,
            "max_events": None if seed % 2 else 4,
            "n_engines": 2 + seed % 3, "n_caps": 4 + seed % 3,
            "beta": 0.8, "threshold": 0.7,
            "compress": seed % 3 == 1})
    for seed in range(16):
        cases.append({
            "seed": 2000 + seed, "in_shape": [2, 6, 6],
            "layers": [
                {"kind": "conv", "c_out": 2, "k": 3, "stride": 1,
                 "padding": 1, "density": 0.7},
                {"kind": "conv", "c_out": 3, "k": 2, "stride": 2,
                 "padding": 0, "density": 0.9},
                {"kind": "dense", "n_out": 6, "density": 0.5}],
            "batch": 3, "t": 4, "p_spike": 0.1 + 0.04 * (seed % 6),
            "max_events": None if seed % 4 else 8,
            "n_engines": 3, "n_caps": 5,
            "beta": 0.9, "threshold": 0.5,
            "compress": seed % 2 == 0})
    # mixed-precision: per-layer 2/4/8-bit words through the packed-operand
    # kernel, crossed with compression and MEM_E caps
    bit_menu = [[4, 8], [8, 4], [4, 4], [2, 8], [8, 2], [2, 4]]
    for seed in range(8):
        cases.append(_dense_case(
            seed=3000 + seed, widths=[8 + seed % 5, 26, 6],
            density=0.4 + 0.05 * (seed % 6), batch=2, t=5,
            p_spike=0.15 + 0.05 * (seed % 5),
            max_events=None if seed % 2 else 5,
            engines=2 + seed % 2, caps=4 + seed % 3,
            compress=seed % 2 == 1,
            quant_bits=bit_menu[seed % len(bit_menu)]))
    for seed in range(8):
        cases.append({
            "seed": 4000 + seed, "in_shape": [2, 6, 6],
            "layers": [
                {"kind": "conv", "c_out": 2, "k": 3, "stride": 1,
                 "padding": 1, "density": 0.7},
                {"kind": "pool", "pool": 2},
                {"kind": "dense", "n_out": 5, "density": 0.6}],
            "batch": 2, "t": 4, "p_spike": 0.2 + 0.04 * (seed % 4),
            "max_events": None if seed % 3 else 6,
            "n_engines": 3, "n_caps": 5,
            "beta": 0.8, "threshold": 0.6,
            "compress": seed % 2 == 0,
            "quant_bits": [bit_menu[seed % len(bit_menu)][0], 8,
                           bit_menu[seed % len(bit_menu)][1]]})
    return cases


@pytest.mark.parametrize("idx", range(64))
def test_seeded_sweep(idx):
    """Hypothesis-free twin of the property tests: 64 deterministic cases
    spanning dense multi-round, conv stride/pad/pool, MEM_E caps, and
    mixed-precision packed-operand stacks."""
    check_case(_sweep_cases()[idx])


# ------------------------------------------------------- fixture replay

def _fixture_files():
    return sorted(GOLDEN_DIR.glob("*.json")) if GOLDEN_DIR.exists() else []


@pytest.mark.parametrize("path", _fixture_files(),
                         ids=lambda p: p.stem)
def test_golden_equivalence_fixtures(path):
    """Replay committed (and previously falsified) minimized cases."""
    check_case(json.loads(path.read_text()))
