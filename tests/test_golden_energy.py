"""Golden regression for the energy model + dispatch accounting on the
``menage_paper`` config (Accel_1 x the N-MNIST MLP shape).

The calibrated energy model is the repo's Table-II claim; silent drift in
any constant, in the dispatch simulator's cycle accounting, or in the
table-building path would quietly invalidate it.  This test pins
``EnergyReport`` and per-layer dispatch/utilization numbers to committed
JSON goldens.  Legitimate model changes update them explicitly:

    pytest tests/test_golden_energy.py --update-goldens

then review the JSON diff like any other code change.

Determinism: weights and spikes come from ``np.random.default_rng`` (stable
across platforms by numpy's documented contract) and mapping uses the pure-
numpy ``greedy`` solver, so the goldens are environment-independent; float
comparisons still allow 1e-9 relative slack for last-ulp platform noise.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np
import pytest

from repro.configs.menage_paper import NMNIST_SNN
from repro.core.accelerator import map_model, run
from repro.core.energy import ACCEL_1

GOLDEN = pathlib.Path(__file__).parent / "golden" / "energy_menage_paper.json"
RTOL = 1e-9


def _build_result():
    sizes = NMNIST_SNN.layer_sizes            # (2312, 200, 100, 40, 10)
    rng = np.random.default_rng(0)
    ws = []
    for i in range(len(sizes) - 1):
        w = rng.normal(0, 0.5, (sizes[i], sizes[i + 1]))
        th = np.quantile(np.abs(w), 0.5)      # 50% L1 prune
        w[np.abs(w) < th] = 0
        ws.append(w.astype(np.float32))
    model = map_model(ws, ACCEL_1, lif=NMNIST_SNN.lif, method="greedy")
    spikes = (np.random.default_rng(1)
              .random((NMNIST_SNN.num_steps, sizes[0])) < 0.02
              ).astype(np.float32)
    return model, run(model, spikes)


def _snapshot(model, res) -> dict:
    energy = dataclasses.asdict(res.energy)
    layers = []
    for layer, stats, util in zip(model.layers, res.per_layer_stats,
                                  res.per_layer_util):
        layers.append({
            "rounds": len(layer.rounds),
            "weight_bytes": layer.weight_bytes,
            "sram_bytes": layer.sram_bytes,
            "sn_rows": sum(r.tables.n_rows for r in layer.rounds),
            "cycles": int(stats.cycles.sum()),
            "rows_touched": int(stats.rows_touched.sum()),
            "engine_ops": int(stats.engine_ops.sum()),
            "events": int(stats.events.sum()),
            "sn_bytes_touched": int(stats.sn_bytes_touched.sum()),
            "mem_e_peak": int(stats.mem_e_peak),
            "utilization": [float(u) for u in util],
        })
    return {"energy": energy, "layers": layers,
            "out_spike_count": int(res.out_spikes.sum())}


def _assert_close(path: str, got, want):
    if isinstance(want, dict):
        assert isinstance(got, dict) and set(got) == set(want), \
            f"{path}: keys {sorted(got)} != golden {sorted(want)}"
        for k in want:
            _assert_close(f"{path}.{k}", got[k], want[k])
    elif isinstance(want, list):
        assert len(got) == len(want), f"{path}: length changed"
        for i, (g, w) in enumerate(zip(got, want)):
            _assert_close(f"{path}[{i}]", g, w)
    elif isinstance(want, float):
        assert np.isclose(got, want, rtol=RTOL, atol=0.0), \
            f"{path}: {got!r} != golden {want!r} (energy-model drift? " \
            f"rerun with --update-goldens and review the diff)"
    else:
        assert got == want, f"{path}: {got!r} != golden {want!r}"


def test_energy_golden_menage_paper(update_goldens):
    model, res = _build_result()
    snap = _snapshot(model, res)
    assert snap["out_spike_count"] > 0, "golden scenario went silent"
    assert snap["energy"]["total_ops"] > 0
    if update_goldens or not GOLDEN.exists():
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(snap, indent=1, sort_keys=True) + "\n")
        if not update_goldens:
            pytest.fail(f"{GOLDEN} did not exist; wrote it — commit the "
                        f"file and rerun", pytrace=False)
        return
    _assert_close("golden", snap, json.loads(GOLDEN.read_text()))
