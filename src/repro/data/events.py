"""Synthetic event-stream datasets (N-MNIST / CIFAR10-DVS stand-ins).

The real datasets are not downloadable offline (DESIGN.md §5).  These
generators produce spike tensors with the same layout — time-major
``[T, B, 2*H*W]`` (two polarity channels, flattened) — with class-conditional
spatial rate patterns plus background noise, and mean spike rates matched to
the activity levels the paper reports (CIFAR10-DVS busier than N-MNIST, which
drives the Figs 6-7 utilization difference and the Table II efficiency gap).

They are *learnable* (each class has a distinct Gaussian-blob rate map) so
the full Algorithm-1 flow — train -> prune -> quantize -> map -> execute —
can be validated end to end.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class EventDatasetConfig:
    name: str
    height: int
    width: int
    num_classes: int = 10
    num_steps: int = 25
    base_rate: float = 0.01       # background spike probability
    signal_rate: float = 0.35     # peak in-blob spike probability
    blobs_per_class: int = 3

    @property
    def n_in(self) -> int:
        return 2 * self.height * self.width

    @staticmethod
    def nmnist_like() -> "EventDatasetConfig":
        # N-MNIST is 34x34x2, sparse saccade events
        return EventDatasetConfig("nmnist-syn", 34, 34, base_rate=0.008,
                                  signal_rate=0.30)

    @staticmethod
    def cifar10_dvs_like(down: int = 4) -> "EventDatasetConfig":
        # CIFAR10-DVS is 128x128x2 and markedly busier; we keep the busier
        # statistics but allow spatial downsampling for CPU-budget training.
        return EventDatasetConfig("cifar10dvs-syn", 128 // down, 128 // down,
                                  base_rate=0.03, signal_rate=0.5,
                                  blobs_per_class=5)


def _class_rate_maps(cfg: EventDatasetConfig, seed: int = 1234) -> np.ndarray:
    """Per-class Poisson rate maps [C, 2, H, W]."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:cfg.height, 0:cfg.width]
    maps = np.full((cfg.num_classes, 2, cfg.height, cfg.width),
                   cfg.base_rate, dtype=np.float32)
    for c in range(cfg.num_classes):
        for _ in range(cfg.blobs_per_class):
            cy, cx = rng.uniform(0, cfg.height), rng.uniform(0, cfg.width)
            sig = rng.uniform(cfg.height / 12, cfg.height / 5)
            pol = rng.integers(0, 2)
            blob = np.exp(-((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * sig**2))
            maps[c, pol] += cfg.signal_rate * blob.astype(np.float32)
    return np.clip(maps, 0.0, 0.95)


def synthetic_event_dataset(cfg: EventDatasetConfig, n_per_class: int,
                            key: jax.Array, seed: int = 1234):
    """Returns (spikes [n, T, n_in], labels [n]) as numpy arrays."""
    maps = _class_rate_maps(cfg, seed)
    n = n_per_class * cfg.num_classes
    labels = np.repeat(np.arange(cfg.num_classes), n_per_class)
    rates = maps[labels].reshape(n, 1, cfg.n_in)  # [n, 1, n_in]
    u = jax.random.uniform(key, (n, cfg.num_steps, cfg.n_in))
    spikes = (np.asarray(u) < rates).astype(np.float32)
    perm = np.random.default_rng(seed + 1).permutation(n)
    return spikes[perm], labels[perm]


def event_batches(spikes: np.ndarray, labels: np.ndarray, batch: int,
                  seed: int = 0):
    """Infinite iterator of time-major batches (spikes [T, B, n_in], labels [B])."""
    rng = np.random.default_rng(seed)
    n = spikes.shape[0]
    while True:
        idx = rng.integers(0, n, size=batch)
        yield jnp.asarray(spikes[idx].swapaxes(0, 1)), jnp.asarray(labels[idx])


def event_batch_at(spikes: np.ndarray, labels: np.ndarray, batch: int,
                   step: int, seed: int = 0):
    """The step-keyed batch: time-major ``(spikes [T, B, n_in], labels
    [B])`` derived from ``(seed, step)`` alone, so a restarted training run
    replays the exact remaining batches with no reader state — the
    restart-safe data form :func:`repro.engine.snn_train.train_snn_model`
    wants (same contract as ``data/tokens.token_batch``)."""
    rng = np.random.default_rng((seed, step))
    idx = rng.integers(0, spikes.shape[0], size=batch)
    return spikes[idx].swapaxes(0, 1), labels[idx]
