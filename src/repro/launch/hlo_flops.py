"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers program is undercounted by ~n_layers (verified: a 7-step
scanned matmul reports 1/7 of the true FLOPs).  This module re-derives
roofline inputs from the optimized HLO text with loop multipliers:

  * computations are parsed into (op, shape, operands, attrs) lists;
  * a DFS from ENTRY propagates a multiplier: ``while`` bodies/conditions get
    ``mult * trip_count`` (trip count from the ``known_trip_count``
    backend_config, falling back to the condition's compare constant);
    fusion/call/branch subcomputations inherit the caller's multiplier;
  * FLOPs: dots count ``2 * prod(output) * prod(contracting dims)``;
    elementwise arithmetic/transcendentals count ``prod(shape)``;
  * bytes (HBM roofline model): ops at *schedule level* (entry, while
    bodies, branches — NOT inside fusions) read their operands and write
    their result once; fusion-internal ops move no HBM bytes.  parameter /
    gte / tuple / constant / bitcast are free;
  * collectives: result bytes x multiplier, by kind.

SPMD note: the compiled module is the per-device program, so all numbers are
per-device — exactly what the roofline terms need.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs", "sign",
    "cosine", "sine", "logistic", "expm1", "log1p", "atan2", "remainder",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "erf",
    "cbrt",
}

_FREE_OPS = {
    "parameter", "get-tuple-element", "tuple", "constant", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shapes(sig: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(sig):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
        out.append((dt, dims))
    return out


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _parse_shapes(sig):
        total += math.prod(dims) * _DTYPE_BYTES[dt]
    return total


def _shape_elems(sig: str) -> int:
    return sum(math.prod(dims) for _, dims in _parse_shapes(sig))


@dataclasses.dataclass
class Op:
    name: str
    result_sig: str          # everything left of the opcode (result type(s))
    opcode: str
    operands: list[str]
    line: str


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")


def parse_hlo(text: str) -> dict[str, list[Op]]:
    """computation name -> ops."""
    comps: dict[str, list[Op]] = {}
    current: str | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if current is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{", s)
            if m and not s.startswith("//"):
                current = m.group(1)
                comps[current] = []
                if s.startswith("ENTRY"):
                    comps["__entry__"] = comps[current]
            continue
        if s == "}":
            current = None
            continue
        m = _OP_RE.match(s)
        if m:
            name, result_sig, opcode, rest = m.groups()
            # operand names: %foo tokens inside the first paren group
            depth, i = 1, 0
            while i < len(rest) and depth > 0:
                if rest[i] == "(":
                    depth += 1
                elif rest[i] == ")":
                    depth -= 1
                i += 1
            inner = rest[:i - 1] if depth == 0 else rest
            operands = re.findall(r"%([\w.\-]+)", inner)
            comps[current].append(Op(name, result_sig, opcode, operands,
                                     s))
    return comps


def _trip_count(op: Op, comps: dict[str, list[Op]]) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.line)
    if m:
        return int(m.group(1))
    # fallback: constant in the condition computation's compare
    m = re.search(r"condition=%?([\w.\-]+)", op.line)
    if m and m.group(1) in comps:
        for o in comps[m.group(1)]:
            if o.opcode == "constant":
                mc = re.search(r"constant\((\d+)\)", o.line)
                if mc:
                    return int(mc.group(1))
    return 1


def _dot_flops(op: Op, shapes: dict[str, str]) -> float:
    out_elems = _shape_elems(op.result_sig)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    lhs_sig = shapes.get(op.operands[0], "") if op.operands else ""
    lhs_shapes = _parse_shapes(lhs_sig)
    k = 1
    if m and lhs_shapes:
        dims = lhs_shapes[0][1]
        for d in (int(x) for x in m.group(1).split(",") if x):
            if d < len(dims):
                k *= dims[d]
    return 2.0 * out_elems * k


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_counts: dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    dot_flops: float = 0.0

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def analyze_hlo(text: str) -> HloCost:
    comps = parse_hlo(text)
    cost = HloCost()
    entry = comps.get("__entry__")
    assert entry is not None, "no ENTRY computation found"

    def _param_touch_bytes(comp_name: str) -> dict[int, float] | None:
        """For a fused computation: per-parameter-index HBM bytes actually
        touched, modelling in-place dynamic-slice / dynamic-update-slice —
        a param consumed ONLY via dynamic-slice (or as the in-place target
        of a dynamic-update-slice) moves slice-sized bytes, not the whole
        buffer.  Returns None when the computation can't be analyzed."""
        ops = comps.get(comp_name)
        if ops is None:
            return None
        shapes = {o.name: o.result_sig for o in ops}
        param_name: dict[int, str] = {}
        for o in ops:
            if o.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", o.line)
                if m:
                    param_name[int(m.group(1))] = o.name
        # 'convert' is treated as a view here: XLA:CPU promotes bf16 buffers
        # to f32 wholesale (no native bf16); on the modeled TPU the storage
        # dtype flows through, so a param read via convert->slice moves
        # slice-sized bytes.
        view_ops = {"bitcast", "reshape", "copy", "transpose", "convert"}
        touch: dict[int, float] = {}
        for idx, pname in param_name.items():
            # traverse view-op chains: param -> bitcast/reshape -> consumer
            frontier = [pname]
            seen = {pname}
            total = 0.0
            full = False
            while frontier and not full:
                cur = frontier.pop()
                for u in ops:
                    if cur not in u.operands:
                        continue
                    if u.opcode in view_ops:
                        if u.name not in seen:
                            seen.add(u.name)
                            frontier.append(u.name)
                    elif u.opcode == "dynamic-slice":
                        total += _shape_bytes(u.result_sig)
                    elif (u.opcode == "dynamic-update-slice"
                          and u.operands and u.operands[0] in seen):
                        upd = u.operands[1] if len(u.operands) > 1 else None
                        total += (_shape_bytes(shapes.get(upd, ""))
                                  if upd else 0.0)
                    else:
                        full = True
                        break
            touch[idx] = (_shape_bytes(shapes.get(pname, ""))
                          if full else total)
        return touch

    def _fusion_output_bytes(op: Op, comp_name: str) -> float:
        """Output bytes of a fusion; if ROOT is (a view/convert chain over) a
        dynamic-update-slice, the write is update-sized — the buffer is
        aliased in place on TPU.  (XLA:CPU materializes a bf16<->f32
        converted copy of the whole carried buffer per scan iteration; a TPU
        build updates in place in the storage dtype, which is the hardware
        this roofline models.)"""
        ops = comps.get(comp_name)
        if ops:
            shapes = {o.name: o.result_sig for o in ops}
            by_name = {o.name: o for o in ops}
            cur = ops[-1]
            view_ops = {"convert", "bitcast", "reshape", "copy", "transpose"}
            for _ in range(8):  # bounded walk through view/convert chain
                if cur.opcode == "dynamic-update-slice":
                    if len(cur.operands) > 1:
                        return _shape_bytes(shapes.get(cur.operands[1], ""))
                    break
                if cur.opcode in view_ops and cur.operands and \
                        cur.operands[0] in by_name:
                    cur = by_name[cur.operands[0]]
                    continue
                break
        return _shape_bytes(op.result_sig)

    def visit(ops: list[Op], mult: float, schedule_level: bool):
        shapes = {o.name: o.result_sig for o in ops}
        for op in ops:
            oc = op.opcode
            if oc == "while":
                trip = _trip_count(op, comps)
                for attr in ("body", "condition"):
                    m = re.search(rf"{attr}=%?([\w.\-]+)", op.line)
                    if m and m.group(1) in comps:
                        visit(comps[m.group(1)], mult * trip, schedule_level)
                continue
            if oc == "conditional":
                for name in re.findall(r"(?:branch_computations=\{|true_computation=|false_computation=)%?([\w.\-]+)",
                                       op.line):
                    if name in comps:
                        visit(comps[name], mult, schedule_level)
                # fall through to count its own bytes
            called = re.search(r"calls=%?([\w.\-]+)", op.line)
            if oc in ("fusion",) and called and called.group(1) in comps:
                visit(comps[called.group(1)], mult, False)
            elif oc in ("call", "async-start") and called and called.group(1) in comps:
                visit(comps[called.group(1)], mult, schedule_level)

            if oc in ("dot", "convolution"):
                f = _dot_flops(op, shapes)
                cost.flops += mult * f
                cost.dot_flops += mult * f
            elif oc in _ELEMENTWISE:
                cost.flops += mult * _shape_elems(op.result_sig)
            elif oc in ("reduce", "reduce-window"):
                # ~1 flop per input element
                in_elems = sum(_shape_elems(shapes.get(o, ""))
                               for o in op.operands[:1])
                cost.flops += mult * in_elems

            base_kind = oc[:-6] if oc.endswith("-start") else oc
            if base_kind in _COLLECTIVES and not oc.endswith("-done"):
                b = _shape_bytes(op.result_sig)
                cost.coll_bytes[base_kind] += mult * b
                cost.coll_counts[base_kind] += mult

            if schedule_level and oc not in _FREE_OPS and oc != "while":
                if oc == "dynamic-slice":
                    b = 2.0 * _shape_bytes(op.result_sig)
                elif oc == "dynamic-update-slice":
                    upd = (_shape_bytes(shapes.get(op.operands[1], ""))
                           if len(op.operands) > 1 else 0.0)
                    b = 2.0 * upd
                elif oc == "fusion" and called:
                    touch = _param_touch_bytes(called.group(1))
                    b = _fusion_output_bytes(op, called.group(1))
                    if touch is not None:
                        for i, o in enumerate(op.operands):
                            b += touch.get(i, _shape_bytes(shapes.get(o, "")))
                    else:
                        for o in op.operands:
                            b += _shape_bytes(shapes.get(o, ""))
                else:
                    b = _shape_bytes(op.result_sig)
                    for o in op.operands:
                        b += _shape_bytes(shapes.get(o, ""))
                cost.bytes += mult * b

    visit(entry, 1.0, True)
    return cost


def summarize(cost: HloCost) -> dict:
    return {"flops": cost.flops, "dot_flops": cost.dot_flops,
            "bytes": cost.bytes,
            "coll_bytes": dict(cost.coll_bytes),
            "coll_counts": dict(cost.coll_counts),
            "total_coll_bytes": cost.total_coll_bytes}
