"""Continuous-batching front end: variable-length event streams -> buckets.

Production DVS traffic is a stream of requests, each its own spike train
``[T_i, n_in]`` with its own duration.  Feeding those shapes straight into
``run_batched`` / ``run_sharded`` retraces the jit on every distinct
``(B, T)`` — the cache-churn bug this module fixes.  Instead, a
:class:`BucketPolicy` fixes a small grid of padded ``(B, T)`` shapes; the
scheduler groups pending requests by time bucket, chunks them into batch
buckets, zero-pads, runs, and slices each request's exact result back out.

Why padding is free (bit-wise): the LIF scan is causal, so zero-current
steps appended after ``T_i`` cannot change steps ``< T_i``; zero batch rows
are independent samples that get discarded.  Every per-request result —
output spikes, per-step DispatchStats, utilization, overflow, energy — is
therefore bit-identical to running that request alone at its native shape,
and hence to the numpy oracle (tested, ``tests/test_serving.py``).

The jit cache is bounded by construction: at most ``policy.n_buckets``
distinct shapes ever reach the engine, verified through the existing
``trace_count()`` probe.
"""

from __future__ import annotations

import dataclasses
import logging
import time

import numpy as np

from repro.core.energy import FRAME_CYCLES, EnergyReport, energy_model
from repro.core.memories import DispatchStats
from repro.engine import batched_run as br
from repro.engine.sharded_run import run_sharded

_log = logging.getLogger(__name__)


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


class OverlongRequestError(ValueError):
    """Raised at admission when requests exceed the policy's largest time
    bucket and auto-extension is off.  ``requests`` lists ``(index,
    length)`` per offending request so callers can reject those requests
    individually instead of failing the whole batch plan."""

    def __init__(self, requests: list[tuple[int, int]], t_max: int):
        self.requests = list(requests)
        self.t_max = t_max
        detail = ", ".join(f"request {i}: {t} steps" for i, t in self.requests)
        super().__init__(
            f"{len(self.requests)} request(s) exceed the largest time bucket "
            f"({t_max}): {detail} — pass overlong='extend' to grow the grid, "
            f"or reject these requests at admission")


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """The fixed ``(B, T)`` shape grid the engine is allowed to see.

    ``batch_sizes`` and ``time_steps`` are ascending; a request of length
    ``T_i`` lands in the smallest time bucket ``>= T_i``, and a chunk of
    ``k`` requests pads to the smallest batch bucket ``>= k`` (chunks are
    capped at ``max_batch``).  ``n_buckets`` bounds the jit-trace count.
    """

    batch_sizes: tuple[int, ...] = (1, 4, 16)
    time_steps: tuple[int, ...] = (8, 16, 32)

    def __post_init__(self):
        for name in ("batch_sizes", "time_steps"):
            v = getattr(self, name)
            assert v and all(x > 0 for x in v) and list(v) == sorted(set(v)), \
                f"{name} must be ascending unique positive ints, got {v}"

    @property
    def max_batch(self) -> int:
        return self.batch_sizes[-1]

    @property
    def n_buckets(self) -> int:
        return len(self.batch_sizes) * len(self.time_steps)

    def t_bucket(self, t: int) -> int:
        for tb in self.time_steps:
            if t <= tb:
                return tb
        raise ValueError(
            f"request of {t} steps exceeds the largest time bucket "
            f"{self.time_steps[-1]}; extend the policy "
            f"(BucketPolicy.covering picks buckets from observed lengths)")

    def fits(self, t: int) -> bool:
        """Whether a ``t``-step request lands in the grid at all — the
        admission check that keeps :meth:`t_bucket` from failing mid-plan."""
        return 0 < t <= self.time_steps[-1]

    def with_time_bucket(self, t: int) -> "BucketPolicy":
        """The policy extended to cover a ``t``-step request: the largest
        bucket doubles until it covers ``t`` (geometric growth, so a stream
        of ever-longer requests costs O(log T) new traces, not one each).
        Returns ``self`` unchanged when ``t`` already fits."""
        assert t > 0, f"cannot extend the grid to a {t}-step request"
        if self.fits(t):
            return self
        tb = self.time_steps[-1]
        while tb < t:
            tb *= 2
        return dataclasses.replace(self, time_steps=self.time_steps + (tb,))

    def b_bucket(self, b: int) -> int:
        assert 0 < b <= self.max_batch
        for bb in self.batch_sizes:
            if b <= bb:
                return bb
        raise AssertionError  # unreachable: b <= max_batch

    @classmethod
    def covering(cls, lengths, *, n_shards: int = 1,
                 max_batch: int = 16) -> "BucketPolicy":
        """A policy whose time buckets are the powers of two covering the
        observed request ``lengths`` and whose batch buckets are powers of
        two up to ``max_batch``, each rounded up to a multiple of
        ``n_shards`` (so every bucket splits evenly on the serving mesh)."""
        t_max = max(int(t) for t in lengths)
        steps, t = [], 1
        while t < t_max:
            t *= 2
        for tb in (max(t // 4, 1), max(t // 2, 1), t):
            if tb not in steps:
                steps.append(tb)
        bs, b = [], 1
        while b < max_batch:
            bs.append(_round_up(b, n_shards))
            b *= 4
        bs.append(_round_up(max_batch, n_shards))
        return cls(batch_sizes=tuple(sorted(set(bs))),
                   time_steps=tuple(sorted(set(steps))))

    @classmethod
    def for_mesh(cls, n_shards: int,
                 batch_sizes: tuple[int, ...] = (1, 4, 16),
                 time_steps: tuple[int, ...] = (8, 16, 32)) -> "BucketPolicy":
        """Round every batch bucket up to a multiple of the mesh's data-axis
        extent so ``run_sharded`` always gets a divisible batch."""
        return cls(batch_sizes=tuple(sorted({_round_up(b, n_shards)
                                             for b in batch_sizes})),
                   time_steps=tuple(time_steps))


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """One engine call: which requests ride it and the padded shape."""

    indices: tuple[int, ...]
    b_pad: int
    t_pad: int


def plan_batches(lengths, policy: BucketPolicy) -> list[BatchPlan]:
    """Deterministic scheduler: group requests by time bucket (arrival order
    preserved within a bucket), chunk each group at ``max_batch``, pad each
    chunk's batch to its batch bucket.  Every index appears exactly once."""
    groups: dict[int, list[int]] = {}
    for i, t in enumerate(lengths):
        assert t > 0, f"request {i} has {t} time steps"
        groups.setdefault(policy.t_bucket(int(t)), []).append(i)
    plans = []
    for t_pad in sorted(groups):
        idxs = groups[t_pad]
        for lo in range(0, len(idxs), policy.max_batch):
            chunk = idxs[lo:lo + policy.max_batch]
            plans.append(BatchPlan(indices=tuple(chunk),
                                   b_pad=policy.b_bucket(len(chunk)),
                                   t_pad=t_pad))
    return plans


@dataclasses.dataclass
class RequestResult:
    """One request's slice of a bucketed run — the same surfaces as the
    oracle :class:`repro.core.accelerator.RunResult`, bit-exact."""

    out_spikes: np.ndarray                      # [T_i, n_out]
    stats: list[DispatchStats]                  # per layer (empty w/o stats)
    util: list[np.ndarray]                      # [T_i] per layer
    overflow: list[np.ndarray]                  # [T_i] per layer
    spec: object = None
    per_layer_bits: "list[int] | None" = None   # stored word widths (energy)

    def energy(self, frame_cycles: int | None = FRAME_CYCLES) -> EnergyReport:
        """Same signature as :func:`repro.core.energy.energy_model`: the
        frame period defaults to the calibrated ``FRAME_CYCLES`` constant,
        ``None`` means throughput mode (no idle between frames).
        Mixed-precision models price the C2C MAC energy at each layer's
        stored word width."""
        assert self.spec is not None and self.stats, \
            "energy needs with_stats=True and an AcceleratorSpec"
        return energy_model(self.spec, self.stats, frame_cycles=frame_cycles,
                            per_core_bits=self.per_layer_bits)


def _slice_request(res: "br.BatchedRunResult", row: int, t: int,
                   with_stats: bool) -> RequestResult:
    out = res.out_spikes[row, :t]
    if not with_stats:
        return RequestResult(out_spikes=out, stats=[], util=[], overflow=[],
                             spec=res.spec, per_layer_bits=res.per_layer_bits)
    stats = []
    for bs in res.per_layer_stats:
        full = bs.sample(row)
        stats.append(DispatchStats(
            cycles=full.cycles[:t], rows_touched=full.rows_touched[:t],
            engine_ops=full.engine_ops[:t], events=full.events[:t],
            sn_bytes_touched=full.sn_bytes_touched[:t],
            # padded steps are silent -> they contribute 0 to the peak
            mem_e_peak=full.mem_e_peak))
    return RequestResult(
        out_spikes=out, stats=stats,
        util=[u[row, :t] for u in res.per_layer_util],
        overflow=[o[row, :t] for o in res.overflow],
        spec=res.spec, per_layer_bits=res.per_layer_bits)


# The per-engine-call telemetry record schema, shared by ``run_bucketed``
# and the async ``StreamServer`` (schema-locked in tests/test_serving.py so
# dashboards reading BENCH_serving.json don't silently break).  ``seq`` is
# a monotonic per-producer dispatch ordinal and ``ts`` the producer's clock
# at dispatch (the StreamServer passes its pluggable clock's now, so
# VirtualClock replays stamp deterministic timestamps; ``seconds`` stays
# wall-measured engine time) — records shared through one ``telemetry=``
# list across rounds are now self-ordering.
TELEMETRY_KEYS = ("seq", "ts", "b_pad", "t_pad", "n_requests", "events",
                  "out_spikes", "seconds")


def execute_plan(packed: "br.PackedModel", streams, plan: BatchPlan, *,
                 mesh=None, max_events: int | None = None,
                 sn_capacity_rows: int | None = None,
                 with_stats: bool = True,
                 donate: bool | None = None,
                 seq: int = 0, ts: float | None = None,
                 now=None, span_log: list | None = None
                 ) -> tuple[list[RequestResult], dict]:
    """One engine call: zero-pad ``plan``'s requests into the plan's
    ``(b_pad, t_pad)`` bucket, run (sharded when ``mesh`` is given), and
    slice each request's bit-exact result back out.

    The single execution path shared by the closed-list front end
    (:func:`run_bucketed`) and the always-on async loop
    (:mod:`repro.engine.stream_server`) — batch formation policy differs,
    what happens to a formed batch cannot.  Returns the per-request results
    (aligned with ``plan.indices``) and one ``TELEMETRY_KEYS`` record.
    ``donate`` recycles the padded upload buffer into the engine call
    (default: on unless the backend is CPU) — back-to-back dispatches of
    the same bucket then reuse one allocation instead of piling up copies.

    ``seq``/``ts`` stamp the telemetry record (see ``TELEMETRY_KEYS``).
    ``span_log``, if a list, receives ``(kind, t0, t1, attrs)`` tuples for
    the ``pad`` and ``slice`` stages measured on ``now`` (the caller's
    clock; defaults to ``time.monotonic``) — the tracer hook the
    StreamServer unions into each request's :class:`RequestTrace`.  Under a
    VirtualClock these are zero-width point events, so traces stay
    replay-deterministic.
    """
    clock = time.monotonic if now is None else now
    if span_log is not None:
        t_pad0 = clock()
    padded = np.zeros((plan.b_pad, plan.t_pad, packed.n_in),
                      dtype=np.float32)
    for row, i in enumerate(plan.indices):
        padded[row, :streams[i].shape[0]] = streams[i]
    if span_log is not None:
        span_log.append(("pad", t_pad0, clock(),
                         {"b_pad": plan.b_pad, "t_pad": plan.t_pad}))
    t0 = time.perf_counter()
    if mesh is None:
        res = br.run_batched(packed, padded, max_events=max_events,
                             sn_capacity_rows=sn_capacity_rows,
                             with_stats=with_stats, donate=donate)
    else:
        res = run_sharded(packed, padded, mesh=mesh, max_events=max_events,
                          sn_capacity_rows=sn_capacity_rows,
                          with_stats=with_stats, donate=donate)
    dt = time.perf_counter() - t0
    record = {
        "seq": int(seq),
        "ts": float(time.monotonic() if ts is None else ts),
        "b_pad": plan.b_pad, "t_pad": plan.t_pad,
        "n_requests": len(plan.indices),
        "events": int(sum((streams[i] > 0).sum() for i in plan.indices)),
        "out_spikes": int(sum(
            res.out_spikes[row, :streams[i].shape[0]].sum()
            for row, i in enumerate(plan.indices))),
        "seconds": dt}
    if span_log is not None:
        t_sl0 = clock()
    results = [_slice_request(res, row, streams[i].shape[0], with_stats)
               for row, i in enumerate(plan.indices)]
    if span_log is not None:
        span_log.append(("slice", t_sl0, clock(),
                         {"n_requests": len(plan.indices)}))
    return results, record


def run_bucketed(model, streams, *, policy: BucketPolicy | None = None,
                 mesh=None, max_events: int | None = None,
                 sn_capacity_rows: int | None = None,
                 with_stats: bool = True,
                 telemetry: list | None = None,
                 overlong: str = "error",
                 donate: bool | None = None,
                 noise=None, noise_key=0) -> list[RequestResult]:
    """Serve a list of variable-length spike streams (``[T_i, n_in]`` each)
    through the bucketed engine; results come back in request order.

    ``policy`` defaults to :meth:`BucketPolicy.covering` over the observed
    lengths (divisibility-adjusted when ``mesh`` is given).  ``mesh`` routes
    execution through :func:`run_sharded`; ``None`` serves single-device.
    ``telemetry``, if a list, receives one dict per engine call (padded
    shape, request count, events served, wall seconds) — the hook
    ``benchmarks/serving_bench.py`` uses for p50/p99 step latencies.

    ``overlong`` governs requests longer than the policy's largest time
    bucket, checked at admission (before any engine work): ``"error"``
    raises :class:`OverlongRequestError` naming every offending request;
    ``"extend"`` grows the grid geometrically (new traces, logged) so the
    rest of the batch is unaffected.

    ``noise`` (an :class:`repro.core.noise.AnalogNoise`) serves the batch
    through one deterministic noisy device instance:
    :func:`repro.core.noise.perturb_packed` applies the C2C-ladder gain
    error to the replayed effective weights under ``noise_key`` (an int
    seed or jax PRNG key) before any dispatch.  The same ``(noise,
    noise_key)`` is bit-reproducible — the unit-level anchor for the soak
    harness's accuracy-under-noise metric (tests/test_noise.py).
    """
    assert overlong in ("error", "extend"), overlong
    packed = model if isinstance(model, br.PackedModel) else model.pack()
    if noise is not None:
        from repro.core.noise import as_noise_key, perturb_packed
        packed = perturb_packed(as_noise_key(noise_key), packed, noise)
    streams = [np.asarray(s, dtype=np.float32) for s in streams]
    for i, s in enumerate(streams):
        assert s.ndim == 2 and s.shape[1] == packed.n_in, \
            f"request {i}: expected [T, {packed.n_in}], got {s.shape}"
    if not streams:
        return []
    lengths = [s.shape[0] for s in streams]
    for i, t in enumerate(lengths):
        assert t > 0, f"request {i} has a zero-length spike train"
    if policy is None:
        policy = BucketPolicy.covering(
            lengths, n_shards=mesh.size if mesh is not None else 1)
    over = [(i, t) for i, t in enumerate(lengths) if not policy.fits(t)]
    if over:
        if overlong == "error":
            raise OverlongRequestError(over, policy.time_steps[-1])
        for _, t in over:
            policy = policy.with_time_bucket(t)
        _log.warning("run_bucketed: %d over-long request(s) extended the "
                     "bucket grid to time_steps=%s (new jit traces)",
                     len(over), policy.time_steps)
    results: list[RequestResult | None] = [None] * len(streams)
    for seq, plan in enumerate(plan_batches(lengths, policy)):
        reqs, record = execute_plan(packed, streams, plan, mesh=mesh,
                                    max_events=max_events,
                                    sn_capacity_rows=sn_capacity_rows,
                                    with_stats=with_stats, donate=donate,
                                    seq=seq)
        if telemetry is not None:
            telemetry.append(record)
        for row, i in enumerate(plan.indices):
            results[i] = reqs[row]
    return results  # type: ignore[return-value]
