from repro.data.events import EventDatasetConfig, synthetic_event_dataset, event_batches  # noqa: F401
from repro.data.tokens import TokenPipelineConfig, token_batches  # noqa: F401
