"""Spiking CNNs — the convolutional model family MENAGE claims (§III).

Architecture per conv block: ``conv -> LIF -> sum-pool -> LIF``; after the
blocks, a flatten and one or more dense layers, each followed by LIF.  The
sum-pool is spiking pooling — a fixed depthwise all-ones window whose LIF
fires when enough window inputs spiked — because every mapped MX-NEURACORE
layer ends in its A-NEURON LIF bank; the training graph mirrors the
hardware structure exactly so a trained model lowers faithfully.

Training shares the MLP machinery: the same ``lif_step`` surrogate-gradient
cell (:mod:`repro.core.lif`), the same rate decoding (spike counts are the
logits), the same unified engine loop (:mod:`repro.engine.snn_train`).
Feature maps are NCHW and flatten
channel-major — the index convention of :mod:`repro.core.layers`, so
``layer_specs`` hands ``map_model`` a ``[Conv2d, SumPool2d(Conv2d), ...,
Dense]`` stack with no permutation glue.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layers import Conv2d, Dense, LayerSpec, SumPool2d
from repro.core.lif import LIFParams, lif_step


@dataclasses.dataclass(frozen=True)
class ConvSNNConfig:
    """A conv->LIF->pool stack with a dense head.

    in_shape:       (C, H, W) of the flattened channel-major spike input
    conv_channels:  output channels per conv block
    kernel_size / stride / padding: per conv (shared across blocks)
    pool:           sum-pool window+stride after each conv block (1 = none)
    dense_hidden:   hidden dense widths between flatten and the class head
    """

    in_shape: tuple[int, int, int]
    conv_channels: tuple[int, ...] = (8, 16)
    kernel_size: int = 3
    stride: int = 1
    padding: int = 1
    pool: int = 2
    dense_hidden: tuple[int, ...] = ()
    num_classes: int = 10
    lif: LIFParams = LIFParams(beta=0.9, threshold=1.0)
    num_steps: int = 25

    @staticmethod
    def cifar10_dvs(down: int = 4, channels: tuple[int, ...] = (8, 16)
                    ) -> "ConvSNNConfig":
        """Conv counterpart of the paper's CIFAR10-DVS MLP, on the same
        synthetic DVS input (2 polarity channels, 128/down square)."""
        side = 128 // down
        return ConvSNNConfig(in_shape=(2, side, side), conv_channels=channels)

    @property
    def n_in(self) -> int:
        c, h, w = self.in_shape
        return c * h * w

    def conv_out_hw(self, h: int, w: int) -> tuple[int, int]:
        """Conv output spatial dims — the single home of the
        ``(h + 2p - k) // s + 1`` arithmetic (matches Conv2d.out_shape)."""
        k, s, p = self.kernel_size, self.stride, self.padding
        return ((h + 2 * p - k) // s + 1, (w + 2 * p - k) // s + 1)

    def feature_shapes(self) -> list[tuple[int, int, int]]:
        """(C, H, W) entering each conv block, then the final map shape."""
        shapes = [self.in_shape]
        _, h, w = self.in_shape
        for ch in self.conv_channels:
            h, w = self.conv_out_hw(h, w)
            if self.pool > 1:
                h, w = h // self.pool, w // self.pool
            shapes.append((ch, h, w))
        return shapes

    def dense_sizes(self) -> tuple[int, ...]:
        c, h, w = self.feature_shapes()[-1]
        return (c * h * w, *self.dense_hidden, self.num_classes)


def init_conv_snn(key: jax.Array, cfg: ConvSNNConfig) -> list[jax.Array]:
    """Trainable params, in forward order: OIHW conv kernels then dense
    matrices (pools are fixed and carry no params).  Kaiming-ish, no bias
    (the hardware has no bias path)."""
    params: list[jax.Array] = []
    c_in = cfg.in_shape[0]
    k = cfg.kernel_size
    for c_out in cfg.conv_channels:
        key, sub = jax.random.split(key)
        fan_in = c_in * k * k
        params.append(jax.random.normal(sub, (c_out, c_in, k, k))
                      * jnp.sqrt(2.0 / fan_in))
        c_in = c_out
    sizes = cfg.dense_sizes()
    for i in range(len(sizes) - 1):
        key, sub = jax.random.split(key)
        params.append(jax.random.normal(sub, (sizes[i], sizes[i + 1]))
                      * jnp.sqrt(2.0 / sizes[i]))
    return params


def _split_params(params: list[jax.Array], cfg: ConvSNNConfig):
    n_conv = len(cfg.conv_channels)
    return params[:n_conv], params[n_conv:]


def _sum_pool(x: jax.Array, pool: int) -> jax.Array:
    """Non-overlapping sum pooling over NCHW maps (the SumPool2d spec)."""
    return jax.lax.reduce_window(x, 0.0, jax.lax.add,
                                 (1, 1, pool, pool), (1, 1, pool, pool),
                                 "VALID")


def conv_snn_forward(params: list[jax.Array], spikes: jax.Array,
                     cfg: ConvSNNConfig):
    """spikes [T, B, n_in] -> (out_counts [B, n_cls], out_spikes [T, B, n_cls]).

    Per step: conv -> LIF -> sum-pool -> LIF per block, flatten, dense ->
    LIF per head layer — one LIF membrane carried per mapped layer, the
    structure ``map_model`` lowers.
    """
    convs, denses = _split_params(params, cfg)
    batch = spikes.shape[1]
    shapes = cfg.feature_shapes()

    def step(vs, s_t):
        new_vs = []
        vi = 0
        x = s_t.reshape(batch, *cfg.in_shape)
        for bi, k in enumerate(convs):
            cur = jax.lax.conv_general_dilated(
                x, k, window_strides=(cfg.stride, cfg.stride),
                padding=[(cfg.padding, cfg.padding)] * 2,
                dimension_numbers=("NCHW", "OIHW", "NCHW"))
            v, x = lif_step(vs[vi], cur, cfg.lif)
            new_vs.append(v); vi += 1
            if cfg.pool > 1:
                cur = _sum_pool(x, cfg.pool)
                v, x = lif_step(vs[vi], cur, cfg.lif)
                new_vs.append(v); vi += 1
        x = x.reshape(batch, -1)
        for w in denses:
            cur = x @ w
            v, x = lif_step(vs[vi], cur, cfg.lif)
            new_vs.append(v); vi += 1
        return new_vs, x

    v0 = []
    for bi, ch in enumerate(cfg.conv_channels):
        ph, pw = cfg.conv_out_hw(shapes[bi][1], shapes[bi][2])
        v0.append(jnp.zeros((batch, ch, ph, pw)))
        if cfg.pool > 1:
            v0.append(jnp.zeros((batch, ch, ph // cfg.pool, pw // cfg.pool)))
    for n in cfg.dense_sizes()[1:]:
        v0.append(jnp.zeros((batch, n)))
    _, out_spikes = jax.lax.scan(step, v0, spikes)
    return out_spikes.sum(axis=0), out_spikes


def layer_specs(params: "list[jax.Array] | list[np.ndarray]",
                cfg: ConvSNNConfig) -> list[LayerSpec]:
    """Lower trained (possibly pruned) params to the ``map_model`` stack:
    ``Conv2d`` per conv block, ``SumPool2d`` after it, ``Dense`` per head
    layer — one spec per MX-NEURACORE, LIF after each, exactly the
    training graph of :func:`conv_snn_forward`."""
    convs, denses = _split_params([np.asarray(p) for p in params], cfg)
    specs: list[LayerSpec] = []
    shapes = cfg.feature_shapes()
    for bi, k in enumerate(convs):
        conv = Conv2d(kernel=k, in_shape=shapes[bi], stride=cfg.stride,
                      padding=cfg.padding)
        specs.append(conv)
        if cfg.pool > 1:
            specs.append(SumPool2d(conv.out_shape, cfg.pool))
    for w in denses:
        specs.append(Dense(w=w))
    return specs


def conv_snn_loss(params, spikes, labels, cfg: ConvSNNConfig):
    counts, _ = conv_snn_forward(params, spikes, cfg)
    logp = jax.nn.log_softmax(counts)   # rate code: counts are the logits
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    acc = (counts.argmax(-1) == labels).mean()
    return loss, acc


# Training lives in the unified engine path: repro.engine.snn_train
# (train_snn_model with CONV_MODEL / model_for(cfg)) — sharded DP, dynamic
# lr, checkpoint/elastic/straggler machinery.  This module only defines the
# model: init / forward / loss / layer_specs.
