"""Pallas TPU kernel: int8-weight matmul — the C2C ladder MAC, MXU-native.

The A-SYN's C2C ladder is an 8-bit digital-word x analog-voltage multiplier
(paper eq. (2)).  Its TPU-native equivalent is an int8-weight matmul with a
dequant scale folded into the epilogue: activations (spike rates / counts)
in f32, weights resident as int8 (half the HBM traffic of bf16), MXU-aligned
128x128x128 blocking, f32 accumulation across the K grid axis.

Grid = (M/bm, N/bn, K/bk) with the output block revisited along K
(accumulate-in-place; initialized at k==0).  Block shapes default to MXU
multiples (128) and keep the working set (bm*bk + bk*bn + bm*bn floats)
well under VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _c2c_matmul_kernel(x_ref, w_ref, scale_ref, out_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]                                   # [bm, bk] f32
    w = w_ref[...].astype(jnp.float32)               # [bk, bn] int8 -> f32
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    out_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _epilogue():
        out_ref[...] *= scale_ref[0]


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def c2c_matmul(x: jax.Array, w_q: jax.Array, scale: jax.Array,
               bm: int = 128, bk: int = 128, bn: int = 128,
               interpret: bool = False) -> jax.Array:
    """x [M, K] f32, w_q [K, N] int8, scale scalar f32 -> [M, N] f32."""
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        f"({m},{k},{n}) not tileable by ({bm},{bk},{bn})"
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    scale_arr = jnp.reshape(scale.astype(jnp.float32), (1,))
    kern = functools.partial(_c2c_matmul_kernel, n_k=n_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w_q, scale_arr)
