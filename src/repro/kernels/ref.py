"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret-mode
allclose sweeps in tests/test_kernels.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import c2c_ladder_value


def event_synapse_ref(events: jax.Array, weights: jax.Array) -> jax.Array:
    """Event-driven synaptic accumulation, dense oracle.

    events:  [B, E] int32 — indices of spiking source neurons, padded with -1.
    weights: [n_src, n_dest] f32.
    returns: [B, n_dest] f32 — sum of weight rows of the (valid) events.
    """
    mask = (events >= 0)[..., None]                      # [B, E, 1]
    rows = weights[jnp.clip(events, 0), :]               # [B, E, n_dest]
    return jnp.sum(jnp.where(mask, rows, 0.0), axis=1)


def lif_update_ref(v: jax.Array, current: jax.Array, beta: float,
                   threshold: float, v_reset: float):
    """Fused LIF membrane update oracle (matches core.lif.lif_step forward)."""
    v_int = beta * v + current
    spikes = (v_int >= threshold).astype(v.dtype)
    v_next = jnp.where(spikes > 0, v_reset, v_int)
    return v_next, spikes


def c2c_matmul_ref(x: jax.Array, w_q: jax.Array, scale: jax.Array) -> jax.Array:
    """int8-weight matmul oracle: x [M,K] f32, w_q [K,N] int8, scale scalar.

    out = x @ (w_q * scale)
    """
    return x @ (w_q.astype(jnp.float32) * scale)


def c2c_matmul_ladder_ref(x: jax.Array, w_q: jax.Array, scale: jax.Array,
                          bits: int = 8) -> jax.Array:
    """Bit-serial evaluation through the *ideal C2C ladder* (paper eq. (2)):

        V_out = V_ref * sum_i W_i 2^{i-n},   V_ref = scale * 2^n

    Proves the kernel computes exactly what the analog ladder would ideally
    produce (sign-magnitude handling per quant.py).
    """
    frac = c2c_ladder_value(w_q, bits=bits)              # q / 2^n in [-1, 1)
    v_ref = scale * (2.0**bits)
    return x @ (frac * v_ref)
