"""Checkpointing: atomic commit, async writer, restore, elastic resharding."""

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32)),
            "opt": {"m": jnp.zeros((8, 16)), "step": jnp.asarray(3)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 10, t)
    assert latest_step(str(tmp_path)) == 10
    r = restore_checkpoint(str(tmp_path), 10, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


import jax  # noqa: E402


def test_atomic_commit_ignores_tmp(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    # simulate a crashed writer: stale tmp dir for step 7
    os.makedirs(tmp_path / "step_00000007.tmp")
    assert latest_step(str(tmp_path)) == 5


def test_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, t)
    mgr.wait()
    steps = sorted(int(d[5:]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]
    assert mgr.latest() == 4


def test_restore_across_device_counts(tmp_path):
    """Elastic restart: save on 8 emulated devices (sharded), restore on 4 —
    run in subprocesses with different device counts."""
    script = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_checkpoint
mode, path = sys.argv[1], sys.argv[2]
mesh = jax.make_mesh((%d,), ("data",))
sh = NamedSharding(mesh, P("data"))
t = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
if mode == "save":
    t = {"w": jax.device_put(t["w"], sh)}
    save_checkpoint(path, 1, t)
else:
    r = restore_checkpoint(path, 1, t, shardings={"w": sh})
    assert r["w"].sharding == sh
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
print("OK", mode)
"""
    env = dict(os.environ, PYTHONPATH="src")
    p1 = subprocess.run([sys.executable, "-c", script % (8, 8), "save",
                         str(tmp_path)], capture_output=True, text=True,
                        env=env, cwd=os.path.dirname(os.path.dirname(
                            os.path.abspath(__file__))))
    assert "OK save" in p1.stdout, p1.stderr[-2000:]
    p2 = subprocess.run([sys.executable, "-c", script % (4, 4), "restore",
                         str(tmp_path)], capture_output=True, text=True,
                        env=env, cwd=os.path.dirname(os.path.dirname(
                            os.path.abspath(__file__))))
    assert "OK restore" in p2.stdout, p2.stderr[-2000:]
