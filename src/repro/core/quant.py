"""8-bit post-training quantization (paper §III, Algorithm 1 step 2).

The accelerator stores weights in 8-bit digital form feeding the C2C ladder
(eq. (2)): the ladder computes ``V_ref * sum_i W_i 2^{i-n}`` — an unsigned
fractional n-bit multiply.  Signed weights are handled the way charge-domain
macros do it in practice: sign-magnitude, with the sign selecting the
polarity of V_ref.  We therefore quantize symmetrically to int8 with a
per-tensor (or per-row) scale.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """int8 values + float scale; dequant = q * scale."""

    q: jax.Array          # int8
    scale: jax.Array      # f32 scalar or per-axis vector

    def dequantize(self) -> jax.Array:
        return self.q.astype(jnp.float32) * self.scale

    @property
    def shape(self):
        return self.q.shape


def quantize_symmetric(w: jax.Array, bits: int = 8, axis: int | None = None) -> QuantizedTensor:
    """Symmetric signed quantization to ``bits`` bits.

    axis=None → per-tensor scale; axis=k → per-slice scale along axis k
    (kept as a broadcastable vector).

    The clip is symmetric ``[-qmax, qmax]``: the sign-magnitude C2C ladder
    (1 polarity bit + ``bits-1`` magnitude bits, eq. (2)) cannot represent
    the two's-complement extreme ``-(qmax+1)`` — its magnitude needs a
    ``bits``-th magnitude bit — so that code must never be emitted.
    """
    qmax = 2 ** (bits - 1) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(w))
    else:
        amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int8)
    return QuantizedTensor(q=q, scale=scale.astype(jnp.float32))


def c2c_ladder_value(q_row: jax.Array, bits: int = 8) -> jax.Array:
    """Ideal C2C-ladder output fraction for a digital word (paper eq. (2)).

    For an unsigned word W with bits W_{n-1}..W_0:
        frac = sum_{i=0}^{n-1} W_i * 2^{i-n}
    Signed int8 is treated sign-magnitude (sign flips V_ref polarity).
    Returns the fraction in [-1, 1), such that ``V_out = V_ref * frac``.
    """
    sign = jnp.where(q_row < 0, -1.0, 1.0)
    mag = jnp.abs(q_row.astype(jnp.int32))
    weights = 2.0 ** (jnp.arange(bits) - bits)  # 2^{i-n}
    bit_vals = jnp.stack([(mag >> i) & 1 for i in range(bits)], axis=-1).astype(jnp.float32)
    return sign * (bit_vals @ weights)


def quantize_pytree(params, bits: int = 8):
    """Quantize every >=2-D float leaf of a pytree (weight matrices); leave
    biases / scalars in float.  Returns (quantized pytree of QuantizedTensor
    or raw leaf, dequantized float pytree for execution)."""

    def q_leaf(w):
        if hasattr(w, "ndim") and w.ndim >= 2 and jnp.issubdtype(w.dtype, jnp.floating):
            return quantize_symmetric(w, bits=bits)
        return w

    qtree = jax.tree.map(q_leaf, params)

    def dq_leaf(leaf):
        if isinstance(leaf, QuantizedTensor):
            return leaf.dequantize()
        return leaf

    dqtree = jax.tree.map(dq_leaf, qtree, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    return qtree, dqtree


def quantization_error(w: jax.Array, bits: int = 8) -> jax.Array:
    qt = quantize_symmetric(w, bits=bits)
    return jnp.max(jnp.abs(qt.dequantize() - w))
