"""Sharding rules + distributed execution on emulated multi-device meshes."""

import os
import subprocess
import sys

import jax
import pytest

from repro.parallel.sharding import (DECODE_RULES_SP, TRAIN_RULES,
                                     ShardingRules)


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    pre = (f'import os; os.environ["XLA_FLAGS"] = '
           f'"--xla_force_host_platform_device_count={devices}"\n')
    p = subprocess.run([sys.executable, "-c", pre + script],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=600)
    assert p.returncode == 0, (p.stdout[-2000:], p.stderr[-4000:])
    return p.stdout


def test_rules_divisibility_fallback():
    mesh = jax.make_mesh((1,), ("model",))
    r = ShardingRules(mesh, TRAIN_RULES)
    # kv_heads=8 divisible by 1 -> sharded (trivially)
    spec = r.spec(("layers", "embed", "kv_heads", "head_dim"), (2, 16, 8, 4))
    assert spec[2] == "model"


def test_rules_drop_nondivisible():
    from jax.sharding import PartitionSpec
    # fake a 16-wide model axis via a mesh of shape (1,) is impossible —
    # test the arithmetic path directly with a virtual mesh in a subprocess
    out = _run("""
import jax
from repro.parallel.sharding import ShardingRules, TRAIN_RULES
mesh = jax.make_mesh((2, 4), ("data", "model"))
r = ShardingRules(mesh, TRAIN_RULES)
# kv_heads=6 not divisible by 4 -> dropped
s1 = r.spec(("kv_heads",), (6,))
assert s1[0] is None, s1
# heads=8 divisible -> sharded
s2 = r.spec(("heads",), (8,))
assert s2[0] == "model", s2
# batch maps to ("pod","data") but pod missing -> data only
s3 = r.spec(("act_batch",), (8,))
assert s3[0] == "data", s3
print("OK")
""")
    assert "OK" in out


def test_no_axis_reuse_within_spec():
    mesh = jax.make_mesh((1,), ("model",))
    r = ShardingRules(mesh, TRAIN_RULES)
    spec = r.spec(("heads", "mlp"), (4, 8))
    used = [s for s in spec if s is not None]
    assert len(used) == len(set(used))


def test_train_step_spmd_8dev():
    """Full sharded train step executes on a 4x2 mesh and loss is finite."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.parallel.sharding import TRAIN_RULES, activate
from repro.engine.train_loop import make_train_step, init_train_state
from repro.optim.adamw import AdamWConfig
cfg = get_smoke_config("internlm2_1_8b")
bundle = build_model(cfg)
mesh = jax.make_mesh((4, 2), ("data", "model"))
with activate(mesh, TRAIN_RULES):
    params = bundle.init(jax.random.key(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1)
    state = init_train_state(None, params, opt_cfg).as_tree()
    step = jax.jit(make_train_step(bundle.loss, opt_cfg))
    batch = {"tokens": jnp.ones((8, 17), jnp.int32)}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
print("OK", float(metrics["loss"]))
""")
    assert "OK" in out


def test_sp_flash_decode_matches_baseline():
    """Sequence-parallel flash-decoding == baseline decode attention, on an
    8-device mesh with the cache seq-sharded."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.transformer import decode_attention, _cache_positions
from repro.parallel.decode import make_sp_attention
from repro.configs import get_smoke_config

mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
B, H, KH, HD, C = 4, 8, 2, 16, 32
q = jnp.asarray(rng.normal(size=(B, H, HD)).astype(np.float32))
ck = jnp.asarray(rng.normal(size=(B, KH, C, HD)).astype(np.float32))
cv = jnp.asarray(rng.normal(size=(B, KH, C, HD)).astype(np.float32))
pos = jnp.asarray(20, jnp.int32)
slot_pos = jnp.where(jnp.arange(C) <= 20, jnp.arange(C), -1)

want = decode_attention(q, ck, cv, slot_pos, pos, None)

cks = jax.device_put(ck, NamedSharding(mesh, P("data", None, "model")))
cvs = jax.device_put(cv, NamedSharding(mesh, P("data", None, "model")))
attn = make_sp_attention(mesh, batch_axes=("data",))
got = attn(q, cks, cvs, slot_pos, pos, None)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

# windowed variant
want_w = decode_attention(q, ck, cv, slot_pos, pos, 8)
got_w = attn(q, cks, cvs, slot_pos, pos, 8)
np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w), atol=1e-5)
print("OK")
""")
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import pipeline_forward, sequential_reference
mesh = jax.make_mesh((4,), ("stage",))
rng = np.random.default_rng(0)
n_stages, n_micro, mb, d = 4, 6, 3, 8
params = {"w": jnp.asarray(rng.normal(size=(n_stages, d, d)).astype(np.float32) * 0.3)}
xs = jnp.asarray(rng.normal(size=(n_micro, mb, d)).astype(np.float32))
def layer_fn(p, x):
    return jnp.tanh(x @ p["w"])
got = pipeline_forward(layer_fn, params, xs, mesh)
want = sequential_reference(layer_fn, params, xs)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
print("OK")
""", devices=4)
    assert "OK" in out


def test_multipod_mesh_axes():
    out = _run("""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert m1.axis_names == ("data", "model") and m1.devices.shape == (16, 16)
m2 = make_production_mesh(multi_pod=True)
assert m2.axis_names == ("pod", "data", "model")
assert m2.devices.shape == (2, 16, 16)
print("OK")
""", devices=512)
    assert "OK" in out
