"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment the conv/mel frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings [B, S_enc, d_model].  The backbone is faithful:
sinusoidal positions, pre-LN transformer encoder (bidirectional), decoder
with causal self-attention + cross-attention, tied LM head on the decoder.

train_4k:   S_enc = seq_len, S_dec = seq_len // decoder_ratio, seq2seq CE loss.
prefill:    encode + decoder prefill over the prompt -> (self + cross caches).
decode:     one decoder token against self cache (cache_len) + cross cache.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.common import ArchConfig
from repro.models.layers import (P, bf16_layers, cross_entropy,
                                 flash_attention, init_params, param_axes,
                                 rms_norm)
from repro.models.transformer import _cache_positions, decode_attention
from repro.parallel.sharding import shard


def _hd(cfg):
    return cfg.resolved_head_dim()


def whisper_specs(cfg: ArchConfig) -> dict:
    d, hd = cfg.d_model, _hd(cfg)
    h, kh = cfg.n_heads, cfg.n_kv_heads
    le, ld = cfg.n_encoder_layers or cfg.n_layers, cfg.n_layers

    def attn(L):
        return {
            "wq": P((L, d, h, hd), ("layers", "embed", "heads", "head_dim")),
            "wk": P((L, d, kh, hd), ("layers", "embed", "kv_heads", "head_dim")),
            "wv": P((L, d, kh, hd), ("layers", "embed", "kv_heads", "head_dim")),
            "wo": P((L, h, hd, d), ("layers", "heads", "head_dim", "embed")),
        }

    def mlp(L):
        return {
            "w_in": P((L, d, cfg.d_ff), ("layers", "embed", "mlp")),
            "w_out": P((L, cfg.d_ff, d), ("layers", "mlp", "embed")),
        }

    enc = {"ln1": P((le, d), ("layers", "embed"), "ones"),
           "ln2": P((le, d), ("layers", "embed"), "ones"),
           **attn(le), **mlp(le)}
    dec = {"ln1": P((ld, d), ("layers", "embed"), "ones"),
           "ln2": P((ld, d), ("layers", "embed"), "ones"),
           "ln3": P((ld, d), ("layers", "embed"), "ones"),
           **attn(ld),
           "xwq": P((ld, d, h, hd), ("layers", "embed", "heads", "head_dim")),
           "xwk": P((ld, d, kh, hd), ("layers", "embed", "kv_heads", "head_dim")),
           "xwv": P((ld, d, kh, hd), ("layers", "embed", "kv_heads", "head_dim")),
           "xwo": P((ld, h, hd, d), ("layers", "heads", "head_dim", "embed")),
           **mlp(ld)}
    return {
        "embed": P((cfg.vocab_size, d), ("vocab", "embed"), "embed", scale=0.02),
        "ln_enc": P((d,), ("embed",), "ones"),
        "ln_dec": P((d,), ("embed",), "ones"),
        "encoder": enc,
        "decoder": dec,
    }


def init_whisper(key, cfg: ArchConfig, dtype=jnp.float32):
    return init_params(key, whisper_specs(cfg), dtype)


def whisper_axes(cfg: ArchConfig):
    return param_axes(whisper_specs(cfg))


def _sinusoid(s: int, d: int) -> jax.Array:
    pos = jnp.arange(s)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _gelu_mlp(x, lp, cfg):
    h = rms_norm(x, lp["ln2"] if "ln3" not in lp else lp["ln3"], cfg.norm_eps)
    y = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, lp["w_in"]))
    y = shard(y, "act_batch", "act_seq", "act_mlp")
    return x + shard(jnp.einsum("bsf,fd->bsd", y, lp["w_out"]),
                     "act_batch", "act_seq", "act_embed")


def _self_attn(x, lp, cfg, causal, q_chunk=512, kv_chunk=512):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
    q = shard(q, "act_batch", "act_seq", "act_heads", "act_head_dim")
    o = flash_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                        kv_chunk=kv_chunk)
    return x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])


def _cross_attn(x, enc_out, lp, cfg, q_chunk=512, kv_chunk=512):
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["xwq"])
    k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xwk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xwv"])
    q = shard(q, "act_batch", "act_seq", "act_heads", "act_head_dim")
    o = flash_attention(q, k, v, causal=False, q_chunk=q_chunk,
                        kv_chunk=kv_chunk)
    return x + jnp.einsum("bshk,hkd->bsd", o, lp["xwo"])


def whisper_encode(params, cfg: ArchConfig, frames: jax.Array,
                   remat: bool = True) -> jax.Array:
    """frames [B, S_enc, d] (stub frontend output) -> encoder states."""
    b, s, d = frames.shape
    x = (frames + _sinusoid(s, d)[None]).astype(jnp.bfloat16)
    x = shard(x, "act_batch", "act_seq", "act_embed")

    def body(xx, lp):
        xx = _self_attn(xx, lp, cfg, causal=False)
        xx = _gelu_mlp(xx, lp, cfg)
        return xx, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, bf16_layers(params["encoder"]))
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)


def whisper_decoder_logits(params, cfg: ArchConfig, tokens: jax.Array,
                           enc_out: jax.Array, remat: bool = True):
    b, s = tokens.shape
    d = cfg.d_model
    x = params["embed"][tokens].astype(jnp.bfloat16) * math.sqrt(d)
    x = (x + _sinusoid(s, d)[None].astype(jnp.bfloat16))
    x = shard(x, "act_batch", "act_seq", "act_embed")

    def body(xx, lp):
        xx = _self_attn(xx, lp, cfg, causal=True)
        xx = _cross_attn(xx, enc_out, lp, cfg)
        xx = _gelu_mlp(xx, lp, cfg)
        return xx, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, bf16_layers(params["decoder"]))
    x = rms_norm(x, params["ln_dec"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x,
                        params["embed"].astype(jnp.bfloat16))  # tied head
    return shard(logits, "act_batch", "act_seq", "act_vocab")


def whisper_loss(params, cfg: ArchConfig, batch: dict) -> jax.Array:
    enc_out = whisper_encode(params, cfg, batch["frames"])
    toks = batch["tokens"]
    logits = whisper_decoder_logits(params, cfg, toks[:, :-1], enc_out)
    return cross_entropy(logits, toks[:, 1:])


# ------------------------------------------------------------------ decode

def whisper_cache_spec(cfg: ArchConfig, batch: int, cache_len: int):
    hd = _hd(cfg)
    ld = cfg.n_layers
    self_shape = (ld, batch, cfg.n_kv_heads, cache_len, hd)
    cross_shape = (ld, batch, cfg.n_kv_heads, cfg.cross_len, hd)
    ax = ("layers", "cache_batch", "cache_kv_heads", "cache_seq",
          "act_head_dim")
    cax = ("layers", "cache_batch", "cache_kv_heads", "act_seq", "act_head_dim")
    return ({"k": jax.ShapeDtypeStruct(self_shape, jnp.bfloat16),
             "v": jax.ShapeDtypeStruct(self_shape, jnp.bfloat16),
             "xk": jax.ShapeDtypeStruct(cross_shape, jnp.bfloat16),
             "xv": jax.ShapeDtypeStruct(cross_shape, jnp.bfloat16)},
            {"k": ax, "v": ax, "xk": cax, "xv": cax})


def init_whisper_cache(cfg: ArchConfig, batch: int, cache_len: int):
    spec, _ = whisper_cache_spec(cfg, batch, cache_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def whisper_decode_step(params, cfg: ArchConfig, cache: dict,
                        tokens: jax.Array, pos: jax.Array,
                        attn_impl=decode_attention):
    b = tokens.shape[0]
    d = cfg.d_model
    clen = cache["k"].shape[3]
    slot_pos = _cache_positions(cfg, clen, pos)
    cross_pos = jnp.arange(cache["xk"].shape[3])
    x = params["embed"][tokens].astype(jnp.bfloat16) * math.sqrt(d)
    x = x + _sinusoid_at(pos, d).astype(jnp.bfloat16)

    def body(xx, layer_in):
        lp, ck, cv, xk, xv = layer_in
        h = rms_norm(xx, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bd,dhk->bhk", h, lp["wq"])
        k_new = jnp.einsum("bd,dhk->bhk", h, lp["wk"])
        v_new = jnp.einsum("bd,dhk->bhk", h, lp["wv"])
        ck = jax.lax.dynamic_update_slice(ck, k_new.astype(ck.dtype)[:, :, None],
                                          (0, 0, pos, 0))
        cv = jax.lax.dynamic_update_slice(cv, v_new.astype(cv.dtype)[:, :, None],
                                          (0, 0, pos, 0))
        o = attn_impl(q, ck, cv, slot_pos, pos, None)
        xx = xx + jnp.einsum("bhk,hkd->bd", o, lp["wo"])
        # cross attention against the (precomputed) encoder cache
        h2 = rms_norm(xx, lp["ln2"], cfg.norm_eps)
        q2 = jnp.einsum("bd,dhk->bhk", h2, lp["xwq"])
        o2 = attn_impl(q2, xk, xv, cross_pos, jnp.asarray(2**30, jnp.int32),
                       None)
        xx = xx + jnp.einsum("bhk,hkd->bd", o2, lp["xwo"])
        h3 = rms_norm(xx, lp["ln3"], cfg.norm_eps)
        y = jax.nn.gelu(jnp.einsum("bd,df->bf", h3, lp["w_in"]))
        xx = xx + jnp.einsum("bf,fd->bd", y, lp["w_out"])
        return xx, (ck, cv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (bf16_layers(params["decoder"]), cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = rms_norm(x, params["ln_dec"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x, params["embed"].astype(jnp.bfloat16))
    new_cache = dict(cache)
    new_cache.update({"k": nk, "v": nv})
    return shard(logits, "act_batch", "act_vocab"), new_cache


def _sinusoid_at(pos: jax.Array, d: int) -> jax.Array:
    i = jnp.arange(d // 2).astype(jnp.float32)
    ang = pos.astype(jnp.float32) / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])


def whisper_prefill(params, cfg: ArchConfig, frames: jax.Array,
                    tokens: jax.Array):
    """Encode frames + prefill the decoder prompt.  Returns (last logits,
    cache dict with self-cache filled to len(tokens) and cross caches)."""
    enc_out = whisper_encode(params, cfg, frames, remat=False)
    b, s = tokens.shape
    d = cfg.d_model
    x = params["embed"][tokens].astype(jnp.bfloat16) * math.sqrt(d)
    x = x + _sinusoid(s, d)[None].astype(jnp.bfloat16)
    x = shard(x, "act_batch", "act_seq", "act_embed")

    def body(xx, lp):
        h = rms_norm(xx, lp["ln1"], cfg.norm_eps)
        kk = jnp.einsum("bsd,dhk->bshk", h, lp["wk"]).transpose(0, 2, 1, 3)
        vv = jnp.einsum("bsd,dhk->bshk", h, lp["wv"]).transpose(0, 2, 1, 3)
        xk = jnp.einsum("bsd,dhk->bshk", enc_out,
                        lp["xwk"]).transpose(0, 2, 1, 3)
        xv = jnp.einsum("bsd,dhk->bshk", enc_out,
                        lp["xwv"]).transpose(0, 2, 1, 3)
        xx = _self_attn(xx, lp, cfg, causal=True)
        xx = _cross_attn(xx, enc_out, lp, cfg)
        xx = _gelu_mlp(xx, lp, cfg)
        return xx, (kk.astype(jnp.bfloat16), vv.astype(jnp.bfloat16),
                    xk.astype(jnp.bfloat16), xv.astype(jnp.bfloat16))

    x, (k, v, xk, xv) = jax.lax.scan(body, x,
                                     bf16_layers(params["decoder"]))
    x = rms_norm(x, params["ln_dec"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1],
                        params["embed"].astype(jnp.bfloat16))
    return (shard(logits, "act_batch", "act_vocab"),
            {"k": k, "v": v, "xk": xk, "xv": xv})
