"""Elastic scaling end-to-end: train on an 8-device mesh, checkpoint,
resume on a 4-device mesh (different sharding), continue training — the
full launcher-level restart path."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os, sys
devices, ckpt, phase = int(sys.argv[1]), sys.argv[2], sys.argv[3]
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.engine.train_loop import (TrainLoopConfig, init_train_state,
                                     make_train_step, resume_or_init,
                                     train_loop)
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import TRAIN_RULES, activate
from repro.data.tokens import TokenPipelineConfig, token_batch

cfg = get_smoke_config("internlm2_1_8b")
bundle = build_model(cfg)
data_cfg = TokenPipelineConfig(vocab_size=cfg.vocab_size, seq_len=32,
                               global_batch=8)
mesh = jax.make_mesh((devices // 2, 2), ("data", "model"))
opt = AdamWConfig(lr=1e-3, warmup_steps=1)
with activate(mesh, TRAIN_RULES):
    params = bundle.init(jax.random.key(0))
    state = init_train_state(None, params, opt).as_tree()
    step_fn = jax.jit(make_train_step(bundle.loss, opt))
    loop_cfg = TrainLoopConfig(steps=10 if phase == "a" else 20,
                               checkpoint_every=10, checkpoint_dir=ckpt,
                               log_every=1000)
    state, start = resume_or_init(loop_cfg, state)
    if phase == "b":
        assert start == 10, start          # resumed across mesh sizes
    def batch_fn(step):
        return {"tokens": jnp.asarray(token_batch(data_cfg, step)["tokens"])}
    state, hist = train_loop(state, step_fn, batch_fn, loop_cfg,
                             start_step=start, log_fn=lambda s: None)
print("OK", phase, float(hist["loss"][-1]))
"""


def test_elastic_restart_8_to_4_devices(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    for devices, phase in ((8, "a"), (4, "b")):
        p = subprocess.run(
            [sys.executable, "-c", SCRIPT, str(devices), str(tmp_path),
             phase],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
        assert p.returncode == 0, (p.stdout[-1500:], p.stderr[-4000:])
        assert f"OK {phase}" in p.stdout
