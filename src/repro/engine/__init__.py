from repro.engine.batched_run import (BatchedDispatchStats, BatchedRunResult,  # noqa: F401
                                      PackedLayer, PackedModel, PackedRound,
                                      pack_model, run_batched, trace_count)
from repro.engine.train_loop import TrainLoopConfig, TrainState, make_train_step, train_loop  # noqa: F401
