"""Spoof a multi-device CPU host — must run before jax initializes.

Shared by the serving entry points (``launch/serve_snn.py``,
``benchmarks/serving_bench.py``): they call :func:`spoof_devices_from_argv`
at module top, before their first jax import.  This module must therefore
never import jax itself.
"""

from __future__ import annotations

import os
import sys


def spoof_devices_from_argv(argv: list[str] | None = None) -> int | None:
    """Scan argv for ``--spoof-devices N`` / ``--spoof-devices=N`` and set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.  Returns the
    requested count (None if the flag is absent) so callers can assert the
    device count actually took effect after jax initializes."""
    argv = sys.argv if argv is None else argv
    n: int | None = None
    for i, arg in enumerate(argv):
        if arg == "--spoof-devices":
            if i + 1 >= len(argv):
                raise SystemExit("--spoof-devices requires a device count")
            n = int(argv[i + 1])
        elif arg.startswith("--spoof-devices="):
            n = int(arg.split("=", 1)[1])
    if n is not None:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}")
    return n


def assert_spoof_applied(requested: int | None) -> None:
    """Call after jax init: fail loudly if the spoof did not take effect
    (e.g. jax was already initialized by an earlier import)."""
    if requested is None:
        return
    import jax
    assert len(jax.devices()) >= requested, \
        f"requested {requested} spoofed devices but jax sees " \
        f"{len(jax.devices())} — was jax imported before the spoof?"
