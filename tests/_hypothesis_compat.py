"""`hypothesis` when available, no-op stand-ins otherwise.

`hypothesis` ships in the package's ``[dev]`` extra (installed by CI), not as
a runtime dependency.  Importing ``given``/``settings``/``st`` from here lets
a bare environment skip just the property tests instead of erroring out of —
or skipping — whole modules that are mostly plain pytest tests.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:
    def given(*_args, **_kwargs):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _Strategies:
        """Accepts any strategy expression at decoration time."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
