"""Per-request span tracing + flight recorder for the serving fabric.

The serving stack already computes everything an operator needs to answer
"where did request X spend its time" — admission instants, scheduler
trigger decisions, per-dispatch :class:`~repro.core.memories.DispatchStats`,
utilization, energy — and then throws it away at aggregate granularity:
25-odd ``METRIC_KEYS`` scalars and a bounded telemetry deque.  This module
is the measurement substrate underneath those aggregates (the
bottleneck-modeling argument of arXiv 2511.21549: optimization needs
measured per-stage breakdowns, not end-to-end averages):

  * :class:`RequestTrace` — one admitted request's life as typed
    :class:`Span` s (``admit -> queue -> schedule -> pad -> dispatch ->
    slice -> complete``, plus per-layer ``hw`` sub-spans carrying the
    dispatch counters and energy sampled from the engine results).  Every
    timestamp comes from the *server's* pluggable clock, so a
    :class:`~repro.engine.stream_server.VirtualClock` replay produces
    byte-identical traces that tests golden-lock (``dump_json()``).
  * :class:`FlightRecorder` — a bounded ring buffer of the last N completed
    traces plus **every** anomalous one (deadline miss, shed, reject,
    device loss, hot-swap pin, noise-probe disagreement, policy extension
    — :data:`ANOMALY_KINDS`), with lifetime-exact ``anomaly_counts`` and a
    sorted-keys JSON ``dump()`` for on-demand or on-fault snapshots.  The
    chaos harness asserts every injected fault appears here as a typed
    anomaly.
  * :class:`Histogram` — fixed-bucket cumulative histograms
    (:data:`HIST_KEYS`: TTFD, clock-observed service time, end-to-end
    latency, bucket fill).  ``ServerMetrics`` percentiles are computed from
    these, so long soaks never silently forget the tail the way the
    bounded ``METRICS_WINDOW`` deque does; the windowed values survive
    under explicit ``recent_*`` keys.
  * jit probe — :meth:`FlightRecorder.attach_jit_probe` subscribes to the
    engine's retrace counter (:func:`repro.engine.batched_run
    .add_trace_listener`), so compile and donation events land in
    ``jit_events``.  They are deliberately **excluded** from ``dump()``:
    the first replay of a trace compiles and the second hits the cache, so
    including them would break the byte-identical-replay contract.

Determinism contract (tested, ``tests/test_tracing.py``): two
``run_scenario`` replays of the same scenario yield byte-identical
``dump_json()``, and a tracer-on run is bit-exact (results *and* metrics)
with a tracer-off run — the observer effect on the served bits is zero.
Wall-clock measurements (``record["seconds"]``) never enter a trace.
"""

from __future__ import annotations

import bisect
import collections
import dataclasses
import json
import math

from repro.engine import batched_run as br

# The span taxonomy, in request-lifecycle order.  Locked by
# tests/test_tracing.py and the docs/OBSERVABILITY.md span table
# (tests/test_docs.py) — dashboards parsing dumps key on these.
SPAN_KINDS = ("admit", "queue", "schedule", "pad", "dispatch", "slice",
              "hw", "complete")

# Typed anomaly kinds a FlightRecorder can record; every chaos-injected
# fault must surface as one of these (asserted by the soak harness).
# Locked like SPAN_KINDS.
ANOMALY_KINDS = ("reject", "shed", "policy_extension", "deadline_miss",
                 "device_loss", "hot_swap_pin", "noise_disagreement")

# The cumulative-histogram set (FlightRecorder.hist and the histogram
# fields of ServerMetrics): time-to-first-dispatch, clock-observed service
# time per dispatch, end-to-end latency, and bucket fill ratio.
HIST_KEYS = ("ttfd_s", "service_s", "latency_s", "fill")

# Log-spaced time edges, 8 buckets/decade over [1 us, 100 s]: fine enough
# that a p99 read off a bucket's upper edge is within ~33% of exact, fixed
# so dumps from different runs/hosts are comparable bucket-for-bucket.
TIME_EDGES = tuple(10.0 ** (-6.0 + i / 8.0) for i in range(65))

# Linear edges for ratios in (0, 1] (bucket fill).
RATIO_EDGES = tuple((i + 1) / 32.0 for i in range(32))


class Histogram:
    """Fixed-bucket cumulative histogram with deterministic percentiles.

    ``add`` is O(log n_edges); ``percentile(q)`` returns the **upper edge**
    of the bucket holding the q-th sample (an upper bound on the true
    percentile, exact to one bucket width) — a pure function of the counts,
    so two runs that saw the same samples report identical percentiles.
    Unlike a bounded sample window, the counts are lifetime-exact: a
    million-request soak's p99 still reflects every request."""

    __slots__ = ("edges", "counts", "n", "total")

    def __init__(self, edges: tuple[float, ...] = TIME_EDGES):
        self.edges = tuple(float(e) for e in edges)
        assert self.edges and list(self.edges) == sorted(self.edges)
        # counts[i] holds values <= edges[i] (and > edges[i-1]); the final
        # slot is the overflow bucket for values beyond the last edge
        self.counts = [0] * (len(self.edges) + 1)
        self.n = 0
        self.total = 0.0

    def add(self, value: float) -> None:
        v = float(value)
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.n += 1
        self.total += v

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def percentile(self, q: float) -> float:
        """Upper edge of the bucket containing the q-th percentile sample
        (overflow clamps to the last edge); 0.0 when empty."""
        if not self.n:
            return 0.0
        rank = max(1, math.ceil(q / 100.0 * self.n))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return self.edges[min(i, len(self.edges) - 1)]
        return self.edges[-1]

    def to_dict(self) -> dict:
        """JSON-ready summary: sparse nonzero bucket counts (keyed by
        bucket index into the fixed edge grid) plus n/mean/p50/p99."""
        return {"n": int(self.n), "mean": float(self.mean),
                "p50": float(self.percentile(50)),
                "p99": float(self.percentile(99)),
                "counts": {str(i): int(c)
                           for i, c in enumerate(self.counts) if c}}


def _jsonable(v):
    """Coerce span/anomaly attribute values to plain JSON scalars (numpy
    ints/floats sneak in from stats aggregation; inf has no strict-JSON
    encoding, so best-effort deadlines are dropped by callers)."""
    if isinstance(v, bool) or v is None or isinstance(v, str):
        return v
    if isinstance(v, int):
        return int(v)
    if isinstance(v, float):
        return float(v)
    if hasattr(v, "item"):            # numpy scalar
        return _jsonable(v.item())
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return str(v)


@dataclasses.dataclass
class Span:
    """One typed interval of a request's life, on the server's clock.
    ``t0 == t1`` is a point event (every execute-side span under a
    VirtualClock, which does not advance inside an engine call)."""

    kind: str
    t0: float
    t1: float
    attrs: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"kind": self.kind, "t0": float(self.t0),
                "t1": float(self.t1),
                "attrs": {k: _jsonable(v) for k, v in self.attrs.items()}}


@dataclasses.dataclass
class RequestTrace:
    """Every span and anomaly of one admitted request, pinned to the
    (model, generation) it was admitted under."""

    rid: int
    model: str
    generation: int
    arrival_t: float
    spans: list[Span] = dataclasses.field(default_factory=list)
    anomalies: list[dict] = dataclasses.field(default_factory=list)
    completed: bool = False
    end_t: float | None = None

    def to_dict(self) -> dict:
        return {"rid": int(self.rid), "model": self.model,
                "generation": int(self.generation),
                "arrival_t": float(self.arrival_t),
                "completed": bool(self.completed),
                "end_t": None if self.end_t is None else float(self.end_t),
                "spans": [s.to_dict() for s in self.spans],
                "anomalies": list(self.anomalies)}


class FlightRecorder:
    """Bounded in-memory trace store for an always-on server.

    ``keep_completed`` recent completed traces ride a ring buffer;
    anomalous traces (any trace carrying an anomaly, plus every aborted
    one) ride their own larger ring so a burst of healthy traffic cannot
    evict the evidence of a fault.  Server-level anomalies with no request
    attached (admission-time rejects, device loss, hot-swap pins) land in
    ``events``.  ``anomaly_counts`` is lifetime-exact.  All mutators are
    no-ops for unknown rids, so a recorder attached mid-flight never
    raises out of the serving loop."""

    def __init__(self, keep_completed: int = 64, keep_anomalous: int = 256,
                 keep_events: int = 1024):
        self.active: dict[int, RequestTrace] = {}
        self.completed: collections.deque[RequestTrace] = \
            collections.deque(maxlen=keep_completed)
        self.anomalous: collections.deque[RequestTrace] = \
            collections.deque(maxlen=keep_anomalous)
        self.events: collections.deque[dict] = \
            collections.deque(maxlen=keep_events)
        self.anomaly_counts: dict[str, int] = {}
        self.hist: dict[str, Histogram] = {
            "ttfd_s": Histogram(TIME_EDGES),
            "service_s": Histogram(TIME_EDGES),
            "latency_s": Histogram(TIME_EDGES),
            "fill": Histogram(RATIO_EDGES),
        }
        assert tuple(self.hist) == HIST_KEYS
        self.n_started = 0
        self.n_completed = 0
        # jit compile/donation events from the engine's trace probe —
        # kept OUT of dump() (first replay compiles, second is cached;
        # including them would break byte-identical replays)
        self.jit_events: collections.deque[dict] = \
            collections.deque(maxlen=256)
        self._probe_attached = False

    # ---------------------------------------------------------- lifecycle

    def start(self, rid: int, *, model: str, generation: int,
              t: float) -> RequestTrace:
        tr = RequestTrace(rid=int(rid), model=model,
                          generation=int(generation), arrival_t=float(t))
        self.active[rid] = tr
        self.n_started += 1
        return tr

    def span(self, rid: int, kind: str, t0: float, t1: float,
             **attrs) -> None:
        tr = self.active.get(rid)
        if tr is not None:
            tr.spans.append(Span(kind=kind, t0=float(t0), t1=float(t1),
                                 attrs=attrs))

    def complete(self, rid: int, t: float) -> None:
        tr = self.active.pop(rid, None)
        if tr is None:
            return
        tr.completed = True
        tr.end_t = float(t)
        self.n_completed += 1
        self.completed.append(tr)
        if tr.anomalies:
            self.anomalous.append(tr)

    def abort(self, rid: int, t: float) -> None:
        """A traced request that will never complete (shed from the
        queue): always anomalous, never in the completed ring."""
        tr = self.active.pop(rid, None)
        if tr is None:
            return
        tr.end_t = float(t)
        self.anomalous.append(tr)

    def anomaly(self, kind: str, *, t: float, rid: int | None = None,
                **attrs) -> None:
        """Record a typed anomaly — attached to ``rid``'s trace when it is
        still known (active, completed, or already anomalous; late
        anomalies like a post-completion noise-probe disagreement promote
        the trace into the anomalous ring), else as a server-level
        event."""
        assert kind in ANOMALY_KINDS, f"unknown anomaly kind {kind!r}"
        self.anomaly_counts[kind] = self.anomaly_counts.get(kind, 0) + 1
        rec = {"kind": kind, "t": float(t),
               "rid": None if rid is None else int(rid)}
        rec.update({k: _jsonable(v) for k, v in attrs.items()})
        tr = None if rid is None else self.trace(rid)
        if tr is None:
            self.events.append(rec)
            return
        tr.anomalies.append(rec)
        if tr.rid not in self.active and \
                not any(t2 is tr for t2 in self.anomalous):
            self.anomalous.append(tr)

    def observe(self, key: str, value: float) -> None:
        self.hist[key].add(value)

    # ------------------------------------------------------------- probes

    def jit_event(self, kind: str, donated: bool) -> None:
        self.jit_events.append({"kind": kind, "donated": bool(donated)})

    def attach_jit_probe(self) -> "FlightRecorder":
        """Subscribe to the engine's (process-global) retrace probe; jit
        compile + donation events then land in :attr:`jit_events`.
        Idempotent; :meth:`detach_jit_probe` unsubscribes."""
        if not self._probe_attached:
            br.add_trace_listener(self.jit_event)
            self._probe_attached = True
        return self

    def detach_jit_probe(self) -> None:
        if self._probe_attached:
            br.remove_trace_listener(self.jit_event)
            self._probe_attached = False

    # ------------------------------------------------------------ queries

    def trace(self, rid: int) -> RequestTrace | None:
        """Find a trace by rid — active first, then the rings."""
        tr = self.active.get(rid)
        if tr is not None:
            return tr
        for ring in (self.completed, self.anomalous):
            for t in reversed(ring):
                if t.rid == rid:
                    return t
        return None

    def last(self) -> RequestTrace | None:
        """The most recently completed trace."""
        return self.completed[-1] if self.completed else None

    def dump(self) -> dict:
        """The full deterministic snapshot: completed + anomalous rings,
        server-level events, lifetime anomaly counts, and the cumulative
        histograms.  Everything inside comes off the server's clock —
        under a VirtualClock two replays of the same trace produce
        identical dumps (``jit_events`` and wall seconds are excluded for
        exactly this reason)."""
        return {
            "n_started": int(self.n_started),
            "n_completed": int(self.n_completed),
            "completed": [t.to_dict() for t in self.completed],
            "anomalous": [t.to_dict() for t in self.anomalous],
            "events": list(self.events),
            "anomaly_counts": {k: int(v) for k, v in
                               sorted(self.anomaly_counts.items())},
            "histograms": {k: h.to_dict() for k, h in self.hist.items()},
        }

    def dump_json(self) -> str:
        """Sorted-keys JSON of :meth:`dump` — the byte-comparable form the
        determinism tests golden-lock and the ADMIN ``trace`` verb ships."""
        return json.dumps(self.dump(), sort_keys=True)
