"""Pallas TPU kernel: event-driven synaptic accumulation.

The TPU-native form of MENAGE's A-SYN dispatch (DESIGN.md §2): work is
proportional to *events*, not to the dense n_src x n_dest product.  A padded
event list (the software MEM_E) gathers weight rows from the VMEM-resident
weight tile and accumulates membrane currents.

Tiling: grid = (B, n_dest / BLOCK_D).  Each program instance owns one
(sample, dest-block) pair; the full event list of that sample and the
[n_src, BLOCK_D] weight tile are in VMEM.  The inner fori_loop plays the role
of the controller's per-event dispatch cycles; BLOCK_D is the vectorized lane
dimension — the "engine" axis onto which virtual neurons are packed.

The event list is padded to a static length E (MEM_E depth).  Padding entries
are -1 and are masked — the pad factor is the same overflow budget the paper
provisions for the utilization spikes of Figs 6-7.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_D = 256


def _event_synapse_kernel(events_ref, weights_ref, out_ref):
    """events [1, E] int32; weights [n_src, BD] f32; out [1, BD] f32."""
    events = events_ref[0, :]                       # [E]
    n_events = events.shape[0]
    bd = out_ref.shape[1]

    def body(e, acc):
        idx = events[e]
        valid = idx >= 0
        safe = jnp.where(valid, idx, 0)
        row = pl.load(weights_ref, (pl.dslice(safe, 1), slice(None)))  # [1, BD]
        return acc + jnp.where(valid, row[0], jnp.zeros((bd,), acc.dtype))

    acc = jax.lax.fori_loop(0, n_events, body, jnp.zeros((bd,), out_ref.dtype))
    out_ref[0, :] = acc


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def event_synapse(events: jax.Array, weights: jax.Array,
                  block_d: int = DEFAULT_BLOCK_D,
                  interpret: bool = False) -> jax.Array:
    """events [B, E] int32 (pad=-1); weights [n_src, n_dest] f32 ->
    currents [B, n_dest] f32."""
    b, n_events = events.shape
    n_src, n_dest = weights.shape
    if n_events == 0 or b == 0:
        # static zero-depth MEM_E (nothing dispatches) or an empty batch —
        # a zero-size grid still asks pallas for a (1, E) block slice of the
        # (0, E) events operand, so short-circuit before the kernel
        return jnp.zeros((b, n_dest), weights.dtype)
    bd = min(block_d, n_dest)
    assert n_dest % bd == 0, f"n_dest={n_dest} not divisible by block_d={bd}"
    grid = (b, n_dest // bd)
    return pl.pallas_call(
        _event_synapse_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, events.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((n_src, bd), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n_dest), weights.dtype),
        interpret=interpret,
    )(events, weights)


def _event_synapse_packed_kernel(events_ref, packed_ref, scale_ref, out_ref,
                                 *, bits: int):
    """events [1, E] i32; packed [n_src, BDB] int8 (sign-magnitude lanes);
    scale [1, 1] f32; out [1, BD] f32 with ``BD = BDB * 8/bits``.

    The weight tile arrives packed — ``bits/32`` of the f32 VMEM footprint,
    the twin of A-SYN storing sub-byte ladder words.  It is unpacked *once
    per tile* before the event loop: split each byte into ``8/bits``
    sub-words, then 1 sign + ``bits-1`` magnitude bits per word (the C2C
    ladder's own format, quant.pack_signmag) and dequantize by the layer
    scale — the DAC step at the ladder input.  The dequantized tile is a
    loop operand (materialized at the fori_loop boundary), so the event loop
    is gather+add only, with f32 partial sums bit-identical to the dense
    kernel.  Keeping the multiply *inside* the loop is not an option: XLA
    contracts mul+add into an FMA (even across optimization_barrier /
    bitcast fences), skipping the intermediate rounding the dense path has.
    """
    ell = 8 // bits
    mask = (1 << bits) - 1
    mag_mask = (1 << (bits - 1)) - 1
    events = events_ref[0, :]
    n_events = events.shape[0]
    n_src, bd_bytes = packed_ref.shape
    bd = bd_bytes * ell
    scale = scale_ref[0, 0]

    r = packed_ref[...].astype(jnp.int32) & 0xFF  # undo int8 sign extension
    lanes = jnp.stack([(r >> (s * bits)) & mask for s in range(ell)],
                      axis=-1)                    # [n_src, BDB, L], dest-major
    w = lanes.reshape(n_src, bd)
    mag = w & mag_mask
    sign = (w >> (bits - 1)) & 1
    q = (mag - 2 * sign * mag).astype(jnp.float32)
    w_tile = q * scale                            # fl32(q * scale), per elem

    def body(e, acc):
        idx = events[e]
        valid = idx >= 0
        safe = jnp.where(valid, idx, 0)
        row = jax.lax.dynamic_slice_in_dim(w_tile, safe, 1, axis=0)  # [1, BD]
        return acc + jnp.where(valid, row[0], jnp.zeros((bd,), acc.dtype))

    acc = jax.lax.fori_loop(0, n_events, body, jnp.zeros((bd,), out_ref.dtype))
    out_ref[0, :] = acc


@functools.partial(jax.jit, static_argnames=("bits", "block_d", "interpret"))
def event_synapse_packed(events: jax.Array, packed_w: jax.Array,
                         scale: jax.Array, bits: int = 8,
                         block_d: int = DEFAULT_BLOCK_D,
                         interpret: bool = False) -> jax.Array:
    """Packed-operand twin of :func:`event_synapse`.

    events   [B, E] int32 (pad=-1)
    packed_w [n_src, n_dest * bits / 8] int8 — sign-magnitude codes packed
             ``8/bits`` destination lanes per byte (quant.pack_signmag)
    scale    f32 scalar (or [1, 1]) — the layer's symmetric quant scale
    returns  currents [B, n_dest] f32

    The VMEM weight tile per grid point shrinks proportionally to ``bits``
    (int8 codes at 8 bits are already 4x under f32; 4/2-bit lanes are 8x and
    16x).  ``n_dest`` must be a multiple of ``8/bits`` so byte lanes align
    with the dest tiling.
    """
    ell = 8 // bits
    b, n_events = events.shape
    n_src, n_bytes = packed_w.shape
    n_dest = n_bytes * ell
    scale = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    if n_events == 0 or b == 0:
        return jnp.zeros((b, n_dest), jnp.float32)
    bd = min(block_d, n_dest)
    assert bd % ell == 0, \
        f"block_d={bd} not a multiple of {ell} lanes/byte at {bits} bits"
    assert n_dest % bd == 0, f"n_dest={n_dest} not divisible by block_d={bd}"
    grid = (b, n_dest // bd)
    return pl.pallas_call(
        functools.partial(_event_synapse_packed_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, events.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((n_src, bd // ell), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n_dest), jnp.float32),
        interpret=interpret,
    )(events, packed_w, scale)


def events_from_spikes(spikes: jax.Array, max_events: int) -> jax.Array:
    """Convert a dense spike vector batch [B, n_src] to a padded event list
    [B, max_events] (int32, pad=-1) — the software MEM_E writer.  Events
    beyond max_events are dropped (counted by callers via overflow_count).

    Stable O(n) compaction: each spiking source's slot is its exclusive
    prefix count along the row (cumsum is monotone in source index, so the
    emitted order is ascending — the hardware FIFO write order and the
    accumulation order the oracle equivalence relies on).  Non-spiking and
    overflowing sources scatter into a trash slot that is sliced off, so no
    O(n log n) argsort and no data-dependent shapes.

    A row of ``n`` sources can emit at most ``n`` events, so the event list
    is at most ``n`` wide even when ``max_events`` exceeds it — the same
    clamp the argsort reference inherits from slicing past the row length.
    """
    b, n = spikes.shape
    max_events = min(int(max_events), n)
    spk = spikes > 0
    pos = jnp.cumsum(spk, axis=1, dtype=jnp.int32) - 1    # slot if spiking
    pos = jnp.where(spk & (pos < max_events), pos, max_events)
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
    out = jnp.full((b, max_events + 1), -1, jnp.int32)
    out = out.at[jnp.arange(b, dtype=jnp.int32)[:, None], pos].set(idx)
    return out[:, :max_events]


def _events_from_spikes_argsort(spikes: jax.Array, max_events: int) -> jax.Array:
    """The original O(n log n) full-width argsort MEM_E writer — kept as the
    bit-identical reference :func:`events_from_spikes`'s cumsum compaction is
    asserted against (tests/test_kernels.py, kernels_bench timing check)."""
    b, n = spikes.shape
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
    # sort spiking indices to the front: key = (1-spike)*n + arange
    key = jnp.where(spikes > 0, idx, n + idx)
    order = jnp.argsort(key, axis=1)[:, :max_events]
    gathered = jnp.take_along_axis(idx, order, axis=1)
    valid = jnp.take_along_axis(spikes > 0, order, axis=1)
    return jnp.where(valid, gathered, -1).astype(jnp.int32)


def overflow_count(spikes: jax.Array, max_events: int) -> jax.Array:
    """How many events were dropped by the static MEM_E depth."""
    n_spk = (spikes > 0).sum(axis=1)
    return jnp.maximum(n_spk - max_events, 0)
