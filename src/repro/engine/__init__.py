from repro.engine.train_loop import TrainLoopConfig, TrainState, make_train_step, train_loop  # noqa: F401
