"""Spikified linear-layer execution: MENAGE's event-driven engine applied to
a conventional dense layer (DESIGN.md §Arch-applicability).

Any matmul ``y = x @ W`` with non-negative activations (post-ReLU/GELU-ish)
can be executed MENAGE-style: rate-encode ``x`` into ``T`` Bernoulli spike
frames, push each frame's *events* through the synaptic accumulation
(``kernels/event_synapse`` — work ∝ events, not n_src·n_dest), and decode by
averaging.  The estimator is unbiased: E[y_hat] = x_clipped @ W; the error
shrinks as 1/sqrt(T) and with activation sparsity the event path touches
only ``mean_rate`` of the dense weight traffic — the paper's energy
proposition mapped onto TPU arithmetic.

``spikified_linear`` is the user-facing op; tests/test_spikify.py checks the
convergence law, and examples use it to run an FFN block in spiking mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops


def spikified_linear(key: jax.Array, x: jax.Array, w: jax.Array,
                     num_steps: int = 32, x_max: float | None = None,
                     max_events: int | None = None):
    """x [B, n_in] (>=0), w [n_in, n_out] -> (y_hat [B, n_out], stats).

    Rate-codes x/x_max into ``num_steps`` Bernoulli frames, accumulates each
    frame's events through the event_synapse kernel, decodes by averaging.
    """
    b, n_in = x.shape
    if x_max is None:
        x_max = jnp.maximum(jnp.max(x), 1e-6)
    rates = jnp.clip(x / x_max, 0.0, 1.0)
    if max_events is None:
        max_events = n_in
    keys = jax.random.split(key, num_steps)

    def frame(carry, k):
        acc, n_events = carry
        spikes = (jax.random.uniform(k, (b, n_in)) < rates).astype(jnp.float32)
        ev = ops.events_from_spikes(spikes, max_events)
        cur = ops.event_synapse(ev, w)
        return (acc + cur, n_events + (ev >= 0).sum()), None

    (acc, n_events), _ = jax.lax.scan(
        frame, (jnp.zeros((b, w.shape[1])), jnp.zeros((), jnp.int32)), keys)
    y = acc / num_steps * x_max
    stats = {
        "events": n_events,
        "dense_equiv_events": num_steps * b * n_in,
        "event_fraction": n_events / (num_steps * b * n_in),
    }
    return y, stats


def spikified_ffn(key: jax.Array, x: jax.Array, w_in: jax.Array,
                  w_out: jax.Array, num_steps: int = 32):
    """A spikified 2-layer ReLU FFN: dense-in -> ReLU -> spikified matmul.

    The second matmul consumes the *sparse, non-negative* ReLU activations —
    exactly where event-driven execution pays (DESIGN.md: event-driven
    sparsity == activation sparsity)."""
    h = jax.nn.relu(x @ w_in)
    y, stats = spikified_linear(key, h, w_out, num_steps=num_steps)
    return y, stats
