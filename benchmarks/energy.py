"""Table II reproduction: TOPS/W of Accel_1 (N-MNIST) and Accel_2
(CIFAR10-DVS) from the calibrated energy model driven by the cycle-level
dispatch simulator.

Flow = Algorithm 1: train (short, synthetic stand-in datasets) -> L1 prune
-> 8-bit quantize -> ILP map -> execute -> energy report.
For speed the SNN is trained briefly; energy depends on spike statistics,
not accuracy, and the synthetic sets match the paper's activity contrast
(CIFAR10-DVS busier than N-MNIST).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.menage_paper import (CIFAR_DATA, CIFAR_SNN, NMNIST_DATA,
                                        NMNIST_SNN)
from repro.core.accelerator import map_model, run
from repro.core.energy import ACCEL_1, ACCEL_2
from repro.core.prune import prune_pytree
from repro.core.quant import quantize_pytree
from repro.data.events import event_batches, synthetic_event_dataset
from repro.snn.mlp import train_snn


def _prepare(data_cfg, snn_cfg, train_steps: int, key):
    spikes, labels = synthetic_event_dataset(data_cfg, n_per_class=8, key=key)
    it = event_batches(spikes, labels, batch=16)
    params, _ = train_snn(key, snn_cfg, it, steps=train_steps, lr=1e-3)
    pruned, _ = prune_pytree(params, 0.5)
    _, dq = quantize_pytree(pruned)
    return [np.asarray(w) for w in dq], spikes


def measure(spec, data_cfg, snn_cfg, n_images: int = 4,
            train_steps: int = 30, seed: int = 0):
    key = jax.random.key(seed)
    weights, spikes = _prepare(data_cfg, snn_cfg, train_steps, key)
    model = map_model(weights, spec, lif=snn_cfg.lif)
    reports = []
    for i in range(n_images):
        res = run(model, spikes[i])
        reports.append(res.energy)
    tops_w = float(np.mean([r.tops_per_w for r in reports]))
    util = float(np.mean([r.utilization for r in reports]))
    ops = int(np.mean([r.total_ops for r in reports]))
    return {"accel": spec.name, "tops_per_w": tops_w, "utilization": util,
            "ops_per_image": ops,
            "rounds_per_layer": [len(l.rounds) for l in model.layers]}


def main(fast: bool = True):
    t0 = time.monotonic()
    rows = []
    # NOTE: CIFAR10-DVS synthetic stand-in is spatially downsampled (DESIGN.md
    # §5) so the CPU-hosted simulation finishes; activity statistics are
    # preserved, layer widths are the paper's.
    r1 = measure(ACCEL_1, NMNIST_DATA, NMNIST_SNN,
                 n_images=2 if fast else 8)
    rows.append(r1)
    r2 = measure(ACCEL_2, CIFAR_DATA, CIFAR_SNN,
                 n_images=1 if fast else 4, train_steps=15)
    rows.append(r2)
    paper = {"Accel1": 3.4, "Accel2": 12.1}
    for r in rows:
        target = paper[r["accel"]]
        print(f"energy/{r['accel']},{r['tops_per_w']:.3f},"
              f"paper={target},util={r['utilization']:.3f},"
              f"ops={r['ops_per_image']}")
    print(f"energy,elapsed,{time.monotonic()-t0:.1f}s")
    return rows


if __name__ == "__main__":
    main(fast=False)
