"""Always-on async serving loop: arrival-driven continuous batching.

``run_bucketed`` drains a *closed* list of requests; production DVS traffic
from edge sensors is an open stream.  :class:`StreamServer` is the always-on
front end for that stream:

  * **Arrival queue with admission control.**  ``submit`` admits one request
    at the current clock time.  The queue is bounded (``queue_capacity``);
    an arrival that would overflow it is either rejected or sheds the oldest
    pending request (``backpressure="reject" | "shed_oldest"``).  Requests
    longer than the policy's largest time bucket are rejected at admission
    with a per-request reason — or, with ``overlong="extend"``, grow the
    bucket grid geometrically (new jit trace, logged) instead.
  * **Deadline-aware batch formation.**  Pending requests group by time
    bucket.  A group dispatches the moment it can fill a ``max_batch`` chunk
    — or *earlier*, partially full, when the oldest member's deadline slack
    (deadline − now − estimated service time − ``dispatch_margin``) runs
    out.  This is the fix for the batch-formation stall of event-driven
    dispatch (Yik et al. 2025): a short request never waits for a bucket
    that might not fill.
  * **Bit-exact execution.**  A formed batch runs through the *same*
    :func:`repro.engine.serving.execute_plan` as the closed-list path —
    zero-pad into the policy bucket, ``run_batched`` / ``run_sharded``,
    slice each request back out — so every served result is bit-identical
    to ``run_bucketed``'s and hence to the numpy oracle (tested,
    ``tests/test_stream_server.py``).  The jit cache stays bounded by
    ``policy.n_buckets`` by construction.
  * **Metrics.**  :class:`ServerMetrics` tracks queue depth,
    time-to-first-dispatch, end-to-end latency percentiles, deadline-miss
    rate, and bucket fill ratio — the ``BENCH_async_serving.json`` surface.
  * **Chaos-ready.**  Three production failure modes are first-class (the
    soak harness, :mod:`repro.engine.chaos` / ``benchmarks/soak_bench.py``,
    drives all of them): a ``chaos_hook`` may raise
    :class:`~repro.engine.sharded_run.DeviceLossError` at any dispatch
    boundary and the server recovers onto the shrunken mesh (elastic
    serving — no request is lost to hardware loss); an :class:`SLOPolicy`
    flips between extend-biased admission and shedding on the windowed
    deadline-miss rate; and ``noise=AnalogNoise(...)`` serves through one
    deterministic noisy device instance with periodic shadow probes
    against the clean model (the ``noise_agreement`` accuracy-under-noise
    metric).  Every scenario replays deterministically on a VirtualClock
    (tests/test_chaos.py).

Time is pluggable: the default :class:`WallClock` serves real traffic;
:class:`VirtualClock` + :func:`serve_trace` replay a time-stamped arrival
trace deterministically (the clock only moves between arrivals and at
deadline-trigger instants), which is what makes the scheduler's dispatch
decisions unit-testable and the benchmark reproducible.
"""

from __future__ import annotations

import collections
import dataclasses
import logging
import math
import time

import numpy as np

from repro.engine import batched_run as br
from repro.engine.serving import (BatchPlan, BucketPolicy, RequestResult,
                                  execute_plan)
from repro.engine.sharded_run import DeviceLossError, shrink_mesh

_log = logging.getLogger(__name__)


# ------------------------------------------------------------------- clocks

class WallClock:
    """Real time — the production configuration."""

    def now(self) -> float:
        return time.monotonic()


class VirtualClock:
    """Manually-advanced time for deterministic replay of arrival traces."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        assert dt >= 0.0, f"time cannot run backwards (dt={dt})"
        self._t += dt


# ----------------------------------------------------------------- requests

@dataclasses.dataclass(frozen=True)
class Request:
    """One admitted in-flight request."""

    rid: int
    stream: np.ndarray          # [T_i, n_in]
    arrival_t: float
    deadline: float             # absolute; math.inf = best-effort
    t_pad: int                  # time bucket it was admitted into


@dataclasses.dataclass(frozen=True)
class Rejection:
    """Why a request never produced a result: ``queue_full`` (bounded-queue
    backpressure), ``shed`` (displaced by a newer arrival under
    ``backpressure="shed_oldest"``), or ``overlong`` (admission control)."""

    rid: int | None             # None when rejected before admission
    reason: str
    detail: str
    at: float


# ------------------------------------------------------------------ metrics

# Always-on means unbounded time: per-request samples (latency, TTFD, fill)
# and the telemetry/rejection logs keep the most recent WINDOW entries, so a
# long-lived server reports sliding-window percentiles at O(1) memory
# instead of growing until OOM.  Counters are exact over the full lifetime.
METRICS_WINDOW = 10_000

# The ServerMetrics.snapshot() schema, locked by tests/test_serving.py AND
# by the docs/SERVING.md metrics table (tests/test_docs.py) so dashboards
# reading BENCH_async_serving.json / BENCH_soak.json don't silently break.
METRIC_KEYS = (
    "submitted", "admitted", "rejected", "shed", "completed",
    "deadline_misses", "deadline_miss_rate", "dispatches",
    "forced_dispatches", "policy_extensions", "queue_depth",
    "max_queue_depth", "bucket_fill_ratio", "p50_ttfd_s", "p99_ttfd_s",
    "p50_latency_s", "p99_latency_s", "device_losses", "slo_switches",
    "slo_shedding", "noise_probes", "noise_agreement")


@dataclasses.dataclass
class ServerMetrics:
    """Serving-loop counters plus per-request latency samples.

    ``snapshot()`` reduces to the fixed ``METRIC_KEYS`` dict: queue depth
    (current/max), time-to-first-dispatch and end-to-end latency
    percentiles, deadline-miss rate over completed requests, and the mean
    bucket fill ratio (requests per dispatch / padded batch rows — how much
    of each engine call was real work).  Counters are lifetime-exact;
    percentiles/fill are over the last ``METRICS_WINDOW`` samples."""

    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    shed: int = 0
    completed: int = 0
    deadline_misses: int = 0
    dispatches: int = 0
    forced_dispatches: int = 0      # deadline-triggered partial dispatches
    policy_extensions: int = 0
    queue_depth: int = 0
    max_queue_depth: int = 0
    device_losses: int = 0          # chaos/watchdog-reported mesh shrinks
    slo_switches: int = 0           # shed<->extend mode flips by the SLO loop
    slo_shedding: bool = False      # currently in degraded (shedding) mode
    noise_probes: int = 0           # requests shadow-checked vs clean model
    noise_disagreements: int = 0    # probes whose prediction flipped
    ttfd_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=METRICS_WINDOW))
    latency_s: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=METRICS_WINDOW))
    fill: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=METRICS_WINDOW))

    @staticmethod
    def _pct(xs, q: float) -> float:
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

    def snapshot(self) -> dict:
        return {
            "submitted": self.submitted,
            "admitted": self.admitted,
            "rejected": self.rejected,
            "shed": self.shed,
            "completed": self.completed,
            "deadline_misses": self.deadline_misses,
            "deadline_miss_rate": (self.deadline_misses / self.completed
                                   if self.completed else 0.0),
            "dispatches": self.dispatches,
            "forced_dispatches": self.forced_dispatches,
            "policy_extensions": self.policy_extensions,
            "queue_depth": self.queue_depth,
            "max_queue_depth": self.max_queue_depth,
            "bucket_fill_ratio": (float(np.mean(self.fill))
                                  if self.fill else 0.0),
            "p50_ttfd_s": self._pct(self.ttfd_s, 50),
            "p99_ttfd_s": self._pct(self.ttfd_s, 99),
            "p50_latency_s": self._pct(self.latency_s, 50),
            "p99_latency_s": self._pct(self.latency_s, 99),
            "device_losses": self.device_losses,
            "slo_switches": self.slo_switches,
            "slo_shedding": int(self.slo_shedding),
            "noise_probes": self.noise_probes,
            # accuracy under analog noise: fraction of shadow-probed
            # requests whose prediction matched the clean model (1.0 when
            # probing is off — no evidence of degradation)
            "noise_agreement": ((self.noise_probes - self.noise_disagreements)
                                / self.noise_probes
                                if self.noise_probes else 1.0),
        }


# ------------------------------------------------------------------- server

_EWMA_ALPHA = 0.3


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """SLO-driven shed-vs-extend switching for an always-on server.

    The server normally runs *extend-biased*: whatever ``backpressure`` /
    ``overlong`` it was built with (typically admit-everything).  When the
    deadline-miss rate over the last ``window`` completed requests exceeds
    ``target_miss_rate``, it flips to *shed* mode — ``backpressure=
    "shed_oldest"`` (newest data wins; stale queued requests would miss
    anyway) and ``overlong="reject"`` (no mid-overload grid growth, which
    costs a jit trace at the worst possible moment).  Once the windowed
    rate drops below ``restore_factor * target_miss_rate``, the original
    policies are restored.  Mode flips are counted in the ``slo_switches``
    metric and the current mode is exported as ``slo_shedding`` — the
    measured, scenario-driven alternative to hand-tuning backpressure per
    deployment (cf. the bottleneck-modeling argument of arXiv 2511.21549).
    """

    target_miss_rate: float = 0.05
    window: int = 64
    min_samples: int = 16          # don't flap on the first few requests
    restore_factor: float = 0.5

    def __post_init__(self):
        assert 0.0 < self.target_miss_rate <= 1.0
        assert 0.0 <= self.restore_factor < 1.0
        assert 0 < self.min_samples <= self.window


class StreamServer:
    """The always-on continuous-batching loop (module docstring has the
    design).  Drive it with :meth:`submit` on arrival, :meth:`poll` when
    time passes (:meth:`next_deadline` says when that matters), and
    :meth:`flush` at shutdown; completed ``(rid, RequestResult)`` pairs
    come back from ``poll``/``flush``.
    """

    def __init__(self, model, *, policy: BucketPolicy,
                 mesh=None, clock=None,
                 queue_capacity: int = 256,
                 backpressure: str = "reject",
                 overlong: str = "reject",
                 default_slack: float = math.inf,
                 dispatch_margin: float = 0.0,
                 service_model=None,
                 max_events: int | None = None,
                 sn_capacity_rows: int | None = None,
                 with_stats: bool = False,
                 donate: bool | None = None,
                 noise=None, noise_key=0, noise_probe_every: int = 8,
                 slo: SLOPolicy | None = None,
                 chaos_hook=None, on_rejection=None):
        assert backpressure in ("reject", "shed_oldest"), backpressure
        assert overlong in ("reject", "extend"), overlong
        assert queue_capacity > 0
        assert noise_probe_every >= 0
        self.packed = (model if isinstance(model, br.PackedModel)
                       else model.pack())
        # serving-time analog noise: serve every request through one
        # deterministic noisy device instance (core/noise.perturb_packed);
        # every noise_probe_every-th dispatch is shadow-replayed through
        # the clean model to track prediction agreement (the
        # accuracy-under-noise metric).  0 disables probing.
        self._clean_packed = self.packed
        if noise is not None and noise.weight_sigma > 0:
            from repro.core.noise import as_noise_key, perturb_packed
            self.packed = perturb_packed(as_noise_key(noise_key),
                                         self.packed, noise)
        else:
            # weight_sigma <= 0 applies no perturbation: probing would
            # shadow-replay the batch through an identical model (always
            # agreeing) — normalize to "noise off" so the gate in
            # _dispatch means "a perturbed model is actually serving"
            noise = None
        self.noise = noise
        self.noise_probe_every = noise_probe_every
        # SLO controller state: the configured backpressure/overlong are the
        # "extend-biased" baseline it restores to after a shed episode
        self.slo = slo
        self._slo_base = (backpressure, overlong)
        self._slo_misses: collections.deque = collections.deque(
            maxlen=slo.window if slo is not None else 1)
        # chaos_hook(dispatch_ordinal) runs at every dispatch boundary and
        # may raise DeviceLossError — the soak harness's failure injection,
        # mirroring train_loop's failure_hook
        self.chaos_hook = chaos_hook
        self.policy = policy
        self.mesh = mesh
        self.clock = clock if clock is not None else WallClock()
        self.queue_capacity = queue_capacity
        self.backpressure = backpressure
        self.overlong = overlong
        self.default_slack = default_slack
        self.dispatch_margin = dispatch_margin
        # service_model(b_pad, t_pad) -> seconds: the scheduler's estimate of
        # one engine call on that bucket.  None = learn an EWMA from measured
        # wall seconds.  On a VirtualClock the model also *advances* the
        # clock per dispatch, turning the server into a deterministic
        # discrete-event simulation grounded in calibrated timings.
        self.service_model = service_model
        self.max_events = max_events
        self.sn_capacity_rows = sn_capacity_rows
        self.with_stats = with_stats
        # each dispatch uploads one padded bucket buffer; donating it lets
        # the jit recycle that allocation into the outputs, so an always-on
        # server never accumulates input copies across dispatches.  CPU XLA
        # has no donation, hence the backend-aware default.
        self.donate = br.should_donate(donate)
        # on_rejection(Rejection) fires synchronously for every rejection
        # as it happens — the delivery channel for transports that must
        # answer displaced clients (the socket layer's REJECT frames).
        # The `rejections` deque below is a bounded *metrics* window and
        # can overflow under sustained shedding; consumers that may not
        # lose a record subscribe here instead of scraping it.
        self.on_rejection = on_rejection
        self.metrics = ServerMetrics()
        # execute_plan records / rejection log, last METRICS_WINDOW entries
        self.telemetry: collections.deque = \
            collections.deque(maxlen=METRICS_WINDOW)
        self.rejections: collections.deque = \
            collections.deque(maxlen=METRICS_WINDOW)
        self._pending: dict[int, collections.deque[Request]] = {}
        self._n_pending = 0
        self._completed: list[tuple[int, RequestResult]] = []
        self._next_rid = 0
        self._ewma: dict[tuple[int, int], float] = {}

    # ------------------------------------------------------------ admission

    def now(self) -> float:
        return self.clock.now()

    @property
    def queue_depth(self) -> int:
        return self._n_pending

    def _reject(self, rid: int | None, reason: str, detail: str) -> None:
        rej = Rejection(rid=rid, reason=reason, detail=detail, at=self.now())
        self.rejections.append(rej)
        if reason == "shed":
            self.metrics.shed += 1
        else:
            self.metrics.rejected += 1
        if self.on_rejection is not None:
            self.on_rejection(rej)

    def _shed_oldest(self) -> None:
        """Backpressure by displacement: drop the oldest pending request
        (across all buckets) to make room for the new arrival."""
        t_pad = min((q[0].arrival_t, tp) for tp, q in self._pending.items()
                    if q)[1]
        victim = self._pending[t_pad].popleft()
        self._n_pending -= 1
        self._reject(victim.rid, "shed",
                     f"displaced after {self.now() - victim.arrival_t:.3g}s "
                     f"in queue (capacity {self.queue_capacity})")

    def submit(self, stream, *, deadline: float | None = None,
               slack: float | None = None,
               arrival_t: float | None = None) -> int | None:
        """Admit one request at the current clock time.  Returns its rid, or
        ``None`` if it was rejected (recorded in :attr:`rejections`).  The
        deadline is absolute; ``slack`` is relative to now; neither given
        falls back to ``default_slack``.  A group that reaches ``max_batch``
        dispatches immediately — collect results via :meth:`poll`.

        ``arrival_t`` back-dates the request's arrival for latency/TTFD
        accounting (≤ now): on a virtual clock a request that physically
        arrived while the executor was busy is only admitted once the
        engine call returns, but its latency still counts from when the
        sensor produced it."""
        now = self.now()
        if arrival_t is None:
            arrival_t = now
        assert arrival_t <= now + 1e-9, \
            f"arrival_t {arrival_t} is in the future (now={now})"
        self.metrics.submitted += 1
        stream = np.asarray(stream, dtype=np.float32)
        # a real raise, not an assert: submit is the boundary where
        # external traffic enters, so the shape check must survive -O and
        # give transports a typed error to map to a rejection
        if stream.ndim != 2 or stream.shape[1] != self.packed.n_in:
            raise ValueError(
                f"expected [T, {self.packed.n_in}], got {stream.shape}")
        t_len = stream.shape[0]
        if t_len == 0:
            self._reject(None, "empty", "zero-length spike train")
            return None
        needs_extend = not self.policy.fits(t_len)
        if needs_extend and self.overlong == "reject":
            self._reject(None, "overlong",
                         f"{t_len} steps > largest time bucket "
                         f"{self.policy.time_steps[-1]}")
            return None
        if self._n_pending >= self.queue_capacity:
            if self.backpressure == "reject":
                self._reject(None, "queue_full",
                             f"queue at capacity {self.queue_capacity}")
                return None
            self._shed_oldest()
        # grid extension is a side effect (new jit trace) — apply it only
        # once the request is actually admitted
        if needs_extend:
            self.policy = self.policy.with_time_bucket(t_len)
            self.metrics.policy_extensions += 1
            _log.warning("stream_server: %d-step request extended the "
                         "bucket grid to time_steps=%s (new jit trace)",
                         t_len, self.policy.time_steps)
        rid = self._next_rid
        self._next_rid += 1
        if deadline is None:
            s = self.default_slack if slack is None else slack
            deadline = arrival_t + s
        req = Request(rid=rid, stream=stream, arrival_t=arrival_t,
                      deadline=deadline, t_pad=self.policy.t_bucket(t_len))
        self._pending.setdefault(req.t_pad, collections.deque()).append(req)
        self._n_pending += 1
        self.metrics.admitted += 1
        self.metrics.queue_depth = self._n_pending
        self.metrics.max_queue_depth = max(self.metrics.max_queue_depth,
                                           self._n_pending)
        if len(self._pending[req.t_pad]) >= self.policy.max_batch:
            self._dispatch(req.t_pad, self.policy.max_batch, forced=False)
        return rid

    # ----------------------------------------------------------- scheduling

    def _est_service(self, b_pad: int, t_pad: int) -> float:
        if self.service_model is not None:
            return float(self.service_model(b_pad, t_pad))
        return self._ewma.get((b_pad, t_pad), 0.0)

    def _trigger_time(self, t_pad: int) -> float:
        """When the group forces a (possibly partial) dispatch: its
        *tightest* member deadline minus the estimated service time for the
        batch we would form now, minus the safety margin.  (Tightest, not
        oldest: a best-effort ``inf``-deadline request admitted first must
        not mask a deadline behind it.  Groups stay below ``max_batch`` —
        full chunks dispatch at submit — so a forced dispatch always takes
        the whole group, tight member included.)"""
        q = self._pending[t_pad]
        k = min(len(q), self.policy.max_batch)
        b_pad = self.policy.b_bucket(k)
        return (min(r.deadline for r in q)
                - self._est_service(b_pad, t_pad) - self.dispatch_margin)

    def next_deadline(self) -> float | None:
        """The earliest instant at which :meth:`poll` would force a partial
        dispatch — drivers advance their clock to ``min(next arrival,
        next_deadline())``.  ``None`` when nothing pending has a finite
        trigger."""
        triggers = [self._trigger_time(tp) for tp, q in self._pending.items()
                    if q]
        finite = [t for t in triggers if t != math.inf]
        return min(finite) if finite else None

    def poll(self) -> list[tuple[int, RequestResult]]:
        """Dispatch every group that is full or past its deadline trigger at
        the current clock time; return all newly completed results."""
        now = self.now()
        for t_pad in sorted(self._pending,
                            key=lambda tp: (min(r.deadline
                                                for r in self._pending[tp])
                                            if self._pending[tp] else math.inf)):
            q = self._pending[t_pad]
            # submit() dispatches a group the moment it reaches max_batch,
            # so pending groups are always partial — only deadlines fire here
            assert len(q) < self.policy.max_batch
            if q and self._trigger_time(t_pad) <= now:
                self._dispatch(t_pad, len(q), forced=True)
        return self.collect()

    def flush(self) -> list[tuple[int, RequestResult]]:
        """Dispatch everything still pending (shutdown / end of trace) and
        return all remaining completed results."""
        for t_pad in sorted(self._pending):
            q = self._pending[t_pad]
            if q:
                assert len(q) < self.policy.max_batch  # see poll()
                self._dispatch(t_pad, len(q), forced=False)
        return self.collect()

    def collect(self) -> list[tuple[int, RequestResult]]:
        """Completed ``(rid, result)`` pairs since the last collection."""
        done, self._completed = self._completed, []
        return done

    # ------------------------------------------------------------ execution

    def _recover_mesh(self, err: DeviceLossError) -> None:
        """Elastic recovery at a dispatch boundary: shrink the serving mesh
        to the survivors (the replicated PackedModel needs no state
        movement), re-round the batch buckets to the new shard count
        (time buckets — and hence every queued request's ``t_pad`` — are
        preserved), and drop service-time estimates measured on the dead
        topology.  The serving twin of the train loop's elastic restart."""
        if self.mesh is None:
            raise err   # no mesh to shrink — single-device loss is fatal
        old = self.mesh.size
        self.mesh = shrink_mesh(self.mesh, err.n_lost)   # raises if none left
        self.policy = BucketPolicy.for_mesh(
            self.mesh.size, batch_sizes=self.policy.batch_sizes,
            time_steps=self.policy.time_steps)
        self._ewma.clear()
        self.metrics.device_losses += 1
        _log.warning("stream_server: lost %d device(s) mid-serving; "
                     "recovered %d -> %d-way mesh, batch buckets now %s "
                     "(new jit traces)", err.n_lost, old, self.mesh.size,
                     self.policy.batch_sizes)

    def _execute(self, streams: list, plan: BatchPlan, packed=None):
        return execute_plan(
            self.packed if packed is None else packed, streams, plan,
            mesh=self.mesh, max_events=self.max_events,
            sn_capacity_rows=self.sn_capacity_rows,
            with_stats=self.with_stats, donate=self.donate)

    def _noise_probe(self, reqs, results, streams, plan: BatchPlan) -> None:
        """Shadow-replay this dispatch through the clean (un-perturbed)
        model and count per-request prediction flips — the serving-time
        accuracy-under-noise signal.  Runs off the metrics clock (a
        measurement, not service work): no telemetry record, no EWMA
        update, no virtual-clock advance."""
        clean, _ = self._execute(streams, plan, packed=self._clean_packed)
        m = self.metrics
        for res, ref in zip(results, clean):
            noisy_pred = int(res.out_spikes.sum(axis=0).argmax())
            clean_pred = int(ref.out_spikes.sum(axis=0).argmax())
            m.noise_probes += 1
            m.noise_disagreements += int(noisy_pred != clean_pred)

    def _slo_update(self) -> None:
        """Flip between extend-biased and shed mode on the windowed
        deadline-miss rate (see :class:`SLOPolicy`)."""
        if self.slo is None or len(self._slo_misses) < self.slo.min_samples:
            return
        rate = sum(self._slo_misses) / len(self._slo_misses)
        m = self.metrics
        if not m.slo_shedding and rate > self.slo.target_miss_rate:
            m.slo_shedding = True
            m.slo_switches += 1
            self.backpressure, self.overlong = "shed_oldest", "reject"
            _log.warning("stream_server: SLO breach (miss rate %.3f > "
                         "%.3f over %d reqs) — shedding", rate,
                         self.slo.target_miss_rate, len(self._slo_misses))
        elif m.slo_shedding and \
                rate < self.slo.restore_factor * self.slo.target_miss_rate:
            m.slo_shedding = False
            m.slo_switches += 1
            self.backpressure, self.overlong = self._slo_base
            _log.warning("stream_server: SLO recovered (miss rate %.3f) — "
                         "restoring backpressure=%s overlong=%s", rate,
                         *self._slo_base)

    def _dispatch(self, t_pad: int, k: int, forced: bool) -> None:
        q = self._pending[t_pad]
        reqs = [q.popleft() for _ in range(k)]
        self._n_pending -= k
        streams = [r.stream for r in reqs]
        dispatch_t = self.now()
        # device loss surfaces at the dispatch boundary (from the chaos
        # hook here; from the runtime's watchdog in production); recovery
        # shrinks the mesh and retries the same requests — requests are
        # only lost to explicit shedding, never to hardware loss
        while True:
            b_pad = self.policy.b_bucket(k)
            plan = BatchPlan(indices=tuple(range(k)), b_pad=b_pad,
                             t_pad=t_pad)
            try:
                if self.chaos_hook is not None:
                    self.chaos_hook(self.metrics.dispatches)
                results, record = self._execute(streams, plan)
                break
            except DeviceLossError as e:
                self._recover_mesh(e)
        self.telemetry.append(record)
        key = (b_pad, t_pad)
        prev = self._ewma.get(key)
        self._ewma[key] = record["seconds"] if prev is None else \
            _EWMA_ALPHA * record["seconds"] + (1 - _EWMA_ALPHA) * prev
        if self.service_model is not None and hasattr(self.clock, "advance"):
            self.clock.advance(float(self.service_model(b_pad, t_pad)))
        end_t = self.now()
        m = self.metrics
        m.dispatches += 1
        m.forced_dispatches += int(forced)
        m.fill.append(k / b_pad)
        m.queue_depth = self._n_pending
        for req, res in zip(reqs, results):
            self._completed.append((req.rid, res))
            m.completed += 1
            m.ttfd_s.append(dispatch_t - req.arrival_t)
            m.latency_s.append(end_t - req.arrival_t)
            missed = end_t > req.deadline
            m.deadline_misses += int(missed)
            self._slo_misses.append(missed)
        if (self.noise is not None and self.noise_probe_every
                and m.dispatches % self.noise_probe_every == 0):
            self._noise_probe(reqs, results, streams, plan)
        self._slo_update()


# ------------------------------------------------------------- trace driver

def serve_trace(server: StreamServer, trace):
    """Replay a time-stamped arrival trace through a :class:`StreamServer`
    on a :class:`VirtualClock`, firing deadline-triggered dispatches at the
    exact instants they become due between arrivals.

    ``trace``: iterable of ``(arrival_t, stream)`` or ``(arrival_t, stream,
    deadline)`` tuples, non-decreasing in ``arrival_t`` (absolute deadline;
    ``None`` = the server's ``default_slack``).  When a simulated service
    period (``service_model``) runs past the next arrival, that request is
    admitted as soon as the executor frees up — back-dated to its true
    arrival for latency accounting, exactly like a single-threaded server
    draining a socket between engine calls.  Remaining requests are flushed
    after the last arrival.  Returns ``(results, rids)``: a dict ``rid ->
    RequestResult`` and the per-trace-entry rid (``None`` where admission
    rejected the request).
    """
    clock = server.clock
    assert isinstance(clock, VirtualClock), \
        "serve_trace replays simulated time; build the server with a " \
        "VirtualClock (a WallClock server is driven by real arrivals instead)"
    results: dict[int, RequestResult] = {}
    rids: list[int | None] = []

    def drain(pairs):
        for rid, res in pairs:
            results[rid] = res

    prev_t = -math.inf
    for item in trace:
        t_a, stream, deadline = item if len(item) == 3 else (*item, None)
        assert t_a >= prev_t, \
            f"trace arrivals must be non-decreasing ({t_a} < {prev_t})"
        prev_t = t_a
        while True:
            nd = server.next_deadline()
            if nd is None or nd > t_a:
                break
            clock.advance(max(0.0, nd - clock.now()))
            fired = server.poll()
            drain(fired)
            if not fired:
                break   # estimate moved the trigger; re-check next arrival
        clock.advance(max(0.0, t_a - clock.now()))
        rids.append(server.submit(stream, deadline=deadline,
                                  arrival_t=min(t_a, clock.now())))
        drain(server.poll())
    drain(server.flush())
    return results, rids
