"""Spikified linear execution: unbiasedness + 1/sqrt(T) convergence + the
event-sparsity proposition."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spikify import spikified_ffn, spikified_linear


def test_spikified_linear_converges(rng):
    x = jnp.asarray(np.abs(rng.normal(size=(4, 64))).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    want = np.asarray(x @ w)
    errs = []
    for t in (8, 128):
        y, _ = spikified_linear(jax.random.key(0), x, w, num_steps=t)
        errs.append(float(np.abs(np.asarray(y) - want).mean()))
    assert errs[1] < errs[0] * 0.5         # ~1/sqrt(16) = 4x expected
    # decent absolute accuracy at T=128
    scale = float(np.abs(want).mean())
    assert errs[1] < 0.25 * scale


def test_event_fraction_tracks_sparsity(rng):
    """Sparse activations -> proportionally fewer events (the paper's
    work ∝ spikes claim on TPU)."""
    w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    dense_x = jnp.asarray(np.abs(rng.normal(size=(4, 64))).astype(np.float32))
    sparse_x = dense_x * (jnp.asarray(rng.random((4, 64))) < 0.1)
    _, s_dense = spikified_linear(jax.random.key(1), dense_x, w, num_steps=16)
    _, s_sparse = spikified_linear(jax.random.key(1), sparse_x, w,
                                   num_steps=16)
    assert float(s_sparse["event_fraction"]) < \
        float(s_dense["event_fraction"]) * 0.5


def test_spikified_ffn_runs(rng):
    x = jnp.asarray(rng.normal(size=(2, 32)).astype(np.float32))
    w_in = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32) * 0.3)
    w_out = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32) * 0.3)
    y, stats = spikified_ffn(jax.random.key(2), x, w_in, w_out, num_steps=64)
    want = np.asarray(jax.nn.relu(x @ w_in) @ w_out)
    got = np.asarray(y)
    assert np.all(np.isfinite(got))
    # correlation with the dense FFN output (stochastic estimator)
    c = np.corrcoef(got.ravel(), want.ravel())[0, 1]
    assert c > 0.9, c
