import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    parser.addoption(
        "--update-goldens", action="store_true", default=False,
        help="rewrite tests/golden/*.json from the current model instead of "
             "comparing against it (review the diff before committing)")
    parser.addoption(
        "--durations-budget", type=float, default=None, metavar="SECONDS",
        help="fail any single test whose call phase exceeds this many "
             "seconds (the CI time-cap guard; pair with --durations=10)")


@pytest.fixture
def update_goldens(request) -> bool:
    return request.config.getoption("--update-goldens")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_makereport(item, call):
    """CI budget guard: with ``--durations-budget N``, a test whose call
    phase runs longer than N seconds fails loudly instead of silently
    growing the suite past the CI time cap.  (A report hook, not an autouse
    fixture, so hypothesis's function_scoped_fixture health check stays
    quiet on the property tests.)"""
    outcome = yield
    report = outcome.get_result()
    budget = item.config.getoption("--durations-budget")
    if (budget is not None and report.when == "call" and report.passed
            and report.duration > budget):
        report.outcome = "failed"
        report.longrepr = (
            f"{item.nodeid} took {report.duration:.1f}s, over the "
            f"--durations-budget of {budget:.0f}s — speed it up or split it")
