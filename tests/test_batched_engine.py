"""Batched JAX engine vs. the cycle-accurate numpy oracle.

The contract under test (DESIGN: batched engine): ``run_batched`` executes
the same control-memory content as ``run`` and must agree **bit-exactly** on
output spikes, DispatchStats aggregates, and MEM_S&N utilization for every
batch element — plus MEM_E overflow accounting and jit cache stability.
"""

import numpy as np
import pytest

from repro.core.accelerator import lif_rollout_np, map_model, run_batch
from repro.core.energy import AcceleratorSpec
from repro.core.lif import LIFParams
from repro.engine import batched_run as br

SPEC = AcceleratorSpec("test", n_cores=3, n_engines=4, n_caps=8,
                       weight_mem_bytes=1 << 16)
STAT_FIELDS = ("cycles", "rows_touched", "engine_ops", "events",
               "sn_bytes_touched")


def _pruned_mlp(rng, sizes, density=0.5):
    ws = []
    for i in range(len(sizes) - 1):
        w = rng.normal(0, 0.5, (sizes[i], sizes[i + 1]))
        th = np.quantile(np.abs(w), 1 - density)
        w[np.abs(w) < th] = 0
        ws.append(w.astype(np.float32))
    return ws


def _assert_sample_equivalent(res, model, oracle, b):
    np.testing.assert_array_equal(res.out_spikes[b], oracle.out_spikes)
    for li, (bs, os_) in enumerate(zip(res.sample_stats(b),
                                       oracle.per_layer_stats)):
        for f in STAT_FIELDS:
            np.testing.assert_array_equal(
                getattr(bs, f), getattr(os_, f), err_msg=f"layer {li} {f}")
        assert bs.mem_e_peak == os_.mem_e_peak
    for li in range(len(model.layers)):
        np.testing.assert_array_equal(res.per_layer_util[li][b],
                                      oracle.per_layer_util[li])
    e = res.sample_energy(b)
    assert e.total_ops == oracle.energy.total_ops
    assert e.tops_per_w == oracle.energy.tops_per_w


@pytest.mark.parametrize("seed,sizes,density,p_spk", [
    (0, (24, 16, 12, 8), 0.5, 0.3),
    (1, (18, 20, 6), 0.7, 0.5),
    (2, (32, 8), 0.3, 0.15),
])
def test_batched_matches_oracle(seed, sizes, density, p_spk):
    rng = np.random.default_rng(seed)
    model = map_model(_pruned_mlp(rng, sizes, density), SPEC,
                      lif=LIFParams(beta=0.8, threshold=0.7))
    spikes = (rng.random((4, 10, sizes[0])) < p_spk).astype(np.float32)
    res = br.run_batched(model, spikes)
    for b, oracle in enumerate(run_batch(model, spikes)):
        _assert_sample_equivalent(res, model, oracle, b)


def test_batched_matches_oracle_multi_round(rng):
    """A 64-wide layer on 4x8 capacitors runs in two capacitor-reassignment
    rounds; the fused dense replay must still be bit-exact."""
    ws = _pruned_mlp(rng, (10, 64), density=0.5)
    model = map_model(ws, SPEC, lif=LIFParams(beta=0.8, threshold=0.7))
    assert len(model.layers[0].rounds) == 2
    spikes = (rng.random((3, 8, 10)) < 0.4).astype(np.float32)
    res = br.run_batched(model, spikes)
    for b, oracle in enumerate(run_batch(model, spikes)):
        _assert_sample_equivalent(res, model, oracle, b)


def test_dense_weights_replay_tables(rng):
    """MemTables.dense_weights reconstructs exactly the assigned entries of
    the quantized weight matrix from the memory content."""
    ws = _pruned_mlp(rng, (12, 10))
    model = map_model(ws, SPEC)
    layer = model.layers[0]
    w = layer.rounds[0].tables.dense_weights(layer.n_dest)
    assigned = layer.mapping.engine >= 0
    np.testing.assert_array_equal(w[:, assigned], layer.w_q[:, assigned])
    np.testing.assert_array_equal(w[:, ~assigned], 0.0)


def test_to_jax_padding_and_stats_vectors(rng):
    """to_jax pads MEM_E2A/MEM_S&N to the requested static geometry, and the
    derived per-source stats vectors match a direct table walk."""
    ws = _pruned_mlp(rng, (9, 7))
    tables = map_model(ws, SPEC).layers[0].rounds[0].tables
    pt = tables.to_jax(pad_src=16, pad_rows=tables.n_rows + 5)
    assert pt.e2a_count.shape == (16,) and pt.e2a_count.dtype == np.int32
    assert pt.sn_valid.shape == (tables.n_rows + 5, SPEC.n_engines)
    assert int(np.asarray(pt.e2a_count)[9:].sum()) == 0
    assert int(np.asarray(pt.sn_valid)[tables.n_rows:].sum()) == 0
    rows_v, cyc_v, ops_v = pt.stats_vectors()
    for m in range(9):
        a, b = int(tables.e2a_addr[m]), int(tables.e2a_count[m])
        assert rows_v[m] == b and cyc_v[m] == max(b, 1)
        assert ops_v[m] == int(tables.sn_valid[a:a + b].sum())


def test_mem_e_overflow_accounting(rng):
    """With a tight static MEM_E depth, dropped-event counts match
    overflow semantics and the engine computes exactly the truncated event
    stream (lowest source indices retained, hardware FIFO order)."""
    ws = _pruned_mlp(rng, (10, 12), density=0.8)
    lif = LIFParams(beta=0.8, threshold=0.7)
    model = map_model(ws, SPEC, lif=lif)
    spikes = (rng.random((3, 6, 10)) < 0.7).astype(np.float32)
    depth = 3
    res = br.run_batched(model, spikes, max_events=depth)
    n_spk = (spikes > 0).sum(-1)
    np.testing.assert_array_equal(res.overflow[0],
                                  np.maximum(n_spk - depth, 0))
    w_eff = model.layers[0].rounds[0].tables.dense_weights(12)
    for b in range(3):
        currents = np.zeros((6, 12), np.float32)
        for t in range(6):
            for m in np.nonzero(spikes[b, t])[0][:depth]:
                currents[t] += w_eff[m]
        np.testing.assert_array_equal(res.out_spikes[b],
                                      lif_rollout_np(currents, lif))


def test_overflow_propagates_to_downstream_layers(rng):
    """run(max_events=k) and run_batched(max_events=k) agree bit-exactly on
    a 3-layer stack where the cap binds: the truncated layer-0 event stream
    changes layer-0 spikes, which changes what layers 1-2 receive — spikes,
    stats, utilization, and overflow must all match the oracle under the
    same cap at every depth of the chain."""
    from _equivalence import assert_oracle_engine_equivalent
    ws = _pruned_mlp(rng, (14, 12, 10, 6), density=0.8)
    lif = LIFParams(beta=0.85, threshold=0.5)
    model = map_model(ws, SPEC, lif=lif)
    spikes = (rng.random((4, 7, 14)) < 0.6).astype(np.float32)
    for depth in (0, 2, 5, None):
        res = assert_oracle_engine_equivalent(model, spikes, max_events=depth,
                                              tag=f"depth={depth}")
        # downstream layers must actually see fewer arrivals than uncapped
        if depth == 2:
            full = br.run_batched(model, spikes)
            assert res.per_layer_stats[1].events.sum() \
                < full.per_layer_stats[1].events.sum(), \
                "cap on layer 0 did not propagate to layer 1's event stream"


def test_zero_mem_e_depth(rng):
    """A zero-depth MEM_E drops every event: silent output, full overflow
    (regression: the Pallas interpret path used to die on an E=0 block)."""
    ws = _pruned_mlp(rng, (16, 8))
    model = map_model(ws, SPEC)
    spikes = (rng.random((2, 4, 16)) < 0.5).astype(np.float32)
    res = br.run_batched(model, spikes, max_events=0)
    assert res.out_spikes.sum() == 0
    np.testing.assert_array_equal(res.overflow[0],
                                  (spikes > 0).sum(-1))


def test_jit_cache_stability(rng):
    """Fixed shapes => exactly one trace, however many batches are served."""
    ws = _pruned_mlp(rng, (16, 12, 8))
    packed = map_model(ws, SPEC).pack()
    def batch():
        return (rng.random((2, 5, 16)) < 0.3).astype(np.float32)
    br.run_batched(packed, batch())
    n = br.trace_count()
    for _ in range(3):
        br.run_batched(packed, batch())
    assert br.trace_count() == n
    # a new batch size is a new trace (shape change), exactly once
    wide = (rng.random((4, 5, 16)) < 0.3).astype(np.float32)
    br.run_batched(packed, wide)
    assert br.trace_count() == n + 1
    br.run_batched(packed, wide)
    assert br.trace_count() == n + 1


def test_empty_batch(rng):
    """B=0 returns an empty result instead of crashing (regression: the
    Pallas call used to die slicing a (1, E) block out of (0, E) events)."""
    ws = _pruned_mlp(rng, (16, 12, 8))
    model = map_model(ws, SPEC)
    res = br.run_batched(model, np.zeros((0, 5, 16), np.float32))
    assert res.out_spikes.shape == (0, 5, 8) and res.batch == 0
    for li, s in enumerate(res.per_layer_stats):
        assert s.cycles.shape == (0, 5) and s.mem_e_peak.shape == (0,)
        assert res.per_layer_util[li].shape == (0, 5)
        assert res.overflow[li].shape == (0, 5)
    # and with a finite MEM_E cap / without stats
    assert br.run_batched(model, np.zeros((0, 5, 16), np.float32),
                          max_events=2).out_spikes.shape == (0, 5, 8)
    assert br.run_batched(model, np.zeros((0, 5, 16), np.float32),
                          with_stats=False).per_layer_stats == []


def test_single_timestep(rng):
    """T=1: the LIF scan degenerates to one step; full oracle equivalence
    (spikes, stats, util, overflow) must hold."""
    from _equivalence import assert_oracle_engine_equivalent
    ws = _pruned_mlp(rng, (14, 10, 6), density=0.7)
    model = map_model(ws, SPEC, lif=LIFParams(beta=0.8, threshold=0.7))
    spikes = (rng.random((3, 1, 14)) < 0.5).astype(np.float32)
    for depth in (None, 2):
        assert_oracle_engine_equivalent(model, spikes, max_events=depth,
                                        tag=f"T=1 depth={depth}")


def test_all_silent_input(rng):
    """No events anywhere: silent output, all-zero stats, zero MEM_E peak —
    and still bit-exact against the oracle walking the same silence."""
    from _equivalence import assert_oracle_engine_equivalent
    ws = _pruned_mlp(rng, (16, 12, 8))
    model = map_model(ws, SPEC)
    spikes = np.zeros((2, 6, 16), np.float32)
    res = assert_oracle_engine_equivalent(model, spikes, tag="silent")
    assert res.out_spikes.sum() == 0
    for s in res.per_layer_stats:
        assert s.cycles.sum() == 0 and s.engine_ops.sum() == 0
        assert (s.mem_e_peak == 0).all()


def test_with_stats_false_skips_accounting(rng):
    ws = _pruned_mlp(rng, (16, 8))
    model = map_model(ws, SPEC)
    spikes = (rng.random((2, 5, 16)) < 0.3).astype(np.float32)
    res = br.run_batched(model, spikes, with_stats=False)
    full = br.run_batched(model, spikes)
    np.testing.assert_array_equal(res.out_spikes, full.out_spikes)
    assert res.per_layer_stats == [] and res.overflow == []
