"""Mixed-precision Pareto benchmark: accuracy vs energy vs throughput.

Maps an MLP and a CIFAR_CONV-style stack at every supported weight width
(8/4/2 plus the greedy-searched mixed config), runs each through the
packed-operand engine, and writes ``BENCH_precision.json`` — one Pareto
point per config following :data:`repro.core.precision.PARETO_POINT_KEYS`.

  PYTHONPATH=src python benchmarks/precision_bench.py [--smoke] \
      [--out BENCH_precision.json] [--spoof-devices 2]

Gates (CI fails loudly on regression):
  * the 8-bit packed-operand engine is bit-exact vs the seed (unpacked
    dense-replay) engine AND vs the cycle-accurate oracle;
  * the hot pass adds ZERO jit traces (packed kernels cache like dense);
  * allocated weight-word bytes shrink monotonically 8 -> 4 -> 2;
  * all-4-bit buys >= 1.8x byte reduction and strictly lower modeled
    energy/frame than all-8-bit;
  * p50 bucketed step latency on the default serving path does not regress
    vs the in-run 8-bit baseline (and vs ``BENCH_serving.json`` when that
    artifact is present from the same CI run).
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.launch._spoof import (assert_spoof_applied,
                                 spoof_devices_from_argv)

_SPOOFED = spoof_devices_from_argv()  # before any jax import in this process

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.accelerator import map_model, run  # noqa: E402
from repro.core.energy import AcceleratorSpec  # noqa: E402
from repro.core.layers import Conv2d, Dense, SumPool2d  # noqa: E402
from repro.core.lif import LIFParams  # noqa: E402
from repro.core.precision import (agreement, energy_per_frame,  # noqa: E402
                                  pareto_point, search_bits)
from repro.engine import BucketPolicy, run_batched, trace_count  # noqa: E402
from repro.engine.sharded_run import snn_serve_mesh  # noqa: E402
from repro.launch.serve_snn import serve_stream, synth_requests  # noqa: E402

# p50 slack vs the in-run baseline / recorded artifact: same machine, same
# process, but single-digit-ms timings still jitter under CI load
P50_SLACK = 1.5


def build_weights(kind: str, *, smoke: bool, seed: int = 0):
    """Float pruned layer specs + design point for one bench model."""
    rng = np.random.default_rng(seed)
    spec = AcceleratorSpec("precision-bench", n_cores=4, n_engines=8,
                           n_caps=16, weight_mem_bytes=1 << 20)
    lif = LIFParams(beta=0.85, threshold=0.6)
    if kind == "MLP":
        sizes = (64, 48, 10) if smoke else (196, 96, 48, 10)
        ws = []
        for i in range(len(sizes) - 1):
            w = rng.normal(0, 0.4, (sizes[i], sizes[i + 1])).astype(np.float32)
            w[np.abs(w) < np.quantile(np.abs(w), 0.6)] = 0
            ws.append(Dense(w=w))
        return ws, spec, lif
    if kind == "CIFAR_CONV":
        c, side = (2, 6) if smoke else (3, 10)
        k = rng.normal(0, 0.6, (4, c, 3, 3)).astype(np.float32)
        k[rng.random(k.shape) > 0.6] = 0
        conv = Conv2d(kernel=k, in_shape=(c, side, side), stride=1, padding=1)
        pool = SumPool2d(conv.out_shape, 2)
        head = rng.normal(0, 0.4, (int(np.prod(pool.out_shape)), 10)) \
            .astype(np.float32)
        head[np.abs(head) < np.quantile(np.abs(head), 0.4)] = 0
        return [conv, pool, Dense(w=head)], spec, lif
    raise ValueError(f"unknown model kind {kind!r} (MLP|CIFAR_CONV)")


def engine_throughput(packed, spikes: np.ndarray) -> tuple[float, int]:
    """Hot-pass events/s through ``run_batched`` + jit traces added by the
    hot pass (the zero-retrace gate's measurement)."""
    run_batched(packed, spikes, with_stats=False)          # compile + warm
    n0 = trace_count()
    t0 = time.perf_counter()
    out = run_batched(packed, spikes, with_stats=False)
    jax.block_until_ready(out.out_spikes)
    dt = time.perf_counter() - t0
    return float(spikes.sum()) / max(dt, 1e-9), trace_count() - n0


def p50_step_ms(packed, streams, mesh, passes: int = 3) -> float:
    """Hot-pass p50 bucketed step latency via the serving path — best of
    ``passes`` hot passes (single-digit-ms medians jitter under load; the
    minimum is the stable machine-speed estimate)."""
    policy = BucketPolicy.covering([s.shape[0] for s in streams],
                                   n_shards=mesh.size,
                                   max_batch=4 * mesh.size)
    serve_stream(packed, streams, policy=policy, mesh=mesh)      # warm
    best = float("inf")
    for _ in range(passes):
        _, hot = serve_stream(packed, streams, policy=policy, mesh=mesh)
        best = min(best, float(hot["p50_step_ms"]))
    return best


def bench_model(kind: str, *, smoke: bool, mesh, seed: int = 0) -> dict:
    specs, accel, lif = build_weights(kind, smoke=smoke, seed=seed)
    rng = np.random.default_rng(seed + 1)
    n_in = specs[0].n_src
    t_steps, batch = (10, 8) if smoke else (20, 16)
    probe = (rng.random((t_steps, n_in)) < 0.25).astype(np.float32)
    spikes = (rng.random((batch, t_steps, n_in)) < 0.25).astype(np.float32)

    # ---- gate: 8-bit packed operands are bit-exact vs the seed engine ----
    m8 = map_model(specs, accel, lif=lif, quant_bits=8)
    seed_engine = m8.pack(packed_ops=False)       # the pre-packed-ops path
    packed8 = m8.pack(packed_ops=True)
    out_seed = run_batched(seed_engine, spikes, with_stats=False).out_spikes
    out_pack = run_batched(packed8, spikes, with_stats=False).out_spikes
    assert np.array_equal(out_seed, out_pack), \
        f"{kind}: 8-bit packed-operand engine != seed engine"
    oracle = run(m8, probe)
    eng_probe = run_batched(packed8, probe[None], with_stats=False)
    assert np.array_equal(oracle.out_spikes, eng_probe.out_spikes[0]), \
        f"{kind}: 8-bit packed engine != cycle-accurate oracle"

    # ---- per-config Pareto sweep -----------------------------------------
    mixed = search_bits(specs, accel, probe, lif=lif, budget=0.05,
                        choices=(8, 4, 2))
    configs = [("w8", [8] * len(specs)), ("w4", [4] * len(specs)),
               ("w2", [2] * len(specs)),
               ("mixed", mixed.per_layer_bits)]
    base_out = oracle.out_spikes
    points, sram_bytes, hot_traces = [], {}, {}
    for label, bits in configs:
        mapped = m8 if bits == [8] * len(specs) else \
            map_model(specs, accel, lif=lif, quant_bits=bits)
        res = run(mapped, probe)
        packed = mapped.pack(packed_ops=True)
        ev_s, traces = engine_throughput(packed, spikes)
        eng = run_batched(packed, probe[None], with_stats=False)
        assert np.array_equal(res.out_spikes, eng.out_spikes[0]), \
            f"{kind}/{label}: packed engine != oracle at bits={bits}"
        pt = pareto_point(label, bits, res, mapped,
                          agreement(res.out_spikes, base_out),
                          events_per_s=ev_s)
        points.append(pt)
        sram_bytes[label] = pt["weight_sram_bytes"]
        hot_traces[label] = traces
        print(f"precision/{kind}/{label},bits={bits},"
              f"agreement={pt['agreement']:.3f},"
              f"sram_bytes={pt['weight_sram_bytes']},"
              f"e_frame={pt['energy_per_frame_j']:.3e},"
              f"events_per_s={ev_s:.0f}")

    # ---- gates: retrace, byte monotonicity, 4-bit Pareto win -------------
    assert all(t == 0 for t in hot_traces.values()), \
        f"{kind}: hot pass retraced: {hot_traces}"
    assert sram_bytes["w8"] > sram_bytes["w4"] > sram_bytes["w2"], \
        f"{kind}: weight-word bytes not monotone in bits: {sram_bytes}"
    reduction = sram_bytes["w8"] / sram_bytes["w4"]
    assert reduction >= 1.8, \
        f"{kind}: 4-bit byte reduction {reduction:.2f}x < 1.8x"
    e8 = next(p for p in points if p["config"] == "w8")["energy_per_frame_j"]
    e4 = next(p for p in points if p["config"] == "w4")["energy_per_frame_j"]
    assert e4 < e8, f"{kind}: 4-bit energy/frame {e4} !< 8-bit {e8}"

    # ---- gate: serving p50 does not regress ------------------------------
    streams = synth_requests(16 if smoke else 48, n_in,
                             t_hi=12 if smoke else 30, seed=seed + 2)
    p50_base = p50_step_ms(seed_engine, streams, mesh)
    p50_now = p50_step_ms(m8.pack(), streams, mesh)
    assert p50_now <= max(p50_base * P50_SLACK, p50_base + 0.5), \
        f"{kind}: p50 step latency regressed {p50_base:.2f} -> {p50_now:.2f} ms"
    print(f"precision/{kind}/serving,p50_base={p50_base:.2f}ms,"
          f"p50_now={p50_now:.2f}ms")

    return {"model": kind, "pareto": points,
            "bit_exact_8bit_packed": True,
            "hot_traces": hot_traces,
            "byte_reduction_4bit": reduction,
            "p50_step_ms_baseline": p50_base,
            "p50_step_ms": p50_now,
            "search": {"per_layer_bits": mixed.per_layer_bits,
                       "agreement": mixed.agreement,
                       "energy_reduction": mixed.energy_reduction,
                       "steps": len(mixed.history)}}


def check_vs_serving_artifact(rows: list[dict],
                              path: str = "BENCH_serving.json") -> None:
    """When serving_bench ran earlier in the same CI job, hold the p50 step
    latency to its recorded seed numbers (same machine, same process tree)."""
    if not os.path.exists(path):
        print(f"no {path} — skipping cross-artifact p50 check")
        return
    with open(path) as f:
        blob = json.load(f)
    recorded = [m["p50_step_ms"] for m in blob.get("models", [])]
    if not recorded:
        return
    worst_recorded = max(recorded)
    worst_now = max(r["p50_step_ms"] for r in rows)
    assert worst_now <= max(worst_recorded * P50_SLACK, worst_recorded + 0.5), \
        (f"p50 step latency regressed vs {path}: "
         f"{worst_recorded:.2f} -> {worst_now:.2f} ms")
    print(f"p50 vs {path}: {worst_recorded:.2f} -> {worst_now:.2f} ms (ok)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_precision.json")
    ap.add_argument("--data", type=int, default=None)
    ap.add_argument("--spoof-devices", type=int, default=None)
    args = ap.parse_args()
    assert_spoof_applied(_SPOOFED)
    mesh = snn_serve_mesh(args.data)
    rows = [bench_model(kind, smoke=args.smoke, mesh=mesh)
            for kind in ("MLP", "CIFAR_CONV")]
    check_vs_serving_artifact(rows)
    blob = {"bench": "precision", "smoke": args.smoke,
            "backend": jax.default_backend(),
            "n_devices": len(jax.devices()), "models": rows}
    with open(args.out, "w") as f:
        json.dump(blob, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
