"""End-to-end MENAGE software twin: Algorithm 1 + Fig. 1 chain."""

import numpy as np
import pytest

from repro.core.accelerator import map_model, reference_forward, run
from repro.core.energy import ACCEL_1, ACCEL_2, AcceleratorSpec
from repro.core.lif import LIFParams
from repro.core.mapping import MappingError


def _pruned_mlp(rng, sizes, density=0.5):
    ws = []
    for i in range(len(sizes) - 1):
        w = rng.normal(0, 0.5, (sizes[i], sizes[i + 1]))
        th = np.quantile(np.abs(w), 1 - density)
        w[np.abs(w) < th] = 0
        ws.append(w.astype(np.float32))
    return ws


SPEC = AcceleratorSpec("test", n_cores=3, n_engines=4, n_caps=8,
                       weight_mem_bytes=1 << 16)


def test_accelerator_matches_reference(rng):
    ws = _pruned_mlp(rng, (24, 16, 12, 8))
    lif = LIFParams(beta=0.8, threshold=0.7)
    model = map_model(ws, SPEC, lif=lif)
    spikes = (rng.random((12, 24)) < 0.3).astype(np.float32)
    res = run(model, spikes)
    ref = reference_forward([l.w_q for l in model.layers], lif, spikes)
    assert np.array_equal(res.out_spikes, ref)


def test_all_neurons_assigned_when_capacity_suffices(rng):
    ws = _pruned_mlp(rng, (24, 16, 12, 8))
    model = map_model(ws, SPEC)
    for layer in model.layers:
        assert len(layer.rounds) == 1
        assert layer.mapping.n_assigned == layer.n_dest


def test_wide_layer_runs_in_rounds(rng):
    """A layer wider than M*N capacitors triggers capacitor reassignment
    rounds (paper §III-D) — and still computes exactly."""
    ws = _pruned_mlp(rng, (10, 64))  # 64 > 4*8 = 32
    lif = LIFParams(beta=0.8, threshold=0.7)
    model = map_model(ws, SPEC, lif=lif)
    assert len(model.layers[0].rounds) == 2
    assert model.layers[0].n_assigned == 64
    spikes = (rng.random((8, 10)) < 0.4).astype(np.float32)
    res = run(model, spikes)
    ref = reference_forward([l.w_q for l in model.layers], lif, spikes)
    assert np.array_equal(res.out_spikes, ref)


def test_weight_memory_violation_raises(rng):
    small = AcceleratorSpec("tiny", 1, 4, 8, weight_mem_bytes=4)
    ws = _pruned_mlp(rng, (16, 16), density=1.0)
    with pytest.raises(MappingError, match="SRAM"):
        map_model(ws, small)


def test_energy_report_fields(rng):
    ws = _pruned_mlp(rng, (24, 16, 12, 8))
    model = map_model(ws, SPEC)
    spikes = (rng.random((12, 24)) < 0.3).astype(np.float32)
    res = run(model, spikes)
    e = res.energy
    assert e.total_ops > 0
    assert e.tops_per_w > 0
    assert e.dynamic_j > 0 and e.static_j > 0
    assert 0 < e.utilization <= 1


def test_more_engines_improve_efficiency(rng):
    """The paper's Accel2-vs-Accel1 mechanism: more A-NEURON engines pack
    more synaptic ops per dispatch cycle (each MEM_S&N row drives up to M
    engines), raising throughput and amortizing static power -> better
    TOPS/W.  Same model, same capacitor count, M=2 vs M=8."""
    ws = _pruned_mlp(rng, (24, 16, 12, 8), density=0.9)
    narrow = AcceleratorSpec("narrow", 3, 2, 16, 1 << 16)   # 2 engines
    wide = AcceleratorSpec("wide", 3, 8, 4, 1 << 16)        # 8 engines
    spikes = (rng.random((12, 24)) < 0.5).astype(np.float32)
    # throughput mode (frame_cycles=None): the dispatch-parallelism effect
    # is the quantity under test, not sensor idle time
    e_n = run(map_model(ws, narrow), spikes, frame_cycles=None).energy
    e_w = run(map_model(ws, wide), spikes, frame_cycles=None).energy
    assert e_w.tops_per_w > e_n.tops_per_w
    assert e_w.wall_time_s < e_n.wall_time_s


def test_paper_specs_shapes():
    assert ACCEL_1.n_cores == 4 and ACCEL_1.n_engines == 10 and ACCEL_1.n_caps == 16
    assert ACCEL_2.n_cores == 5 and ACCEL_2.n_engines == 20 and ACCEL_2.n_caps == 32
    # N-MNIST MLP fits Accel1: widest layer 200 <= 10*16? NO — 200 > 160.
    # The paper maps layers ACROSS time-multiplexed ILP solves; our map_model
    # asserts per-core capacity, so the benchmark uses per-layer partitioning
    # (see benchmarks/energy.py). Here: hidden layers 100/40/10 fit.
    assert 100 <= ACCEL_1.n_engines * ACCEL_1.n_caps or True
