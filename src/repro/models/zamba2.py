"""Zamba2-style hybrid: Mamba2 backbone + a *shared* attention block applied
every ``hybrid_period`` SSM layers (arXiv:2411.15242).

The shared block's weights are a single parameter set reused at every
application (Zamba's signature trick — attention capacity at ~1/9 of the
parameter cost); each application keeps its own KV cache.
Structure: reshape the 54 stacked mamba layers into (n_outer, period) and
scan over outer groups; the body scans the inner mamba layers then applies
the shared attention+FFN block.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.common import ArchConfig
from repro.models.layers import (P, bf16_layers, cross_entropy,
                                 flash_attention, init_params, param_axes,
                                 rms_norm, rotary_embed, swiglu)
from repro.models.mamba2 import (init_mamba2_cache, mamba2_block,
                                 mamba2_block_decode, mamba2_cache_spec,
                                 mamba2_layer_specs)
from repro.models.transformer import _cache_positions, decode_attention
from repro.parallel.sharding import shard


def _outer(cfg: ArchConfig) -> tuple[int, int]:
    period = cfg.hybrid_period
    assert cfg.n_layers % period == 0
    return cfg.n_layers // period, period


def zamba2_specs(cfg: ArchConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim()
    h, kh = cfg.n_heads, cfg.n_kv_heads
    shared = {
        "ln1": P((d,), ("embed",), "ones"),
        "ln2": P((d,), ("embed",), "ones"),
        "wq": P((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, kh, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, kh, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((h, hd, d), ("heads", "head_dim", "embed")),
        "w_gate": P((d, cfg.d_ff), ("embed", "mlp")),
        "w_up": P((d, cfg.d_ff), ("embed", "mlp")),
        "w_down": P((cfg.d_ff, d), ("mlp", "embed")),
    }
    return {
        "embed": P((cfg.vocab_size, d), ("vocab", "embed"), "embed", scale=0.02),
        "lm_head": P((d, cfg.vocab_size), ("embed", "vocab")),
        "ln_f": P((d,), ("embed",), "ones"),
        "mamba": mamba2_layer_specs(cfg),
        "shared": shared,
    }


def init_zamba2(key, cfg: ArchConfig, dtype=jnp.float32):
    return init_params(key, zamba2_specs(cfg), dtype)


def zamba2_axes(cfg: ArchConfig):
    return param_axes(zamba2_specs(cfg))


def _shared_block(x, sp, cfg: ArchConfig, positions, q_chunk=512, kv_chunk=512):
    h = rms_norm(x, sp["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, sp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, sp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, sp["wv"])
    q = rotary_embed(q, positions, cfg.rope_theta)
    k = rotary_embed(k, positions, cfg.rope_theta)
    q = shard(q, "act_batch", "act_seq", "act_heads", "act_head_dim")
    o = flash_attention(q, k, v, causal=True, window=cfg.window,
                        q_chunk=q_chunk, kv_chunk=kv_chunk)
    x = x + jnp.einsum("bshk,hkd->bsd", o, sp["wo"])
    h2 = rms_norm(x, sp["ln2"], cfg.norm_eps)
    x = x + swiglu(h2, sp["w_gate"], sp["w_up"], sp["w_down"])
    return shard(x, "act_batch", "act_seq", "act_embed")


def _group_params(params, cfg: ArchConfig):
    n_outer, period = _outer(cfg)
    return jax.tree.map(
        lambda a: a.reshape(n_outer, period, *a.shape[1:]),
        bf16_layers(params["mamba"]))


def zamba2_logits(params: dict, cfg: ArchConfig, tokens: jax.Array,
                  remat: bool = True) -> jax.Array:
    b, s = tokens.shape
    x = params["embed"][tokens].astype(jnp.bfloat16) * math.sqrt(cfg.d_model)
    x = shard(x, "act_batch", "act_seq", "act_embed")
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    grouped = _group_params(params, cfg)
    shared = jax.tree.map(lambda a: a.astype(jnp.bfloat16), params["shared"])

    def outer_body(xx, group):
        def inner(xi, lp):
            xi, _ = mamba2_block(xi, lp, cfg)
            return xi, None

        xx, _ = jax.lax.scan(inner, xx, group)
        xx = _shared_block(xx, shared, cfg, positions)
        return xx, None

    body = jax.checkpoint(outer_body) if remat else outer_body
    x, _ = jax.lax.scan(body, x, grouped)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(jnp.bfloat16))
    return shard(logits, "act_batch", "act_seq", "act_vocab")


def zamba2_loss(params, cfg: ArchConfig, batch: dict) -> jax.Array:
    toks = batch["tokens"]
    logits = zamba2_logits(params, cfg, toks[:, :-1])
    return cross_entropy(logits, toks[:, 1:])


def zamba2_prefill(params: dict, cfg: ArchConfig, tokens: jax.Array):
    """Full forward collecting the decode cache: per-layer SSM states, conv
    tail (zero stand-in, as in mamba2 prefill — documented), and the shared
    block's KV per application.  Returns (last-token logits, cache)."""
    n_outer, period = _outer(cfg)
    b, s = tokens.shape
    hd = cfg.resolved_head_dim()
    x = params["embed"][tokens].astype(jnp.bfloat16) * math.sqrt(cfg.d_model)
    x = shard(x, "act_batch", "act_seq", "act_embed")
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    grouped = _group_params(params, cfg)
    shared = jax.tree.map(lambda a: a.astype(jnp.bfloat16), params["shared"])

    def outer_body(xx, group):
        def inner(xi, lp):
            xi, state = mamba2_block(xi, lp, cfg)
            return xi, state

        xx, states = jax.lax.scan(inner, xx, group)
        h = rms_norm(xx, shared["ln1"], cfg.norm_eps)
        kk = jnp.einsum("bsd,dhk->bshk", h, shared["wk"])
        kk = rotary_embed(kk, positions, cfg.rope_theta)
        vv = jnp.einsum("bsd,dhk->bshk", h, shared["wv"])
        xx = _shared_block(xx, shared, cfg, positions)
        ck = kk.transpose(0, 2, 1, 3).astype(jnp.bfloat16)
        cv = vv.transpose(0, 2, 1, 3).astype(jnp.bfloat16)
        return xx, (states, ck, cv)

    x, (ssm, ck, cv) = jax.lax.scan(outer_body, x, grouped)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1],
                        params["lm_head"].astype(jnp.bfloat16))
    d_in = cfg.ssm_expand * cfg.d_model
    cw = cfg.ssm_conv_width
    cache = {
        "ssm": ssm.reshape(cfg.n_layers, *ssm.shape[2:]).astype(jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, b, cw - 1, d_in), jnp.bfloat16),
        "attn_k": ck, "attn_v": cv,
    }
    return shard(logits, "act_batch", "act_vocab"), cache


# ------------------------------------------------------------------ decode

def zamba2_cache_spec(cfg: ArchConfig, batch: int, cache_len: int):
    n_outer, _ = _outer(cfg)
    hd = cfg.resolved_head_dim()
    mspec, maxes = mamba2_cache_spec(cfg, batch)
    kv_shape = (n_outer, batch, cfg.n_kv_heads, cache_len, hd)
    kv_axes = ("layers", "cache_batch", "cache_kv_heads", "cache_seq",
               "act_head_dim")
    spec = dict(mspec)
    spec["attn_k"] = jax.ShapeDtypeStruct(kv_shape, jnp.bfloat16)
    spec["attn_v"] = jax.ShapeDtypeStruct(kv_shape, jnp.bfloat16)
    axes = dict(maxes)
    axes["attn_k"] = kv_axes
    axes["attn_v"] = kv_axes
    return spec, axes


def init_zamba2_cache(cfg: ArchConfig, batch: int, cache_len: int):
    spec, _ = zamba2_cache_spec(cfg, batch, cache_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def zamba2_decode_step(params: dict, cfg: ArchConfig, cache: dict,
                       tokens: jax.Array, pos: jax.Array,
                       attn_impl=decode_attention):
    n_outer, period = _outer(cfg)
    b = tokens.shape[0]
    clen = cache["attn_k"].shape[3]
    slot = pos
    slot_pos = _cache_positions(cfg, clen, pos)
    x = params["embed"][tokens].astype(jnp.bfloat16) * math.sqrt(cfg.d_model)
    shared = jax.tree.map(lambda a: a.astype(jnp.bfloat16), params["shared"])
    grouped = _group_params(params, cfg)
    g_ssm = jax.tree.map(
        lambda a: a.reshape(n_outer, period, *a.shape[1:]), cache["ssm"])
    g_conv = jax.tree.map(
        lambda a: a.reshape(n_outer, period, *a.shape[1:]), cache["conv"])

    def outer_body(xx, group_in):
        lp_group, ssm_g, conv_g, ck, cv = group_in

        def inner(xi, layer_in):
            lp, ssm, conv = layer_in
            xi, s2, c2 = mamba2_block_decode(xi, lp, cfg, ssm, conv)
            return xi, (s2, c2)

        xx, (ssm2, conv2) = jax.lax.scan(inner, xx, (lp_group, ssm_g, conv_g))
        # shared attention (decode step)
        sp = shared
        h = rms_norm(xx, sp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bd,dhk->bhk", h, sp["wq"])
        k_new = jnp.einsum("bd,dhk->bhk", h, sp["wk"])
        v_new = jnp.einsum("bd,dhk->bhk", h, sp["wv"])
        posb = jnp.broadcast_to(pos, (b, 1))
        q = rotary_embed(q[:, None], posb, cfg.rope_theta)[:, 0]
        k_new = rotary_embed(k_new[:, None], posb, cfg.rope_theta)[:, 0]
        ck = jax.lax.dynamic_update_slice(
            ck, k_new.astype(ck.dtype)[:, :, None], (0, 0, slot, 0))
        cv = jax.lax.dynamic_update_slice(
            cv, v_new.astype(cv.dtype)[:, :, None], (0, 0, slot, 0))
        o = attn_impl(q, ck, cv, slot_pos, pos, cfg.window)
        xx = xx + jnp.einsum("bhk,hkd->bd", o, sp["wo"])
        h2 = rms_norm(xx, sp["ln2"], cfg.norm_eps)
        xx = xx + swiglu(h2, sp["w_gate"], sp["w_up"], sp["w_down"])
        return xx, (ssm2, conv2, ck, cv)

    x, (ssm, conv, ak, av) = jax.lax.scan(
        outer_body, x,
        (grouped, g_ssm, g_conv, cache["attn_k"], cache["attn_v"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x, params["lm_head"].astype(jnp.bfloat16))
    new_cache = {
        "ssm": ssm.reshape(cfg.n_layers, *ssm.shape[2:]),
        "conv": conv.reshape(cfg.n_layers, *conv.shape[2:]),
        "attn_k": ak, "attn_v": av,
    }
    return shard(logits, "act_batch", "act_vocab"), new_cache
