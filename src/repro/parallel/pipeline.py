"""Pipeline parallelism: GPipe-style microbatch pipeline over a mesh axis.

A `shard_map`-based schedule: each stage owns a contiguous slice of layers;
activations flow stage->stage via `collective_permute` ring steps.  With M
microbatches and S stages the schedule runs M+S-1 ticks; each tick every
stage applies its layer block to the microbatch it holds, then shifts.

The production configs use DP x TP (+pod DP) — PP is the config option for
depth-dominated models (deepseek-67b 95L) where it converts the FSDP
all-gather traffic into point-to-point transfers; see EXPERIMENTS.md §Perf
for where it wins and where it doesn't.  Correctness is tested on a small
mesh in tests/test_pipeline.py (pipeline == sequential execution, bit-close).
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import pcast_varying, shard_map


def pipeline_forward(layer_fn: Callable, params_stacked, x_microbatches,
                     mesh: Mesh, stage_axis: str = "stage"):
    """Run a pipelined forward.

    layer_fn(params_slice, x) -> x          (one stage's layer block)
    params_stacked: pytree with leading dim = n_stages (sharded over
                    ``stage_axis``)
    x_microbatches: [n_micro, mb, ...] activations (replicated)

    Returns [n_micro, mb, ...] outputs.
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = x_microbatches.shape[0]
    ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(params_loc, xs):
        # params_loc: this stage's params (leading dim 1) — squeeze
        p_loc = jax.tree.map(lambda a: a[0], params_loc)
        stage = jax.lax.axis_index(stage_axis)
        mb_shape = xs.shape[1:]
        buf = jnp.zeros(mb_shape, xs.dtype)              # activation in flight
        outs = jnp.zeros_like(xs)
        # mark carries as device-varying (they diverge across stages after
        # the first ppermute) so scan's carry types stay consistent
        buf = pcast_varying(buf, (stage_axis,))
        outs = pcast_varying(outs, (stage_axis,))

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any left)
            feed = jnp.where(t < n_micro, t, n_micro - 1)
            incoming = xs[feed]
            buf = jnp.where(stage == 0,
                            jnp.where(t < n_micro, incoming, buf), buf)
            # every stage processes what it holds
            buf = layer_fn(p_loc, buf)
            # last stage emits microbatch t - (S-1)
            out_idx = t - (n_stages - 1)
            safe = jnp.clip(out_idx, 0, n_micro - 1)
            emit = (stage == n_stages - 1) & (out_idx >= 0)
            outs = jnp.where(
                emit,
                jax.lax.dynamic_update_slice(
                    outs, buf[None].astype(outs.dtype),
                    (safe,) + (0,) * len(mb_shape)),
                outs)
            # shift ring: stage i -> i+1
            buf = jax.lax.ppermute(buf, stage_axis, perm)
            # ppermute moved our buf away and brought the previous stage's in;
            # stage 0's incoming slot is overwritten next tick by the feed.
            return (buf, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    return shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(stage_axis), params_stacked),
                  P()),
        out_specs=P(),
    )(params_stacked, x_microbatches)


def sequential_reference(layer_fn, params_stacked, x_microbatches):
    """Oracle: apply all stages sequentially to each microbatch."""
    n_stages = jax.tree.leaves(params_stacked)[0].shape[0]

    def run_one(x):
        for s in range(n_stages):
            p = jax.tree.map(lambda a: a[s], params_stacked)
            x = layer_fn(p, x)
        return x

    return jax.vmap(run_one)(x_microbatches)
