"""Training loop: convergence, checkpoint/restart (exactly-once), straggler
detection, gradient compression with error feedback."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.train_loop import (TrainLoopConfig, init_train_state,
                                     make_train_step, resume_or_init,
                                     train_loop)
from repro.optim.adamw import AdamWConfig
from repro.optim.compress import CompressionConfig, compress_gradients, decompress_gradients, init_residual


def _toy_problem(seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=(8, 4)).astype(np.float32)
    x = rng.normal(size=(64, 8)).astype(np.float32)
    y = x @ w_true

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    def batch_fn(step):
        r = np.random.default_rng(step)
        idx = r.integers(0, 64, 16)
        return {"x": jnp.asarray(x[idx]), "y": jnp.asarray(y[idx])}

    params = {"w": jnp.zeros((8, 4))}
    return loss_fn, batch_fn, params


def test_loss_decreases(tmp_path):
    loss_fn, batch_fn, params = _toy_problem()
    opt_cfg = AdamWConfig(lr=3e-2, weight_decay=0.0, warmup_steps=1)
    step = jax.jit(make_train_step(loss_fn, opt_cfg))
    state = init_train_state(None, params, opt_cfg)
    cfg = TrainLoopConfig(steps=60, checkpoint_every=1000,
                          checkpoint_dir=str(tmp_path), log_every=1000)
    _, hist = train_loop(state.as_tree(), step, batch_fn, cfg,
                         log_fn=lambda s: None)
    assert hist["loss"][-1] < hist["loss"][0] * 0.2


def test_checkpoint_restart_exactly_once(tmp_path):
    """Kill at step 25, restart, final state identical to uninterrupted."""
    loss_fn, batch_fn, params = _toy_problem()
    opt_cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, warmup_steps=1)
    step = jax.jit(make_train_step(loss_fn, opt_cfg))

    def fresh():
        return init_train_state(None, params, opt_cfg).as_tree()

    cfg = TrainLoopConfig(steps=40, checkpoint_every=5,
                          checkpoint_dir=str(tmp_path), log_every=1000)

    # uninterrupted reference
    ref, _ = train_loop(fresh(), step, batch_fn,
                        TrainLoopConfig(steps=40, checkpoint_every=1000,
                                        checkpoint_dir=str(tmp_path) + "_ref",
                                        log_every=1000),
                        log_fn=lambda s: None)

    class Boom(RuntimeError):
        pass

    def bomb(s):
        if s == 27:
            raise Boom()

    try:
        train_loop(fresh(), step, batch_fn, cfg, failure_hook=bomb,
                   log_fn=lambda s: None)
        raise AssertionError("should have failed")
    except Boom:
        pass
    # restart from latest checkpoint (step 25)
    state, start = resume_or_init(cfg, fresh())
    assert start == 25
    final, _ = train_loop(state, step, batch_fn, cfg, start_step=start,
                          log_fn=lambda s: None)
    np.testing.assert_allclose(np.asarray(final["params"]["w"]),
                               np.asarray(ref["params"]["w"]), atol=1e-6)


def test_straggler_detection(tmp_path):
    loss_fn, batch_fn, params = _toy_problem()
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=1)
    inner = jax.jit(make_train_step(loss_fn, opt_cfg))

    def slow_step(state, batch):
        out = inner(state, batch)
        jax.block_until_ready(out[1]["loss"])
        return out

    calls = {"n": 0}

    def step(state, batch):
        calls["n"] += 1
        if calls["n"] == 20:
            time.sleep(0.5)                     # injected straggler
        return slow_step(state, batch)

    cfg = TrainLoopConfig(steps=30, checkpoint_every=1000,
                          checkpoint_dir=str(tmp_path), log_every=1000)
    state = init_train_state(None, params, opt_cfg)
    _, hist = train_loop(state.as_tree(), step, batch_fn, cfg,
                         log_fn=lambda s: None)
    assert hist["stragglers"] >= 1


def test_gradient_compression_error_feedback():
    """Compressed+EF gradients converge close to exact."""
    loss_fn, batch_fn, params = _toy_problem()
    opt_cfg = AdamWConfig(lr=3e-2, weight_decay=0.0, warmup_steps=1)
    comp = CompressionConfig(enabled=True, block=64)
    step_c = jax.jit(make_train_step(loss_fn, opt_cfg, comp))
    step_e = jax.jit(make_train_step(loss_fn, opt_cfg))
    sc = init_train_state(None, params, opt_cfg, comp).as_tree()
    se = init_train_state(None, params, opt_cfg).as_tree()
    for s in range(100):
        b = batch_fn(s)
        sc, mc = step_c(sc, b)
        se, me = step_e(se, b)
    assert float(mc["loss"]) < 0.05
    assert abs(float(mc["loss"]) - float(me["loss"])) < 0.01


def test_compression_roundtrip_unbiased(rng):
    grads = {"w": jnp.asarray(rng.normal(size=(37, 13)).astype(np.float32))}
    comp_cfg = CompressionConfig(enabled=True, block=32)
    residual = init_residual(grads)
    comp, res = compress_gradients(grads, residual, comp_cfg)
    approx = decompress_gradients(comp, grads)
    # residual exactly accounts for the quantization error
    np.testing.assert_allclose(
        np.asarray(approx["w"] + res["w"]), np.asarray(grads["w"]),
        atol=1e-6)


def test_microbatched_grads_match_full():
    """Gradient accumulation (K microbatches) == full-batch gradients."""
    loss_fn, batch_fn, params = _toy_problem()
    opt_cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, warmup_steps=1)
    full = jax.jit(make_train_step(loss_fn, opt_cfg))
    micro = jax.jit(make_train_step(loss_fn, opt_cfg, microbatches=4))
    s1 = init_train_state(None, params, opt_cfg).as_tree()
    s2 = init_train_state(None, params, opt_cfg).as_tree()
    b = batch_fn(0)
    s1, m1 = full(s1, b)
    s2, m2 = micro(s2, b)
    np.testing.assert_allclose(np.asarray(s1["params"]["w"]),
                               np.asarray(s2["params"]["w"]), atol=1e-6)
    # microbatched loss is the mean over microbatch losses == full-batch MSE
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)

def test_decompress_preserves_leaf_dtype(rng):
    """Regression: decompress_gradients always returned float32, silently
    widening bf16 gradient trees — optimizer updates downstream of the
    all-reduce would run at the wrong dtype (and double the memory)."""
    grads = {"w": jnp.asarray(rng.normal(size=(17, 5)), dtype=jnp.bfloat16),
             "b": jnp.asarray(rng.normal(size=(33,)).astype(np.float32))}
    comp_cfg = CompressionConfig(enabled=True, block=16)
    comp, _ = compress_gradients(grads, init_residual(grads), comp_cfg)
    approx = decompress_gradients(comp, grads)
    assert approx["w"].dtype == jnp.bfloat16
    assert approx["b"].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(approx["w"], np.float32),
                               np.asarray(grads["w"], np.float32), atol=0.1)
