"""Analog non-ideality models (DESIGN.md §2, assumption (a)).

The silicon has op-amp offsets, capacitor mismatch and C2C ladder element
variation; we model them as optional stochastic perturbations so accuracy
sensitivity can be studied without circuit simulation.

Two injection points:

  * **Training-side** (`perturb_weights` / `perturb_membrane` /
    `perturb_beta`): perturb the float training parameters to study
    robustness of the learned model.
  * **Serving-side** (`perturb_packed`): perturb the *effective* synaptic
    weights of an already-packed engine model — the replayed A-SYN SRAM
    content — modelling one physical chip's static C2C-ladder mismatch.
    The perturbation is deterministic in the key (per-layer/round subkeys
    via ``fold_in``), so a given ``(key, sigma)`` names one reproducible
    "device instance": serving it twice is bit-identical, which is what
    lets accuracy-under-noise be a tracked serving metric rather than a
    flaky estimate (cf. the memristive analog-neuron literature, arXiv
    2509.04960: noise belongs in the serving measurement loop).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AnalogNoise:
    weight_sigma: float = 0.0      # relative C2C ladder gain error
    offset_sigma: float = 0.0      # op-amp input-referred offset (abs, V)
    leak_mismatch: float = 0.0     # relative per-capacitor leak variation


def perturb_weights(key: jax.Array, w: jax.Array, noise: AnalogNoise) -> jax.Array:
    if noise.weight_sigma <= 0:
        return w
    return w * (1.0 + noise.weight_sigma * jax.random.normal(key, w.shape))


def perturb_membrane(key: jax.Array, v: jax.Array, noise: AnalogNoise) -> jax.Array:
    if noise.offset_sigma <= 0:
        return v
    return v + noise.offset_sigma * jax.random.normal(key, v.shape)


def perturb_beta(key: jax.Array, beta: float, shape, noise: AnalogNoise) -> jax.Array:
    b = jnp.full(shape, beta)
    if noise.leak_mismatch <= 0:
        return b
    return jnp.clip(b * (1.0 + noise.leak_mismatch * jax.random.normal(key, shape)), 0.0, 1.0)


def as_noise_key(key) -> jax.Array:
    """Coerce an int seed to a jax PRNG key (keys pass through) — the
    convenience the serving entry points use so operators can write
    ``noise_seed=0`` instead of importing jax.random."""
    return jax.random.key(key) if isinstance(key, int) else key


def perturb_packed(key: jax.Array, packed, noise: AnalogNoise):
    """One noisy device instance of a packed engine model.

    Applies the relative C2C-ladder gain error (``weight_sigma``) to every
    round's effective weights — dense replay tiles and COO synapse values
    alike — and returns a new :class:`repro.engine.batched_run.PackedModel`
    sharing the untouched control-memory tables.  Zeros stay exactly zero
    (multiplicative noise: an absent synapse has no ladder to mismatch), so
    event-driven sparsity is preserved.

    Deterministic: subkeys are ``fold_in``-derived from the (layer, round)
    position, so the same ``(key, noise)`` always yields the bit-identical
    perturbed model regardless of call order — the anchor for the
    serving-time accuracy-under-noise metric (tests/test_noise.py).
    ``weight_sigma <= 0`` returns ``packed`` unchanged (identity, same
    object — no new jit cache entries from a no-op perturbation).
    """
    import dataclasses as _dc

    if noise.weight_sigma <= 0:
        return packed
    layers = []
    for li, layer in enumerate(packed.layers):
        if getattr(layer, "w_packed", None) is not None:
            # packed-operand tiles hold sub-byte integer codes; a relative
            # f32 gain error is not representable in them.  The unpacked
            # engine path executes the same model at any bit-width.
            raise ValueError(
                f"layer {li} uses packed sub-byte operands; analog noise "
                "needs the f32 replay path — repack with "
                "model.pack(packed_ops=False)")
        rounds = []
        for ri, rnd in enumerate(layer.rounds):
            k = jax.random.fold_in(jax.random.fold_in(key, li), ri)
            if rnd.w_dense is not None:
                rounds.append(_dc.replace(
                    rnd, w_dense=perturb_weights(k, rnd.w_dense, noise)))
            elif rnd.coo_widx is not None:
                # compressed round: the dictionary is a digital artifact but
                # each *synapse dispatch* runs through its own C2C ladder, so
                # mismatch is per-synapse — materialize the values through
                # the indirection, perturb, and drop the now-stale pointer
                val = perturb_weights(
                    k, packed.weight_dict[rnd.coo_widx], noise)
                rounds.append(_dc.replace(rnd, coo_val=val, coo_widx=None))
            else:
                rounds.append(_dc.replace(
                    rnd, coo_val=perturb_weights(k, rnd.coo_val, noise)))
        layers.append(_dc.replace(layer, rounds=rounds))
    return _dc.replace(packed, layers=layers)
