"""shard_map MoE: locality-exact expert dispatch (§Perf iteration 4).

The GSPMD baseline (transformer.moe_ffn) expresses dispatch as a global
sort + scatter; the partitioner cannot prove the scatter local and inserts
all-gathers of the (E, cap, d) dispatch buffers — the dominant collective
cost of both MoE train cells (mixtral train_4k: 212 s collective term).

This implementation exploits a structural fact of our sharding: at the FFN
input, activations x[B,S,d] are sharded over batch only — every ``model``
shard already holds all of its tokens.  So each model shard can run the
whole dispatch *locally* for its slice of the expert computation:

  * EP mode  (E %% model == 0, qwen3):  shard owns E/model experts (full f);
  * TP mode  (otherwise, mixtral):      shard owns all experts' f-slice;

and the ONLY collective is the down-projection partial-sum psum over
``model`` — identical to a dense TP FFN.  Per-shard capacity replaces global
capacity (drop decisions become shard-local; same capacity_factor).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map


def _local_moe(xf, router, wg, wu, wd, *, n_experts: int, top_k: int,
               capacity_factor: float, ep_mode: bool, model_axis: str,
               batch_axes: tuple[str, ...], mesh: Mesh):
    """Runs inside shard_map.  xf [t_loc, d] (this shard's tokens, replicated
    over model); router [d, E] replicated; expert weights sliced over
    ``model`` (experts in EP mode, f in TP mode)."""
    t, d = xf.shape
    e, k = n_experts, top_k
    logits = jnp.einsum("td,de->te", xf, router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # load-balance aux (local estimate; batch-mean via psum below)
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (t * k))
    aux = e * jnp.sum(me * ce)
    # mean over batch shards (it is already invarying across model shards —
    # the router inputs are replicated over the model axis)
    if batch_axes:
        aux = jax.lax.pmean(aux, batch_axes)

    if ep_mode:
        # keep only pairs routed to this shard's experts
        e_loc = wg.shape[0]
        shard = jax.lax.axis_index(model_axis)
        lo = shard * e_loc
        local = (expert_idx >= lo) & (expert_idx < lo + e_loc)
        eff_idx = jnp.where(local, expert_idx - lo, e_loc)  # e_loc = drop row
        n_disp_experts = e_loc
    else:
        local = jnp.ones_like(expert_idx, dtype=bool)
        eff_idx = expert_idx
        n_disp_experts = e

    # §Perf iteration 7: round capacity to a 128-multiple (MXU-aligned),
    # not a power of two — pow2 rounding padded qwen3's dispatch 1.6x
    cap = int(math.ceil(t * k / n_experts * capacity_factor / 128.0)) * 128
    cap = max(min(cap, t), 1)

    flat_e = eff_idx.reshape(-1)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    grp_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(t * k) - grp_start
    keep = (pos_in_e < cap) & (sorted_e < n_disp_experts)
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, n_disp_experts * cap)
    token_of = order // k

    disp = jnp.zeros((n_disp_experts * cap, d), xf.dtype)
    disp = disp.at[slot].add(xf[token_of], mode="drop")
    disp = disp.reshape(n_disp_experts, cap, d)

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", disp, wg,
                               preferred_element_type=jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", disp, wu,
                   preferred_element_type=jnp.float32)
    out = jnp.einsum("ecf,efd->ecd", (g * u).astype(xf.dtype), wd,
                     preferred_element_type=jnp.float32)
    out = out.reshape(n_disp_experts * cap, d)

    contrib = out[jnp.where(keep, slot, 0)] * (
        keep * gate.reshape(-1)[order]).astype(out.dtype)[:, None]
    y = jnp.zeros((t, d), out.dtype).at[token_of].add(contrib)
    # partial sums over the model axis: EP -> each shard contributed only its
    # experts; TP -> each shard contributed its f-slice.  Same combine:
    y = jax.lax.psum(y, model_axis)
    return y.astype(xf.dtype), aux


def moe_ffn_sharded(x: jax.Array, lp: dict, cfg, mesh: Mesh,
                    capacity_factor: float = 1.25,
                    model_axis: str = "model",
                    batch_axes: tuple[str, ...] = ("pod", "data")):
    """Drop-in for transformer.moe_ffn under an active mesh.  x [B,S,d]."""
    b, s, d = x.shape
    e = cfg.n_experts
    b_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    # batch must divide the batch shards; otherwise replicate batch
    n_b = 1
    for a in b_axes:
        n_b *= mesh.shape[a]
    if b % max(n_b, 1) != 0:
        b_axes, n_b = (), 1
    ep_mode = (model_axis in mesh.axis_names
               and e % mesh.shape[model_axis] == 0)

    xb = P(b_axes or None, None, None)
    if ep_mode:
        # weights sliced over experts: wg/wu (E, d, f); wd (E, f, d)
        wg_spec = P(model_axis, None, None)
        wd_spec = P(model_axis, None, None)
    else:
        # weights sliced over f: TP inside each expert
        wg_spec = P(None, None, model_axis)
        wd_spec = P(None, model_axis, None)

    body = partial(_local_moe, n_experts=e, top_k=cfg.top_k,
                   capacity_factor=capacity_factor, ep_mode=ep_mode,
                   model_axis=model_axis, batch_axes=b_axes, mesh=mesh)

    def wrapper(x3, router, wg, wu, wd):
        t_loc = x3.shape[0] * x3.shape[1]
        y, aux = body(x3.reshape(t_loc, d), router, wg, wu, wd)
        return y.reshape(x3.shape), aux

    y, aux = shard_map(
        wrapper, mesh=mesh,
        in_specs=(xb, P(None, None), wg_spec, wg_spec, wd_spec),
        out_specs=(xb, P()),
    )(x, lp["router"], lp["we_gate"], lp["we_up"], lp["we_down"])
    return y, aux
