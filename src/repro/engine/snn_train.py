"""Unified sharded SNN training engine.

The paper's evaluation models (§IV-A) train with surrogate gradients; this
module is the single production path for that training, replacing the two
hand-rolled single-device Adam loops that used to live in ``snn/mlp.py`` and
``snn/conv.py``.  One entry point — :func:`train_snn_model` — drives any
:class:`SNNModel` (MLP or conv) through the *same* machinery the transformer
stack trains with:

  * **engine/train_loop.py** — async atomic checkpoints, elastic restart
    onto a different mesh, straggler detection, step-keyed restart-safe
    data.
  * **optim/adamw.py** — :func:`adamw_update` with the base learning rate
    passed as a *dynamic* scalar, so an LR schedule changes the rate every
    step without retracing the jitted train step (the old loops made ``lr``
    a static argname and retraced per value).
  * **parallel/sharding.py** — a new ``SNN_TRAIN_RULES`` table: the spike
    batch shards over the ``("data",)`` mesh exactly like serving, params
    and optimizer state stay replicated, and a batch the mesh cannot split
    degrades gracefully to replicated execution (mirroring ``run_sharded``).

Bit-exactness contract (the serving suite's equivalence discipline, applied
to training): the gradient of a step is *defined* as a fixed-order left fold
over ``grad_shards`` contiguous batch chunks of per-chunk gradients, scaled
by ``1/K``.  The mesh only decides *where* chunks are computed — each device
evaluates its contiguous chunk(s) with the same traced chunk body, the
per-chunk results are ``all_gather``-ed in device order (= global chunk
order) and folded left-to-right, a deterministic psum.  Sharding therefore
cannot change a single bit: training on a 1×N spoofed mesh is bit-exact with
single-device training for the same ``grad_shards`` and data order, and a
checkpoint written on an 8-device mesh resumes on 4 devices onto the *same*
loss trajectory (tested, ``tests/test_snn_train.py``).  ``grad_shards``
defaults to the mesh's split of the batch (1 without a mesh), so the default
single-device configuration pays no chunking overhead.
"""

from __future__ import annotations

import dataclasses
import functools
import logging
import math
from typing import Any, Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec

from repro.engine.batched_run import should_donate
from repro.engine.sharded_run import snn_serve_mesh
from repro.engine.train_loop import (TrainLoopConfig, init_train_state,
                                     resume_or_init, train_loop)
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.parallel.compat import shard_map
from repro.parallel.sharding import SNN_TRAIN_RULES, ShardingRules
from repro.snn import conv as _conv
from repro.snn import mlp as _mlp

_log = logging.getLogger(__name__)


# ------------------------------------------------------------ model protocol

@runtime_checkable
class SNNModel(Protocol):
    """What the unified trainer needs from a model family.

    ``spikes`` are time-major ``[T, B, n_in]`` (the ``lax.scan`` training
    layout); ``loss`` returns ``(mean_loss, mean_accuracy)`` over the batch,
    differentiable through the surrogate-gradient LIF; ``layer_specs``
    lowers trained (possibly pruned) params to the ``map_model`` stack.
    """

    name: str

    def init(self, key: jax.Array, cfg) -> Any: ...

    def forward(self, params, spikes: jax.Array, cfg): ...

    def loss(self, params, spikes: jax.Array, labels: jax.Array, cfg): ...

    def layer_specs(self, params, cfg) -> list: ...


class _MLPModel:
    """The paper's spiking MLPs (``snn/mlp.py``) behind the protocol."""

    name = "mlp"

    def init(self, key, cfg: "_mlp.SNNConfig"):
        return _mlp.init_snn(key, cfg)

    def forward(self, params, spikes, cfg: "_mlp.SNNConfig"):
        return _mlp.snn_forward(params, spikes, cfg)

    def loss(self, params, spikes, labels, cfg: "_mlp.SNNConfig"):
        return _mlp.snn_loss(params, spikes, labels, cfg)

    def layer_specs(self, params, cfg: "_mlp.SNNConfig"):
        # bare 2-D matrices; map_model coerces them to Dense specs
        return [np.asarray(w) for w in params]


class _ConvModel:
    """The spiking CNN family (``snn/conv.py``) behind the protocol."""

    name = "conv"

    def init(self, key, cfg: "_conv.ConvSNNConfig"):
        return _conv.init_conv_snn(key, cfg)

    def forward(self, params, spikes, cfg: "_conv.ConvSNNConfig"):
        return _conv.conv_snn_forward(params, spikes, cfg)

    def loss(self, params, spikes, labels, cfg: "_conv.ConvSNNConfig"):
        return _conv.conv_snn_loss(params, spikes, labels, cfg)

    def layer_specs(self, params, cfg: "_conv.ConvSNNConfig"):
        return _conv.layer_specs(params, cfg)


MLP_MODEL: SNNModel = _MLPModel()
CONV_MODEL: SNNModel = _ConvModel()


def model_for(cfg) -> SNNModel:
    """The model family matching a config dataclass."""
    if isinstance(cfg, _conv.ConvSNNConfig):
        return CONV_MODEL
    if isinstance(cfg, _mlp.SNNConfig):
        return MLP_MODEL
    raise TypeError(f"no SNN model family for config {type(cfg).__name__}")


# ------------------------------------------------------------- configuration

@dataclasses.dataclass(frozen=True)
class SNNTrainConfig:
    """Hyperparameters + loop/sharding knobs for :func:`train_snn_model`.

    The defaults are the paper's Table-I Adam (lr=1e-3, b2=0.999, no weight
    decay, no clipping, constant rate).  ``lr`` may be a schedule
    ``step -> rate``; it reaches the step as a dynamic scalar, so schedules
    never retrace.  ``mesh`` turns on data-parallel sharding over the
    ``SNN_TRAIN_RULES`` axes; ``grad_shards`` pins the gradient's chunked
    fold order independent of the mesh (see module docstring) — ``None``
    means "however many ways the mesh splits the batch".  ``checkpoint_dir``
    ``None`` trains ephemerally (no checkpoint I/O at all); a real
    path makes training resume-aware across restarts and mesh sizes.
    """

    steps: int = 100
    lr: "float | Callable[[int], float]" = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = math.inf
    warmup_steps: int = 1
    mesh: Mesh | None = None
    grad_shards: int | None = None
    checkpoint_dir: str | None = None
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 50
    straggler_factor: float = 3.0
    donate: bool | None = None      # None: on unless the backend is CPU

    def adamw(self) -> AdamWConfig:
        base = self.lr if not callable(self.lr) else self.lr(0)
        return AdamWConfig(lr=float(base), b1=self.b1, b2=self.b2,
                           eps=self.eps, weight_decay=self.weight_decay,
                           grad_clip=self.grad_clip,
                           warmup_steps=self.warmup_steps)


def snn_train_mesh(n_data: int | None = None) -> Mesh:
    """A 1-D ``("data",)`` host mesh over ``n_data`` devices (default: all
    visible) — literally the serving stack's pure-DP topology
    (:func:`repro.engine.sharded_run.snn_serve_mesh`), so training and
    serving can never drift onto different meshes."""
    return snn_serve_mesh(n_data)


# ---------------------------------------------------------------- train step

_train_traces = 0


def snn_train_trace_count() -> int:
    """How many times the unified SNN train step has been (re)traced — the
    regression probe for the dynamic-lr contract (two different learning
    rates through the same step must cost exactly one trace)."""
    return _train_traces


def _bump_train_trace() -> None:
    global _train_traces
    _train_traces += 1


def _batch_split(mesh: Mesh, dims: tuple[int, int, int]):
    """How the training rules shard a ``[T, B, n_in]`` spike batch on
    ``mesh``: returns ``(n_shards, spikes_spec, labels_spec, axes)`` with
    the same graceful degradation as serving — a batch the mesh cannot
    split evenly replicates (``n_shards == 1``) instead of crashing."""
    rules = ShardingRules(mesh, SNN_TRAIN_RULES)
    spec = rules.spec(("event_time", "event_batch", "neuron"), dims)
    axes = spec[1]
    if axes is None:
        return 1, spec, PartitionSpec(), ()
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n, spec, PartitionSpec(spec[1]), axes


def make_snn_train_step(model: SNNModel, cfg, opt_cfg: AdamWConfig, *,
                        mesh: Mesh | None = None,
                        grad_shards: int | None = None,
                        donate: bool | None = None):
    """Build the jitted unified step ``(state_tree, batch) -> (state_tree,
    metrics)`` for :func:`repro.engine.train_loop.train_loop`.

    ``batch`` is ``{"spikes": [T, B, n_in], "labels": [B], "lr": scalar}``
    (``lr`` optional — dynamic base rate for :func:`adamw_update`).  The
    gradient is the fixed-order chunked fold described in the module
    docstring: ``K = grad_shards`` chunks (default: the mesh's split of B,
    1 without a mesh), each chunk's ``value_and_grad`` of the model's mean
    loss, summed left-to-right and scaled by ``1/K``.  With a mesh, the
    chunk work distributes over the devices via ``shard_map`` (params
    replicated per ``SNN_TRAIN_RULES``); ``K`` must be a multiple of the
    mesh's split so every device owns whole chunks.
    """

    def chunk_body(params, chunk):
        spikes, labels = chunk
        (l, a), g = jax.value_and_grad(model.loss, has_aux=True)(
            params, spikes, labels, cfg)
        return l, a, g

    def chunked(params, spikes, labels, k):
        """Stacked per-chunk (loss, acc, grads) over ``k`` contiguous
        batch chunks of a time-major ``[T, b, n]`` shard."""
        t, b, n = spikes.shape
        sc = jnp.moveaxis(spikes.reshape(t, k, b // k, n), 1, 0)
        lc = labels.reshape(k, b // k)
        return jax.lax.map(functools.partial(chunk_body, params), (sc, lc))

    def fold(stacked, k):
        """Left-to-right sum over the leading chunk axis — the
        deterministic psum that fixes the reduction order."""
        chunks = [jax.tree.map(lambda x: x[i], stacked) for i in range(k)]
        return functools.reduce(
            lambda u, v: jax.tree.map(jnp.add, u, v), chunks)

    def step(state: dict, batch: dict):
        _bump_train_trace()
        spikes, labels = batch["spikes"], batch["labels"]
        t, b, n = spikes.shape
        n_split, spikes_spec, labels_spec, axes = (
            _batch_split(mesh, (t, b, n)) if mesh is not None
            else (1, None, None, ()))
        k = n_split if grad_shards is None else grad_shards
        assert b % k == 0, \
            f"batch {b} not divisible into grad_shards={k} chunks"
        # graceful fallbacks replicate instead of crashing, but must not be
        # silent: a user who built a mesh believes they get DP throughput
        # (trace-time python, so each warning logs once per batch shape)
        if k % n_split != 0:
            _log.warning(
                "snn_train: grad_shards=%d is not a multiple of the mesh's "
                "%d-way batch split — training replicated on one device "
                "instead of data-parallel", k, n_split)
            n_split = 1
        elif mesh is not None and mesh.size > 1 and n_split == 1:
            _log.warning(
                "snn_train: batch %d does not split over the %d-device "
                "mesh — training replicated on one device instead of "
                "data-parallel", b, mesh.size)
        if mesh is not None and n_split > 1:
            def body(params, sp, lb):
                local = chunked(params, sp, lb, k // n_split)
                return jax.lax.all_gather(local, axes, tiled=True)

            stacked = shard_map(
                body, mesh=mesh,
                in_specs=(PartitionSpec(), spikes_spec, labels_spec),
                out_specs=PartitionSpec(), check_rep=False)(
                    state["params"], spikes, labels)
        else:
            stacked = chunked(state["params"], spikes, labels, k)
        loss, acc, grads = fold(stacked, k)
        inv = 1.0 / k
        loss, acc = loss * inv, acc * inv
        grads = jax.tree.map(lambda g: g * inv, grads)
        params, opt, metrics = adamw_update(
            opt_cfg, state["params"], state["opt"], grads,
            lr=batch.get("lr"))
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["acc"] = acc
        return {"params": params, "opt": opt}, metrics

    return jax.jit(step,
                   donate_argnums=(0,) if should_donate(donate) else ())


# --------------------------------------------------------------- entry point

def train_snn_model(model: SNNModel, cfg, data_iter,
                    train_cfg: SNNTrainConfig, *,
                    key: jax.Array | None = None, params=None,
                    log_fn: Callable[[str], None] = print):
    """Train an SNN family through the production engine loop.

    ``data_iter`` is either a step-keyed callable ``step -> (spikes
    [T, B, n_in], labels [B])`` — the restart-safe form: resuming from a
    checkpoint replays the exact remaining batches — or any iterator
    yielding such pairs (``data/events.event_batches``), which trains fine
    but cannot guarantee the same batches after a restart.

    Returns ``(params, history)``; ``history`` is the train-loop dict
    (``loss`` / ``acc`` / ``step_time`` / ``stragglers`` /
    ``checkpoints``).
    """
    if params is None:
        params = model.init(key if key is not None else jax.random.key(0),
                            cfg)
    elif should_donate(train_cfg.donate):
        # the jitted step donates its state; copy caller-supplied params so
        # the caller's arrays survive the first update (warm starts,
        # before/after comparisons)
        params = jax.tree.map(lambda p: jnp.array(p, copy=True), params)
    opt_cfg = train_cfg.adamw()
    state = init_train_state(None, params, opt_cfg).as_tree()
    step_fn = make_snn_train_step(model, cfg, opt_cfg, mesh=train_cfg.mesh,
                                  grad_shards=train_cfg.grad_shards,
                                  donate=train_cfg.donate)
    if callable(data_iter):
        data = data_iter
    else:
        it = iter(data_iter)
        data = lambda step: next(it)  # noqa: E731
    lr = train_cfg.lr
    lr_of = lr if callable(lr) else (lambda step: lr)

    def batch_fn(step: int) -> dict:
        spikes, labels = data(step)
        return {"spikes": jnp.asarray(spikes, dtype=jnp.float32),
                "labels": jnp.asarray(labels),
                "lr": jnp.asarray(lr_of(step), dtype=jnp.float32)}

    loop_cfg = TrainLoopConfig(steps=train_cfg.steps,
                               checkpoint_every=train_cfg.checkpoint_every,
                               checkpoint_dir=train_cfg.checkpoint_dir,
                               log_every=train_cfg.log_every,
                               straggler_factor=train_cfg.straggler_factor,
                               keep_checkpoints=train_cfg.keep_checkpoints)
    start = 0
    if train_cfg.checkpoint_dir is not None:
        state, start = resume_or_init(loop_cfg, state)
        if start:
            log_fn(f"[snn_train] resumed {model.name} from step {start} "
                   f"({train_cfg.checkpoint_dir})")
    state, history = train_loop(state, step_fn, batch_fn, loop_cfg,
                                start_step=start, log_fn=log_fn)
    return state["params"], history
