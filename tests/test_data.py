"""Data pipelines: determinism (restart-safety), statistics."""

import jax
import numpy as np

from repro.data.events import EventDatasetConfig, event_batches, synthetic_event_dataset
from repro.data.tokens import TokenPipelineConfig, token_batch


def test_token_pipeline_deterministic():
    cfg = TokenPipelineConfig(vocab_size=1000, seq_len=32, global_batch=4,
                              seed=7)
    a = token_batch(cfg, 12)
    b = token_batch(cfg, 12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = token_batch(cfg, 13)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_token_shapes_and_range():
    cfg = TokenPipelineConfig(vocab_size=500, seq_len=16, global_batch=3)
    t = token_batch(cfg, 0)["tokens"]
    assert t.shape == (3, 17) and t.dtype == np.int32
    assert t.min() >= 0 and t.max() < 500


def test_event_dataset_statistics():
    cfg = EventDatasetConfig.nmnist_like()
    spikes, labels = synthetic_event_dataset(cfg, n_per_class=4,
                                             key=jax.random.key(0))
    assert spikes.shape == (40, cfg.num_steps, cfg.n_in)
    assert set(labels.tolist()) == set(range(10))
    rate = spikes.mean()
    assert 0.005 < rate < 0.1          # sparse, N-MNIST-like


def test_cifar_like_busier_than_nmnist_like():
    k = jax.random.key(0)
    nm = EventDatasetConfig.nmnist_like()
    cf = EventDatasetConfig.cifar10_dvs_like()
    s1, _ = synthetic_event_dataset(nm, 2, k)
    s2, _ = synthetic_event_dataset(cf, 2, k)
    assert s2.mean() > s1.mean()       # drives Figs 6-7 / Table II contrast


def test_event_batches_time_major():
    cfg = EventDatasetConfig.nmnist_like()
    spikes, labels = synthetic_event_dataset(cfg, 2, jax.random.key(1))
    it = event_batches(spikes, labels, batch=8)
    sb, lb = next(it)
    assert sb.shape == (cfg.num_steps, 8, cfg.n_in)
    assert lb.shape == (8,)


def test_classes_are_distinguishable():
    """The synthetic set must be learnable: per-class mean rate maps differ."""
    cfg = EventDatasetConfig.nmnist_like()
    spikes, labels = synthetic_event_dataset(cfg, 8, jax.random.key(2))
    means = np.stack([spikes[labels == c].mean(axis=(0, 1))
                      for c in range(10)])
    d = np.linalg.norm(means[0] - means[1])
    assert d > 0.05
