"""Tier-1 smoke under ``python -O`` — the assert-stripped interpreter.

``-O`` removes every ``assert`` statement, so any *load-bearing* validation
written as an assert silently vanishes in optimized deployments.  This
script drives the mapping -> memories -> engine chain end to end and checks
that (a) results are still bit-exact and (b) the hardened error paths —
:class:`repro.core.mapping.MappingError` / ``ValueError`` conversions from
PR 7 — still raise with asserts stripped.  pytest is useless here (its own
test asserts would be stripped too); every check below raises a real
exception on failure.

  python -O tools/o_smoke.py
"""

from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402


def check(cond: bool, msg: str) -> None:
    """assert that survives -O."""
    if not cond:
        raise SystemExit(f"o_smoke FAILED: {msg}")


def expect_raises(exc_type, fn, msg: str) -> None:
    try:
        fn()
    except exc_type:
        return
    raise SystemExit(f"o_smoke FAILED: {msg} (no {exc_type.__name__})")


def main() -> None:
    if __debug__:
        print("o_smoke: WARNING — running without -O; the assert-stripping "
              "this script exists to cover is not exercised")

    import dataclasses

    from repro.core.accelerator import map_model, run
    from repro.core.energy import AcceleratorSpec
    from repro.core.layers import Conv2d, as_layer_spec
    from repro.core.mapping import (MappingError, MappingProblem,
                                    autotune_grid, max_flow_assignment,
                                    solve_mapping)
    from repro.engine.batched_run import run_batched

    spec = AcceleratorSpec("osmoke", n_cores=2, n_engines=4, n_caps=8,
                           weight_mem_bytes=1 << 20)
    rng = np.random.default_rng(0)
    w1 = rng.normal(size=(12, 40)) * (rng.random((12, 40)) < 0.5)
    w2 = rng.normal(size=(40, 6)) * (rng.random((40, 6)) < 0.6)

    # 1. the oracle-vs-engine contract holds, compressed and not
    m0 = map_model([w1, w2], spec)
    m1 = map_model([w1, w2], spec, compress=True)
    spikes = (rng.random((2, 5, 12)) < 0.3).astype(np.float32)
    r0 = run_batched(m0, spikes)
    r1 = run_batched(m1, spikes)
    check(np.array_equal(r0.out_spikes, r1.out_spikes),
          "compressed engine != uncompressed engine")
    oracle = run(m1, spikes[0])
    check(np.array_equal(np.asarray(oracle.out_spikes), r1.out_spikes[0]),
          "oracle != engine under -O")
    check(sum(l.sram_bytes for l in m1.layers)
          < sum(l.sram_bytes for l in m0.layers),
          "compression did not shrink allocated words")

    # 2. hardened error paths fire with asserts stripped
    tiny = AcceleratorSpec("tiny", n_cores=1, n_engines=4, n_caps=4,
                           weight_mem_bytes=2)
    expect_raises(MappingError, lambda: map_model([w1[:, :16]], tiny),
                  "SRAM overflow must raise MappingError under -O")
    expect_raises(ValueError,
                  lambda: as_layer_spec(rng.normal(size=(2, 2, 3, 3))),
                  "4-D bare array must raise ValueError under -O")
    expect_raises(ValueError,
                  lambda: Conv2d(kernel=np.zeros((2, 3, 3, 3)),
                                 in_shape=(1, 6, 6)),
                  "channel mismatch must raise ValueError under -O")
    expect_raises(ValueError, lambda: map_model([w1, w1], spec),
                  "chain-shape mismatch must raise ValueError under -O")

    conn = np.ones((2, 4), dtype=bool)
    prob = MappingProblem(n_dest=4, n_engines=2, n_caps=2, conn=conn,
                          fanout=np.full(2, 4))
    sol = solve_mapping(prob, method="reduced_ilp")
    sol.check(prob)
    bad = dataclasses.replace(sol, n_assigned=sol.n_assigned + 1)
    expect_raises(MappingError, lambda: bad.check(prob),
                  "corrupt solution must raise MappingError under -O")
    tight = MappingProblem(n_dest=4, n_engines=2, n_caps=2, conn=conn,
                           fanout=np.full(2, 1))
    expect_raises(MappingError, lambda: max_flow_assignment(tight),
                  "max-flow without fan-out slack must raise under -O")

    # 3. the autotuner's no-regression guarantee holds under -O
    res = autotune_grid([w1, w2], spec)
    check(res.best.rounds_per_timestep <= res.default.rounds_per_timestep,
          "autotuner regressed rounds-per-timestep")

    print("o_smoke: OK (__debug__ =", __debug__, ")")


if __name__ == "__main__":
    main()
