"""Chaos scenarios for the always-on serving loop, replayable bit-for-bit.

MENAGE's pitch is an always-on edge accelerator; an always-on server earns
that claim by surviving the failure modes the paper's substrate implies, not
just a Poisson replay.  This module is the scenario layer the soak harness
(``benchmarks/soak_bench.py``) and the tier-1 suite (``tests/test_chaos.py``)
share:

  * :func:`synth_arrival_trace` — the arrival processes.  Beyond the
    ``poisson`` baseline and the ``bursty`` batch-formation stressor, it
    adds ``diurnal`` (sinusoidally-modulated offered load — the day/night
    swing an edge deployment actually sees) and ``adversarial`` (alternating
    flood/famine phases with tight deadlines on the floods and a lone
    long request per famine — engineered to leave partial buckets behind
    and force deadline-triggered dispatches at worst-case moments).
  * :class:`ChaosScenario` + :data:`SCENARIOS` — named, fully-parameterized
    failure scripts: device loss mid-serving (via :func:`make_chaos_hook`
    raising :class:`~repro.engine.sharded_run.DeviceLossError` at scripted
    dispatch ordinals), serving-time analog noise
    (:class:`~repro.core.noise.AnalogNoise` through the server's shadow
    probes), SLO-driven shed-vs-extend switching
    (:class:`~repro.engine.stream_server.SLOPolicy`), and the combined
    ``blackout`` scenario that fires all of them in one run.
  * :func:`run_scenario` — one scenario end-to-end on a
    :class:`~repro.engine.stream_server.VirtualClock` with a constant
    simulated service time: **every** number in the returned metrics is
    derived from counters and simulated time, so a scenario replays
    deterministically — the soak logic is tier-1 testable with zero
    wall-clock flakiness, and the live soak harness runs the *same*
    scripts against a real socket.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.noise import AnalogNoise
from repro.engine import batched_run as br
from repro.engine.registry import ModelRegistry
from repro.engine.serving import BucketPolicy
from repro.engine.sharded_run import DeviceLossError
from repro.engine.stream_server import (SLOPolicy, StreamServer, VirtualClock,
                                        serve_trace)


# ----------------------------------------------------------- arrival synth

def synth_arrival_trace(n: int, n_in: int, *, mode: str = "poisson",
                        rate: float = 200.0, burst: int = 6,
                        t_lo: int = 4, t_hi: int = 30,
                        spike_p: float = 0.15, slack: float = 0.25,
                        period: float = 1.0, depth: float = 0.9,
                        seed: int = 0) -> list[tuple[float, np.ndarray, float]]:
    """A time-stamped arrival process for the async server: ``n`` requests
    as ``(arrival_t, stream, deadline)`` tuples, non-decreasing in time.

    ``poisson`` draws i.i.d. exponential interarrivals at ``rate`` req/s —
    the memoryless baseline.  ``bursty`` emits back-to-back bursts of
    ``burst`` simultaneous requests with exponential gaps between bursts at
    the *same* mean offered load — the adversarial case for batch
    formation, where a deadline-blind scheduler would sit on partial
    buckets.  ``diurnal`` modulates the instantaneous rate sinusoidally
    (``rate * (1 + depth * sin(2*pi*t / period))``, floored at 5% of
    ``rate``): sustained peaks that probe queue growth and troughs that
    probe deadline-forced partial dispatch.  ``adversarial`` alternates
    flood phases — ``burst - 1`` simultaneous *short* requests with
    quarter ``slack`` — with famine phases of a single long request after
    a dead gap: floods race tight deadlines, famines strand lone requests
    in otherwise-empty buckets, and the length split scatters the queue
    across time buckets.  Deadlines are ``arrival + slack`` seconds except
    where noted."""
    rng = np.random.default_rng(seed)
    slacks: list[float] | None = None
    if mode == "poisson":
        lengths = rng.integers(t_lo, t_hi + 1, size=n)
        times = np.cumsum(rng.exponential(1.0 / rate, size=n))
    elif mode == "bursty":
        lengths = rng.integers(t_lo, t_hi + 1, size=n)
        n_bursts = -(-n // burst)
        starts = np.cumsum(rng.exponential(burst / rate, size=n_bursts))
        times = np.repeat(starts, burst)[:n]
    elif mode == "diurnal":
        lengths = rng.integers(t_lo, t_hi + 1, size=n)
        ts, t = [], 0.0
        for _ in range(n):
            r = max(rate * (1.0 + depth * math.sin(2 * math.pi * t / period)),
                    0.05 * rate)
            t += float(rng.exponential(1.0 / r))
            ts.append(t)
        times = np.asarray(ts)
    elif mode == "adversarial":
        ts, ls, sl, t = [], [], [], 0.0
        while len(ts) < n:
            for _ in range(max(burst - 1, 1)):          # flood: short + tight
                if len(ts) >= n:
                    break
                ts.append(t)
                ls.append(t_lo)
                sl.append(slack * 0.25)
            t += 4.0 * burst / rate                     # dead gap
            if len(ts) < n:                             # famine: lone + long
                ts.append(t)
                ls.append(t_hi)
                sl.append(slack)
            t += float(rng.exponential(burst / rate))
        times, lengths, slacks = np.asarray(ts), np.asarray(ls), sl
    else:
        raise ValueError(f"unknown arrival mode {mode!r} "
                         "(poisson|bursty|diurnal|adversarial)")
    if slacks is None:
        slacks = [slack] * n
    return [(float(t_a),
             (rng.random((int(t_len), n_in)) < spike_p).astype(np.float32),
             float(t_a) + s)
            for t_a, t_len, s in zip(times, lengths, slacks)]


ARRIVAL_MODES = ("poisson", "bursty", "diurnal", "adversarial")


# ------------------------------------------------------------- chaos hooks

def make_chaos_hook(lose_devices):
    """A dispatch-boundary failure injector: ``lose_devices`` is a sequence
    of ``(dispatch_ordinal, n_lost)`` pairs; the hook raises
    :class:`DeviceLossError` the first time the server reaches each
    scripted ordinal (and never again for that ordinal, so the recovery
    retry proceeds) — the serving analogue of the ``failure_hook`` the
    train loop's elastic-restart tests inject."""
    pending = dict(lose_devices)

    def hook(dispatch_ordinal: int) -> None:
        n = pending.pop(dispatch_ordinal, None)
        if n:
            raise DeviceLossError(
                n, f"chaos injection at dispatch {dispatch_ordinal}")

    return hook


# -------------------------------------------------------------- scenarios

@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant of a multi-tenant scenario: its own arrival process and
    deadline profile on the shared fabric.  ``seed_offset`` decorrelates
    the tenant's trace from its neighbours under the scenario seed;
    ``weight`` is its weighted-fair scheduling share."""

    name: str
    arrivals: str = "poisson"
    n_requests: int = 24
    rate: float = 200.0
    slack: float = 0.25
    t_lo: int = 3
    t_hi: int = 12
    weight: float = 1.0
    seed_offset: int = 0


@dataclasses.dataclass(frozen=True)
class ChaosScenario:
    """One named failure script for the always-on server.  Every field is
    plain data, so a scenario is reproducible from its definition alone;
    ``needs_mesh`` marks scripts that only make sense with >= 2 devices
    (device loss on a 1-device mesh has nothing to recover onto)."""

    name: str
    description: str
    arrivals: str = "poisson"
    n_requests: int = 32
    rate: float = 200.0
    slack: float = 0.25
    t_lo: int = 3
    t_hi: int = 12
    noise_sigma: float = 0.0            # serving-time C2C gain error
    noise_probe_every: int = 1          # shadow-probe cadence (dispatches)
    lose_devices: tuple[tuple[int, int], ...] = ()  # (dispatch_idx, n_lost)
    slo: SLOPolicy | None = None
    backpressure: str = "reject"
    overlong: str = "extend"
    queue_capacity: int = 256
    service_s: float = 0.002            # simulated seconds per engine call
    seed: int = 0
    # multi-tenant scripts: each TenantSpec serves the scenario model as its
    # own registry entry (own bucket policy, own arrival process); an empty
    # tuple is the single-tenant fast path.  swap_tenant/swap_at script a
    # mid-soak hot-swap: at simulated time swap_at the named tenant's
    # weights are replaced by a deterministically perturbed instance
    # (swap_sigma C2C gain error on the base model) — same shapes,
    # different bits, fully reproducible from the scenario seed.
    tenants: tuple[TenantSpec, ...] = ()
    swap_tenant: str | None = None
    swap_at: float = 0.08               # simulated seconds into the soak
    swap_sigma: float = 0.2

    @property
    def needs_mesh(self) -> bool:
        return bool(self.lose_devices)


SCENARIOS: dict[str, ChaosScenario] = {s.name: s for s in (
    ChaosScenario(
        name="baseline",
        description="Poisson arrivals, no faults — the control run every "
                    "chaos metric is read against."),
    ChaosScenario(
        name="diurnal",
        description="Sinusoidally-modulated offered load: peak pressure on "
                    "the queue, trough pressure on deadline-forced partial "
                    "dispatch.",
        arrivals="diurnal", n_requests=48, rate=400.0, slack=0.1),
    ChaosScenario(
        name="adversarial",
        description="Flood/famine arrival pattern engineered against batch "
                    "formation: tight-deadline floods, stranded lone "
                    "requests, lengths scattered across time buckets.",
        arrivals="adversarial", n_requests=48, rate=300.0, slack=0.2),
    ChaosScenario(
        name="device_loss",
        description="Lose a device at the 2nd dispatch mid-serving; the "
                    "server must recover onto the shrunken mesh with zero "
                    "requests lost.",
        n_requests=32, lose_devices=((1, 1),)),
    ChaosScenario(
        name="analog_noise",
        description="Serve through one noisy device instance (5% C2C gain "
                    "error) with a shadow probe every dispatch: "
                    "accuracy-under-noise lands in the metrics.",
        noise_sigma=0.05, noise_probe_every=1),
    ChaosScenario(
        name="slo_shed",
        description="Offered load beyond capacity with tight deadlines and "
                    "an SLO controller: the server must flip to shedding "
                    "when the windowed miss rate breaches target, and flip "
                    "back once load drains.",
        arrivals="bursty", n_requests=64, rate=2000.0, slack=0.02,
        service_s=0.008, queue_capacity=8,
        slo=SLOPolicy(target_miss_rate=0.2, window=16, min_samples=4)),
    ChaosScenario(
        name="blackout",
        description="The acceptance combo: adversarial arrivals + device "
                    "loss mid-serving + serving-time analog noise + SLO "
                    "shedding, all in one run — the server must end the "
                    "trace recovered, with deadline-miss and "
                    "accuracy-under-noise metrics populated.",
        arrivals="adversarial", n_requests=48, rate=300.0, slack=0.2,
        noise_sigma=0.05, noise_probe_every=2, lose_devices=((2, 1),),
        slo=SLOPolicy(target_miss_rate=0.5, window=16, min_samples=8)),
    ChaosScenario(
        name="multi_tenant",
        description="Two tenants share one fabric: a steady Poisson tenant "
                    "with real deadlines next to an adversarial flood "
                    "tenant, plus a mid-soak hot-swap of the steady "
                    "tenant's weights.  Weighted-fair scheduling must keep "
                    "the flood from starving the steady tenant's deadlines, "
                    "and the swap must drain on the old weights with zero "
                    "requests lost.",
        tenants=(TenantSpec(name="steady", arrivals="poisson",
                            n_requests=24, rate=150.0, slack=0.25,
                            seed_offset=1),
                 TenantSpec(name="bursty", arrivals="adversarial",
                            n_requests=32, rate=400.0, slack=0.2,
                            seed_offset=2)),
        swap_tenant="steady", swap_at=0.08),
)}


def run_scenario(model, scenario: ChaosScenario, *, mesh=None,
                 policy: BucketPolicy | None = None, recorder=None):
    """Replay one scenario deterministically on a :class:`VirtualClock`.

    The server's service times come from the scenario's constant
    ``service_s`` (grounding the discrete-event simulation), arrivals from
    :func:`synth_arrival_trace` under the scenario seed, and faults from
    the scenario script — so two runs of the same scenario produce
    bit-identical results and metrics (tested).  Returns ``(results, rids,
    metrics)`` where ``metrics`` is the ``ServerMetrics`` snapshot plus
    scenario bookkeeping (name, mesh sizes, makespan, admitted-served
    accounting).

    ``recorder`` (a :class:`~repro.engine.tracing.FlightRecorder`) attaches
    the span tracer to the replay: every injected fault then lands as a
    typed anomaly and, because the replay runs on a VirtualClock, two
    replays of the same scenario produce byte-identical
    ``recorder.dump_json()`` — the soak harness's determinism gate."""
    packed = model if isinstance(model, br.PackedModel) else model.pack()
    if scenario.needs_mesh:
        assert mesh is not None and mesh.size >= 2, \
            f"scenario {scenario.name!r} scripts device loss — run it on a " \
            f">= 2-device mesh (--spoof-devices N on CPU)"
    if scenario.tenants:
        return _run_multi_tenant(packed, scenario, mesh=mesh, policy=policy,
                                 recorder=recorder)
    trace = synth_arrival_trace(
        scenario.n_requests, packed.n_in, mode=scenario.arrivals,
        rate=scenario.rate, slack=scenario.slack, t_lo=scenario.t_lo,
        t_hi=scenario.t_hi, seed=scenario.seed)
    if policy is None:
        n_shards = mesh.size if mesh is not None else 1
        policy = BucketPolicy.covering([s.shape[0] for _, s, _ in trace],
                                       n_shards=n_shards,
                                       max_batch=4 * n_shards)
    noise = (AnalogNoise(weight_sigma=scenario.noise_sigma)
             if scenario.noise_sigma > 0 else None)
    server = StreamServer(
        packed, policy=policy, mesh=mesh, clock=VirtualClock(),
        queue_capacity=scenario.queue_capacity,
        backpressure=scenario.backpressure, overlong=scenario.overlong,
        service_model=lambda b, t: scenario.service_s,
        noise=noise, noise_key=scenario.seed,
        noise_probe_every=scenario.noise_probe_every, slo=scenario.slo,
        chaos_hook=(make_chaos_hook(scenario.lose_devices)
                    if scenario.lose_devices else None),
        tracer=recorder)
    results, rids = serve_trace(server, trace)
    snap = server.metrics.snapshot()
    snap.update({
        "scenario": scenario.name,
        "requests": len(trace),
        "served_all_admitted": snap["completed"] == snap["admitted"],
        "mesh_size_start": mesh.size if mesh is not None else 1,
        "mesh_size_end": (server.mesh.size if server.mesh is not None
                          else 1),
        "makespan_s": server.now(),
    })
    return results, rids, snap


def swap_model_for(packed, scenario: ChaosScenario):
    """The weights a multi-tenant scenario hot-swaps in at ``swap_at``: one
    deterministic perturbed instance of the base model (same shapes —
    same-shape swaps add no jit traces — different bits, reproducible from
    the scenario seed alone).  Exposed so tests and the soak bench can
    verify post-swap results bit-exact against the exact swapped model."""
    from repro.core.noise import as_noise_key, perturb_packed
    return perturb_packed(as_noise_key(scenario.seed + 7919), packed,
                          AnalogNoise(weight_sigma=scenario.swap_sigma))


def _run_multi_tenant(packed, scenario: ChaosScenario, *, mesh,
                      policy: BucketPolicy | None, recorder=None):
    """The multi-tenant leg of :func:`run_scenario`: every tenant serves
    the scenario model as its own registry entry (per-tenant covering
    bucket policy), the merged per-tenant traces replay on one fabric, and
    ``swap_tenant`` is hot-swapped to :func:`swap_model_for`'s weights at
    ``swap_at`` via a serve_trace control event."""
    n_shards = mesh.size if mesh is not None else 1
    registry = ModelRegistry()
    tagged = []
    for spec in scenario.tenants:
        trace = synth_arrival_trace(
            spec.n_requests, packed.n_in, mode=spec.arrivals, rate=spec.rate,
            slack=spec.slack, t_lo=spec.t_lo, t_hi=spec.t_hi,
            seed=scenario.seed + spec.seed_offset)
        p = policy if policy is not None else BucketPolicy.covering(
            [s.shape[0] for _, s, _ in trace], n_shards=n_shards,
            max_batch=4 * n_shards)
        registry.register(spec.name, packed, policy=p, weight=spec.weight)
        tagged.extend((t, s, d, spec.name) for t, s, d in trace)
    tagged.sort(key=lambda e: e[0])     # stable: ties keep tenant order
    control = []
    if scenario.swap_tenant is not None:
        swapped = swap_model_for(packed, scenario)
        control.append((scenario.swap_at,
                        lambda srv: srv.swap(scenario.swap_tenant, swapped)))
    server = StreamServer(
        registry, mesh=mesh, clock=VirtualClock(),
        queue_capacity=scenario.queue_capacity,
        backpressure=scenario.backpressure, overlong=scenario.overlong,
        service_model=lambda b, t: scenario.service_s,
        noise_probe_every=scenario.noise_probe_every, slo=scenario.slo,
        chaos_hook=(make_chaos_hook(scenario.lose_devices)
                    if scenario.lose_devices else None),
        tracer=recorder)
    results, rids = serve_trace(server, tagged, control=control)
    snap = server.metrics.snapshot()
    snap.update({
        "scenario": scenario.name,
        "requests": len(tagged),
        "served_all_admitted": snap["completed"] == snap["admitted"],
        "mesh_size_start": mesh.size if mesh is not None else 1,
        "mesh_size_end": (server.mesh.size if server.mesh is not None
                          else 1),
        "makespan_s": server.now(),
    })
    return results, rids, snap
