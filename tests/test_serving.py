"""Continuous-batching front end: bucket policy, scheduler, and the
per-request bit-exactness + jit-cache-stability contracts.

The two serving guarantees under test (engine/serving.py):

  * every request's result — spikes, per-step DispatchStats, utilization,
    overflow, energy — is bit-identical to running that request alone on
    the numpy oracle, despite batch/time padding; and
  * a stream of mixed-shape requests costs at most ``policy.n_buckets``
    jit traces (the cache-churn regression), and a second stream hitting
    the same buckets costs zero.
"""

import numpy as np
import pytest

from repro.core.accelerator import map_model, run
from repro.core.energy import AcceleratorSpec
from repro.core.layers import Conv2d, Dense, SumPool2d
from repro.core.lif import LIFParams
from repro.engine import (METRIC_KEYS, BucketPolicy, OverlongRequestError,
                          ServerMetrics, StreamServer, TELEMETRY_KEYS,
                          VirtualClock, plan_batches, run_bucketed,
                          trace_count)
from repro.engine.serving import BatchPlan

SPEC = AcceleratorSpec("serve-test", n_cores=3, n_engines=4, n_caps=8,
                       weight_mem_bytes=1 << 18)


def _dense_model(rng, sizes=(14, 12, 6), density=0.6):
    ws = []
    for i in range(len(sizes) - 1):
        w = rng.normal(0, 0.5, (sizes[i], sizes[i + 1])).astype(np.float32)
        w[rng.random(w.shape) > density] = 0
        ws.append(w)
    return map_model(ws, SPEC, lif=LIFParams(beta=0.8, threshold=0.7))


def _conv_model(rng):
    k = rng.normal(0, 0.8, (2, 1, 3, 3)).astype(np.float32)
    k[rng.random(k.shape) > 0.6] = 0
    conv = Conv2d(kernel=k, in_shape=(1, 6, 6), stride=1, padding=1)
    pool = SumPool2d(conv.out_shape, 2)
    head = rng.normal(0, 0.5, (int(np.prod(pool.out_shape)), 5)) \
        .astype(np.float32)
    return map_model([conv, pool, Dense(w=head)], SPEC,
                     lif=LIFParams(beta=0.8, threshold=0.7))


def _streams(rng, n_in, lengths, p=0.35):
    return [(rng.random((t, n_in)) < p).astype(np.float32) for t in lengths]


def _assert_request_matches_oracle(req, model, stream, max_events=None):
    oracle = run(model, stream, max_events=max_events)
    np.testing.assert_array_equal(req.out_spikes, oracle.out_spikes)
    for li, (a, b) in enumerate(zip(req.stats, oracle.per_layer_stats)):
        for f in ("cycles", "rows_touched", "engine_ops", "events",
                  "sn_bytes_touched"):
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                          err_msg=f"layer {li} {f}")
        assert a.mem_e_peak == b.mem_e_peak, f"layer {li} mem_e_peak"
        np.testing.assert_array_equal(req.util[li],
                                      oracle.per_layer_util[li])
        np.testing.assert_array_equal(req.overflow[li], oracle.overflow[li])
    assert req.energy() == oracle.energy


# ------------------------------------------------------------------ policy

def test_policy_bucket_selection():
    p = BucketPolicy(batch_sizes=(1, 4, 16), time_steps=(8, 16, 32))
    assert p.t_bucket(1) == 8 and p.t_bucket(8) == 8 and p.t_bucket(9) == 16
    assert p.t_bucket(32) == 32
    with pytest.raises(ValueError, match="exceeds the largest time bucket"):
        p.t_bucket(33)
    assert p.b_bucket(1) == 1 and p.b_bucket(2) == 4 and p.b_bucket(16) == 16
    assert p.max_batch == 16 and p.n_buckets == 9


def test_policy_validation():
    with pytest.raises(AssertionError):
        BucketPolicy(batch_sizes=(4, 1), time_steps=(8,))
    with pytest.raises(AssertionError):
        BucketPolicy(batch_sizes=(1,), time_steps=())


def test_policy_for_mesh_divisibility():
    p = BucketPolicy.for_mesh(3, batch_sizes=(1, 4, 16))
    assert all(b % 3 == 0 for b in p.batch_sizes)


def test_policy_covering():
    p = BucketPolicy.covering([3, 17, 9], n_shards=2, max_batch=8)
    assert p.time_steps[-1] >= 17
    assert all(b % 2 == 0 for b in p.batch_sizes)
    assert p.max_batch >= 8


def test_policy_fits_and_extension():
    p = BucketPolicy(batch_sizes=(1, 4), time_steps=(8, 16))
    assert p.fits(16) and not p.fits(17) and not p.fits(0)
    assert p.with_time_bucket(12) is p            # already covered
    q = p.with_time_bucket(40)                    # 16 -> 32 -> 64
    assert q.time_steps == (8, 16, 64) and q.fits(40)
    assert p.time_steps == (8, 16)                # original untouched


# --------------------------------------------------------------- scheduler

def test_plan_batches_partitions_all_requests():
    policy = BucketPolicy(batch_sizes=(1, 2, 4), time_steps=(4, 8))
    lengths = [3, 7, 5, 8, 2, 8, 1, 4, 6, 8, 8]
    plans = plan_batches(lengths, policy)
    seen = [i for p in plans for i in p.indices]
    assert sorted(seen) == list(range(len(lengths)))
    for p in plans:
        assert p.b_pad in policy.batch_sizes and p.t_pad in policy.time_steps
        assert len(p.indices) <= p.b_pad
        for i in p.indices:
            assert lengths[i] <= p.t_pad


def test_plan_batches_chunks_at_max_batch():
    policy = BucketPolicy(batch_sizes=(2,), time_steps=(8,))
    plans = plan_batches([5] * 7, policy)
    assert [len(p.indices) for p in plans] == [2, 2, 2, 1]
    assert all(p.b_pad == 2 for p in plans)


def test_plan_batches_deterministic():
    policy = BucketPolicy(batch_sizes=(1, 4), time_steps=(4, 16))
    lengths = [10, 2, 16, 4, 9, 1]
    assert plan_batches(lengths, policy) == plan_batches(lengths, policy)
    assert plan_batches(lengths, policy)[0] == BatchPlan(
        indices=(1, 3, 5), b_pad=4, t_pad=4)


# -------------------------------------------------- per-request equivalence

def test_bucketed_matches_oracle_dense(rng):
    model = _dense_model(rng)
    streams = _streams(rng, 14, [3, 7, 5, 8, 2, 8, 1])
    res = run_bucketed(model, streams,
                       policy=BucketPolicy(batch_sizes=(1, 2, 4),
                                           time_steps=(4, 8)))
    for req, s in zip(res, streams):
        _assert_request_matches_oracle(req, model, s)


def test_bucketed_matches_oracle_conv(rng):
    model = _conv_model(rng)
    n_in = model.layers[0].n_src
    streams = _streams(rng, n_in, [2, 6, 4, 5], p=0.25)
    res = run_bucketed(model, streams,
                       policy=BucketPolicy(batch_sizes=(2, 4),
                                           time_steps=(4, 8)))
    for req, s in zip(res, streams):
        _assert_request_matches_oracle(req, model, s)


def test_bucketed_max_events_cap(rng):
    """The MEM_E cap threads through padding: overflow counts and truncated
    downstream spikes still match the oracle under the same cap."""
    model = _dense_model(rng, density=0.9)
    streams = _streams(rng, 14, [3, 6, 5], p=0.7)
    res = run_bucketed(model, streams, max_events=2,
                       policy=BucketPolicy(batch_sizes=(4,), time_steps=(8,)))
    for req, s in zip(res, streams):
        _assert_request_matches_oracle(req, model, s, max_events=2)
        assert sum(o.sum() for o in req.overflow) > 0


def test_bucketed_empty_and_single(rng):
    model = _dense_model(rng)
    assert run_bucketed(model, []) == []
    streams = _streams(rng, 14, [5])
    req = run_bucketed(model, streams)[0]
    _assert_request_matches_oracle(req, model, streams[0])


def test_bucketed_without_stats(rng):
    model = _dense_model(rng)
    streams = _streams(rng, 14, [4, 9])
    res = run_bucketed(model, streams, with_stats=False,
                       policy=BucketPolicy(batch_sizes=(2,),
                                           time_steps=(4, 16)))
    for req, s in zip(res, streams):
        assert req.stats == [] and req.util == []
        np.testing.assert_array_equal(req.out_spikes,
                                      run(model, s).out_spikes)


def test_bucketed_telemetry(rng):
    model = _dense_model(rng)
    streams = _streams(rng, 14, [4, 9, 3])
    telemetry = []
    run_bucketed(model, streams, telemetry=telemetry,
                 policy=BucketPolicy(batch_sizes=(2,), time_steps=(4, 16)))
    assert len(telemetry) == 2
    assert sum(t["n_requests"] for t in telemetry) == 3
    assert sum(t["events"] for t in telemetry) \
        == int(sum((s > 0).sum() for s in streams))


# -------------------------------------------------- metrics schema locks

def test_telemetry_schema_locked(rng):
    """The per-engine-call telemetry record keys are a dashboard contract
    (BENCH_serving.json): adding/renaming fields must update TELEMETRY_KEYS
    and this test together.  ``seq``/``ts`` make records shared through one
    ``telemetry=`` list self-ordering across dispatch rounds."""
    assert TELEMETRY_KEYS == ("seq", "ts", "b_pad", "t_pad", "n_requests",
                              "events", "out_spikes", "seconds")
    model = _dense_model(rng)
    telemetry = []
    run_bucketed(model, _streams(rng, 14, [4, 9]), telemetry=telemetry,
                 policy=BucketPolicy(batch_sizes=(2,), time_steps=(4, 16)))
    for t in telemetry:
        assert tuple(t.keys()) == TELEMETRY_KEYS
    # per-call monotonic ordinals
    assert [t["seq"] for t in telemetry] == list(range(len(telemetry)))
    # the async server emits the same records, stamped with its clock
    server = StreamServer(model, clock=VirtualClock(),
                          policy=BucketPolicy(batch_sizes=(2,),
                                              time_steps=(4, 16)))
    server.submit(_streams(rng, 14, [4])[0])
    server.flush()
    rec = server.telemetry[0]
    assert tuple(rec.keys()) == TELEMETRY_KEYS
    assert rec["seq"] == 0 and rec["ts"] == 0.0  # VirtualClock dispatch time


def test_server_metrics_schema_locked():
    """ServerMetrics.snapshot() keys are the BENCH_async_serving.json
    surface — locked so dashboards don't silently break.  ``p50/p99_*``
    come from lifetime cumulative histograms; the windowed deque values
    survive under the explicit ``recent_*`` keys."""
    assert METRIC_KEYS == (
        "submitted", "admitted", "rejected", "shed", "completed",
        "deadline_misses", "deadline_miss_rate", "dispatches",
        "forced_dispatches", "policy_extensions", "queue_depth",
        "max_queue_depth", "bucket_fill_ratio", "p50_ttfd_s", "p99_ttfd_s",
        "p50_latency_s", "p99_latency_s", "recent_p50_ttfd_s",
        "recent_p99_ttfd_s", "recent_p50_latency_s", "recent_p99_latency_s",
        "device_losses", "slo_switches", "slo_shedding", "noise_probes",
        "noise_agreement", "models", "hot_swaps", "per_model")
    snap = ServerMetrics().snapshot()
    assert tuple(snap.keys()) == METRIC_KEYS
    assert snap["deadline_miss_rate"] == 0.0      # no div-by-zero when idle
    assert snap["noise_agreement"] == 1.0         # no probes = no evidence
    assert snap["per_model"] == {} and snap["models"] == 0
    assert snap["p50_latency_s"] == 0.0 and snap["recent_p99_ttfd_s"] == 0.0


# ------------------------------------------------- over-long requests

def test_bucketed_overlong_error_names_requests(rng):
    """An over-long request fails at admission with a per-request error,
    not mid-plan after other requests already ran."""
    model = _dense_model(rng)
    streams = _streams(rng, 14, [4, 40, 3, 99])
    policy = BucketPolicy(batch_sizes=(2,), time_steps=(4, 8))
    with pytest.raises(OverlongRequestError) as ei:
        run_bucketed(model, streams, policy=policy)
    assert ei.value.requests == [(1, 40), (3, 99)]
    assert "request 1: 40 steps" in str(ei.value)


def test_bucketed_overlong_extend_matches_oracle(rng):
    model = _dense_model(rng)
    streams = _streams(rng, 14, [4, 40, 3])
    policy = BucketPolicy(batch_sizes=(2,), time_steps=(4, 8))
    res = run_bucketed(model, streams, policy=policy, overlong="extend")
    for req, s in zip(res, streams):
        _assert_request_matches_oracle(req, model, s)


# ------------------------------------------------- jit-cache churn (bugfix)

def test_mixed_shape_stream_bounded_traces(rng):
    """The regression the bucketing layer fixes: a stream of requests with
    many distinct (B, T) shapes must cost at most n_buckets traces, and a
    second mixed stream hitting the same buckets must cost zero."""
    model = _dense_model(rng)
    packed = model.pack()
    policy = BucketPolicy(batch_sizes=(2, 4), time_steps=(4, 8, 16))
    lengths_a = [1, 2, 3, 5, 7, 9, 11, 13, 15, 16, 4, 8]
    lengths_b = [16, 1, 6, 10, 2, 12, 3, 14]
    assert len(set(lengths_a)) > policy.n_buckets // 2   # genuinely mixed
    n0 = trace_count()
    run_bucketed(packed, _streams(rng, 14, lengths_a), policy=policy)
    run_bucketed(packed, _streams(rng, 14, lengths_b), policy=policy)
    total = trace_count() - n0
    assert 0 < total <= policy.n_buckets, \
        f"{total} traces for {len(lengths_a) + len(lengths_b)} " \
        f"mixed-shape requests > {policy.n_buckets} buckets"
    n1 = trace_count()
    run_bucketed(packed, _streams(rng, 14, lengths_b), policy=policy)
    run_bucketed(packed, _streams(rng, 14, lengths_a), policy=policy)
    assert trace_count() == n1, "repeat streams retraced the jit"


def test_request_shape_validation(rng):
    model = _dense_model(rng)
    with pytest.raises(AssertionError, match="expected \\[T, 14\\]"):
        run_bucketed(model, [np.zeros((4, 9), np.float32)])
