"""§Roofline: read the dry-run JSONs and emit the per-(arch x shape) table —
three terms, dominant bottleneck, MODEL_FLOPS/HLO_FLOPS ratio, and a
one-line lever per cell."""

from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.launch.hlo_analysis import PEAK_FLOPS_BF16


def count_params(cfg) -> tuple[float, float]:
    """(total, active) parameter counts from the config arithmetic."""
    d, hd = cfg.d_model, cfg.resolved_head_dim()
    v = cfg.vocab_size
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * d
        h = d_in // cfg.ssm_head_dim
        n = cfg.ssm_state
        per = d * (2 * d_in + 2 * n + h) + cfg.ssm_conv_width * d_in \
            + d_in * d + 3 * h + d_in + d
        total = cfg.n_layers * per + 2 * v * d
        return total, total
    attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
    if cfg.n_experts:
        ff_total = cfg.n_experts * 3 * d * cfg.d_ff + d * cfg.n_experts
        ff_active = cfg.top_k * 3 * d * cfg.d_ff + d * cfg.n_experts
    else:
        ff_total = ff_active = 3 * d * cfg.d_ff
    if cfg.family == "encdec":
        enc = cfg.n_encoder_layers * (attn + 2 * d * cfg.d_ff)
        dec = cfg.n_layers * (2 * attn + 2 * d * cfg.d_ff)
        total = enc + dec + v * d
        return total, total
    if cfg.family == "hybrid":
        d_in = cfg.ssm_expand * d
        h = d_in // cfg.ssm_head_dim
        per_ssm = d * (2 * d_in + 2 * cfg.ssm_state + h) \
            + cfg.ssm_conv_width * d_in + d_in * d
        shared = attn + ff_total
        total = cfg.n_layers * per_ssm + shared + 2 * v * d
        return total, total
    per_layer = attn + ff_total
    per_active = attn + ff_active
    total = cfg.n_layers * per_layer + 2 * v * d
    active = cfg.n_layers * per_active + 2 * v * d
    return total, active


def model_flops_for(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    total, active = count_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    # decode: one token per sequence
    return 2.0 * active * shape.global_batch


def load_table(out_dir: str = "results/dryrun", tag: str = "pod",
               suffix: str = "") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, tag, f"*{suffix}.json"))):
        base = os.path.basename(path)[:-5]
        if suffix and not base.endswith(suffix):
            continue
        if not suffix and ("_sp_" in base or base.endswith("_sp")
                           or "_opt" in base):
            continue
        with open(path) as f:
            rec = json.load(f)
        r = rec["roofline"]
        arch, shape = rec["arch"], rec["shape"]
        mf = model_flops_for(arch, shape)
        n_dev = r["n_devices"]
        hlo_total = r["hlo_flops"] * n_dev
        rows.append({
            "arch": arch, "shape": shape, "kind": rec["kind"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "model_flops": mf, "hlo_flops_total": hlo_total,
            "useful_ratio": mf / hlo_total if hlo_total else 0.0,
            "step_s": r["step_time_s"],
            "mfu_bound": (mf / n_dev / PEAK_FLOPS_BF16) / r["step_time_s"]
            if r["step_time_s"] else 0.0,
        })
    return rows


def main():
    # canonical = the optimized framework's sweep; fall back to the baseline
    # sweep dir if final results are absent
    out_dir = "results/final" if os.path.isdir("results/final/pod") \
        else "results/dryrun"
    rows = load_table(out_dir)
    print("roofline: arch,shape,compute_ms,memory_ms,collective_ms,dominant,"
          "useful_ratio,mfu_bound")
    for r in rows:
        print(f"roofline/{r['arch']}/{r['shape']},"
              f"{r['compute_s']*1e3:.2f},{r['memory_s']*1e3:.2f},"
              f"{r['collective_s']*1e3:.2f},{r['dominant']},"
              f"{r['useful_ratio']:.3f},{r['mfu_bound']:.3f}")
    # dry-run coverage summary (deliverable e): both production meshes
    for tag in ("pod", "multipod"):
        n = len(glob.glob(os.path.join(out_dir, tag, "*.json")))
        print(f"dryrun/{tag},cells={n},expected=34")


if __name__ == "__main__":
    main()
