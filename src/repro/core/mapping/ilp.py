"""ILP-based mapping of destination-layer neurons onto A-NEURON capacitors.

Paper §III-D, eqs. (3)-(7):

  variables   x_{i,j,k} ∈ {0,1}   — neuron i → capacitor k of A-NEURON j   (3)
  objective   min Σ_{i,j,k} (1 - x_{i,j,k})   ≡   max Σ x  (assigned count) (4)
  (5) engine capacity:       Σ_{i,k} x_{i,j,k} ≤ N            ∀ j
  (6) unique assignment:     Σ_{j,k} x_{i,j,k} ≤ 1            ∀ i
  (7) source fan-out:        Σ_{i∈S_m} Σ_{j,k} x_{i,j,k} ≤ fanout_m  ∀ m

Note on (6): the paper states "= 1" but simultaneously minimizes the number
of *unassigned* neurons, which is only meaningful when full assignment may be
infeasible (N1 > M*N, or fan-out limits bind).  We therefore use "≤ 1" and
maximize assignments — the paper's stated objective — and expose
``require_all`` to assert the "=1" reading when feasible.

Solvers:
  * ``solve_mapping_full_ilp``    — the literal x_{i,j,k} ILP via scipy HiGHS.
  * ``solve_mapping_reduced_ilp`` — capacitor symmetry removes k:
        y_{i,j} ∈ {0,1}, Σ_i y_{i,j} ≤ N, Σ_j y_{i,j} ≤ 1, fan-out as before.
    Equivalent optimum (capacitors within an engine are interchangeable:
    any y solution expands to an x solution by enumerating free capacitors,
    and any x solution projects to y).  Scales to real layers.
  * ``solve_mapping_greedy``      — the fast heuristic used online.
  * ``solve_mapping_bruteforce``  — exhaustive, for tiny test instances.
  * maxflow (see maxflow.py)      — exact when fan-out constraints are slack.
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
import os
import warnings

import numpy as np
from scipy.optimize import Bounds, LinearConstraint, milp


class MappingError(RuntimeError):
    """A mapping could not be produced or violates the paper's constraints.

    Raised — never ``assert``-ed, so ``python -O`` cannot strip the check —
    when a solver returns no feasible incumbent, when a solution fails
    :meth:`MappingSolution.check`, or when ``map_model`` cannot fit a layer
    (SRAM budget, unassignable neurons)."""


@contextlib.contextmanager
def _quiet_cstdout():
    """Silence HiGHS's C++ stdout chatter (incumbent-improvement spam when a
    time limit binds) without touching Python-level stdout semantics."""
    try:
        fd = os.dup(1)
    except OSError:
        yield
        return
    try:
        devnull = os.open(os.devnull, os.O_WRONLY)
        os.dup2(devnull, 1)
        os.close(devnull)
        yield
    finally:
        os.dup2(fd, 1)
        os.close(fd)


@dataclasses.dataclass(frozen=True)
class MappingProblem:
    """One layer's mapping instance.

    n_dest:     N1 — neurons in the destination layer (to be assigned)
    n_engines:  M  — A-NEURON engines in the MX-NEURACORE
    n_caps:     N  — capacitors (virtual neurons) per A-NEURON
    conn:       bool[n_src, n_dest] — synaptic connectivity (pruned weights != 0);
                S_m = {i : conn[m, i]}
    fanout:     int[n_src] — per-source fan-out limits (constraint (7))
    """

    n_dest: int
    n_engines: int
    n_caps: int
    conn: np.ndarray
    fanout: np.ndarray

    @property
    def n_src(self) -> int:
        return self.conn.shape[0]

    def validate(self) -> None:
        if self.conn.shape != (self.n_src, self.n_dest):
            raise ValueError(f"conn shape {self.conn.shape} != "
                             f"({self.n_src}, {self.n_dest})")
        if self.fanout.shape != (self.n_src,):
            raise ValueError(f"fanout shape {self.fanout.shape} != "
                             f"({self.n_src},)")

    @staticmethod
    def from_weights(w: np.ndarray, n_engines: int, n_caps: int,
                     fanout: np.ndarray | int | None = None) -> "MappingProblem":
        """Build from a (n_src, n_dest) pruned weight matrix."""
        conn = np.asarray(w) != 0
        n_src, n_dest = conn.shape
        if fanout is None:
            fanout = np.full(n_src, n_dest, dtype=np.int64)  # slack
        elif np.isscalar(fanout):
            fanout = np.full(n_src, int(fanout), dtype=np.int64)
        return MappingProblem(n_dest=n_dest, n_engines=n_engines, n_caps=n_caps,
                              conn=conn, fanout=np.asarray(fanout, dtype=np.int64))


@dataclasses.dataclass(frozen=True)
class MappingSolution:
    """assignment[i] = (engine j, capacitor k) or (-1, -1) if unassigned."""

    engine: np.ndarray      # int[n_dest], -1 = unassigned
    capacitor: np.ndarray   # int[n_dest], -1 = unassigned
    n_assigned: int
    objective: int          # paper's (4): number of unassigned neurons
    solver: str
    mip_gap: float = 0.0    # HiGHS relative optimality gap of the accepted
                            # incumbent; 0.0 = proven optimal (or not an ILP)

    def check(self, p: MappingProblem, require_all: bool = False) -> None:
        """Verify constraints (5)-(7) hold; raises :class:`MappingError`
        (a real exception — this is a load-bearing correctness gate, not a
        debugging aid ``python -O`` may strip)."""
        assigned = self.engine >= 0
        # (6) unique by construction (one entry per i); capacitor uniqueness:
        for j in range(p.n_engines):
            caps = self.capacitor[(self.engine == j)]
            if len(caps) != len(set(caps.tolist())):
                raise MappingError(f"capacitor reuse in engine {j}")
            if len(caps) > p.n_caps:                                       # (5)
                raise MappingError(
                    f"engine {j} capacity exceeded: {len(caps)} > {p.n_caps}")
        for m in range(p.n_src):
            used = int(np.sum(assigned & p.conn[m]))
            if used > p.fanout[m]:                                         # (7)
                raise MappingError(
                    f"fanout violated for source {m}: {used} > {p.fanout[m]}")
        if require_all and not assigned.all():
            raise MappingError(
                f"not all neurons assigned: {int((~assigned).sum())} missing")
        if self.n_assigned != int(assigned.sum()):
            raise MappingError(
                f"n_assigned={self.n_assigned} inconsistent with engine "
                f"vector ({int(assigned.sum())} assigned)")


def _expand_engines_to_caps(p: MappingProblem, engine_of: np.ndarray) -> MappingSolution:
    """Given engine choice per neuron (-1 = none), allocate capacitor indices."""
    cap = np.full(p.n_dest, -1, dtype=np.int64)
    next_free = np.zeros(p.n_engines, dtype=np.int64)
    for i in range(p.n_dest):
        j = engine_of[i]
        if j >= 0:
            cap[i] = next_free[j]
            next_free[j] += 1
    n_assigned = int((engine_of >= 0).sum())
    return MappingSolution(engine=engine_of.astype(np.int64), capacitor=cap,
                           n_assigned=n_assigned,
                           objective=p.n_dest - n_assigned, solver="")


def _accept_milp(res, solver: str) -> float:
    """Vet a scipy ``milp`` result: no incumbent is a hard
    :class:`MappingError`; a time-limit incumbent is accepted (it is
    feasible) but its HiGHS optimality gap is surfaced — returned for
    :attr:`MappingSolution.mip_gap` and warned about — instead of being
    silently passed off as the optimum."""
    if res.x is None:
        raise MappingError(
            f"{solver}: HiGHS found no feasible solution "
            f"(status {res.status}): {res.message}")
    gap = float(getattr(res, "mip_gap", 0.0) or 0.0)
    if res.status != 0 and gap > 0.0:
        warnings.warn(
            f"{solver}: accepted a time-limit incumbent with relative "
            f"optimality gap {gap:.3g} — not proven optimal",
            RuntimeWarning, stacklevel=3)
    return gap


def solve_mapping_full_ilp(p: MappingProblem, time_limit: float = 60.0) -> MappingSolution:
    """The literal paper formulation over x_{i,j,k}.  O(N1*M*N) variables —
    use only on small instances; ``solve_mapping_reduced_ilp`` is equivalent."""
    p.validate()
    n1, m_eng, n_cap = p.n_dest, p.n_engines, p.n_caps
    nvar = n1 * m_eng * n_cap

    def vid(i, j, k):
        return (i * m_eng + j) * n_cap + k

    c = -np.ones(nvar)  # max Σx  ≡  min Σ(1-x)
    rows, cols, vals, lb, ub = [], [], [], [], []
    r = 0
    # (5) engine capacity
    for j in range(m_eng):
        for i in range(n1):
            for k in range(n_cap):
                rows.append(r); cols.append(vid(i, j, k)); vals.append(1.0)
        lb.append(-np.inf); ub.append(n_cap); r += 1
    # (6) unique assignment (≤ 1, see module docstring)
    for i in range(n1):
        for j in range(m_eng):
            for k in range(n_cap):
                rows.append(r); cols.append(vid(i, j, k)); vals.append(1.0)
        lb.append(-np.inf); ub.append(1.0); r += 1
    # capacitor exclusivity (implicit in the paper's hardware: one neuron per
    # capacitor): Σ_i x_{i,j,k} ≤ 1  ∀ j,k
    for j in range(m_eng):
        for k in range(n_cap):
            for i in range(n1):
                rows.append(r); cols.append(vid(i, j, k)); vals.append(1.0)
            lb.append(-np.inf); ub.append(1.0); r += 1
    # (7) fan-out
    for m in range(p.n_src):
        idx = np.nonzero(p.conn[m])[0]
        if len(idx) == 0:
            continue
        for i in idx:
            for j in range(m_eng):
                for k in range(n_cap):
                    rows.append(r); cols.append(vid(i, j, k)); vals.append(1.0)
        lb.append(-np.inf); ub.append(float(p.fanout[m])); r += 1

    from scipy.sparse import csr_matrix
    a = csr_matrix((vals, (rows, cols)), shape=(r, nvar))
    with _quiet_cstdout():
        res = milp(c=c,
                   constraints=LinearConstraint(a, np.array(lb), np.array(ub)),
                   integrality=np.ones(nvar), bounds=Bounds(0, 1),
                   options={"time_limit": time_limit})
    # status 0 = proven optimal; 1/3 = limit reached with an incumbent —
    # accept the incumbent (feasible) but surface its optimality gap
    gap = _accept_milp(res, "full_ilp")
    x = np.round(res.x).astype(np.int64).reshape(n1, m_eng, n_cap)
    engine = np.full(n1, -1, dtype=np.int64)
    cap = np.full(n1, -1, dtype=np.int64)
    for i in range(n1):
        jk = np.argwhere(x[i] == 1)
        if len(jk):
            engine[i], cap[i] = jk[0]
    n_assigned = int((engine >= 0).sum())
    return MappingSolution(engine=engine, capacitor=cap, n_assigned=n_assigned,
                           objective=n1 - n_assigned, solver="full_ilp",
                           mip_gap=gap)


def solve_mapping_reduced_ilp(p: MappingProblem, time_limit: float = 120.0) -> MappingSolution:
    """Capacitor-symmetry-reduced ILP over y_{i,j}.  Exact (same optimum as
    the full formulation — capacitors within an engine are interchangeable)."""
    p.validate()
    n1, m_eng = p.n_dest, p.n_engines
    nvar = n1 * m_eng

    def vid(i, j):
        return i * m_eng + j

    c = -np.ones(nvar)
    rows, cols, vals, lb, ub = [], [], [], [], []
    r = 0
    for j in range(m_eng):                       # (5)
        for i in range(n1):
            rows.append(r); cols.append(vid(i, j)); vals.append(1.0)
        lb.append(-np.inf); ub.append(p.n_caps); r += 1
    for i in range(n1):                          # (6)
        for j in range(m_eng):
            rows.append(r); cols.append(vid(i, j)); vals.append(1.0)
        lb.append(-np.inf); ub.append(1.0); r += 1
    for m in range(p.n_src):                     # (7)
        idx = np.nonzero(p.conn[m])[0]
        if len(idx) == 0:
            continue
        for i in idx:
            for j in range(m_eng):
                rows.append(r); cols.append(vid(i, j)); vals.append(1.0)
        lb.append(-np.inf); ub.append(float(p.fanout[m])); r += 1

    from scipy.sparse import csr_matrix
    a = csr_matrix((vals, (rows, cols)), shape=(r, nvar))
    with _quiet_cstdout():
        res = milp(c=c,
                   constraints=LinearConstraint(a, np.array(lb), np.array(ub)),
                   integrality=np.ones(nvar), bounds=Bounds(0, 1),
                   options={"time_limit": time_limit})
    gap = _accept_milp(res, "reduced_ilp")
    y = np.round(res.x).astype(np.int64).reshape(n1, m_eng)
    engine = np.where(y.sum(axis=1) > 0, y.argmax(axis=1), -1)
    sol = _expand_engines_to_caps(p, engine)
    return dataclasses.replace(sol, solver="reduced_ilp", mip_gap=gap)


def solve_mapping_greedy(p: MappingProblem) -> MappingSolution:
    """Online heuristic: assign neurons in decreasing fan-in order to the
    least-loaded engine, respecting capacity and fan-out budgets."""
    p.validate()
    fanin = p.conn.sum(axis=0)
    order = np.argsort(-fanin, kind="stable")
    load = np.zeros(p.n_engines, dtype=np.int64)
    budget = p.fanout.astype(np.int64).copy()
    engine = np.full(p.n_dest, -1, dtype=np.int64)
    for i in order:
        srcs = np.nonzero(p.conn[:, i])[0]
        if len(srcs) and (budget[srcs] <= 0).any():
            continue  # assigning i would break some source's fan-out
        j = int(np.argmin(load))
        if load[j] >= p.n_caps:
            continue  # all engines full
        engine[i] = j
        load[j] += 1
        budget[srcs] -= 1
    sol = _expand_engines_to_caps(p, engine)
    return dataclasses.replace(sol, solver="greedy")


def solve_mapping_bruteforce(p: MappingProblem) -> MappingSolution:
    """Exhaustive search over engine choices (None/0..M-1 per neuron).
    Only for tiny instances in tests."""
    p.validate()
    if (p.n_engines + 1) ** p.n_dest > 2_000_000:
        raise ValueError("instance too large for brute force")
    best, best_count = None, -1
    for choice in itertools.product(range(-1, p.n_engines), repeat=p.n_dest):
        eng = np.array(choice, dtype=np.int64)
        loads = np.bincount(eng[eng >= 0], minlength=p.n_engines)
        if (loads > p.n_caps).any():
            continue
        assigned = eng >= 0
        ok = True
        for m in range(p.n_src):
            if int(np.sum(assigned & p.conn[m])) > p.fanout[m]:
                ok = False
                break
        if not ok:
            continue
        cnt = int(assigned.sum())
        if cnt > best_count:
            best, best_count = eng, cnt
    sol = _expand_engines_to_caps(p, best)
    return dataclasses.replace(sol, solver="bruteforce")


def solve_mapping(p: MappingProblem, method: str = "auto") -> MappingSolution:
    """Entry point.  method: auto | full_ilp | reduced_ilp | greedy | maxflow."""
    if method == "auto":
        slack_fanout = bool((p.fanout >= p.conn.sum(axis=1)).all())
        if slack_fanout:
            from repro.core.mapping.maxflow import max_flow_assignment
            return max_flow_assignment(p)
        method = "reduced_ilp" if p.n_dest * p.n_engines > 64 else "full_ilp"
    if method == "full_ilp":
        return solve_mapping_full_ilp(p)
    if method == "reduced_ilp":
        return solve_mapping_reduced_ilp(p)
    if method == "greedy":
        return solve_mapping_greedy(p)
    if method == "maxflow":
        from repro.core.mapping.maxflow import max_flow_assignment
        return max_flow_assignment(p)
    raise ValueError(f"unknown method {method!r}")
