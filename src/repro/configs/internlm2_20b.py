"""InternLM2-20B: dense 48L, GQA 48/8 [arXiv:2403.17297; hf]."""

import dataclasses

from repro.configs.common import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92544, head_dim=128,
    rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256)
