"""Multi-tenant model registry: named packed models behind one fabric.

MENAGE's central trick is the *virtual neuron* — one physical neuron engine
time-multiplexes many model neurons by exploiting event sparsity.  The
serving stack applies the same idea one level up: one always-on
:class:`~repro.engine.stream_server.StreamServer` ("the fabric")
time-multiplexes many *models*.  This module is the bookkeeping layer that
makes that safe:

  * :class:`ModelEntry` — one tenant: a packed ``MemTables`` pytree (plus
    its clean twin when serving-time analog noise is configured), the
    tenant's own :class:`~repro.engine.serving.BucketPolicy`, a
    weighted-fair scheduling ``weight``, and a monotonically increasing
    ``generation`` — the hot-swap epoch counter.
  * :class:`ModelRegistry` — named entries with **atomic replacement**
    semantics: :meth:`swap` installs a new generation in one assignment, so
    a concurrent reader sees either the old entry or the new one, never a
    half-built tenant.  The registry itself never touches in-flight work;
    the server's :meth:`~repro.engine.stream_server.StreamServer.swap`
    drains pending dispatches on the old weights *before* calling it, and
    every admitted request pins the entry (name, generation) it was
    admitted under — so even a registry swapped out from underneath the
    scheduler cannot corrupt a queued request.

Entries are plain frozen data; everything mutable (runtime bucket policies,
EWMA service estimates, fair-queueing virtual time) lives on the server.
"""

from __future__ import annotations

import dataclasses

from repro.engine import batched_run as br
from repro.engine.serving import BucketPolicy

#: The tenant name single-model servers (and v1 wire frames, which carry no
#: model id) are routed to.
DEFAULT_MODEL = "default"

# sentinel: "inherit the old entry's noise config" on swap
_KEEP = object()


class UnknownModelError(KeyError):
    """A submit/swap referenced a model name the registry does not hold —
    transports map this to a reasoned rejection instead of crashing."""

    def __init__(self, name: str, known):
        self.name = name
        self.known = tuple(known)
        super().__init__(name)

    def __str__(self) -> str:
        return (f"unknown model {self.name!r} "
                f"(registered: {', '.join(self.known) or 'none'})")


@dataclasses.dataclass(frozen=True)
class ModelEntry:
    """One tenant of the serving fabric (immutable; swaps replace it)."""

    name: str
    packed: br.PackedModel          # the weights requests are served on
    clean: br.PackedModel           # un-perturbed twin (== packed w/o noise)
    policy: BucketPolicy            # the tenant's admission-time bucket grid
    noise: object | None = None     # AnalogNoise or None
    weight: float = 1.0             # weighted-fair scheduling share
    generation: int = 1             # hot-swap epoch, bumps on every swap


def _build_entry(name: str, model, *, policy: BucketPolicy | None,
                 noise=None, noise_key=0, weight: float = 1.0,
                 generation: int = 1) -> ModelEntry:
    packed = model if isinstance(model, br.PackedModel) else model.pack()
    clean = packed
    if noise is not None and noise.weight_sigma > 0:
        from repro.core.noise import as_noise_key, perturb_packed
        packed = perturb_packed(as_noise_key(noise_key), packed, noise)
    else:
        # weight_sigma <= 0 perturbs nothing: normalize to "noise off" so
        # the server's probe gate means "a perturbed model is serving"
        noise = None
    if not (isinstance(weight, (int, float)) and weight > 0):
        raise ValueError(f"model {name!r}: scheduling weight must be a "
                         f"positive number, got {weight!r}")
    return ModelEntry(name=name, packed=packed, clean=clean,
                      policy=policy if policy is not None else BucketPolicy(),
                      noise=noise, weight=float(weight),
                      generation=generation)


class ModelRegistry:
    """Named :class:`ModelEntry` map with atomic hot-swap semantics.

    ``register`` adds a tenant (duplicate names raise — replacing weights
    is a :meth:`swap`, which keeps the generation history honest).  The
    first registered tenant becomes the default route unless ``default=``
    names another; v1 wire frames and model-less submits go there.
    """

    def __init__(self, *, default: str | None = None):
        self._entries: dict[str, ModelEntry] = {}
        self._default = default

    # ------------------------------------------------------------- queries

    @property
    def default(self) -> str:
        # an explicit default that has not (yet) been registered must not
        # strand routing — fall back to insertion order until it shows up
        if self._default is not None and self._default in self._entries:
            return self._default
        if not self._entries:
            raise UnknownModelError(self._default or DEFAULT_MODEL, ())
        return next(iter(self._entries))

    def names(self) -> tuple[str, ...]:
        return tuple(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def get(self, name: str | None = None) -> ModelEntry:
        """The entry for ``name`` (``None`` = the default route)."""
        if name is None:
            name = self.default
        try:
            return self._entries[name]
        except KeyError:
            raise UnknownModelError(name, self._entries) from None

    # ----------------------------------------------------------- mutations

    def register(self, name: str, model, *, policy: BucketPolicy | None = None,
                 noise=None, noise_key=0, weight: float = 1.0) -> ModelEntry:
        """Add a tenant.  ``model`` is a ``PackedModel`` or anything with a
        ``.pack()``; ``policy`` defaults to a fresh :class:`BucketPolicy`.
        ``noise`` serves the tenant through one deterministic noisy device
        instance (the clean twin is kept for shadow probes)."""
        if not name:
            raise ValueError("model name must be non-empty")
        if name in self._entries:
            raise ValueError(f"model {name!r} is already registered — "
                             f"hot-swapping weights is swap(), not register()")
        entry = _build_entry(name, model, policy=policy, noise=noise,
                             noise_key=noise_key, weight=weight)
        self._entries[name] = entry
        return entry

    def swap(self, name: str, model, *, policy: BucketPolicy | None = None,
             noise=_KEEP, noise_key=0, weight: float | None = None
             ) -> ModelEntry:
        """Atomically replace ``name``'s entry with a new generation.

        Everything not given is inherited from the old entry (policy,
        noise config, weight), so the common call is just ``swap(name,
        new_packed)``.  The single-assignment replacement is the atomicity
        guarantee: readers see old or new, never a mix.  Draining in-flight
        work on the old weights is the *server's* job
        (:meth:`StreamServer.swap`) — a bare registry swap only redirects
        future lookups."""
        old = self.get(name)
        entry = _build_entry(
            name, model,
            policy=policy if policy is not None else old.policy,
            noise=(old.noise if noise is _KEEP else noise),
            noise_key=noise_key,
            weight=weight if weight is not None else old.weight,
            generation=old.generation + 1)
        self._entries[name] = entry     # the atomic redirect
        return entry
