"""Soak-and-chaos benchmark for the always-on serving stack.

Two halves, one JSON (``BENCH_soak.json``), both driven by the same
scenario scripts (:mod:`repro.engine.chaos`):

  * **Deterministic scenario replays** — every named scenario (diurnal and
    adversarial arrivals, device loss mid-serving, serving-time analog
    noise, SLO shed-vs-extend switching, the combined blackout) runs twice
    on a VirtualClock and must produce *identical* metrics: the replay
    determinism the tier-1 suite locks, re-checked here on the benchmark
    topology.
  * **Live socket soak** — a real client paces an adversarial arrival
    trace over TCP (:mod:`repro.launch.socket_serve`, the ingest protocol)
    into a WallClock server configured with serving-time analog noise and
    a scripted device loss.  The server must answer *every* request
    (result or reasoned rejection), recover onto the shrunken mesh, keep
    probing accuracy-under-noise, and stay bit-exact against the
    single-device engine.

  PYTHONPATH=src python benchmarks/soak_bench.py [--smoke] \
      [--out BENCH_soak.json] [--spoof-devices 2]

Gates (CI fails loudly on regression):
  * every scenario replay is deterministic (two runs, identical metrics
    AND byte-identical flight-recorder ``dump_json()`` — the tracing
    determinism contract of docs/OBSERVABILITY.md);
  * request conservation everywhere: completed + rejected + shed ==
    submitted — no request ever silently vanishes, chaos or not;
  * scripted faults actually landed: device-loss scenarios shrink the
    mesh with zero admitted requests lost, noise scenarios populate
    ``noise_agreement``, the SLO scenario flips to shedding — and every
    fault appears in the recorder as a typed anomaly whose count matches
    the corresponding metric;
  * the live soak serves through the socket with every request answered,
    a spot request bit-exact vs ``run_batched`` on the same (noisy)
    device instance, and the ADMIN ``metrics`` / ``trace`` verbs
    round-tripping the schema-locked snapshot and recorder dump live.
"""

from __future__ import annotations

import argparse
import json
import time

from repro.launch._spoof import (assert_spoof_applied,
                                 spoof_devices_from_argv)

_SPOOFED = spoof_devices_from_argv()  # before any jax import in this process

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.noise import AnalogNoise  # noqa: E402
from repro.engine import (BucketPolicy, FlightRecorder,  # noqa: E402
                          METRIC_KEYS, run_batched, run_sharded,
                          trace_count)
from repro.engine.chaos import (SCENARIOS, make_chaos_hook,  # noqa: E402
                                run_scenario, synth_arrival_trace)
from repro.engine.sharded_run import snn_serve_mesh  # noqa: E402
from repro.launch.serve_snn import build_demo_model  # noqa: E402
from repro.launch.socket_serve import (SpikeClient,  # noqa: E402
                                       SpikeSocketServer, serving_thread)

# the live soak scripts one device loss at this dispatch ordinal (skipped on
# single-device meshes, where there is nothing to recover onto)
_LIVE_LOSS = ((1, 1),)


def _conserved(m: dict) -> bool:
    return m["completed"] + m["rejected"] + m["shed"] == m["submitted"]


def _anomalies_match(tag: str, counts: dict, m: dict) -> None:
    """Every fault the metrics counted must appear in the flight recorder
    as a typed anomaly, one for one (docs/OBSERVABILITY.md anomaly
    table)."""
    flips = m["noise_probes"] - round(m["noise_agreement"]
                                      * m["noise_probes"])
    for kind, want in (("reject", m["rejected"]), ("shed", m["shed"]),
                       ("policy_extension", m["policy_extensions"]),
                       ("deadline_miss", m["deadline_misses"]),
                       ("device_loss", m["device_losses"]),
                       ("hot_swap_pin", m["hot_swaps"]),
                       ("noise_disagreement", flips)):
        got = counts.get(kind, 0)
        assert got == want, \
            f"{tag}: recorder saw {got} {kind} anomalies, metrics say {want}"


def _scenario_row(m: dict) -> dict:
    keep = ("scenario", "requests", "submitted", "admitted", "completed",
            "rejected", "shed", "deadline_misses", "deadline_miss_rate",
            "dispatches", "forced_dispatches", "device_losses",
            "mesh_size_start", "mesh_size_end", "slo_switches",
            "slo_shedding", "noise_probes", "noise_agreement",
            "bucket_fill_ratio", "max_queue_depth", "makespan_s",
            "hot_swaps", "per_model")
    return {k: m[k] for k in keep}


def bench_scenarios(packed, mesh) -> list[dict]:
    """Replay every named scenario twice; gate on determinism and on the
    scripted fault actually landing."""
    rows = []
    for name, sc in SCENARIOS.items():
        if sc.needs_mesh and (mesh is None or mesh.size < 2):
            print(f"soak/scenario/{name}: SKIP (needs >= 2 devices)")
            rows.append({"scenario": name, "skipped": True})
            continue
        rec1, rec2 = FlightRecorder(), FlightRecorder()
        _, _, m1 = run_scenario(packed, sc, mesh=mesh, recorder=rec1)
        _, _, m2 = run_scenario(packed, sc, mesh=mesh, recorder=rec2)
        rec1.detach_jit_probe()
        rec2.detach_jit_probe()
        assert m1 == m2, f"{name}: scenario replay is not deterministic"
        assert rec1.dump_json() == rec2.dump_json(), \
            f"{name}: flight-recorder dump is not replay-deterministic"
        _anomalies_match(name, rec1.anomaly_counts, m1)
        assert _conserved(m1), f"{name}: request leak {m1}"
        if sc.lose_devices:
            assert m1["device_losses"] == len(sc.lose_devices), \
                f"{name}: scripted loss never fired"
            assert m1["mesh_size_end"] < m1["mesh_size_start"]
            assert m1["served_all_admitted"], \
                f"{name}: admitted requests lost to device loss"
        if sc.noise_sigma > 0:
            assert m1["noise_probes"] > 0, f"{name}: no noise probes ran"
        if name == "slo_shed":    # the one scenario engineered to overload
            assert m1["slo_switches"] >= 1, \
                f"{name}: SLO controller never switched"
        if sc.tenants:
            per = m1["per_model"]
            assert set(per) == {t.name for t in sc.tenants}
            for t in sc.tenants:   # conservation holds tenant by tenant
                mm = per[t.name]
                assert mm["submitted"] == mm["admitted"] + mm["rejected"] \
                    and mm["admitted"] == mm["completed"] + mm["shed"], \
                    f"{name}: tenant {t.name} leaked requests: {mm}"
            if sc.swap_tenant:
                assert m1["hot_swaps"] == 1 and \
                    per[sc.swap_tenant]["hot_swaps"] == 1, \
                    f"{name}: scripted hot-swap never fired"
                assert per[sc.swap_tenant]["deadline_miss_rate"] <= 0.05, \
                    f"{name}: burst starved the swap tenant's deadlines"
        print(f"soak/scenario/{name}: {m1['completed']}/{m1['requests']} "
              f"served | miss {m1['deadline_miss_rate']:.3f} | mesh "
              f"{m1['mesh_size_start']}->{m1['mesh_size_end']} | slo_sw "
              f"{m1['slo_switches']} | agree {m1['noise_agreement']:.3f}")
        row = _scenario_row(m1)
        row["anomalies"] = dict(sorted(rec1.anomaly_counts.items()))
        rows.append(row)
    return rows


def _warm_buckets(packed, policy: BucketPolicy, mesh) -> float:
    """Compile every bucket the policy can dispatch and return the slowest
    warm engine-call time — the live soak's deadline-slack yardstick."""
    worst = 0.0
    for b in policy.batch_sizes:
        for t in policy.time_steps:
            zeros = np.zeros((b, t, packed.n_in), dtype=np.float32)
            for _ in range(2):     # first call compiles; second measures
                t0 = time.perf_counter()
                if mesh is None:
                    run_batched(packed, zeros, with_stats=False)
                else:
                    run_sharded(packed, zeros, mesh=mesh, with_stats=False)
                dt = time.perf_counter() - t0
            worst = max(worst, dt)
    return worst


def live_soak(packed, mesh, *, smoke: bool, seed: int = 0) -> dict:
    """Sustained adversarial offered load over a real TCP socket, with
    analog noise on the served weights and (on multi-device meshes) a
    scripted mid-soak device loss."""
    n_req = 24 if smoke else 96
    noise = AnalogNoise(weight_sigma=0.05)
    lose = _LIVE_LOSS if mesh is not None and mesh.size >= 2 else ()
    trace = synth_arrival_trace(n_req, packed.n_in, mode="adversarial",
                                rate=150.0, slack=1.0, t_lo=3, t_hi=12,
                                seed=seed + 1)
    policy = BucketPolicy.covering([s.shape[0] for _, s, _ in trace],
                                   n_shards=mesh.size if mesh else 1,
                                   max_batch=4 * (mesh.size if mesh else 1))
    worst_s = _warm_buckets(packed, policy, mesh)
    # pace arrivals so one warm engine call fits inside a flood's tight
    # quarter-slack deadline; recovery compiles mid-soak still cause
    # (measured, reported) misses — that is the point of a soak
    scale = max(1.0, 8.0 * worst_s / 0.25)
    n0 = trace_count()
    srv = SpikeSocketServer(
        packed, policy=policy, mesh=mesh, port=0,
        queue_capacity=max(n_req, 32), noise=noise, noise_key=seed,
        noise_probe_every=1, chaos_hook=make_chaos_hook(lose) if lose
        else None)
    host, port = srv.address
    t0 = time.monotonic()
    with serving_thread(srv):
        cli = SpikeClient(host, port)
        for t_a, stream, deadline in trace:
            delay = t_a * scale - (time.monotonic() - t0)
            if delay > 0:
                time.sleep(delay)
            cli.send(stream, slack=(deadline - t_a) * scale)
        cli.recv_all()
        # observability round-trip while the server is still live: the
        # schema-locked metrics snapshot and the full recorder dump
        met = cli.admin({"op": "metrics"})
        trc = cli.admin({"op": "trace"})
        cli.recv_all()
        cli.close()
    wall = time.monotonic() - t0
    m = srv.server.metrics.snapshot()
    mrep = cli.admin_replies[met]
    assert mrep.get("ok") and set(mrep["metrics"]) == set(METRIC_KEYS), \
        "live soak: ADMIN metrics reply is not schema-locked"
    trep = cli.admin_replies[trc]
    assert trep.get("ok") and trep["dump"]["n_completed"] == \
        m["completed"], "live soak: ADMIN trace dump disagrees with metrics"
    _anomalies_match("live soak", srv.tracer.anomaly_counts, m)
    answered = len(cli.results) + len(cli.rejections)
    assert answered == n_req, \
        f"live soak: {answered}/{n_req} requests answered over the socket"
    assert _conserved(m), f"live soak: request leak {m}"
    assert m["completed"] == len(cli.results) > 0
    assert m["noise_probes"] > 0, "live soak: no noise probes ran"
    if lose:
        assert m["device_losses"] == len(lose), \
            "live soak: scripted device loss never fired"
        assert srv.server.mesh.size == mesh.size - 1
    # bit-exactness through the full wire: longest answered request,
    # replayed alone through run_batched on the same noisy device instance
    served = [i for i in range(n_req) if i in cli.results]
    spot = max(served, key=lambda i: trace[i][1].shape[0])
    alone = run_batched(srv.server.packed, trace[spot][1][None],
                        with_stats=False)
    assert np.array_equal(cli.results[spot], alone.out_spikes[0]), \
        "live soak: socket-served result != run_batched"
    m.update({
        "requests": n_req, "answered": answered,
        "results": len(cli.results), "rejections": len(cli.rejections),
        "wall_s": wall, "throughput_rps": m["completed"] / max(wall, 1e-9),
        "pace_scale": scale, "worst_bucket_s": worst_s,
        "new_traces_during_soak": trace_count() - n0,
        "mesh_size_start": mesh.size if mesh else 1,
        "mesh_size_end": srv.server.mesh.size if srv.server.mesh else 1,
    })
    print(f"soak/live: {m['completed']}/{n_req} served "
          f"(+{len(cli.rejections)} rejected) in {wall:.1f}s | miss "
          f"{m['deadline_miss_rate']:.3f} | mesh {m['mesh_size_start']}->"
          f"{m['mesh_size_end']} | agree {m['noise_agreement']:.3f} "
          f"({m['noise_probes']} probes) | p99 "
          f"{m['p99_latency_s']*1e3:.0f} ms")
    return m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_soak.json")
    ap.add_argument("--model", default="mlp", choices=["mlp", "conv"])
    ap.add_argument("--data", type=int, default=None)
    ap.add_argument("--spoof-devices", type=int, default=None)
    args = ap.parse_args()
    assert_spoof_applied(_SPOOFED)
    mesh = snn_serve_mesh(args.data)
    packed = build_demo_model(args.model, smoke=args.smoke).pack()
    scenarios = bench_scenarios(packed, mesh)
    live = live_soak(packed, mesh, smoke=args.smoke)
    blob = {"bench": "soak", "smoke": args.smoke, "model": args.model,
            "backend": jax.default_backend(),
            "n_devices": len(jax.devices()), "n_shards": mesh.size,
            "scenarios": scenarios, "live": live}
    with open(args.out, "w") as f:
        json.dump(blob, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
