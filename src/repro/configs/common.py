"""Architecture config schema + the shape grid assigned to this paper."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                  # 0 for attn-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # attention extras
    window: int | None = None     # SWA window, None = full
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): a shared attention block every `hybrid_period` ssm layers
    hybrid_period: int = 0
    # enc-dec (whisper)
    n_encoder_layers: int = 0
    decoder_ratio: int = 4        # train/prefill decoder len = seq_len // ratio
    cross_len: int = 1500         # encoder output length seen by decode_step
    # vlm
    n_image_embeds: int = 0       # prefix image-patch embeds (stub frontend)
    # numerics
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode with a 500k context at sub-quadratic cost?
        SSM/hybrid: O(1) state.  SWA: windowed cache."""
        return self.family in ("ssm", "hybrid") or self.window is not None


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """The shape cells this arch runs (DESIGN.md §Arch-applicability):
    long_500k only for sub-quadratic archs."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out
