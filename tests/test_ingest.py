"""Wire-protocol contract for live spike-stream ingest (engine/ingest.py).

The framing is what a real sensor link speaks to the socket front end, so
the suite locks it byte-level: exact round-trips through bit-packing at
awkward shapes, incremental decoding across arbitrary chunk boundaries
(including one-byte-at-a-time), and loud ProtocolErrors on corruption —
a length-prefixed stream cannot resynchronize, so corruption must never
pass silently.
"""

import math

import numpy as np
import pytest

from repro.engine import ingest


def _raster(rng, t, n):
    return (rng.random((t, n)) < 0.3).astype(np.float32)


# ------------------------------------------------------------- round-trips

def test_request_roundtrip_bit_exact(rng):
    """[T, n_in] rasters survive bit-packing exactly, including shapes
    whose T*n_in is not a multiple of 8."""
    for t, n in [(1, 1), (3, 7), (13, 17), (30, 64), (8, 8)]:
        stream = _raster(rng, t, n)
        frame = ingest.FrameDecoder().feed(
            ingest.encode_request(7, stream, 0.25))[0]
        assert frame.kind == ingest.KIND_REQUEST
        req_id, out, slack, model = ingest.decode_request(frame.payload)
        assert req_id == 7 and slack == 0.25 and model is None
        assert out.shape == (t, n)
        assert np.array_equal(out, stream)


def test_request_roundtrip_carries_model_name(rng):
    """v2 frames route to a named tenant; the name survives utf-8 intact."""
    stream = _raster(rng, 6, 10)
    frame = ingest.FrameDecoder().feed(
        ingest.encode_request(9, stream, 0.5, model="conv-µ"))[0]
    assert frame.version == ingest.VERSION
    req_id, out, slack, model = ingest.decode_request(frame.payload)
    assert (req_id, slack, model) == (9, 0.5, "conv-µ")
    assert np.array_equal(out, stream)
    assert ingest.peek_request(frame.payload) == (9, 6, 10, 0.5, "conv-µ")


def test_v1_request_roundtrip_still_accepted(rng):
    """Deployed v1 sensors keep working: no model id on the wire, decoded
    as model=None (the registry default)."""
    stream = _raster(rng, 5, 8)
    frame = ingest.FrameDecoder().feed(
        ingest.encode_request(4, stream, 2.0, version=1))[0]
    assert frame.version == 1
    req_id, out, slack, model = ingest.decode_request(frame.payload,
                                                      frame.version)
    assert (req_id, slack, model) == (4, 2.0, None)
    assert np.array_equal(out, stream)
    # v1 cannot carry a model id; asking for one is a caller bug.
    with pytest.raises(ingest.ProtocolError, match="v1"):
        ingest.encode_request(4, stream, model="mlp", version=1)


def test_model_name_over_255_bytes_rejected(rng):
    with pytest.raises(ingest.ProtocolError, match="255"):
        ingest.encode_request(0, _raster(rng, 2, 2), model="x" * 256)


def test_request_default_slack_is_inf(rng):
    frame = ingest.FrameDecoder().feed(
        ingest.encode_request(0, _raster(rng, 4, 5)))[0]
    assert frame.kind == ingest.KIND_REQUEST
    _, _, slack, _ = ingest.decode_request(frame.payload)
    assert math.isinf(slack)


def test_peek_request_reads_header_without_unpacking(rng):
    """The server validates the claimed [T, n_in] against its model before
    committing to the decode; peek must agree with the full decode and
    still reject truncated headers."""
    frame = ingest.FrameDecoder().feed(
        ingest.encode_request(3, _raster(rng, 5, 9), 1.5))[0]
    assert ingest.peek_request(frame.payload) == (3, 5, 9, 1.5, None)
    with pytest.raises(ingest.ProtocolError):
        ingest.peek_request(frame.payload[:8])
    # A claimed name length past the end of the payload is corruption,
    # not an index error.
    with pytest.raises(ingest.ProtocolError, match="name truncated"):
        ingest.peek_request(frame.payload[:ingest._REQ_HEAD_V2.size - 1]
                            + b"\xff")


def test_result_roundtrip_bit_exact(rng):
    out = _raster(rng, 9, 10)
    frame = ingest.FrameDecoder().feed(ingest.encode_result(42, out))[0]
    assert frame.kind == ingest.KIND_RESULT
    req_id, got = ingest.decode_result(frame.payload)
    assert req_id == 42
    assert np.array_equal(got, out)


def test_rejection_roundtrip():
    frame = ingest.FrameDecoder().feed(
        ingest.encode_rejection(3, "queue_full: capacity 8"))[0]
    assert frame.kind == ingest.KIND_REJECT
    assert ingest.decode_rejection(frame.payload) == \
        (3, "queue_full: capacity 8")


def test_admin_roundtrip():
    """The control plane is JSON over an ADMIN frame, req_id echoed."""
    body = {"op": "swap", "model": "mlp", "seed": 3}
    frame = ingest.FrameDecoder().feed(ingest.encode_admin(11, body))[0]
    assert frame.kind == ingest.KIND_ADMIN
    assert ingest.decode_admin(frame.payload) == (11, body)


def test_admin_rejects_non_json_and_non_object():
    with pytest.raises(ingest.ProtocolError, match="JSON"):
        ingest.decode_admin(b"\x00\x00\x00\x01not json")
    with pytest.raises(ingest.ProtocolError, match="object"):
        ingest.decode_admin(b"\x00\x00\x00\x01[1, 2]")
    with pytest.raises(ingest.ProtocolError, match="truncated"):
        ingest.decode_admin(b"\x00\x00")


# ------------------------------------------------------ incremental decode

def test_decoder_handles_arbitrary_chunk_boundaries(rng):
    """Frames come out whole no matter how the transport splits the bytes
    — including a one-byte-at-a-time trickle."""
    blobs = [ingest.encode_request(i, _raster(rng, 3 + i, 11), float(i))
             for i in range(5)]
    wire = b"".join(blobs)
    for chunk_size in (1, 2, 7, 64, len(wire)):
        dec = ingest.FrameDecoder()
        frames = []
        for off in range(0, len(wire), chunk_size):
            frames.extend(dec.feed(wire[off:off + chunk_size]))
        assert len(frames) == 5
        assert dec.pending_bytes == 0
        for i, frame in enumerate(frames):
            req_id, stream, slack, _ = ingest.decode_request(frame.payload)
            assert req_id == i and slack == float(i)
            assert stream.shape == (3 + i, 11)


def test_decoder_emits_multiple_frames_per_chunk(rng):
    wire = (ingest.encode_rejection(1, "a") + ingest.encode_rejection(2, "b")
            + ingest.encode_rejection(3, "c"))
    frames = ingest.FrameDecoder().feed(wire)
    assert [ingest.decode_rejection(f.payload)[0] for f in frames] == \
        [1, 2, 3]


# ------------------------------------------------------------- corruption

def test_bad_magic_raises():
    with pytest.raises(ingest.ProtocolError, match="magic"):
        ingest.FrameDecoder().feed(b"XX" + b"\x00" * 10)


def test_bad_version_raises(rng):
    wire = bytearray(ingest.encode_rejection(0, "ok"))
    wire[2] = ingest.VERSION + 1
    with pytest.raises(ingest.ProtocolError, match="version"):
        ingest.FrameDecoder().feed(bytes(wire))


def test_absurd_length_prefix_raises():
    wire = ingest._HEADER.pack(ingest.MAGIC, ingest.VERSION,
                               ingest.KIND_REQUEST, ingest.MAX_PAYLOAD + 1)
    with pytest.raises(ingest.ProtocolError, match="length"):
        ingest.FrameDecoder().feed(wire)


def test_truncated_payloads_raise(rng):
    full = ingest.FrameDecoder().feed(
        ingest.encode_request(0, _raster(rng, 4, 9)))[0].payload
    with pytest.raises(ingest.ProtocolError):
        ingest.decode_request(full[:8])          # header cut short
    with pytest.raises(ingest.ProtocolError):
        ingest.decode_request(full[:-1])         # raster bytes missing
    with pytest.raises(ingest.ProtocolError):
        ingest.decode_result(b"\x00\x00")
    with pytest.raises(ingest.ProtocolError):
        ingest.decode_rejection(b"\x01")


def test_decoder_reset_recovers_after_corruption(rng):
    """A length-prefixed stream cannot resync after corruption: the bad
    bytes stay buffered and every later feed re-raises — until reset()
    discards them, after which the decoder parses clean frames again."""
    dec = ingest.FrameDecoder()
    with pytest.raises(ingest.ProtocolError):
        dec.feed(b"XX" + b"\x00" * 10)
    good = ingest.encode_request(5, _raster(rng, 3, 4), 1.0)
    with pytest.raises(ingest.ProtocolError):
        dec.feed(good)                   # still poisoned by buffered bytes
    assert dec.reset() > 0               # reports how much it threw away
    frames = dec.feed(good)              # same decoder, clean slate
    assert len(frames) == 1
    assert ingest.peek_request(frames[0].payload)[0] == 5
    assert dec.reset() == 0              # idempotent on an empty buffer
