"""End-to-end driver: train a MENAGE evaluation model through the unified
sharded training engine (`repro.engine.snn_train`), then run the full
prune -> quantize -> map -> execute flow.

  --model mlp   (default) the paper's N-MNIST MLP (200/100/40/10) on Accel_1
  --model conv  the spiking CNN (conv->LIF->pool x2 + dense head) on the
                synthetic CIFAR10-DVS stream, lowered layer-spec by layer-spec
                (Conv2d with shared weight-SRAM words) onto Accel_2

Both families train through the same `train_snn_model` entry point: AdamW
via `engine/train_loop.py` (async checkpoints -> the run is resume-aware:
re-launching with the same --ckpt continues from the last checkpoint),
data-parallel over a ("data",) mesh when more than one device is visible
(`--spoof-devices N` emulates an N-device host on CPU), step-keyed batches
so restarts replay the exact remaining data.

  PYTHONPATH=src python examples/train_snn.py [--steps 300] [--model conv] \
      [--spoof-devices 8] [--ckpt /tmp/menage_snn_ckpt]
"""

import argparse

from repro.launch._spoof import (assert_spoof_applied,
                                 spoof_devices_from_argv)

_SPOOFED = spoof_devices_from_argv()  # before any jax import in this process

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.menage_paper import (CIFAR_CONV, CIFAR_CONV_DATA,  # noqa: E402
                                        NMNIST_DATA, NMNIST_SNN)
from repro.core.accelerator import map_model, run  # noqa: E402
from repro.core.energy import ACCEL_1, ACCEL_2  # noqa: E402
from repro.core.prune import prune_pytree  # noqa: E402
from repro.core.quant import quantize_pytree  # noqa: E402
from repro.data.events import event_batch_at, synthetic_event_dataset  # noqa: E402
from repro.engine import (BucketPolicy, SNNTrainConfig, model_for,  # noqa: E402
                          run_bucketed, snn_train_mesh, trace_count,
                          train_snn_model)
from repro.snn.conv import conv_snn_forward, layer_specs  # noqa: E402
from repro.snn.mlp import snn_forward  # noqa: E402


def _train(cfg, spikes, labels, n_test, args, *, batch, name):
    """Unified training: sharded over all visible devices, resume-aware."""
    mesh = snn_train_mesh() if len(jax.devices()) > 1 else None
    if mesh is not None:
        print(f"{name}: data-parallel over {mesh.size} device(s)")
    # grad_shards pinned (not left to the mesh): the gradient arithmetic is
    # then device-count-independent, so re-launching with a different
    # --spoof-devices count resumes the SAME loss trajectory bit for bit
    # (any device count dividing 8 shards the chunks; others replicate)
    train_cfg = SNNTrainConfig(
        steps=args.steps, lr=1e-3, mesh=mesh, grad_shards=8,
        checkpoint_dir=f"{args.ckpt}_{name}", checkpoint_every=100,
        log_every=50)

    def batch_of(step):
        return event_batch_at(spikes[n_test:], labels[n_test:], batch, step)

    model = model_for(cfg)
    params, hist = train_snn_model(model, cfg, batch_of, train_cfg,
                                   key=jax.random.key(1))
    if hist["loss"]:
        print(f"{name} train: loss={hist['loss'][-1]:.3f} "
              f"acc={hist['acc'][-1]:.2f} "
              f"(checkpoints at {hist['checkpoints']})")
    else:
        print(f"{name}: checkpoint already at step {train_cfg.steps} — "
              f"nothing left to train")
    return params


def main_conv(args):
    """Conv path: train through the unified engine, prune, lower to
    Conv2d/SumPool2d/Dense specs, map onto Accel_2, and cross-check the two
    executers."""
    cfg = CIFAR_CONV
    spikes, labels = synthetic_event_dataset(CIFAR_CONV_DATA, n_per_class=16,
                                             key=jax.random.key(0))
    n_test = len(labels) // 5
    params = _train(cfg, spikes, labels, n_test, args, batch=32, name="conv")

    counts, _ = conv_snn_forward(
        params, jnp.asarray(spikes[:n_test].swapaxes(0, 1)), cfg)
    acc = float((np.asarray(counts).argmax(-1) == labels[:n_test]).mean())
    print(f"conv test accuracy (before prune/quant): {acc:.3f}")

    pruned, _ = prune_pytree(params, 0.5)
    model = map_model(layer_specs(pruned, cfg), ACCEL_2, lif=cfg.lif)
    for li, layer in enumerate(model.layers):
        print(f"  layer {li}: {layer.n_src}->{layer.n_dest} "
              f"rounds={len(layer.rounds)} sram={layer.sram_bytes}B "
              f"(unique {layer.weight_bytes}B) shared={layer.shared_weights}")
    # serve the test clips through the bucketed engine (bounded jit cache)
    policy = BucketPolicy(batch_sizes=(4,), time_steps=(cfg.num_steps,))
    n0 = trace_count()
    served = run_bucketed(model, list(spikes[:4]), policy=policy)
    res = run(model, spikes[0])
    for b, r in enumerate(served):
        assert (r.out_spikes == run(model, spikes[b]).out_spikes).all(), \
            f"engine diverged from oracle on sample {b}"
    print(f"Accel_2 conv execution: {res.energy.tops_per_w:.2f} TOPS/W "
          f"(oracle == bucketed engine on {len(served)} samples, "
          f"{trace_count() - n0} trace(s))")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/menage_snn_ckpt")
    ap.add_argument("--model", choices=("mlp", "conv"), default="mlp")
    ap.add_argument("--spoof-devices", type=int, default=None,
                    help="emulate N CPU devices (set before jax init)")
    args = ap.parse_args()
    assert_spoof_applied(_SPOOFED)
    if args.model == "conv":
        return main_conv(args)

    spikes, labels = synthetic_event_dataset(NMNIST_DATA, n_per_class=32,
                                             key=jax.random.key(0))
    n_test = len(labels) // 5
    params = _train(NMNIST_SNN, spikes, labels, n_test, args, batch=64,
                    name="mlp")

    # eval
    counts, _ = snn_forward(params,
                            jnp.asarray(spikes[:n_test].swapaxes(0, 1)),
                            NMNIST_SNN)
    acc = float((np.asarray(counts).argmax(-1) == labels[:n_test]).mean())
    print(f"test accuracy (before prune/quant): {acc:.3f}")

    pruned, _ = prune_pytree(params, 0.5)
    _, dq = quantize_pytree(pruned)
    counts, _ = snn_forward(dq, jnp.asarray(spikes[:n_test].swapaxes(0, 1)),
                            NMNIST_SNN)
    acc_pq = float((np.asarray(counts).argmax(-1) == labels[:n_test]).mean())
    print(f"test accuracy (after prune+quant):  {acc_pq:.3f} "
          f"(paper: 94.75% -> 94.1%)")

    model = map_model([np.asarray(w) for w in dq], ACCEL_1,
                      lif=NMNIST_SNN.lif)
    res = run(model, spikes[0])
    print(f"Accel_1 execution: {res.energy.tops_per_w:.2f} TOPS/W "
          f"(paper Table II: 3.4)")


if __name__ == "__main__":
    main()
