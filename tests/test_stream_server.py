"""Always-on async serving loop: bit-exactness, deadlines, backpressure.

Contracts under test (engine/stream_server.py):

  * every result served through the async loop is bit-identical to
    ``run_bucketed`` on the same request set — and transitively to the
    numpy oracle — deterministically and as a hypothesis property over
    random arrival traces (random lengths, gaps, and deadlines);
  * the scheduler dispatches a *partially-full* bucket before the oldest
    pending request's deadline expires (deadline-miss rate 0 at low load)
    and the jit-trace count stays <= ``policy.n_buckets``;
  * the arrival queue is bounded: ``reject`` and ``shed_oldest``
    backpressure policies, over-long requests rejected (or grid-extended)
    at admission with per-request reasons.
"""

import gc
import math
import time

import jax
import numpy as np
import pytest
from _equivalence import STAT_FIELDS
from _hypothesis_compat import given, settings, st

from repro.core.accelerator import map_model
from repro.core.energy import AcceleratorSpec
from repro.core.lif import LIFParams
from repro.engine import (BucketPolicy, StreamServer, VirtualClock,
                          WallClock, run_bucketed, serve_trace, should_donate,
                          trace_count)
from repro.engine import serving as serving_mod

SPEC = AcceleratorSpec("stream-test", n_cores=3, n_engines=4, n_caps=8,
                       weight_mem_bytes=1 << 18)
N_IN = 14


@pytest.fixture(scope="module")
def packed():
    rng = np.random.default_rng(7)
    ws = []
    for a, b in ((N_IN, 12), (12, 6)):
        w = rng.normal(0, 0.5, (a, b)).astype(np.float32)
        w[rng.random(w.shape) > 0.6] = 0
        ws.append(w)
    return map_model(ws, SPEC, lif=LIFParams(beta=0.8, threshold=0.7)).pack()


def _streams(rng, lengths, p=0.35):
    return [(rng.random((t, N_IN)) < p).astype(np.float32) for t in lengths]


def _policy():
    return BucketPolicy(batch_sizes=(1, 2, 4), time_steps=(4, 8))


def _assert_request_results_equal(a, b, tag=""):
    np.testing.assert_array_equal(a.out_spikes, b.out_spikes,
                                  err_msg=f"{tag} spikes")
    assert len(a.stats) == len(b.stats), tag
    for li, (sa, sb) in enumerate(zip(a.stats, b.stats)):
        for f in STAT_FIELDS:
            np.testing.assert_array_equal(getattr(sa, f), getattr(sb, f),
                                          err_msg=f"{tag} layer {li} {f}")
        assert sa.mem_e_peak == sb.mem_e_peak, f"{tag} layer {li}"
        np.testing.assert_array_equal(a.util[li], b.util[li],
                                      err_msg=f"{tag} layer {li} util")
        np.testing.assert_array_equal(a.overflow[li], b.overflow[li],
                                      err_msg=f"{tag} layer {li} overflow")
    if a.stats:
        assert a.energy() == b.energy(), tag
        assert a.energy(frame_cycles=None) == b.energy(frame_cycles=None), tag


# ------------------------------------------------ bit-exactness vs bucketed

def test_async_matches_bucketed_deterministic(rng, packed):
    """The async loop and the closed-list path serve the same request set
    bit-identically on every result surface (and run_bucketed is itself
    oracle-equivalent, so transitively the async loop matches the oracle)."""
    lengths = [3, 7, 5, 8, 2, 8, 1]
    streams = _streams(rng, lengths)
    ref = run_bucketed(packed, streams, policy=_policy())
    server = StreamServer(packed, policy=_policy(), clock=VirtualClock(),
                          with_stats=True)
    trace = [(0.05 * i, s) for i, s in enumerate(streams)]
    results, rids = serve_trace(server, trace)
    assert rids == list(range(len(streams)))
    snap = server.metrics.snapshot()
    assert snap["completed"] == len(streams) and snap["rejected"] == 0
    for i, r in enumerate(ref):
        _assert_request_results_equal(results[rids[i]], r, tag=f"req {i}")


def test_async_matches_oracle_under_max_events(rng, packed):
    """The MEM_E cap threads through the async path identically."""
    streams = _streams(rng, [3, 6, 5], p=0.7)
    server = StreamServer(packed, policy=_policy(), clock=VirtualClock(),
                          with_stats=True, max_events=2)
    results, rids = serve_trace(server, [(0.0, s) for s in streams])
    ref = run_bucketed(packed, streams, policy=_policy(), max_events=2)
    for i in range(len(streams)):
        _assert_request_results_equal(results[rids[i]], ref[i], tag=f"req {i}")


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_async_property_random_traces(packed, data):
    """Property: for ANY arrival trace (random lengths, inter-arrival gaps,
    and finite/infinite deadlines), every admitted request's output spikes
    are bit-identical to the closed-list bucketed run of the same streams."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    n = data.draw(st.integers(1, 8))
    lengths = [data.draw(st.integers(1, 8)) for _ in range(n)]
    gaps = [data.draw(st.floats(0.0, 0.4)) for _ in range(n)]
    slacks = [data.draw(st.sampled_from([0.05, 0.3, math.inf]))
              for _ in range(n)]
    streams = _streams(rng, lengths)
    times = np.cumsum(gaps)
    trace = [(float(t), s, float(t) + sl)
             for t, s, sl in zip(times, streams, slacks)]
    server = StreamServer(packed, policy=_policy(), clock=VirtualClock(),
                          service_model=lambda b, t: 0.01)
    results, rids = serve_trace(server, trace)
    ref = run_bucketed(packed, streams, policy=_policy(), with_stats=False)
    assert all(r is not None for r in rids)    # nothing rejected here
    for i in range(n):
        np.testing.assert_array_equal(results[rids[i]].out_spikes,
                                      ref[i].out_spikes,
                                      err_msg=f"request {i} (T={lengths[i]})")
    snap = server.metrics.snapshot()
    assert snap["completed"] == n


# ------------------------------------------------------- deadline pressure

def test_deadline_forces_partial_dispatch(rng, packed):
    """Bursty arrivals smaller than the bucket: the scheduler must dispatch
    a partially-full bucket before the oldest request's deadline expires —
    zero misses at low load — instead of waiting for the bucket to fill."""
    policy = BucketPolicy(batch_sizes=(4,), time_steps=(8,))
    streams = _streams(rng, [5, 6, 3])
    server = StreamServer(packed, policy=policy, clock=VirtualClock(),
                          service_model=lambda b, t: 0.1)
    n0 = trace_count()
    # two requests at t~0 with 1s deadlines; next arrival far beyond them
    trace = [(0.0, streams[0], 1.0), (0.05, streams[1], 1.05),
             (50.0, streams[2], 51.0)]
    results, rids = serve_trace(server, trace)
    snap = server.metrics.snapshot()
    assert snap["forced_dispatches"] >= 1, "deadline never forced a dispatch"
    assert snap["deadline_misses"] == 0 and snap["deadline_miss_rate"] == 0.0
    # the forced dispatch was partially full: 2 requests in a 4-wide bucket
    assert 0.5 in server.metrics.fill
    assert trace_count() - n0 <= policy.n_buckets
    # and it dispatched *before* the deadline: completion = trigger(0.9)
    # + service(0.1) = deadline exactly, so TTFD < slack
    assert max(list(server.metrics.ttfd_s)[:2]) < 1.0
    ref = run_bucketed(packed, streams, policy=policy, with_stats=False)
    for i in range(3):
        np.testing.assert_array_equal(results[rids[i]].out_spikes,
                                      ref[i].out_spikes)


def test_tight_deadline_behind_best_effort_request(rng, packed):
    """A best-effort (inf-deadline) request admitted first must not mask a
    tight deadline behind it in the same bucket: the trigger tracks the
    group's *tightest* member, and the forced dispatch takes both."""
    policy = BucketPolicy(batch_sizes=(4,), time_steps=(8,))
    server = StreamServer(packed, policy=policy, clock=VirtualClock(),
                          service_model=lambda b, t: 0.1)
    streams = _streams(rng, [5, 6])
    server.submit(streams[0])                       # best-effort, inf slack
    server.submit(streams[1], slack=1.0)            # tight, behind it
    assert server.next_deadline() == pytest.approx(0.9)
    server.clock.advance(0.9)
    done = server.poll()
    assert len(done) == 2 and server.queue_depth == 0
    snap = server.metrics.snapshot()
    assert snap["forced_dispatches"] == 1 and snap["deadline_misses"] == 0


def test_full_bucket_dispatches_immediately(rng, packed):
    """A group that reaches max_batch dispatches at submit time, no
    deadline involvement (forced == 0), even with infinite slack."""
    policy = BucketPolicy(batch_sizes=(2,), time_steps=(8,))
    server = StreamServer(packed, policy=policy, clock=VirtualClock())
    streams = _streams(rng, [4, 6])
    for s in streams:
        server.submit(s)
    done = server.collect()
    assert len(done) == 2 and server.queue_depth == 0
    snap = server.metrics.snapshot()
    assert snap["dispatches"] == 1 and snap["forced_dispatches"] == 0
    assert snap["bucket_fill_ratio"] == pytest.approx(1.0)


def test_infinite_slack_waits_for_flush(rng, packed):
    """Best-effort requests (no deadline) below max_batch sit in the queue
    until flush — next_deadline() reports nothing to wake up for."""
    server = StreamServer(packed, policy=_policy(), clock=VirtualClock())
    server.submit(_streams(rng, [5])[0])
    assert server.next_deadline() is None
    assert server.poll() == [] and server.queue_depth == 1
    done = server.flush()
    assert len(done) == 1 and server.queue_depth == 0


# ----------------------------------------------------------- backpressure

def test_backpressure_reject(rng, packed):
    server = StreamServer(packed, policy=_policy(), clock=VirtualClock(),
                          queue_capacity=2, backpressure="reject")
    streams = _streams(rng, [3, 3, 3])
    rids = [server.submit(s) for s in streams]
    assert rids[0] is not None and rids[1] is not None and rids[2] is None
    assert server.rejections[-1].reason == "queue_full"
    snap = server.metrics.snapshot()
    assert snap["rejected"] == 1 and snap["admitted"] == 2
    assert snap["queue_depth"] == 2 == snap["max_queue_depth"]
    assert len(server.flush()) == 2


def test_backpressure_shed_oldest(rng, packed):
    server = StreamServer(packed, policy=_policy(), clock=VirtualClock(),
                          queue_capacity=2, backpressure="shed_oldest")
    streams = _streams(rng, [3, 3, 3])
    rids = [server.submit(s) for s in streams]
    assert all(r is not None for r in rids)       # newest always admitted
    assert server.rejections[-1].reason == "shed"
    assert server.rejections[-1].rid == rids[0]   # oldest displaced
    done = dict(server.flush())
    assert set(done) == {rids[1], rids[2]}
    snap = server.metrics.snapshot()
    assert snap["shed"] == 1 and snap["completed"] == 2


# ------------------------------------------------------ admission control

def test_overlong_rejected_at_admission(rng, packed):
    policy = BucketPolicy(batch_sizes=(2,), time_steps=(4,))
    server = StreamServer(packed, policy=policy, clock=VirtualClock())
    ok = server.submit(_streams(rng, [4])[0])
    bad = server.submit(_streams(rng, [9])[0])
    assert ok is not None and bad is None
    assert server.rejections[-1].reason == "overlong"
    assert "9 steps" in server.rejections[-1].detail
    assert len(server.flush()) == 1               # the batch plan survived


def test_overlong_extends_grid(rng, packed):
    policy = BucketPolicy(batch_sizes=(2,), time_steps=(4,))
    server = StreamServer(packed, policy=policy, clock=VirtualClock(),
                          overlong="extend")
    stream = _streams(rng, [9])[0]
    rid = server.submit(stream)
    assert rid is not None
    assert server.policy.time_steps == (4, 16)    # doubled until it covers
    assert server.metrics.snapshot()["policy_extensions"] == 1
    done = dict(server.flush())
    oracle = run_bucketed(packed, [stream], policy=server.policy,
                          with_stats=False)[0]
    np.testing.assert_array_equal(done[rid].out_spikes, oracle.out_spikes)


def test_overlong_rejected_by_backpressure_leaves_grid_alone(rng, packed):
    """Grid extension is a side effect of *admission*: an over-long request
    that then bounces off the full queue must not have grown the policy."""
    policy = BucketPolicy(batch_sizes=(2,), time_steps=(4,))
    server = StreamServer(packed, policy=policy, clock=VirtualClock(),
                          overlong="extend", queue_capacity=1)
    assert server.submit(_streams(rng, [3])[0]) is not None   # fills queue
    assert server.submit(_streams(rng, [9])[0]) is None
    assert server.rejections[-1].reason == "queue_full"
    assert server.policy.time_steps == (4,)       # untouched
    assert server.metrics.snapshot()["policy_extensions"] == 0


def test_empty_stream_rejected(rng, packed):
    server = StreamServer(packed, policy=_policy(), clock=VirtualClock())
    assert server.submit(np.zeros((0, N_IN), np.float32)) is None
    assert server.rejections[-1].reason == "empty"
    assert server.metrics.snapshot()["rejected"] == 1


def test_submit_bad_width_raises_typed_error(rng, packed):
    """submit is where external traffic enters: a raster with the wrong
    width raises ValueError (not a -O-strippable assert) so transports can
    map it to a rejection instead of dying."""
    server = StreamServer(packed, policy=_policy(), clock=VirtualClock())
    with pytest.raises(ValueError, match=f"expected \\[T, {N_IN}\\]"):
        server.submit(np.zeros((4, N_IN + 1), np.float32))


def test_rejection_callback_sees_every_rejection(rng, packed):
    """on_rejection fires synchronously for pre-admission rejects and
    post-admission sheds alike — the unbounded delivery channel the socket
    layer answers REJECT frames from."""
    seen = []
    server = StreamServer(packed, policy=_policy(), clock=VirtualClock(),
                          queue_capacity=2, backpressure="shed_oldest",
                          on_rejection=seen.append)
    rids = [server.submit(s) for s in _streams(rng, [3, 3, 3])]
    server.submit(np.zeros((0, N_IN), np.float32))    # pre-admission reject
    assert [(r.reason, r.rid) for r in seen] == \
        [("shed", rids[0]), ("empty", None)]
    assert list(server.rejections) == seen            # same records, ordered


def test_zero_sigma_noise_normalized_to_off(rng, packed):
    """AnalogNoise(weight_sigma=0) applies no perturbation, so the server
    must treat it as noise-off: no shadow probes of identical models, and
    the served model IS the clean model."""
    from repro.core.noise import AnalogNoise
    server = StreamServer(packed, policy=_policy(), clock=VirtualClock(),
                          noise=AnalogNoise(weight_sigma=0.0,
                                            leak_mismatch=0.1),
                          noise_probe_every=1)
    assert server.noise is None
    assert server.packed is server._clean_packed
    for s in _streams(rng, [3, 4]):
        server.submit(s)
    server.flush()
    snap = server.metrics.snapshot()
    assert snap["completed"] == 2
    assert snap["noise_probes"] == 0 and snap["noise_agreement"] == 1.0


# -------------------------------------------------------- jit-cache bound

def test_async_trace_bound_and_hot_replay(rng, packed):
    """A mixed async trace costs at most n_buckets traces; replaying the
    same trace costs zero — the always-on loop keeps the cache bounded."""
    # B=3 buckets are unique to this test, so the cold pass must trace
    policy = BucketPolicy(batch_sizes=(3,), time_steps=(4, 8))
    streams = _streams(rng, [1, 2, 3, 5, 7, 8, 4, 6, 8, 2])
    trace = [(0.02 * i, s) for i, s in enumerate(streams)]

    def one_pass():
        server = StreamServer(packed, policy=policy, clock=VirtualClock(),
                              default_slack=0.07)
        return serve_trace(server, trace)

    n0 = trace_count()
    one_pass()
    total = trace_count() - n0
    assert 0 < total <= policy.n_buckets, \
        f"{total} traces > {policy.n_buckets} buckets"
    n1 = trace_count()
    one_pass()
    assert trace_count() == n1, "hot async replay retraced the jit"


# ----------------------------------------------------- wall clock / donation

def test_wallclock_live_smoke(rng, packed):
    """A small live trace on the real clock (no VirtualClock): three
    requests submitted at wall time, polled once mid-flight, flushed —
    every result bit-exact vs the closed-list path and the clock strictly
    monotonic through the run."""
    server = StreamServer(packed, policy=_policy())
    assert isinstance(server.clock, WallClock)      # the default
    t0 = server.now()
    streams = _streams(rng, [3, 5, 7])
    rids = [server.submit(s, slack=30.0) for s in streams]
    assert all(r is not None for r in rids)
    time.sleep(0.005)
    assert server.now() > t0
    done = dict(server.poll())                      # nothing due yet
    done.update(server.flush())
    assert set(done) == set(rids)
    ref = run_bucketed(packed, streams, policy=_policy(), with_stats=False)
    for i, rid in enumerate(rids):
        np.testing.assert_array_equal(done[rid].out_spikes,
                                      ref[i].out_spikes,
                                      err_msg=f"live request {i}")
    snap = server.metrics.snapshot()
    assert snap["completed"] == 3 and snap["deadline_misses"] == 0
    assert all(lat >= 0.005 for lat in server.metrics.latency_s)


def test_donate_default_backend_aware(packed):
    """``donate=None`` resolves off on CPU (XLA implements no donation
    there) and on for accelerator backends; an explicit value wins."""
    server = StreamServer(packed, policy=_policy(), clock=VirtualClock())
    assert server.donate == (jax.default_backend() != "cpu")
    assert StreamServer(packed, policy=_policy(), clock=VirtualClock(),
                        donate=True).donate is True
    assert should_donate(False) is False
    assert should_donate(True) is True


def test_donate_plumbs_through_dispatch(rng, packed, monkeypatch):
    """The server's donation choice reaches the engine on every dispatch
    (the padded bucket buffer is what gets donated)."""
    seen = []
    real = serving_mod.br.run_batched

    def spy(model, padded, **kw):
        seen.append(kw.pop("donate"))
        return real(model, padded, donate=False, **kw)

    monkeypatch.setattr(serving_mod.br, "run_batched", spy)
    server = StreamServer(packed, policy=_policy(), clock=VirtualClock(),
                          donate=True)
    for s in _streams(rng, [3, 5, 6]):
        server.submit(s)
    server.flush()
    assert seen and all(d is True for d in seen)


def test_hot_dispatches_add_no_device_copies(rng, packed):
    """Across back-to-back dispatches of the same bucket, the number of
    live device buffers stays flat: each dispatch's padded input is
    released, not accumulated (off-CPU the donated buffer is recycled
    in-place; on CPU this asserts the no-leak baseline the donation
    preserves)."""
    policy = BucketPolicy(batch_sizes=(2,), time_steps=(8,))
    server = StreamServer(packed, policy=policy, clock=VirtualClock())

    def dispatch_pair():
        for s in _streams(rng, [5, 6]):
            server.submit(s)            # 2 = max_batch -> dispatches
        assert len(server.collect()) == 2

    dispatch_pair()                     # warm the jit + constant caches
    gc.collect()
    n0 = len(jax.live_arrays())
    for _ in range(4):
        dispatch_pair()
    gc.collect()
    assert len(jax.live_arrays()) == n0, \
        "serving dispatches leaked device buffers"
