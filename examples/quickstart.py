"""Quickstart: the full MENAGE flow (paper Algorithm 1) in ~60 lines.

Trains a small spiking MLP on a synthetic event dataset, prunes + quantizes
it, solves the ILP mapping, builds the control memories, executes the input
on the cycle-level accelerator twin, and prints the Table-II-style energy
report.

  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import numpy as np

from repro.core.accelerator import map_model, reference_forward, run
from repro.core.energy import AcceleratorSpec
from repro.core.prune import prune_pytree, sparsity
from repro.core.quant import quantize_pytree
from repro.data.events import EventDatasetConfig, event_batches, synthetic_event_dataset
from repro.engine import (BucketPolicy, MLP_MODEL, SNNTrainConfig,
                          run_batched, run_bucketed, trace_count,
                          train_snn_model)
from repro.snn.mlp import SNNConfig, snn_forward_batch_major


def main():
    # 1. data + model (a small N-MNIST-like setup)
    data_cfg = EventDatasetConfig("quickstart", 16, 16, num_steps=20,
                                  base_rate=0.01, signal_rate=0.4)
    snn_cfg = SNNConfig(layer_sizes=(data_cfg.n_in, 64, 32, 10), num_steps=20)
    spikes, labels = synthetic_event_dataset(data_cfg, n_per_class=16,
                                             key=jax.random.key(0))

    # 2. train (surrogate gradients, unified engine loop), prune, quantize
    #    (Algorithm 1 steps 1-3)
    it = event_batches(spikes, labels, batch=32)
    params, hist = train_snn_model(MLP_MODEL, snn_cfg, it,
                                   SNNTrainConfig(steps=200, log_every=100),
                                   key=jax.random.key(1))
    print(f"trained: final loss={hist['loss'][-1]:.3f} "
          f"acc={hist['acc'][-1]:.2f}")
    pruned, _ = prune_pytree(params, 0.5)
    _, weights = quantize_pytree(pruned)
    print(f"pruned to {sparsity(pruned):.0%} sparsity, 8-bit quantized")

    # 3. ILP mapping onto an accelerator design point (steps 4-5)
    accel = AcceleratorSpec("quickstart", n_cores=3, n_engines=8, n_caps=8,
                            weight_mem_bytes=1 << 20)
    model = map_model([np.asarray(w) for w in weights], accel,
                      lif=snn_cfg.lif)
    for li, layer in enumerate(model.layers):
        print(f"  layer {li}: {layer.n_dest} neurons -> "
              f"{len(layer.rounds)} round(s), "
              f"{layer.tables.n_rows} MEM_S&N rows")

    # 4. execute one input through the MX-NEURACORE chain
    res = run(model, spikes[0])
    ref = reference_forward([l.w_q for l in model.layers], snn_cfg.lif,
                            spikes[0])
    assert np.array_equal(res.out_spikes, ref), "HW twin != reference!"
    pred = res.out_spikes.sum(axis=0).argmax()
    print(f"prediction: class {pred} (label {labels[0]}), "
          f"bit-exact vs dense reference")

    # 5. energy report (calibrated Table-II model)
    e = res.energy
    print(f"energy: {e.tops_per_w:.2f} TOPS/W  "
          f"({e.total_ops} ops, util {e.utilization:.1%}, "
          f"dynamic {e.dynamic_j*1e9:.1f} nJ, static {e.static_j*1e9:.1f} nJ)")

    # 6. batched engine: the same memories, jit-compiled over a whole batch
    batch = np.asarray(spikes[:8])
    packed = model.pack()
    res_b = run_batched(packed, batch)      # traces once
    t0 = time.perf_counter()
    res_b = run_batched(packed, batch)
    dt = time.perf_counter() - t0
    assert np.array_equal(res_b.out_spikes[0], res.out_spikes), \
        "batched engine != cycle-accurate twin!"
    preds = res_b.out_spikes.sum(axis=1).argmax(axis=1)
    counts, _ = snn_forward_batch_major([jax.numpy.asarray(l.w_q)
                                         for l in model.layers],
                                        batch, snn_cfg)
    agree = float((np.asarray(counts).argmax(-1) == preds).mean())
    print(f"batched engine: {len(batch)} samples in {dt*1e3:.1f} ms, "
          f"preds {preds.tolist()} (labels {labels[:8].tolist()}), "
          f"{agree:.0%} agreement with the training-graph forward")

    # 7. serving: variable-length requests, bucketed so the jit cache stays
    #    bounded (every result still bit-exact vs the oracle)
    rng = np.random.default_rng(7)
    streams = [spikes[i, :rng.integers(5, 21)] for i in range(12)]
    policy = BucketPolicy(batch_sizes=(4, 8), time_steps=(10, 20))
    n0 = trace_count()
    served = run_bucketed(packed, streams, policy=policy)
    assert np.array_equal(served[0].out_spikes,
                          run(model, streams[0]).out_spikes), \
        "bucketed serving != cycle-accurate twin!"
    print(f"served {len(streams)} requests of {sorted({len(s) for s in streams})} "
          f"steps in {trace_count() - n0} jit trace(s) "
          f"(<= {policy.n_buckets} buckets)")


if __name__ == "__main__":
    main()
