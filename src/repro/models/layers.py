"""Shared model layers + the param-spec system.

Params are nested dicts of arrays; every param is declared through a
:class:`P` spec carrying its *logical axis names*, so initialization and
sharding annotations can never drift apart.  Logical axes are mapped to mesh
axes by the rules in ``repro.parallel.sharding``.

Logical axis vocabulary (weights):
  layers      — scanned layer stack dim (never sharded)
  embed       — model width on weights (FSDP -> 'data')
  heads/kv_heads — attention heads (TP -> 'model')
  head_dim    — per-head width (unsharded)
  mlp         — FFN hidden (TP -> 'model')
  vocab       — embedding rows / logits (TP -> 'model')
  experts     — MoE expert dim (EP -> 'model' when divisible)
  expert_mlp  — per-expert FFN hidden (TP fallback for MoE)
  ssm_*       — Mamba2 dims
Activations use the ``act_*`` names (see sharding.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- param specs

@dataclasses.dataclass(frozen=True)
class P:
    """Param spec: shape + logical axes + init."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | embed
    scale: float | None = None    # stddev override

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def init_params(key: jax.Array, specs: Any, dtype=jnp.float32):
    """Materialize a pytree of P specs into arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, spec in zip(keys, leaves):
        assert isinstance(spec, P), f"non-spec leaf {spec!r}"
        if spec.init == "zeros":
            a = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            a = jnp.ones(spec.shape, dtype)
        else:
            if spec.scale is not None:
                std = spec.scale
            elif spec.init == "embed":
                std = 1.0
            else:  # fan-in
                fan_in = spec.shape[0] if len(spec.shape) == 1 else math.prod(
                    spec.shape[:-1])
                # for stacked-layer weights the leading 'layers' dim is not fan-in
                if len(spec.axes) >= 2 and spec.axes[0] == "layers":
                    fan_in = math.prod(spec.shape[1:-1]) or spec.shape[-1]
                std = 1.0 / math.sqrt(max(fan_in, 1))
            a = std * jax.random.normal(k, spec.shape, dtype)
        out.append(a)
    return jax.tree.unflatten(treedef, out)


def param_axes(specs: Any):
    """Same pytree, leaves replaced by the logical-axes tuples."""
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, P))


def abstract_params(specs: Any, dtype=jnp.float32):
    """ShapeDtypeStruct pytree (for AOT lowering without allocation)."""
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------- primitives

def bf16_layers(tree):
    """Cast a stacked-layer param pytree to bf16 ONCE, outside the scan
    (§Perf iteration 2): the per-layer FSDP all-gather inside the scan then
    moves bf16 (half the bytes) and each weight converts once per step
    instead of once per layer visit (fwd + bwd + remat)."""
    return jax.tree.map(
        lambda a: a.astype(jnp.bfloat16)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, tree)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dtype)


def rotary_embed(x: jax.Array, positions: jax.Array,
                 theta: float = 10000.0) -> jax.Array:
    """RoPE.  x: [..., S, H, D] (D even); positions: [..., S] int."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., S, half]
    ang = ang[..., None, :]                                     # broadcast heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    """SwiGLU FFN: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


# ------------------------------------------------------- chunked flash attn

def _attn_chunk(q, k, v, qpos, kpos, window: int | None, causal: bool,
                softmax_scale: float):
    """One (q-chunk x kv-chunk) tile of online-softmax attention.

    q: [B, Qc, KH, G, D]; k, v: [B, Kc, KH, D]; returns (m, l, o) partials.

    Numerics (§Perf iteration 1): operands stay in their storage dtype
    (bf16) — the QK^T and PV matmuls accumulate in f32 via
    ``preferred_element_type`` instead of upcasting K/V, which removed the
    per-q-chunk full-KV f32 convert+copy the baseline HLO showed.
    Set REPRO_BASELINE_ATTN=1 to restore the pre-iteration-1 numerics (used
    to reproduce the §Perf baseline measurements).
    """
    import os as _os
    if _os.environ.get("REPRO_BASELINE_ATTN"):
        s = jnp.einsum("bqhgd,bkhd->bqhgk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * softmax_scale
        mask = jnp.ones((q.shape[1], k.shape[1]), dtype=bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m = jnp.max(s, axis=-1)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
        return m, l, o
    s = jnp.einsum("bqhgd,bkhd->bqhgk", q, k,
                   preferred_element_type=jnp.float32) * softmax_scale
    mask = jnp.ones((q.shape[1], k.shape[1]), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)                                   # [B,Qc,KH,G]
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(mask[None, :, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m, l, o


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True, window: int | None = None,
                    q_chunk: int = 512, kv_chunk: int = 512,
                    q_offset: int = 0) -> jax.Array:
    """Memory-bounded attention with GQA.

    q: [B, Sq, H, D]; k, v: [B, Sk, KH, D]; H % KH == 0.
    Scans over q chunks (rematerialized) with an inner scan over kv chunks —
    peak live buffer is O(q_chunk * kv_chunk), never S^2.
    ``q_offset``: absolute position of q[0] (cross/self prefill alignment).
    """
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    assert h % kh == 0
    g = h // kh
    scale = 1.0 / math.sqrt(d)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    # pad to multiples
    pad_q = (-sq) % q_chunk
    pad_k = (-sk) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // kv_chunk
    qp = qp.reshape(b, nq, q_chunk, kh, g, d)
    kp = kp.reshape(b, nk, kv_chunk, kh, d)
    vp = vp.reshape(b, nk, kv_chunk, kh, d)
    kpos_all = jnp.arange(nk * kv_chunk)
    kv_valid = kpos_all < sk

    def one_q_chunk(qi_and_chunk):
        qi, qc = qi_and_chunk
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def body(carry, inputs):
            m, l, o = carry
            kc, vc, ki = inputs
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask_valid = kpos < sk
            kpos = jnp.where(mask_valid, kpos, jnp.iinfo(jnp.int32).max)
            mi, li, oi = _attn_chunk(qc, kc, vc, qpos, kpos, window, causal,
                                     scale)
            m_new = jnp.maximum(m, mi)
            m_new_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            a = jnp.exp(jnp.where(jnp.isfinite(m), m, -jnp.inf) - m_new_safe)
            bcoef = jnp.exp(jnp.where(jnp.isfinite(mi), mi, -jnp.inf) - m_new_safe)
            l_new = a * l + bcoef * li
            o_new = a[..., None] * o + bcoef[..., None] * oi
            return (m_new, l_new, o_new), None

        m0 = jnp.full((b, q_chunk, kh, g), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, kh, g), jnp.float32)
        o0 = jnp.zeros((b, q_chunk, kh, g, d), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            body, (m0, l0, o0),
            (kp.swapaxes(0, 1), vp.swapaxes(0, 1), jnp.arange(nk)))
        return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    outs = jax.lax.map(jax.checkpoint(one_q_chunk),
                       (jnp.arange(nq), qp.swapaxes(0, 1)))
    out = outs.swapaxes(0, 1).reshape(b, nq * q_chunk, h, d)
    return out[:, :sq]


def naive_attention(q, k, v, *, causal=True, window=None, q_offset: int = 0):
    """O(S^2) oracle for flash_attention (tests only)."""
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    g = h // kh
    qr = q.reshape(b, sq, kh, g, d)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qr, k) / math.sqrt(d)
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqhgk,bkhd->bqhgd", p, v).reshape(b, sq, h, d)


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean token cross-entropy.  logits [..., V], targets [...] int."""
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
