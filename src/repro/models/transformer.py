"""Decoder-only transformer LM: GQA, RoPE, RMSNorm, SWA, MoE, VLM prefix.

One composable implementation covers the dense (internlm2, deepseek-67b,
h2o-danube), MoE (qwen3-moe, mixtral), and VLM-backbone (internvl2) assigned
architectures.  Layers are stacked along a leading ``layers`` axis and
executed with ``jax.lax.scan`` (+ remat) so the compiled HLO is O(1) in depth
— the standard production pattern (MaxText) and what keeps the 512-device
dry-run compile tractable.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.common import ArchConfig
from repro.models.layers import (P, bf16_layers as L_bf16, cross_entropy,
                                 flash_attention, init_params, param_axes,
                                 rms_norm, rotary_embed, swiglu)
from repro.parallel.sharding import shard


# ----------------------------------------------------------------- specs

def transformer_specs(cfg: ArchConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim()
    h, kh, L = cfg.n_heads, cfg.n_kv_heads, cfg.n_layers
    layer: dict[str, P] = {
        "ln1": P((L, d), ("layers", "embed"), "ones"),
        "ln2": P((L, d), ("layers", "embed"), "ones"),
        "wq": P((L, d, h, hd), ("layers", "embed", "heads", "head_dim")),
        "wk": P((L, d, kh, hd), ("layers", "embed", "kv_heads", "head_dim")),
        "wv": P((L, d, kh, hd), ("layers", "embed", "kv_heads", "head_dim")),
        "wo": P((L, h, hd, d), ("layers", "heads", "head_dim", "embed")),
    }
    if cfg.n_experts:
        e, eff = cfg.n_experts, cfg.d_ff
        layer.update({
            "router": P((L, d, e), ("layers", "embed", "experts")),
            "we_gate": P((L, e, d, eff), ("layers", "experts", "expert_embed", "expert_mlp")),
            "we_up": P((L, e, d, eff), ("layers", "experts", "expert_embed", "expert_mlp")),
            "we_down": P((L, e, eff, d), ("layers", "experts", "expert_mlp", "expert_embed")),
        })
    else:
        layer.update({
            "w_gate": P((L, d, cfg.d_ff), ("layers", "embed", "mlp")),
            "w_up": P((L, d, cfg.d_ff), ("layers", "embed", "mlp")),
            "w_down": P((L, cfg.d_ff, d), ("layers", "mlp", "embed")),
        })
    return {
        "embed": P((cfg.vocab_size, d), ("vocab", "embed"), "embed", scale=0.02),
        "lm_head": P((d, cfg.vocab_size), ("embed", "vocab")),
        "ln_f": P((d,), ("embed",), "ones"),
        "layers": layer,
    }


def transformer_axes(cfg: ArchConfig):
    return param_axes(transformer_specs(cfg))


def init_transformer(key: jax.Array, cfg: ArchConfig, dtype=jnp.float32):
    return init_params(key, transformer_specs(cfg), dtype)


# ----------------------------------------------------------------- MoE FFN

def moe_ffn(x: jax.Array, lp: dict, cfg: ArchConfig,
            capacity_factor: float = 1.25):
    """Token-choice top-k MoE with sort-based static-capacity dispatch.

    x: [B, S, d].  Returns (y, aux_loss).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf, lp["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)                  # [t, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (t * k))
    aux = e * jnp.sum(me * ce)

    cap = int(2 ** math.ceil(math.log2(max(t * k / e * capacity_factor, 1))))
    cap = min(cap, t)
    # sort (token,k) pairs by expert; position within expert via searchsorted
    flat_e = expert_idx.reshape(-1)                             # [t*k]
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    grp_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(t * k) - grp_start
    slot = sorted_e * cap + pos_in_e                            # [t*k]
    keep = pos_in_e < cap
    token_of = order // k                                       # source token
    # dispatch: [e*cap, d]
    disp = jnp.zeros((e * cap, d), x.dtype)
    disp = disp.at[jnp.where(keep, slot, e * cap)].add(
        xf[token_of], mode="drop")
    disp = shard(disp.reshape(e, cap, d), "act_experts", "act_expert_cap",
                 "act_embed")
    # expert FFN
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", disp, lp["we_gate"]))
    u = jnp.einsum("ecd,edf->ecf", disp, lp["we_up"])
    out = jnp.einsum("ecf,efd->ecd", g * u, lp["we_down"])
    out = shard(out, "act_experts", "act_expert_cap", "act_embed").reshape(
        e * cap, d)
    # combine
    contrib = out[jnp.where(keep, slot, 0)] * (
        keep * gate.reshape(-1)[order])[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[token_of].add(contrib)
    return y.reshape(b, s, d), aux


# ------------------------------------------------------------- layer body

def _attn_block(x: jax.Array, lp: dict, cfg: ArchConfig, positions: jax.Array,
                q_chunk: int, kv_chunk: int):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
    kk = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
    q = shard(q, "act_batch", "act_seq", "act_heads", "act_head_dim")
    kk = shard(kk, "act_batch", "act_seq", "act_kv_heads", "act_head_dim")
    q = rotary_embed(q, positions, cfg.rope_theta)
    kk = rotary_embed(kk, positions, cfg.rope_theta)
    o = flash_attention(q, kk, v, causal=True, window=cfg.window,
                        q_chunk=q_chunk, kv_chunk=kv_chunk)
    o = jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
    return x + shard(o, "act_batch", "act_seq", "act_embed")


def _ffn_block(x: jax.Array, lp: dict, cfg: ArchConfig):
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        # §Perf iteration 4: under an active multi-device mesh, use the
        # shard_map MoE (locality-exact dispatch, psum-only collectives);
        # the GSPMD einsum path remains as the single-device/test fallback.
        import os as _os
        from repro.parallel.sharding import active_mesh
        mesh = active_mesh()
        if mesh is not None and "model" in mesh.axis_names \
                and mesh.devices.size > 1 \
                and not _os.environ.get("REPRO_BASELINE_MOE"):
            from repro.parallel.moe import moe_ffn_sharded
            y, aux = moe_ffn_sharded(h, lp, cfg, mesh)
        else:
            y, aux = moe_ffn(h, lp, cfg)
    else:
        y = swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
        y = shard(y, "act_batch", "act_seq", "act_embed")
        aux = jnp.zeros((), jnp.float32)
    return x + y, aux


def transformer_layer(x, lp, cfg: ArchConfig, positions, q_chunk=512,
                      kv_chunk=512):
    x = _attn_block(x, lp, cfg, positions, q_chunk, kv_chunk)
    x, aux = _ffn_block(x, lp, cfg)
    return x, aux


# ------------------------------------------------------------- full forward

def transformer_logits(params: dict, cfg: ArchConfig, tokens: jax.Array,
                       image_embeds: jax.Array | None = None,
                       q_chunk: int = 1024, kv_chunk: int = 2048,
                       remat: bool = True) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  tokens [B, S] -> (logits [B, S, V], aux)."""
    import os as _os
    if _os.environ.get("REPRO_BASELINE_CHUNKS"):   # §Perf iteration 3 baseline
        q_chunk, kv_chunk = 512, 512
    b, s = tokens.shape
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    x = x.astype(jnp.bfloat16)
    if image_embeds is not None:
        n_img = image_embeds.shape[1]
        x = jax.lax.dynamic_update_slice(
            x, image_embeds.astype(x.dtype), (0, 0, 0))
        del n_img
    x = shard(x, "act_batch", "act_seq", "act_embed")
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, lp):
        xx, aux = carry
        xx, a = transformer_layer(xx, lp, cfg, positions, q_chunk, kv_chunk)
        return (xx, aux + a), None

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               L_bf16(params["layers"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(jnp.bfloat16))
    logits = shard(logits, "act_batch", "act_seq", "act_vocab")
    return logits, aux


def transformer_loss(params, cfg: ArchConfig, batch: dict,
                     q_chunk: int = 1024, kv_chunk: int = 2048) -> jax.Array:
    toks = batch["tokens"]
    inputs, targets = toks[:, :-1], toks[:, 1:]
    logits, aux = transformer_logits(params, cfg, inputs,
                                     batch.get("image_embeds"),
                                     q_chunk, kv_chunk)
    return cross_entropy(logits, targets) + 0.01 * aux


# ------------------------------------------------------------------ decode

def cache_spec(cfg: ArchConfig, batch: int, cache_len: int):
    """ShapeDtypeStructs of the KV cache pytree (+ logical axes)."""
    hd = cfg.resolved_head_dim()
    clen = min(cache_len, cfg.window) if cfg.window else cache_len
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, clen, hd)
    axes = ("layers", "cache_batch", "cache_kv_heads", "cache_seq",
            "act_head_dim")
    return ({"k": jax.ShapeDtypeStruct(shape, jnp.bfloat16),
             "v": jax.ShapeDtypeStruct(shape, jnp.bfloat16)},
            {"k": axes, "v": axes})


def init_cache(cfg: ArchConfig, batch: int, cache_len: int):
    spec, _ = cache_spec(cfg, batch, cache_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def _cache_positions(cfg: ArchConfig, clen: int, pos: jax.Array) -> jax.Array:
    """Absolute position held by each cache slot (ring buffer for SWA)."""
    idx = jnp.arange(clen)
    if cfg.window:
        # slot i holds the largest p <= pos with p % clen == i
        p = pos - ((pos - idx) % clen)
        return jnp.where(p < 0, -1, p)
    return jnp.where(idx <= pos, idx, -1)


def decode_attention(q, ck, cv, slot_pos, pos, window):
    """q [B,H,hd]; ck/cv [B,KH,C,hd]; slot_pos [C] absolute positions, -1
    invalid.  Plain (baseline) attention over the cache."""
    b, h, hd = q.shape
    kh = ck.shape[1]
    g = h // kh
    qr = q.reshape(b, kh, g, hd)
    s = jnp.einsum("bhgd,bhcd->bhgc", qr, ck.astype(qr.dtype)) / math.sqrt(hd)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        valid &= (pos - slot_pos) < window
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(qr.dtype)
    o = jnp.einsum("bhgc,bhcd->bhgd", p, cv.astype(qr.dtype))
    return o.reshape(b, h, hd)


def transformer_decode_step(params: dict, cfg: ArchConfig, cache: dict,
                            tokens: jax.Array, pos: jax.Array,
                            attn_impl=decode_attention):
    """One decode step.  tokens [B] int32; pos scalar int32 (next position).

    Returns (logits [B, V], new_cache).  ``attn_impl`` is swappable — the SP
    flash-decode path (parallel/decode.py) plugs in here.
    """
    b = tokens.shape[0]
    hd = cfg.resolved_head_dim()
    clen = cache["k"].shape[3]
    slot = pos % clen if cfg.window else pos
    slot_pos = _cache_positions(cfg, clen, pos)
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    x = x.astype(jnp.bfloat16)
    x = shard(x, "act_batch", "act_embed")

    def body(x, layer_in):
        lp, ck, cv = layer_in
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bd,dhk->bhk", h, lp["wq"])
        k_new = jnp.einsum("bd,dhk->bhk", h, lp["wk"])
        v_new = jnp.einsum("bd,dhk->bhk", h, lp["wv"])
        posb = jnp.broadcast_to(pos, (b, 1))
        q = rotary_embed(q[:, None], posb, cfg.rope_theta)[:, 0]
        k_new = rotary_embed(k_new[:, None], posb, cfg.rope_theta)[:, 0]
        ck = jax.lax.dynamic_update_slice(ck, k_new.astype(ck.dtype)[:, :, None],
                                          (0, 0, slot, 0))
        cv = jax.lax.dynamic_update_slice(cv, v_new.astype(cv.dtype)[:, :, None],
                                          (0, 0, slot, 0))
        o = attn_impl(q, ck, cv, slot_pos, pos, cfg.window)
        x = x + jnp.einsum("bhk,hkd->bd", o, lp["wo"])
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            y, _ = moe_ffn(h2[:, None], lp, cfg)
            y = y[:, 0]
        else:
            y = swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"])
        x = x + y
        x = shard(x, "act_batch", "act_embed")
        return x, (ck, cv)

    x, (nk, nv) = jax.lax.scan(body, x,
                               (L_bf16(params["layers"]), cache["k"],
                                cache["v"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x, params["lm_head"].astype(jnp.bfloat16))
    logits = shard(logits, "act_batch", "act_vocab")
    return logits, {"k": nk, "v": nv}


def transformer_prefill(params: dict, cfg: ArchConfig, tokens: jax.Array,
                        image_embeds: jax.Array | None = None,
                        q_chunk: int = 512, kv_chunk: int = 512):
    """Prefill: single pass that emits the KV cache (the artifact a serving
    system keeps) per scanned layer and last-position logits.

    SWA archs keep only the last ``window`` positions, ring-buffer-aligned
    with ``transformer_decode_step``'s slot convention (slot = pos % window).
    """
    b, s = tokens.shape
    x = params["embed"][tokens].astype(jnp.bfloat16) * math.sqrt(cfg.d_model)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if image_embeds is not None:
        x = jax.lax.dynamic_update_slice(x, image_embeds.astype(x.dtype),
                                         (0, 0, 0))
    x = shard(x, "act_batch", "act_seq", "act_embed")

    def body(xx, lp):
        h = rms_norm(xx, lp["ln1"], cfg.norm_eps)
        kk = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        kk = rotary_embed(kk, positions, cfg.rope_theta)
        vv = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        xx = _attn_block(xx, lp, cfg, positions, q_chunk, kv_chunk)
        xx, _ = _ffn_block(xx, lp, cfg)
        ck = kk.transpose(0, 2, 1, 3)      # [B, KH, S, hd]
        cv = vv.transpose(0, 2, 1, 3)
        if cfg.window and cfg.window < s:
            w = cfg.window
            ck = jnp.roll(ck[:, :, -w:], shift=s % w, axis=2)
            cv = jnp.roll(cv[:, :, -w:], shift=s % w, axis=2)
        ck = shard(ck.astype(jnp.bfloat16), "cache_batch", "cache_kv_heads",
                   "cache_seq", "act_head_dim")
        cv = shard(cv.astype(jnp.bfloat16), "cache_batch", "cache_kv_heads",
                   "cache_seq", "act_head_dim")
        return xx, (ck, cv)

    x, (k_all, v_all) = jax.lax.scan(body, x, L_bf16(params["layers"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1],
                        params["lm_head"].astype(jnp.bfloat16))
    logits = shard(logits, "act_batch", "act_vocab")
    return logits, {"k": k_all, "v": v_all}
