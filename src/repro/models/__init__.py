from repro.models.api import ModelBundle, build_model  # noqa: F401
