"""The paper's own configurations (§IV-A): two SNN models x two accelerator
design points."""

from repro.core.energy import ACCEL_1, ACCEL_2  # noqa: F401
from repro.core.lif import LIFParams
from repro.data.events import EventDatasetConfig
from repro.snn.conv import ConvSNNConfig
from repro.snn.mlp import SNNConfig

# N-MNIST: 200/100/40/10 MLP on Accel_1 (4 cores, M=10, N=16, 400 KB/core)
NMNIST_DATA = EventDatasetConfig.nmnist_like()
NMNIST_SNN = SNNConfig(layer_sizes=(NMNIST_DATA.n_in, 200, 100, 40, 10),
                       lif=LIFParams(beta=0.9, threshold=1.0),
                       num_steps=25)

# CIFAR10-DVS: 1000/500/200/100/10 MLP on Accel_2 (5 cores, M=20, N=32, 20 MB)
CIFAR_DATA = EventDatasetConfig.cifar10_dvs_like()
CIFAR_SNN = SNNConfig(layer_sizes=(CIFAR_DATA.n_in, 1000, 500, 200, 100, 10),
                      lif=LIFParams(beta=0.9, threshold=1.0),
                      num_steps=25)

# Conv counterpart on the same synthetic CIFAR10-DVS stream (§III claims
# linear AND convolutional models; Table II implies the split).  Five mapped
# layers — conv, pool, conv, pool, dense — one per Accel_2 MX-NEURACORE.
# Default down=8 keeps the CPU-hosted cycle-level oracle tractable.
CIFAR_CONV_DATA = EventDatasetConfig.cifar10_dvs_like(down=8)
CIFAR_CONV = ConvSNNConfig(
    in_shape=(2, 128 // 8, 128 // 8),
    conv_channels=(8, 16), kernel_size=3, stride=1, padding=1, pool=2,
    lif=LIFParams(beta=0.9, threshold=1.0), num_steps=25)

TRAIN_PARAMS = {  # Table I
    "nmnist": {"lr": 1e-3, "epochs": 50, "prune": "l1", "quant_bits": 8},
    "cifar10_dvs": {"lr": 1e-3, "epochs": 100, "prune": "l1", "quant_bits": 8},
}
