"""Unified sharded SNN training engine (engine/snn_train.py).

Contracts under test:

  * **bit-exact sharding** (the serving suite's equivalence discipline,
    applied to training): with the gradient pinned to a ``grad_shards``-way
    fixed-order chunk fold, training on a 1×N spoofed device mesh produces
    the *identical* loss trajectory and final parameters as single-device
    training on the same data order — for the MLP and the conv family;
  * **dynamic learning rate**: the unified step takes ``lr`` as a traced
    scalar, so two different rates cost exactly one trace (the old
    ``snn/mlp.py:_train_step`` made ``lr`` a static argname and retraced
    per value — regression-locked here);
  * **engine machinery**: resume from an async checkpoint continues onto
    the uninterrupted trajectory (step-keyed data), and elastic restart —
    checkpoint on 8 devices, resume on 4 — matches the uninterrupted run
    for the conv SNN (mirrors test_elastic.py for the transformer stack).
"""

import os
import subprocess
import sys

import jax
import numpy as np

from repro.data.events import (EventDatasetConfig, event_batch_at,
                               synthetic_event_dataset)
from repro.engine.snn_train import (CONV_MODEL, MLP_MODEL, SNNModel,
                                    SNNTrainConfig, _batch_split,
                                    make_snn_train_step, model_for,
                                    snn_train_mesh, snn_train_trace_count,
                                    train_snn_model)
from repro.engine.train_loop import init_train_state
from repro.snn.conv import ConvSNNConfig
from repro.snn.mlp import SNNConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DATA = EventDatasetConfig("train-test", 8, 8, num_steps=8, base_rate=0.02,
                          signal_rate=0.5)
MLP_CFG = SNNConfig(layer_sizes=(DATA.n_in, 24, 10), num_steps=8)
CONV_CFG = ConvSNNConfig(in_shape=(2, 8, 8), conv_channels=(4,),
                         num_steps=8)


def _dataset():
    return synthetic_event_dataset(DATA, n_per_class=8, key=jax.random.key(0))


def _batch_of(spikes, labels, batch=16):
    def fn(step):
        return event_batch_at(spikes, labels, batch, step)
    return fn


def _run(script: str, devices: int, *argv: str) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    pre = (f'import os; os.environ["XLA_FLAGS"] = '
           f'"--xla_force_host_platform_device_count={devices}"\n')
    p = subprocess.run([sys.executable, "-c", pre + script, *argv],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=900)
    assert p.returncode == 0, (p.stdout[-2000:], p.stderr[-4000:])
    return p.stdout


# --------------------------------------------------------------- basic loop

def test_unified_training_converges(tmp_path):
    spikes, labels = _dataset()
    cfg = SNNTrainConfig(steps=40, lr=2e-3, log_every=1000)
    params, hist = train_snn_model(MLP_MODEL, MLP_CFG,
                                   _batch_of(spikes, labels), cfg,
                                   key=jax.random.key(1),
                                   log_fn=lambda s: None)
    assert hist["loss"][-1] < hist["loss"][0]
    # the generic metric recording carries accuracy and the AdamW internals
    assert len(hist["acc"]) == 40 and len(hist["grad_norm"]) == 40
    assert hist["lr"][-1] == np.float32(2e-3)
    assert len(params) == len(MLP_CFG.layer_sizes) - 1


def test_model_protocol_dispatch():
    assert model_for(MLP_CFG) is MLP_MODEL
    assert model_for(CONV_CFG) is CONV_MODEL
    assert isinstance(MLP_MODEL, SNNModel)
    assert isinstance(CONV_MODEL, SNNModel)
    # layer_specs lowers what forward trains: conv yields conv/pool/dense
    params = CONV_MODEL.init(jax.random.key(0), CONV_CFG)
    specs = CONV_MODEL.layer_specs(params, CONV_CFG)
    assert len(specs) == 3          # Conv2d, SumPool2d, Dense head


# ------------------------------------------------------------- dynamic lr

def test_lr_is_dynamic_one_trace_across_rates():
    """Regression for the old retrace-per-lr bug: two different learning
    rates through the unified step must cost exactly one jit trace, and
    both rates must actually take effect."""
    spikes, labels = _dataset()
    model, cfg = MLP_MODEL, MLP_CFG
    opt_cfg = SNNTrainConfig(lr=1e-3).adamw()
    step = make_snn_train_step(model, cfg, opt_cfg, donate=False)
    params = model.init(jax.random.key(1), cfg)
    state0 = init_train_state(None, params, opt_cfg).as_tree()
    sp, lb = event_batch_at(spikes, labels, 16, 0)
    batch = {"spikes": jax.numpy.asarray(sp), "labels": jax.numpy.asarray(lb)}
    n0 = snn_train_trace_count()
    outs = {}
    for lr in (1e-3, 1e-2):
        s, metrics = step(dict(state0),
                          dict(batch, lr=jax.numpy.float32(lr)))
        assert float(metrics["lr"]) == np.float32(lr)
        outs[lr] = np.asarray(s["params"][0])
    assert snn_train_trace_count() - n0 == 1, \
        "a second learning rate retraced the unified train step"
    assert not np.array_equal(outs[1e-3], outs[1e-2]), \
        "the dynamic lr was ignored by the update"


# ------------------------------------------------- resume (async checkpoints)

def test_resume_matches_uninterrupted(tmp_path):
    """Stop at step 10, re-launch with the same checkpoint dir: the final
    params are bit-identical to an uninterrupted 20-step run (step-keyed
    data, exactly-once restart — the train_loop machinery, now carrying
    SNN training)."""
    spikes, labels = _dataset()
    data = _batch_of(spikes, labels)

    def run(steps, ckpt):
        cfg = SNNTrainConfig(steps=steps, lr=2e-3, checkpoint_dir=ckpt,
                             checkpoint_every=10, log_every=1000)
        return train_snn_model(MLP_MODEL, MLP_CFG, data, cfg,
                               key=jax.random.key(1), log_fn=lambda s: None)

    ref, ref_hist = run(20, str(tmp_path / "ref"))
    run(10, str(tmp_path / "ab"))                   # "preempted" at step 10
    resumed, hist = run(20, str(tmp_path / "ab"))   # picks up at step 10
    assert len(hist["loss"]) == 10                  # only the remaining steps
    np.testing.assert_array_equal(np.asarray(hist["loss"]),
                                  np.asarray(ref_hist["loss"][10:]))
    for a, b in zip(resumed, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------- sharded bit-exact

def test_mesh_matches_pinned_shards_inprocess():
    """On whatever devices exist (1 in the plain suite, 8 in the CI mesh
    re-run): training over the data mesh == single-device training with
    ``grad_shards`` pinned to the mesh's split — same losses, same params,
    bit for bit."""
    spikes, labels = _dataset()
    mesh = snn_train_mesh()
    k = _batch_split(mesh, (DATA.num_steps, 16, DATA.n_in))[0]
    data = _batch_of(spikes, labels)

    def run(**kw):
        cfg = SNNTrainConfig(steps=10, lr=2e-3, log_every=1000, **kw)
        return train_snn_model(MLP_MODEL, MLP_CFG, data, cfg,
                               key=jax.random.key(1), log_fn=lambda s: None)

    p_mesh, h_mesh = run(mesh=mesh)
    p_single, h_single = run(grad_shards=k)
    np.testing.assert_array_equal(np.asarray(h_mesh["loss"]),
                                  np.asarray(h_single["loss"]))
    for a, b in zip(p_mesh, p_single):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


_EQ_SCRIPT = r"""
import jax, numpy as np
import sys
sys.path.insert(0, "tests")
from test_snn_train import (CONV_CFG, MLP_CFG, _batch_of, _dataset)
from repro.engine.snn_train import (CONV_MODEL, MLP_MODEL, SNNTrainConfig,
                                    snn_train_mesh, train_snn_model)

assert len(jax.devices()) == 8
spikes, labels = _dataset()
data = _batch_of(spikes, labels)
mesh = snn_train_mesh()
for model, cfg in ((MLP_MODEL, MLP_CFG), (CONV_MODEL, CONV_CFG)):
    runs = {}
    for tag, kw in (("sharded", dict(mesh=mesh)),
                    ("single", dict(grad_shards=8))):
        tc = SNNTrainConfig(steps=8, lr=2e-3, log_every=1000, **kw)
        runs[tag] = train_snn_model(model, cfg, data, tc,
                                    key=jax.random.key(1),
                                    log_fn=lambda s: None)
    (ps, hs), (p1, h1) = runs["sharded"], runs["single"]
    np.testing.assert_array_equal(np.asarray(hs["loss"]),
                                  np.asarray(h1["loss"]),
                                  err_msg=f"{model.name} loss trajectory")
    for li, (a, b) in enumerate(zip(ps, p1)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            f"{model.name} params[{li}] diverged"
    print("OK", model.name, float(hs["loss"][-1]))
"""


def test_sharded_1x8_bit_exact_8dev():
    """Acceptance: on a spoofed 8-device host, data-parallel training over
    the 1×8 mesh is bit-exact with single-device training for the same
    data order — both model families."""
    out = _run(_EQ_SCRIPT, devices=8)
    assert "OK mlp" in out and "OK conv" in out


# ------------------------------------------------------------ elastic resume

_ELASTIC_SCRIPT = r"""
import sys
devices, ckpt, steps = int(sys.argv[1]), sys.argv[2], int(sys.argv[3])
import os
import jax, numpy as np
sys.path.insert(0, "tests")
from test_snn_train import CONV_CFG, _batch_of, _dataset
from repro.engine.snn_train import (CONV_MODEL, SNNTrainConfig,
                                    snn_train_mesh, train_snn_model)

assert len(jax.devices()) == devices
spikes, labels = _dataset()
# grad_shards pinned to 8: the gradient arithmetic is mesh-independent, so
# the 4-device resume continues the 8-device trajectory bit for bit
tc = SNNTrainConfig(steps=steps, lr=2e-3, mesh=snn_train_mesh(),
                    grad_shards=8, checkpoint_dir=ckpt,
                    checkpoint_every=4, log_every=1000)
params, hist = train_snn_model(CONV_MODEL, CONV_CFG,
                               _batch_of(spikes, labels), tc,
                               key=jax.random.key(1), log_fn=lambda s: None)
np.savez(os.path.join(ckpt, f"out_{steps}_{devices}.npz"),
         losses=np.asarray(hist["loss"]),
         **{f"p{i}": np.asarray(p) for i, p in enumerate(params)})
print("DONE", devices, steps)
"""


def test_elastic_conv_8dev_to_4dev(tmp_path):
    """Checkpoint conv-SNN training on a spoofed 8-device mesh at step 4,
    resume on a 4-device mesh to step 8: the loss trajectory and final
    params match the uninterrupted 8-device run exactly."""

    def phase(devices, ckpt, steps):
        out = _run(_ELASTIC_SCRIPT, devices, str(devices), ckpt, str(steps))
        assert f"DONE {devices} {steps}" in out

    ref_dir, ab_dir = str(tmp_path / "ref"), str(tmp_path / "ab")
    phase(8, ref_dir, 8)            # uninterrupted reference
    phase(8, ab_dir, 4)             # phase a: checkpoint at step 4
    phase(4, ab_dir, 8)             # phase b: elastic resume on 4 devices
    ref = np.load(os.path.join(ref_dir, "out_8_8.npz"))
    a = np.load(os.path.join(ab_dir, "out_4_8.npz"))
    b = np.load(os.path.join(ab_dir, "out_8_4.npz"))
    # phase b trained only steps 4..8; its losses are the trajectory's tail
    np.testing.assert_array_equal(b["losses"], ref["losses"][4:])
    np.testing.assert_array_equal(a["losses"], ref["losses"][:4])
    for k in ref.files:
        if k.startswith("p"):
            np.testing.assert_array_equal(b[k], ref[k],
                                          err_msg=f"elastic {k} diverged")
