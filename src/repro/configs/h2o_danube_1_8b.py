"""H2O-Danube-1.8B: dense 24L, GQA 32/8, SWA (llama+mistral mix)
[arXiv:2401.16818; hf]."""

import dataclasses

from repro.configs.common import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab_size=32000, head_dim=80,
    window=4096,
    rope_theta=10000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, window=16)
