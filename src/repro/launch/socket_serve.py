"""Live-socket serving front end: real connections -> StreamServer.

Until now the always-on loop only replayed closed traces; this launcher
binds it to a TCP socket speaking the length-prefixed
:mod:`repro.engine.ingest` protocol, so real clients (the soak harness,
``benchmarks/soak_bench.py``, or an actual DVS gateway) drive admission,
deadlines, backpressure, and chaos recovery over a live connection:

  PYTHONPATH=src python -m repro.launch.socket_serve --model mlp \
      --port 7473 [--spoof-devices 2] [--noise-sigma 0.05] \
      [--slo-target 0.1] [--smoke]
  PYTHONPATH=src python -m repro.launch.socket_serve --models mlp,conv \
      --port 7473 [--smoke]        # multi-tenant fabric, one per name

Design: a single-threaded ``selectors`` event loop.  Engine dispatches run
inline (the loop drains sockets between engine calls — exactly the
single-threaded-server model ``serve_trace`` simulates, so the soak
numbers and the VirtualClock replays describe the same machine).  The
select timeout tracks ``StreamServer.next_deadline()``, so deadline-forced
partial dispatches fire on time even when no bytes arrive.  Every request
gets an answer: results as bit-exact spike rasters, rejections (admission,
backpressure, shed, unknown model) as reasoned REJECT frames.

Multi-tenant serving: v2 REQUEST frames carry a model name and route to
that tenant of the server's :class:`~repro.engine.registry.ModelRegistry`;
v1 frames (older edge sensors) route to the default model.  ADMIN frames
are the control plane — ``{"op": "swap", "model": ..., ...}`` hot-swaps a
tenant live through the configured ``model_factory`` (in-flight requests
drain on the old weights, zero drops), ``{"op": "list"}`` enumerates
tenants and their generations, ``{"op": "metrics"}`` returns the
schema-locked ``ServerMetrics.snapshot()``, and ``{"op": "trace"}``
exports per-request span traces / the flight-recorder dump (the server
runs a :class:`~repro.engine.tracing.FlightRecorder` by default; see
``docs/OBSERVABILITY.md``).
"""

from __future__ import annotations

import argparse
import contextlib
import logging
import math
import selectors
import socket
import threading
import time

from repro.launch._spoof import (assert_spoof_applied,
                                 spoof_devices_from_argv)

_SPOOFED = spoof_devices_from_argv()  # before any jax import in this process

import numpy as np  # noqa: E402

from repro.engine import ingest  # noqa: E402
from repro.engine.registry import (ModelRegistry,  # noqa: E402
                                   UnknownModelError)
from repro.engine.serving import BucketPolicy  # noqa: E402
from repro.engine.stream_server import SLOPolicy, StreamServer  # noqa: E402
from repro.engine.tracing import FlightRecorder  # noqa: E402

_log = logging.getLogger(__name__)

# select timeout ceiling: how stale next_deadline() may get while idle
_TICK_S = 0.05


class _Conn:
    """Per-connection state: incremental decoder + in-flight accounting."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.decoder = ingest.FrameDecoder()
        self.inflight = 0
        self.draining = False       # client sent EOF; close when drained


class SpikeSocketServer:
    """A :class:`StreamServer` behind a TCP listener.

    ``serve(...)`` runs the event loop in the calling thread;
    :func:`serving_thread` wraps it for in-process harnesses.  All
    ``StreamServer`` chaos knobs (noise, SLO policy, chaos hook, mesh)
    pass through ``server_kwargs`` — the soak harness injects device loss
    into a *live* socket server exactly as the deterministic replays do.

    ``model`` is a single packed/mapped model (with ``policy``) or a
    :class:`~repro.engine.registry.ModelRegistry` (multi-tenant; leave
    ``policy`` unset).  ``model_factory(spec: dict) -> PackedModel`` turns
    an ADMIN swap request's JSON body into new weights; without one, swap
    requests are refused (the data plane is unaffected).

    A live socket server always runs a flight recorder (``tracer``; pass
    your own :class:`~repro.engine.tracing.FlightRecorder` to size the
    rings) — the ADMIN ``metrics`` / ``trace`` verbs are the wire
    export of ``ServerMetrics.snapshot()`` and the recorder.
    """

    def __init__(self, model, *, policy: BucketPolicy | None = None,
                 host: str = "127.0.0.1", port: int = 0,
                 max_request_steps: int = 4096, model_factory=None,
                 tracer: FlightRecorder | None = None,
                 **server_kwargs):
        self.tracer = tracer if tracer is not None else FlightRecorder()
        self.server = StreamServer(model, policy=policy,
                                   on_rejection=self._on_rejection,
                                   tracer=self.tracer,
                                   **server_kwargs)
        self.model_factory = model_factory
        # untrusted-input bound: a protocol-valid REQUEST header may claim
        # any u32 T; cap it before unpacking (T * n_in float32 blows up
        # ~32x over the wire size) and before it reaches admission
        self.max_request_steps = max_request_steps
        self._listener = socket.create_server((host, port))
        self._listener.setblocking(False)
        self.address = self._listener.getsockname()
        self._sel = selectors.DefaultSelector()
        self._sel.register(self._listener, selectors.EVENT_READ, None)
        self._conns: dict[socket.socket, _Conn] = {}
        self._owner: dict[int, tuple[_Conn, int]] = {}  # rid -> (conn, req_id)
        # rejections arrive via the server's on_rejection callback, an
        # unbounded outbox: post-admission sheds are answered from here by
        # _drain_new_rejections, never inferred from the bounded metrics
        # deque (which overflows under sustained shed-mode load)
        self._rej_outbox: list = []
        self._last_inline_rej = None
        self._stop = threading.Event()
        self.served = 0

    # ------------------------------------------------------------- control

    def stop(self) -> None:
        """Ask the loop to exit after its current iteration (thread-safe)."""
        self._stop.set()

    # ----------------------------------------------------------- plumbing

    def _send(self, conn: _Conn, data: bytes) -> None:
        try:
            conn.sock.sendall(data)
        except OSError:
            self._drop(conn)

    def _drop(self, conn: _Conn) -> None:
        if conn.sock not in self._conns:
            return
        with contextlib.suppress(KeyError):
            self._sel.unregister(conn.sock)
        del self._conns[conn.sock]
        conn.sock.close()
        # orphan its in-flight requests: results with no owner are dropped
        self._owner = {rid: (c, q) for rid, (c, q) in self._owner.items()
                       if c is not conn}

    def _on_rejection(self, rej) -> None:
        """StreamServer's rejection callback (fires inside ``submit``)."""
        if rej.rid is None:
            self._last_inline_rej = rej  # answered by _on_request's caller
        else:
            self._rej_outbox.append(rej)

    def _drain_new_rejections(self) -> None:
        """Answer every post-admission rejection (queued requests shed by
        backpressure) accumulated in the outbox since the last drain."""
        if not self._rej_outbox:
            return
        outbox, self._rej_outbox = self._rej_outbox, []
        for rej in outbox:
            owner = self._owner.pop(rej.rid, None)
            if owner is not None:
                conn, req_id = owner
                conn.inflight -= 1
                self._send(conn, ingest.encode_rejection(
                    req_id, f"{rej.reason}: {rej.detail}"))

    def _deliver(self, done) -> None:
        for rid, res in done:
            owner = self._owner.pop(rid, None)
            if owner is None:
                continue            # connection vanished mid-service
            conn, req_id = owner
            conn.inflight -= 1
            self.served += 1
            self._send(conn, ingest.encode_result(req_id, res.out_spikes))

    def _on_request(self, conn: _Conn, frame: ingest.Frame) -> None:
        # resolve the tenant and validate the claimed shape BEFORE
        # unpacking or submitting: a well-framed request with an unknown
        # model, the wrong raster width, or an absurd T must answer with a
        # REJECT, not raise out of the event loop and kill serving for
        # every other connected client.  v1 frames carry no model name and
        # route to the registry default.
        req_id, t, n_in, slack, model = ingest.peek_request(
            frame.payload, frame.version)
        try:
            entry = self.server.registry.get(model)
        except UnknownModelError as e:
            self._send(conn, ingest.encode_rejection(
                req_id, f"unknown_model: {e}"))
            return
        want = entry.packed.n_in
        if n_in != want:
            self._send(conn, ingest.encode_rejection(
                req_id, f"bad_shape: raster width {n_in} != model "
                        f"{entry.name!r} n_in {want}"))
            return
        if t > self.max_request_steps:
            self._send(conn, ingest.encode_rejection(
                req_id, f"overlong: {t} steps > socket cap "
                        f"{self.max_request_steps}"))
            return
        _, stream, slack, model = ingest.decode_request(
            frame.payload, frame.version)
        rid = self.server.submit(
            stream, model=model, slack=None if math.isinf(slack) else slack)
        if rid is None:
            rej = self._last_inline_rej
            self._send(conn, ingest.encode_rejection(
                req_id, f"{rej.reason}: {rej.detail}"))
            return
        self._owner[rid] = (conn, req_id)
        conn.inflight += 1

    def _on_admin(self, conn: _Conn, frame: ingest.Frame) -> None:
        """Control plane: hot-swap a tenant / list tenants / export metrics
        and traces.  Every admin request gets an ADMIN reply echoing its
        req_id; failures answer ``{"ok": false, "error": ...}`` instead of
        touching the data plane."""
        req_id, body = ingest.decode_admin(frame.payload)
        op = body.get("op")
        try:
            if op == "metrics":
                # the full schema-locked snapshot (METRIC_KEYS, with the
                # PER_MODEL_KEYS sub-table) — note json sorts keys on the
                # wire, so consumers key by name, not position
                reply = {"ok": True,
                         "metrics": self.server.metrics.snapshot()}
            elif op == "trace":
                tr = self.server.tracer
                if tr is None:
                    raise RuntimeError("tracing is disabled on this server")
                if body.get("rid") is not None:
                    t = tr.trace(int(body["rid"]))
                    if t is None:
                        raise KeyError(
                            f"no trace for rid {body['rid']} (completed "
                            f"ring keeps the last {tr.completed.maxlen})")
                    reply = {"ok": True, "trace": t.to_dict()}
                elif body.get("last"):
                    t = tr.last()
                    if t is None:
                        raise KeyError("no completed traces yet")
                    reply = {"ok": True, "trace": t.to_dict()}
                else:
                    reply = {"ok": True, "dump": tr.dump()}
            elif op == "list":
                reply = {"ok": True,
                         "default": self.server.registry.default,
                         "models": {n: self.server.registry.get(n).generation
                                    for n in self.server.registry.names()}}
            elif op == "swap":
                if self.model_factory is None:
                    raise RuntimeError("no model_factory configured; "
                                       "hot-swap is disabled on this server")
                name = body.get("model") or self.server.registry.default
                packed = self.model_factory(dict(body))
                entry = self.server.swap(name, packed)
                # the swap drained the tenant's in-flight requests on the
                # old weights — answer their owners before acking the swap
                self._deliver(self.server.collect())
                reply = {"ok": True, "model": name,
                         "generation": entry.generation}
                _log.info("socket_serve: hot-swapped %r -> generation %d",
                          name, entry.generation)
            else:
                raise ValueError(f"unknown admin op {op!r}")
        except Exception as e:  # control plane: report, never crash serving
            reply = {"ok": False, "error": f"{type(e).__name__}: {e}"}
        self._send(conn, ingest.encode_admin(req_id, reply))

    def _on_readable(self, sock: socket.socket) -> None:
        if sock is self._listener:
            client, addr = self._listener.accept()
            client.setblocking(False)
            conn = _Conn(client)
            self._conns[client] = conn
            self._sel.register(client, selectors.EVENT_READ, conn)
            _log.info("socket_serve: connection from %s", addr)
            return
        conn = self._conns[sock]
        try:
            chunk = sock.recv(1 << 16)
        except OSError:
            self._drop(conn)
            return
        if not chunk:
            # EOF: finish its in-flight, then close.  Unregister the read
            # side now — a half-closed socket is permanently readable, so
            # leaving it in the selector busy-spins select() and keeps
            # refreshing last_activity, starving the idle-flush path the
            # connection needs to ever drain.  The write side stays open
            # for the pending results.
            conn.draining = True
            with contextlib.suppress(KeyError):
                self._sel.unregister(sock)
            return
        try:
            for frame in conn.decoder.feed(chunk):
                if frame.kind == ingest.KIND_ADMIN:
                    self._on_admin(conn, frame)
                elif frame.kind == ingest.KIND_REQUEST:
                    self._on_request(conn, frame)
                else:
                    raise ingest.ProtocolError(
                        f"client sent frame kind {frame.kind}, "
                        f"expected REQUEST or ADMIN")
                # a full-bucket submit may have dispatched inline
                self._deliver(self.server.collect())
                self._drain_new_rejections()
        except ingest.ProtocolError as e:
            # the stream is corrupt beyond resync: discard this
            # connection's buffered bytes (FrameDecoder.reset) so nothing
            # re-parses them, then drop only this client — other
            # connections keep their own decoders and never notice
            dropped = conn.decoder.reset()
            _log.warning("socket_serve: protocol error, dropping client "
                         "(%d buffered bytes discarded): %s", dropped, e)
            self._drop(conn)

    # ---------------------------------------------------------------- loop

    def _tick(self) -> None:
        """One scheduler beat: fire due deadline dispatches, deliver."""
        self._deliver(self.server.poll())
        self._drain_new_rejections()
        for conn in [c for c in self._conns.values()
                     if c.draining and c.inflight == 0]:
            self._drop(conn)

    def serve(self, *, max_requests: int | None = None,
              idle_flush_s: float = 0.25) -> None:
        """Run the event loop until :meth:`stop` (or ``max_requests``
        results have been served).  ``idle_flush_s``: with pending
        best-effort requests, no deadline due, and no bytes arriving for
        this long, flush — a lone trailing request never hangs the
        socket."""
        last_activity = time.monotonic()
        while not self._stop.is_set():
            nd = self.server.next_deadline()
            timeout = (_TICK_S if nd is None
                       else min(max(nd - self.server.now(), 0.0), _TICK_S))
            events = self._sel.select(timeout)
            if events:
                last_activity = time.monotonic()
            for key, _ in events:
                self._on_readable(key.fileobj)
            self._tick()
            if (self.server.queue_depth > 0 and not events
                    and self.server.next_deadline() is None
                    and time.monotonic() - last_activity > idle_flush_s):
                self._deliver(self.server.flush())
                self._drain_new_rejections()
            if max_requests is not None and self.served >= max_requests:
                break
        self._deliver(self.server.flush())
        self._drain_new_rejections()

    def close(self) -> None:
        self.stop()
        for conn in list(self._conns.values()):
            self._drop(conn)
        with contextlib.suppress(KeyError):
            self._sel.unregister(self._listener)
        self._listener.close()
        self._sel.close()


@contextlib.contextmanager
def serving_thread(server: SpikeSocketServer, **serve_kwargs):
    """Run ``server.serve()`` on a daemon thread for in-process harnesses
    (the soak bench and the tier-1 socket test); joins and closes on
    exit."""
    t = threading.Thread(target=server.serve, kwargs=serve_kwargs,
                         daemon=True, name="spike-socket-serve")
    t.start()
    try:
        yield server
    finally:
        server.stop()
        t.join(timeout=30)
        server.close()


# ------------------------------------------------------------------ client

class SpikeClient:
    """A minimal blocking client for the ingest protocol — what the soak
    harness runs many of.  ``send`` streams a request; ``recv_all`` blocks
    until every outstanding request is answered (result or rejection)."""

    def __init__(self, host: str, port: int, timeout: float = 60.0):
        self.sock = socket.create_connection((host, port), timeout=timeout)
        self.decoder = ingest.FrameDecoder()
        self._next_id = 0
        self.results: dict[int, np.ndarray] = {}
        self.rejections: dict[int, str] = {}
        self.admin_replies: dict[int, dict] = {}

    def send(self, stream, slack: float = math.inf, *,
             model: str | None = None,
             version: int = ingest.VERSION) -> int:
        """Stream one request.  ``model`` routes to that tenant (v2);
        ``version=1`` emits a legacy frame (no model id — exercises the
        default-model compatibility path)."""
        req_id = self._next_id
        self._next_id += 1
        self.sock.sendall(ingest.encode_request(req_id, stream, slack,
                                                model=model,
                                                version=version))
        return req_id

    def admin(self, body: dict) -> int:
        """Send a control-plane request (e.g. ``{"op": "swap", "model":
        ..., ...}``); the reply lands in :attr:`admin_replies`."""
        req_id = self._next_id
        self._next_id += 1
        self.sock.sendall(ingest.encode_admin(req_id, body))
        return req_id

    def _pump(self) -> None:
        chunk = self.sock.recv(1 << 16)
        if not chunk:
            raise ConnectionError("server closed the connection")
        for frame in self.decoder.feed(chunk):
            if frame.kind == ingest.KIND_RESULT:
                req_id, out = ingest.decode_result(frame.payload)
                self.results[req_id] = out
            elif frame.kind == ingest.KIND_REJECT:
                req_id, reason = ingest.decode_rejection(frame.payload)
                self.rejections[req_id] = reason
            elif frame.kind == ingest.KIND_ADMIN:
                req_id, body = ingest.decode_admin(frame.payload)
                self.admin_replies[req_id] = body
            else:
                raise ingest.ProtocolError(
                    f"server sent frame kind {frame.kind}")

    def recv_all(self) -> None:
        """Block until every sent request has a result, a rejection, or an
        admin reply."""
        while (len(self.results) + len(self.rejections)
               + len(self.admin_replies)) < self._next_id:
            self._pump()

    def close(self) -> None:
        self.sock.close()


# --------------------------------------------------------------------- CLI

def main():
    from repro.engine.sharded_run import snn_serve_mesh
    from repro.launch.serve_snn import build_demo_model, synth_requests

    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mlp", choices=["mlp", "conv"])
    ap.add_argument("--models", default=None,
                    help="comma-separated demo model kinds (e.g. mlp,conv): "
                         "serve them as a multi-tenant fabric, one tenant "
                         "per name, with ADMIN hot-swap enabled; overrides "
                         "--model")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7473)
    ap.add_argument("--data", type=int, default=None,
                    help="mesh data-axis extent (default: all devices)")
    ap.add_argument("--spoof-devices", type=int, default=None)
    ap.add_argument("--queue-capacity", type=int, default=256)
    ap.add_argument("--backpressure", default="reject",
                    choices=["reject", "shed_oldest"])
    ap.add_argument("--default-slack", type=float, default=math.inf,
                    help="deadline slack for requests that send inf")
    ap.add_argument("--noise-sigma", type=float, default=0.0,
                    help="serving-time C2C gain error (core/noise.py); "
                         "shadow probes feed the noise_agreement metric")
    ap.add_argument("--slo-target", type=float, default=None,
                    help="enable SLO shed-vs-extend switching at this "
                         "windowed deadline-miss rate")
    ap.add_argument("--smoke", action="store_true",
                    help="serve a built-in burst of local requests through "
                         "the socket and exit (CI liveness check)")
    args = ap.parse_args()
    assert_spoof_applied(_SPOOFED)
    logging.basicConfig(level=logging.INFO)

    from repro.core.noise import AnalogNoise  # after jax device spoof

    mesh = snn_serve_mesh(args.data)
    noise = (AnalogNoise(weight_sigma=args.noise_sigma)
             if args.noise_sigma > 0 else None)
    slo = (SLOPolicy(target_miss_rate=args.slo_target)
           if args.slo_target is not None else None)

    def model_factory(spec: dict):
        """ADMIN swap body -> new packed weights: {"op": "swap", "model":
        <tenant>, "kind": mlp|conv (default: the tenant name), "seed": n}"""
        kind = spec.get("kind", spec.get("model", args.model))
        if kind not in ("mlp", "conv"):
            raise ValueError(f"unknown demo model kind {kind!r}")
        return build_demo_model(kind, smoke=args.smoke,
                                seed=int(spec.get("seed", 0))).pack()

    kinds = ([k.strip() for k in args.models.split(",") if k.strip()]
             if args.models else None)
    if kinds:
        registry = ModelRegistry()
        for kind in kinds:
            registry.register(
                kind, build_demo_model(kind, smoke=args.smoke).pack(),
                policy=BucketPolicy.for_mesh(mesh.size), noise=noise)
        srv = SpikeSocketServer(
            registry, host=args.host, port=args.port, mesh=mesh,
            queue_capacity=args.queue_capacity,
            backpressure=args.backpressure,
            default_slack=args.default_slack, slo=slo,
            model_factory=model_factory)
        label = "+".join(kinds)
    else:
        packed = build_demo_model(args.model, smoke=args.smoke).pack()
        srv = SpikeSocketServer(
            packed, policy=BucketPolicy.for_mesh(mesh.size),
            host=args.host, port=args.port, mesh=mesh,
            queue_capacity=args.queue_capacity,
            backpressure=args.backpressure,
            default_slack=args.default_slack, noise=noise, slo=slo,
            model_factory=model_factory)
        label = args.model
    host, port = srv.address
    names = srv.server.registry.names()
    print(f"socket-serve/{label}: listening on {host}:{port} "
          f"({mesh.size}-way mesh, {len(names)} tenant(s): "
          f"{', '.join(names)})")

    if args.smoke:
        # best-effort requests: full buckets dispatch inline, the remainder
        # rides the idle-flush path — no deadline misses from cold-jit wall
        # time polluting a liveness check.  Multi-tenant smoke: traffic to
        # every tenant (plus one legacy v1 frame on the default route), a
        # live ADMIN hot-swap of the first tenant, then traffic onto the
        # swapped-in weights.
        per_model = 6
        plan = []        # (model | None, version, stream) per request
        for name in names:
            n_in = srv.server.registry.get(name).packed.n_in
            for i, s in enumerate(synth_requests(per_model, n_in,
                                                 t_hi=12, seed=1)):
                # first request of the default tenant goes out as a v1
                # frame: the pre-registry protocol must still be served
                legacy = (name == srv.server.registry.default and i == 0)
                plan.append((None if legacy else name,
                             1 if legacy else ingest.VERSION, s))
        swap_tenant = names[0]
        swap_kind = kinds[0] if kinds else args.model
        post_swap = synth_requests(
            per_model, srv.server.registry.get(swap_tenant).packed.n_in,
            t_hi=12, seed=2)
        n_results = len(plan) + len(post_swap)
        with serving_thread(srv, max_requests=n_results):
            cli = SpikeClient(host, port)
            for model, version, s in plan:
                cli.send(s, model=model, version=version)
            adm = cli.admin({"op": "swap", "model": swap_tenant,
                             "kind": swap_kind, "seed": 1})
            for s in post_swap:
                cli.send(s, model=swap_tenant)
            # observability round-trip while the loop is live: the full
            # metrics snapshot and a flight-recorder dump over the wire
            met = cli.admin({"op": "metrics"})
            trc = cli.admin({"op": "trace"})
            cli.recv_all()
            cli.close()
        snap = srv.server.metrics.snapshot()
        assert len(cli.results) == n_results, \
            f"served {len(cli.results)}/{n_results}"
        reply = cli.admin_replies[adm]
        assert reply.get("ok") and reply.get("generation") == 2, reply
        assert snap["hot_swaps"] == 1 and snap["rejected"] == 0, snap
        from repro.engine.stream_server import METRIC_KEYS
        mrep = cli.admin_replies[met]
        assert mrep.get("ok") and set(mrep["metrics"]) == set(METRIC_KEYS), \
            f"ADMIN metrics reply is not schema-locked: {sorted(mrep)}"
        trep = cli.admin_replies[trc]
        assert trep.get("ok") and "anomaly_counts" in trep["dump"], trep
        # every fault this smoke injected is a typed recorder anomaly
        counts = srv.tracer.anomaly_counts
        assert counts.get("hot_swap_pin", 0) == 1, counts
        per_done = ", ".join(
            f"{n}={mm['completed']}" for n, mm in snap["per_model"].items())
        print(f"socket-serve smoke: {snap['completed']} served across "
              f"{snap['models']} tenant(s) ({per_done}), "
              f"{snap['hot_swaps']} hot-swap, "
              f"p50 latency {snap['p50_latency_s']*1e3:.1f} ms, "
              f"miss rate {snap['deadline_miss_rate']:.3f}")
        return
    try:
        srv.serve()
    except KeyboardInterrupt:
        pass
    finally:
        srv.close()


if __name__ == "__main__":
    main()
