"""Qwen3-235B-A22B: 94L MoE, 128 experts top-8, GQA 64/4
[hf:Qwen/Qwen3-235B-A22B family; hf]."""

import dataclasses

from repro.configs.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536,                      # per-expert intermediate
    vocab_size=151936, head_dim=128,
    n_experts=128, top_k=8,
    rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256, n_experts=8, top_k=2)
