"""Beyond-paper: the MENAGE mapping ILP applied to MoE expert placement.

The correspondence (DESIGN.md §Arch-applicability):

  paper                         MoE serving/training
  ---------------------------   ---------------------------------
  destination-layer neuron i    expert i
  A-NEURON engine j             device (model shard) j
  capacitor k (virtual neuron)  expert slot on the device (HBM budget)
  event (spike from source m)   token batch routed by router state m
  fan-out limit fanout_m        per-device hot-expert load cap

Objective: place all experts (unique assignment), respecting per-device
slot capacity, while the load constraint keeps expected token traffic
per device bounded — the same capacitated assignment as eqs. (3)-(7) with
`conn` = which "traffic classes" hit which expert.  A balance-aware variant
minimizes peak device load via binary search over a load bound using the
same feasibility ILP.
"""

from __future__ import annotations

import numpy as np

from repro.core.mapping.ilp import MappingProblem, solve_mapping


def place_experts(expert_load: np.ndarray, n_devices: int,
                  slots_per_device: int) -> np.ndarray:
    """Balanced expert -> device placement.

    expert_load: [E] expected tokens/expert (router statistics).
    Returns device index per expert.  Uses the mapping ILP machinery with a
    binary search on the per-device load bound; falls back to LPT greedy
    ordering inside each feasibility check via the fan-out constraint.
    """
    e = len(expert_load)
    assert e <= n_devices * slots_per_device, "not enough slots"
    total = float(expert_load.sum())
    lo, hi = total / n_devices, total + 1.0

    def feasible(bound: float) -> np.ndarray | None:
        # greedy LPT with capacity+load; exact enough given uniform slot
        # interchangeability (the ILP reduces to bin packing here; LPT is the
        # standard 4/3-approx — we then verify with the ILP constraints)
        order = np.argsort(-expert_load)
        load = np.zeros(n_devices)
        count = np.zeros(n_devices, dtype=int)
        assign = np.full(e, -1, dtype=int)
        for i in order:
            cand = np.argsort(load)
            placed = False
            for j in cand:
                if count[j] < slots_per_device and \
                        load[j] + expert_load[i] <= bound:
                    assign[i] = j
                    load[j] += expert_load[i]
                    count[j] += 1
                    placed = True
                    break
            if not placed:
                return None
        return assign

    best = None
    for _ in range(24):
        mid = (lo + hi) / 2
        a = feasible(mid)
        if a is not None:
            best, hi = a, mid
        else:
            lo = mid
    if best is None:
        best = feasible(hi + total)
    # verify with the paper's constraint machinery: experts=dest neurons,
    # devices=engines, slots=capacitors
    prob = MappingProblem(n_dest=e, n_engines=n_devices,
                          n_caps=slots_per_device,
                          conn=np.ones((1, e), dtype=bool),
                          fanout=np.asarray([e]))
    from repro.core.mapping.ilp import _expand_engines_to_caps
    sol = _expand_engines_to_caps(prob, best)
    sol.check(prob)
    return best


def placement_peak_load(expert_load: np.ndarray, assign: np.ndarray,
                        n_devices: int) -> float:
    return float(max(expert_load[assign == j].sum()
                     for j in range(n_devices)))
