"""Uniform model bundle: one entry point per (family) dispatching to the
concrete implementations.  Everything the launcher, dry-run, and tests need:

  bundle = build_model(cfg)
  bundle.init(key)                  -> params
  bundle.param_axes()               -> logical-axes pytree
  bundle.abstract_params()          -> ShapeDtypeStruct pytree
  bundle.loss(params, batch)        -> scalar
  bundle.prefill(params, batch)     -> (logits, cache)
  bundle.decode(params, cache, batch) -> (logits, cache)
  bundle.cache_spec(batch, len)     -> (ShapeDtypeStructs, axes)
  bundle.input_specs(shape)         -> {name: ShapeDtypeStruct}, axes
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.common import ArchConfig, ShapeSpec
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import transformer as T
from repro.models import whisper as W
from repro.models import zamba2 as Z


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    specs: Any
    loss: Callable
    prefill: Callable
    decode: Callable
    cache_spec: Callable          # (batch, cache_len) -> (specs, axes)

    def init(self, key: jax.Array, dtype=jnp.float32):
        return L.init_params(key, self.specs, dtype)

    def param_axes(self):
        return L.param_axes(self.specs)

    def abstract_params(self, dtype=jnp.float32):
        return L.abstract_params(self.specs, dtype)

    # ---------------------------------------------------------- input specs
    def input_specs(self, shape: ShapeSpec) -> tuple[dict, dict]:
        """ShapeDtypeStruct stand-ins for every model input of a shape cell,
        plus their logical sharding axes.  No device allocation."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct
        if shape.kind == "train":
            if cfg.family == "encdec":
                sd = s // cfg.decoder_ratio
                return ({"frames": tok((b, s, cfg.d_model), jnp.float32),
                         "tokens": tok((b, sd + 1), jnp.int32)},
                        {"frames": ("act_batch", "act_seq", "act_embed"),
                         "tokens": ("act_batch", "act_seq")})
            out = {"tokens": tok((b, s + 1), jnp.int32)}
            axes = {"tokens": ("act_batch", "act_seq")}
            if cfg.n_image_embeds:
                out["image_embeds"] = tok((b, cfg.n_image_embeds, cfg.d_model),
                                          jnp.float32)
                axes["image_embeds"] = ("act_batch", "act_seq", "act_embed")
            return out, axes
        if shape.kind == "prefill":
            if cfg.family == "encdec":
                sd = s // cfg.decoder_ratio
                return ({"frames": tok((b, s, cfg.d_model), jnp.float32),
                         "tokens": tok((b, sd), jnp.int32)},
                        {"frames": ("act_batch", "act_seq", "act_embed"),
                         "tokens": ("act_batch", "act_seq")})
            out = {"tokens": tok((b, s), jnp.int32)}
            axes = {"tokens": ("act_batch", "act_seq")}
            if cfg.n_image_embeds:
                out["image_embeds"] = tok((b, cfg.n_image_embeds, cfg.d_model),
                                          jnp.float32)
                axes["image_embeds"] = ("act_batch", "act_seq", "act_embed")
            return out, axes
        # decode: one new token against a cache of seq_len
        return ({"tokens": tok((b,), jnp.int32),
                 "pos": tok((), jnp.int32)},
                {"tokens": ("act_batch",), "pos": ()})


def build_model(cfg: ArchConfig) -> ModelBundle:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        specs = T.transformer_specs(cfg)

        def loss(params, batch):
            return T.transformer_loss(params, cfg, batch)

        def prefill(params, batch):
            return T.transformer_prefill(params, cfg, batch["tokens"],
                                         batch.get("image_embeds"))

        def decode(params, cache, batch, attn_impl=T.decode_attention):
            return T.transformer_decode_step(params, cfg, cache,
                                             batch["tokens"], batch["pos"],
                                             attn_impl)

        def cache_spec(batch, cache_len):
            return T.cache_spec(cfg, batch, cache_len)

    elif fam == "ssm":
        specs = M.mamba2_specs(cfg)

        def loss(params, batch):
            return M.mamba2_loss(params, cfg, batch)

        def prefill(params, batch):
            # SSM prefill = full forward; the "cache" is the final SSM state.
            # Run the layer scan collecting states.
            return _mamba2_prefill(params, cfg, batch["tokens"])

        def decode(params, cache, batch, attn_impl=None):
            return M.mamba2_decode_step(params, cfg, cache, batch["tokens"],
                                        batch["pos"])

        def cache_spec(batch, cache_len):
            return M.mamba2_cache_spec(cfg, batch)

    elif fam == "hybrid":
        specs = Z.zamba2_specs(cfg)

        def loss(params, batch):
            return Z.zamba2_loss(params, cfg, batch)

        def prefill(params, batch):
            return Z.zamba2_prefill(params, cfg, batch["tokens"])

        def decode(params, cache, batch, attn_impl=T.decode_attention):
            return Z.zamba2_decode_step(params, cfg, cache, batch["tokens"],
                                        batch["pos"], attn_impl)

        def cache_spec(batch, cache_len):
            return Z.zamba2_cache_spec(cfg, batch, cache_len)

    elif fam == "encdec":
        specs = W.whisper_specs(cfg)

        def loss(params, batch):
            return W.whisper_loss(params, cfg, batch)

        def prefill(params, batch):
            return W.whisper_prefill(params, cfg, batch["frames"],
                                     batch["tokens"])

        def decode(params, cache, batch, attn_impl=T.decode_attention):
            return W.whisper_decode_step(params, cfg, cache, batch["tokens"],
                                         batch["pos"], attn_impl)

        def cache_spec(batch, cache_len):
            return W.whisper_cache_spec(cfg, batch, cache_len)

    else:
        raise ValueError(f"unknown family {fam!r}")

    return ModelBundle(cfg=cfg, specs=specs, loss=loss, prefill=prefill,
                       decode=decode, cache_spec=cache_spec)


def _mamba2_prefill(params, cfg: ArchConfig, tokens: jax.Array):
    """Mamba2 prefill: full forward, collect final per-layer SSM + conv
    states as the cache, return last-token logits."""
    import math as _math

    b, s = tokens.shape
    x = params["embed"][tokens].astype(jnp.bfloat16) * _math.sqrt(cfg.d_model)

    def body(xx, lp):
        xx, state = M.mamba2_block(xx, lp, cfg)
        return xx, state

    x, states = jax.lax.scan(body, x, L.bf16_layers(params["layers"]))
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1],
                        params["lm_head"].astype(jnp.bfloat16))
    d_in = cfg.ssm_expand * cfg.d_model
    cw = cfg.ssm_conv_width
    # conv tail state: last cw-1 inputs of the x-branch are not retained by
    # the scan; a serving system would keep them — stand in with zeros here
    # (prefill cell correctness for state handoff is tested at smoke scale).
    conv = jnp.zeros((cfg.n_layers, b, cw - 1, d_in), jnp.bfloat16)
    return logits, {"ssm": states, "conv": conv}
