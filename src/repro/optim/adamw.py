"""AdamW with decoupled weight decay + global-norm clipping.

Pure-pytree implementation (no optax dependency).  Optimizer state inherits
the parameters' sharding (moments are elementwise), so under the FSDP rules
the full Adam state is sharded — ZeRO-3 for free via pjit out_shardings.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def adamw_init(params):
    return {
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(jnp.zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step, base_lr=None):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return (cfg.lr if base_lr is None else base_lr) * warm


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, opt_state, grads, *, lr=None):
    """Returns (new_params, new_opt_state, metrics).

    ``lr``, when given, is a *dynamic* scalar overriding ``cfg.lr`` as the
    schedule's base rate (the warmup ramp still applies).  Because it is a
    traced value rather than a static config field, an external LR schedule
    feeds a new rate every step without retracing the jitted train step —
    the fix for the retrace-per-lr bug the old SNN Adam loops had.
    """
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)
    step = opt_state["step"] + 1
    lr = _schedule(cfg, step, lr)
    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, opt_state["m"], grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g,
                     opt_state["v"], grads)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1**t
    bc2 = 1 - b2**t

    def upd(p, mm, vv):
        mhat = mm / bc1
        vhat = vv / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
