"""Per-layer bit-width search + mixed-precision engine/energy plumbing."""

import numpy as np
import pytest

from repro.core.accelerator import map_model, run
from repro.core.energy import ACCEL_1, AcceleratorSpec, energy_model
from repro.core.layers import Dense
from repro.core.lif import LIFParams
from repro.core.precision import (PARETO_POINT_KEYS, PrecisionSearchResult,
                                  agreement, energy_per_frame, pareto_point,
                                  search_bits)

SPEC = AcceleratorSpec("prec-test", n_cores=4, n_engines=8, n_caps=16,
                       weight_mem_bytes=1 << 20)


def _stack(rng, sizes=(24, 32, 10), scale=0.6):
    return [rng.normal(0, scale, (sizes[i], sizes[i + 1])).astype(np.float32)
            for i in range(len(sizes) - 1)]


def _probe(rng, n_in, t=10, p=0.3):
    return (rng.random((t, n_in)) < p).astype(np.float32)


# ------------------------------------------------------------- search_bits

def test_search_zero_budget_keeps_8bit(rng):
    ws = _stack(rng)
    res = search_bits(ws, SPEC, _probe(rng, 24), budget=0.0)
    assert res.per_layer_bits == [8, 8]
    assert res.agreement == 1.0
    # every sub-8 candidate was evaluated and rejected (or layers never
    # reached — greedy stops a layer at its first rejected width)
    assert all(not s.accepted or s.agreement >= 1.0 for s in res.history)


def test_search_loose_budget_downgrades(rng):
    ws = _stack(rng)
    res = search_bits(ws, SPEC, _probe(rng, 24), budget=0.5)
    assert any(b < 8 for b in res.per_layer_bits), \
        "a 50% disagreement budget must buy at least one sub-8 layer"
    assert res.agreement >= 0.5
    assert all(b in (2, 4, 8) for b in res.per_layer_bits)


def test_search_agreement_floor_respected(rng):
    ws = _stack(rng)
    budget = 0.1
    res = search_bits(ws, SPEC, _probe(rng, 24), budget=budget)
    assert res.agreement >= 1.0 - budget
    for step in res.history:
        if step.accepted:
            assert step.agreement >= 1.0 - budget


def test_search_energy_never_increases(rng):
    ws = _stack(rng)
    res = search_bits(ws, SPEC, _probe(rng, 24), budget=0.5)
    base = res.baseline_energy.dynamic_j + res.baseline_energy.static_j
    fin = res.energy.dynamic_j + res.energy.static_j
    assert fin <= base
    assert 0.0 <= res.energy_reduction <= 1.0


def test_search_respects_pinned_spec_bits(rng):
    ws = _stack(rng)
    pinned = [Dense(w=ws[0], bits=4), Dense(w=ws[1])]
    res = search_bits(pinned, SPEC, _probe(rng, 24), budget=0.0)
    # the pin survives AND the search never touched the pinned layer
    assert res.per_layer_bits[0] == 4
    assert all(s.layer != 0 for s in res.history)


def test_search_choices_validation(rng):
    ws = _stack(rng)
    probe = _probe(rng, 24)
    with pytest.raises(ValueError, match="8-bit baseline"):
        search_bits(ws, SPEC, probe, choices=(4, 2))
    with pytest.raises(ValueError):
        search_bits(ws, SPEC, probe, choices=(8, 3))
    with pytest.raises(ValueError, match="budget"):
        search_bits(ws, SPEC, probe, budget=1.5)
    with pytest.raises(ValueError, match="probe_spikes"):
        search_bits(ws, SPEC, probe[None])


def test_search_result_config_runs(rng):
    """The chosen config maps, runs, and its modeled energy matches the
    result's — the search's score is the real model, not an estimate."""
    ws = _stack(rng)
    probe = _probe(rng, 24)
    res = search_bits(ws, SPEC, probe, budget=0.3)
    m = map_model(ws, SPEC, quant_bits=res.per_layer_bits)
    rr = run(m, probe)
    assert [l.bits for l in m.layers] == res.per_layer_bits
    assert rr.energy.breakdown["E_mac_J"] == \
        res.energy.breakdown["E_mac_J"]


def test_search_8bit_only_choices_is_identity(rng):
    ws = _stack(rng)
    res = search_bits(ws, SPEC, _probe(rng, 24), choices=(8,))
    assert res.per_layer_bits == [8, 8]
    assert res.history == []
    assert isinstance(res, PrecisionSearchResult)


# ------------------------------------------------------------ pareto points

def test_pareto_point_schema(rng):
    ws = _stack(rng)
    probe = _probe(rng, 24)
    m = map_model(ws, SPEC, quant_bits=[4, 8])
    rr = run(m, probe)
    pt = pareto_point("mixed", [4, 8], rr, m, 0.97, events_per_s=1e5)
    assert tuple(pt) == PARETO_POINT_KEYS
    assert pt["per_layer_bits"] == [4, 8]
    assert pt["weight_sram_bytes"] == sum(l.sram_bytes for l in m.layers)
    assert pt["energy_per_frame_j"] == \
        energy_per_frame(rr.energy, probe.shape[0])
    assert pt["events_per_s"] == 1e5


def test_agreement_basics():
    a = np.array([[1.0, 0.0], [0.0, 1.0]])
    assert agreement(a, a) == 1.0
    assert agreement(a, 1 - a) == 0.0
    with pytest.raises(ValueError):
        agreement(a, a[:1])


# ----------------------------------------------- energy model bit scaling

def test_energy_scales_with_bits(rng):
    ws = _stack(rng)
    probe = _probe(rng, 24)
    stats = run(map_model(ws, SPEC, quant_bits=8), probe).per_layer_stats
    e8 = energy_model(SPEC, stats, per_core_bits=[8, 8])
    e4 = energy_model(SPEC, stats, per_core_bits=[4, 4])
    e2 = energy_model(SPEC, stats, per_core_bits=[2, 2])
    # only the C2C MAC term scales, and it scales ~bits/8
    assert e8.breakdown["E_mac_J"] > e4.breakdown["E_mac_J"] \
        > e2.breakdown["E_mac_J"] > 0
    np.testing.assert_allclose(e4.breakdown["E_mac_J"],
                               e8.breakdown["E_mac_J"] / 2, rtol=1e-12)
    assert e8.breakdown["E_ctrl_rows_J"] == e4.breakdown["E_ctrl_rows_J"]
    assert e8.breakdown["E_aneuron_J"] == e4.breakdown["E_aneuron_J"]
    # uniform 8-bit takes the legacy single-product path: bit-identical
    legacy = energy_model(SPEC, stats)
    assert e8.breakdown["E_mac_J"] == legacy.breakdown["E_mac_J"]


def test_energy_per_core_bits_length_checked(rng):
    ws = _stack(rng)
    stats = run(map_model(ws, SPEC), _probe(rng, 24)).per_layer_stats
    with pytest.raises(ValueError, match="per_core_bits"):
        energy_model(SPEC, stats, per_core_bits=[8])


# ------------------------------------------- engine interaction edge cases

def test_packed_ops_model_rejects_analog_noise(rng):
    from repro.core.noise import AnalogNoise, perturb_packed
    import jax
    ws = _stack(rng)
    m = map_model(ws, SPEC, quant_bits=[4, 8])
    packed = m.pack()            # auto-selects packed operands (4-bit layer)
    with pytest.raises(ValueError, match="packed sub-byte"):
        perturb_packed(jax.random.key(0), packed,
                       AnalogNoise(weight_sigma=0.05))
    # the f32 replay path of the SAME mapped model accepts noise
    replay = m.pack(packed_ops=False)
    noisy = perturb_packed(jax.random.key(0), replay,
                           AnalogNoise(weight_sigma=0.05))
    assert noisy is not replay


def test_per_layer_bits_reach_engine_energy(rng):
    from repro.engine import run_batched
    ws = _stack(rng)
    probe = _probe(rng, 24)
    m = map_model(ws, SPEC, quant_bits=[4, 8])
    res = run_batched(m, probe[None])
    assert res.per_layer_bits == [4, 8]
    oracle = run(m, probe)
    assert res.sample_energy(0) == oracle.energy
