from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from repro.optim.compress import (CompressionConfig, compress_gradients,  # noqa: F401
                                  decompress_gradients)
