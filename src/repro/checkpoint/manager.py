"""Fault-tolerant checkpointing.

Design (mirrors what Orbax does at scale, self-contained here):
  * atomic commit: write to ``step_<n>.tmp/``, fsync, rename to ``step_<n>/``
    — a preempted writer never corrupts the latest checkpoint;
  * async: a background thread serializes device arrays (snapshot taken
    synchronously via ``jax.device_get``, write overlapped with compute);
  * sharding-agnostic restore: arrays are stored logically (whole-array npz);
    restore places them under ANY target sharding/mesh — this is what makes
    **elastic restart** (resume on a different device count / mesh shape)
    work, tested in tests/test_checkpoint.py;
  * step-keyed data pipeline (data/tokens.py derives batches from (seed,
    step)), so resume is exactly-once without saving reader state.

At real multi-pod scale the npz-per-host writes become per-shard OCDBT
writes; the manager interface (save/restore/latest_step/wait) is unchanged.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, step: int, tree, extra: dict | None = None):
    """Synchronous atomic save."""
    tmp = os.path.join(path, f"step_{step:08d}.tmp")
    final = os.path.join(path, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"a{i}": a for i, a in enumerate(host)})
    meta = {"step": step, "n_leaves": len(host),
            "treedef": str(treedef), "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = []
    for d in os.listdir(path):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(path, d, "meta.json")):
                steps.append(int(d[5:]))
    return max(steps) if steps else None


def restore_checkpoint(path: str, step: int, tree_like,
                       shardings=None):
    """Restore into the structure of ``tree_like``; if ``shardings`` (same
    pytree of NamedSharding) is given, place shards accordingly — works for
    any mesh, enabling elastic restart across different device counts."""
    d = os.path.join(path, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves, treedef = _flatten(tree_like)
    arrs = [data[f"a{i}"] for i in range(len(leaves))]
    if shardings is not None:
        shard_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "mesh"))
        out = [jax.device_put(a, s) for a, s in zip(arrs, shard_leaves)]
    else:
        out = [jax.device_put(a) for a in arrs]
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """Async checkpoint writer with bounded retention."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        os.makedirs(path, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save_async(self, step: int, tree, extra: dict | None = None):
        self.wait()
        # snapshot on the calling thread (cheap host copy), write in background
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        snapshot = jax.tree.unflatten(treedef, host)

        def work():
            try:
                save_checkpoint(self.path, step, snapshot, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(d[5:]) for d in os.listdir(self.path)
            if d.startswith("step_") and not d.endswith(".tmp")
            and os.path.exists(os.path.join(self.path, d, "meta.json")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)

    def latest(self) -> int | None:
        self.wait()
        return latest_step(self.path)
