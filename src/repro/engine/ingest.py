"""Length-prefixed wire protocol for live spike-stream ingest.

The socket front end (:mod:`repro.launch.socket_serve`) feeds
:class:`~repro.engine.stream_server.StreamServer` from real connections;
this module is the framing both ends speak.  It is deliberately socket-free
— pure ``bytes -> frames`` — so the tier-1 suite exercises every byte of
the protocol without opening a port, and any transport (TCP, Unix socket,
serial link from the sensor) can carry it.

Frame layout (network byte order)::

    +----+----+---------+---------+====================+
    |'M' |'G' | ver u8  | kind u8 | len u32 | payload  |
    +----+----+---------+---------+====================+

Kinds:

  * ``REQUEST`` — ``req_id u32, T u32, n_in u32, slack f64`` followed by
    the ``[T, n_in]`` 0/1 spike raster **bit-packed** (``np.packbits``):
    an event-driven edge link ships 1 bit per (step, neuron), 8x smaller
    than float32 and exactly round-trippable since spikes are binary.
    ``slack`` is the per-request deadline slack in seconds (``inf`` =
    best-effort), mapping 1:1 onto ``StreamServer.submit(slack=...)``.
  * ``RESULT`` — ``req_id u32, T u32, n_out u32`` + bit-packed output
    spikes: the request's bit-exact ``RequestResult.out_spikes``.
  * ``REJECT`` — ``req_id u32`` + utf-8 reason (the server's
    :class:`~repro.engine.stream_server.Rejection` reason/detail), so a
    client always learns the fate of every request it sent.

``req_id`` is client-chosen correlation state (the server echoes it back);
it is unrelated to the server's internal rids.  :class:`FrameDecoder` is an
incremental parser: feed it arbitrary chunk boundaries (as TCP delivers
them) and complete frames come out.
"""

from __future__ import annotations

import dataclasses
import math
import struct

import numpy as np

MAGIC = b"MG"
VERSION = 1

KIND_REQUEST = 0
KIND_RESULT = 1
KIND_REJECT = 2

_HEADER = struct.Struct(">2sBBI")           # magic, version, kind, payload len
_REQ_HEAD = struct.Struct(">IIId")          # req_id, T, n_in, slack
_RES_HEAD = struct.Struct(">III")           # req_id, T, n_out
_REJ_HEAD = struct.Struct(">I")             # req_id

# A [T, n_in] raster at the largest serving bucket is a few KiB bit-packed;
# anything near this bound is a corrupt length prefix, not a real request.
MAX_PAYLOAD = 1 << 26


class ProtocolError(ValueError):
    """Corrupt or incompatible framing — the connection should be closed."""


@dataclasses.dataclass(frozen=True)
class Frame:
    kind: int
    payload: bytes


def _pack_bits(spikes: np.ndarray) -> bytes:
    return np.packbits((np.asarray(spikes) > 0).astype(np.uint8),
                       axis=None).tobytes()


def _unpack_bits(buf: bytes, t: int, n: int) -> np.ndarray:
    need = -(-t * n // 8)
    if len(buf) != need:
        raise ProtocolError(f"raster for [{t}, {n}] needs {need} bytes, "
                            f"got {len(buf)}")
    bits = np.unpackbits(np.frombuffer(buf, dtype=np.uint8), count=t * n)
    return bits.reshape(t, n).astype(np.float32)


def _frame(kind: int, payload: bytes) -> bytes:
    return _HEADER.pack(MAGIC, VERSION, kind, len(payload)) + payload


def encode_request(req_id: int, stream: np.ndarray,
                   slack: float = math.inf) -> bytes:
    """One client request: a ``[T, n_in]`` spike raster plus its deadline
    slack, bit-packed into a single frame."""
    stream = np.asarray(stream)
    assert stream.ndim == 2, f"expected [T, n_in], got {stream.shape}"
    t, n_in = stream.shape
    return _frame(KIND_REQUEST,
                  _REQ_HEAD.pack(req_id, t, n_in, float(slack))
                  + _pack_bits(stream))


def peek_request(payload: bytes) -> tuple[int, int, int, float]:
    """Request header ``(req_id, T, n_in, slack)`` without unpacking the
    raster — what the server reads to validate the claimed shape against
    its model *before* committing to the ``[T, n_in]`` decode, so a
    well-framed request with a bogus width answers with a REJECT instead
    of reaching the engine."""
    if len(payload) < _REQ_HEAD.size:
        raise ProtocolError(f"request payload truncated at {len(payload)}B")
    return _REQ_HEAD.unpack_from(payload)


def decode_request(payload: bytes) -> tuple[int, np.ndarray, float]:
    req_id, t, n_in, slack = peek_request(payload)
    return req_id, _unpack_bits(payload[_REQ_HEAD.size:], t, n_in), slack


def encode_result(req_id: int, out_spikes: np.ndarray) -> bytes:
    out = np.asarray(out_spikes)
    assert out.ndim == 2, f"expected [T, n_out], got {out.shape}"
    t, n_out = out.shape
    return _frame(KIND_RESULT,
                  _RES_HEAD.pack(req_id, t, n_out) + _pack_bits(out))


def decode_result(payload: bytes) -> tuple[int, np.ndarray]:
    if len(payload) < _RES_HEAD.size:
        raise ProtocolError(f"result payload truncated at {len(payload)}B")
    req_id, t, n_out = _RES_HEAD.unpack_from(payload)
    return req_id, _unpack_bits(payload[_RES_HEAD.size:], t, n_out)


def encode_rejection(req_id: int, reason: str) -> bytes:
    return _frame(KIND_REJECT, _REJ_HEAD.pack(req_id) + reason.encode())


def decode_rejection(payload: bytes) -> tuple[int, str]:
    if len(payload) < _REJ_HEAD.size:
        raise ProtocolError(f"reject payload truncated at {len(payload)}B")
    (req_id,) = _REJ_HEAD.unpack_from(payload)
    return req_id, payload[_REJ_HEAD.size:].decode()


class FrameDecoder:
    """Incremental frame parser over an arbitrary byte stream.

    ``feed(chunk)`` buffers and returns every frame completed by that
    chunk (possibly none, possibly several) — chunk boundaries are
    whatever the transport delivered.  Corrupt magic, an unknown version,
    or an absurd length prefix raise :class:`ProtocolError`; the caller
    should drop the connection (there is no way to resynchronize a
    length-prefixed stream after corruption)."""

    def __init__(self):
        self._buf = bytearray()

    @property
    def pending_bytes(self) -> int:
        return len(self._buf)

    def feed(self, chunk: bytes) -> list[Frame]:
        self._buf.extend(chunk)
        frames: list[Frame] = []
        while len(self._buf) >= _HEADER.size:
            magic, ver, kind, length = _HEADER.unpack_from(self._buf)
            if magic != MAGIC:
                raise ProtocolError(f"bad magic {magic!r}")
            if ver != VERSION:
                raise ProtocolError(f"protocol version {ver}, want {VERSION}")
            if length > MAX_PAYLOAD:
                raise ProtocolError(f"frame length {length} > {MAX_PAYLOAD}")
            if len(self._buf) < _HEADER.size + length:
                break
            payload = bytes(self._buf[_HEADER.size:_HEADER.size + length])
            del self._buf[:_HEADER.size + length]
            frames.append(Frame(kind=kind, payload=payload))
        return frames
