"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret-mode
allclose sweeps in tests/test_kernels.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import c2c_ladder_value, unpack_signmag


def event_synapse_ref(events: jax.Array, weights: jax.Array) -> jax.Array:
    """Event-driven synaptic accumulation, dense oracle.

    events:  [B, E] int32 — indices of spiking source neurons, padded with -1.
    weights: [n_src, n_dest] f32.
    returns: [B, n_dest] f32 — sum of weight rows of the (valid) events.
    """
    mask = (events >= 0)[..., None]                      # [B, E, 1]
    rows = weights[jnp.clip(events, 0), :]               # [B, E, n_dest]
    return jnp.sum(jnp.where(mask, rows, 0.0), axis=1)


def event_synapse_packed_ref(events: jax.Array, packed_w: jax.Array,
                             scale: jax.Array, bits: int) -> jax.Array:
    """Packed-operand oracle: unpack the sign-magnitude lanes to a dense
    dequantized f32 matrix, then run the dense event accumulation.  The
    kernel must match this bit for bit (same per-element ``q * scale``
    product, same accumulation order)."""
    q = unpack_signmag(packed_w, bits)                   # [n_src, n_dest]
    w = q.astype(jnp.float32) * jnp.asarray(scale, jnp.float32).reshape(())
    return event_synapse_ref(events, w)


def lif_update_ref(v: jax.Array, current: jax.Array, beta: float,
                   threshold: float, v_reset: float):
    """Fused LIF membrane update oracle (matches core.lif.lif_step forward)."""
    v_int = beta * v + current
    spikes = (v_int >= threshold).astype(v.dtype)
    v_next = jnp.where(spikes > 0, v_reset, v_int)
    return v_next, spikes


def c2c_matmul_ref(x: jax.Array, w_q: jax.Array, scale: jax.Array) -> jax.Array:
    """int8-weight matmul oracle: x [M,K] f32, w_q [K,N] int8, scale scalar.

    out = x @ (w_q * scale)
    """
    return x @ (w_q.astype(jnp.float32) * scale)


def c2c_matmul_ladder_ref(x: jax.Array, w_q: jax.Array, scale: jax.Array,
                          bits: int = 8) -> jax.Array:
    """Bit-serial evaluation through the *ideal C2C ladder* (paper eq. (2)):

        V_out = V_ref * sum_i W_i 2^{i-(n-1)},   V_ref = scale * 2^{n-1}

    with 1 sign bit selecting V_ref polarity and ``bits-1`` magnitude lanes
    W_{n-2}..W_0.  Proves the kernel computes exactly what the analog ladder
    would ideally produce (sign-magnitude handling per quant.py).
    """
    frac = c2c_ladder_value(w_q, bits=bits)       # sign * mag / 2^{bits-1}
    v_ref = scale * (2.0 ** (bits - 1))
    return x @ (frac * v_ref)
