#!/usr/bin/env python
"""Markdown link checker for the repo docs (no network, no deps).

Walks the given markdown files/directories, extracts inline links and
images (``[text](target)``), and fails if a relative target does not exist
on disk — the docs-rot gate CI runs over README.md and docs/.  External
(``http(s)://``, ``mailto:``) targets are skipped: CI must not flake on
someone else's uptime.  Anchors are checked against the target file's
headings (GitHub slug rules, simplified).

  python tools/check_links.py README.md docs
"""

from __future__ import annotations

import argparse
import os
import re
import sys

# inline [text](target) and ![alt](target); ignores ``` fenced blocks below
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _slug(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation (including
    backticks), spaces to dashes."""
    text = heading.strip().lower()
    text = re.sub(r"[`*_~]", "", text)
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _strip_fences(text: str) -> str:
    out, fenced = [], False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        if not fenced:
            out.append(line)
    return "\n".join(out)


def _anchors(path: str) -> set[str]:
    with open(path, encoding="utf-8") as f:
        return {_slug(h) for h in _HEADING.findall(_strip_fences(f.read()))}


def check_file(path: str) -> list[str]:
    errors = []
    with open(path, encoding="utf-8") as f:
        body = _strip_fences(f.read())
    base = os.path.dirname(os.path.abspath(path))
    for target in _LINK.findall(body):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        ref, _, anchor = target.partition("#")
        dest = os.path.normpath(os.path.join(base, ref)) if ref \
            else os.path.abspath(path)
        if ref and not os.path.exists(dest):
            # badge-style links into .github metadata (../../actions/...)
            # point at the forge UI, not the tree — skip those
            if "/actions/" in target:
                continue
            errors.append(f"{path}: broken link target {target!r}")
            continue
        if anchor and dest.endswith(".md") and os.path.exists(dest):
            if anchor not in _anchors(dest):
                errors.append(f"{path}: missing anchor {target!r}")
    return errors


def collect(paths: list[str]) -> list[str]:
    files = []
    for p in paths:
        if os.path.isdir(p):
            for root, _, names in os.walk(p):
                files.extend(os.path.join(root, n) for n in sorted(names)
                             if n.endswith(".md"))
        else:
            files.append(p)
    return files


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+",
                    help="markdown files or directories to walk")
    args = ap.parse_args(argv)
    files = collect(args.paths)
    errors = [e for f in files for e in check_file(f)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} files, {len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
