"""DeepSeek-67B: dense 95L, GQA 64/8, llama-arch [arXiv:2401.02954; hf]."""

import dataclasses

from repro.configs.common import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=102400, head_dim=128,
    rope_theta=10000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256)
