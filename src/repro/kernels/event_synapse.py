"""Pallas TPU kernel: event-driven synaptic accumulation.

The TPU-native form of MENAGE's A-SYN dispatch (DESIGN.md §2): work is
proportional to *events*, not to the dense n_src x n_dest product.  A padded
event list (the software MEM_E) gathers weight rows from the VMEM-resident
weight tile and accumulates membrane currents.

Tiling: grid = (B, n_dest / BLOCK_D).  Each program instance owns one
(sample, dest-block) pair; the full event list of that sample and the
[n_src, BLOCK_D] weight tile are in VMEM.  The inner fori_loop plays the role
of the controller's per-event dispatch cycles; BLOCK_D is the vectorized lane
dimension — the "engine" axis onto which virtual neurons are packed.

The event list is padded to a static length E (MEM_E depth).  Padding entries
are -1 and are masked — the pad factor is the same overflow budget the paper
provisions for the utilization spikes of Figs 6-7.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_D = 256


def _event_synapse_kernel(events_ref, weights_ref, out_ref):
    """events [1, E] int32; weights [n_src, BD] f32; out [1, BD] f32."""
    events = events_ref[0, :]                       # [E]
    n_events = events.shape[0]
    bd = out_ref.shape[1]

    def body(e, acc):
        idx = events[e]
        valid = idx >= 0
        safe = jnp.where(valid, idx, 0)
        row = pl.load(weights_ref, (pl.dslice(safe, 1), slice(None)))  # [1, BD]
        return acc + jnp.where(valid, row[0], jnp.zeros((bd,), acc.dtype))

    acc = jax.lax.fori_loop(0, n_events, body, jnp.zeros((bd,), out_ref.dtype))
    out_ref[0, :] = acc


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def event_synapse(events: jax.Array, weights: jax.Array,
                  block_d: int = DEFAULT_BLOCK_D,
                  interpret: bool = False) -> jax.Array:
    """events [B, E] int32 (pad=-1); weights [n_src, n_dest] f32 ->
    currents [B, n_dest] f32."""
    b, n_events = events.shape
    n_src, n_dest = weights.shape
    if n_events == 0 or b == 0:
        # static zero-depth MEM_E (nothing dispatches) or an empty batch —
        # a zero-size grid still asks pallas for a (1, E) block slice of the
        # (0, E) events operand, so short-circuit before the kernel
        return jnp.zeros((b, n_dest), weights.dtype)
    bd = min(block_d, n_dest)
    assert n_dest % bd == 0, f"n_dest={n_dest} not divisible by block_d={bd}"
    grid = (b, n_dest // bd)
    return pl.pallas_call(
        _event_synapse_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, events.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((n_src, bd), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n_dest), weights.dtype),
        interpret=interpret,
    )(events, weights)


def events_from_spikes(spikes: jax.Array, max_events: int) -> jax.Array:
    """Convert a dense spike vector batch [B, n_src] to a padded event list
    [B, max_events] (int32, pad=-1) — the software MEM_E writer.  Events
    beyond max_events are dropped (counted by callers via overflow_count)."""
    b, n = spikes.shape
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
    # sort spiking indices to the front: key = (1-spike)*n + arange
    key = jnp.where(spikes > 0, idx, n + idx)
    order = jnp.argsort(key, axis=1)[:, :max_events]
    gathered = jnp.take_along_axis(idx, order, axis=1)
    valid = jnp.take_along_axis(spikes > 0, order, axis=1)
    return jnp.where(valid, gathered, -1).astype(jnp.int32)


def overflow_count(spikes: jax.Array, max_events: int) -> jax.Array:
    """How many events were dropped by the static MEM_E depth."""
    n_spk = (spikes > 0).sum(axis=1)
    return jnp.maximum(n_spk - max_events, 0)
