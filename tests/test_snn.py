"""SNN training (paper §IV-A flow): surrogate-gradient learning works, and
the full Algorithm-1 pipeline (train -> prune -> quantize -> map -> run)
preserves accuracy within the paper-reported ~0.65% drop ballpark."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.accelerator import map_model, run_batch
from repro.core.energy import AcceleratorSpec
from repro.core.lif import LIFParams
from repro.core.prune import prune_pytree, sparsity
from repro.core.quant import quantize_pytree
from repro.data.events import EventDatasetConfig, event_batches, synthetic_event_dataset
from repro.engine import MLP_MODEL, SNNTrainConfig, train_snn_model
from repro.snn.mlp import SNNConfig, init_snn, snn_forward


@pytest.fixture(scope="module")
def trained():
    cfg_d = EventDatasetConfig("tiny", 8, 8, num_steps=15, base_rate=0.02,
                               signal_rate=0.5)
    spikes, labels = synthetic_event_dataset(cfg_d, n_per_class=24,
                                             key=jax.random.key(0))
    snn = SNNConfig(layer_sizes=(cfg_d.n_in, 48, 24, 10), num_steps=15)
    it = event_batches(spikes, labels, batch=32)
    params, hist = train_snn_model(
        MLP_MODEL, snn, it, SNNTrainConfig(steps=150, lr=2e-3,
                                           log_every=1000),
        key=jax.random.key(1), log_fn=lambda s: None)
    return cfg_d, snn, params, (spikes, labels)


def _accuracy(params, snn, spikes, labels):
    counts, _ = snn_forward(params, jnp.asarray(spikes.swapaxes(0, 1)), snn)
    return float((np.asarray(counts).argmax(-1) == labels).mean())


def test_training_beats_chance(trained):
    cfg_d, snn, params, (spikes, labels) = trained
    acc = _accuracy(params, snn, spikes, labels)
    assert acc > 0.5, f"accuracy {acc} barely above chance"


def test_prune_quantize_small_drop(trained):
    """Algorithm 1 steps 2: accuracy drop after 50% L1 prune + 8-bit PTQ
    should be small (paper: 94.75->94.1, 65.38->65.03)."""
    cfg_d, snn, params, (spikes, labels) = trained
    acc0 = _accuracy(params, snn, spikes, labels)
    pruned, _ = prune_pytree(params, 0.5)
    _, dq = quantize_pytree(pruned)
    acc1 = _accuracy(dq, snn, spikes, labels)
    assert sparsity(pruned) > 0.45
    assert acc0 - acc1 < 0.10, f"{acc0} -> {acc1}"


def test_full_flow_on_accelerator(trained):
    """Algorithm 1 end-to-end: the mapped accelerator classifies like the
    quantized reference SNN."""
    cfg_d, snn, params, (spikes, labels) = trained
    pruned, _ = prune_pytree(params, 0.5)
    _, dq = quantize_pytree(pruned)
    spec = AcceleratorSpec("flow", n_cores=3, n_engines=8, n_caps=8,
                           weight_mem_bytes=1 << 22)
    model = map_model([np.asarray(w) for w in dq], spec,
                      lif=snn.lif, quant_bits=8)
    n = 16
    correct = 0
    for i, res in enumerate(run_batch(model, spikes[:n])):
        pred = res.out_spikes.sum(axis=0).argmax()
        correct += int(pred == labels[i])
    acc_ref = _accuracy(dq, snn, spikes[:n], labels[:n])
    acc_hw = correct / n
    assert abs(acc_hw - acc_ref) <= 0.25   # same decisions up to quant noise
    assert acc_hw > 0.3
