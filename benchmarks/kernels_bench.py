"""Pallas kernel microbenchmarks.

CPU-interpret timings are NOT TPU performance — the derived column reports
the structural quantities that matter on the target (bytes moved per call,
arithmetic intensity, event-sparsity speedup factor)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _timeit(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_batched_engine(batch: int = 32, t_steps: int = 20,
                         density: float = 0.2) -> float:
    """Batched jit engine vs. the looped cycle-accurate oracle on the same
    mapped model.  Returns the wall-clock speedup at the given batch size
    (CI asserts >= 5x at batch 32)."""
    from repro.core.accelerator import map_model, run
    from repro.core.energy import AcceleratorSpec
    from repro.core.lif import LIFParams
    from repro.engine import run_batched

    rng = np.random.default_rng(0)
    sizes = (128, 96, 64)
    ws = []
    for i in range(len(sizes) - 1):
        w = rng.normal(0, 0.4, (sizes[i], sizes[i + 1])).astype(np.float32)
        w[np.abs(w) < np.quantile(np.abs(w), 0.6)] = 0
        ws.append(w)
    spec = AcceleratorSpec("bench", n_cores=2, n_engines=8, n_caps=16,
                           weight_mem_bytes=1 << 20)
    model = map_model(ws, spec, lif=LIFParams(beta=0.85, threshold=0.6))
    spikes = (rng.random((batch, t_steps, sizes[0])) < density) \
        .astype(np.float32)

    packed = model.pack()
    res_b = run_batched(packed, spikes)          # compile
    t0 = time.perf_counter()
    res_b = run_batched(packed, spikes)
    t_batched = time.perf_counter() - t0

    t0 = time.perf_counter()
    oracle_out = [run(model, spikes[b]).out_spikes for b in range(batch)]
    t_loop = time.perf_counter() - t0

    assert all(np.array_equal(res_b.out_spikes[b], oracle_out[b])
               for b in range(batch)), "batched engine != oracle"
    speedup = t_loop / max(t_batched, 1e-9)
    print(f"engine/run_batched_b{batch},{t_batched*1e6:.0f},"
          f"speedup_vs_loop={speedup:.1f}x")
    assert speedup >= 5.0 or batch < 32, \
        f"batched engine speedup regressed: {speedup:.1f}x < 5x at batch {batch}"
    return speedup


def bench_event_compaction(rng) -> None:
    """events_from_spikes: cumsum-based stable compaction vs the O(n log n)
    full-width argsort it replaced.  Gate: bit-identical event streams and
    the cumsum path does not regress (<= 1.5x of argsort; it is typically
    faster once n_src is large enough for the sort to matter)."""
    from repro.kernels.event_synapse import _events_from_spikes_argsort
    n_src, max_ev = 4096, 1024
    spikes = jnp.asarray((rng.random((8, n_src)) < 0.2).astype(np.float32))
    ev_cumsum = ops.events_from_spikes(spikes, max_ev)
    ev_argsort = _events_from_spikes_argsort(spikes, max_ev)
    assert np.array_equal(np.asarray(ev_cumsum), np.asarray(ev_argsort)), \
        "cumsum event compaction != argsort reference"
    us_c = _timeit(ops.events_from_spikes, spikes, max_ev)
    us_a = _timeit(_events_from_spikes_argsort, spikes, max_ev)
    print(f"kernel/events_from_spikes_cumsum,{us_c:.0f},"
          f"argsort_ref_us={us_a:.0f}")
    assert us_c <= us_a * 1.5 + 50, \
        f"cumsum compaction regressed: {us_c:.0f}us vs argsort {us_a:.0f}us"


def bench_packed_synapse(rng) -> None:
    """Packed sub-byte operand kernel vs the dense f32 kernel: the derived
    column is the weight-tile byte shrink (the quantity that matters on the
    target — VMEM traffic scales with stored bits, not with CPU-interpret
    wall time).  Gate: 8-bit packed output is bit-exact vs dense."""
    from repro.core.quant import pack_signmag
    n_src, n_dest = 512, 512
    q = rng.integers(-127, 128, (n_src, n_dest)).astype(np.int8)
    scale = np.float32(0.01)
    w = jnp.asarray(q.astype(np.float32) * scale)
    spikes = jnp.asarray((rng.random((4, n_src)) < 0.1).astype(np.float32))
    ev = ops.events_from_spikes(spikes, 128)
    dense = ops.event_synapse(ev, w)
    for bits in (8, 4, 2):
        qb = np.clip(q, -(2 ** (bits - 1) - 1), 2 ** (bits - 1) - 1) \
            .astype(np.int8)
        packed = jnp.asarray(pack_signmag(qb, bits))
        us = _timeit(lambda e, p: ops.event_synapse_packed(
            e, p, scale, bits=bits), ev, packed)
        shrink = w.nbytes / packed.nbytes
        print(f"kernel/event_synapse_packed_b{bits},{us:.0f},"
              f"weight_byte_shrink={shrink:.1f}x")
        if bits == 8:
            out = ops.event_synapse_packed(ev, packed, scale, bits=8)
            assert np.array_equal(np.asarray(out), np.asarray(dense)), \
                "8-bit packed kernel != dense kernel"


def main():
    rng = np.random.default_rng(0)
    # event_synapse: sparsity-proportional work
    n_src, n_dest = 1024, 1024
    w = jnp.asarray(rng.normal(size=(n_src, n_dest)).astype(np.float32))
    for density in (0.05, 0.25):
        spikes = jnp.asarray((rng.random((4, n_src)) < density)
                             .astype(np.float32))
        max_ev = max(int(density * n_src * 2), 16)
        ev = ops.events_from_spikes(spikes, max_ev)
        us = _timeit(ops.event_synapse, ev, w)
        # derived: fraction of dense bytes touched (events/n_src)
        frac = float((np.asarray(ev) >= 0).mean() * max_ev / n_src)
        print(f"kernel/event_synapse_d{density},{us:.0f},"
              f"dense_byte_frac={max_ev/n_src:.3f}")
    bench_event_compaction(rng)
    bench_packed_synapse(rng)
    # lif_update: fused vs unfused byte traffic
    v = jnp.asarray(rng.normal(size=(64, 4096)).astype(np.float32))
    i = jnp.asarray(rng.normal(size=(64, 4096)).astype(np.float32))
    us = _timeit(lambda a, b: ops.lif_update(a, b)[0], v, i)
    print(f"kernel/lif_update,{us:.0f},fused_hbm_bytes={4*v.size*4}")
    # c2c_matmul: int8 weights halve weight traffic vs bf16
    x = jnp.asarray(rng.normal(size=(256, 1024)).astype(np.float32))
    wq = jnp.asarray(rng.integers(-127, 128, (1024, 1024)).astype(np.int8))
    us = _timeit(ops.c2c_matmul, x, wq, jnp.float32(0.01))
    ai = 2 * 256 * 1024 * 1024 / (x.nbytes + wq.nbytes + 256 * 1024 * 4)
    print(f"kernel/c2c_matmul,{us:.0f},arith_intensity={ai:.0f}")
    # batched accelerator engine vs looped oracle
    bench_batched_engine(batch=32)


if __name__ == "__main__":
    main()
