"""Serving launcher: prefill + continuous batched decode.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x7b --smoke \
      --requests 8 --prompt-len 16 --gen 16 [--mesh 1,1] [--sp]

--sp activates sequence-parallel flash-decoding (the production decode
config on multi-device meshes).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.parallel.decode import make_sp_attention
from repro.parallel.sharding import DECODE_RULES, DECODE_RULES_SP, activate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="1,1")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sp", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    bundle = build_model(cfg)
    dm, mm = (int(x) for x in args.mesh.split(","))
    mesh = jax.make_mesh((dm, mm), ("data", "model"))
    rules = DECODE_RULES_SP if args.sp else DECODE_RULES
    total = args.prompt_len + args.gen

    with activate(mesh, rules):
        params = bundle.init(jax.random.key(0), dtype=jnp.bfloat16)
        prompts = jax.random.randint(
            jax.random.key(1), (args.requests, args.prompt_len), 0,
            cfg.vocab_size)
        batch = {"tokens": prompts}
        if cfg.family == "encdec":
            batch = {"frames": jnp.zeros(
                (args.requests, args.prompt_len * cfg.decoder_ratio,
                 cfg.d_model)), "tokens": prompts}
        if cfg.n_image_embeds:
            batch["image_embeds"] = jnp.zeros(
                (args.requests, cfg.n_image_embeds, cfg.d_model))

        t0 = time.perf_counter()
        logits, cache = jax.jit(bundle.prefill)(params, batch)
        jax.block_until_ready(logits)
        print(f"prefill: {(time.perf_counter()-t0)*1e3:.0f} ms")

        # pad self cache to the horizon
        spec, _ = bundle.cache_spec(args.requests, total)
        cache = {k: (_fit(cache[k], s.shape).astype(s.dtype)
                     if k in cache else jnp.zeros(s.shape, s.dtype))
                 for k, s in spec.items()}
        attn = (make_sp_attention(mesh) if args.sp and mm > 1 else None)

        def decode(p, c, t, pos):
            if attn is not None:
                return bundle.decode(p, c, {"tokens": t, "pos": pos},
                                     attn_impl=attn)
            return bundle.decode(p, c, {"tokens": t, "pos": pos})

        decode = jax.jit(decode)
        toks = jnp.argmax(logits, axis=-1)
        t0 = time.perf_counter()
        outs = [toks]
        for i in range(args.gen - 1):
            pos = jnp.asarray(args.prompt_len + i, jnp.int32)
            logits, cache = decode(params, cache, toks, pos)
            toks = jnp.argmax(logits, axis=-1)
            outs.append(toks)
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
    seq = np.stack([np.asarray(t) for t in outs], 1)
    print(f"decoded {args.gen-1} x {args.requests} in {dt*1e3:.0f} ms "
          f"({dt/(max(args.gen-1,1))*1e3:.1f} ms/step)")
    print(f"sample: {seq[0][:12].tolist()}")


def _fit(arr, shape):
    """Pad/trim the seq dim (axis 3) of a cache tensor to match shape."""
    if arr.shape == tuple(shape):
        return arr
    if len(arr.shape) == 5 and arr.shape[:3] == tuple(shape[:3]):
        d = shape[3] - arr.shape[3]
        if d > 0:
            return jnp.pad(arr, ((0, 0),) * 3 + ((0, d), (0, 0)))
        return arr[:, :, :, :shape[3]]
    return jnp.zeros(shape, arr.dtype)


if __name__ == "__main__":
    main()
