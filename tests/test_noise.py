"""Analog non-ideality sensitivity (core/noise.py, DESIGN.md §2a)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noise import AnalogNoise, perturb_beta, perturb_membrane, perturb_weights


def test_zero_noise_is_identity(rng):
    w = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
    n = AnalogNoise()
    assert np.array_equal(np.asarray(perturb_weights(jax.random.key(0), w, n)),
                          np.asarray(w))


def test_weight_noise_magnitude(rng):
    w = jnp.ones((64, 64))
    n = AnalogNoise(weight_sigma=0.05)
    w2 = perturb_weights(jax.random.key(0), w, n)
    rel = float(jnp.std(w2 - w))
    assert 0.03 < rel < 0.07


def test_snn_accuracy_degrades_gracefully(rng):
    """C2C gain error <= 2% costs little accuracy; 50% destroys it —
    the qualitative robustness story for the analog path."""
    from repro.data.events import EventDatasetConfig, event_batches, synthetic_event_dataset
    from repro.engine import MLP_MODEL, SNNTrainConfig, train_snn_model
    from repro.snn.mlp import SNNConfig, snn_forward

    cfg_d = EventDatasetConfig("noise", 8, 8, num_steps=12, base_rate=0.02,
                               signal_rate=0.5)
    snn = SNNConfig(layer_sizes=(cfg_d.n_in, 32, 10), num_steps=12)
    spikes, labels = synthetic_event_dataset(cfg_d, 12, jax.random.key(0))
    params, _ = train_snn_model(MLP_MODEL, snn,
                                event_batches(spikes, labels, 32),
                                SNNTrainConfig(steps=120, log_every=1000),
                                key=jax.random.key(1), log_fn=lambda s: None)

    def acc(p):
        counts, _ = snn_forward(p, jnp.asarray(spikes.swapaxes(0, 1)), snn)
        return float((np.asarray(counts).argmax(-1) == labels).mean())

    base = acc(params)

    def noisy(sigma, seed):
        n = AnalogNoise(weight_sigma=sigma)
        return [perturb_weights(jax.random.key(seed + i), w, n)
                for i, w in enumerate(params)]

    small = np.mean([acc(noisy(0.02, s)) for s in range(3)])
    large = np.mean([acc(noisy(0.8, s)) for s in range(3)])
    assert small > base - 0.15
    assert large < small
