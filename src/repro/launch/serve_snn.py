"""MENAGE serving launcher: continuous batching of DVS event streams over a
data-parallel host mesh — closed-list or always-on async.

  PYTHONPATH=src python -m repro.launch.serve_snn --model both --requests 48 \
      [--data 2] [--spoof-devices 2] [--smoke] \
      [--arrivals poisson|bursty|diurnal|adversarial --rate 200 --slack 0.25] \
      [--noise-sigma 0.05] [--scenario blackout|all|...]

Requests are variable-length spike trains; the front end
(:mod:`repro.engine.serving`) pads them into the policy's fixed ``(B, T)``
bucket grid (bounded jit cache, verified via ``trace_count``) and
:func:`repro.engine.sharded_run.run_sharded` fans each bucket batch out over
the mesh — batch axis sharded, control memories replicated, input buffers
donated between steps on accelerator backends.

``--arrivals poisson|bursty|diurnal|adversarial`` switches from the
closed-list ``run_bucketed`` pass to the always-on loop
(:mod:`repro.engine.stream_server`): a time-stamped arrival process
(:func:`repro.engine.chaos.synth_arrival_trace`) replays through a
:class:`StreamServer` on a virtual clock, with per-request deadlines
(``--slack``) forcing partial bucket dispatches and a bounded arrival queue
applying backpressure.  ``--noise-sigma`` serves through a deterministic
noisy device instance (accuracy-under-noise shadow probes), and
``--scenario NAME|all`` replays named chaos scripts from
:data:`repro.engine.chaos.SCENARIOS` instead (device loss, SLO shedding,
the combined blackout).

``--spoof-devices N`` emulates an N-device host on CPU (sets
``XLA_FLAGS=--xla_force_host_platform_device_count`` before jax initializes;
must be the launcher that imports jax first, hence the sys.argv peek below).
"""

from __future__ import annotations

import argparse
import time

from repro.launch._spoof import (assert_spoof_applied,
                                 spoof_devices_from_argv)

_SPOOFED = spoof_devices_from_argv()  # before any jax import in this process

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.accelerator import MappedModel, map_model  # noqa: E402
from repro.core.energy import AcceleratorSpec  # noqa: E402
from repro.core.layers import Conv2d, Dense, SumPool2d  # noqa: E402
from repro.core.lif import LIFParams  # noqa: E402
from repro.core.noise import AnalogNoise  # noqa: E402
from repro.engine import (BucketPolicy, StreamServer,  # noqa: E402
                          VirtualClock, run_bucketed, serve_trace,
                          trace_count)
# arrival synthesis lives with the chaos scenarios now; re-exported here so
# existing imports (benchmarks/async_serving_bench.py) keep working
from repro.engine.chaos import (ARRIVAL_MODES, SCENARIOS,  # noqa: E402,F401
                                run_scenario, synth_arrival_trace)
from repro.engine.sharded_run import snn_serve_mesh  # noqa: E402


def build_demo_model(kind: str, *, smoke: bool = False,
                     seed: int = 0) -> MappedModel:
    """A servable mapped model with random pruned weights (training is not
    the point of the serving path; spike statistics are).  ``mlp`` mirrors
    the paper's N-MNIST-style stack, ``conv`` the conv/pool/dense lowering."""
    rng = np.random.default_rng(seed)
    spec = AcceleratorSpec("serve-demo", n_cores=4, n_engines=8, n_caps=16,
                           weight_mem_bytes=1 << 20)
    lif = LIFParams(beta=0.85, threshold=0.6)
    if kind == "mlp":
        sizes = (64, 48, 10) if smoke else (256, 128, 64, 10)
        ws = []
        for i in range(len(sizes) - 1):
            w = rng.normal(0, 0.4, (sizes[i], sizes[i + 1])).astype(np.float32)
            w[np.abs(w) < np.quantile(np.abs(w), 0.6)] = 0
            ws.append(w)
        return map_model(ws, spec, lif=lif)
    if kind == "conv":
        c, side = (2, 6) if smoke else (2, 10)
        k = rng.normal(0, 0.6, (4, c, 3, 3)).astype(np.float32)
        k[rng.random(k.shape) > 0.6] = 0
        conv = Conv2d(kernel=k, in_shape=(c, side, side), stride=1, padding=1)
        pool = SumPool2d(conv.out_shape, 2)
        head = rng.normal(0, 0.4, (int(np.prod(pool.out_shape)), 10)) \
            .astype(np.float32)
        head[np.abs(head) < np.quantile(np.abs(head), 0.4)] = 0
        return map_model([conv, pool, Dense(w=head)], spec, lif=lif)
    raise ValueError(f"unknown model kind {kind!r} (mlp|conv)")


def synth_requests(n: int, n_in: int, *, t_lo: int = 4, t_hi: int = 30,
                   rate: float = 0.15, seed: int = 0) -> list[np.ndarray]:
    """A stream of n variable-length DVS-style requests ``[T_i, n_in]``."""
    rng = np.random.default_rng(seed)
    lengths = rng.integers(t_lo, t_hi + 1, size=n)
    return [(rng.random((int(t), n_in)) < rate).astype(np.float32)
            for t in lengths]


def serve_async(model, trace, *, policy: BucketPolicy, mesh,
                queue_capacity: int = 256, backpressure: str = "reject",
                service_model=None, max_events: int | None = None,
                with_stats: bool = False, donate: bool | None = None,
                noise=None, noise_key=0, tracer=None):
    """One async serving pass over an arrival trace (virtual clock);
    returns ``(results, rids, metrics)``.  ``metrics`` is the
    ``ServerMetrics`` snapshot plus the trajectory numbers
    ``BENCH_async_serving.json`` records: offered load, simulated-time
    throughput, wall seconds, and the jit-trace delta.  ``tracer`` (a
    :class:`~repro.engine.tracing.FlightRecorder`) enables per-request span
    tracing — the overhead benchmark's on/off comparison surface."""
    server = StreamServer(model, policy=policy, mesh=mesh,
                          clock=VirtualClock(),
                          queue_capacity=queue_capacity,
                          backpressure=backpressure,
                          service_model=service_model,
                          max_events=max_events, with_stats=with_stats,
                          donate=donate, noise=noise, noise_key=noise_key,
                          tracer=tracer)
    n0 = trace_count()
    t0 = time.perf_counter()
    results, rids = serve_trace(server, trace)
    wall = time.perf_counter() - t0
    snap = server.metrics.snapshot()
    makespan = max(server.now(), 1e-9)
    span = max(trace[-1][0] - trace[0][0], 1e-9) if len(trace) > 1 else 1e-9
    events = sum(t["events"] for t in server.telemetry)
    snap.update({
        "requests": len(trace),
        "offered_rps": len(trace) / span,
        "throughput_rps": snap["completed"] / makespan,
        "events_per_s": events / max(wall, 1e-9),
        "makespan_s": makespan,
        "wall_s": wall,
        "new_traces": trace_count() - n0,
        "n_buckets": server.policy.n_buckets,
    })
    return results, rids, snap


def serve_stream(model, streams, *, policy: BucketPolicy, mesh,
                 max_events: int | None = None, with_stats: bool = False):
    """One serving pass; returns (results, metrics).  Metrics are the
    serving-trajectory numbers BENCH_serving.json records: events/s,
    spikes/s, p50/p99 per-bucket step latency, and the jit-trace count."""
    telemetry: list[dict] = []
    n0 = trace_count()
    t0 = time.perf_counter()
    results = run_bucketed(model, streams, policy=policy, mesh=mesh,
                           max_events=max_events, with_stats=with_stats,
                           telemetry=telemetry)
    wall = time.perf_counter() - t0
    lat_ms = np.asarray([t["seconds"] for t in telemetry]) * 1e3
    events = sum(t["events"] for t in telemetry)
    spikes = sum(t["out_spikes"] for t in telemetry)
    metrics = {
        "requests": len(streams),
        "engine_steps": len(telemetry),
        "wall_s": wall,
        "events_per_s": events / max(wall, 1e-9),
        "spikes_per_s": spikes / max(wall, 1e-9),
        "p50_step_ms": float(np.percentile(lat_ms, 50)) if len(lat_ms) else 0.0,
        "p99_step_ms": float(np.percentile(lat_ms, 99)) if len(lat_ms) else 0.0,
        "new_traces": trace_count() - n0,
        "n_buckets": policy.n_buckets,
    }
    return results, metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mlp", choices=["mlp", "conv", "both"])
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--data", type=int, default=None,
                    help="mesh data-axis extent (default: all devices)")
    ap.add_argument("--spoof-devices", type=int, default=None,
                    help="emulate N CPU devices (set before jax init)")
    ap.add_argument("--max-events", type=int, default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--arrivals", default="closed",
                    choices=["closed", *ARRIVAL_MODES],
                    help="closed: drain a fixed request list (run_bucketed);"
                         " otherwise: always-on async loop over a synthetic"
                         " arrival process (StreamServer) — poisson, bursty,"
                         " diurnal (day/night load swing), adversarial"
                         " (flood/famine with tight deadlines)")
    ap.add_argument("--noise-sigma", type=float, default=0.0,
                    help="serving-time analog noise: C2C-ladder gain error "
                         "sigma (core/noise.py); async arrivals only")
    ap.add_argument("--scenario", default=None,
                    help="replay a named chaos scenario from "
                         f"repro.engine.chaos ({', '.join(SCENARIOS)}) "
                         "or 'all'; overrides --arrivals")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="mean offered load for async arrivals, requests/s")
    ap.add_argument("--slack", type=float, default=0.25,
                    help="per-request deadline slack, seconds after arrival")
    ap.add_argument("--queue-capacity", type=int, default=256,
                    help="async arrival-queue bound (backpressure kicks in)")
    ap.add_argument("--donate", default="auto", choices=["auto", "on", "off"],
                    help="donate the padded bucket buffer to each engine "
                         "call (auto: on unless the backend is CPU)")
    args = ap.parse_args()
    donate = None if args.donate == "auto" else args.donate == "on"
    assert_spoof_applied(_SPOOFED)

    mesh = snn_serve_mesh(args.data)
    n_shards = mesh.size
    kinds = ["mlp", "conv"] if args.model == "both" else [args.model]
    n_req = min(args.requests, 16) if args.smoke else args.requests
    t_hi = 12 if args.smoke else 30
    if args.scenario is not None:
        names = list(SCENARIOS) if args.scenario == "all" else \
            [args.scenario]
        for kind in kinds:
            packed = build_demo_model(kind, smoke=args.smoke).pack()
            for name in names:
                sc = SCENARIOS[name]
                if sc.needs_mesh and n_shards < 2:
                    print(f"chaos/{kind}/{name}: SKIP (needs >= 2 devices; "
                          f"use --spoof-devices)")
                    continue
                _, _, m = run_scenario(packed, sc, mesh=mesh)
                print(f"chaos/{kind}/{name}: {m['completed']}/{m['requests']}"
                      f" served | miss rate {m['deadline_miss_rate']:.3f} | "
                      f"shed {m['shed']} rejected {m['rejected']} | mesh "
                      f"{m['mesh_size_start']}->{m['mesh_size_end']} | "
                      f"slo switches {m['slo_switches']} | noise agreement "
                      f"{m['noise_agreement']:.3f} "
                      f"({m['noise_probes']} probes)")
        return

    for kind in kinds:
        model = build_demo_model(kind, smoke=args.smoke)
        packed = model.pack()
        if args.arrivals != "closed":
            trace = synth_arrival_trace(n_req, packed.n_in,
                                        mode=args.arrivals, rate=args.rate,
                                        slack=args.slack, t_hi=t_hi, seed=1)
            policy = BucketPolicy.covering([s.shape[0] for _, s, _ in trace],
                                           n_shards=n_shards,
                                           max_batch=4 * n_shards)
            # instantaneous-service simulation: batch formation then depends
            # only on the (fixed) trace, so the warm replay compiles exactly
            # the buckets the hot replay hits and the retrace gate below is
            # deterministic (the bench calibrates real service times instead)
            svc = lambda b, t: 0.0  # noqa: E731
            noise = (AnalogNoise(weight_sigma=args.noise_sigma)
                     if args.noise_sigma > 0 else None)
            serve_async(packed, trace, policy=policy, mesh=mesh,
                        queue_capacity=args.queue_capacity,
                        service_model=svc, max_events=args.max_events,
                        donate=donate, noise=noise)
            results, rids, m = serve_async(
                packed, trace, policy=policy, mesh=mesh,
                queue_capacity=args.queue_capacity,
                service_model=svc, max_events=args.max_events,
                donate=donate, noise=noise)
            assert m["new_traces"] == 0, "hot async pass retraced the jit!"
            preds = [int(results[r].out_spikes.sum(axis=0).argmax())
                     for r in rids[:8] if r is not None and r in results]
            print(f"serve-async/{kind} [{args.arrivals}]: "
                  f"{m['completed']}/{m['requests']} reqs over "
                  f"{n_shards}-way mesh | offered {m['offered_rps']:.0f} "
                  f"rps, served {m['throughput_rps']:.0f} rps | latency "
                  f"p50 {m['p50_latency_s']*1e3:.1f} ms p99 "
                  f"{m['p99_latency_s']*1e3:.1f} ms | miss rate "
                  f"{m['deadline_miss_rate']:.3f} | fill "
                  f"{m['bucket_fill_ratio']:.2f} | forced "
                  f"{m['forced_dispatches']}/{m['dispatches']} | "
                  f"buckets<= {m['n_buckets']} | sample preds {preds}")
            continue
        streams = synth_requests(n_req, packed.n_in, t_hi=t_hi, seed=1)
        policy = BucketPolicy.covering([s.shape[0] for s in streams],
                                       n_shards=n_shards,
                                       max_batch=4 * n_shards)
        # warm every bucket this stream touches, then measure a hot pass
        serve_stream(packed, streams, policy=policy, mesh=mesh,
                     max_events=args.max_events)
        results, m = serve_stream(packed, streams, policy=policy, mesh=mesh,
                                  max_events=args.max_events)
        assert m["new_traces"] == 0, "hot serving pass retraced the jit!"
        preds = [int(r.out_spikes.sum(axis=0).argmax()) for r in results[:8]]
        print(f"serve/{kind}: {m['requests']} reqs over {n_shards}-way mesh "
              f"in {m['wall_s']*1e3:.0f} ms | "
              f"{m['events_per_s']/1e3:.1f}k events/s, "
              f"{m['spikes_per_s']/1e3:.1f}k spikes/s | "
              f"step p50 {m['p50_step_ms']:.1f} ms p99 "
              f"{m['p99_step_ms']:.1f} ms | "
              f"buckets<= {m['n_buckets']} | sample preds {preds}")


if __name__ == "__main__":
    main()
