"""Live-socket serving front end: real connections -> StreamServer.

Until now the always-on loop only replayed closed traces; this launcher
binds it to a TCP socket speaking the length-prefixed
:mod:`repro.engine.ingest` protocol, so real clients (the soak harness,
``benchmarks/soak_bench.py``, or an actual DVS gateway) drive admission,
deadlines, backpressure, and chaos recovery over a live connection:

  PYTHONPATH=src python -m repro.launch.socket_serve --model mlp \
      --port 7473 [--spoof-devices 2] [--noise-sigma 0.05] \
      [--slo-target 0.1] [--smoke]

Design: a single-threaded ``selectors`` event loop.  Engine dispatches run
inline (the loop drains sockets between engine calls — exactly the
single-threaded-server model ``serve_trace`` simulates, so the soak
numbers and the VirtualClock replays describe the same machine).  The
select timeout tracks ``StreamServer.next_deadline()``, so deadline-forced
partial dispatches fire on time even when no bytes arrive.  Every request
gets an answer: results as bit-exact spike rasters, rejections (admission,
backpressure, shed) as reasoned REJECT frames.
"""

from __future__ import annotations

import argparse
import contextlib
import logging
import math
import selectors
import socket
import threading
import time

from repro.launch._spoof import (assert_spoof_applied,
                                 spoof_devices_from_argv)

_SPOOFED = spoof_devices_from_argv()  # before any jax import in this process

import numpy as np  # noqa: E402

from repro.engine import ingest  # noqa: E402
from repro.engine.serving import BucketPolicy  # noqa: E402
from repro.engine.stream_server import SLOPolicy, StreamServer  # noqa: E402

_log = logging.getLogger(__name__)

# select timeout ceiling: how stale next_deadline() may get while idle
_TICK_S = 0.05


class _Conn:
    """Per-connection state: incremental decoder + in-flight accounting."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.decoder = ingest.FrameDecoder()
        self.inflight = 0
        self.draining = False       # client sent EOF; close when drained


class SpikeSocketServer:
    """A :class:`StreamServer` behind a TCP listener.

    ``serve(...)`` runs the event loop in the calling thread;
    :func:`serving_thread` wraps it for in-process harnesses.  All
    ``StreamServer`` chaos knobs (noise, SLO policy, chaos hook, mesh)
    pass through ``server_kwargs`` — the soak harness injects device loss
    into a *live* socket server exactly as the deterministic replays do.
    """

    def __init__(self, model, *, policy: BucketPolicy,
                 host: str = "127.0.0.1", port: int = 0,
                 max_request_steps: int = 4096, **server_kwargs):
        self.server = StreamServer(model, policy=policy,
                                   on_rejection=self._on_rejection,
                                   **server_kwargs)
        # untrusted-input bound: a protocol-valid REQUEST header may claim
        # any u32 T; cap it before unpacking (T * n_in float32 blows up
        # ~32x over the wire size) and before it reaches admission
        self.max_request_steps = max_request_steps
        self._listener = socket.create_server((host, port))
        self._listener.setblocking(False)
        self.address = self._listener.getsockname()
        self._sel = selectors.DefaultSelector()
        self._sel.register(self._listener, selectors.EVENT_READ, None)
        self._conns: dict[socket.socket, _Conn] = {}
        self._owner: dict[int, tuple[_Conn, int]] = {}  # rid -> (conn, req_id)
        # rejections arrive via the server's on_rejection callback, an
        # unbounded outbox: post-admission sheds are answered from here by
        # _drain_new_rejections, never inferred from the bounded metrics
        # deque (which overflows under sustained shed-mode load)
        self._rej_outbox: list = []
        self._last_inline_rej = None
        self._stop = threading.Event()
        self.served = 0

    # ------------------------------------------------------------- control

    def stop(self) -> None:
        """Ask the loop to exit after its current iteration (thread-safe)."""
        self._stop.set()

    # ----------------------------------------------------------- plumbing

    def _send(self, conn: _Conn, data: bytes) -> None:
        try:
            conn.sock.sendall(data)
        except OSError:
            self._drop(conn)

    def _drop(self, conn: _Conn) -> None:
        if conn.sock not in self._conns:
            return
        with contextlib.suppress(KeyError):
            self._sel.unregister(conn.sock)
        del self._conns[conn.sock]
        conn.sock.close()
        # orphan its in-flight requests: results with no owner are dropped
        self._owner = {rid: (c, q) for rid, (c, q) in self._owner.items()
                       if c is not conn}

    def _on_rejection(self, rej) -> None:
        """StreamServer's rejection callback (fires inside ``submit``)."""
        if rej.rid is None:
            self._last_inline_rej = rej  # answered by _on_request's caller
        else:
            self._rej_outbox.append(rej)

    def _drain_new_rejections(self) -> None:
        """Answer every post-admission rejection (queued requests shed by
        backpressure) accumulated in the outbox since the last drain."""
        if not self._rej_outbox:
            return
        outbox, self._rej_outbox = self._rej_outbox, []
        for rej in outbox:
            owner = self._owner.pop(rej.rid, None)
            if owner is not None:
                conn, req_id = owner
                conn.inflight -= 1
                self._send(conn, ingest.encode_rejection(
                    req_id, f"{rej.reason}: {rej.detail}"))

    def _deliver(self, done) -> None:
        for rid, res in done:
            owner = self._owner.pop(rid, None)
            if owner is None:
                continue            # connection vanished mid-service
            conn, req_id = owner
            conn.inflight -= 1
            self.served += 1
            self._send(conn, ingest.encode_result(req_id, res.out_spikes))

    def _on_request(self, conn: _Conn, frame: ingest.Frame) -> None:
        if frame.kind != ingest.KIND_REQUEST:
            raise ingest.ProtocolError(
                f"client sent frame kind {frame.kind}, expected REQUEST")
        # validate the claimed shape BEFORE unpacking or submitting: a
        # well-framed request with the wrong raster width (or an absurd T)
        # must answer with a REJECT, not raise out of the event loop and
        # kill serving for every other connected client
        req_id, t, n_in, _ = ingest.peek_request(frame.payload)
        want = self.server.packed.n_in
        if n_in != want:
            self._send(conn, ingest.encode_rejection(
                req_id, f"bad_shape: raster width {n_in} != model "
                        f"n_in {want}"))
            return
        if t > self.max_request_steps:
            self._send(conn, ingest.encode_rejection(
                req_id, f"overlong: {t} steps > socket cap "
                        f"{self.max_request_steps}"))
            return
        _, stream, slack = ingest.decode_request(frame.payload)
        rid = self.server.submit(
            stream, slack=None if math.isinf(slack) else slack)
        if rid is None:
            rej = self._last_inline_rej
            self._send(conn, ingest.encode_rejection(
                req_id, f"{rej.reason}: {rej.detail}"))
            return
        self._owner[rid] = (conn, req_id)
        conn.inflight += 1

    def _on_readable(self, sock: socket.socket) -> None:
        if sock is self._listener:
            client, addr = self._listener.accept()
            client.setblocking(False)
            conn = _Conn(client)
            self._conns[client] = conn
            self._sel.register(client, selectors.EVENT_READ, conn)
            _log.info("socket_serve: connection from %s", addr)
            return
        conn = self._conns[sock]
        try:
            chunk = sock.recv(1 << 16)
        except OSError:
            self._drop(conn)
            return
        if not chunk:
            # EOF: finish its in-flight, then close.  Unregister the read
            # side now — a half-closed socket is permanently readable, so
            # leaving it in the selector busy-spins select() and keeps
            # refreshing last_activity, starving the idle-flush path the
            # connection needs to ever drain.  The write side stays open
            # for the pending results.
            conn.draining = True
            with contextlib.suppress(KeyError):
                self._sel.unregister(sock)
            return
        try:
            for frame in conn.decoder.feed(chunk):
                self._on_request(conn, frame)
                # a full-bucket submit may have dispatched inline
                self._deliver(self.server.collect())
                self._drain_new_rejections()
        except ingest.ProtocolError as e:
            _log.warning("socket_serve: protocol error, dropping client: %s",
                         e)
            self._drop(conn)

    # ---------------------------------------------------------------- loop

    def _tick(self) -> None:
        """One scheduler beat: fire due deadline dispatches, deliver."""
        self._deliver(self.server.poll())
        self._drain_new_rejections()
        for conn in [c for c in self._conns.values()
                     if c.draining and c.inflight == 0]:
            self._drop(conn)

    def serve(self, *, max_requests: int | None = None,
              idle_flush_s: float = 0.25) -> None:
        """Run the event loop until :meth:`stop` (or ``max_requests``
        results have been served).  ``idle_flush_s``: with pending
        best-effort requests, no deadline due, and no bytes arriving for
        this long, flush — a lone trailing request never hangs the
        socket."""
        last_activity = time.monotonic()
        while not self._stop.is_set():
            nd = self.server.next_deadline()
            timeout = (_TICK_S if nd is None
                       else min(max(nd - self.server.now(), 0.0), _TICK_S))
            events = self._sel.select(timeout)
            if events:
                last_activity = time.monotonic()
            for key, _ in events:
                self._on_readable(key.fileobj)
            self._tick()
            if (self.server.queue_depth > 0 and not events
                    and self.server.next_deadline() is None
                    and time.monotonic() - last_activity > idle_flush_s):
                self._deliver(self.server.flush())
                self._drain_new_rejections()
            if max_requests is not None and self.served >= max_requests:
                break
        self._deliver(self.server.flush())
        self._drain_new_rejections()

    def close(self) -> None:
        self.stop()
        for conn in list(self._conns.values()):
            self._drop(conn)
        with contextlib.suppress(KeyError):
            self._sel.unregister(self._listener)
        self._listener.close()
        self._sel.close()


@contextlib.contextmanager
def serving_thread(server: SpikeSocketServer, **serve_kwargs):
    """Run ``server.serve()`` on a daemon thread for in-process harnesses
    (the soak bench and the tier-1 socket test); joins and closes on
    exit."""
    t = threading.Thread(target=server.serve, kwargs=serve_kwargs,
                         daemon=True, name="spike-socket-serve")
    t.start()
    try:
        yield server
    finally:
        server.stop()
        t.join(timeout=30)
        server.close()


# ------------------------------------------------------------------ client

class SpikeClient:
    """A minimal blocking client for the ingest protocol — what the soak
    harness runs many of.  ``send`` streams a request; ``recv_all`` blocks
    until every outstanding request is answered (result or rejection)."""

    def __init__(self, host: str, port: int, timeout: float = 60.0):
        self.sock = socket.create_connection((host, port), timeout=timeout)
        self.decoder = ingest.FrameDecoder()
        self._next_id = 0
        self.results: dict[int, np.ndarray] = {}
        self.rejections: dict[int, str] = {}

    def send(self, stream, slack: float = math.inf) -> int:
        req_id = self._next_id
        self._next_id += 1
        self.sock.sendall(ingest.encode_request(req_id, stream, slack))
        return req_id

    def _pump(self) -> None:
        chunk = self.sock.recv(1 << 16)
        if not chunk:
            raise ConnectionError("server closed the connection")
        for frame in self.decoder.feed(chunk):
            if frame.kind == ingest.KIND_RESULT:
                req_id, out = ingest.decode_result(frame.payload)
                self.results[req_id] = out
            elif frame.kind == ingest.KIND_REJECT:
                req_id, reason = ingest.decode_rejection(frame.payload)
                self.rejections[req_id] = reason
            else:
                raise ingest.ProtocolError(
                    f"server sent frame kind {frame.kind}")

    def recv_all(self) -> None:
        """Block until every sent request has a result or a rejection."""
        while len(self.results) + len(self.rejections) < self._next_id:
            self._pump()

    def close(self) -> None:
        self.sock.close()


# --------------------------------------------------------------------- CLI

def main():
    from repro.engine.sharded_run import snn_serve_mesh
    from repro.launch.serve_snn import build_demo_model, synth_requests

    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mlp", choices=["mlp", "conv"])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7473)
    ap.add_argument("--data", type=int, default=None,
                    help="mesh data-axis extent (default: all devices)")
    ap.add_argument("--spoof-devices", type=int, default=None)
    ap.add_argument("--queue-capacity", type=int, default=256)
    ap.add_argument("--backpressure", default="reject",
                    choices=["reject", "shed_oldest"])
    ap.add_argument("--default-slack", type=float, default=math.inf,
                    help="deadline slack for requests that send inf")
    ap.add_argument("--noise-sigma", type=float, default=0.0,
                    help="serving-time C2C gain error (core/noise.py); "
                         "shadow probes feed the noise_agreement metric")
    ap.add_argument("--slo-target", type=float, default=None,
                    help="enable SLO shed-vs-extend switching at this "
                         "windowed deadline-miss rate")
    ap.add_argument("--smoke", action="store_true",
                    help="serve a built-in burst of local requests through "
                         "the socket and exit (CI liveness check)")
    args = ap.parse_args()
    assert_spoof_applied(_SPOOFED)
    logging.basicConfig(level=logging.INFO)

    from repro.core.noise import AnalogNoise  # after jax device spoof

    mesh = snn_serve_mesh(args.data)
    model = build_demo_model(args.model, smoke=args.smoke)
    packed = model.pack()
    policy = BucketPolicy.for_mesh(mesh.size)
    noise = (AnalogNoise(weight_sigma=args.noise_sigma)
             if args.noise_sigma > 0 else None)
    slo = (SLOPolicy(target_miss_rate=args.slo_target)
           if args.slo_target is not None else None)
    srv = SpikeSocketServer(
        packed, policy=policy, host=args.host, port=args.port, mesh=mesh,
        queue_capacity=args.queue_capacity, backpressure=args.backpressure,
        default_slack=args.default_slack, noise=noise, slo=slo)
    host, port = srv.address
    print(f"socket-serve/{args.model}: listening on {host}:{port} "
          f"({mesh.size}-way mesh, buckets<={policy.n_buckets})")

    if args.smoke:
        # best-effort requests: full buckets dispatch inline, the remainder
        # rides the idle-flush path — no deadline misses from cold-jit wall
        # time polluting a liveness check
        streams = synth_requests(12, packed.n_in, t_hi=12, seed=1)
        with serving_thread(srv, max_requests=len(streams)):
            cli = SpikeClient(host, port)
            for s in streams:
                cli.send(s)
            cli.recv_all()
            cli.close()
        snap = srv.server.metrics.snapshot()
        assert len(cli.results) == len(streams), \
            f"served {len(cli.results)}/{len(streams)}"
        print(f"socket-serve smoke: {snap['completed']} served, "
              f"p50 latency {snap['p50_latency_s']*1e3:.1f} ms, "
              f"miss rate {snap['deadline_miss_rate']:.3f}")
        return
    try:
        srv.serve()
    except KeyboardInterrupt:
        pass
    finally:
        srv.close()


if __name__ == "__main__":
    main()
