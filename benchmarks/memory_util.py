"""Figs 6-7 reproduction: MEM_S&N utilization per time step while processing
one input image, per layer, for Accel_1/N-MNIST and Accel_2/CIFAR10-DVS."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.energy import _prepare
from repro.configs.menage_paper import (CIFAR_DATA, CIFAR_SNN, NMNIST_DATA,
                                        NMNIST_SNN)
from repro.core.accelerator import map_model, run
from repro.core.energy import ACCEL_1, ACCEL_2


def _spark(values, width: int = 40) -> str:
    chars = " .:-=+*#%@"
    v = np.asarray(values, dtype=float)
    if len(v) > width:
        idx = np.linspace(0, len(v) - 1, width).astype(int)
        v = v[idx]
    hi = v.max() or 1.0
    return "".join(chars[int(min(x / hi, 1.0) * (len(chars) - 1))] for x in v)


def measure(spec, data_cfg, snn_cfg, train_steps=15, image: int = 0):
    key = jax.random.key(0)
    weights, spikes = _prepare(data_cfg, snn_cfg, train_steps, key)
    model = map_model(weights, spec, lif=snn_cfg.lif)
    res = run(model, spikes[image])
    return res.per_layer_util, res.per_layer_stats


def main():
    for spec, dc, sc, tag in [(ACCEL_1, NMNIST_DATA, NMNIST_SNN, "nmnist"),
                              (ACCEL_2, CIFAR_DATA, CIFAR_SNN, "cifar10dvs")]:
        utils, stats = measure(spec, dc, sc)
        for li, u in enumerate(utils):
            print(f"memutil/{tag}/L{li},avg={u.mean():.4f},"
                  f"peak={u.max():.4f},trace={_spark(u)}")
        # the paper's headline observation: avg utilization stays low, spikes
        # at busy steps
        avg = float(np.mean([u.mean() for u in utils]))
        peak = float(np.max([u.max() for u in utils]))
        print(f"memutil/{tag},avg={avg:.4f},peak={peak:.4f},"
              f"peak_over_avg={peak/max(avg,1e-9):.1f}x")


if __name__ == "__main__":
    main()
