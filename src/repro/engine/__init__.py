from repro.engine.batched_run import (BatchedDispatchStats, BatchedRunResult,  # noqa: F401
                                      PackedLayer, PackedModel, PackedRound,
                                      pack_model, run_batched, should_donate,
                                      trace_count)
from repro.engine.serving import (BucketPolicy, OverlongRequestError,  # noqa: F401
                                  RequestResult, TELEMETRY_KEYS,
                                  execute_plan, plan_batches, run_bucketed)
from repro.engine.sharded_run import (DeviceLossError, run_sharded,  # noqa: F401
                                      shrink_mesh, snn_serve_mesh)
from repro.engine.tracing import (ANOMALY_KINDS, FlightRecorder,  # noqa: F401
                                  HIST_KEYS, Histogram, RequestTrace,
                                  SPAN_KINDS, Span)
from repro.engine.registry import (DEFAULT_MODEL, ModelEntry,  # noqa: F401
                                   ModelRegistry, UnknownModelError)
from repro.engine.stream_server import (METRIC_KEYS, PER_MODEL_KEYS,  # noqa: F401
                                        Rejection, Request, SLOPolicy,
                                        ServerMetrics, StreamServer,
                                        VirtualClock, WallClock, serve_trace)
from repro.engine.chaos import (ARRIVAL_MODES, ChaosScenario,  # noqa: F401
                                SCENARIOS, TenantSpec, make_chaos_hook,
                                run_scenario, swap_model_for,
                                synth_arrival_trace)
from repro.engine.train_loop import TrainLoopConfig, TrainState, make_train_step, train_loop  # noqa: F401
from repro.engine.snn_train import (CONV_MODEL, MLP_MODEL, SNNModel,  # noqa: F401
                                    SNNTrainConfig, make_snn_train_step,
                                    model_for, snn_train_mesh,
                                    snn_train_trace_count, train_snn_model)
