"""A-NEURON behaviour (paper §III-A, Fig. 5): integrate, fire, reset, leak."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lif import (LIFParams, lif_rollout, lif_step, rate_encode,
                            spike_fn)


def test_integrate_and_fire_waveform():
    """Constant sub-threshold current accumulates, crosses V_th, fires once,
    resets — the Fig. 5 waveform shape."""
    p = LIFParams(beta=1.0, threshold=1.0, v_reset=0.0)  # no leak
    currents = jnp.full((10, 1), 0.3)
    spikes, vtrace = lif_rollout(currents, p)
    v = np.asarray(vtrace)[:, 0]
    s = np.asarray(spikes)[:, 0]
    # V: .3 .6 .9 -> fire at 1.2 (>=1) -> reset to 0
    assert s[0] == 0 and s[1] == 0 and s[2] == 0
    assert s[3] == 1
    assert v[3] == 0.0            # reset after fire
    assert np.isclose(v[2], 0.9, atol=1e-6)


def test_leak_discharges_between_steps():
    p = LIFParams(beta=0.5, threshold=10.0)
    currents = jnp.zeros((4, 1))
    _, vtrace = lif_rollout(currents, p, v0=jnp.ones((1,)) * 8.0)
    v = np.asarray(vtrace)[:, 0]
    assert np.allclose(v, [4.0, 2.0, 1.0, 0.5])


def test_reset_to_v_reset_value():
    p = LIFParams(beta=1.0, threshold=1.0, v_reset=0.25)
    v, s = lif_step(jnp.asarray([0.9]), jnp.asarray([0.5]), p)
    assert s[0] == 1.0 and np.isclose(v[0], 0.25)


def test_surrogate_gradient_nonzero_near_threshold():
    p = LIFParams()

    def f(v):
        return spike_fn(v, p.threshold, p.surrogate_slope).sum()

    g_at = jax.grad(f)(jnp.asarray([1.0]))       # at threshold
    g_far = jax.grad(f)(jnp.asarray([-10.0]))    # far below
    assert g_at[0] > 0.1
    assert g_far[0] < g_at[0] * 1e-2


def test_rate_encode_statistics():
    x = jnp.asarray([0.1, 0.9])
    spikes = rate_encode(x, 2000, jax.random.key(0))
    rates = np.asarray(spikes.mean(axis=0))
    assert np.allclose(rates, [0.1, 0.9], atol=0.05)


def test_lif_gradient_flows_through_time():
    p = LIFParams(beta=0.9, threshold=1.0)

    def loss(w):
        currents = jnp.ones((5, 3)) * w
        spikes, _ = lif_rollout(currents, p)
        return spikes.sum()

    g = jax.grad(loss)(0.4)
    assert np.isfinite(g) and abs(g) > 0
