"""Mixtral-8x7B: 32L, 8 experts top-2, GQA 32/8, SWA 4096
[arXiv:2401.04088; hf]."""

import dataclasses

from repro.configs.common import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=32000, head_dim=128,
    n_experts=8, top_k=2,
    window=4096,                     # sliding-window attention
    rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256, n_experts=4, top_k=2, window=16)
