"""Model-stack correctness: per-arch smoke tests (deliverable f) + component
oracles (flash attention, SSD, decode-vs-prefill consistency)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, applicable_shapes
from repro.models import build_model
from repro.models.layers import flash_attention, naive_attention


KEY = jax.random.key(0)


def _train_batch(cfg, b=2, s=17):
    if cfg.family == "encdec":
        return {"frames": jnp.ones((b, 16, cfg.d_model), jnp.float32),
                "tokens": jnp.ones((b, 5), jnp.int32)}
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)}
    if cfg.n_image_embeds:
        batch["image_embeds"] = jnp.ones(
            (b, cfg.n_image_embeds, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """Reduced config: one fwd+bwd step, finite loss and gradients."""
    cfg = get_smoke_config(arch)
    bundle = build_model(cfg)
    params = bundle.init(KEY)
    loss, grads = jax.value_and_grad(bundle.loss)(params, _train_batch(cfg))
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(g, np.float32)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    bundle = build_model(cfg)
    params = bundle.init(KEY)
    spec, _ = bundle.cache_spec(2, 32)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
    logits, cache2 = bundle.decode(
        params, cache, {"tokens": jnp.ones((2,), jnp.int32),
                        "pos": jnp.asarray(3, jnp.int32)})
    assert logits.shape == (2, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert all(a.shape == b.shape for a, b in
               zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)))


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment table."""
    c = get_config("deepseek_67b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.d_ff, c.vocab_size) == (95, 8192, 64, 8, 22016, 102400)
    q = get_config("qwen3_moe_235b_a22b")
    assert (q.n_layers, q.n_experts, q.top_k, q.vocab_size) == (94, 128, 8, 151936)
    m = get_config("mixtral_8x7b")
    assert (m.n_experts, m.top_k, m.window) == (8, 2, 4096)
    z = get_config("zamba2_2_7b")
    assert (z.n_layers, z.ssm_state, z.family) == (54, 64, "hybrid")
    mm = get_config("mamba2_2_7b")
    assert (mm.n_layers, mm.ssm_state, mm.d_ff) == (64, 128, 0)
    w = get_config("whisper_medium")
    assert (w.n_layers, w.d_model, w.vocab_size) == (24, 1024, 51865)


def test_long_500k_applicability():
    """Sub-quadratic archs run long_500k; full-attention archs skip."""
    runs = {a: "long_500k" in applicable_shapes(get_config(a))
            for a in ARCH_IDS}
    assert runs["mamba2_2_7b"] and runs["zamba2_2_7b"]
    assert runs["mixtral_8x7b"] and runs["h2o_danube_1_8b"]  # SWA
    for a in ("internvl2_26b", "qwen3_moe_235b_a22b", "internlm2_20b",
              "internlm2_1_8b", "deepseek_67b", "whisper_medium"):
        assert not runs[a]


# ------------------------------------------------------------ flash attention

@pytest.mark.parametrize("s,h,kh,window", [
    (64, 4, 4, None),       # MHA
    (64, 8, 2, None),       # GQA
    (96, 4, 2, 16),         # GQA + SWA, non-multiple seq
    (33, 2, 1, None),       # ragged seq vs chunks
])
def test_flash_attention_vs_naive(rng, s, h, kh, window):
    b, d = 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kh, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kh, d)).astype(np.float32))
    out = flash_attention(q, k, v, causal=True, window=window,
                          q_chunk=16, kv_chunk=16)
    ref = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_flash_attention_cross(rng):
    """Non-causal cross-attention (whisper decoder path)."""
    b, sq, sk, h, d = 2, 24, 40, 4, 16
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, sk, h, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, sk, h, d)).astype(np.float32))
    out = flash_attention(q, k, v, causal=False, q_chunk=8, kv_chunk=8)
    ref = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_flash_attention_gradients(rng):
    b, s, h, d = 1, 32, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))

    g1 = jax.grad(lambda q: flash_attention(q, k, v, q_chunk=8,
                                            kv_chunk=8).sum())(q)
    g2 = jax.grad(lambda q: naive_attention(q, k, v).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


# --------------------------------------------------- decode/prefill agreement

def test_transformer_decode_matches_prefill(rng):
    """Strong cache-path test: prefill(S) then decode token S must equal the
    full forward at position S."""
    from repro.models.transformer import (transformer_decode_step,
                                          transformer_logits,
                                          transformer_prefill)
    cfg = get_smoke_config("internlm2_1_8b")
    bundle = build_model(cfg)
    params = bundle.init(KEY)
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    # full forward over S+1 tokens -> logits at position S
    logits_all, _ = transformer_logits(params, cfg, toks, remat=False)
    want = np.asarray(logits_all[:, -1], np.float32)
    # prefill S tokens, then decode token S-... prefill covers [0..S-1],
    # decode consumes token S? Here: prefill first 11, decode token index 11.
    last_logits, cache = transformer_prefill(params, cfg, toks[:, :11])
    # grow cache to 12 slots
    k = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, 0), (0, 1), (0, 0)))
    v = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, 0), (0, 1), (0, 0)))
    got_logits, _ = transformer_decode_step(
        params, cfg, {"k": k, "v": v}, toks[:, 11],
        jnp.asarray(11, jnp.int32))
    got = np.asarray(got_logits, np.float32)
    # bf16 internals: agreement within bf16 tolerance
    np.testing.assert_allclose(got, want, atol=0.15, rtol=0.05)


def test_mamba2_decode_matches_forward(rng):
    """SSM state handoff: prefill state + step == full forward (logits at
    the next position)."""
    from repro.models.api import _mamba2_prefill
    from repro.models.mamba2 import mamba2_decode_step, mamba2_logits
    cfg = get_smoke_config("mamba2_2_7b")
    bundle = build_model(cfg)
    params = bundle.init(KEY)
    toks = jax.random.randint(jax.random.key(2), (2, 9), 0, cfg.vocab_size)
    logits_all = mamba2_logits(params, cfg, toks, remat=False)
    want = np.asarray(logits_all[:, -1], np.float32)
    _, cache = _mamba2_prefill(params, cfg, toks[:, :8])
    # conv tail is a zero stand-in in prefill; rebuild it from the true
    # inputs is exercised here by feeding the last conv_width-1 tokens
    # through decode steps instead:
    spec, _ = bundle.cache_spec(2, 9)
    cache_run = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
    logits = None
    for t in range(9):
        logits, cache_run = mamba2_decode_step(
            params, cfg, cache_run, toks[:, t], jnp.asarray(t, jnp.int32))
    got = np.asarray(logits, np.float32)
    np.testing.assert_allclose(got, want, atol=0.2, rtol=0.05)


def test_swa_ring_buffer_consistency(rng):
    """SWA arch decode with ring-buffer cache == full forward, once the
    window has wrapped."""
    from repro.models.transformer import (transformer_decode_step,
                                          transformer_logits)
    cfg = get_smoke_config("h2o_danube_1_8b")  # window=16
    bundle = build_model(cfg)
    params = bundle.init(KEY)
    s_total = 24                                # > window -> wrap
    toks = jax.random.randint(jax.random.key(3), (1, s_total), 0,
                              cfg.vocab_size)
    logits_all, _ = transformer_logits(params, cfg, toks, remat=False)
    want = np.asarray(logits_all[:, -1], np.float32)
    spec, _ = bundle.cache_spec(1, s_total)
    cache = jax.tree.map(lambda sp: jnp.zeros(sp.shape, sp.dtype), spec)
    assert cache["k"].shape[3] == cfg.window    # ring buffer size
    logits = None
    for t in range(s_total):
        logits, cache = transformer_decode_step(
            params, cfg, cache, toks[:, t], jnp.asarray(t, jnp.int32))
    got = np.asarray(logits, np.float32)
    np.testing.assert_allclose(got, want, atol=0.2, rtol=0.05)
