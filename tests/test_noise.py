"""Analog non-ideality sensitivity (core/noise.py, DESIGN.md §2a)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.noise import (AnalogNoise, as_noise_key, perturb_beta,
                              perturb_membrane, perturb_packed,
                              perturb_weights)


def test_zero_noise_is_identity(rng):
    w = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
    n = AnalogNoise()
    assert np.array_equal(np.asarray(perturb_weights(jax.random.key(0), w, n)),
                          np.asarray(w))


def test_weight_noise_magnitude(rng):
    w = jnp.ones((64, 64))
    n = AnalogNoise(weight_sigma=0.05)
    w2 = perturb_weights(jax.random.key(0), w, n)
    rel = float(jnp.std(w2 - w))
    assert 0.03 < rel < 0.07


def test_snn_accuracy_degrades_gracefully(rng):
    """C2C gain error <= 2% costs little accuracy; 50% destroys it —
    the qualitative robustness story for the analog path."""
    from repro.data.events import EventDatasetConfig, event_batches, synthetic_event_dataset
    from repro.engine import MLP_MODEL, SNNTrainConfig, train_snn_model
    from repro.snn.mlp import SNNConfig, snn_forward

    cfg_d = EventDatasetConfig("noise", 8, 8, num_steps=12, base_rate=0.02,
                               signal_rate=0.5)
    snn = SNNConfig(layer_sizes=(cfg_d.n_in, 32, 10), num_steps=12)
    spikes, labels = synthetic_event_dataset(cfg_d, 12, jax.random.key(0))
    params, _ = train_snn_model(MLP_MODEL, snn,
                                event_batches(spikes, labels, 32),
                                SNNTrainConfig(steps=120, log_every=1000),
                                key=jax.random.key(1), log_fn=lambda s: None)

    def acc(p):
        counts, _ = snn_forward(p, jnp.asarray(spikes.swapaxes(0, 1)), snn)
        return float((np.asarray(counts).argmax(-1) == labels).mean())

    base = acc(params)

    def noisy(sigma, seed):
        n = AnalogNoise(weight_sigma=sigma)
        return [perturb_weights(jax.random.key(seed + i), w, n)
                for i, w in enumerate(params)]

    small = np.mean([acc(noisy(0.02, s)) for s in range(3)])
    large = np.mean([acc(noisy(0.8, s)) for s in range(3)])
    assert small > base - 0.15
    assert large < small


# ------------------------------------------- serving-side device instances

def _mapped_model(rng, sizes=(14, 12, 6)):
    from repro.core.accelerator import map_model
    from repro.core.energy import AcceleratorSpec
    from repro.core.lif import LIFParams
    ws = []
    for i in range(len(sizes) - 1):
        w = rng.normal(0, 0.5, (sizes[i], sizes[i + 1])).astype(np.float32)
        w[rng.random(w.shape) > 0.6] = 0
        ws.append(w)
    return map_model(ws, AcceleratorSpec("noise-test", n_cores=3,
                                         n_engines=4, n_caps=8,
                                         weight_mem_bytes=1 << 18),
                     lif=LIFParams(beta=0.8, threshold=0.5))


def _round_weights(packed):
    return [np.asarray(r.w_dense if r.w_dense is not None else r.coo_val)
            for layer in packed.layers for r in layer.rounds]


def test_perturb_packed_is_a_deterministic_device_instance(rng):
    """Same (key, sigma) -> bit-identical noisy model; different keys ->
    different device instances; zero sigma is the identity (same object);
    absent synapses stay exactly zero."""
    packed = _mapped_model(rng).pack()
    n = AnalogNoise(weight_sigma=0.05)
    a = perturb_packed(as_noise_key(7), packed, n)
    b = perturb_packed(as_noise_key(7), packed, n)
    for wa, wb in zip(_round_weights(a), _round_weights(b)):
        assert np.array_equal(wa, wb)
    other = perturb_packed(as_noise_key(8), packed, n)
    assert any(not np.array_equal(wa, wo) for wa, wo in
               zip(_round_weights(a), _round_weights(other)))
    assert perturb_packed(as_noise_key(7), packed, AnalogNoise()) is packed
    for w0, wa in zip(_round_weights(packed), _round_weights(a)):
        assert np.array_equal(wa == 0, w0 == 0), \
            "multiplicative noise must preserve the sparsity pattern"


def test_run_bucketed_noise_injection_is_reproducible(rng):
    """The serving entry point's noise kwargs name one device instance:
    same seed -> bit-exact outputs (the accuracy delta vs clean is a fixed
    number, not a distribution); no noise -> the clean outputs."""
    from repro.engine import BucketPolicy, run_bucketed
    model = _mapped_model(rng)
    streams = [(rng.random((t, 14)) < 0.3).astype(np.float32)
               for t in (4, 9, 6, 3)]
    policy = BucketPolicy(batch_sizes=(2, 4), time_steps=(10,))
    kw = dict(policy=policy, with_stats=False)
    noise = AnalogNoise(weight_sigma=0.08)
    clean = run_bucketed(model, streams, **kw)
    n1 = run_bucketed(model, streams, noise=noise, noise_key=3, **kw)
    n2 = run_bucketed(model, streams, noise=noise, noise_key=3, **kw)
    for r1, r2 in zip(n1, n2):
        assert np.array_equal(r1.out_spikes, r2.out_spikes)
    assert any(not np.array_equal(c.out_spikes, r.out_spikes)
               for c, r in zip(clean, n1)), \
        "8% serving noise changed no output"
    same_as_clean = run_bucketed(model, streams, noise=None, **kw)
    for c, r in zip(clean, same_as_clean):
        assert np.array_equal(c.out_spikes, r.out_spikes)
    # a fixed, reproducible accuracy-delta style statistic
    flips1 = sum(int(c.out_spikes.sum(0).argmax() != r.out_spikes.sum(0)
                     .argmax()) for c, r in zip(clean, n1))
    flips2 = sum(int(c.out_spikes.sum(0).argmax() != r.out_spikes.sum(0)
                     .argmax()) for c, r in zip(clean, n2))
    assert flips1 == flips2
