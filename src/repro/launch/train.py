"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch internlm2_1_8b \
      --steps 1000 --mesh 16,16 [--smoke] [--compress] [--ckpt DIR]

On real hardware the mesh maps onto the pod's devices; on this CPU container
use --smoke (reduced config + small mesh over emulated devices via
XLA_FLAGS=--xla_force_host_platform_device_count=N).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, get_smoke_config
from repro.data.tokens import TokenPipelineConfig, token_batch
from repro.engine.train_loop import (TrainLoopConfig, init_train_state,
                                     make_train_step, resume_or_init,
                                     train_loop)
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.optim.compress import CompressionConfig
from repro.parallel.sharding import TRAIN_RULES, activate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--mesh", default="1,1", help="data,model")
    ap.add_argument("--seq", type=int, default=0, help="0 = train_4k shape")
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=200)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    assert cfg.family != "encdec" or True
    shape = SHAPES["train_4k"]
    seq = args.seq or (64 if args.smoke else shape.seq_len)
    batch = args.batch or (8 if args.smoke else shape.global_batch)
    bundle = build_model(cfg)
    data_cfg = TokenPipelineConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                   global_batch=batch)
    dm, mm = (int(x) for x in args.mesh.split(","))
    mesh = jax.make_mesh((dm, mm), ("data", "model"))
    opt_cfg = AdamWConfig(lr=args.lr)
    comp = CompressionConfig(enabled=args.compress)

    with activate(mesh, TRAIN_RULES):
        params = bundle.init(jax.random.key(0))
        n = sum(p.size for p in jax.tree.leaves(params))
        print(f"{cfg.name}: {n/1e6:.1f}M params, mesh {mesh.devices.shape}, "
              f"batch {batch} x seq {seq}")
        state = init_train_state(None, params, opt_cfg, comp).as_tree()
        step_fn = jax.jit(make_train_step(bundle.loss, opt_cfg, comp),
                          donate_argnums=(0,))
        loop_cfg = TrainLoopConfig(steps=args.steps,
                                   checkpoint_every=args.checkpoint_every,
                                   checkpoint_dir=args.ckpt)
        state, start = resume_or_init(loop_cfg, state)

        def batch_fn(step):
            if cfg.family == "encdec":
                b = token_batch(data_cfg, step)
                return {"frames": jnp.zeros((batch, seq, cfg.d_model)),
                        "tokens": jnp.asarray(
                            b["tokens"][:, :seq // cfg.decoder_ratio + 1])}
            b = {"tokens": jnp.asarray(token_batch(data_cfg, step)["tokens"])}
            if cfg.n_image_embeds:
                b["image_embeds"] = jnp.zeros(
                    (batch, cfg.n_image_embeds, cfg.d_model))
            return b

        state, hist = train_loop(state, step_fn, batch_fn, loop_cfg,
                                 start_step=start)
    print(f"final loss {hist['loss'][-1]:.4f}; "
          f"checkpoints: {hist['checkpoints']}")


if __name__ == "__main__":
    main()
