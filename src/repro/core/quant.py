"""8-bit post-training quantization (paper §III, Algorithm 1 step 2).

The accelerator stores weights in 8-bit digital form feeding the C2C ladder
(eq. (2)): the ladder computes ``V_ref * sum_i W_i 2^{i-n}`` — an unsigned
fractional n-bit multiply.  Signed weights are handled the way charge-domain
macros do it in practice: sign-magnitude, with the sign selecting the
polarity of V_ref.  We therefore quantize symmetrically to int8 with a
per-tensor (or per-row) scale.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# Weight bit-widths the operand path supports end to end: quantization,
# sign-magnitude packing, the packed event_synapse kernel, SRAM pricing, and
# the energy model all key off this tuple (docs/PRECISION.md is locked to it).
SUPPORTED_BITS = (2, 4, 8)


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """int8 values + float scale; dequant = q * scale."""

    q: jax.Array          # int8
    scale: jax.Array      # f32 scalar or per-axis vector

    def dequantize(self) -> jax.Array:
        return self.q.astype(jnp.float32) * self.scale

    @property
    def shape(self):
        return self.q.shape


def quantize_symmetric(w: jax.Array, bits: int = 8, axis: int | None = None) -> QuantizedTensor:
    """Symmetric signed quantization to ``bits`` bits.

    axis=None → per-tensor scale; axis=k → per-slice scale along axis k
    (kept as a broadcastable vector).

    The clip is symmetric ``[-qmax, qmax]``: the sign-magnitude C2C ladder
    (1 polarity bit + ``bits-1`` magnitude bits, eq. (2)) cannot represent
    the two's-complement extreme ``-(qmax+1)`` — its magnitude needs a
    ``bits``-th magnitude bit — so that code must never be emitted.
    """
    qmax = 2 ** (bits - 1) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(w))
    else:
        amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int8)
    return QuantizedTensor(q=q, scale=scale.astype(jnp.float32))


def c2c_ladder_value(q_row: jax.Array, bits: int = 8) -> jax.Array:
    """Ideal C2C-ladder output fraction for a digital word (paper eq. (2)).

    A ``bits``-wide sign-magnitude word carries 1 polarity bit and
    ``bits-1`` magnitude bits W_{n-2}..W_0, so the ladder sums over the
    magnitude lanes only:
        frac = sum_{i=0}^{n-2} W_i * 2^{i-(n-1)} = magnitude / 2^{bits-1}
    (the sign flips V_ref polarity).  Full-scale codes ``+-qmax`` therefore
    reach ``(2^{bits-1}-1)/2^{bits-1}`` — one LSB short of the rail, the
    intended ladder fraction.  Returns the fraction in (-1, 1), such that
    ``V_out = V_ref * frac`` with ``V_ref = scale * 2^{bits-1}``.
    """
    n_mag = bits - 1
    sign = jnp.where(q_row < 0, -1.0, 1.0)
    mag = jnp.abs(q_row.astype(jnp.int32))
    weights = 2.0 ** (jnp.arange(n_mag) - n_mag)  # 2^{i-(n-1)}
    bit_vals = jnp.stack([(mag >> i) & 1 for i in range(n_mag)], axis=-1).astype(jnp.float32)
    return sign * (bit_vals @ weights)


def quantize_pytree(params, bits: int = 8):
    """Quantize every >=2-D float leaf of a pytree (weight matrices); leave
    biases / scalars in float.  Returns (quantized pytree of QuantizedTensor
    or raw leaf, dequantized float pytree for execution)."""

    def q_leaf(w):
        if hasattr(w, "ndim") and w.ndim >= 2 and jnp.issubdtype(w.dtype, jnp.floating):
            return quantize_symmetric(w, bits=bits)
        return w

    qtree = jax.tree.map(q_leaf, params)

    def dq_leaf(leaf):
        if isinstance(leaf, QuantizedTensor):
            return leaf.dequantize()
        return leaf

    dqtree = jax.tree.map(dq_leaf, qtree, is_leaf=lambda x: isinstance(x, QuantizedTensor))
    return qtree, dqtree


def quantization_error(w: jax.Array, bits: int = 8) -> jax.Array:
    qt = quantize_symmetric(w, bits=bits)
    return jnp.max(jnp.abs(qt.dequantize() - w))


# --------------------------------------------------- sub-byte operand packing

def check_bits(bits: int) -> int:
    """Validate a weight bit-width against the packed operand path."""
    if bits not in SUPPORTED_BITS:
        raise ValueError(
            f"unsupported weight bit-width {bits}; the packed operand path "
            f"supports {SUPPORTED_BITS}")
    return bits


def lanes_per_byte(bits: int) -> int:
    """How many ``bits``-wide sign-magnitude words one int8 lane carries."""
    return 8 // check_bits(bits)


def pack_signmag(q: np.ndarray, bits: int) -> np.ndarray:
    """Pack signed integer codes into sign-magnitude sub-byte lanes.

    ``q[..., n]`` (any signed integer dtype, values in ``[-qmax, qmax]``)
    becomes ``int8[..., n * bits / 8]``: each code is stored as 1 sign bit +
    ``bits-1`` magnitude bits, and ``8/bits`` consecutive destination lanes
    share one byte (lane ``j`` lives in byte ``j // L`` at bit offset
    ``(j % L) * bits`` — the layout the packed event_synapse kernel unpacks
    in-kernel).  The last axis must be a multiple of ``8/bits``.
    """
    ell = lanes_per_byte(bits)
    qmax = 2 ** (bits - 1) - 1
    q = np.asarray(q)
    if q.shape[-1] % ell:
        raise ValueError(
            f"last axis {q.shape[-1]} not a multiple of {ell} lanes/byte "
            f"at {bits} bits — pad destinations first")
    qi = q.astype(np.int64)
    if qi.size and (qi.max() > qmax or qi.min() < -qmax):
        raise ValueError(
            f"codes outside the {bits}-bit sign-magnitude range "
            f"[-{qmax}, {qmax}]: [{qi.min()}, {qi.max()}]")
    words = ((qi < 0).astype(np.uint8) << (bits - 1)) \
        | np.abs(qi).astype(np.uint8)
    grouped = words.reshape(*q.shape[:-1], -1, ell)
    packed = np.zeros(grouped.shape[:-1], dtype=np.uint8)
    for s in range(ell):
        packed |= grouped[..., s] << (s * bits)
    return packed.view(np.int8)


def unpack_signmag(packed, bits: int):
    """Inverse of :func:`pack_signmag`: ``int8[..., m]`` packed lanes back to
    integer codes ``[..., m * 8 / bits]`` (int32).  Pure ``jnp`` ops, so it
    runs under jit and inside Pallas interpret mode; numpy arrays work too.
    """
    ell = lanes_per_byte(bits)
    mask = (1 << bits) - 1
    r = packed.astype(jnp.int32) & 0xFF        # undo int8 sign extension
    lanes = jnp.stack([(r >> (s * bits)) & mask for s in range(ell)], axis=-1)
    words = lanes.reshape(*packed.shape[:-1], packed.shape[-1] * ell)
    mag = words & (2 ** (bits - 1) - 1)
    sign = (words >> (bits - 1)) & 1
    return mag - 2 * sign * mag
