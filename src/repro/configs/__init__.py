"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``.

One module per assigned architecture (exact published config) plus the
paper's own two MENAGE accelerator/SNN configs.  Smoke configs are reduced
same-family variants for CPU tests.
"""

from __future__ import annotations

import importlib

from repro.configs.common import SHAPES, ArchConfig, ShapeSpec, applicable_shapes  # noqa: F401

ARCH_IDS = [
    "internvl2_26b",
    "qwen3_moe_235b_a22b",
    "mixtral_8x7b",
    "internlm2_20b",
    "h2o_danube_1_8b",
    "internlm2_1_8b",
    "deepseek_67b",
    "whisper_medium",
    "mamba2_2_7b",
    "zamba2_2_7b",
]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}


def _module(name: str):
    name = _ALIAS.get(name, name)
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    return _module(name).SMOKE


def all_configs() -> dict[str, ArchConfig]:
    return {n: get_config(n) for n in ARCH_IDS}
