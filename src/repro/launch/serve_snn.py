"""MENAGE serving launcher: continuous batching of DVS event streams over a
data-parallel host mesh.

  PYTHONPATH=src python -m repro.launch.serve_snn --model both --requests 48 \
      [--data 2] [--spoof-devices 2] [--smoke]

Requests are variable-length spike trains; the front end
(:mod:`repro.engine.serving`) pads them into the policy's fixed ``(B, T)``
bucket grid (bounded jit cache, verified via ``trace_count``) and
:func:`repro.engine.sharded_run.run_sharded` fans each bucket batch out over
the mesh — batch axis sharded, control memories replicated, input buffers
donated between steps on accelerator backends.

``--spoof-devices N`` emulates an N-device host on CPU (sets
``XLA_FLAGS=--xla_force_host_platform_device_count`` before jax initializes;
must be the launcher that imports jax first, hence the sys.argv peek below).
"""

from __future__ import annotations

import argparse
import time

from repro.launch._spoof import (assert_spoof_applied,
                                 spoof_devices_from_argv)

_SPOOFED = spoof_devices_from_argv()  # before any jax import in this process

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.accelerator import MappedModel, map_model  # noqa: E402
from repro.core.energy import AcceleratorSpec  # noqa: E402
from repro.core.layers import Conv2d, Dense, SumPool2d  # noqa: E402
from repro.core.lif import LIFParams  # noqa: E402
from repro.engine import (BucketPolicy, run_bucketed,  # noqa: E402
                          trace_count)
from repro.engine.sharded_run import snn_serve_mesh  # noqa: E402


def build_demo_model(kind: str, *, smoke: bool = False,
                     seed: int = 0) -> MappedModel:
    """A servable mapped model with random pruned weights (training is not
    the point of the serving path; spike statistics are).  ``mlp`` mirrors
    the paper's N-MNIST-style stack, ``conv`` the conv/pool/dense lowering."""
    rng = np.random.default_rng(seed)
    spec = AcceleratorSpec("serve-demo", n_cores=4, n_engines=8, n_caps=16,
                           weight_mem_bytes=1 << 20)
    lif = LIFParams(beta=0.85, threshold=0.6)
    if kind == "mlp":
        sizes = (64, 48, 10) if smoke else (256, 128, 64, 10)
        ws = []
        for i in range(len(sizes) - 1):
            w = rng.normal(0, 0.4, (sizes[i], sizes[i + 1])).astype(np.float32)
            w[np.abs(w) < np.quantile(np.abs(w), 0.6)] = 0
            ws.append(w)
        return map_model(ws, spec, lif=lif)
    if kind == "conv":
        c, side = (2, 6) if smoke else (2, 10)
        k = rng.normal(0, 0.6, (4, c, 3, 3)).astype(np.float32)
        k[rng.random(k.shape) > 0.6] = 0
        conv = Conv2d(kernel=k, in_shape=(c, side, side), stride=1, padding=1)
        pool = SumPool2d(conv.out_shape, 2)
        head = rng.normal(0, 0.4, (int(np.prod(pool.out_shape)), 10)) \
            .astype(np.float32)
        head[np.abs(head) < np.quantile(np.abs(head), 0.4)] = 0
        return map_model([conv, pool, Dense(w=head)], spec, lif=lif)
    raise ValueError(f"unknown model kind {kind!r} (mlp|conv)")


def synth_requests(n: int, n_in: int, *, t_lo: int = 4, t_hi: int = 30,
                   rate: float = 0.15, seed: int = 0) -> list[np.ndarray]:
    """A stream of n variable-length DVS-style requests ``[T_i, n_in]``."""
    rng = np.random.default_rng(seed)
    lengths = rng.integers(t_lo, t_hi + 1, size=n)
    return [(rng.random((int(t), n_in)) < rate).astype(np.float32)
            for t in lengths]


def serve_stream(model, streams, *, policy: BucketPolicy, mesh,
                 max_events: int | None = None, with_stats: bool = False):
    """One serving pass; returns (results, metrics).  Metrics are the
    serving-trajectory numbers BENCH_serving.json records: events/s,
    spikes/s, p50/p99 per-bucket step latency, and the jit-trace count."""
    telemetry: list[dict] = []
    n0 = trace_count()
    t0 = time.perf_counter()
    results = run_bucketed(model, streams, policy=policy, mesh=mesh,
                           max_events=max_events, with_stats=with_stats,
                           telemetry=telemetry)
    wall = time.perf_counter() - t0
    lat_ms = np.asarray([t["seconds"] for t in telemetry]) * 1e3
    events = sum(t["events"] for t in telemetry)
    spikes = sum(t["out_spikes"] for t in telemetry)
    metrics = {
        "requests": len(streams),
        "engine_steps": len(telemetry),
        "wall_s": wall,
        "events_per_s": events / max(wall, 1e-9),
        "spikes_per_s": spikes / max(wall, 1e-9),
        "p50_step_ms": float(np.percentile(lat_ms, 50)) if len(lat_ms) else 0.0,
        "p99_step_ms": float(np.percentile(lat_ms, 99)) if len(lat_ms) else 0.0,
        "new_traces": trace_count() - n0,
        "n_buckets": policy.n_buckets,
    }
    return results, metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mlp", choices=["mlp", "conv", "both"])
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--data", type=int, default=None,
                    help="mesh data-axis extent (default: all devices)")
    ap.add_argument("--spoof-devices", type=int, default=None,
                    help="emulate N CPU devices (set before jax init)")
    ap.add_argument("--max-events", type=int, default=None)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    assert_spoof_applied(_SPOOFED)

    mesh = snn_serve_mesh(args.data)
    n_shards = mesh.size
    kinds = ["mlp", "conv"] if args.model == "both" else [args.model]
    n_req = min(args.requests, 16) if args.smoke else args.requests
    for kind in kinds:
        model = build_demo_model(kind, smoke=args.smoke)
        packed = model.pack()
        streams = synth_requests(n_req, packed.n_in,
                                 t_hi=12 if args.smoke else 30, seed=1)
        policy = BucketPolicy.covering([s.shape[0] for s in streams],
                                       n_shards=n_shards,
                                       max_batch=4 * n_shards)
        # warm every bucket this stream touches, then measure a hot pass
        serve_stream(packed, streams, policy=policy, mesh=mesh,
                     max_events=args.max_events)
        results, m = serve_stream(packed, streams, policy=policy, mesh=mesh,
                                  max_events=args.max_events)
        assert m["new_traces"] == 0, "hot serving pass retraced the jit!"
        preds = [int(r.out_spikes.sum(axis=0).argmax()) for r in results[:8]]
        print(f"serve/{kind}: {m['requests']} reqs over {n_shards}-way mesh "
              f"in {m['wall_s']*1e3:.0f} ms | "
              f"{m['events_per_s']/1e3:.1f}k events/s, "
              f"{m['spikes_per_s']/1e3:.1f}k spikes/s | "
              f"step p50 {m['p50_step_ms']:.1f} ms p99 "
              f"{m['p99_step_ms']:.1f} ms | "
              f"buckets<= {m['n_buckets']} | sample preds {preds}")


if __name__ == "__main__":
    main()
