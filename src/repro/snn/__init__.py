from repro.snn.mlp import (SNNConfig, init_snn, snn_forward,  # noqa: F401
                           snn_forward_batch_major, snn_loss)
from repro.snn.conv import (ConvSNNConfig, conv_snn_forward,  # noqa: F401
                            conv_snn_loss, init_conv_snn, layer_specs)
