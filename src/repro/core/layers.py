"""Layer specs: what ``map_model`` lowers onto MX-NEURACOREs.

The paper (§III) claims MENAGE executes "linear and convolutional neural
models" through the same memory-based control technique — the control
memories do not care *why* a source neuron connects to a destination, only
*that* it does.  A layer spec therefore reduces to two things:

  * ``unroll()``    — the effective sparse synaptic matrix ``[n_src, n_dest]``
                      (what the dispatch hardware computes per event), and
  * ``share_ids()`` — an integer per synapse naming the *stored* weight it
                      reads.  Dense layers store one SRAM word per synapse
                      (``None`` = all unique).  Convolutions store one word
                      per kernel tap and let many MEM_S&N rows point at it
                      (cf. arXiv:2112.07019's synapse compression): the
                      unrolled matrix has ``oh*ow`` synapses per tap but the
                      A-SYN SRAM holds each tap once per engine that uses it.

Index convention (matches :mod:`repro.data.events` and the NCHW training
models in :mod:`repro.snn.conv`): feature maps flatten channel-major,
``idx = c*H*W + y*W + x``; a conv output flattens the same way, so stacking
``Conv2d`` specs — or ending in a ``Dense`` head over the flattened map —
needs no permutation glue.

``SumPool2d`` is a fixed-weight depthwise convolution (every tap = 1.0):
spiking sum-pooling, lowered through the exact same path and followed by the
layer's LIF like every mapped layer (the hardware has no LIF-free bypass).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Dense:
    """A fully-connected layer: ``w[n_in, n_out]`` pruned float weights.

    ``bits`` pins this layer's stored weight bit-width (2/4/8 sign-magnitude
    ladder words); ``None`` defers to ``map_model``'s ``quant_bits``.
    """

    w: np.ndarray
    bits: int | None = None

    @property
    def n_src(self) -> int:
        return self.w.shape[0]

    @property
    def n_dest(self) -> int:
        return self.w.shape[1]

    @property
    def stored_weights(self) -> np.ndarray:
        """The tensor actually kept in SRAM (quantization target)."""
        return self.w

    def with_stored(self, w: np.ndarray) -> "Dense":
        return Dense(w=np.asarray(w), bits=self.bits)

    def unroll(self) -> np.ndarray:
        return np.asarray(self.w)

    def share_ids(self) -> None:
        return None                      # every synapse owns its SRAM word

    @property
    def unique_weight_bytes(self) -> int:
        """Bytes of A-SYN SRAM for the stored (nonzero) words at this
        layer's bit-width (8-bit -> 1 byte per word, 4-bit -> half, ...)."""
        n_words = int((np.asarray(self.w) != 0).sum())
        return -(-n_words * (self.bits or 8) // 8)


@dataclasses.dataclass(frozen=True)
class Conv2d:
    """A 2-D convolution over a ``(C_in, H, W)`` channel-major input.

    kernel:   float ``[c_out, c_in, kh, kw]`` (OIHW, prunable — zero taps
              produce no synapses and no SRAM words)
    in_shape: ``(c_in, h, w)`` of the incoming flattened feature map
    stride / padding: symmetric, SAME-style zero padding of ``padding`` px.
    """

    kernel: np.ndarray
    in_shape: tuple[int, int, int]
    stride: int = 1
    padding: int = 0
    bits: int | None = None       # stored-word bit-width; None = map default

    def __post_init__(self):
        c_out, c_in, kh, kw = self.kernel.shape
        if c_in != self.in_shape[0]:
            raise ValueError(f"kernel expects {c_in} input channels, "
                             f"input has {self.in_shape[0]}")
        oh, ow = self.out_shape[1:]
        if oh <= 0 or ow <= 0:
            raise ValueError(
                f"conv collapses {self.in_shape} to {self.out_shape}")

    @property
    def out_shape(self) -> tuple[int, int, int]:
        c_out, _, kh, kw = self.kernel.shape
        _, h, w = self.in_shape
        oh = (h + 2 * self.padding - kh) // self.stride + 1
        ow = (w + 2 * self.padding - kw) // self.stride + 1
        return (c_out, oh, ow)

    @property
    def n_src(self) -> int:
        c, h, w = self.in_shape
        return c * h * w

    @property
    def n_dest(self) -> int:
        c, h, w = self.out_shape
        return c * h * w

    @property
    def stored_weights(self) -> np.ndarray:
        return self.kernel

    def with_stored(self, kernel: np.ndarray) -> "Conv2d":
        return Conv2d(kernel=np.asarray(kernel), in_shape=self.in_shape,
                      stride=self.stride, padding=self.padding,
                      bits=self.bits)

    def _tap_indices(self):
        """For every nonzero kernel tap and every valid output position:
        (src_flat, dest_flat, tap_flat) index triplets, vectorized."""
        c_out, c_in, kh, kw = self.kernel.shape
        _, h, w = self.in_shape
        _, oh, ow = self.out_shape
        oy = np.arange(oh)
        ox = np.arange(ow)
        srcs, dests, taps = [], [], []
        for co, ci, ky, kx in zip(*np.nonzero(self.kernel)):
            iy = oy * self.stride + ky - self.padding          # [oh]
            ix = ox * self.stride + kx - self.padding          # [ow]
            my = (iy >= 0) & (iy < h)
            mx = (ix >= 0) & (ix < w)
            if not (my.any() and mx.any()):
                continue
            yy, xx = np.meshgrid(iy[my], ix[mx], indexing="ij")
            dy, dx = np.meshgrid(oy[my], ox[mx], indexing="ij")
            srcs.append(ci * h * w + yy.ravel() * w + xx.ravel())
            dests.append(co * oh * ow + dy.ravel() * ow + dx.ravel())
            tap = ((co * c_in + ci) * kh + ky) * kw + kx
            taps.append(np.full(yy.size, tap, dtype=np.int64))
        if not srcs:
            z = np.zeros(0, dtype=np.int64)
            return z, z, z
        return (np.concatenate(srcs), np.concatenate(dests),
                np.concatenate(taps))

    def unroll(self) -> np.ndarray:
        """The effective ``[n_src, n_dest]`` synaptic matrix.  Each
        (src, dest) pair is touched by at most one kernel tap (the tap
        offset is determined by the two positions), so plain assignment —
        not accumulation — is exact."""
        w = np.zeros((self.n_src, self.n_dest), dtype=np.float32)
        src, dest, tap = self._tap_indices()
        w[src, dest] = self.kernel.reshape(-1)[tap]
        return w

    def share_ids(self) -> np.ndarray:
        """``[n_src, n_dest]`` int32: flat kernel-tap index per synapse,
        -1 where no synapse.  Synapses with equal ids share one A-SYN SRAM
        word per engine.  (Dense like the unrolled matrix map_model already
        holds; int32 keeps it the smaller of the two.)"""
        ids = np.full((self.n_src, self.n_dest), -1, dtype=np.int32)
        src, dest, tap = self._tap_indices()
        ids[src, dest] = tap
        return ids

    @property
    def unique_weight_bytes(self) -> int:
        """SRAM bytes for the stored kernel taps at this layer's bit-width —
        NOT per unrolled synapse."""
        n_words = int((np.asarray(self.kernel) != 0).sum())
        return -(-n_words * (self.bits or 8) // 8)


def SumPool2d(in_shape: tuple[int, int, int], pool: int = 2,
              bits: int | None = None) -> Conv2d:
    """Spiking sum-pooling as a fixed depthwise conv: ``pool x pool`` window,
    stride ``pool``, all taps 1.0, channel-diagonal kernel."""
    c, h, w = in_shape
    k = np.zeros((c, c, pool, pool), dtype=np.float32)
    for ci in range(c):
        k[ci, ci] = 1.0
    return Conv2d(kernel=k, in_shape=in_shape, stride=pool, padding=0,
                  bits=bits)


LayerSpec = Dense | Conv2d


def as_layer_spec(layer: "np.ndarray | LayerSpec") -> LayerSpec:
    """Backwards-compatible coercion: bare ``(n_in, n_out)`` matrices are
    Dense layers (the pre-conv ``map_model`` API)."""
    if isinstance(layer, (Dense, Conv2d)):
        return layer
    arr = np.asarray(layer)
    if arr.ndim != 2:
        raise ValueError(
            f"bare weight arrays must be 2-D (n_in, n_out); got {arr.shape} "
            f"— wrap 4-D kernels in Conv2d(kernel, in_shape, stride, padding)")
    return Dense(w=arr)
