"""8-bit quantization + L1 pruning (Algorithm 1 steps) + C2C ladder math."""

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.prune import l1_prune_mask, prune_pytree, sparsity
from repro.core.quant import (c2c_ladder_value, quantize_symmetric,
                              quantization_error, quantize_pytree)


def test_quant_error_bound(rng):
    w = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    err = quantization_error(w, bits=8)
    assert float(err) <= float(jnp.max(jnp.abs(w))) / 127 + 1e-6


def test_quant_roundtrip_int8_range(rng):
    w = jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32))
    qt = quantize_symmetric(w, bits=8)
    q = np.asarray(qt.q)
    assert q.dtype == np.int8 and q.max() <= 127 and q.min() >= -128


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_c2c_ladder_equals_q_over_2n(seed):
    """eq. (2): sum W_i 2^{i-(n-1)} == magnitude/2^{n-1} (sign-magnitude:
    1 sign bit + ``bits-1`` magnitude lanes on the ladder)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(-127, 128, size=(16,)).astype(np.int8))
    frac = c2c_ladder_value(q, bits=8)
    np.testing.assert_allclose(np.asarray(frac),
                               np.asarray(q, np.float32) / 128.0, atol=1e-7)


def test_ladder_times_scale_recovers_dequant(rng):
    w = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
    qt = quantize_symmetric(w, bits=8)
    v_ref = qt.scale * 128.0           # V_ref = scale * 2^{bits-1}
    np.testing.assert_allclose(np.asarray(c2c_ladder_value(qt.q) * v_ref),
                               np.asarray(qt.dequantize()), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), bits=st.sampled_from([2, 4, 8]))
def test_ladder_roundtrip_every_supported_bitwidth(seed, bits):
    """S1 lock: at every supported bit-width, ladder fraction * V_ref
    recovers the dequantized weight exactly (the packed kernel depends on
    this identity to stay bit-exact with the dense path)."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
    qt = quantize_symmetric(w, bits=bits)
    v_ref = qt.scale * 2.0 ** (bits - 1)
    np.testing.assert_allclose(
        np.asarray(c2c_ladder_value(qt.q, bits=bits) * v_ref),
        np.asarray(qt.dequantize()), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), bits=st.sampled_from([2, 4, 6, 8]))
def test_every_code_is_ladder_representable(seed, bits):
    """Regression: the clip used to admit ``-(qmax+1)`` (two's-complement
    extreme), whose magnitude needs a ``bits``-th magnitude bit the
    sign-magnitude C2C ladder does not have — eq. (2) would silently read
    the word as 0 magnitude.  Every emitted code must stay in
    ``[-qmax, qmax]`` and round-trip through the ladder exactly."""
    rng = np.random.default_rng(seed)
    w = np.concatenate([rng.normal(size=30).astype(np.float32),
                        [-1.0, 1.0, -1e9, 1e9, 0.0, -0.5]]).astype(np.float32)
    qt = quantize_symmetric(jnp.asarray(w), bits=bits)
    q = np.asarray(qt.q, dtype=np.int64)
    qmax = 2 ** (bits - 1) - 1
    assert q.min() >= -qmax and q.max() <= qmax
    # ladder fraction * 2^{bits-1} recovers the code bit for bit
    recon = np.round(np.asarray(c2c_ladder_value(qt.q, bits=bits),
                                dtype=np.float64) * 2.0 ** (bits - 1))
    np.testing.assert_array_equal(recon.astype(np.int64), q)


def test_prune_amount(rng):
    w = jnp.asarray(rng.normal(size=(50, 40)).astype(np.float32))
    mask = l1_prune_mask(w, 0.7)
    assert abs(float((~mask).mean()) - 0.7) < 0.02
    # keeps the largest magnitudes
    kept_min = float(jnp.abs(w[mask]).min())
    dropped_max = float(jnp.abs(w[~mask]).max())
    assert kept_min >= dropped_max - 1e-6


def test_prune_pytree_and_sparsity(rng):
    params = {"a": jnp.asarray(rng.normal(size=(20, 20)).astype(np.float32)),
              "b": jnp.asarray(rng.normal(size=(5,)).astype(np.float32))}
    pruned, masks = prune_pytree(params, 0.5)
    assert masks["b"] is None                # 1-D left alone
    assert 0.4 < sparsity(pruned) < 0.6


def test_quantize_pytree_skips_biases(rng):
    params = {"w": jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32)),
              "bias": jnp.zeros((8,))}
    qtree, dq = quantize_pytree(params)
    from repro.core.quant import QuantizedTensor
    assert isinstance(qtree["w"], QuantizedTensor)
    assert not isinstance(qtree["bias"], QuantizedTensor)
    assert dq["w"].shape == (8, 8)
