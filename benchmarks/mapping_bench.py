"""ILP mapping benchmark (§III-D): solver runtime + optimality gap of the
greedy heuristic vs the exact solvers across layer sizes; dispatch-cycle
benefit of ILP load-balancing (the quantity the mapping actually optimizes).

Layers are built as :mod:`repro.core.layers` specs — the post-conv-support
model path — so the bench measures exactly what ``map_model`` solves,
including a shared-weight conv case (one A-SYN word, many MEM_S&N rows).

With ``--out`` it additionally maps the menage_paper conv topology on
Accel_2 and writes ``BENCH_mapping.json``: synapse-compression ratio
(``map_model(compress=True)``), rounds-per-timestep, and autotuned-vs-
default throughput on the bucketed engine.  Gates (CI fails loudly):

  * compression shrinks the allocated A-SYN words and is bit-exact;
  * the autotuned grid never regresses rounds-per-timestep;
  * autotuned throughput stays within 2x of the default grid's.

  PYTHONPATH=src python benchmarks/mapping_bench.py [--smoke] \
      [--out BENCH_mapping.json]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.layers import Conv2d, Dense, LayerSpec
from repro.core.mapping import (MappingProblem, solve_mapping_greedy,
                                solve_mapping_reduced_ilp)
from repro.core.memories import build_event_memories


def dense_spec(n_src: int, n_dest: int, density: float, seed: int = 0) -> Dense:
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(n_src, n_dest)).astype(np.float32)
    w[rng.random(w.shape) > density] = 0
    return Dense(w=w)


def conv_spec(c_in: int, side: int, c_out: int, k: int, density: float,
              seed: int = 0) -> Conv2d:
    rng = np.random.default_rng(seed)
    kern = rng.normal(size=(c_out, c_in, k, k)).astype(np.float32)
    kern[rng.random(kern.shape) > density] = 0
    return Conv2d(kernel=kern, in_shape=(c_in, side, side), stride=1,
                  padding=1)


def bench_one(spec: LayerSpec, m: int, n: int, tag: str,
              fanout_slack: float | None = 0.9,
              time_limit: float = 5.0) -> dict:
    """Solve one layer spec's mapping with the reduced ILP and the greedy
    heuristic; compare assignments, runtime, and resulting MEM_S&N rows
    (dispatch cycles — what the ILP load-balances)."""
    w = np.asarray(spec.unroll())
    share = spec.share_ids()
    fanout = None
    if fanout_slack is not None and share is None:
        fanout = np.maximum((w != 0).sum(1) * fanout_slack, 1).astype(int)
    p = MappingProblem.from_weights(w, m, n, fanout=fanout)

    t0 = time.perf_counter()
    s_ilp = solve_mapping_reduced_ilp(p, time_limit=time_limit)
    t_ilp = time.perf_counter() - t0
    t0 = time.perf_counter()
    s_gr = solve_mapping_greedy(p)
    t_gr = time.perf_counter() - t0

    # dispatch-cycle quality: total MEM_S&N rows (cycles) per solution
    rows_ilp = build_event_memories(w, s_ilp, m, n, share_ids=share).n_rows
    rows_gr = build_event_memories(w, s_gr, m, n, share_ids=share).n_rows
    return {
        "size": f"{tag}_{spec.n_src}x{spec.n_dest}_M{m}N{n}",
        "ilp_assigned": s_ilp.n_assigned, "greedy_assigned": s_gr.n_assigned,
        "ilp_ms": t_ilp * 1e3, "greedy_ms": t_gr * 1e3,
        "ilp_rows": rows_ilp, "greedy_rows": rows_gr,
    }


def cases(smoke: bool):
    if smoke:
        yield bench_one(dense_spec(64, 40, 0.5), 10, 16, "dense")
        yield bench_one(conv_spec(2, 6, 3, 3, 0.6), 10, 16, "conv",
                        fanout_slack=None)
        return
    yield bench_one(dense_spec(64, 40, 0.5), 10, 16, "dense")
    yield bench_one(dense_spec(128, 64, 0.5, seed=1), 10, 16, "dense")
    yield bench_one(dense_spec(200, 100, 0.4, seed=2), 20, 32, "dense")
    yield bench_one(conv_spec(2, 8, 4, 3, 0.6), 10, 16, "conv",
                    fanout_slack=None)
    yield bench_one(conv_spec(4, 10, 8, 3, 0.5, seed=1), 20, 32, "conv",
                    fanout_slack=None)


def _bucketed_events_per_s(model, streams) -> float:
    """Hot-pass throughput of ``run_bucketed`` (events served per second):
    first pass warms the jit caches, second is measured."""
    from repro.engine import run_bucketed
    run_bucketed(model, streams, with_stats=False)
    t0 = time.perf_counter()
    res = run_bucketed(model, streams, with_stats=False)
    dt = time.perf_counter() - t0
    events = sum(float(s.sum()) for s in streams)
    assert res, "bucketed engine returned no results"
    return events / max(dt, 1e-9)


def bench_compression(smoke: bool, seed: int = 0) -> dict:
    """Map the menage_paper conv topology (full input resolution, or
    reduced under ``--smoke``) on Accel_2: compression ratio, grid
    autotuning, and throughput — with the correctness gates inline."""
    import dataclasses

    import jax

    from repro.configs.menage_paper import CIFAR_CONV
    from repro.core.accelerator import map_model
    from repro.core.energy import ACCEL_2
    from repro.core.mapping import autotune_grid
    from repro.engine import run_batched
    from repro.snn.conv import init_conv_snn, layer_specs

    cfg = dataclasses.replace(CIFAR_CONV, in_shape=(2, 8, 8),
                              num_steps=10) if smoke else CIFAR_CONV
    params = init_conv_snn(jax.random.key(seed), cfg)
    specs = layer_specs(params, cfg)
    spec = ACCEL_2

    t0 = time.perf_counter()
    plain = map_model(specs, spec)
    t_map = time.perf_counter() - t0
    t0 = time.perf_counter()
    comp = map_model(specs, spec, compress=True)
    t_comp = time.perf_counter() - t0

    raw_words = sum(l.sram_bytes for l in plain.layers)
    comp_words = sum(l.sram_bytes for l in comp.layers)
    assert comp_words < raw_words, \
        f"compression gate: {comp_words} words !< {raw_words}"

    rng = np.random.default_rng(seed + 1)
    spikes = (rng.random((4, cfg.num_steps, cfg.n_in)) < 0.2
              ).astype(np.float32)
    r_plain = run_batched(plain, spikes, with_stats=False)
    r_comp = run_batched(comp, spikes, with_stats=False)
    assert np.array_equal(r_plain.out_spikes, r_comp.out_spikes), \
        "compression gate: compressed out_spikes differ from uncompressed"

    # grid autotuning (compressed) over a pinned candidate set — the full
    # divisor sweep re-solves the ILP per grid, too slow for a smoke lane
    m0, n0 = spec.n_engines, spec.n_caps
    tuned = autotune_grid(specs, spec, compress=True,
                          candidates=[(m0, n0), (2 * m0, n0 // 2),
                                      (m0 // 2, 2 * n0)])
    assert tuned.best.rounds_per_timestep <= \
        tuned.default.rounds_per_timestep, "autotune gate: rounds regressed"

    streams = [(rng.random((int(t), cfg.n_in)) < 0.2).astype(np.float32)
               for t in rng.integers(cfg.num_steps // 2,
                                     cfg.num_steps + 1, size=8)]
    tput_default = _bucketed_events_per_s(plain, streams)
    tput_tuned = _bucketed_events_per_s(tuned.model, streams)
    # generous gate: the tuned grid reshapes jit tile geometry, so allow
    # noise — but a >2x collapse is a real regression
    assert tput_tuned >= 0.5 * tput_default, \
        f"throughput gate: tuned {tput_tuned:.0f} ev/s < " \
        f"half of default {tput_default:.0f} ev/s"

    row = {
        "config": "menage_paper.CIFAR_CONV" + ("@2x8x8" if smoke else ""),
        "spec": spec.name,
        "n_weight_words_raw": int(raw_words),
        "n_weight_words_compressed": int(comp_words),
        "compression": comp.compression.as_dict(),
        "map_ms": t_map * 1e3, "map_compress_ms": t_comp * 1e3,
        "rounds_per_timestep_default": tuned.default.rounds_per_timestep,
        "rounds_per_timestep_tuned": tuned.best.rounds_per_timestep,
        "grid_default": [m0, n0],
        "grid_tuned": [tuned.best.n_engines, tuned.best.n_caps],
        "grid_scores": [s.as_dict() for s in tuned.scores],
        "events_per_s_default": tput_default,
        "events_per_s_tuned": tput_tuned,
    }
    print(f"mapping/compress_{row['config']},"
          f"words={raw_words}->{comp_words},"
          f"ratio={comp.compression.ratio:.2f},"
          f"rounds={row['rounds_per_timestep_default']}->"
          f"{row['rounds_per_timestep_tuned']},"
          f"grid={m0}x{n0}->{tuned.best.n_engines}x{tuned.best.n_caps},"
          f"ev_per_s={tput_default:.0f}->{tput_tuned:.0f}")
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two small cases (CI drift guard)")
    ap.add_argument("--out", default=None,
                    help="write BENCH_mapping.json (compression + autotune "
                         "+ throughput on the menage_paper conv config)")
    args = ap.parse_args()
    rows = []
    for r in cases(args.smoke):
        gap = r["ilp_assigned"] - r["greedy_assigned"]
        rows.append(r)
        print(f"mapping/{r['size']},ilp_ms={r['ilp_ms']:.1f},"
              f"greedy_ms={r['greedy_ms']:.1f},"
              f"assigned_gap={gap},"
              f"rows_ilp={r['ilp_rows']},rows_greedy={r['greedy_rows']}")
    if args.out:
        comp = bench_compression(args.smoke)
        blob = {"bench": "mapping", "smoke": args.smoke,
                "solvers": rows, "compression": comp}
        with open(args.out, "w") as f:
            json.dump(blob, f, indent=2)
            f.write("\n")
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
