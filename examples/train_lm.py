"""End-to-end LM training driver: train a ~100M-param dense transformer for
a few hundred steps on the synthetic token pipeline, with the production
training loop (checkpoint/restart, straggler detection, optional gradient
compression) on whatever devices exist.

  PYTHONPATH=src python examples/train_lm.py --steps 200 [--compress]
  # multi-device (emulated):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/train_lm.py --steps 50 --mesh 4,2
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.common import ArchConfig
from repro.data.tokens import TokenPipelineConfig, token_batch
from repro.engine.train_loop import (TrainLoopConfig, init_train_state,
                                     make_train_step, resume_or_init,
                                     train_loop)
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.optim.compress import CompressionConfig
from repro.parallel.sharding import TRAIN_RULES, activate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--size", choices=["tiny", "100m"], default="tiny",
                    help="'100m' is the deliverable config (use on real "
                         "accelerators); 'tiny' smoke-runs the same driver "
                         "on CPU")
    ap.add_argument("--mesh", default="1,1")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    if args.size == "100m":
        # ~100M params: 12L x 768 (GPT-2-small-ish) with GQA
        cfg = ArchConfig(name="lm100m", family="dense", n_layers=12,
                         d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
                         vocab_size=32000, head_dim=64)
    else:
        cfg = ArchConfig(name="lm-tiny", family="dense", n_layers=2,
                         d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                         vocab_size=2048, head_dim=32)
    bundle = build_model(cfg)
    data_cfg = TokenPipelineConfig(vocab_size=cfg.vocab_size,
                                   seq_len=args.seq,
                                   global_batch=args.batch)

    dm, mm = (int(x) for x in args.mesh.split(","))
    mesh = jax.make_mesh((dm, mm), ("data", "model"))
    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=50)
    comp = CompressionConfig(enabled=args.compress)

    with activate(mesh, TRAIN_RULES):
        params = bundle.init(jax.random.key(0))
        n = sum(p.size for p in jax.tree.leaves(params))
        print(f"model: {n/1e6:.1f}M params on mesh {mesh.devices.shape}")
        state = init_train_state(None, params, opt_cfg, comp).as_tree()
        step_fn = jax.jit(make_train_step(bundle.loss, opt_cfg, comp),
                          donate_argnums=(0,))
        loop_cfg = TrainLoopConfig(steps=args.steps, checkpoint_every=100,
                                   checkpoint_dir=args.ckpt, log_every=20)
        state, start = resume_or_init(loop_cfg, state)
        if start:
            print(f"resumed from step {start}")

        def batch_fn(step):
            b = token_batch(data_cfg, step)
            return {"tokens": jnp.asarray(b["tokens"])}

        state, hist = train_loop(state, step_fn, batch_fn, loop_cfg,
                                 start_step=start)
    print(f"done: loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}, "
          f"{hist['stragglers']} straggler events, "
          f"checkpoints at {hist['checkpoints']}")


if __name__ == "__main__":
    main()
