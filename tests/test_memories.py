"""Memory-based event control (paper §III-C, Fig. 4): bit-level tables +
cycle-level dispatch equivalence with the dense computation."""

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.mapping import MappingProblem, solve_mapping
from repro.core.memories import (build_event_memories, dispatch_simulate,
                                 mem_sn_utilization)


def _mapped_layer(rng, n_src=12, n_dest=10, m=3, n=4, density=0.5):
    w = rng.normal(size=(n_src, n_dest)).astype(np.float32)
    w[rng.random((n_src, n_dest)) > density] = 0
    p = MappingProblem.from_weights(w, n_engines=m, n_caps=n)
    sol = solve_mapping(p)
    tables = build_event_memories(w, sol, m, n)
    return w, sol, tables


def test_e2a_row_counts_match_engine_grouping(rng):
    w, sol, tables = _mapped_layer(rng)
    for src in range(w.shape[0]):
        dests = np.nonzero(w[src])[0]
        dests = dests[sol.engine[dests] >= 0]
        per_engine = np.bincount(sol.engine[dests], minlength=3) if len(dests) \
            else np.zeros(3, int)
        assert tables.e2a_count[src] == per_engine.max() if len(dests) else 0


def test_rows_one_destination_per_engine_per_cycle(rng):
    """Hardware invariant: each MEM_S&N row drives each A-NEURON at most
    once (one synapse integrated per engine per clock)."""
    _, _, tables = _mapped_layer(rng)
    assert tables.sn_valid.dtype == bool
    # valid is [R, M]; by construction one entry per engine per row
    assert tables.sn_valid.ndim == 2


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 5000))
def test_dispatch_equals_dense_reference(seed):
    """The event-driven dispatch through MEM_E2A/MEM_S&N reproduces
    spikes @ W exactly on assigned neurons, for random layers and trains."""
    rng = np.random.default_rng(seed)
    w, sol, tables = _mapped_layer(rng, n_src=10, n_dest=8, m=2, n=4)
    spikes = (rng.random((6, 10)) < 0.4).astype(np.float32)
    currents, stats = dispatch_simulate(tables, spikes, 8)
    dense = spikes @ w
    assigned = sol.engine >= 0
    assert np.allclose(currents[:, assigned], dense[:, assigned], atol=1e-5)
    # unassigned neurons receive nothing
    assert np.all(currents[:, ~assigned] == 0)


def test_cycles_track_event_row_counts(rng):
    w, sol, tables = _mapped_layer(rng)
    spikes = np.zeros((3, w.shape[0]), dtype=np.float32)
    spikes[1, 2] = 1
    spikes[1, 5] = 1
    _, stats = dispatch_simulate(tables, spikes, w.shape[1])
    assert stats.cycles[0] == 0 and stats.cycles[2] == 0
    expected = max(tables.e2a_count[2], 1) + max(tables.e2a_count[5], 1)
    assert stats.cycles[1] == expected
    assert stats.events[1] == 2


def test_utilization_scales_with_activity(rng):
    w, sol, tables = _mapped_layer(rng, density=0.8)
    quiet = (rng.random((5, w.shape[0])) < 0.05).astype(np.float32)
    busy = (rng.random((5, w.shape[0])) < 0.6).astype(np.float32)
    u_q = mem_sn_utilization(tables, quiet, tables.n_rows)
    u_b = mem_sn_utilization(tables, busy, tables.n_rows)
    assert u_b.mean() > u_q.mean()


def test_row_bit_width_matches_fig4(rng):
    """Fig. 4: row = M valid bits + M*log2(N) virtual idx + M*waddr bits."""
    _, _, tables = _mapped_layer(rng, m=3, n=4)
    m = 3
    virt_bits = 2           # log2(4)
    waddr_bits = int(np.ceil(np.log2(max(tables.weight_mem.shape[1], 2))))
    assert tables.bits_per_row() == m * (1 + virt_bits + waddr_bits)
