"""Data-parallel sharded serving of the batched MENAGE engine.

:func:`run_sharded` executes the same packed control-memory pytree as
``run_batched``, but ``shard_map``-ped over a host mesh: the spike batch is
split along the mesh's data axes while the :class:`PackedModel` — the
MEM_E2A / MEM_S&N tables and the replayed A-SYN weights — is replicated on
every device, mirroring how the silicon replicates a full MX-NEURACORE chain
per die.  Which axes shard is decided by the same logical-axis rule
machinery the transformer stack uses (:mod:`repro.parallel.sharding`,
``SNN_SERVE_RULES``): ``event_batch`` maps to ``("pod", "data")``,
``event_time`` and ``neuron`` stay local, and a batch the mesh cannot split
evenly degrades gracefully to replicated execution instead of crashing.

Equivalence contract (tested, ``tests/test_sharded_engine.py``): every
sample's dispatch is independent — the kernel grid is per-(sample,
dest-block) and the LIF scan never mixes batch rows — so sharding the batch
axis cannot change any bit.  ``run_sharded`` returns the identical
:class:`BatchedRunResult` surface (spikes, DispatchStats, utilization,
overflow, energy) as single-device ``run_batched``, and therefore stays
bit-exact against the numpy oracle.

Serving notes:

  * jit cache: one compiled executable per (mesh, partition spec, shapes);
    the front end (:mod:`repro.engine.serving`) pads requests into a small
    fixed set of ``(B, T)`` buckets so the trace count stays bounded — the
    shared ``trace_count()`` probe counts this path too.
  * donation: on accelerator backends the padded input-spike buffer is
    donated back to the allocator between steps (``donate=True`` default
    off-CPU; CPU XLA does not implement buffer donation and would warn).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec

from repro.engine import batched_run as br
from repro.parallel.compat import shard_map
from repro.parallel.sharding import SNN_SERVE_RULES, ShardingRules


def snn_serve_mesh(n_data: int | None = None) -> Mesh:
    """A 1-D ``("data",)`` host mesh over ``n_data`` devices (default: all
    visible devices) — the serving topology for pure-DP event streaming."""
    n = len(jax.devices()) if n_data is None else n_data
    return jax.make_mesh((n,), ("data",))


class DeviceLossError(RuntimeError):
    """Devices dropped out mid-serving.  Raised by hardware watchdogs in
    production and by chaos hooks in the soak harness
    (:mod:`repro.engine.chaos`); :class:`repro.engine.stream_server
    .StreamServer` catches it at the dispatch boundary and recovers onto
    the shrunken mesh — the serving-side twin of the train loop's elastic
    restart (checkpoints are sharding-agnostic there; here the replicated
    control memories are, so recovery is re-placement, not reload)."""

    def __init__(self, n_lost: int = 1, detail: str = ""):
        self.n_lost = int(n_lost)
        msg = f"lost {self.n_lost} device(s) mid-serving"
        super().__init__(msg + (f": {detail}" if detail else ""))


def shrink_mesh(mesh: Mesh, n_lost: int) -> Mesh:
    """The serving mesh after ``n_lost`` devices drop: a fresh 1-D data
    mesh over the surviving devices.  Because the :class:`PackedModel` is
    replicated (every device holds the full control-memory chain), any
    subset of survivors can serve — recovery needs no state movement, only
    a re-shard of future batches.  Raises :class:`DeviceLossError` when no
    device survives (nothing to recover onto)."""
    assert len(mesh.axis_names) == 1, \
        f"shrink_mesh handles 1-D serving meshes, got axes {mesh.axis_names}"
    survivors = mesh.size - n_lost
    if survivors < 1:
        raise DeviceLossError(n_lost, f"all {mesh.size} devices lost")
    devs = np.asarray(mesh.devices).reshape(-1)[:survivors]
    return Mesh(devs, mesh.axis_names)


def batch_spec(mesh: Mesh, shape: tuple[int, int, int]) -> PartitionSpec:
    """PartitionSpec for a ``[B, T, n_in]`` spike tensor under the SNN
    serving rules: batch over the mesh's data axes when divisible, else
    dropped (replicated) — the rule machinery's graceful degradation."""
    rules = ShardingRules(mesh, SNN_SERVE_RULES)
    return rules.spec(("event_batch", "event_time", "neuron"), tuple(shape))


def n_batch_shards(mesh: Mesh, batch: int) -> int:
    """How many ways ``batch`` actually splits on ``mesh`` (1 = replicated)."""
    spec = batch_spec(mesh, (batch, 1, 1))
    axes = spec[0]
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else axes
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@functools.lru_cache(maxsize=None)
def _sharded_forward(mesh: Mesh, spec: PartitionSpec, donate: bool):
    """Build (once per mesh/spec/donation mode) the jitted sharded forward.
    The per-shard body is ``batched_run._forward_impl`` — the very same
    traced computation as the single-device path, which is what makes the
    bit-exactness hold by construction rather than by luck."""

    def fwd(packed, spikes, max_events):
        br._bump_trace("sharded", donated=donate)
        body = functools.partial(br._forward_impl, max_events=max_events)
        mapped = shard_map(body, mesh=mesh,
                           in_specs=(PartitionSpec(), spec),
                           out_specs=spec, check_rep=False)
        return mapped(packed, spikes)

    kwargs = dict(static_argnames=("max_events",))
    if donate:
        kwargs["donate_argnums"] = (1,)
    return jax.jit(fwd, **kwargs)


def run_sharded(model, in_spikes: np.ndarray, *,
                mesh: Mesh | None = None,
                max_events: int | None = None,
                sn_capacity_rows: int | None = None,
                with_stats: bool = True,
                donate: bool | None = None) -> "br.BatchedRunResult":
    """``run_batched`` over a device mesh: spikes ``[B, T, n_in]`` sharded on
    the batch axis, control memories replicated, results gathered back into
    the identical :class:`BatchedRunResult` surface.

    ``mesh`` defaults to a 1-D data mesh over all visible devices.  ``B``
    should be a multiple of the mesh's data-axis extent for actual
    parallelism (the serving bucket policy guarantees this; see
    ``BucketPolicy.for_mesh``); non-divisible batches run replicated.
    ``donate`` re-uses the input spike buffer on accelerator backends
    (default: on unless the backend is CPU, where XLA lacks donation).
    """
    packed = model if isinstance(model, br.PackedModel) else model.pack()
    spikes_np = np.asarray(in_spikes, dtype=np.float32)
    assert spikes_np.ndim == 3 and spikes_np.shape[2] == packed.n_in, \
        f"expected [B, T, {packed.n_in}], got {spikes_np.shape}"
    if spikes_np.shape[0] == 0:
        # nothing to shard; the single-device path owns the empty-batch case
        return br.run_batched(packed, spikes_np, max_events=max_events,
                              sn_capacity_rows=sn_capacity_rows,
                              with_stats=with_stats)
    mesh = snn_serve_mesh() if mesh is None else mesh
    spec = batch_spec(mesh, spikes_np.shape)
    fwd = _sharded_forward(mesh, spec, br.should_donate(donate))
    layer_outs = fwd(packed, jnp.asarray(spikes_np), max_events)
    return br._finalize(packed, spikes_np, layer_outs, max_events,
                        sn_capacity_rows, with_stats)
