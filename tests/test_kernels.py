"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.quant import pack_signmag
from repro.kernels import ops
from repro.kernels.event_synapse import _events_from_spikes_argsort
from repro.kernels.ref import (c2c_matmul_ladder_ref, c2c_matmul_ref,
                               event_synapse_packed_ref, event_synapse_ref,
                               lif_update_ref)


# ------------------------------------------------------------ event_synapse

@pytest.mark.parametrize("n_src,n_dest,block_d", [
    (16, 128, 128), (40, 512, 256), (100, 256, 64), (7, 384, 128),
])
def test_event_synapse_shapes(rng, n_src, n_dest, block_d):
    w = jnp.asarray(rng.normal(size=(n_src, n_dest)).astype(np.float32))
    spikes = jnp.asarray((rng.random((3, n_src)) < 0.3).astype(np.float32))
    ev = ops.events_from_spikes(spikes, max_events=n_src)
    out = ops.event_synapse(ev, w, block_d=block_d)
    np.testing.assert_allclose(out, event_synapse_ref(ev, w), atol=1e-5)


def test_event_synapse_all_padding(rng):
    w = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    ev = jnp.full((2, 4), -1, jnp.int32)
    out = ops.event_synapse(ev, w)
    assert np.all(np.asarray(out) == 0)


def test_events_from_spikes_roundtrip(rng):
    spikes = jnp.asarray((rng.random((5, 32)) < 0.4).astype(np.float32))
    ev = ops.events_from_spikes(spikes, max_events=32)
    for b in range(5):
        got = sorted(int(i) for i in np.asarray(ev[b]) if i >= 0)
        want = sorted(np.nonzero(np.asarray(spikes[b]))[0].tolist())
        assert got == want


def test_event_overflow_counting(rng):
    spikes = jnp.ones((1, 32))
    assert int(ops.overflow_count(spikes, 10)[0]) == 22
    ev = ops.events_from_spikes(spikes, 10)
    assert np.all(np.asarray(ev) >= 0) and ev.shape == (1, 10)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), density=st.floats(0.0, 0.9))
def test_event_synapse_property(seed, density):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(24, 256)).astype(np.float32))
    spikes = jnp.asarray((rng.random((2, 24)) < density).astype(np.float32))
    ev = ops.events_from_spikes(spikes, max_events=24)
    out = ops.event_synapse(ev, w)
    # equivalence with the dense matmul (the A-SYN contract)
    np.testing.assert_allclose(out, spikes @ w, atol=1e-4)


# ----------------------------------------------------- event_synapse_packed

def _random_codes(rng, n_src, n_dest, bits):
    qmax = 2 ** (bits - 1) - 1
    return rng.integers(-qmax, qmax + 1, (n_src, n_dest)).astype(np.int8)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("n_src,n_dest,block_d", [
    (16, 128, 128), (40, 256, 64), (7, 64, 32),
])
def test_event_synapse_packed_matches_ref(rng, bits, n_src, n_dest, block_d):
    """Packed sub-byte kernel == unpack-then-dense reference at every
    supported bit-width (tentpole contract; allclose — the reference
    reduces in a different order, bit-exactness is vs the dense kernel)."""
    q = _random_codes(rng, n_src, n_dest, bits)
    packed = jnp.asarray(pack_signmag(q, bits))
    scale = np.float32(0.013)
    spikes = jnp.asarray((rng.random((3, n_src)) < 0.3).astype(np.float32))
    ev = ops.events_from_spikes(spikes, max_events=n_src)
    out = ops.event_synapse_packed(ev, packed, scale, bits=bits,
                                   block_d=block_d)
    ref = event_synapse_packed_ref(ev, packed, scale, bits=bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_event_synapse_packed_bit_exact_vs_dense(rng, bits):
    """The packed kernel is BIT-EXACT against the f32 dense kernel on the
    dequantized weights — the invariant that lets the engine switch operand
    layouts without perturbing a single output spike."""
    q = _random_codes(rng, 24, 128, bits)
    scale = np.float32(0.007)
    w = jnp.asarray(q.astype(np.float32) * scale)
    packed = jnp.asarray(pack_signmag(q, bits))
    spikes = jnp.asarray((rng.random((4, 24)) < 0.4).astype(np.float32))
    ev = ops.events_from_spikes(spikes, max_events=24)
    dense = ops.event_synapse(ev, w)
    pk = ops.event_synapse_packed(ev, packed, scale, bits=bits)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(dense))


def test_event_synapse_packed_all_padding(rng):
    q = _random_codes(rng, 8, 64, 4)
    packed = jnp.asarray(pack_signmag(q, 4))
    ev = jnp.full((2, 4), -1, jnp.int32)
    out = ops.event_synapse_packed(ev, packed, np.float32(0.1), bits=4)
    assert np.all(np.asarray(out) == 0)


def test_event_synapse_packed_rejects_bad_bits(rng):
    q = _random_codes(rng, 8, 64, 4)
    packed = jnp.asarray(pack_signmag(q, 4))
    ev = jnp.full((1, 2), -1, jnp.int32)
    with pytest.raises(ValueError):
        ops.event_synapse_packed(ev, packed, np.float32(0.1), bits=3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), bits=st.sampled_from([2, 4, 8]),
       density=st.floats(0.0, 0.9))
def test_event_synapse_packed_property(seed, bits, density):
    """Random dense stacks: packed kernel == dequantized dense matmul at
    every supported width (allclose; the matmul reduces in a different
    order) AND bit-exact vs the gather-order dense kernel."""
    rng = np.random.default_rng(seed)
    q = _random_codes(rng, 20, 192, bits)
    scale = np.float32(0.02)
    w = q.astype(np.float32) * scale
    packed = jnp.asarray(pack_signmag(q, bits))
    spikes = jnp.asarray((rng.random((2, 20)) < density).astype(np.float32))
    ev = ops.events_from_spikes(spikes, max_events=20)
    out = ops.event_synapse_packed(ev, packed, scale, bits=bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(spikes) @ w,
                               atol=1e-4)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ops.event_synapse(ev, jnp.asarray(w))))


# ------------------------------------------------- event-stream compaction

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), density=st.floats(0.0, 1.0),
       max_ev=st.integers(1, 48))
def test_events_cumsum_matches_argsort(seed, density, max_ev):
    """The O(n) cumsum-based stable compaction is bit-identical to the
    full-width argsort it replaced — same events, same order, same padding
    — including under overflow truncation."""
    rng = np.random.default_rng(seed)
    spikes = jnp.asarray((rng.random((3, 40)) < density).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(ops.events_from_spikes(spikes, max_ev)),
        np.asarray(_events_from_spikes_argsort(spikes, max_ev)))


def test_events_cumsum_matches_argsort_edges():
    for spikes in (jnp.zeros((2, 16)), jnp.ones((2, 16))):
        for max_ev in (1, 8, 16, 32):
            np.testing.assert_array_equal(
                np.asarray(ops.events_from_spikes(spikes, max_ev)),
                np.asarray(_events_from_spikes_argsort(spikes, max_ev)))


# ---------------------------------------------------------------- lif_update

@pytest.mark.parametrize("shape,block", [
    ((8, 512), (8, 512)), ((16, 1024), (8, 256)), ((4, 128), (2, 128)),
])
def test_lif_update_shapes(rng, shape, block):
    v = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    i = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    vn, s = ops.lif_update(v, i, beta=0.85, threshold=0.7, v_reset=0.1,
                           block=block)
    vr, sr = lif_update_ref(v, i, 0.85, 0.7, 0.1)
    np.testing.assert_allclose(vn, vr, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


def test_lif_update_matches_core_lif(rng):
    """Kernel forward == core.lif.lif_step forward (shared convention)."""
    from repro.core.lif import LIFParams, lif_step
    p = LIFParams(beta=0.9, threshold=1.0, v_reset=0.0)
    v = jnp.asarray(rng.normal(size=(4, 256)).astype(np.float32))
    i = jnp.asarray(rng.normal(size=(4, 256)).astype(np.float32))
    vn_k, s_k = ops.lif_update(v, i, beta=p.beta, threshold=p.threshold,
                               v_reset=p.v_reset, block=(4, 256))
    vn_c, s_c = lif_step(v, i, p)
    np.testing.assert_allclose(vn_k, vn_c, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_c))


# ---------------------------------------------------------------- c2c_matmul

@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (128, 256, 384, 128, 128, 128),
    (64, 128, 128, 64, 64, 128),
    (256, 512, 256, 128, 256, 128),
])
def test_c2c_matmul_shapes(rng, m, k, n, bm, bk, bn):
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    wq = jnp.asarray(rng.integers(-127, 128, size=(k, n)).astype(np.int8))
    scale = jnp.float32(0.02)
    out = ops.c2c_matmul(x, wq, scale, bm=bm, bk=bk, bn=bn)
    np.testing.assert_allclose(out, c2c_matmul_ref(x, wq, scale),
                               rtol=1e-4, atol=1e-3)


def test_c2c_matmul_equals_ideal_ladder(rng):
    """Kernel == bit-serial C2C ladder evaluation (paper eq. (2))."""
    x = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))
    wq = jnp.asarray(rng.integers(-127, 128, size=(128, 128)).astype(np.int8))
    scale = jnp.float32(0.013)
    out = ops.c2c_matmul(x, wq, scale, bm=64, bk=128, bn=128)
    np.testing.assert_allclose(out, c2c_matmul_ladder_ref(x, wq, scale),
                               rtol=1e-4, atol=1e-3)


def test_c2c_matmul_int8_extremes():
    x = jnp.ones((8, 128), jnp.float32)
    wq = jnp.full((128, 128), -128, jnp.int8)
    out = ops.c2c_matmul(x, wq, jnp.float32(1.0), bm=8)
    np.testing.assert_allclose(out, x @ (wq.astype(jnp.float32)), rtol=1e-5)
