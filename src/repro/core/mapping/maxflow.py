"""Dinic max-flow fast path for the mapping ILP when fan-out is slack.

When constraint (7) does not bind (fanout_m >= |S_m| for all sources — the
common case after pruning, since the paper's fan-out limit models dispatch
bandwidth, not connectivity), the ILP reduces to a max-cardinality capacitated
assignment: neurons (cap 1 each) into engines (cap N each).  That problem's
constraint matrix is totally unimodular, so max-flow gives the certified ILP
optimum in O(E sqrt(V)) instead of branch-and-cut.  (The optimum is trivially
min(N1, M*N) here, but we keep the general flow machinery because the engine
graph becomes non-trivial once per-engine affinity restrictions are added —
see ``allowed``.)
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.mapping.ilp import (MappingError, MappingProblem,
                                    MappingSolution, _expand_engines_to_caps)


class Dinic:
    def __init__(self, n: int):
        self.n = n
        self.head: list[list[int]] = [[] for _ in range(n)]
        self.to: list[int] = []
        self.cap: list[int] = []

    def add_edge(self, u: int, v: int, c: int) -> int:
        eid = len(self.to)
        self.head[u].append(eid)
        self.to.append(v)
        self.cap.append(c)
        self.head[v].append(eid + 1)
        self.to.append(u)
        self.cap.append(0)
        return eid

    def bfs(self, s: int, t: int) -> bool:
        self.level = [-1] * self.n
        self.level[s] = 0
        q = [s]
        while q:
            nq = []
            for u in q:
                for eid in self.head[u]:
                    v = self.to[eid]
                    if self.cap[eid] > 0 and self.level[v] < 0:
                        self.level[v] = self.level[u] + 1
                        nq.append(v)
            q = nq
        return self.level[t] >= 0

    def dfs(self, u: int, t: int, f: int) -> int:
        if u == t:
            return f
        while self.it[u] < len(self.head[u]):
            eid = self.head[u][self.it[u]]
            v = self.to[eid]
            if self.cap[eid] > 0 and self.level[v] == self.level[u] + 1:
                d = self.dfs(v, t, min(f, self.cap[eid]))
                if d > 0:
                    self.cap[eid] -= d
                    self.cap[eid ^ 1] += d
                    return d
            self.it[u] += 1
        return 0

    def max_flow(self, s: int, t: int) -> int:
        flow = 0
        while self.bfs(s, t):
            self.it = [0] * self.n
            while True:
                f = self.dfs(s, t, 1 << 60)
                if f == 0:
                    break
                flow += f
        return flow


def max_flow_assignment(p: MappingProblem,
                        allowed: np.ndarray | None = None) -> MappingSolution:
    """Exact assignment via max-flow.  ``allowed[i, j]`` optionally restricts
    which engines neuron i may use (default: all).  Requires slack fan-out;
    asserts it."""
    p.validate()
    if not (p.fanout >= p.conn.sum(axis=1)).all():
        raise MappingError(
            "max-flow path requires slack fan-out; use the ILP solver")
    n1, m_eng = p.n_dest, p.n_engines
    if allowed is None:
        allowed = np.ones((n1, m_eng), dtype=bool)
    s, t = 0, 1
    neuron0, engine0 = 2, 2 + n1
    g = Dinic(2 + n1 + m_eng)
    edge_of = {}
    for i in range(n1):
        g.add_edge(s, neuron0 + i, 1)
        for j in range(m_eng):
            if allowed[i, j]:
                edge_of[(i, j)] = g.add_edge(neuron0 + i, engine0 + j, 1)
    for j in range(m_eng):
        g.add_edge(engine0 + j, t, p.n_caps)
    g.max_flow(s, t)
    engine = np.full(n1, -1, dtype=np.int64)
    for (i, j), eid in edge_of.items():
        if g.cap[eid] == 0:  # saturated forward edge = assignment
            engine[i] = j
    sol = _expand_engines_to_caps(p, engine)
    return dataclasses.replace(sol, solver="maxflow")
