"""Span tracer + flight recorder: schema locks, replay determinism, and
zero observer effect.

The observability layer's contracts (ISSUE 9 / docs/OBSERVABILITY.md):

  * the span/anomaly/histogram taxonomies are schema-locked tuples, like
    METRIC_KEYS — dashboards parse dumps by these names;
  * every request's life is covered by typed spans in lifecycle order,
    timestamped off the *server's* clock;
  * two VirtualClock replays of the same chaos scenario produce
    byte-identical ``FlightRecorder.dump_json()`` output, and every
    injected fault lands as a typed anomaly;
  * tracing changes nothing it observes: a tracer-on run is bit-exact
    (results and metrics) with a tracer-off run, and adds zero jit traces;
  * the socket ADMIN ``metrics`` / ``trace`` verbs round-trip the
    schema-locked snapshot and the recorder over a live connection.
"""

import json
import math

import numpy as np
import pytest

from repro.engine import (ANOMALY_KINDS, HIST_KEYS, METRIC_KEYS, SCENARIOS,
                          SPAN_KINDS, BucketPolicy, FlightRecorder,
                          Histogram, ServerMetrics, StreamServer,
                          VirtualClock, run_batched, run_scenario,
                          trace_count)
from repro.engine.tracing import RATIO_EDGES, TIME_EDGES
from repro.launch.serve_snn import build_demo_model


@pytest.fixture(scope="module")
def packed():
    return build_demo_model("mlp", smoke=True, seed=0).pack()


def _stream(packed, t=6, seed=0, p=0.2):
    rng = np.random.default_rng(seed)
    return (rng.random((t, packed.n_in)) < p).astype(np.float32)


def _server(packed, recorder, **kw):
    kw.setdefault("policy", BucketPolicy(batch_sizes=(2,), time_steps=(8,)))
    kw.setdefault("clock", VirtualClock())
    kw.setdefault("service_model", lambda b, t: 0.001)
    return StreamServer(packed, tracer=recorder, **kw)


# ------------------------------------------------------------ schema locks

def test_span_and_anomaly_schemas_locked():
    """The taxonomy tuples are a dashboard contract, locked here and in
    docs/OBSERVABILITY.md (tests/test_docs.py)."""
    assert SPAN_KINDS == ("admit", "queue", "schedule", "pad", "dispatch",
                          "slice", "hw", "complete")
    assert ANOMALY_KINDS == ("reject", "shed", "policy_extension",
                             "deadline_miss", "device_loss", "hot_swap_pin",
                             "noise_disagreement")
    assert HIST_KEYS == ("ttfd_s", "service_s", "latency_s", "fill")
    rec = FlightRecorder()
    assert tuple(rec.hist) == HIST_KEYS
    with pytest.raises(AssertionError):
        rec.anomaly("not_a_kind", t=0.0)


# -------------------------------------------------------------- histograms

def test_histogram_percentiles_deterministic():
    h = Histogram(TIME_EDGES)
    assert h.percentile(50) == 0.0 and h.n == 0
    for v in (0.001, 0.002, 0.002, 0.004, 10.0):
        h.add(v)
    assert h.n == 5 and h.mean == pytest.approx(np.mean(
        [0.001, 0.002, 0.002, 0.004, 10.0]))
    # the percentile is the upper edge of the sample's bucket: an upper
    # bound within one bucket width (8 buckets/decade -> ~33%)
    for q, v in ((10, 0.001), (50, 0.002), (90, 10.0)):
        p = h.percentile(q)
        assert v <= p <= v * 10 ** (1 / 8) * (1 + 1e-9), (q, v, p)
    # overflow clamps to the last edge instead of emitting inf
    h2 = Histogram(TIME_EDGES)
    h2.add(1e6)
    assert h2.percentile(99) == TIME_EDGES[-1]
    # identical sample streams -> identical serialized histograms
    a, b = Histogram(RATIO_EDGES), Histogram(RATIO_EDGES)
    for v in (0.1, 0.5, 0.5, 1.0):
        a.add(v)
        b.add(v)
    assert json.dumps(a.to_dict()) == json.dumps(b.to_dict())


def test_server_metrics_percentiles_from_histograms():
    """Satellite: p50/p99 survive beyond the bounded window.  A value seen
    once, long ago, still shapes the lifetime percentile but not the
    recent_* one."""
    m = ServerMetrics()
    m.observe_latency(5.0)                 # the early outlier
    for _ in range(m.latency_s.maxlen):    # ...pushed out of the window
        m.observe_latency(0.001)
    snap = m.snapshot()
    assert 5.0 not in m.latency_s
    assert snap["recent_p99_latency_s"] < 0.01      # window forgot it
    assert snap["p50_latency_s"] < 0.01             # median unaffected
    assert m.latency_hist.n == m.latency_s.maxlen + 1


# ------------------------------------------------------------ span lifecycle

def test_trace_covers_request_lifecycle(packed):
    rec = FlightRecorder()
    srv = _server(packed, rec, with_stats=True)
    rid0 = srv.submit(_stream(packed, seed=1))
    rid1 = srv.submit(_stream(packed, seed=2))   # fills the 2-bucket
    assert len(srv.collect()) == 2
    tr = rec.trace(rid0)
    assert tr is not None and tr.completed and rec.last().rid == rid1
    kinds = [sp.kind for sp in tr.spans]
    # lifecycle order, with per-layer hw sub-spans between slice и complete
    assert kinds[:6] == ["admit", "queue", "schedule", "pad", "dispatch",
                         "slice"]
    assert kinds[-1] == "complete" and "hw" in kinds
    assert all(k in SPAN_KINDS for k in kinds)
    for sp in tr.spans:
        assert sp.t1 >= sp.t0
    dispatch = next(sp for sp in tr.spans if sp.kind == "dispatch")
    # the deterministic union of the telemetry record (seconds excluded)
    for k in ("seq", "b_pad", "t_pad", "n_requests", "events",
              "out_spikes", "model", "generation"):
        assert k in dispatch.attrs, k
    assert "seconds" not in dispatch.attrs
    # with_stats=True: per-layer hardware roll-up + energy attribution
    hw = next(sp for sp in tr.spans if sp.kind == "hw")
    assert hw.attrs["engine_ops"] > 0 and 0 <= hw.attrs["util_mean"] <= 1
    assert dispatch.attrs["energy_j"] > 0
    # histograms saw the dispatch
    assert rec.hist["latency_s"].n == 2 and rec.hist["fill"].n == 1
    # dump is valid sorted-keys json
    d = json.loads(rec.dump_json())
    assert d["n_completed"] == 2 and len(d["completed"]) == 2


def test_schedule_span_says_why(packed):
    """The scheduler's *why*: a full bucket vs a deadline-forced partial
    dispatch are distinguishable from the trace alone."""
    rec = FlightRecorder()
    srv = _server(packed, rec)
    srv.submit(_stream(packed, seed=1))
    srv.submit(_stream(packed, seed=2))          # full bucket
    t0 = srv.now()                               # clock moved past service
    rid = srv.submit(_stream(packed, seed=3), slack=0.05)
    srv.clock.advance(0.06)
    srv.poll()                                    # deadline fires
    full = next(sp for sp in rec.trace(0).spans if sp.kind == "schedule")
    forced = next(sp for sp in rec.trace(rid).spans if sp.kind == "schedule")
    assert full.attrs["why"] == "full_bucket"
    assert forced.attrs["why"] == "deadline"
    assert forced.attrs["group_deadline"] == pytest.approx(t0 + 0.05)


def test_anomalies_reject_shed_miss_extension(packed):
    rec = FlightRecorder()
    srv = _server(packed, rec, queue_capacity=1,
                  backpressure="shed_oldest", overlong="extend",
                  default_slack=0.0005)           # everything misses
    rid0 = srv.submit(_stream(packed, seed=1))
    srv.submit(_stream(packed, t=12, seed=2))     # sheds rid0, extends grid
    srv.flush()
    c = rec.anomaly_counts
    assert c["shed"] == 1 and c["policy_extension"] == 1
    assert c["deadline_miss"] == 1
    # the shed trace is aborted into the anomalous ring, never completed
    tr = rec.trace(rid0)
    assert not tr.completed and tr.anomalies[0]["kind"] == "shed"
    assert any(t.rid == rid0 for t in rec.anomalous)
    # pre-admission rejection -> server-level event (no rid to attach to)
    srv2 = _server(packed, FlightRecorder(), overlong="reject")
    srv2.submit(_stream(packed, t=99, seed=3))
    ev = srv2.tracer.events[-1]
    assert ev["kind"] == "reject" and ev["rid"] is None
    assert srv2.tracer.anomaly_counts["reject"] == 1


# --------------------------------------------------- determinism contracts

@pytest.mark.parametrize("name", ["slo_shed", "analog_noise", "multi_tenant"])
def test_scenario_replays_byte_identical(packed, name):
    """Tentpole acceptance: same scenario, same VirtualClock -> the flight
    recorder dumps are byte-identical, and the injected faults all appear
    as typed anomalies matching the metrics."""
    sc = SCENARIOS[name]
    rec1, rec2 = FlightRecorder(), FlightRecorder()
    _, _, m1 = run_scenario(packed, sc, recorder=rec1)
    _, _, m2 = run_scenario(packed, sc, recorder=rec2)
    assert m1 == m2
    assert rec1.dump_json() == rec2.dump_json()
    c = rec1.anomaly_counts
    assert c.get("deadline_miss", 0) == m1["deadline_misses"]
    assert c.get("shed", 0) == m1["shed"]
    assert c.get("reject", 0) == m1["rejected"]
    assert c.get("hot_swap_pin", 0) == m1["hot_swaps"]
    exp_flips = m1["noise_probes"] - round(m1["noise_agreement"]
                                           * m1["noise_probes"])
    assert c.get("noise_disagreement", 0) == exp_flips


def test_tracer_off_is_bit_exact(packed):
    """Observer effect = zero: tracing must not change a single served bit
    or metric."""
    sc = SCENARIOS["adversarial"]
    res_on, rids_on, m_on = run_scenario(packed, sc,
                                         recorder=FlightRecorder())
    res_off, rids_off, m_off = run_scenario(packed, sc)
    assert m_on == m_off and rids_on == rids_off
    assert set(res_on) == set(res_off)
    for rid in res_off:
        assert np.array_equal(res_on[rid].out_spikes,
                              res_off[rid].out_spikes)


def test_tracing_adds_no_jit_traces(packed):
    """Attaching the recorder's jit probe and spanning every request must
    not perturb the jit cache: a warm bucket stays warm under tracing."""
    warm = _server(packed, None)
    warm.submit(_stream(packed, seed=1))
    warm.flush()                                  # compile the (2, 8) bucket
    rec = FlightRecorder()
    n0 = trace_count()
    srv = _server(packed, rec)
    srv.submit(_stream(packed, seed=2))
    srv.submit(_stream(packed, seed=3))
    srv.collect()
    assert trace_count() == n0, "tracing must not retrace warm buckets"
    assert len(rec.jit_events) == 0
    rec.detach_jit_probe()


def test_jit_probe_sees_compiles(packed):
    """A cold shape compiled with the probe attached lands in jit_events
    (and jit_events stay OUT of the deterministic dump)."""
    rec = FlightRecorder().attach_jit_probe()
    try:
        # a (B=3, T=29) batch no other test compiles -> guaranteed retrace
        spikes = np.stack([_stream(packed, t=29, seed=9 + i)
                           for i in range(3)])
        run_batched(packed, spikes)
        assert any(e["kind"] == "batched" for e in rec.jit_events)
        assert "jit_events" not in rec.dump()
    finally:
        rec.detach_jit_probe()


# --------------------------------------------------------- wire round-trip

def test_socket_admin_metrics_and_trace(packed):
    """ADMIN `metrics` returns the schema-locked snapshot and `trace
    <rid>|last` returns span traces over a live socket."""
    from repro.launch.socket_serve import (SpikeClient, SpikeSocketServer,
                                           serving_thread)
    srv = SpikeSocketServer(
        packed, policy=BucketPolicy(batch_sizes=(2,), time_steps=(8,)))
    host, port = srv.address
    with serving_thread(srv, idle_flush_s=0.05):
        cli = SpikeClient(host, port)
        for seed in range(4):
            cli.send(_stream(packed, seed=seed))
        cli.recv_all()                  # all results in -> traces completed
        assert len(cli.results) == 4
        met = cli.admin({"op": "metrics"})
        last = cli.admin({"op": "trace", "last": True})
        dump = cli.admin({"op": "trace"})
        bad = cli.admin({"op": "trace", "rid": 10 ** 9})
        cli.recv_all()
        cli.close()
    mrep = cli.admin_replies[met]
    # json sorts keys on the wire: same key *set*, values by name
    assert mrep["ok"] and set(mrep["metrics"]) == set(METRIC_KEYS)
    assert mrep["metrics"]["completed"] == 4
    trep = cli.admin_replies[last]
    assert trep["ok"] and trep["trace"]["completed"]
    kinds = [sp["kind"] for sp in trep["trace"]["spans"]]
    assert "dispatch" in kinds and kinds[0] == "admit"
    drep = cli.admin_replies[dump]
    assert drep["ok"] and drep["dump"]["n_completed"] == 4
    assert not cli.admin_replies[bad]["ok"]
    assert "no trace for rid" in cli.admin_replies[bad]["error"]


# ----------------------------------------------------------- recorder edges

def test_recorder_rings_bounded_and_late_anomalies():
    rec = FlightRecorder(keep_completed=2, keep_anomalous=4)
    for rid in range(5):
        rec.start(rid, model="m", generation=1, t=0.0)
        rec.complete(rid, 1.0)
    assert [t.rid for t in rec.completed] == [3, 4]   # ring keeps last 2
    assert rec.n_started == rec.n_completed == 5
    # a late anomaly (noise probe after completion) promotes the trace
    # into the anomalous ring exactly once
    rec.anomaly("noise_disagreement", t=2.0, rid=4)
    rec.anomaly("noise_disagreement", t=2.5, rid=4)
    assert [t.rid for t in rec.anomalous] == [4]
    assert len(rec.trace(4).anomalies) == 2
    # unknown rids are no-ops, not crashes, and land as server events
    rec.span(999, "queue", 0.0, 1.0)
    rec.complete(999, 1.0)
    rec.anomaly("deadline_miss", t=3.0, rid=999)
    assert rec.events[-1]["rid"] == 999
    assert math.isfinite(json.loads(rec.dump_json())["anomaly_counts"]
                         ["noise_disagreement"])
