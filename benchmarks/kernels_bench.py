"""Pallas kernel microbenchmarks.

CPU-interpret timings are NOT TPU performance — the derived column reports
the structural quantities that matter on the target (bytes moved per call,
arithmetic intensity, event-sparsity speedup factor)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _timeit(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_batched_engine(batch: int = 32, t_steps: int = 20,
                         density: float = 0.2) -> float:
    """Batched jit engine vs. the looped cycle-accurate oracle on the same
    mapped model.  Returns the wall-clock speedup at the given batch size
    (CI asserts >= 5x at batch 32)."""
    from repro.core.accelerator import map_model, run
    from repro.core.energy import AcceleratorSpec
    from repro.core.lif import LIFParams
    from repro.engine import run_batched

    rng = np.random.default_rng(0)
    sizes = (128, 96, 64)
    ws = []
    for i in range(len(sizes) - 1):
        w = rng.normal(0, 0.4, (sizes[i], sizes[i + 1])).astype(np.float32)
        w[np.abs(w) < np.quantile(np.abs(w), 0.6)] = 0
        ws.append(w)
    spec = AcceleratorSpec("bench", n_cores=2, n_engines=8, n_caps=16,
                           weight_mem_bytes=1 << 20)
    model = map_model(ws, spec, lif=LIFParams(beta=0.85, threshold=0.6))
    spikes = (rng.random((batch, t_steps, sizes[0])) < density) \
        .astype(np.float32)

    packed = model.pack()
    res_b = run_batched(packed, spikes)          # compile
    t0 = time.perf_counter()
    res_b = run_batched(packed, spikes)
    t_batched = time.perf_counter() - t0

    t0 = time.perf_counter()
    oracle_out = [run(model, spikes[b]).out_spikes for b in range(batch)]
    t_loop = time.perf_counter() - t0

    assert all(np.array_equal(res_b.out_spikes[b], oracle_out[b])
               for b in range(batch)), "batched engine != oracle"
    speedup = t_loop / max(t_batched, 1e-9)
    print(f"engine/run_batched_b{batch},{t_batched*1e6:.0f},"
          f"speedup_vs_loop={speedup:.1f}x")
    assert speedup >= 5.0 or batch < 32, \
        f"batched engine speedup regressed: {speedup:.1f}x < 5x at batch {batch}"
    return speedup


def main():
    rng = np.random.default_rng(0)
    # event_synapse: sparsity-proportional work
    n_src, n_dest = 1024, 1024
    w = jnp.asarray(rng.normal(size=(n_src, n_dest)).astype(np.float32))
    for density in (0.05, 0.25):
        spikes = jnp.asarray((rng.random((4, n_src)) < density)
                             .astype(np.float32))
        max_ev = max(int(density * n_src * 2), 16)
        ev = ops.events_from_spikes(spikes, max_ev)
        us = _timeit(ops.event_synapse, ev, w)
        # derived: fraction of dense bytes touched (events/n_src)
        frac = float((np.asarray(ev) >= 0).mean() * max_ev / n_src)
        print(f"kernel/event_synapse_d{density},{us:.0f},"
              f"dense_byte_frac={max_ev/n_src:.3f}")
    # lif_update: fused vs unfused byte traffic
    v = jnp.asarray(rng.normal(size=(64, 4096)).astype(np.float32))
    i = jnp.asarray(rng.normal(size=(64, 4096)).astype(np.float32))
    us = _timeit(lambda a, b: ops.lif_update(a, b)[0], v, i)
    print(f"kernel/lif_update,{us:.0f},fused_hbm_bytes={4*v.size*4}")
    # c2c_matmul: int8 weights halve weight traffic vs bf16
    x = jnp.asarray(rng.normal(size=(256, 1024)).astype(np.float32))
    wq = jnp.asarray(rng.integers(-127, 128, (1024, 1024)).astype(np.int8))
    us = _timeit(ops.c2c_matmul, x, wq, jnp.float32(0.01))
    ai = 2 * 256 * 1024 * 1024 / (x.nbytes + wq.nbytes + 256 * 1024 * 4)
    print(f"kernel/c2c_matmul,{us:.0f},arith_intensity={ai:.0f}")
    # batched accelerator engine vs looped oracle
    bench_batched_engine(batch=32)


if __name__ == "__main__":
    main()
