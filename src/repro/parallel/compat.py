"""Version compatibility shims for the `jax` public API.

`shard_map` graduated from `jax.experimental.shard_map` to the top-level
`jax.shard_map` in jax 0.5; this repo pins `jax[cpu]==0.4.37` in CI but must
keep working when the container ships a newer jax.  Import it from here
everywhere instead of hard-coding either location.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:  # jax < 0.5
    from jax.experimental.shard_map import shard_map  # noqa: F401


def pcast_varying(x, axis_names: tuple[str, ...]):
    """`jax.lax.pcast(x, axes, to="varying")` on jax versions that type
    manual-axis values as replicated/varying; identity on older jax (0.4.x),
    where every value inside shard_map is already device-varying."""
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is None:
        return x
    return pcast(x, axis_names, to="varying")


def compiled_cost_analysis(compiled) -> dict:
    """`Compiled.cost_analysis()` returns a dict on jax >= 0.5 but a
    one-element list of dicts on 0.4.x."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


__all__ = ["shard_map", "pcast_varying", "compiled_cost_analysis"]
