"""run_sharded == run_batched, bit for bit, on host meshes.

The contract (engine/sharded_run.py): sharding the batch axis over a data
mesh — control memories replicated, spikes split — must not change a single
bit of the result surface: output spikes, every DispatchStats field,
utilization, overflow, and per-sample EnergyReport.  Since ``run_batched``
is itself proven equal to the numpy oracle, this extends the PR 2
equivalence contract to the mesh.

In-process tests run on whatever devices exist (a 1-device mesh still goes
through the full shard_map path); subprocess tests spoof a multi-device CPU
host, covering the >=2-device acceptance criterion for dense and conv
models.  CI additionally re-runs this module under a spoofed 8-device host.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from _equivalence import (assert_engine_results_equal,
                          assert_oracle_engine_equivalent)
from _hypothesis_compat import given, settings, st
from test_equivalence_prop import build_case, dense_cases, conv_cases

from repro.engine import batched_run as br
from repro.engine import run_sharded
from repro.engine.sharded_run import (batch_spec, n_batch_shards,
                                      snn_serve_mesh)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    pre = (f'import os; os.environ["XLA_FLAGS"] = '
           f'"--xla_force_host_platform_device_count={devices}"\n')
    p = subprocess.run([sys.executable, "-c", pre + script],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=600)
    assert p.returncode == 0, (p.stdout[-2000:], p.stderr[-4000:])
    return p.stdout


# ------------------------------------------------- in-process (any devices)

DENSE_CASE = {"seed": 3, "in_shape": [14, 1, 1],
              "layers": [{"kind": "dense", "n_out": 12, "density": 0.6},
                         {"kind": "dense", "n_out": 6, "density": 0.8}],
              "batch": 4, "t": 6, "p_spike": 0.35, "max_events": None,
              "n_engines": 3, "n_caps": 5, "beta": 0.8, "threshold": 0.7}

CONV_CASE = {"seed": 5, "in_shape": [2, 6, 6],
             "layers": [{"kind": "conv", "c_out": 2, "k": 3, "stride": 1,
                         "padding": 1, "density": 0.6},
                        {"kind": "pool", "pool": 2},
                        {"kind": "dense", "n_out": 5, "density": 0.7}],
             "batch": 4, "t": 5, "p_spike": 0.25, "max_events": None,
             "n_engines": 3, "n_caps": 6, "beta": 0.8, "threshold": 0.7}


@pytest.mark.parametrize("case,cap", [
    (DENSE_CASE, None), (DENSE_CASE, 3), (CONV_CASE, None), (CONV_CASE, 4)])
def test_sharded_matches_batched(case, cap):
    model, spikes = build_case(case)
    mesh = snn_serve_mesh()
    a = run_sharded(model, spikes, mesh=mesh, max_events=cap)
    b = br.run_batched(model, spikes, max_events=cap)
    assert_engine_results_equal(a, b, tag=f"cap={cap}")


def test_sharded_matches_oracle_transitively():
    """The chain closes: sharded == batched == numpy oracle."""
    model, spikes = build_case(DENSE_CASE)
    assert_oracle_engine_equivalent(model, spikes)
    assert_engine_results_equal(run_sharded(model, spikes),
                                br.run_batched(model, spikes))


def test_sharded_empty_batch():
    model, spikes = build_case(DENSE_CASE)
    res = run_sharded(model, spikes[:0])
    assert res.out_spikes.shape == (0, spikes.shape[1], model.layers[-1].n_dest)
    assert all(s.cycles.shape[0] == 0 for s in res.per_layer_stats)


def test_batch_spec_rules():
    """The SNN serving rules shard only the batch axis, and drop the mapping
    (replicate) when the batch is not divisible by the mesh."""
    mesh = snn_serve_mesh()
    n = mesh.shape["data"]
    spec = batch_spec(mesh, (4 * n, 7, 13))
    assert spec[1] is None and spec[2] is None
    assert n_batch_shards(mesh, 4 * n) == n
    if n > 1:
        assert spec[0] == "data"
        assert n_batch_shards(mesh, 4 * n + 1) == 1   # graceful degradation
    else:
        assert n_batch_shards(mesh, 5) == 1


def test_sharded_trace_count_shared_probe():
    """run_sharded bumps the same trace_count() probe as run_batched, and a
    repeated shape does not retrace."""
    model, spikes = build_case(DENSE_CASE)
    mesh = snn_serve_mesh()
    run_sharded(model, spikes, mesh=mesh)
    n = br.trace_count()
    run_sharded(model, spikes, mesh=mesh)
    assert br.trace_count() == n


@settings(max_examples=25, deadline=None)
@given(case=dense_cases())
def test_prop_sharded_dense(case):
    """Property: run_sharded on a 1xN host mesh == run_batched (spikes,
    DispatchStats, EnergyReport) for random dense stacks."""
    model, spikes = build_case(case)
    a = run_sharded(model, spikes, mesh=snn_serve_mesh(),
                    max_events=case.get("max_events"))
    b = br.run_batched(model, spikes, max_events=case.get("max_events"))
    assert_engine_results_equal(a, b)


@settings(max_examples=15, deadline=None)
@given(case=conv_cases())
def test_prop_sharded_conv(case):
    """Property: sharded == batched for random conv/pool/dense stacks."""
    model, spikes = build_case(case)
    a = run_sharded(model, spikes, mesh=snn_serve_mesh(),
                    max_events=case.get("max_events"))
    b = br.run_batched(model, spikes, max_events=case.get("max_events"))
    assert_engine_results_equal(a, b)


# ------------------------------------------- spoofed multi-device acceptance

def test_sharded_8dev_bit_exact():
    """Dense + conv + capped models on a spoofed 8-device host mesh: the
    batch really splits 8 ways and every surface stays bit-exact."""
    out = _run("""
import numpy as np
import sys
sys.path.insert(0, "tests")
from _equivalence import assert_engine_results_equal
from test_equivalence_prop import build_case
from test_sharded_engine import DENSE_CASE, CONV_CASE
from repro.engine import batched_run as br
from repro.engine import run_sharded
from repro.engine.sharded_run import n_batch_shards, snn_serve_mesh

mesh = snn_serve_mesh()
assert mesh.size == 8, mesh
for case, cap in [(DENSE_CASE, None), (DENSE_CASE, 2),
                  (CONV_CASE, None), (CONV_CASE, 3)]:
    case = dict(case, batch=8)
    model, spikes = build_case(case)
    assert n_batch_shards(mesh, spikes.shape[0]) == 8
    a = run_sharded(model, spikes, mesh=mesh, max_events=cap)
    b = br.run_batched(model, spikes, max_events=cap)
    assert_engine_results_equal(a, b, tag=f"8dev cap={cap}")
print("OK")
""")
    assert "OK" in out


def test_sharded_8dev_nondivisible_graceful():
    """B=6 on an 8-device mesh can't split: the rule machinery degrades to
    replicated execution and the result is still bit-exact."""
    out = _run("""
import numpy as np
import sys
sys.path.insert(0, "tests")
from _equivalence import assert_engine_results_equal
from test_equivalence_prop import build_case
from test_sharded_engine import DENSE_CASE
from repro.engine import batched_run as br
from repro.engine import run_sharded
from repro.engine.sharded_run import n_batch_shards, snn_serve_mesh

mesh = snn_serve_mesh()
case = dict(DENSE_CASE, batch=6)
model, spikes = build_case(case)
assert n_batch_shards(mesh, 6) == 1
assert_engine_results_equal(run_sharded(model, spikes, mesh=mesh),
                            br.run_batched(model, spikes))
print("OK")
""")
    assert "OK" in out
