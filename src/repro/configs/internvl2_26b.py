"""InternVL2-26B backbone (InternViT-6B frontend STUBBED per assignment;
backbone = InternLM2-20B-chat) [arXiv:2404.16821; hf]."""

import dataclasses

from repro.configs.common import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92553, head_dim=128,
    n_image_embeds=256,            # ViT patch embeds injected as a prefix
    rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, n_image_embeds=4)
