"""End-to-end serving benchmark for the bucketed sharded engine path.

Drives variable-length event-stream traffic (MLP and conv models) through
``run_bucketed`` -> ``run_sharded`` on the host mesh and writes
``BENCH_serving.json``: events/s, spikes/s, p50/p99 per-bucket step latency,
and the jit-trace count — the serving perf trajectory CI records per PR.

  PYTHONPATH=src python benchmarks/serving_bench.py [--smoke] \
      [--out BENCH_serving.json] [--spoof-devices 2]

Gates (CI fails loudly on regression):
  * the hot pass must not retrace (jit cache stable across mixed shapes);
  * total traces per model stay <= the policy's bucket count;
  * a spot request is bit-exact vs single-device ``run_batched``.
"""

from __future__ import annotations

import argparse
import json

from repro.launch._spoof import (assert_spoof_applied,
                                 spoof_devices_from_argv)

_SPOOFED = spoof_devices_from_argv()  # before any jax import in this process

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.engine import BucketPolicy, run_batched, trace_count  # noqa: E402
from repro.engine.sharded_run import snn_serve_mesh  # noqa: E402
from repro.launch.serve_snn import (build_demo_model, serve_stream,  # noqa: E402
                                    synth_requests)


def bench_model(kind: str, *, smoke: bool, mesh, seed: int = 0) -> dict:
    model = build_demo_model(kind, smoke=smoke, seed=seed)
    packed = model.pack()
    n_req = 24 if smoke else 96
    streams = synth_requests(n_req, packed.n_in,
                             t_hi=12 if smoke else 30, seed=seed + 1)
    policy = BucketPolicy.covering([s.shape[0] for s in streams],
                                   n_shards=mesh.size,
                                   max_batch=4 * mesh.size)
    n0 = trace_count()
    _, warm = serve_stream(packed, streams, policy=policy, mesh=mesh)
    results, hot = serve_stream(packed, streams, policy=policy, mesh=mesh)
    traces_total = trace_count() - n0
    assert hot["new_traces"] == 0, \
        f"{kind}: hot serving pass retraced ({hot['new_traces']} traces)"
    assert traces_total <= policy.n_buckets, \
        f"{kind}: {traces_total} traces > {policy.n_buckets} buckets"
    # bit-exactness spot check: the longest request, served alone
    i = int(np.argmax([s.shape[0] for s in streams]))
    alone = run_batched(packed, streams[i][None], with_stats=False)
    assert np.array_equal(results[i].out_spikes, alone.out_spikes[0]), \
        f"{kind}: bucketed serving != run_batched on request {i}"
    row = {"model": kind, "n_shards": mesh.size,
           "requests": hot["requests"], "engine_steps": hot["engine_steps"],
           "events_per_s": hot["events_per_s"],
           "spikes_per_s": hot["spikes_per_s"],
           "p50_step_ms": hot["p50_step_ms"],
           "p99_step_ms": hot["p99_step_ms"],
           "traces": traces_total, "n_buckets": policy.n_buckets,
           "warm_wall_s": warm["wall_s"], "hot_wall_s": hot["wall_s"]}
    print(f"serving/{kind},events_per_s={row['events_per_s']:.0f},"
          f"spikes_per_s={row['spikes_per_s']:.0f},"
          f"p50_ms={row['p50_step_ms']:.2f},p99_ms={row['p99_step_ms']:.2f},"
          f"traces={traces_total}/{policy.n_buckets},shards={mesh.size}")
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--data", type=int, default=None)
    ap.add_argument("--spoof-devices", type=int, default=None)
    args = ap.parse_args()
    assert_spoof_applied(_SPOOFED)
    mesh = snn_serve_mesh(args.data)
    rows = [bench_model(kind, smoke=args.smoke, mesh=mesh)
            for kind in ("mlp", "conv")]
    blob = {"bench": "serving", "smoke": args.smoke,
            "backend": jax.default_backend(),
            "n_devices": len(jax.devices()), "models": rows}
    with open(args.out, "w") as f:
        json.dump(blob, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
