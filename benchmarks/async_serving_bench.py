"""Latency-vs-throughput benchmark for the always-on async serving loop.

Replays Poisson and bursty arrival traces at a sweep of offered loads
through :class:`repro.engine.stream_server.StreamServer` (virtual clock,
service times calibrated from measured engine calls per bucket shape) and
writes ``BENCH_async_serving.json``: throughput vs offered load, p50/p99
end-to-end latency, deadline-miss rate, and bucket fill ratio — the async
half of the serving perf trajectory CI records per PR, next to
``BENCH_serving.json``.

  PYTHONPATH=src python benchmarks/async_serving_bench.py [--smoke] \
      [--out BENCH_async_serving.json] [--spoof-devices 2]

Gates (CI fails loudly on regression):
  * calibration warms every bucket; the serving passes must then run with
    ZERO new jit traces (the hot-pass retrace gate);
  * total traces stay <= the policy's bucket count;
  * a spot request served through the async loop is bit-exact vs
    single-device ``run_batched``.
"""

from __future__ import annotations

import argparse
import json
import time

from repro.launch._spoof import (assert_spoof_applied,
                                 spoof_devices_from_argv)

_SPOOFED = spoof_devices_from_argv()  # before any jax import in this process

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.engine import (BucketPolicy, run_batched, run_sharded,  # noqa: E402
                          trace_count)
from repro.engine.sharded_run import snn_serve_mesh  # noqa: E402
from repro.launch.serve_snn import (build_demo_model, serve_async,  # noqa: E402
                                    synth_arrival_trace)


def calibrate_service(packed, policy: BucketPolicy, mesh) -> dict:
    """Measure one engine call per bucket shape (warm first, then timed).
    Doubles as the warm-up that makes the serving passes retrace-free, and
    grounds the virtual-clock simulation in real measured seconds."""
    timings = {}
    for b in policy.batch_sizes:
        for t in policy.time_steps:
            zeros = np.zeros((b, t, packed.n_in), dtype=np.float32)
            for _ in range(2):     # first call compiles; second measures
                t0 = time.perf_counter()
                if mesh is None:
                    run_batched(packed, zeros, with_stats=False)
                else:
                    run_sharded(packed, zeros, mesh=mesh, with_stats=False)
                dt = time.perf_counter() - t0
            timings[(b, t)] = dt
    return timings


def bench_model(kind: str, *, smoke: bool, mesh, seed: int = 0) -> dict:
    model = build_demo_model(kind, smoke=smoke, seed=seed)
    packed = model.pack()
    n_req = 24 if smoke else 96
    t_hi = 12 if smoke else 30
    base_rate = 100.0
    probe = synth_arrival_trace(n_req, packed.n_in, t_hi=t_hi,
                                rate=base_rate, seed=seed + 1)
    policy = BucketPolicy.covering([s.shape[0] for _, s, _ in probe],
                                   n_shards=mesh.size,
                                   max_batch=4 * mesh.size)
    n_cold = trace_count()
    timings = calibrate_service(packed, policy, mesh)
    max_service = max(timings.values())
    service_model = lambda b, t: timings[(b, t)]  # noqa: E731
    n0 = trace_count()
    sweep = []
    loads = (0.5, 2.0) if smoke else (0.25, 1.0, 4.0)
    for mode in ("poisson", "bursty"):
        for load in loads:
            rate = base_rate * load
            # deadline slack scales with the slowest bucket call so the
            # low-load points are comfortably servable; high load is where
            # the latency/miss tradeoff shows up in the curve
            slack = 8.0 * max_service
            trace = synth_arrival_trace(n_req, packed.n_in, mode=mode,
                                        rate=rate, slack=slack, t_hi=t_hi,
                                        seed=seed + 1)
            _, rids, m = serve_async(packed, trace, policy=policy, mesh=mesh,
                                     service_model=service_model)
            sweep.append({
                "mode": mode, "offered_rps": m["offered_rps"],
                "throughput_rps": m["throughput_rps"],
                "completed": m["completed"], "requests": m["requests"],
                "p50_latency_ms": m["p50_latency_s"] * 1e3,
                "p99_latency_ms": m["p99_latency_s"] * 1e3,
                "p50_ttfd_ms": m["p50_ttfd_s"] * 1e3,
                "deadline_miss_rate": m["deadline_miss_rate"],
                "bucket_fill_ratio": m["bucket_fill_ratio"],
                "forced_dispatches": m["forced_dispatches"],
                "dispatches": m["dispatches"],
                "max_queue_depth": m["max_queue_depth"],
                "rejected": m["rejected"], "shed": m["shed"]})
            print(f"async/{kind}/{mode}@{rate:.0f}rps: served "
                  f"{m['throughput_rps']:.0f} rps, p50 "
                  f"{m['p50_latency_s']*1e3:.1f} ms, p99 "
                  f"{m['p99_latency_s']*1e3:.1f} ms, miss "
                  f"{m['deadline_miss_rate']:.3f}, fill "
                  f"{m['bucket_fill_ratio']:.2f}, forced "
                  f"{m['forced_dispatches']}/{m['dispatches']}")
    hot_traces = trace_count() - n0
    assert hot_traces == 0, \
        f"{kind}: async serving retraced {hot_traces}x after calibration " \
        f"warmed every bucket — the jit cache is churning"
    # total including calibration: one trace per bucket shape, nothing more
    # (checked before the spot check below adds its off-grid [1, T] shape)
    traces_total = trace_count() - n_cold
    assert traces_total <= policy.n_buckets, \
        f"{kind}: {traces_total} traces > {policy.n_buckets} buckets"
    # bit-exactness spot check: the longest request in the last trace,
    # served alone on the single-device engine
    results, rids, _ = serve_async(packed, trace, policy=policy, mesh=mesh,
                                   service_model=service_model)
    i = int(np.argmax([s.shape[0] for _, s, _ in trace]))
    assert rids[i] is not None and rids[i] in results
    alone = run_batched(packed, trace[i][1][None], with_stats=False)
    assert np.array_equal(results[rids[i]].out_spikes, alone.out_spikes[0]), \
        f"{kind}: async serving != run_batched on request {i}"
    return {"model": kind, "n_shards": mesh.size,
            "calibration_ms": {f"{b}x{t}": dt * 1e3
                               for (b, t), dt in timings.items()},
            "n_buckets": policy.n_buckets, "traces_hot": hot_traces,
            "traces_total": traces_total, "sweep": sweep}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_async_serving.json")
    ap.add_argument("--data", type=int, default=None)
    ap.add_argument("--spoof-devices", type=int, default=None)
    args = ap.parse_args()
    assert_spoof_applied(_SPOOFED)
    mesh = snn_serve_mesh(args.data)
    rows = [bench_model(kind, smoke=args.smoke, mesh=mesh)
            for kind in ("mlp", "conv")]
    blob = {"bench": "async_serving", "smoke": args.smoke,
            "backend": jax.default_backend(),
            "n_devices": len(jax.devices()), "models": rows}
    with open(args.out, "w") as f:
        json.dump(blob, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
