"""Pallas TPU kernel: fused LIF membrane update (the A-NEURON clock edge).

Fuses integrate (beta*V + I), fire (compare), and reset (select) into one
VMEM-resident elementwise pass — one HBM read of (V, I) and one write of
(V', S) instead of the 4 reads + 2 writes of the unfused op sequence.
Tiling: flat 2-D blocks aligned to the VPU lane width (128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = (8, 512)


def _lif_update_kernel(v_ref, i_ref, vout_ref, s_ref, *, beta, threshold, v_reset):
    v = v_ref[...]
    cur = i_ref[...]
    v_int = beta * v + cur
    spikes = (v_int >= threshold).astype(v.dtype)
    vout_ref[...] = jnp.where(spikes > 0, jnp.asarray(v_reset, v.dtype), v_int)
    s_ref[...] = spikes


@functools.partial(jax.jit,
                   static_argnames=("beta", "threshold", "v_reset", "block", "interpret"))
def lif_update(v: jax.Array, current: jax.Array, *, beta: float = 0.9,
               threshold: float = 1.0, v_reset: float = 0.0,
               block: tuple[int, int] = DEFAULT_BLOCK,
               interpret: bool = False):
    """v, current: [B, N] (same shape) -> (v_next, spikes)."""
    assert v.shape == current.shape and v.ndim == 2
    b, n = v.shape
    bb, bn = min(block[0], b), min(block[1], n)
    assert b % bb == 0 and n % bn == 0, f"shape {(b, n)} not tileable by {(bb, bn)}"
    grid = (b // bb, n // bn)
    kern = functools.partial(_lif_update_kernel, beta=beta,
                             threshold=threshold, v_reset=v_reset)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((bb, bn), lambda i, j: (i, j))] * 2,
        out_specs=[pl.BlockSpec((bb, bn), lambda i, j: (i, j))] * 2,
        out_shape=[jax.ShapeDtypeStruct((b, n), v.dtype)] * 2,
        interpret=interpret,
    )(v, current)
