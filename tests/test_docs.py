"""The operator docs are locked to the code they describe.

docs/SERVING.md carries the metrics reference every dashboard reads; if
``METRIC_KEYS`` / ``TELEMETRY_KEYS`` / the chaos-scenario registry change
without the tables changing (or vice versa), this module fails — the doc
IS part of the schema lock.  The markdown link checker runs here too, so a
renamed doc or heading breaks tier 1, not a reader.
"""

import inspect
import os
import re
import subprocess
import sys

from repro.core.precision import PARETO_POINT_KEYS, search_bits
from repro.core.quant import SUPPORTED_BITS
from repro.engine import (ANOMALY_KINDS, HIST_KEYS, METRIC_KEYS,
                          PER_MODEL_KEYS, SCENARIOS, SPAN_KINDS,
                          TELEMETRY_KEYS)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SERVING_MD = os.path.join(REPO, "docs", "SERVING.md")
OBSERVABILITY_MD = os.path.join(REPO, "docs", "OBSERVABILITY.md")
PRECISION_MD = os.path.join(REPO, "docs", "PRECISION.md")


def _table_keys(text: str, section: str) -> tuple[str, ...]:
    """Backtick-quoted first-column entries of the first table after the
    given heading (skipping the header and separator rows)."""
    start = text.index(section)
    end = text.find("\n#", start + len(section))
    block = text[start:end if end != -1 else len(text)]
    keys = []
    for line in block.splitlines():
        m = re.match(r"\|\s*`([^`]+)`\s*\|", line)
        if m:
            keys.append(m.group(1))
    return tuple(keys)


def _serving_md() -> str:
    with open(SERVING_MD, encoding="utf-8") as f:
        return f.read()


def test_metric_keys_table_matches_code():
    """Every ServerMetrics.snapshot() key is documented, in order — the
    docs half of test_serving.py's schema lock."""
    doc = _table_keys(_serving_md(), "### `ServerMetrics.snapshot()` keys")
    assert doc == METRIC_KEYS, (
        f"docs/SERVING.md metrics table is out of sync with METRIC_KEYS\n"
        f"  documented: {doc}\n  code:       {METRIC_KEYS}")


def test_per_model_keys_table_matches_code():
    """The per-tenant metrics table is schema-locked like the fabric-wide
    one — the multi-tenant dashboard surface."""
    doc = _table_keys(_serving_md(), "### Per-model snapshot keys")
    assert doc == PER_MODEL_KEYS, (
        f"docs/SERVING.md per-model table is out of sync with "
        f"PER_MODEL_KEYS\n  documented: {doc}\n  code:       "
        f"{PER_MODEL_KEYS}")


def test_telemetry_keys_table_matches_code():
    doc = _table_keys(_serving_md(), "### Per-dispatch telemetry keys")
    assert doc == TELEMETRY_KEYS, (
        f"docs/SERVING.md telemetry table is out of sync with "
        f"TELEMETRY_KEYS\n  documented: {doc}\n  code: {TELEMETRY_KEYS}")


def test_scenario_table_matches_registry():
    doc = _table_keys(_serving_md(), "## Chaos scenarios")
    assert doc == tuple(SCENARIOS), (
        f"docs/SERVING.md scenario table is out of sync with "
        f"chaos.SCENARIOS\n  documented: {doc}\n"
        f"  code:       {tuple(SCENARIOS)}")


def _observability_md() -> str:
    with open(OBSERVABILITY_MD, encoding="utf-8") as f:
        return f.read()


def test_span_table_matches_code():
    """docs/OBSERVABILITY.md documents every span kind, in lifecycle
    order — the trace-consumer half of test_tracing.py's schema lock."""
    doc = _table_keys(_observability_md(), "## Span taxonomy")
    assert doc == SPAN_KINDS, (
        f"docs/OBSERVABILITY.md span table is out of sync with "
        f"SPAN_KINDS\n  documented: {doc}\n  code:       {SPAN_KINDS}")


def test_anomaly_table_matches_code():
    doc = _table_keys(_observability_md(),
                      "## Anomalies and the flight recorder")
    assert doc == ANOMALY_KINDS, (
        f"docs/OBSERVABILITY.md anomaly table is out of sync with "
        f"ANOMALY_KINDS\n  documented: {doc}\n  code:       "
        f"{ANOMALY_KINDS}")


def test_histogram_table_matches_code():
    doc = _table_keys(_observability_md(), "## Histograms")
    assert doc == HIST_KEYS, (
        f"docs/OBSERVABILITY.md histogram table is out of sync with "
        f"HIST_KEYS\n  documented: {doc}\n  code:       {HIST_KEYS}")


def _precision_md() -> str:
    with open(PRECISION_MD, encoding="utf-8") as f:
        return f.read()


def test_supported_bits_table_matches_code():
    """docs/PRECISION.md documents exactly the widths the quantizer and the
    packed kernel accept, ascending — the operator half of SUPPORTED_BITS."""
    doc = _table_keys(_precision_md(), "## Supported bit-widths")
    want = tuple(str(b) for b in sorted(SUPPORTED_BITS))
    assert doc == want, (
        f"docs/PRECISION.md bit-width table is out of sync with "
        f"SUPPORTED_BITS\n  documented: {doc}\n  code:       {want}")


def test_search_knob_table_matches_signature():
    """Every keyword-only knob of search_bits is documented, in signature
    order — renaming a knob without the doc (or vice versa) fails here."""
    doc = _table_keys(_precision_md(), "### Search knobs")
    sig = inspect.signature(search_bits)
    want = tuple(name for name, p in sig.parameters.items()
                 if p.kind is inspect.Parameter.KEYWORD_ONLY)
    assert doc == want, (
        f"docs/PRECISION.md search-knob table is out of sync with "
        f"search_bits' signature\n  documented: {doc}\n  code:       {want}")


def test_pareto_schema_table_matches_code():
    """The BENCH_precision.json point schema is documented key for key —
    the dashboard-consumer half of PARETO_POINT_KEYS."""
    doc = _table_keys(_precision_md(), "## Pareto artifact schema")
    assert doc == PARETO_POINT_KEYS, (
        f"docs/PRECISION.md Pareto table is out of sync with "
        f"PARETO_POINT_KEYS\n  documented: {doc}\n"
        f"  code:       {PARETO_POINT_KEYS}")


def test_markdown_links_resolve():
    """tools/check_links.py over README.md + docs/ — the same invocation
    the CI docs job runs."""
    p = subprocess.run(
        [sys.executable, os.path.join("tools", "check_links.py"),
         "README.md", "docs"],
        capture_output=True, text=True, cwd=REPO, timeout=60)
    assert p.returncode == 0, f"broken links:\n{p.stderr}\n{p.stdout}"
