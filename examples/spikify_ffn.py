"""MENAGE-style event-driven execution of a transformer FFN block.

Demonstrates DESIGN.md §Arch-applicability: the paper's "work ∝ spikes"
proposition applied to a conventional layer — the ReLU activations of an
FFN are rate-encoded and pushed through the event_synapse Pallas kernel, so
weight-traffic scales with activation sparsity instead of the dense n_in.

  PYTHONPATH=src python examples/spikify_ffn.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spikify import spikified_linear


def main():
    rng = np.random.default_rng(0)
    d_model, d_ff, batch = 256, 1024, 8
    w_in = jnp.asarray(rng.normal(size=(d_model, d_ff)).astype(np.float32)
                       / np.sqrt(d_model))
    w_out = jnp.asarray(rng.normal(size=(d_ff, d_model)).astype(np.float32)
                        / np.sqrt(d_ff))
    x = jnp.asarray(rng.normal(size=(batch, d_model)).astype(np.float32))

    # dense reference FFN
    h = jax.nn.relu(x @ w_in)
    y_ref = np.asarray(h @ w_out)
    sparsity = float((h == 0).mean())
    print(f"FFN {d_model}->{d_ff}->{d_model}; ReLU sparsity {sparsity:.1%}")

    for t in (16, 64, 256):
        y, stats = spikified_linear(jax.random.key(1), h, w_out, num_steps=t)
        err = float(np.abs(np.asarray(y) - y_ref).mean()
                    / np.abs(y_ref).mean())
        print(f"T={t:4d}: rel err {err:6.3f}, "
              f"event fraction {float(stats['event_fraction']):.3f} "
              f"(weight-row traffic vs dense)")

    print("-> error falls ~1/sqrt(T); traffic tracks activation sparsity —")
    print("   the paper's event-driven energy story, MXU-native.")


if __name__ == "__main__":
    main()
