"""Length-prefixed wire protocol for live spike-stream ingest.

The socket front end (:mod:`repro.launch.socket_serve`) feeds
:class:`~repro.engine.stream_server.StreamServer` from real connections;
this module is the framing both ends speak.  It is deliberately socket-free
— pure ``bytes -> frames`` — so the tier-1 suite exercises every byte of
the protocol without opening a port, and any transport (TCP, Unix socket,
serial link from the sensor) can carry it.

Frame layout (network byte order)::

    +----+----+---------+---------+====================+
    |'M' |'G' | ver u8  | kind u8 | len u32 | payload  |
    +----+----+---------+---------+====================+

Two wire versions are live.  **v2** (current) carries a model id on every
REQUEST, routing it to a tenant of the server's multi-tenant registry;
**v1** frames (the pre-registry protocol) are still accepted and route to
the registry's default model, so deployed edge sensors keep working
unmodified.  Encoders default to v2; :class:`FrameDecoder` accepts both and
stamps each :class:`Frame` with the version it arrived as, which is what
selects the REQUEST header layout downstream.

Kinds:

  * ``REQUEST`` — ``req_id u32, T u32, n_in u32, slack f64`` (v2 adds
    ``name_len u8`` + that many utf-8 model-name bytes; ``name_len == 0``
    means the default model) followed by the ``[T, n_in]`` 0/1 spike
    raster **bit-packed** (``np.packbits``): an event-driven edge link
    ships 1 bit per (step, neuron), 8x smaller than float32 and exactly
    round-trippable since spikes are binary.  ``slack`` is the per-request
    deadline slack in seconds (``inf`` = best-effort), mapping 1:1 onto
    ``StreamServer.submit(slack=...)``.
  * ``RESULT`` — ``req_id u32, T u32, n_out u32`` + bit-packed output
    spikes: the request's bit-exact ``RequestResult.out_spikes``.
  * ``REJECT`` — ``req_id u32`` + utf-8 reason (the server's
    :class:`~repro.engine.stream_server.Rejection` reason/detail), so a
    client always learns the fate of every request it sent.
  * ``ADMIN`` (v2) — ``req_id u32`` + a utf-8 JSON object: the control
    plane.  ``{"op": "swap", "model": ..., ...}`` hot-swaps a tenant
    through the server's model factory; ``{"op": "list"}`` enumerates
    tenants; ``{"op": "metrics"}`` returns the full schema-locked
    ``ServerMetrics.snapshot()`` (``METRIC_KEYS``); ``{"op": "trace"}``
    exports the flight recorder — ``{"op": "trace", "rid": N}`` one
    request's span trace by server rid, ``{"op": "trace", "last": true}``
    the most recently completed trace, bare ``{"op": "trace"}`` the full
    recorder ``dump()`` (see ``docs/OBSERVABILITY.md``).  The server
    answers with an ADMIN frame echoing ``req_id`` and a JSON reply
    (``{"ok": true/false, ...}``).

``req_id`` is client-chosen correlation state (the server echoes it back);
it is unrelated to the server's internal rids.  :class:`FrameDecoder` is an
incremental parser: feed it arbitrary chunk boundaries (as TCP delivers
them) and complete frames come out.  After it raises
:class:`ProtocolError` the buffered bytes are corrupt beyond resync;
:meth:`FrameDecoder.reset` discards them so a caller that keeps the
decoder (or reuses a pooled one) does not re-raise forever.
"""

from __future__ import annotations

import dataclasses
import json
import math
import struct

import numpy as np

MAGIC = b"MG"
#: The version encoders emit.  v1 = the pre-multi-tenant protocol (no model
#: id); decoders accept every entry of SUPPORTED_VERSIONS.
VERSION = 2
SUPPORTED_VERSIONS = (1, 2)

KIND_REQUEST = 0
KIND_RESULT = 1
KIND_REJECT = 2
KIND_ADMIN = 3

_HEADER = struct.Struct(">2sBBI")           # magic, version, kind, payload len
_REQ_HEAD_V1 = struct.Struct(">IIId")       # req_id, T, n_in, slack
_REQ_HEAD_V2 = struct.Struct(">IIIdB")      # ... + model-name length
_RES_HEAD = struct.Struct(">III")           # req_id, T, n_out
_REJ_HEAD = struct.Struct(">I")             # req_id
_ADM_HEAD = struct.Struct(">I")             # req_id (JSON body follows)

# A [T, n_in] raster at the largest serving bucket is a few KiB bit-packed;
# anything near this bound is a corrupt length prefix, not a real request.
MAX_PAYLOAD = 1 << 26


class ProtocolError(ValueError):
    """Corrupt or incompatible framing — the connection should be closed."""


@dataclasses.dataclass(frozen=True)
class Frame:
    kind: int
    payload: bytes
    version: int = VERSION      # the wire version this frame arrived as


def _pack_bits(spikes: np.ndarray) -> bytes:
    return np.packbits((np.asarray(spikes) > 0).astype(np.uint8),
                       axis=None).tobytes()


def _unpack_bits(buf: bytes, t: int, n: int) -> np.ndarray:
    need = -(-t * n // 8)
    if len(buf) != need:
        raise ProtocolError(f"raster for [{t}, {n}] needs {need} bytes, "
                            f"got {len(buf)}")
    bits = np.unpackbits(np.frombuffer(buf, dtype=np.uint8), count=t * n)
    return bits.reshape(t, n).astype(np.float32)


def _frame(kind: int, payload: bytes, version: int = VERSION) -> bytes:
    return _HEADER.pack(MAGIC, version, kind, len(payload)) + payload


def encode_request(req_id: int, stream: np.ndarray,
                   slack: float = math.inf, *, model: str | None = None,
                   version: int = VERSION) -> bytes:
    """One client request: a ``[T, n_in]`` spike raster plus its deadline
    slack (and, on v2, the target model name — ``None`` routes to the
    server's default model), bit-packed into a single frame."""
    stream = np.asarray(stream)
    assert stream.ndim == 2, f"expected [T, n_in], got {stream.shape}"
    t, n_in = stream.shape
    if version == 1:
        if model is not None:
            raise ProtocolError("v1 frames carry no model id; "
                                "re-encode with version=2")
        head = _REQ_HEAD_V1.pack(req_id, t, n_in, float(slack))
    elif version == 2:
        name = (model or "").encode()
        if len(name) > 255:
            raise ProtocolError(f"model name {len(name)}B > 255B limit")
        head = _REQ_HEAD_V2.pack(req_id, t, n_in, float(slack),
                                 len(name)) + name
    else:
        raise ProtocolError(f"cannot encode protocol version {version}")
    return _frame(KIND_REQUEST, head + _pack_bits(stream), version=version)


def _req_head(payload: bytes, version: int):
    """Parse a REQUEST header; returns ``(req_id, t, n_in, slack, model,
    raster_offset)`` with ``model=None`` for v1 / empty-name v2 frames."""
    if version == 1:
        if len(payload) < _REQ_HEAD_V1.size:
            raise ProtocolError(
                f"request payload truncated at {len(payload)}B")
        req_id, t, n_in, slack = _REQ_HEAD_V1.unpack_from(payload)
        return req_id, t, n_in, slack, None, _REQ_HEAD_V1.size
    if len(payload) < _REQ_HEAD_V2.size:
        raise ProtocolError(f"request payload truncated at {len(payload)}B")
    req_id, t, n_in, slack, name_len = _REQ_HEAD_V2.unpack_from(payload)
    off = _REQ_HEAD_V2.size + name_len
    if len(payload) < off:
        raise ProtocolError(f"request model name truncated "
                            f"({name_len}B claimed, payload {len(payload)}B)")
    name = payload[_REQ_HEAD_V2.size:off]
    try:
        model = name.decode() or None
    except UnicodeDecodeError as e:
        raise ProtocolError(f"model name is not utf-8: {e}") from None
    return req_id, t, n_in, slack, model, off


def peek_request(payload: bytes, version: int = VERSION
                 ) -> tuple[int, int, int, float, str | None]:
    """Request header ``(req_id, T, n_in, slack, model)`` without unpacking
    the raster — what the server reads to resolve the tenant and validate
    the claimed shape against *that model* before committing to the
    ``[T, n_in]`` decode, so a well-framed request with an unknown model or
    a bogus width answers with a REJECT instead of reaching the engine."""
    return _req_head(payload, version)[:5]


def decode_request(payload: bytes, version: int = VERSION
                   ) -> tuple[int, np.ndarray, float, str | None]:
    req_id, t, n_in, slack, model, off = _req_head(payload, version)
    return req_id, _unpack_bits(payload[off:], t, n_in), slack, model


def encode_result(req_id: int, out_spikes: np.ndarray) -> bytes:
    out = np.asarray(out_spikes)
    assert out.ndim == 2, f"expected [T, n_out], got {out.shape}"
    t, n_out = out.shape
    return _frame(KIND_RESULT,
                  _RES_HEAD.pack(req_id, t, n_out) + _pack_bits(out))


def decode_result(payload: bytes) -> tuple[int, np.ndarray]:
    if len(payload) < _RES_HEAD.size:
        raise ProtocolError(f"result payload truncated at {len(payload)}B")
    req_id, t, n_out = _RES_HEAD.unpack_from(payload)
    return req_id, _unpack_bits(payload[_RES_HEAD.size:], t, n_out)


def encode_rejection(req_id: int, reason: str) -> bytes:
    return _frame(KIND_REJECT, _REJ_HEAD.pack(req_id) + reason.encode())


def decode_rejection(payload: bytes) -> tuple[int, str]:
    if len(payload) < _REJ_HEAD.size:
        raise ProtocolError(f"reject payload truncated at {len(payload)}B")
    (req_id,) = _REJ_HEAD.unpack_from(payload)
    return req_id, payload[_REJ_HEAD.size:].decode()


def encode_admin(req_id: int, body: dict) -> bytes:
    """A control-plane frame (v2-only): ``body`` is a JSON-serializable
    object — a request (``{"op": "swap", ...}``) or the server's reply."""
    payload = _ADM_HEAD.pack(req_id) + json.dumps(
        body, sort_keys=True).encode()
    return _frame(KIND_ADMIN, payload)


def decode_admin(payload: bytes) -> tuple[int, dict]:
    if len(payload) < _ADM_HEAD.size:
        raise ProtocolError(f"admin payload truncated at {len(payload)}B")
    (req_id,) = _ADM_HEAD.unpack_from(payload)
    try:
        body = json.loads(payload[_ADM_HEAD.size:].decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ProtocolError(f"admin body is not JSON: {e}") from None
    if not isinstance(body, dict):
        raise ProtocolError(f"admin body must be an object, "
                            f"got {type(body).__name__}")
    return req_id, body


class FrameDecoder:
    """Incremental frame parser over an arbitrary byte stream.

    ``feed(chunk)`` buffers and returns every frame completed by that
    chunk (possibly none, possibly several) — chunk boundaries are
    whatever the transport delivered.  Corrupt magic, an unknown version,
    or an absurd length prefix raise :class:`ProtocolError`; the caller
    should drop the connection (there is no way to resynchronize a
    length-prefixed stream after corruption) and must call :meth:`reset`
    before reusing the decoder — the corrupt bytes stay buffered, so
    without a reset every later ``feed`` re-raises on them."""

    def __init__(self):
        self._buf = bytearray()

    @property
    def pending_bytes(self) -> int:
        return len(self._buf)

    def reset(self) -> int:
        """Discard the buffer (corrupt beyond resync after a
        :class:`ProtocolError`).  Returns how many bytes were dropped."""
        dropped = len(self._buf)
        self._buf.clear()
        return dropped

    def feed(self, chunk: bytes) -> list[Frame]:
        self._buf.extend(chunk)
        frames: list[Frame] = []
        while len(self._buf) >= _HEADER.size:
            magic, ver, kind, length = _HEADER.unpack_from(self._buf)
            if magic != MAGIC:
                raise ProtocolError(f"bad magic {magic!r}")
            if ver not in SUPPORTED_VERSIONS:
                raise ProtocolError(f"protocol version {ver}, "
                                    f"want one of {SUPPORTED_VERSIONS}")
            if length > MAX_PAYLOAD:
                raise ProtocolError(f"frame length {length} > {MAX_PAYLOAD}")
            if len(self._buf) < _HEADER.size + length:
                break
            payload = bytes(self._buf[_HEADER.size:_HEADER.size + length])
            del self._buf[:_HEADER.size + length]
            frames.append(Frame(kind=kind, payload=payload, version=ver))
        return frames
