"""Mamba2-2.7B: attention-free SSD, 64L d=2560, state 128
[arXiv:2405.21060; unverified]."""

import dataclasses

from repro.configs.common import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    ssm_chunk=128,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, vocab_size=256, ssm_state=16,
    ssm_head_dim=16, ssm_chunk=8)
