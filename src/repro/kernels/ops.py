"""Public jit'd entry points for the Pallas kernels.

On CPU (this container) the kernels run in interpret mode automatically; on
TPU they compile natively.  `ref.py` holds the pure-jnp oracles.
"""

from __future__ import annotations

import jax

from repro.core.quant import check_bits
from repro.kernels import ref  # noqa: F401  (re-exported for convenience)
from repro.kernels.c2c_matmul import c2c_matmul as _c2c_matmul
from repro.kernels.event_synapse import (event_synapse as _event_synapse,
                                         event_synapse_packed as
                                         _event_synapse_packed,
                                         events_from_spikes, overflow_count)
from repro.kernels.lif_update import lif_update as _lif_update


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def event_synapse(events, weights, block_d: int = 256):
    return _event_synapse(events, weights, block_d=block_d, interpret=_on_cpu())


def event_synapse_packed(events, packed_w, scale, *, bits: int,
                         block_d: int = 256):
    return _event_synapse_packed(events, packed_w, scale,
                                 bits=check_bits(bits), block_d=block_d,
                                 interpret=_on_cpu())


def lif_update(v, current, *, beta=0.9, threshold=1.0, v_reset=0.0,
               block=(8, 512)):
    return _lif_update(v, current, beta=beta, threshold=threshold,
                       v_reset=v_reset, block=block, interpret=_on_cpu())


def c2c_matmul(x, w_q, scale, bm: int = 128, bk: int = 128, bn: int = 128):
    return _c2c_matmul(x, w_q, scale, bm=bm, bk=bk, bn=bn, interpret=_on_cpu())


__all__ = ["event_synapse", "event_synapse_packed", "lif_update",
           "c2c_matmul", "events_from_spikes", "overflow_count", "ref"]
