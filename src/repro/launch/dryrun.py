import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove every (architecture x shape x mesh) cell lowers,
SPMD-partitions, and compiles on the production meshes — and extract the
roofline inputs (FLOPs, bytes, collective traffic, per-device memory) from
the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape train_4k [--multi-pod] [--rules sp] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init) — hence its position.
"""

import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (SHAPES, applicable_shapes, get_config,  # noqa: E402
                           ARCH_IDS)
from repro.launch.hlo_analysis import (collective_bytes,  # noqa: E402
                                       roofline_terms)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.models.layers import abstract_params  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.parallel.sharding import (DECODE_RULES, DECODE_RULES_SP,  # noqa: E402
                                     TRAIN_RULES, ShardingRules, activate)


def _abstract_opt(params_abs):
    return {"m": params_abs, "v": params_abs,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _axes_opt(axes):
    return {"m": axes, "v": axes, "step": ()}


def _is_axes_leaf(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


def _shardings_for(rules: ShardingRules, axes_tree, abs_tree):
    return jax.tree.map(
        lambda ax, ab: rules.sharding(tuple(ax), tuple(ab.shape)),
        axes_tree, abs_tree, is_leaf=_is_axes_leaf)


def lower_cell(arch: str, shape_name: str, mesh, rules_name: str = "base",
               attn_impl: str = "baseline"):
    """Lower + compile one cell.  Returns (compiled, lowered, meta dict)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    bundle = build_model(cfg)
    kind = shape.kind

    rule_map = {"base": TRAIN_RULES if kind == "train" else DECODE_RULES,
                "sp": DECODE_RULES_SP}[rules_name]

    with activate(mesh, rule_map) as rules:
        params_abs = bundle.abstract_params(
            jnp.float32 if kind == "train" else jnp.bfloat16)
        axes = bundle.param_axes()
        p_shard = _shardings_for(rules, axes, params_abs)
        inputs_abs, in_axes = bundle.input_specs(shape)
        in_shard = {k: rules.sharding(tuple(in_axes[k]),
                                      tuple(inputs_abs[k].shape))
                    for k in inputs_abs}

        if kind == "train":
            from repro.engine.train_loop import make_train_step
            # §Perf iteration 9: gradient accumulation sized so per-device
            # activations fit HBM — big archs split the global batch
            import math as _math
            n_params_b = sum(_math.prod(s.shape)
                             for s in jax.tree.leaves(params_abs)) / 1e9
            micro = 8 if n_params_b > 50 else (4 if n_params_b > 15 else 1)
            if shape.global_batch % max(micro, 1) != 0:
                micro = 1
            step = make_train_step(bundle.loss, AdamWConfig(),
                                   microbatches=micro)
            state_abs = {"params": params_abs, "opt": _abstract_opt(params_abs)}
            state_shard = {"params": p_shard,
                           "opt": {"m": p_shard, "v": p_shard,
                                   "step": rules.sharding(())}}
            fn = jax.jit(step,
                         in_shardings=(state_shard, in_shard),
                         out_shardings=(state_shard, None),
                         donate_argnums=(0,))
            lowered = fn.lower(state_abs, inputs_abs)
        elif kind == "prefill":
            fn = jax.jit(bundle.prefill,
                         in_shardings=(p_shard, in_shard),
                         out_shardings=None)
            lowered = fn.lower(params_abs, inputs_abs)
        else:  # decode
            cache_abs, cache_axes = bundle.cache_spec(shape.global_batch,
                                                      shape.seq_len)
            c_shard = _shardings_for(rules, cache_axes, cache_abs)

            def decode(params, cache, batch):
                if attn_impl == "sp":
                    from repro.parallel.decode import make_sp_attention
                    impl = make_sp_attention(rules.mesh)
                    return bundle.decode(params, cache, batch, attn_impl=impl)
                return bundle.decode(params, cache, batch)

            fn = jax.jit(decode,
                         in_shardings=(p_shard, c_shard, in_shard),
                         out_shardings=(None, c_shard),
                         donate_argnums=(1,))
            lowered = fn.lower(params_abs, cache_abs, inputs_abs)

    t0 = time.monotonic()
    compiled = lowered.compile()
    compile_s = time.monotonic() - t0
    return compiled, lowered, {"arch": arch, "shape": shape_name,
                               "kind": kind, "mesh": list(mesh.devices.shape),
                               "rules": rules_name, "attn": attn_impl,
                               "compile_s": compile_s}


def analyze(compiled, lowered, meta, n_devices: int) -> dict:
    from repro.parallel.compat import compiled_cost_analysis
    cost = compiled_cost_analysis(compiled)
    hlo = compiled.as_text()
    # loop-aware re-analysis: cost_analysis() counts while bodies once (see
    # hlo_flops.py) — with scan-over-layers that undercounts by ~n_layers.
    from repro.launch.hlo_flops import analyze_hlo
    loop_cost = analyze_hlo(hlo)
    coll = collective_bytes(hlo)
    terms = roofline_terms(
        {"flops": loop_cost.flops, "bytes accessed": loop_cost.bytes},
        loop_cost, n_devices)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = getattr(ma, attr)
    except Exception as e:  # pragma: no cover - backend-dependent
        mem["error"] = str(e)
    return {**meta,
            "cost_analysis_raw": {k: float(v) for k, v in cost.items()
                                  if isinstance(v, (int, float))},
            "loop_aware": {"flops": loop_cost.flops,
                           "dot_flops": loop_cost.dot_flops,
                           "bytes": loop_cost.bytes},
            "collectives": {"bytes": loop_cost.coll_bytes,
                            "counts": loop_cost.coll_counts},
            "memory": mem,
            "roofline": terms.to_dict()}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             rules_name: str = "auto", attn_impl: str = "auto",
             verbose: bool = True) -> dict:
    # production defaults: SP flash-decode for decode cells (§Perf iter. 5)
    is_decode = SHAPES[shape_name].kind == "decode"
    explicit = (rules_name != "auto" or attn_impl != "auto")
    if rules_name == "auto":
        rules_name = "sp" if is_decode else "base"
    if attn_impl == "auto":
        attn_impl = "sp" if is_decode else "baseline"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    compiled, lowered, meta = lower_cell(arch, shape_name, mesh,
                                         rules_name, attn_impl)
    rec = analyze(compiled, lowered, meta, n_dev)
    tag = "multipod" if multi_pod else "pod"
    suffix = f"_{rules_name}_{attn_impl}" if explicit else ""
    os.makedirs(os.path.join(out_dir, tag), exist_ok=True)
    path = os.path.join(out_dir, tag,
                        f"{arch}_{shape_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        r = rec["roofline"]
        print(f"[dryrun OK] {arch} x {shape_name} mesh={meta['mesh']} "
              f"compile={meta['compile_s']:.1f}s "
              f"compute={r['compute_s']*1e3:.2f}ms "
              f"memory={r['memory_s']*1e3:.2f}ms "
              f"collective={r['collective_s']*1e3:.2f}ms "
              f"dominant={r['dominant']}")
        try:
            ma = compiled.memory_analysis()
            print(f"  memory_analysis: args={getattr(ma, 'argument_size_in_bytes', '?')} "
                  f"out={getattr(ma, 'output_size_in_bytes', '?')} "
                  f"temp={getattr(ma, 'temp_size_in_bytes', '?')}")
        except Exception:
            pass
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="auto", choices=["auto", "base", "sp"],
                    help="auto = sp flash-decode for decode cells (the "
                         "production config after §Perf iteration 5), base "
                         "elsewhere; 'base' reproduces the pre-iteration "
                         "baseline")
    ap.add_argument("--attn", default="auto",
                    choices=["auto", "baseline", "sp"])
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in applicable_shapes(get_config(a)):
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for a, s in cells:
        try:
            run_cell(a, s, args.multi_pod, args.out, args.rules, args.attn)
        except Exception:
            failures.append((a, s))
            print(f"[dryrun FAIL] {a} x {s}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")
    print(f"all {len(cells)} cells passed")


if __name__ == "__main__":
    main()
