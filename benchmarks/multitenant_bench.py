"""Multi-tenant fabric benchmark: isolation, hot-swap, zero-drop routing.

One shared :class:`~repro.engine.stream_server.StreamServer` serves two
demo models (the MLP and the conv pipeline) as named tenants of a
:class:`~repro.engine.registry.ModelRegistry`, replaying per-tenant
arrival traces on a VirtualClock with a fixed service model — the same
deterministic-replay methodology as the chaos scenarios, so every number
in ``BENCH_multitenant.json`` is reproducible bit-for-bit.  Midway
through the shared run the MLP tenant is hot-swapped onto perturbed
weights, exactly as an operator would push a retrained model into a live
fabric.

  PYTHONPATH=src python benchmarks/multitenant_bench.py [--smoke] \
      [--out BENCH_multitenant.json]

Gates (CI fails loudly on regression):
  * **zero-drop hot swap** — every request of both tenants is admitted
    and completed across the swap: no rejects, no sheds, no lost rids
    (swap downtime == 0 dropped requests);
  * **bit-exactness per request** — each result equals ``run_batched``
    on the packed model that was live for that tenant *at admission
    time* (old weights before the swap instant, new weights after, the
    other tenant untouched);
  * **isolation** — each tenant's throughput on the shared fabric stays
    within 10% of a dedicated single-tenant server replaying the same
    trace (time-multiplexing many models costs < 10% per tenant at this
    load, the virtual-neuron economics one level up);
  * **no retrace on swap** — the same-shaped swap payload reuses every
    compiled bucket: zero new jit traces during the shared run.
"""

from __future__ import annotations

import argparse
import json
import time

from repro.launch._spoof import (assert_spoof_applied,
                                 spoof_devices_from_argv)

_SPOOFED = spoof_devices_from_argv()  # before any jax import in this process

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.noise import (AnalogNoise, as_noise_key,  # noqa: E402
                              perturb_packed)
from repro.engine import (BucketPolicy, ModelRegistry,  # noqa: E402
                          StreamServer, VirtualClock, run_batched,
                          serve_trace, trace_count)
from repro.engine.chaos import synth_arrival_trace  # noqa: E402
from repro.launch.serve_snn import build_demo_model  # noqa: E402

#: Simulated per-dispatch service time (VirtualClock seconds) — fixed so
#: the schedule, and hence every metric, is deterministic.
_SERVICE_S = 0.002
_RATE_RPS = 150.0
_SLACK_S = 0.25


def _tenants(smoke: bool) -> dict:
    """Two demo models as tenants with per-tenant traces: the MLP under
    steady Poisson arrivals, the conv pipeline under adversarial bursts."""
    n_req = 12 if smoke else 40
    out = {}
    for name, mode, seed in (("mlp", "poisson", 1), ("conv", "adversarial", 2)):
        packed = build_demo_model(name, smoke=smoke).pack()
        trace = synth_arrival_trace(n_req, packed.n_in, mode=mode,
                                    rate=_RATE_RPS, slack=_SLACK_S,
                                    t_lo=3, t_hi=12, seed=seed)
        policy = BucketPolicy.covering([s.shape[0] for _, s, _ in trace],
                                       max_batch=4)
        out[name] = {"packed": packed, "trace": trace, "policy": policy}
    return out


def _ref(packed, stream) -> np.ndarray:
    return run_batched(packed, stream[None],
                       with_stats=False).out_spikes[0][:stream.shape[0]]


def _window_end(tenants: dict) -> float:
    """The common serving window both setups are measured over: last
    arrival anywhere plus the deadline slack.  Holding every run open this
    long (via a no-op control event) makes trailing partial buckets
    dispatch on their deadline triggers in *both* setups — otherwise the
    dedicated run's early end-of-trace flush flatters its makespan."""
    last = max(t_a for t in tenants.values() for t_a, _, _ in t["trace"])
    return last + _SLACK_S + 1e-6


def _span_row(name: str, mm: dict, span: float) -> dict:
    """Per-tenant result row: throughput over the tenant's own completion
    span (first arrival to last completion), plus the latency/miss
    surface straight off the schema-locked per-model snapshot."""
    return {
        "completed": mm["completed"],
        "span_s": span,
        "throughput_rps": mm["completed"] / max(span, 1e-9),
        "deadline_miss_rate": mm["deadline_miss_rate"],
        "p99_latency_s": mm["p99_latency_s"],
        "hot_swaps": mm["hot_swaps"],
    }


def dedicated_baseline(tenants: dict, t_end: float) -> dict:
    """Each tenant alone on its own single-tenant server — the isolation
    yardstick the shared fabric is gated against."""
    rows = {}
    for name, t in tenants.items():
        done_t: dict[int, float] = {}
        server = StreamServer(
            t["packed"], policy=t["policy"], clock=VirtualClock(),
            service_model=lambda b, tt: _SERVICE_S,
            on_completion=lambda rid, res: done_t.__setitem__(
                rid, server.now()))
        results, rids = serve_trace(server, t["trace"],
                                    control=[(t_end, lambda s: None)])
        assert None not in rids and len(results) == len(t["trace"])
        span = max(done_t.values()) - min(ta for ta, _, _ in t["trace"])
        mm = server.metrics.snapshot()["per_model"][server.registry.default]
        rows[name] = _span_row(name, mm, span)
        print(f"multitenant/dedicated/{name}: {mm['completed']} served over "
              f"{span:.3f}s sim ({rows[name]['throughput_rps']:.0f} rps)"
              f" | miss {mm['deadline_miss_rate']:.3f}")
    return rows


def shared_fabric(tenants: dict, t_end: float, *, swap_tenant: str = "mlp",
                  swap_sigma: float = 0.2, seed: int = 0) -> tuple[dict, dict]:
    """The measured system: one fabric, both tenants, one mid-run
    hot-swap.  The whole replay runs **twice**: the first pass compiles
    every bucket shape the schedule touches, the second (identical —
    VirtualClock replays are deterministic) is the measured one and must
    add zero jit traces, proving the same-shaped hot-swap reuses every
    compiled bucket.  Returns ``(per-tenant rows, fabric row)`` after
    enforcing the zero-drop, determinism, bit-exactness, and no-retrace
    gates."""
    tagged = sorted(((t_a, s, d, name)
                     for name, t in tenants.items()
                     for t_a, s, d in t["trace"]), key=lambda e: e[0])
    # swap at the median arrival instant: plenty of traffic on both sides
    swap_t = tagged[len(tagged) // 2][0]
    swapped = perturb_packed(as_noise_key(seed + 7919),
                             tenants[swap_tenant]["packed"],
                             AnalogNoise(weight_sigma=swap_sigma))

    def _run():
        reg = ModelRegistry()
        for name, t in tenants.items():
            reg.register(name, t["packed"], policy=t["policy"])
        done_t: dict[int, float] = {}
        server = StreamServer(
            reg, clock=VirtualClock(),
            service_model=lambda b, tt: _SERVICE_S,
            on_completion=lambda rid, res: done_t.__setitem__(
                rid, server.now()))
        t0 = time.perf_counter()
        results, rids = serve_trace(
            server, tagged,
            control=[(swap_t, lambda srv: srv.swap(swap_tenant, swapped)),
                     (t_end, lambda srv: None)])
        wall = time.perf_counter() - t0
        return (results, rids, done_t, server.clock.now(),
                server.metrics.snapshot(), wall)

    r_warm, rids_warm, _, _, m_warm, _ = _run()     # compiles the schedule
    n0 = trace_count()
    results, rids, done_t, makespan, m, wall = _run()

    # gate: zero-drop hot swap — every request admitted and completed
    assert None not in rids, "shared fabric dropped or rejected a request"
    assert len(results) == len(tagged) == m["completed"]
    assert m["rejected"] == 0 and m["shed"] == 0, m
    assert m["hot_swaps"] == 1
    # gate: replay determinism (same discipline as the chaos scenarios)
    assert m == m_warm and rids == rids_warm, \
        "shared fabric replay is not deterministic"
    assert all(np.array_equal(results[r].out_spikes, r_warm[r].out_spikes)
               for r in results)
    # gate: no retrace — the warm pass compiled every bucket shape this
    # schedule dispatches, and the same-shaped hot-swap payload must
    # reuse them all (weights are jit arguments, not constants)
    assert trace_count() == n0, \
        "shared fabric (or the hot-swap) recompiled already-traced buckets"
    # gate: per-request bit-exactness vs the weights live at admission
    live_at = {name: t["packed"] for name, t in tenants.items()}
    n_pre = 0
    for (t_a, s, _, name), rid in zip(tagged, rids):
        live = live_at[name]
        if name == swap_tenant and t_a >= swap_t:
            live = swapped
        else:
            n_pre += name == swap_tenant
        assert np.array_equal(results[rid].out_spikes, _ref(live, s)), \
            f"{name} request at t={t_a:.3f} not bit-exact vs the " \
            f"{'old' if live is not swapped else 'new'} weights"
    assert 0 < n_pre < len(tenants[swap_tenant]["trace"]), \
        "swap instant missed the traffic window — gate is vacuous"

    per = m["per_model"]
    rows = {}
    for name, t in tenants.items():
        mm = per[name]
        mine = [rid for (_, _, _, n), rid in zip(tagged, rids) if n == name]
        span = max(done_t[rid] for rid in mine) \
            - min(ta for ta, _, _ in t["trace"])
        rows[name] = _span_row(name, mm, span)
        # occasional contention misses (a dispatch held past its trigger
        # by the other tenant's service period) are expected at this
        # load; sustained starvation is not
        assert mm["deadline_miss_rate"] <= 0.1, \
            f"tenant {name} starved on the shared fabric: {mm}"
        print(f"multitenant/shared/{name}: {mm['completed']} served over "
              f"{span:.3f}s sim ({rows[name]['throughput_rps']:.0f} rps) | "
              f"miss {mm['deadline_miss_rate']:.3f} | p99 "
              f"{mm['p99_latency_s']*1e3:.1f} ms | swaps {mm['hot_swaps']}")
    fabric = {"makespan_s": makespan, "wall_s": wall,
              "swap_t": swap_t, "pre_swap_requests": int(n_pre),
              "hot_swaps": m["hot_swaps"], "rejected": m["rejected"],
              "shed": m["shed"], "completed": m["completed"],
              "dispatches": m["dispatches"]}
    return rows, fabric


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_multitenant.json")
    ap.add_argument("--spoof-devices", type=int, default=None)
    ap.add_argument("--isolation-floor", type=float, default=0.9,
                    help="min shared/dedicated per-tenant throughput ratio")
    args = ap.parse_args()
    assert_spoof_applied(_SPOOFED)
    tenants = _tenants(args.smoke)
    t_end = _window_end(tenants)
    dedicated = dedicated_baseline(tenants, t_end)  # also warms every bucket
    shared, fabric = shared_fabric(tenants, t_end)
    isolation = {}
    for name in tenants:
        ratio = shared[name]["throughput_rps"] / \
            max(dedicated[name]["throughput_rps"], 1e-9)
        isolation[name] = ratio
        assert ratio >= args.isolation_floor, \
            f"tenant {name}: shared fabric throughput is " \
            f"{ratio:.2f}x dedicated (< {args.isolation_floor:.2f} floor)"
        print(f"multitenant/isolation/{name}: {ratio:.2f}x dedicated")
    blob = {"bench": "multitenant", "smoke": args.smoke,
            "backend": jax.default_backend(),
            "n_devices": len(jax.devices()),
            "service_s": _SERVICE_S, "rate_rps": _RATE_RPS,
            "requests_per_tenant": len(next(iter(tenants.values()))["trace"]),
            "dedicated": dedicated, "shared": shared,
            "isolation_vs_dedicated": isolation, "fabric": fabric}
    with open(args.out, "w") as f:
        json.dump(blob, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
