"""Synthetic token pipeline for the LM architectures.

Deterministic, shardable, host-side generation: each global batch is derived
from (seed, step), so any host can regenerate exactly its shard — which is
what makes checkpoint/restart exactly-once (the loop skips to `step`, no data
state to save) and makes elastic restarts trivial (a new mesh re-derives its
shards).  Sequences follow a Zipf-ish unigram distribution with short-range
repetition structure so the LM loss actually decreases.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


def _zipf_probs(vocab: int, alpha: float = 1.1) -> np.ndarray:
    r = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / r**alpha
    return p / p.sum()


def token_batch(cfg: TokenPipelineConfig, step: int) -> dict[str, np.ndarray]:
    """Batch for a given step: {'tokens': [B, S+1] int32}. tokens[:, :-1] are
    inputs, tokens[:, 1:] targets."""
    rng = np.random.default_rng((cfg.seed, step))
    p = _zipf_probs(min(cfg.vocab_size, 50_000))
    toks = rng.choice(len(p), size=(cfg.global_batch, cfg.seq_len + 1), p=p)
    # short-range copy structure: with prob .3, token t repeats token t-k
    k = rng.integers(1, 8)
    mask = rng.random((cfg.global_batch, cfg.seq_len + 1)) < 0.3
    toks[:, k:][mask[:, k:]] = toks[:, :-k][mask[:, k:]]
    return {"tokens": toks.astype(np.int32)}


def token_batches(cfg: TokenPipelineConfig, start_step: int = 0):
    step = start_step
    while True:
        yield token_batch(cfg, step)
        step += 1
