"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in JAX.

The SSD recurrence per head:  h[t] = exp(dt[t] A) h[t-1] + dt[t] B[t] x[t],
y[t] = C[t]·h[t] + D x[t] — note this *is* the paper's LIF membrane equation
(1) without a firing threshold (DESIGN.md §Arch-applicability): the same
leaky-integrator scan machinery, which is why the MENAGE core and this model
share their discretization conventions.

Implementation: chunked scan — ``lax.scan`` over sequence chunks carrying the
inter-chunk state [B, H, P, N]; intra-chunk work is the quadratic-in-Q
masked einsum (Q = ssm_chunk, default 64-256) so peak buffers are
O(B·H·Q²), never O(L²).  Decode is the O(1) recurrence.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.common import ArchConfig
from repro.models.layers import (P, bf16_layers, cross_entropy,
                                 init_params, param_axes, rms_norm)
from repro.parallel.sharding import shard


def _dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_state, cfg.ssm_head_dim


def mamba2_layer_specs(cfg: ArchConfig, n_layers: int | None = None) -> dict:
    d = cfg.d_model
    d_in, h, n, p = _dims(cfg)
    L = cfg.n_layers if n_layers is None else n_layers
    cw = cfg.ssm_conv_width
    return {
        "ln": P((L, d), ("layers", "embed"), "ones"),
        # in_proj -> [z, x, B, C, dt]
        "w_z": P((L, d, d_in), ("layers", "embed", "ssm_inner")),
        "w_x": P((L, d, d_in), ("layers", "embed", "ssm_inner")),
        "w_b": P((L, d, n), ("layers", "embed", "ssm_state")),
        "w_c": P((L, d, n), ("layers", "embed", "ssm_state")),
        "w_dt": P((L, d, h), ("layers", "embed", "ssm_heads")),
        "dt_bias": P((L, h), ("layers", "ssm_heads"), "zeros"),
        "a_log": P((L, h), ("layers", "ssm_heads"), "zeros"),
        "d_skip": P((L, h), ("layers", "ssm_heads"), "ones"),
        "conv_x": P((L, cw, d_in), ("layers", "conv_width", "ssm_inner"),
                    scale=0.5),
        "ln_y": P((L, d_in), ("layers", "ssm_inner"), "ones"),
        "w_out": P((L, d_in, d), ("layers", "ssm_inner", "embed")),
    }


def mamba2_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    return {
        "embed": P((cfg.vocab_size, d), ("vocab", "embed"), "embed", scale=0.02),
        "lm_head": P((d, cfg.vocab_size), ("embed", "vocab")),
        "ln_f": P((d,), ("embed",), "ones"),
        "layers": mamba2_layer_specs(cfg),
    }


def init_mamba2(key, cfg: ArchConfig, dtype=jnp.float32):
    return init_params(key, mamba2_specs(cfg), dtype)


def mamba2_axes(cfg: ArchConfig):
    return param_axes(mamba2_specs(cfg))


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv.  x [B, L, D], w [CW, D]."""
    cw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(cw):
        out = out + xp[:, i:i + x.shape[1]] * w[i]
    return out


def ssd_scan(x, dt, a, b, c, chunk: int):
    """Chunked SSD.  x [B,L,H,P]; dt [B,L,H]; a [H] (negative);
    b, c [B,L,N] (single group).  Returns y [B,L,H,P], final state [B,H,P,N].
    """
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        # dt=0 on padded steps -> decay exp(0)=1, zero input: state unchanged
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        l_out, l = l, l + pad
    else:
        l_out = l
    nc = l // q
    xs = x.reshape(bsz, nc, q, h, p)
    dts = dt.reshape(bsz, nc, q, h)
    bs = b.reshape(bsz, nc, q, n)
    cs = c.reshape(bsz, nc, q, n)

    def per_chunk(state, inp):
        # NOTE (§Perf iteration 8, REFUTED): casting the O(q^2) einsums to
        # bf16 w/ f32 accumulation made the memory term WORSE (+1.3%) under
        # the schedule-level instrument — the materialized operand converts
        # cost more than the smaller einsum reads saved — and added ~2.5e-2
        # numeric error vs the sequential oracle.  Kept in f32.
        xc, dtc, bc, cc = inp              # [B,q,h,p], [B,q,h], [B,q,n] x2
        da = dtc * a                       # [B,q,h]  (negative)
        cum = jnp.cumsum(da, axis=1)       # [B,q,h]
        # intra-chunk: y[l] += sum_{s<=l} C[l]·B[s] exp(cum[l]-cum[s]) dt[s] x[s]
        seg = cum[:, :, None, :] - cum[:, None, :, :]      # [B,q,q,h]
        tri = jnp.tril(jnp.ones((q, q), bool))
        decay = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("bln,bsn->bls", cc, bc)        # [B,q,q]
        w = scores[:, :, :, None] * decay                  # [B,q,q,h]
        y = jnp.einsum("blsh,bsh,bshp->blhp", w, dtc, xc)
        # contribution of the carried-in state
        dec_in = jnp.exp(cum)                              # [B,q,h]
        y = y + jnp.einsum("bln,blh,bhpn->blhp", cc, dec_in, state)
        # new state
        dec_out = jnp.exp(cum[:, -1:, :] - cum)            # [B,q,h]
        new_state = state * jnp.exp(cum[:, -1])[:, :, None, None]
        new_state = new_state + jnp.einsum(
            "bsh,bsn,bshp->bhpn", dtc * dec_out, bc, xc)
        return new_state, y

    state0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    inputs = (xs.swapaxes(0, 1), dts.swapaxes(0, 1), bs.swapaxes(0, 1),
              cs.swapaxes(0, 1))
    state, ys = jax.lax.scan(
        lambda s, i: per_chunk(s, i), state0, inputs)
    y = ys.swapaxes(0, 1).reshape(bsz, l, h, p)[:, :l_out]
    return y, state


def mamba2_block(x: jax.Array, lp: dict, cfg: ArchConfig,
                 collect_state: bool = False):
    """One Mamba2 block (full sequence).  x [B, L, d]."""
    d_in, h, n, p = _dims(cfg)
    hidden = rms_norm(x, lp["ln"], cfg.norm_eps)
    z = jnp.einsum("bld,de->ble", hidden, lp["w_z"])
    xin = jnp.einsum("bld,de->ble", hidden, lp["w_x"])
    xin = shard(xin, "act_batch", "act_seq", "act_ssm_inner")
    xin = jax.nn.silu(_causal_conv(xin, lp["conv_x"]))
    bmat = jnp.einsum("bld,dn->bln", hidden, lp["w_b"])
    cmat = jnp.einsum("bld,dn->bln", hidden, lp["w_c"])
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", hidden, lp["w_dt"]) + lp["dt_bias"])
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))
    xh = xin.reshape(*xin.shape[:2], h, p)
    y, state = ssd_scan(xh.astype(jnp.float32), dt.astype(jnp.float32), a,
                        bmat.astype(jnp.float32), cmat.astype(jnp.float32),
                        cfg.ssm_chunk)
    y = y + lp["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*xin.shape[:2], d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), lp["ln_y"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, lp["w_out"])
    out = shard(out, "act_batch", "act_seq", "act_embed")
    return x + out, state


def mamba2_logits(params: dict, cfg: ArchConfig, tokens: jax.Array,
                  remat: bool = True) -> jax.Array:
    b, s = tokens.shape
    x = params["embed"][tokens].astype(jnp.bfloat16) * math.sqrt(cfg.d_model)
    x = shard(x, "act_batch", "act_seq", "act_embed")

    def body(xx, lp):
        xx, _ = mamba2_block(xx, lp, cfg)
        return xx, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, bf16_layers(params["layers"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(jnp.bfloat16))
    return shard(logits, "act_batch", "act_seq", "act_vocab")


def mamba2_loss(params, cfg: ArchConfig, batch: dict) -> jax.Array:
    toks = batch["tokens"]
    logits = mamba2_logits(params, cfg, toks[:, :-1])
    return cross_entropy(logits, toks[:, 1:])


# ------------------------------------------------------------------ decode

def mamba2_cache_spec(cfg: ArchConfig, batch: int, n_layers: int | None = None):
    d_in, h, n, p = _dims(cfg)
    L = cfg.n_layers if n_layers is None else n_layers
    cw = cfg.ssm_conv_width
    return ({"ssm": jax.ShapeDtypeStruct((L, batch, h, p, n), jnp.float32),
             "conv": jax.ShapeDtypeStruct((L, batch, cw - 1, d_in), jnp.bfloat16)},
            {"ssm": ("layers", "cache_batch", "act_ssm_heads", "act_head_dim",
                     "act_ssm_state"),
             "conv": ("layers", "cache_batch", "conv_width", "act_ssm_inner")})


def init_mamba2_cache(cfg: ArchConfig, batch: int, n_layers=None):
    spec, _ = mamba2_cache_spec(cfg, batch, n_layers)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def mamba2_block_decode(x: jax.Array, lp: dict, cfg: ArchConfig,
                        ssm_state: jax.Array, conv_state: jax.Array):
    """One token step.  x [B, d]; ssm_state [B,h,p,n]; conv_state [B,cw-1,d_in]."""
    d_in, h, n, p = _dims(cfg)
    hidden = rms_norm(x, lp["ln"], cfg.norm_eps)
    z = jnp.einsum("bd,de->be", hidden, lp["w_z"])
    xin = jnp.einsum("bd,de->be", hidden, lp["w_x"])
    # conv over [state ; xin]
    window = jnp.concatenate([conv_state, xin[:, None]], axis=1)  # [B,cw,d_in]
    conv_out = jnp.einsum("bce,ce->be", window.astype(jnp.float32),
                          lp["conv_x"].astype(jnp.float32))
    xc = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = window[:, 1:]
    bvec = jnp.einsum("bd,dn->bn", hidden, lp["w_b"])
    cvec = jnp.einsum("bd,dn->bn", hidden, lp["w_c"])
    dt = jax.nn.softplus(
        jnp.einsum("bd,dh->bh", hidden, lp["w_dt"]) + lp["dt_bias"])
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))
    xh = xc.reshape(-1, h, p).astype(jnp.float32)
    decay = jnp.exp(dt.astype(jnp.float32) * a)                   # [B,h]
    new_state = (ssm_state * decay[:, :, None, None]
                 + jnp.einsum("bh,bn,bhp->bhpn", dt.astype(jnp.float32),
                              bvec.astype(jnp.float32), xh))
    y = jnp.einsum("bn,bhpn->bhp", cvec.astype(jnp.float32), new_state)
    y = y + lp["d_skip"][None, :, None] * xh
    y = y.reshape(-1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), lp["ln_y"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, lp["w_out"])
    return x + out, new_state, new_conv


def mamba2_decode_step(params: dict, cfg: ArchConfig, cache: dict,
                       tokens: jax.Array, pos: jax.Array):
    x = params["embed"][tokens].astype(jnp.bfloat16) * math.sqrt(cfg.d_model)
    x = shard(x, "act_batch", "act_embed")

    def body(xx, layer_in):
        lp, ssm, conv = layer_in
        xx, new_ssm, new_conv = mamba2_block_decode(xx, lp, cfg, ssm, conv)
        return xx, (new_ssm, new_conv)

    x, (ssm, conv) = jax.lax.scan(
        body, x, (bf16_layers(params["layers"]), cache["ssm"],
                  cache["conv"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x, params["lm_head"].astype(jnp.bfloat16))
    return shard(logits, "act_batch", "act_vocab"), {"ssm": ssm, "conv": conv}


def mamba2_reference_scan(x, dt, a, b, c):
    """O(L) step-by-step SSD oracle (tests): returns y, final state."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * a)                          # [B,h]
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dtt, bt, xt)
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    state, ys = jax.lax.scan(
        step, state,
        (x.swapaxes(0, 1), dt.swapaxes(0, 1), b.swapaxes(0, 1),
         c.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), state
