"""Synapse compression (arXiv:2112.07019 on the A-SYN SRAM): value dedup,
the cross-round/cross-layer shared dictionary, and bit-exact execution
through the indirection — oracle and batched engine alike."""

import numpy as np
import pytest

from _equivalence import assert_oracle_engine_equivalent
from repro.core.accelerator import map_model
from repro.core.energy import AcceleratorSpec
from repro.core.layers import Conv2d, Dense
from repro.core.mapping import MappingProblem, solve_mapping
from repro.core.memories import build_event_memories, compress_weight_words
from repro.engine.batched_run import run_batched

SPEC = AcceleratorSpec("comp", n_cores=3, n_engines=4, n_caps=8,
                       weight_mem_bytes=1 << 20)


def _mapped_tables(rng, n_src=10, n_dest=14, repeated_values=True,
                   dedup=False):
    w = rng.normal(size=(n_src, n_dest)).astype(np.float32)
    w[rng.random(w.shape) > 0.5] = 0
    if repeated_values:
        # quantization collapses weights onto few codes; emulate that so
        # value dedup has something to merge
        w = np.round(w * 4) / 4
    p = MappingProblem.from_weights(w, SPEC.n_engines, SPEC.n_caps)
    sol = solve_mapping(p)
    return w, build_event_memories(w, sol, SPEC.n_engines, SPEC.n_caps,
                                   dedup=dedup)


def test_dedup_shrinks_words_replay_unchanged(rng):
    """Value dedup allocates fewer A-SYN words while replaying the exact
    same dense effective-weight matrix."""
    w, _ = _mapped_tables(np.random.default_rng(7), dedup=False)
    p = MappingProblem.from_weights(w, SPEC.n_engines, SPEC.n_caps)
    sol = solve_mapping(p)
    plain = build_event_memories(w, sol, SPEC.n_engines, SPEC.n_caps)
    deduped = build_event_memories(w, sol, SPEC.n_engines, SPEC.n_caps,
                                   dedup=True)
    n_dest = w.shape[1]
    np.testing.assert_array_equal(plain.dense_weights(n_dest),
                                  deduped.dense_weights(n_dest))
    assert deduped.n_weight_words < plain.n_weight_words
    assert (deduped.alloc_words() <= plain.alloc_words()).all()


def test_dict_ptr_invariant_and_accounting(rng):
    """After compress_weight_words: ``weight_dict[weight_ptr] == weight_mem``
    on every allocated slot, and the per-table new-word counts sum to the
    dictionary size."""
    tabs = []
    for seed in range(3):
        _, tb = _mapped_tables(np.random.default_rng(seed), dedup=True)
        tabs.append(tb)
    stats = compress_weight_words(tabs)
    assert stats.dict_words == sum(tb.n_weight_words for tb in tabs)
    assert stats.dict_words <= stats.slot_words <= stats.synapse_words
    assert stats.ratio >= 1.0
    assert stats.compressed_bytes == stats.dict_bytes + stats.ptr_bytes
    for tb in tabs:
        words = tb.alloc_words()
        for j in range(tb.n_engines):
            a = int(words[j])
            np.testing.assert_array_equal(
                tb.weight_dict[tb.weight_ptr[j, :a]], tb.weight_mem[j, :a])


def test_replay_coo_ptr_matches_replay_coo(rng):
    _, tb = _mapped_tables(np.random.default_rng(11), dedup=True)
    compress_weight_words([tb])
    src, dest, vals = tb.replay_coo()
    src2, dest2, widx = tb.replay_coo_ptr()
    np.testing.assert_array_equal(src, src2)
    np.testing.assert_array_equal(dest, dest2)
    np.testing.assert_array_equal(tb.weight_dict[widx], vals)


def test_replay_coo_ptr_requires_compression(rng):
    _, tb = _mapped_tables(np.random.default_rng(12))
    with pytest.raises(ValueError, match="not compressed"):
        tb.replay_coo_ptr()


def _stack(rng):
    k = rng.normal(size=(3, 2, 3, 3)).astype(np.float32)
    k[rng.random(k.shape) > 0.7] = 0
    conv = Conv2d(kernel=k, in_shape=(2, 6, 6), padding=1)
    w1 = rng.normal(size=(conv.n_dest, 20)).astype(np.float32)
    w1[rng.random(w1.shape) > 0.4] = 0
    w2 = rng.normal(size=(20, 5)).astype(np.float32)
    return [conv, Dense(w=w1), Dense(w=w2)]


def test_compressed_model_shrinks_sram_bit_exact(rng):
    """The tentpole contract: compress=True shrinks every layer's
    ``sram_bytes``, populates the compression report, and changes NOTHING
    about the computed spikes — engine vs engine and oracle vs engine."""
    specs = _stack(np.random.default_rng(21))
    m0 = map_model(specs, SPEC)
    m1 = map_model(specs, SPEC, compress=True)
    assert m1.compression is not None and m1.weight_dict is not None
    assert m1.compression.ratio > 1.0
    assert sum(l.sram_bytes for l in m1.layers) == m1.compression.dict_words
    for l0, l1 in zip(m0.layers, m1.layers):
        assert l1.sram_bytes <= l0.sram_bytes
    rng2 = np.random.default_rng(5)
    spikes = (rng2.random((3, 5, specs[0].n_src)) < 0.25).astype(np.float32)
    r0 = run_batched(m0, spikes)
    r1 = run_batched(m1, spikes)
    np.testing.assert_array_equal(r0.out_spikes, r1.out_spikes)
    # full oracle-vs-engine surface on the compressed model (stats differ
    # from the UNcompressed model — narrower waddr rows — but oracle and
    # engine must agree with each other on every field)
    assert_oracle_engine_equivalent(m1, spikes, tag="compressed")
    assert_oracle_engine_equivalent(m1, spikes, max_events=4,
                                    tag="compressed-capped")


def test_noise_on_compressed_equals_uncompressed_conv(rng):
    """Analog mismatch is per physical synapse dispatch, not per dictionary
    entry: perturbing a compressed conv model must equal perturbing the
    uncompressed one (same fold_in keys, same per-synapse value stream)."""
    import jax

    from repro.core.noise import AnalogNoise, perturb_packed
    k = np.random.default_rng(31).normal(size=(2, 1, 3, 3)).astype(np.float32)
    conv = Conv2d(kernel=k, in_shape=(1, 6, 6))
    m0 = map_model([conv], SPEC)
    m1 = map_model([conv], SPEC, compress=True)
    noise = AnalogNoise(weight_sigma=0.08)
    key = jax.random.key(9)
    p0 = perturb_packed(key, m0.pack(), noise)
    p1 = perturb_packed(key, m1.pack(), noise)
    spikes = (np.random.default_rng(3).random((2, 4, conv.n_src)) < 0.3
              ).astype(np.float32)
    r0 = run_batched(p0, spikes, with_stats=False)
    r1 = run_batched(p1, spikes, with_stats=False)
    np.testing.assert_array_equal(r0.out_spikes, r1.out_spikes)
    # sigma=0 stays the identity (same object, no new jit entries)
    assert perturb_packed(key, m1.pack(), AnalogNoise(weight_sigma=0.0)) \
        is m1.pack()


def test_autotuned_compressed_model_equivalent(rng):
    """Autotuner output composes with compression and still satisfies the
    full oracle-equivalence contract on its (possibly re-shaped) grid."""
    from repro.core.mapping import autotune_grid
    specs = _stack(np.random.default_rng(41))
    res = autotune_grid(specs, SPEC, compress=True)
    assert res.best.rounds_per_timestep <= res.default.rounds_per_timestep
    spikes = (np.random.default_rng(6).random((2, 4, specs[0].n_src)) < 0.2
              ).astype(np.float32)
    assert_oracle_engine_equivalent(res.model, spikes, tag="autotuned")
