"""Analog non-ideality models (DESIGN.md §2, assumption (a)).

The silicon has op-amp offsets, capacitor mismatch and C2C ladder element
variation; we model them as optional stochastic perturbations so accuracy
sensitivity can be studied without circuit simulation.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AnalogNoise:
    weight_sigma: float = 0.0      # relative C2C ladder gain error
    offset_sigma: float = 0.0      # op-amp input-referred offset (abs, V)
    leak_mismatch: float = 0.0     # relative per-capacitor leak variation


def perturb_weights(key: jax.Array, w: jax.Array, noise: AnalogNoise) -> jax.Array:
    if noise.weight_sigma <= 0:
        return w
    return w * (1.0 + noise.weight_sigma * jax.random.normal(key, w.shape))


def perturb_membrane(key: jax.Array, v: jax.Array, noise: AnalogNoise) -> jax.Array:
    if noise.offset_sigma <= 0:
        return v
    return v + noise.offset_sigma * jax.random.normal(key, v.shape)


def perturb_beta(key: jax.Array, beta: float, shape, noise: AnalogNoise) -> jax.Array:
    b = jnp.full(shape, beta)
    if noise.leak_mismatch <= 0:
        return b
    return jnp.clip(b * (1.0 + noise.leak_mismatch * jax.random.normal(key, shape)), 0.0, 1.0)
