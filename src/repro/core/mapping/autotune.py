"""Engine-grid autotuner: pick the ``(M, N)`` core geometry for a model.

MENAGE's per-core grid — M A-NEURON engines x N capacitors each — is a free
design parameter the paper fixes per accelerator instance (§IV-A: 10x16 for
Accel_1, 20x32 for Accel_2).  For a *given* model the fixed grid is rarely
the best use of the M*N capacity: a wide shallow layer wants more engines
(rows carry more synapses each, fewer MEM_S&N rows dispatched per event),
a narrow deep chain wants more capacitors per engine (fewer
capacitor-reassignment rounds).  Restructurable neuromorphic fabrics exploit
exactly this degree of freedom (cf. SpikeHard's 64x64 -> 32x32 core
restructuring, arXiv:2306.15749; bottleneck-driven resizing in
arXiv:2511.21549).

:func:`autotune_grid` re-solves :func:`repro.core.accelerator.map_model`
over candidate grid shapes of the same total capacity M*N, scores every
feasible mapping with a roofline-style dispatch-cost model
(:func:`estimate_cycles`), and returns the best mapping plus the full
scoreboard.  The score is lexicographic ``(rounds_per_timestep, est_cycles,
sram_bytes)`` and the default grid is always a candidate, so the winner
NEVER regresses rounds-per-timestep against the untuned spec — at equal
rounds it must beat (or tie) the estimated dispatch cycles.

The cost model mirrors :func:`repro.core.memories.dispatch_simulate`'s
accounting: the controller spends ``max(B_i, 1)`` cycles per event of source
``i`` (serial MEM_S&N row reads; the M engines fire in parallel *within* a
row), so per time step the expected dispatch cost at source activity ``p``
is ``p * sum_i max(B_i, 1)`` summed over a layer's rounds, plus a
capacitor-reassignment overhead of ``N`` cycles per extra round.  The MAC
roofline ``p * nnz / M`` is folded in via ``max`` — it can only bind for
hypothetical engines slower than one synapse per row slot, but it keeps the
estimate honest if row packing ever changes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.mapping.ilp import MappingError


@dataclasses.dataclass(frozen=True)
class GridScore:
    """One candidate grid's scoreboard entry."""

    n_engines: int
    n_caps: int
    feasible: bool
    rounds_per_timestep: int = 0    # total rounds across the layer chain
    est_cycles: float = 0.0         # roofline dispatch cycles per timestep
    sram_bytes: int = 0             # max per-layer A-SYN bytes allocated
    reason: str = ""                # why infeasible (MappingError text)

    @property
    def key(self) -> tuple:
        """Lexicographic comparison key — smaller is better."""
        return (not self.feasible, self.rounds_per_timestep,
                self.est_cycles, self.sram_bytes)

    def as_dict(self) -> dict:
        return {"n_engines": self.n_engines, "n_caps": self.n_caps,
                "feasible": self.feasible,
                "rounds_per_timestep": self.rounds_per_timestep,
                "est_cycles": self.est_cycles,
                "sram_bytes": self.sram_bytes, "reason": self.reason}


@dataclasses.dataclass
class AutotuneResult:
    """Best mapping + the full scoreboard (sorted best-first)."""

    model: "object"                 # MappedModel on the winning grid
    spec: "object"                  # AcceleratorSpec actually used
    best: GridScore
    default: GridScore              # the untuned spec's entry
    scores: list[GridScore]

    @property
    def tuned(self) -> bool:
        """True when the winner differs from the default grid."""
        return (self.best.n_engines, self.best.n_caps) != \
            (self.default.n_engines, self.default.n_caps)


def candidate_grids(spec, max_candidates: int = 8) -> list[tuple[int, int]]:
    """Candidate ``(M, N)`` shapes: divisor factor pairs of the default
    capacity ``M0*N0``, nearest-to-default first, default always included.
    Degenerate shapes (single engine / single capacitor) are excluded —
    they break the event-driven parallelism the core exists for."""
    m0, n0 = spec.n_engines, spec.n_caps
    cap = m0 * n0
    pairs = {(m0, n0)}
    for m in range(2, cap // 2 + 1):
        if cap % m == 0:
            pairs.add((m, cap // m))
    ordered = sorted(pairs, key=lambda p: (abs(np.log2(p[0] / m0)), p[0]))
    keep = ordered[:max_candidates]
    if (m0, n0) not in keep:        # max_candidates too small to reach it
        keep = [(m0, n0)] + keep[:max_candidates - 1]
    return keep


def estimate_cycles(model, activity: float = 0.1) -> float:
    """Roofline dispatch-cost estimate, in controller cycles per timestep,
    for a mapped model at uniform source-spike probability ``activity``.

    Per round: ``max(p * sum_i max(B_i, 1),  p * nnz / M)`` — serial row
    dispatch vs. parallel engine MACs — plus ``N`` reassignment cycles per
    round after the first.  Layers run on separate chained cores, so the
    chain cost is the max over layers (pipeline bottleneck), not the sum.
    """
    worst = 0.0
    for layer in model.layers:
        cost = 0.0
        for ri, rnd in enumerate(layer.rounds):
            tb = rnd.tables
            rows = float(np.maximum(tb.e2a_count, 1).sum())
            macs = float(tb.sn_valid.sum())
            cost += max(activity * rows, activity * macs / tb.n_engines)
            if ri > 0:
                cost += tb.n_caps          # capacitor reassignment
        worst = max(worst, cost)
    return worst


def autotune_grid(weights, spec, *, activity: float = 0.1,
                  max_candidates: int = 8, candidates=None,
                  **map_kwargs) -> AutotuneResult:
    """Search candidate engine grids for the best mapping of ``weights``.

    ``weights`` / ``**map_kwargs`` are passed straight to
    :func:`repro.core.accelerator.map_model` (so ``compress=True``,
    ``quant_bits``, ``fanout``, ``method`` all compose with the search).
    Candidates default to :func:`candidate_grids`; pass ``candidates`` to
    pin an explicit ``[(m, n), ...]`` list (the default grid is appended if
    missing, preserving the no-regression guarantee).

    Raises :class:`~repro.core.mapping.ilp.MappingError` only when EVERY
    candidate — including the default — is infeasible.
    """
    from repro.core.accelerator import map_model   # circular-at-import-time

    default_mn = (spec.n_engines, spec.n_caps)
    grids = list(candidates) if candidates is not None else \
        candidate_grids(spec, max_candidates=max_candidates)
    grids = [(int(m), int(n)) for m, n in grids]
    if default_mn not in grids:
        grids.append(default_mn)

    scores: list[GridScore] = []
    mapped: dict[tuple[int, int], tuple] = {}
    for m, n in grids:
        cand = dataclasses.replace(spec, n_engines=m, n_caps=n,
                                   name=f"{spec.name}[{m}x{n}]")
        try:
            model = map_model(weights, cand, **map_kwargs)
        except (MappingError, ValueError) as e:
            scores.append(GridScore(n_engines=m, n_caps=n, feasible=False,
                                    reason=str(e)))
            continue
        score = GridScore(
            n_engines=m, n_caps=n, feasible=True,
            rounds_per_timestep=sum(len(l.rounds) for l in model.layers),
            est_cycles=estimate_cycles(model, activity=activity),
            sram_bytes=max(l.sram_bytes for l in model.layers))
        scores.append(score)
        mapped[(m, n)] = (model, cand)

    scores.sort(key=lambda s: s.key)
    default_score = next(s for s in scores
                         if (s.n_engines, s.n_caps) == default_mn)
    best = scores[0]
    if not best.feasible:
        raise MappingError(
            f"autotune_grid: no feasible grid among {grids} for "
            f"{spec.name}: {best.reason}")
    model, cand = mapped[(best.n_engines, best.n_caps)]
    return AutotuneResult(model=model, spec=cand, best=best,
                          default=default_score, scores=scores)
