from repro.core.mapping.ilp import (  # noqa: F401
    MappingError,
    MappingProblem,
    MappingSolution,
    solve_mapping,
    solve_mapping_full_ilp,
    solve_mapping_reduced_ilp,
    solve_mapping_greedy,
    solve_mapping_bruteforce,
)
from repro.core.mapping.maxflow import max_flow_assignment  # noqa: F401
from repro.core.mapping.autotune import (  # noqa: F401
    AutotuneResult,
    GridScore,
    autotune_grid,
    candidate_grids,
    estimate_cycles,
)
