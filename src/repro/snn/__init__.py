from repro.snn.mlp import SNNConfig, init_snn, snn_forward, snn_loss, train_snn  # noqa: F401
