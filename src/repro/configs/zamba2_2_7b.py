"""Zamba2-2.7B: 54 Mamba2 layers + shared attention block (every 6), GQA
32/32 (MHA in the shared block) [arXiv:2411.15242; hf]."""

import dataclasses

from repro.configs.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    ssm_chunk=128,
    hybrid_period=6,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
    hybrid_period=2)
