"""Production training loop: fault tolerance, straggler mitigation, elastic
restart, gradient compression.

Fault-tolerance model (designed for 1000+ nodes, exercised here on CPU):
  * **checkpoint/restart** — async atomic checkpoints every
    ``checkpoint_every`` steps (checkpoint/manager.py); on (re)start the loop
    resumes from the latest valid step.  The data pipeline is step-keyed, so
    restart is exactly-once with no reader state.
  * **preemption** — a ``failure_hook`` (tests inject one) may raise at any
    step boundary; the loop guarantees the last committed checkpoint is
    consistent (atomic rename) and restart converges on the same trajectory
    (tested bit-exact when deterministic).
  * **straggler mitigation** — per-step deadline: steps slower than
    ``straggler_factor`` x the trailing-median are counted and logged; at
    scale the same signal triggers hot-spare swap-in; here it feeds metrics
    (and tests assert the detection fires on an injected sleep).
  * **elastic restart** — checkpoints are sharding-agnostic; resuming on a
    different mesh re-places shards (tests restore 8-dev -> 4-dev -> 8-dev).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager, restore_checkpoint
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compress import (CompressionConfig, compress_gradients,
                                  decompress_gradients, init_residual)


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    residual: Any | None = None   # error-feedback state (compression)

    def as_tree(self):
        t = {"params": self.params, "opt": self.opt}
        if self.residual is not None:
            t["residual"] = self.residual
        return t

    @staticmethod
    def from_tree(t):
        return TrainState(params=t["params"], opt=t["opt"],
                          residual=t.get("residual"))


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str | None = "/tmp/repro_ckpt"   # None: no checkpoints
    log_every: int = 10
    straggler_factor: float = 3.0
    keep_checkpoints: int = 3


def init_train_state(bundle_or_loss, params, opt_cfg: AdamWConfig,
                     comp_cfg: CompressionConfig | None = None) -> TrainState:
    return TrainState(
        params=params,
        opt=adamw_init(params),
        residual=init_residual(params) if (comp_cfg and comp_cfg.enabled)
        else None)


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig,
                    comp_cfg: CompressionConfig | None = None,
                    microbatches: int = 1):
    """Builds the jittable train step: grad -> (compress->decompress with
    error feedback) -> AdamW.  Donates the state.

    ``microbatches > 1`` enables gradient accumulation (§Perf iteration 9):
    the global batch is split along axis 0 and scanned sequentially, so peak
    activation memory scales with the microbatch — what makes the 67B/235B
    train_4k cells fit per-device HBM at global batch 256.  Gradients are
    mathematically the mean over microbatches (bitwise-equal loss up to
    reduction order; tested).
    """

    def grad_fn(params, batch):
        if microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(carry, mb):
            loss_acc, grads_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + l,
                    jax.tree.map(jnp.add, grads_acc, g)), None

        split = jax.tree.map(
            lambda a: a.reshape(microbatches, a.shape[0] // microbatches,
                                *a.shape[1:]), batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss, grads), _ = jax.lax.scan(
            micro, (jnp.zeros((), jnp.float32), zeros), split)
        inv = 1.0 / microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def step(state: dict, batch: dict):
        loss, grads = grad_fn(state["params"], batch)
        residual = state.get("residual")
        if comp_cfg and comp_cfg.enabled:
            comp, residual = compress_gradients(grads, residual, comp_cfg)
            grads = decompress_gradients(comp, grads)
        params, opt, metrics = adamw_update(opt_cfg, state["params"],
                                            state["opt"], grads)
        new_state = {"params": params, "opt": opt}
        if residual is not None:
            new_state["residual"] = residual
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_state, metrics

    return step


def train_loop(state_tree: dict, step_fn, batch_fn, cfg: TrainLoopConfig,
               start_step: int = 0,
               failure_hook: Callable[[int], None] | None = None,
               log_fn: Callable[[str], None] = print):
    """Run the loop.  ``step_fn(state, batch)`` is (usually jit'd),
    ``batch_fn(step)`` produces the step's batch (step-keyed, restart-safe).

    Returns (final state, history dict).
    """
    mgr = (CheckpointManager(cfg.checkpoint_dir, keep=cfg.keep_checkpoints)
           if cfg.checkpoint_dir else None)   # None: ephemeral, no ckpt I/O
    history = {"loss": [], "step_time": [], "stragglers": 0,
               "checkpoints": []}
    durations: list[float] = []
    step = start_step
    try:
        while step < cfg.steps:
            if failure_hook is not None:
                failure_hook(step)
            t0 = time.monotonic()
            batch = batch_fn(step)
            state_tree, metrics = step_fn(state_tree, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            durations.append(dt)
            med = sorted(durations[-32:])[len(durations[-32:]) // 2]
            if len(durations) > 4 and dt > cfg.straggler_factor * med:
                history["stragglers"] += 1
                log_fn(f"[straggler] step {step}: {dt*1e3:.1f}ms vs "
                       f"median {med*1e3:.1f}ms")
            history["loss"].append(loss)
            history["step_time"].append(dt)
            for k, v in metrics.items():
                # record any extra scalar metric (acc, grad_norm, lr, ...)
                if k == "loss" or getattr(v, "ndim", 0) != 0:
                    continue
                history.setdefault(k, []).append(float(v))
            step += 1
            if mgr is not None and (step % cfg.checkpoint_every == 0
                                    or step == cfg.steps):
                mgr.save_async(step, state_tree, extra={"loss": loss})
                history["checkpoints"].append(step)
            if step % cfg.log_every == 0:
                log_fn(f"step {step}: loss={loss:.4f} "
                       f"({dt*1e3:.0f} ms/step)")
    finally:
        if mgr is not None:
            mgr.wait()
    return state_tree, history


def resume_or_init(cfg: TrainLoopConfig, init_state_tree: dict,
                   shardings=None) -> tuple[dict, int]:
    """Restore the latest checkpoint if present (elastic: onto any mesh)."""
    from repro.checkpoint.manager import latest_step

    last = latest_step(cfg.checkpoint_dir)
    if last is None:
        return init_state_tree, 0
    state = restore_checkpoint(cfg.checkpoint_dir, last, init_state_tree,
                               shardings)
    return state, last
