"""Batched, jit-compiled execution engine for the MENAGE software twin.

The numpy :func:`repro.core.accelerator.run` is the cycle-accurate oracle: it
walks timesteps, rounds, MEM_S&N rows, and engines in Python, which is exactly
right for auditing the silicon and exactly wrong for serving traffic.  This
module executes the *same* mapped model — the same control-memory content —
as a batched JAX program:

  * :func:`pack_model` turns a :class:`MappedModel` into a
    :class:`PackedModel` **pytree**: per round, ``MemTables.to_jax()`` (the
    padded int32 MEM_E2A / MEM_S&N tables) plus the dense effective-weight
    matrix replayed out of those tables and scattered to global destination
    columns (padded to the Pallas block size).
  * :func:`run_batched` executes ``spikes[B, T, n_in]`` through the chain.
    Per layer, the ``B*T`` spike vectors become padded event lists via
    ``events_from_spikes`` (the software MEM_E writer; ``overflow_count``
    reports drops against the static depth), synaptic accumulation routes
    through the ``event_synapse`` Pallas kernel (interpret mode on CPU,
    native on TPU), and the per-timestep LIF loop is a single
    ``jax.lax.scan``.

Equivalence contract (tested): output spikes are **bit-identical** to the
oracle's for every batch element, and the reported :class:`DispatchStats`
aggregates match it field for field.  Sub-ULP care: events are emitted in
ascending source order, matching the oracle's accumulation order, and padding
events add an exact ``0.0`` — so even the float32 partial sums agree.

Data layout (see README "Batched engine"):

  PackedModel.layers[l].rounds[r].tables   PackedTables (padded i32 pytree)
  PackedModel.layers[l].rounds[r].w_dense  f32 [n_src, n_dest_pad]  (dense)
  PackedModel.layers[l].rounds[r].coo_*    i32/f32 [nnz]  (shared-weight /
                                           conv rounds: COO synapse replay,
                                           scattered on device under jit)
  events                                   i32 [B*T, E]   (pad = -1)
  currents                                 f32 [B, T, n_dest_pad]
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accelerator import MappedModel
from repro.core.energy import (FRAME_CYCLES, AcceleratorSpec, EnergyReport,
                               energy_model)
from repro.core.lif import LIFParams, lif_rollout
from repro.core.memories import DispatchStats, PackedTables
from repro.core.quant import check_bits, lanes_per_byte, pack_signmag
from repro.kernels import ops
from repro.kernels.event_synapse import DEFAULT_BLOCK_D


def _mem_e_depth(layer: "PackedLayer", max_events: int | None) -> int:
    """Static MEM_E depth for a layer: full fan-in unless capped — shared by
    the kernel dispatch and the overflow accounting, which must agree."""
    return layer.n_src if max_events is None else min(max_events, layer.n_src)


def _pad_dest(n_dest: int, block_d: int) -> int:
    """Smallest dest width event_synapse can tile: unpadded when a single
    block covers the layer, else the next multiple of ``block_d``."""
    if n_dest <= block_d:
        return n_dest
    return -(-n_dest // block_d) * block_d


@dataclasses.dataclass
class PackedRound:
    """One capacitor-assignment round on the device.

    Dense layers carry ``w_dense`` (the replayed effective-weight matrix).
    Shared-weight (conv) layers instead carry a COO indirection —
    ``(coo_src, coo_dest, coo_val)`` synapse triplets replayed from the
    control memories in O(nnz) — so packing never materializes the
    ``n_src x n_dest`` unrolled matrix on the host; the device scatters the
    triplets into the kernel's weight tile under jit.  Exactly one of the
    two representations is set (``None`` fields are empty pytree nodes).

    Compressed models (``map_model(compress=True)``) route EVERY round —
    dense and conv alike — as COO, with ``coo_widx`` (an index into the
    model-wide ``PackedModel.weight_dict``) in place of ``coo_val``: the
    device gathers ``weight_dict[coo_widx]`` and scatters, so the only
    per-synapse float storage on device is the shared dictionary.
    """

    tables: PackedTables
    w_dense: jax.Array | None       # f32 [n_src, n_dest_pad], global columns
    coo_src: jax.Array | None = None    # i32 [nnz]
    coo_dest: jax.Array | None = None   # i32 [nnz], global (padded) columns
    coo_val: jax.Array | None = None    # f32 [nnz]
    coo_widx: jax.Array | None = None   # i32 [nnz] into PackedModel.weight_dict


jax.tree_util.register_dataclass(
    PackedRound,
    data_fields=["tables", "w_dense", "coo_src", "coo_dest", "coo_val",
                 "coo_widx"],
    meta_fields=[])


@dataclasses.dataclass
class PackedLayer:
    rounds: list[PackedRound]
    n_src: int = dataclasses.field(metadata=dict(static=True), default=0)
    n_dest: int = dataclasses.field(metadata=dict(static=True), default=0)
    n_dest_pad: int = dataclasses.field(metadata=dict(static=True), default=0)
    # packed-operand path (pack_model(packed_ops=True)): the layer's fused
    # weight tile as sign-magnitude codes packed ``8/bits`` destination lanes
    # per int8 byte (quant.pack_signmag), plus the per-tensor quant scale —
    # event dispatch then routes through the event_synapse_packed kernel and
    # never materializes the f32 [n_src, n_dest_pad] tile on device
    w_packed: jax.Array | None = None   # i8 [n_src, n_dest_pad * bits / 8]
    scale: jax.Array | None = None      # f32 [1, 1]
    bits: int = dataclasses.field(metadata=dict(static=True), default=8)


jax.tree_util.register_dataclass(
    PackedLayer, data_fields=["rounds", "w_packed", "scale"],
    meta_fields=["n_src", "n_dest", "n_dest_pad", "bits"])


@dataclasses.dataclass
class PackedModel:
    layers: list[PackedLayer]
    lif: LIFParams = dataclasses.field(
        metadata=dict(static=True), default=LIFParams())
    spec: AcceleratorSpec | None = dataclasses.field(
        metadata=dict(static=True), default=None)
    block_d: int = dataclasses.field(
        metadata=dict(static=True), default=DEFAULT_BLOCK_D)
    # compressed models: shared f32 [K] dictionary of unique quantized A-SYN
    # words; rounds reference it through ``coo_widx`` (None = uncompressed)
    weight_dict: jax.Array | None = None

    @property
    def n_in(self) -> int:
        return self.layers[0].n_src

    @property
    def n_out(self) -> int:
        return self.layers[-1].n_dest


jax.tree_util.register_dataclass(
    PackedModel, data_fields=["layers", "weight_dict"],
    meta_fields=["lif", "spec", "block_d"])


def _pack_layer_codes(layer, w_host: np.ndarray, bits: int
                      ) -> tuple[jax.Array, jax.Array]:
    """Host-side operand packing for one layer: recover the integer codes
    from the replayed (dequantized) tile and pack them into sign-magnitude
    sub-byte lanes.  Exactness is *asserted*, not assumed: every stored
    table value must equal ``fl32(code * scale)`` bit for bit, which is what
    makes the packed kernel's in-device dequantization reproduce the dense
    path exactly."""
    scale = np.float32(layer.scale)
    q = np.rint(w_host / scale)
    qmax = 2 ** (bits - 1) - 1
    if np.abs(q).max(initial=0) > qmax:
        raise ValueError(
            f"recovered codes exceed the {bits}-bit range [-{qmax}, {qmax}] "
            f"— layer was not quantized at {bits} bits")
    if not (q.astype(np.float32) * scale == w_host).all():
        raise ValueError(
            "packed-operand exactness violated: table values are not "
            "fl32(code * scale) — the layer's stored weights do not come "
            "from quantize_symmetric at this scale")
    w_packed = pack_signmag(q.astype(np.int8), bits)
    return (jnp.asarray(w_packed),
            jnp.asarray(scale, jnp.float32).reshape(1, 1))


def pack_model(model: MappedModel, block_d: int = DEFAULT_BLOCK_D,
               packed_ops: bool = False) -> PackedModel:
    """Build the device-ready pytree from a mapped model.  The effective
    weights are replayed from the control memories (``MemTables
    .dense_weights`` / ``.replay_coo``), not taken from the original
    matrices — the batched engine executes what is actually in the SRAM.
    Shared-weight (conv) layers replay as COO triplets so the host never
    materializes the unrolled ``n_src x n_dest`` matrix per layer.

    ``packed_ops=True`` ships every layer's weight tile as *packed
    sign-magnitude codes* (``8/bits`` destination lanes per int8 byte) plus
    the layer scale: the on-device weight footprint shrinks from 4 bytes to
    ``bits/8`` bytes per synapse slot and dispatch routes through the
    ``event_synapse_packed`` kernel, which unpacks the ladder words next to
    the MACs.  The replayed values still come from the control memories, and
    packing asserts ``fl32(code * scale)`` reproduces them bit for bit, so
    the packed engine stays bit-exact with the unpacked one at every
    bit-width (tested).  ``MappedModel.pack(packed_ops=None)`` auto-selects
    this path when any layer is quantized below 8 bits."""
    compressed = getattr(model, "weight_dict", None) is not None
    wdict_np = np.asarray(model.weight_dict, dtype=np.float32) \
        if compressed else None
    layers = []
    for layer in model.layers:
        # always recorded (prices sample_energy); only packed_ops uses it to
        # select the packed kernel route
        bits = check_bits(int(getattr(layer, "bits", 8)))
        ell = lanes_per_byte(bits)
        n_dest_pad = _pad_dest(layer.n_dest, block_d)
        if packed_ops:
            if block_d % lanes_per_byte(2):
                raise ValueError(
                    f"packed operands need block_d divisible by "
                    f"{lanes_per_byte(2)} byte lanes; got {block_d}")
            # byte lanes must tile evenly: round the padded width up to a
            # whole number of packed bytes (extra columns carry 0-codes,
            # contribute exact 0.0 currents, and are sliced off post-LIF)
            n_dest_pad = -(-n_dest_pad // ell) * ell
        shared = getattr(layer, "shared_weights", False)
        rounds = []
        # packed layers replay the fused tile on the host instead of
        # shipping per-round dense/COO weight data to the device
        w_host = np.zeros((layer.n_src, n_dest_pad), dtype=np.float32) \
            if packed_ops else None
        for rnd in layer.rounds:
            if compressed:
                # every round replays through the shared-dictionary
                # indirection: (src, dest, widx) triplets, values gathered
                # on device from PackedModel.weight_dict under jit
                src, dest_local, widx = rnd.tables.replay_coo_ptr()
                dest = rnd.neuron_ids[dest_local]
                if packed_ops:
                    np.add.at(w_host, (src, dest), wdict_np[widx])
                    rounds.append(PackedRound(tables=rnd.tables.to_jax(),
                                              w_dense=None))
                    continue
                rounds.append(PackedRound(
                    tables=rnd.tables.to_jax(), w_dense=None,
                    coo_src=jnp.asarray(src, dtype=jnp.int32),
                    coo_dest=jnp.asarray(dest, dtype=jnp.int32),
                    coo_widx=jnp.asarray(widx, dtype=jnp.int32)))
            elif shared:
                src, dest_local, vals = rnd.tables.replay_coo()
                dest = rnd.neuron_ids[dest_local]
                if packed_ops:
                    np.add.at(w_host, (src, dest), vals)
                    rounds.append(PackedRound(tables=rnd.tables.to_jax(),
                                              w_dense=None))
                    continue
                rounds.append(PackedRound(
                    tables=rnd.tables.to_jax(), w_dense=None,
                    coo_src=jnp.asarray(src, dtype=jnp.int32),
                    coo_dest=jnp.asarray(dest, dtype=jnp.int32),
                    coo_val=jnp.asarray(vals)))
            else:
                w_local = rnd.tables.dense_weights(len(rnd.neuron_ids))
                if packed_ops:
                    w_host[:, rnd.neuron_ids] += w_local
                    rounds.append(PackedRound(tables=rnd.tables.to_jax(),
                                              w_dense=None))
                    continue
                w_glob = np.zeros((layer.n_src, n_dest_pad), dtype=np.float32)
                w_glob[:, rnd.neuron_ids] = w_local
                rounds.append(PackedRound(tables=rnd.tables.to_jax(),
                                          w_dense=jnp.asarray(w_glob)))
        w_packed = scale = None
        if packed_ops:
            w_packed, scale = _pack_layer_codes(layer, w_host, bits)
        layers.append(PackedLayer(rounds=rounds, n_src=layer.n_src,
                                  n_dest=layer.n_dest, n_dest_pad=n_dest_pad,
                                  w_packed=w_packed, scale=scale, bits=bits))
    wdict = jnp.asarray(wdict_np) if compressed and not packed_ops else None
    return PackedModel(layers=layers, lif=model.lif, spec=model.spec,
                       block_d=block_d, weight_dict=wdict)


# --------------------------------------------------------------- jitted core

_trace_count = 0


def trace_count() -> int:
    """How many times a jitted engine forward has been (re)traced — the jit
    cache-stability probe used by tests and benchmarks.  Covers both the
    single-device ``run_batched`` path and the ``run_sharded`` mesh path
    (:mod:`repro.engine.sharded_run`), which bumps the same counter."""
    return _trace_count


_trace_listeners: list = []


def add_trace_listener(fn) -> None:
    """Subscribe ``fn(kind, donated)`` to jit (re)trace events — called once
    per compile of an engine forward (kind ``"batched"`` or ``"sharded"``).
    The flight recorder's jit probe lives here; listeners must never raise
    (a probe failure must not poison a compile)."""
    if fn not in _trace_listeners:
        _trace_listeners.append(fn)


def remove_trace_listener(fn) -> None:
    if fn in _trace_listeners:
        _trace_listeners.remove(fn)


def _bump_trace(kind: str = "batched", donated: bool = False) -> None:
    """Called from inside traced function bodies: python side effects execute
    exactly once per (re)trace, which is precisely what we want to count.
    Fans the event out to any registered trace listeners."""
    global _trace_count
    _trace_count += 1
    for fn in list(_trace_listeners):
        try:
            fn(kind, donated)
        except Exception:
            pass


def _lif_scan(currents: jax.Array, lif: LIFParams) -> jax.Array:
    """LIF over ``currents[B, T, n]`` via the shared ``lax.scan`` rollout
    (`repro.core.lif`) — operation-for-operation the oracle's update, so
    float32 results match; the unused voltage trace is dead-code-eliminated
    under jit."""
    spikes, _ = lif_rollout(currents.transpose(1, 0, 2), lif)
    return spikes.transpose(1, 0, 2)


def _layer_weights(layer: PackedLayer,
                   weight_dict: jax.Array | None = None) -> jax.Array:
    """Fuse a layer's rounds into one ``[n_src, n_dest_pad]`` weight tile
    for the event_synapse kernel.  Dense rounds add; COO (shared-weight)
    rounds scatter their synapse triplets — on device, under jit, O(nnz);
    compressed rounds gather their values from the model-wide
    ``weight_dict`` first (``coo_widx`` indirection).  Rounds target
    disjoint destination columns and each (src, dest) pair occurs at most
    once, so addition order cannot change any bit."""
    dense = [r.w_dense for r in layer.rounds if r.w_dense is not None]
    coo = [r for r in layer.rounds if r.w_dense is None]
    w = functools.reduce(jnp.add, dense) if dense else \
        jnp.zeros((layer.n_src, layer.n_dest_pad), jnp.float32)
    if coo:
        src = jnp.concatenate([r.coo_src for r in coo])
        dest = jnp.concatenate([r.coo_dest for r in coo])
        val = jnp.concatenate([
            r.coo_val if r.coo_val is not None else weight_dict[r.coo_widx]
            for r in coo])
        w = w.at[src, dest].add(val)
    return w


def _forward_impl(packed: PackedModel, spikes: jax.Array,
                  max_events: int | None) -> list[jax.Array]:
    """Per-layer output spike trains ([B, T, n_dest] each; the last entry is
    the model output).  Dispatch = MEM_E write + event_synapse kernel; LIF =
    one scan per layer.  Pure traced body — shared verbatim by the jitted
    single-device entry below and the per-shard body of
    :func:`repro.engine.sharded_run.run_sharded`, which is what makes the
    mesh path bit-exact by construction."""
    b, t, _ = spikes.shape
    outs = []
    for layer in packed.layers:
        events = ops.events_from_spikes(spikes.reshape(b * t, layer.n_src),
                                        _mem_e_depth(layer, max_events))
        if layer.w_packed is not None:
            # packed-operand route: the kernel gathers sub-byte ladder words
            # and dequantizes in-device — no f32 weight tile exists
            currents = ops.event_synapse_packed(
                events, layer.w_packed, layer.scale, bits=layer.bits,
                block_d=packed.block_d)
        else:
            # rounds target disjoint dest columns -> one fused kernel call
            w = _layer_weights(layer, packed.weight_dict)
            currents = ops.event_synapse(events, w, block_d=packed.block_d)
        out = _lif_scan(currents.reshape(b, t, layer.n_dest_pad), packed.lif)
        spikes = out[..., :layer.n_dest]
        outs.append(spikes)
    return outs


@functools.partial(jax.jit, static_argnames=("max_events",))
def _forward(packed: PackedModel, spikes: jax.Array,
             max_events: int | None) -> list[jax.Array]:
    _bump_trace("batched")
    return _forward_impl(packed, spikes, max_events)


@functools.partial(jax.jit, static_argnames=("max_events",),
                   donate_argnums=(1,))
def _forward_donated(packed: PackedModel, spikes: jax.Array,
                     max_events: int | None) -> list[jax.Array]:
    """`_forward` with the input spike buffer donated back to the
    allocator: on accelerator backends the padded bucket buffer a serving
    dispatch uploads is recycled into the outputs instead of surviving the
    call — so back-to-back dispatches of the same bucket never accumulate
    input copies.  A separate jit entry (donation is a property of the
    compiled executable, not the call), chosen by ``run_batched(donate=)``;
    CPU XLA implements no donation, so the single-device default stays off
    there."""
    _bump_trace("batched", donated=True)
    return _forward_impl(packed, spikes, max_events)


def should_donate(donate: bool | None) -> bool:
    """Resolve a ``donate`` tri-state: ``None`` means "on unless the
    backend is CPU" — the shared default of ``run_batched``,
    ``run_sharded``, and the serving front ends."""
    return jax.default_backend() != "cpu" if donate is None else donate


# ------------------------------------------------------------ batched result

@dataclasses.dataclass
class BatchedDispatchStats:
    """Per-sample, per-step dispatch statistics (``[B, T]`` int64 arrays);
    ``sample(b)`` recovers the oracle's :class:`DispatchStats` exactly."""

    cycles: np.ndarray
    rows_touched: np.ndarray
    engine_ops: np.ndarray
    events: np.ndarray
    sn_bytes_touched: np.ndarray
    mem_e_peak: np.ndarray      # [B]

    def sample(self, b: int) -> DispatchStats:
        return DispatchStats(
            cycles=self.cycles[b], rows_touched=self.rows_touched[b],
            engine_ops=self.engine_ops[b], events=self.events[b],
            sn_bytes_touched=self.sn_bytes_touched[b],
            mem_e_peak=int(self.mem_e_peak[b]))


@dataclasses.dataclass
class BatchedRunResult:
    out_spikes: np.ndarray                       # [B, T, n_out]
    per_layer_stats: list[BatchedDispatchStats]
    per_layer_util: list[np.ndarray]             # [B, T] float64
    overflow: list[np.ndarray]                   # [B, T] events dropped
    spec: AcceleratorSpec | None = None
    per_layer_bits: list[int] | None = None      # stored word widths (energy)

    @property
    def batch(self) -> int:
        return self.out_spikes.shape[0]

    def sample_stats(self, b: int) -> list[DispatchStats]:
        return [s.sample(b) for s in self.per_layer_stats]

    def sample_energy(self, b: int,
                      frame_cycles: int | None = FRAME_CYCLES) -> EnergyReport:
        """Same signature as :func:`repro.core.energy.energy_model`:
        ``frame_cycles`` defaults to the calibrated frame period, ``None``
        means throughput mode.  Mixed-precision models price the C2C MAC
        energy at each layer's stored word width (``per_layer_bits``)."""
        assert self.spec is not None, "pack_model carried no AcceleratorSpec"
        return energy_model(self.spec, self.sample_stats(b),
                            frame_cycles=frame_cycles,
                            per_core_bits=self.per_layer_bits)


def _layer_stats(in_spikes: np.ndarray, layer: PackedLayer,
                 max_events: int | None,
                 sn_capacity_rows: int | None
                 ) -> tuple[BatchedDispatchStats, np.ndarray, np.ndarray]:
    """Vectorized dispatch accounting for one layer: every per-step counter
    is a dot product of the accepted-event raster with a per-source table
    vector, reproducing the oracle's Python accumulation in int64.

    A finite MEM_E depth accepts only the ``depth`` lowest source indices
    per step (FIFO write order) — dropped events arrive (``events``) but
    dispatch nothing, exactly as the kernel path truncates them."""
    sp = (in_spikes > 0)
    b, t, _ = sp.shape
    depth = _mem_e_depth(layer, max_events)
    if depth >= layer.n_src:
        keep = sp                       # cap can never bind
    else:
        keep = sp & (np.cumsum(sp, axis=2) <= depth)
    shape = (b, t)
    cycles = np.zeros(shape, dtype=np.int64)
    rows = np.zeros(shape, dtype=np.int64)
    mac = np.zeros(shape, dtype=np.int64)
    bytes_t = np.zeros(shape, dtype=np.int64)
    util = np.zeros(shape, dtype=np.float64)
    total_rows = sum(r.tables.n_rows for r in layer.rounds)
    cap = sn_capacity_rows or max(total_rows, 1)
    for rnd in layer.rounds:
        rows_v, cyc_v, ops_v = rnd.tables.stats_vectors()
        r_rows = keep @ rows_v
        cycles += keep @ cyc_v
        rows += r_rows
        mac += keep @ ops_v
        bytes_t += r_rows * rnd.tables.row_bytes
        util += r_rows.astype(np.float64) / cap
    events = sp.sum(axis=2, dtype=np.int64)
    overflow = np.maximum(events - depth, 0)
    stats = BatchedDispatchStats(cycles=cycles, rows_touched=rows,
                                 engine_ops=mac, events=events,
                                 sn_bytes_touched=bytes_t,
                                 mem_e_peak=np.minimum(events, depth)
                                 .max(axis=1, initial=0))
    return stats, util, overflow


def _finalize(packed: PackedModel, in_spikes: np.ndarray,
              layer_outs: list[jax.Array], max_events: int | None,
              sn_capacity_rows: int | None,
              with_stats: bool) -> BatchedRunResult:
    """Device outputs -> :class:`BatchedRunResult`, including the host-side
    dispatch accounting.  Shared by ``run_batched`` and ``run_sharded`` so
    the two entry points cannot drift apart on the stats surface."""
    out = np.asarray(layer_outs[-1])
    bits = [l.bits for l in packed.layers]
    if not with_stats:
        return BatchedRunResult(out_spikes=out, per_layer_stats=[],
                                per_layer_util=[], overflow=[],
                                spec=packed.spec, per_layer_bits=bits)
    stats_all, util_all, drop_all = [], [], []
    layer_in = np.asarray(in_spikes, dtype=np.float32)
    for li, layer in enumerate(packed.layers):
        stats, util, overflow = _layer_stats(layer_in, layer, max_events,
                                             sn_capacity_rows)
        stats_all.append(stats)
        util_all.append(util)
        drop_all.append(overflow)
        layer_in = np.asarray(layer_outs[li])
    return BatchedRunResult(out_spikes=out, per_layer_stats=stats_all,
                            per_layer_util=util_all, overflow=drop_all,
                            spec=packed.spec, per_layer_bits=bits)


def run_batched(model: MappedModel | PackedModel, in_spikes: np.ndarray,
                *, max_events: int | None = None,
                sn_capacity_rows: int | None = None,
                with_stats: bool = True,
                donate: bool | None = None) -> BatchedRunResult:
    """Execute a batch of spike trains ``[B, T, n_in]`` through the chain.

    Bit-exact vs. the oracle ``run`` called with the same ``max_events``
    (tested, including finite caps).  A tight ``max_events`` models the
    finite MEM_E depth: excess events are dropped lowest-priority-last
    (ascending source index kept) before dispatch, counted per step in
    ``result.overflow``, and the loss propagates to downstream layers
    through the LIF exactly as on the oracle.

    Degenerate shapes are valid inputs: ``B=0`` returns an empty result
    (empty stats arrays, no crash), ``T=1`` and all-silent batches follow
    the ordinary path.  ``with_stats=False`` skips the (host-side)
    accounting — the serving configuration, where only the output spikes
    matter.  ``donate`` hands the uploaded spike buffer to the jit for
    reuse (default: on unless the backend is CPU, which lacks donation).
    """
    packed = model if isinstance(model, PackedModel) else model.pack()
    spikes = jnp.asarray(np.asarray(in_spikes, dtype=np.float32))
    assert spikes.ndim == 3 and spikes.shape[2] == packed.n_in, \
        f"expected [B, T, {packed.n_in}], got {spikes.shape}"
    fwd = _forward_donated if should_donate(donate) else _forward
    layer_outs = fwd(packed, spikes, max_events)
    return _finalize(packed, np.asarray(in_spikes, dtype=np.float32),
                     layer_outs, max_events, sn_capacity_rows, with_stats)
