"""Table I reproduction: accuracy before/after L1 prune + 8-bit PTQ.

Synthetic stand-in datasets (DESIGN.md §5): the validated claim is the
*flow* — pruning+quantization costs <~1 accuracy point (paper: 94.75->94.1
on N-MNIST, 65.38->65.03 on CIFAR10-DVS) — not the absolute numbers.
Reduced train budgets keep this CPU-feasible; --full trains longer.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prune import prune_pytree, sparsity
from repro.core.quant import quantize_pytree
from repro.data.events import EventDatasetConfig, event_batches, synthetic_event_dataset
from repro.snn.mlp import SNNConfig, snn_forward, train_snn


def _accuracy(params, snn, spikes, labels, batch=64):
    correct = 0
    for i in range(0, len(labels), batch):
        counts, _ = snn_forward(
            params, jnp.asarray(spikes[i:i + batch].swapaxes(0, 1)), snn)
        correct += int((np.asarray(counts).argmax(-1)
                        == labels[i:i + batch]).sum())
    return correct / len(labels)


def run_one(tag, data_cfg, snn_cfg, steps, prune_amt=0.5, n_per_class=24):
    key = jax.random.key(0)
    spikes, labels = synthetic_event_dataset(data_cfg, n_per_class, key)
    n_test = len(labels) // 5
    tr_s, tr_l = spikes[n_test:], labels[n_test:]
    te_s, te_l = spikes[:n_test], labels[:n_test]
    it = event_batches(tr_s, tr_l, batch=32)
    params, hist = train_snn(jax.random.key(1), snn_cfg, it, steps=steps,
                             lr=1e-3)
    acc0 = _accuracy(params, snn_cfg, te_s, te_l)
    pruned, _ = prune_pytree(params, prune_amt)
    _, dq = quantize_pytree(pruned)
    acc1 = _accuracy(dq, snn_cfg, te_s, te_l)
    print(f"accuracy/{tag},before={acc0:.4f},after_prune_quant={acc1:.4f},"
          f"drop={acc0-acc1:.4f},sparsity={sparsity(pruned):.2f}")
    return acc0, acc1


def main(full: bool = False):
    # N-MNIST-like: the paper's 200/100/40/10 MLP on 34x34x2 input
    nm_data = EventDatasetConfig.nmnist_like()
    nm_snn = SNNConfig.nmnist()
    run_one("nmnist", nm_data, nm_snn, steps=400 if full else 120)
    # CIFAR10-DVS-like: 1000/500/200/100/10 on spatially-reduced input
    cf_data = EventDatasetConfig.cifar10_dvs_like()
    cf_snn = SNNConfig(layer_sizes=(cf_data.n_in, 1000, 500, 200, 100, 10),
                       num_steps=25)
    run_one("cifar10dvs", cf_data, cf_snn, steps=200 if full else 60,
            n_per_class=16)


if __name__ == "__main__":
    main(full="--full" in sys.argv)
