"""Leaky integrate-and-fire neuron dynamics (paper §III-A, eq. (1)).

The A-NEURON emulates discrete-time LIF clocked by the system clock:

    tau_m dV/dt = -V + R_m I    →    V[t+1] = alpha * V[t] + (1-alpha) R_m I[t]

with ``alpha = exp(-dt/tau_m)`` (exact ZOH discretization) or the paper's
simpler per-step capacitive-discharge form ``V[t+1] = beta * V[t] + I[t]``
(snntorch-style ``Leaky``), which is what the hardware's controller-commanded
discharge implements.  We use the snntorch form as the default so that the
software model matches the silicon behaviour the paper simulates.

Firing: ``S[t] = 1[V[t] >= theta]``; reset-to-``V_reset`` (hard reset), as in
§III-A ("the membrane potential is reset to V_reset").

Training uses a fast-sigmoid surrogate gradient (Eshraghian et al., the
paper's SNNTorch reference [31]).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LIFParams:
    """Static LIF cell parameters (shared by a layer)."""

    beta: float = 0.9          # membrane decay per time step (capacitor discharge)
    threshold: float = 1.0     # V_th
    v_reset: float = 0.0       # reset potential
    surrogate_slope: float = 25.0  # fast-sigmoid slope k


@jax.custom_vjp
def spike_fn(v: jax.Array, threshold: float, slope: float) -> jax.Array:
    """Heaviside spike with fast-sigmoid surrogate gradient.

    forward:  S = 1[v >= threshold]
    backward: dS/dv ≈ 1 / (1 + k|v - threshold|)^2
    """
    return (v >= threshold).astype(v.dtype)


def _spike_fwd(v, threshold, slope):
    return spike_fn(v, threshold, slope), (v, threshold, slope)


def _spike_bwd(res, g):
    v, threshold, slope = res
    x = slope * (v - threshold)
    surr = 1.0 / (1.0 + jnp.abs(x)) ** 2
    return (g * surr * slope, None, None)


spike_fn.defvjp(_spike_fwd, _spike_bwd)


def lif_step(v: jax.Array, current: jax.Array, p: LIFParams):
    """One clock edge of the A-NEURON: integrate, fire, reset, leak.

    Order matches the hardware: the stored capacitor voltage is restored,
    the synaptic charge is accumulated, the comparator fires, and the
    controller commands the discharge (leak) for the next step.

    Returns ``(v_next, spikes)``.
    """
    v_integrated = p.beta * v + current
    spikes = spike_fn(v_integrated, p.threshold, p.surrogate_slope)
    v_next = jnp.where(spikes > 0, p.v_reset, v_integrated)
    return v_next, spikes


def lif_rollout(currents: jax.Array, p: LIFParams, v0: jax.Array | None = None):
    """Run LIF over a time-major current sequence ``currents[T, ...]``.

    Returns ``(spikes[T, ...], v_trace[T, ...])``.
    """
    if v0 is None:
        v0 = jnp.zeros_like(currents[0])

    def body(v, i):
        v_next, s = lif_step(v, i, p)
        return v_next, (s, v_next)

    _, (spikes, vtrace) = jax.lax.scan(body, v0, currents)
    return spikes, vtrace


def rate_encode(x: jax.Array, num_steps: int, key: jax.Array) -> jax.Array:
    """Rate-based spike encoding (the accelerator's supported encoding).

    ``x`` in [0, 1]; returns Bernoulli spike trains ``[num_steps, *x.shape]``.
    """
    keys = jax.random.split(key, num_steps)

    def one(k):
        return (jax.random.uniform(k, x.shape) < x).astype(jnp.float32)

    return jax.vmap(one)(keys)


@partial(jax.jit, static_argnames=("num_steps",))
def spike_count_decode(spikes: jax.Array, num_steps: int) -> jax.Array:
    """Rate decode: spike counts over the window (used for classification)."""
    return spikes.sum(axis=0) / num_steps
