"""End-to-end MENAGE accelerator simulation (paper Fig. 1 + Algorithm 1).

A MENAGE instance is a chain of MX-NEURACOREs, one per model layer.  Mapping
a trained+pruned+quantized SNN — a list of layer specs: bare matrices /
``Dense``, or ``Conv2d`` lowered with shared weight-SRAM words (see
:mod:`repro.core.layers`) — onto an :class:`AcceleratorSpec` produces, per
layer: an ILP mapping solution, the three control memories, and the A-SYN
weight SRAM.  ``run`` then executes a spike train through the chain with the
cycle-level dispatch simulator driving discrete-time LIF virtual neurons —
the software twin of the silicon.

Correctness contract (tested): the accelerator simulation's output spike
counts equal the pure-JAX reference SNN's (same LIF params, same quantized
weights) for every neuron the ILP assigned, and the ILP assigns all neurons
whenever capacity M*N >= layer width.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.energy import (FRAME_CYCLES, AcceleratorSpec, EnergyReport,
                               energy_model)
from repro.core.layers import Conv2d, Dense, LayerSpec, as_layer_spec
from repro.core.lif import LIFParams
from repro.core.mapping import (MappingError, MappingProblem, MappingSolution,
                                solve_mapping)
from repro.core.memories import (DispatchStats, MemTables, WeightCompression,
                                 build_event_memories, compress_weight_words,
                                 dispatch_simulate, mem_sn_utilization)
from repro.core.quant import check_bits, quantize_symmetric


@dataclasses.dataclass
class MappedRound:
    """One capacitor-assignment round (§III-D: once a neuron's connections
    are processed its capacitor is reassigned — layers wider than M*N run in
    ceil(n_dest / M*N) sequential rounds, each with its own ILP solve)."""

    neuron_ids: np.ndarray     # global dest indices handled this round
    mapping: MappingSolution   # indices local to neuron_ids
    tables: MemTables


@dataclasses.dataclass
class MappedLayer:
    w_q: np.ndarray            # unrolled dequantized int8 synaptic matrix
    rounds: list[MappedRound]
    n_src: int
    n_dest: int
    layer_spec: LayerSpec | None = None   # quantized Dense/Conv2d spec
    weight_bytes: int = 0      # unique stored bytes (kernel taps for conv)
    sram_bytes: int = 0        # A-SYN bytes physically allocated: words
                               # (a tap shared across engines/rounds is stored
                               # once per engine per round that references it)
                               # priced at the layer's actual word bit-width
    bits: int = 8              # stored weight bit-width (sign-magnitude)
    scale: float = 1.0         # per-tensor symmetric quantization scale

    @property
    def shared_weights(self) -> bool:
        """True when MEM_S&N rows share SRAM words (conv lowering)."""
        return isinstance(self.layer_spec, Conv2d)

    @property
    def mapping(self) -> MappingSolution:  # convenience: first round
        return self.rounds[0].mapping

    @property
    def tables(self) -> MemTables:
        return self.rounds[0].tables

    @property
    def n_assigned(self) -> int:
        return sum(r.mapping.n_assigned for r in self.rounds)


@dataclasses.dataclass
class MappedModel:
    spec: AcceleratorSpec
    layers: list[MappedLayer]
    lif: LIFParams
    # set by map_model(compress=True): the cross-round/cross-layer shared
    # dictionary of unique quantized A-SYN words (every round's
    # MemTables.weight_ptr indexes it) + the compression accounting
    weight_dict: np.ndarray | None = None
    compression: WeightCompression | None = None

    def pack(self, block_d: int | None = None,
             packed_ops: bool | None = None):
        """Pack into the batched JAX engine's pytree representation (see
        :mod:`repro.engine.batched_run`), memoized per (block size, operand
        layout) — the table replay and device transfer happen once, not per
        batch.  ``packed_ops`` selects the sub-byte packed-operand kernel
        path; ``None`` auto-enables it iff any layer is quantized below
        8 bits (see :func:`repro.engine.batched_run.pack_model`)."""
        from repro.engine.batched_run import DEFAULT_BLOCK_D, pack_model
        block_d = DEFAULT_BLOCK_D if block_d is None else block_d
        if packed_ops is None:
            packed_ops = any(l.bits < 8 for l in self.layers)
        cache = self.__dict__.setdefault("_packed_cache", {})
        key = (block_d, bool(packed_ops))
        if key not in cache:
            cache[key] = pack_model(self, block_d=block_d,
                                    packed_ops=packed_ops)
        return cache[key]


def map_model(weights: "list[np.ndarray | LayerSpec]", spec: AcceleratorSpec,
              lif: LIFParams = LIFParams(),
              quant_bits: "int | list[int] | tuple[int, ...]" = 8,
              fanout: int | None = None,
              method: str = "auto", compress: bool = False) -> MappedModel:
    """Algorithm 1 steps 3-5: quantize, ILP-map, build config memories.

    weights: list of layer specs, one per layer — bare ``(n_in, n_out)``
    pruned float matrices (treated as :class:`~repro.core.layers.Dense`) or
    :class:`~repro.core.layers.Conv2d` specs.  Convolutions are quantized at
    the *kernel*, unrolled to their sparse per-output synaptic matrix, and
    lowered with shared A-SYN SRAM words (one stored tap, many MEM_S&N rows
    pointing at it) — the SRAM budget check counts unique kernel bytes, not
    unrolled synapses.  Each layer must fit one MX-NEURACORE's weight SRAM;
    layers wider than M*N run in multiple capacitor-reassignment rounds.

    ``quant_bits`` sets the stored weight bit-width: a single int for every
    layer, or one per layer (mixed precision).  A layer spec's own ``bits``
    field, when set, wins over both.  Words are sign-magnitude C2C ladder
    codes (:data:`repro.core.quant.SUPPORTED_BITS`); SRAM accounting prices
    them at their actual width, and sub-8-bit layers execute through the
    packed-operand kernel path in the batched engine.

    ``compress=True`` turns on the two-level synapse compression
    (arXiv:2112.07019): per-engine *value* dedup inside
    :func:`build_event_memories` (identical quantized words on one engine
    share a slot) plus the cross-round/cross-layer shared word dictionary
    (:func:`compress_weight_words`).  Execution is bit-exact either way —
    only the allocation accounting (``n_weight_words`` / ``sram_bytes``),
    the weight-address field width, and the engine's replay route change;
    the SRAM fit is then checked against the compressed allocation.
    """
    if len(weights) > spec.n_cores:
        raise MappingError(f"model has {len(weights)} layers but "
                           f"{spec.name} has {spec.n_cores} cores")
    if isinstance(quant_bits, (list, tuple)):
        if len(quant_bits) != len(weights):
            raise ValueError(
                f"quant_bits has {len(quant_bits)} entries for "
                f"{len(weights)} layers")
        default_bits = [check_bits(int(b)) for b in quant_bits]
    else:
        default_bits = [check_bits(int(quant_bits))] * len(weights)
    layers = []
    prev: LayerSpec | None = None
    for li, layer_in in enumerate(weights):
        ls = as_layer_spec(layer_in)
        if prev is not None and ls.n_src != prev.n_dest:
            raise ValueError(
                f"layer {li} expects {ls.n_src} inputs but layer {li-1} "
                f"produces {prev.n_dest}")
        prev = ls
        # spec-pinned bit-width wins over the map_model default(s)
        bits = check_bits(ls.bits) if ls.bits is not None else default_bits[li]
        # quantize the STORED tensor (kernel for conv, matrix for dense) so
        # synapses sharing an SRAM word carry identical dequantized values
        stored = np.asarray(ls.stored_weights)
        qt = quantize_symmetric(stored, bits=bits)
        scale = float(np.asarray(qt.scale))
        ls_q = ls.with_stored(np.asarray(qt.dequantize()) * (stored != 0))
        ls_q = dataclasses.replace(ls_q, bits=bits)
        nz_bytes = ls_q.unique_weight_bytes   # words priced at `bits` wide
        # necessary condition, checked before the (expensive) ILP; the
        # sufficient physical-allocation check follows the rounds loop.
        # (Skipped under compression: value dedup can fit a layer whose
        # unique-byte count alone overflows the budget.)
        if not compress and nz_bytes > spec.weight_mem_bytes:
            raise MappingError(f"layer {li}: {nz_bytes} B of weights > "
                               f"{spec.weight_mem_bytes} B SRAM")
        w_q = np.asarray(ls_q.unroll())
        share = ls_q.share_ids()
        n_src, n_dest = ls_q.n_src, ls_q.n_dest
        # multi-round ILP: solve, peel off assigned neurons, re-solve on the
        # remainder (capacitor reassignment, §III-D)
        remaining = np.arange(n_dest)
        rounds: list[MappedRound] = []
        while len(remaining):
            w_sub = w_q[:, remaining]
            prob = MappingProblem.from_weights(w_sub, spec.n_engines,
                                               spec.n_caps, fanout=fanout)
            sol = solve_mapping(prob, method=method)
            sol.check(prob)
            if sol.n_assigned == 0:
                raise MappingError(
                    f"layer {li}: ILP cannot assign any of the remaining "
                    f"{len(remaining)} neurons (fan-out too tight)")
            tables = build_event_memories(
                w_sub, sol, spec.n_engines, spec.n_caps,
                share_ids=None if share is None else share[:, remaining],
                dedup=compress, word_bits=bits)
            rounds.append(MappedRound(neuron_ids=remaining.copy(),
                                      mapping=sol, tables=tables))
            remaining = remaining[sol.engine < 0]
        layers.append(MappedLayer(w_q=w_q, rounds=rounds,
                                  n_src=n_src, n_dest=n_dest,
                                  layer_spec=ls_q, weight_bytes=nz_bytes,
                                  bits=bits, scale=scale))
    weight_dict = None
    compression = None
    if compress:
        compression = compress_weight_words(
            [r.tables for layer in layers for r in layer.rounds])
        weight_dict = layers[0].rounds[0].tables.weight_dict if layers else None
    for li, layer in enumerate(layers):
        # the hardware-fit guarantee: words PHYSICALLY allocated, priced at
        # the layer's word width.  A shared tap is stored once per engine per
        # round that references it (each engine's A-SYN slice is private), so
        # this exceeds weight_bytes for conv; for dense it is the
        # assigned-synapse count.  Compressed: n_weight_words counts only
        # words newly contributed to the shared dictionary, so the budget
        # buys strictly bigger models.
        n_words = sum(r.tables.n_weight_words for r in layer.rounds)
        layer.sram_bytes = -(-n_words * layer.bits // 8)
        if layer.sram_bytes > spec.weight_mem_bytes:
            raise MappingError(
                f"layer {li}: mapping stores {layer.sram_bytes} B across "
                f"{len(layer.rounds)} round(s) > {spec.weight_mem_bytes} B "
                f"SRAM ({layer.weight_bytes} B unique)")
    return MappedModel(spec=spec, layers=layers, lif=lif,
                       weight_dict=weight_dict, compression=compression)


@dataclasses.dataclass
class RunResult:
    out_spikes: np.ndarray                 # [T, n_out]
    per_layer_stats: list[DispatchStats]
    per_layer_util: list[np.ndarray]       # MEM_S&N utilization per step
    energy: EnergyReport
    overflow: list[np.ndarray] = dataclasses.field(default_factory=list)
    # events dropped by the finite MEM_E depth, per layer per step (all
    # zeros when run() was not given ``max_events``)


def lif_rollout_np(currents: np.ndarray, p: LIFParams) -> np.ndarray:
    """Discrete-time LIF over ``currents[T, n]`` (numpy, cycle-accurate twin
    semantics): integrate, compare, hard-reset.  Shared by :func:`run`,
    :func:`reference_forward`, and the batched engine's oracle tests."""
    v = np.zeros(currents.shape[1:], dtype=np.float32)
    out = np.zeros_like(currents)
    for t in range(currents.shape[0]):
        v = p.beta * v + currents[t]
        fired = v >= p.threshold
        out[t] = fired.astype(np.float32)
        v = np.where(fired, p.v_reset, v)
    return out


def run(model: MappedModel, in_spikes: np.ndarray,
        sn_capacity_rows: int | None = None,
        frame_cycles: int | None = FRAME_CYCLES,
        max_events: int | None = None) -> RunResult:
    """Execute a spike train [T, n_in] through the MX-NEURACORE chain.
    Rounds within a layer execute sequentially (their cycles add); their
    currents target disjoint neuron subsets.

    ``frame_cycles`` has :func:`repro.core.energy.energy_model`'s signature:
    it defaults to the calibrated sensor frame period and ``None`` selects
    throughput mode (no idle between frames).

    ``max_events`` caps the per-step MEM_E FIFO depth on every core:
    excess events are dropped lowest-priority-last (ascending source index
    kept first) *before* dispatch, so the loss propagates through the LIF
    into every downstream layer — the same semantics as
    ``run_batched(max_events=...)``, tested equivalent.
    """
    p = model.lif
    spikes = np.asarray(in_spikes, dtype=np.float32)
    stats_all, util_all, drop_all = [], [], []
    for layer in model.layers:
        t_steps = spikes.shape[0]
        currents = np.zeros((t_steps, layer.n_dest), dtype=np.float32)
        agg_stats = None
        total_rows = sum(r.tables.n_rows for r in layer.rounds)
        util = np.zeros(t_steps)
        for rnd in layer.rounds:
            cur_sub, stats = dispatch_simulate(rnd.tables, spikes,
                                               len(rnd.neuron_ids),
                                               max_events=max_events)
            assigned = rnd.mapping.engine >= 0
            currents[:, rnd.neuron_ids[assigned]] += cur_sub[:, assigned]
            agg_stats = stats if agg_stats is None else agg_stats.merge_round(stats)
            cap_rows = sn_capacity_rows or max(total_rows, 1)
            util += mem_sn_utilization(rnd.tables, spikes, cap_rows,
                                       max_events=max_events)
        arrivals = (spikes > 0).sum(axis=1).astype(np.int64)
        depth = arrivals.max(initial=0) if max_events is None else max_events
        drop_all.append(np.maximum(arrivals - depth, 0))
        # discrete-time LIF over the layer's neurons
        out = lif_rollout_np(currents, p)
        util_all.append(util)
        stats_all.append(agg_stats)
        spikes = out
    energy = energy_model(model.spec, stats_all, frame_cycles=frame_cycles,
                          per_core_bits=[l.bits for l in model.layers])
    return RunResult(out_spikes=spikes, per_layer_stats=stats_all,
                     per_layer_util=util_all, energy=energy,
                     overflow=drop_all)


def run_batch(model: MappedModel, in_spikes: np.ndarray,
              sn_capacity_rows: int | None = None,
              frame_cycles: int | None = FRAME_CYCLES,
              max_events: int | None = None) -> list[RunResult]:
    """Batched oracle: :func:`run` over ``in_spikes[B, T, n_in]``, one
    :class:`RunResult` per sample.  Still the per-sample cycle-accurate
    Python walk — this is the reference the equivalence suites compare the
    batched engine against, not a fast path."""
    spikes = np.asarray(in_spikes, dtype=np.float32)
    assert spikes.ndim == 3, f"expected [B, T, n_in], got {spikes.shape}"
    return [run(model, spikes[b], sn_capacity_rows=sn_capacity_rows,
                frame_cycles=frame_cycles, max_events=max_events)
            for b in range(spikes.shape[0])]


def reference_forward(weights: "list[np.ndarray | LayerSpec]", lif: LIFParams,
                      in_spikes: np.ndarray) -> np.ndarray:
    """Pure dense reference: same math, no event machinery (the oracle).
    Accepts the same layer specs as :func:`map_model` — conv layers execute
    as their unrolled synaptic matrices."""
    spikes = np.asarray(in_spikes, dtype=np.float32)
    for layer in weights:
        w = as_layer_spec(layer).unroll()
        currents = spikes @ np.asarray(w, dtype=np.float32)
        spikes = lif_rollout_np(currents, lif)
    return spikes
