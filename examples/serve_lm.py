"""Serving example: prefill + batched decode with KV cache on any assigned
architecture (reduced config), including the SWA ring buffer and — on a
multi-device mesh — sequence-parallel flash-decoding.

  PYTHONPATH=src python examples/serve_lm.py --arch mixtral_8x7b --tokens 32
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.transformer import (transformer_decode_step,
                                      transformer_prefill)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    assert cfg.family in ("dense", "moe", "vlm"), \
        "this example drives the decoder-only serving path"
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0), dtype=jnp.bfloat16)

    total = args.prompt_len + args.tokens
    prompt = jax.random.randint(jax.random.key(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)

    # prefill
    t0 = time.perf_counter()
    logits, cache = jax.jit(
        lambda p, t: transformer_prefill(p, cfg, t))(params, prompt)
    jax.block_until_ready(logits)
    print(f"prefill({args.prompt_len} toks x {args.batch}): "
          f"{(time.perf_counter()-t0)*1e3:.0f} ms")

    # grow self-cache to the full horizon (ring buffer archs keep window size)
    clen = min(cfg.window, total) if cfg.window else total
    pad = clen - cache["k"].shape[3]
    if pad > 0:
        cache = {k: jnp.pad(v, ((0, 0),) * 3 + ((0, pad), (0, 0)))
                 for k, v in cache.items()}
    elif pad < 0:
        cache = {k: v[:, :, :, :clen] for k, v in cache.items()}

    decode = jax.jit(lambda p, c, t, pos: transformer_decode_step(
        p, cfg, c, t, pos))
    toks = jnp.argmax(logits, axis=-1)
    out = [toks]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, cache = decode(params, cache, toks, pos)
        toks = jnp.argmax(logits, axis=-1)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    seq = np.stack([np.asarray(t) for t in out], axis=1)
    print(f"decoded {args.tokens-1} steps x {args.batch} seqs in "
          f"{dt*1e3:.0f} ms ({dt/(args.tokens-1)*1e3:.1f} ms/step)")
    print(f"sample continuation (batch 0): {seq[0][:16].tolist()}")


if __name__ == "__main__":
    main()
