"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; tests and benches see the 1 real CPU device.

Topology mapping (TPU v5e): ``model`` is the innermost axis -> ICI-contiguous
(TP collectives at full link bandwidth); ``data`` spans the pod's other ICI
dim (FSDP all-gathers); ``pod`` crosses DCN (gradient all-reduce only).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests, elastic restart experiments)."""
    return jax.make_mesh(shape, axes)


def host_device_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    n_data = min(n_data, n)
    n_model = max(min(n_model, n // n_data), 1)
    return jax.make_mesh((n_data, n_model), ("data", "model"))
