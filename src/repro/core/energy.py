"""Analytical energy/performance model of MENAGE (paper §IV-B, Table II).

No silicon in this container: HSpice/Design-Compiler numbers enter as model
constants, and the model is calibrated so the two paper design points land at
their reported efficiencies:

  Accel_1 (4 cores, M=10 A-NEURON x N=16 virt, 400 KB/core, N-MNIST)     -> 3.4 TOPS/W
  Accel_2 (5 cores, M=20 A-NEURON x N=32 virt,  20 MB/core, CIFAR10-DVS) -> 12.1 TOPS/W

Anchored constants from the paper:
  * A-NEURON power 97 nW, delay 6.72 ns  (=> ~0.65 fJ per neuron update)
  * system clock 103.2 MHz
  * 1 synaptic MAC = 2 ops (TOPS counting convention)

Free (calibrated) constants, documented in EXPERIMENTS.md:
  * E_MAC        — dynamic energy per synaptic MAC through the A-SYN C2C
                   ladder + SRAM weight read (charge-domain MAC @ 90 nm)
  * E_CTRL_ROW   — controller energy per MEM_S&N row dispatch (digital)
  * P_LEAK_MB    — SRAM leakage per MB (dominates Accel_2's big 20 MB arrays)
  * P_CTRL       — per-core controller static+clock power

The *shape* of the model (utilization-dependent efficiency: higher spike
activity amortizes static power, which is why the bigger Accel_2 running the
busier CIFAR10-DVS wins) is the paper's qualitative story; the constants are
fit to Table II.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.memories import DispatchStats

# ---- anchored constants (paper §IV-B) -------------------------------------
P_ANEURON_W = 97e-9          # 97 nW per active A-NEURON
T_ANEURON_S = 6.72e-9        # A-NEURON delay
F_CLK_HZ = 103.2e6           # system clock
OPS_PER_MAC = 2

# ---- calibrated constants (fit to Table II, see benchmarks/energy.py) -----
E_MAC_J = 30e-15             # per-MAC dynamic energy (A-SYN C2C + SRAM read)
E_CTRL_ROW_J = 200e-15       # per-MEM_S&N-row controller dispatch energy
P_LEAK_PER_MB_W = 0.0        # folded into P_CTRL_CORE_W by calibration
P_CTRL_CORE_W = 39.4e-6      # per-core controller static + clock tree
FRAME_CYCLES = 4700          # sensor frame period (~45.5 us @ 103.2 MHz);
                             # solved so Accel_1/Accel_2 land on Table II


@dataclasses.dataclass(frozen=True)
class AcceleratorSpec:
    """A MENAGE design point (paper §IV-A)."""

    name: str
    n_cores: int              # MX-NEURACOREs (chained, one per layer)
    n_engines: int            # M  A-NEURONs per core
    n_caps: int               # N  virtual neurons per A-NEURON
    weight_mem_bytes: int     # per-core weight memory

    @property
    def total_mem_mb(self) -> float:
        return self.n_cores * self.weight_mem_bytes / 2**20

    @property
    def peak_ops_per_s(self) -> float:
        """All engines doing one MAC per clock."""
        return self.n_cores * self.n_engines * F_CLK_HZ * OPS_PER_MAC


ACCEL_1 = AcceleratorSpec("Accel1", n_cores=4, n_engines=10, n_caps=16,
                          weight_mem_bytes=400 * 1024)
ACCEL_2 = AcceleratorSpec("Accel2", n_cores=5, n_engines=20, n_caps=32,
                          weight_mem_bytes=20 * 1024 * 1024)


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    name: str
    total_ops: int
    wall_time_s: float
    dynamic_j: float
    static_j: float
    tops_per_w: float
    utilization: float
    breakdown: dict


def energy_model(spec: AcceleratorSpec,
                 per_core_stats: list[DispatchStats],
                 frame_cycles: int | None = FRAME_CYCLES,
                 per_core_bits: "list[int] | None" = None) -> EnergyReport:
    """Aggregate per-core dispatch statistics into Table-II-style numbers.

    per_core_stats: one DispatchStats per MX-NEURACORE (layer).  Cores run
    pipelined; wall time is set by the slowest core's cycle count.

    ``frame_cycles`` models real-time event-driven edge operation: the
    sensor delivers one spike frame every ``frame_cycles`` clock cycles, so
    a core that finishes dispatching early IDLES (static power still burns)
    until the next frame.  This is what makes the sparse N-MNIST workload
    less efficient than the busy CIFAR10-DVS one on the *larger* Accel_2 —
    the paper's Table II contrast.  ``None`` = throughput mode (no idle).

    ``per_core_bits`` gives each core's stored weight bit-width (one entry
    per DispatchStats; ``None`` = all 8-bit).  Only the C2C-ladder MAC
    energy scales with it: a ``bits``-wide sign-magnitude word switches
    ``bits`` ladder capacitors + SRAM bitlines per MAC, so E_MAC scales
    ~``bits/8`` while controller row dispatch (digital, word-width-blind)
    and A-NEURON integration are unchanged.  This is the lever behind the
    paper's sub-byte TOPS/W headline.
    """
    assert len(per_core_stats) <= spec.n_cores
    if per_core_bits is not None and len(per_core_bits) != len(per_core_stats):
        raise ValueError(
            f"per_core_bits has {len(per_core_bits)} entries for "
            f"{len(per_core_stats)} cores")
    total_macs = sum(int(s.engine_ops.sum()) for s in per_core_stats)
    total_rows = sum(int(s.rows_touched.sum()) for s in per_core_stats)
    total_ops = total_macs * OPS_PER_MAC
    if frame_cycles is None:
        slowest_cycles = max(int(s.cycles.sum()) for s in per_core_stats)
    else:
        # per time step: max(dispatch cycles, frame period) on the slowest core
        slowest_cycles = max(
            int(np.maximum(s.cycles, frame_cycles).sum())
            for s in per_core_stats)
    wall_time = max(slowest_cycles, 1) / F_CLK_HZ

    if per_core_bits is None or all(b == 8 for b in per_core_bits):
        # uniform 8-bit: single product, bit-identical to the legacy model
        e_mac = total_macs * E_MAC_J
    else:
        e_mac = sum(int(s.engine_ops.sum()) * E_MAC_J * (b / 8)
                    for s, b in zip(per_core_stats, per_core_bits))
    e_rows = total_rows * E_CTRL_ROW_J
    # A-NEURON active energy: one update per MAC landing on it
    e_neuron = total_macs * P_ANEURON_W * T_ANEURON_S
    dynamic = e_mac + e_rows + e_neuron

    p_static = (spec.n_cores * P_CTRL_CORE_W
                + spec.total_mem_mb * P_LEAK_PER_MB_W)
    static = p_static * wall_time

    total_j = dynamic + static
    tops_w = (total_ops / total_j) / 1e12 if total_j > 0 else 0.0
    peak_ops = spec.peak_ops_per_s * wall_time
    return EnergyReport(
        name=spec.name,
        total_ops=total_ops,
        wall_time_s=wall_time,
        dynamic_j=dynamic,
        static_j=static,
        tops_per_w=tops_w,
        utilization=total_ops / max(peak_ops, 1e-30),
        breakdown={
            "E_mac_J": e_mac,
            "E_ctrl_rows_J": e_rows,
            "E_aneuron_J": e_neuron,
            "E_static_J": static,
            "P_static_W": p_static,
        },
    )
