"""Table I reproduction: accuracy before/after L1 prune + 8-bit PTQ.

Synthetic stand-in datasets (DESIGN.md §5): the validated claim is the
*flow* — pruning+quantization costs <~1 accuracy point (paper: 94.75->94.1
on N-MNIST, 65.38->65.03 on CIFAR10-DVS) — not the absolute numbers.
Reduced train budgets keep this CPU-feasible; --full trains longer.

``--model conv`` adds the spiking-CNN path on the same CIFAR10-DVS-like
stream and prints the MLP-vs-CNN accuracy split (the general-platform claim
of §III); ``--model both`` runs mlp then conv and prints the delta.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prune import prune_pytree, sparsity
from repro.core.quant import quantize_pytree
from repro.data.events import EventDatasetConfig, event_batches, synthetic_event_dataset
from repro.engine import SNNTrainConfig, model_for, train_snn_model
from repro.snn.conv import conv_snn_forward
from repro.snn.mlp import SNNConfig, snn_forward


def _accuracy(params, snn, spikes, labels, batch=64, forward=snn_forward):
    correct = 0
    for i in range(0, len(labels), batch):
        counts, _ = forward(
            params, jnp.asarray(spikes[i:i + batch].swapaxes(0, 1)), snn)
        correct += int((np.asarray(counts).argmax(-1)
                        == labels[i:i + batch]).sum())
    return correct / len(labels)


def run_one(tag, data_cfg, snn_cfg, steps, prune_amt=0.5, n_per_class=24):
    key = jax.random.key(0)
    spikes, labels = synthetic_event_dataset(data_cfg, n_per_class, key)
    n_test = len(labels) // 5
    tr_s, tr_l = spikes[n_test:], labels[n_test:]
    te_s, te_l = spikes[:n_test], labels[:n_test]
    it = event_batches(tr_s, tr_l, batch=32)
    params, _ = train_snn_model(model_for(snn_cfg), snn_cfg, it,
                                SNNTrainConfig(steps=steps, lr=1e-3,
                                               log_every=1000),
                                key=jax.random.key(1), log_fn=lambda s: None)
    acc0 = _accuracy(params, snn_cfg, te_s, te_l)
    pruned, _ = prune_pytree(params, prune_amt)
    _, dq = quantize_pytree(pruned)
    acc1 = _accuracy(dq, snn_cfg, te_s, te_l)
    print(f"accuracy/{tag},before={acc0:.4f},after_prune_quant={acc1:.4f},"
          f"drop={acc0-acc1:.4f},sparsity={sparsity(pruned):.2f}")
    return acc0, acc1


def run_one_conv(tag, data_cfg, conv_cfg, steps, prune_amt=0.5,
                 n_per_class=16):
    """Conv twin of :func:`run_one`: same flow, spiking-CNN model."""
    key = jax.random.key(0)
    spikes, labels = synthetic_event_dataset(data_cfg, n_per_class, key)
    n_test = len(labels) // 5
    it = event_batches(spikes[n_test:], labels[n_test:], batch=32)
    params, _ = train_snn_model(model_for(conv_cfg), conv_cfg, it,
                                SNNTrainConfig(steps=steps, lr=1e-3,
                                               log_every=1000),
                                key=jax.random.key(1), log_fn=lambda s: None)
    te_s, te_l = spikes[:n_test], labels[:n_test]
    acc0 = _accuracy(params, conv_cfg, te_s, te_l, forward=conv_snn_forward)
    pruned, _ = prune_pytree(params, prune_amt)
    _, dq = quantize_pytree(pruned)
    acc1 = _accuracy(dq, conv_cfg, te_s, te_l, forward=conv_snn_forward)
    print(f"accuracy/{tag},before={acc0:.4f},after_prune_quant={acc1:.4f},"
          f"drop={acc0-acc1:.4f},sparsity={sparsity(pruned):.2f}")
    return acc0, acc1


def main(full: bool = False, model: str = "mlp"):
    results = {}
    if model in ("mlp", "both"):
        # N-MNIST-like: the paper's 200/100/40/10 MLP on 34x34x2 input
        nm_data = EventDatasetConfig.nmnist_like()
        nm_snn = SNNConfig.nmnist()
        run_one("nmnist", nm_data, nm_snn, steps=400 if full else 120)
        # CIFAR10-DVS-like: 1000/500/200/100/10 on spatially-reduced input
        cf_data = EventDatasetConfig.cifar10_dvs_like()
        cf_snn = SNNConfig(layer_sizes=(cf_data.n_in, 1000, 500, 200, 100, 10),
                           num_steps=25)
        results["mlp"] = run_one("cifar10dvs", cf_data, cf_snn,
                                 steps=200 if full else 60, n_per_class=16)
    if model in ("conv", "both"):
        # spiking CNN on the same (further downsampled) CIFAR10-DVS stream —
        # the canonical config shared with benchmarks/energy.py
        from repro.configs.menage_paper import CIFAR_CONV, CIFAR_CONV_DATA
        results["conv"] = run_one_conv("cifar10dvs-conv", CIFAR_CONV_DATA,
                                       CIFAR_CONV,
                                       steps=200 if full else 60)
    if model == "both":
        print(f"accuracy/split,mlp_after={results['mlp'][1]:.4f},"
              f"conv_after={results['conv'][1]:.4f},"
              f"conv_minus_mlp={results['conv'][1]-results['mlp'][1]:.4f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--model", choices=("mlp", "conv", "both"), default="mlp")
    args = ap.parse_args()
    main(full=args.full, model=args.model)
