"""ILP mapping (paper §III-D, eqs. 3-7): exactness + constraint compliance."""

import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.mapping import (MappingError, MappingProblem, autotune_grid,
                                candidate_grids, max_flow_assignment,
                                solve_mapping, solve_mapping_bruteforce,
                                solve_mapping_full_ilp, solve_mapping_greedy,
                                solve_mapping_reduced_ilp)


def _random_problem(rng, n_src, n_dest, m, n, density, fanout_slack):
    conn = rng.random((n_src, n_dest)) < density
    fanin = conn.sum(axis=1)
    if fanout_slack:
        fanout = np.maximum(fanin, 1)
    else:
        fanout = np.maximum((fanin * rng.uniform(0.3, 1.0, n_src)).astype(int), 1)
    return MappingProblem(n_dest=n_dest, n_engines=m, n_caps=n,
                          conn=conn, fanout=fanout)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_full_equals_reduced_equals_bruteforce(seed):
    rng = np.random.default_rng(seed)
    p = _random_problem(rng, n_src=4, n_dest=4, m=2, n=2,
                        density=0.6, fanout_slack=False)
    s_full = solve_mapping_full_ilp(p)
    s_red = solve_mapping_reduced_ilp(p)
    s_bf = solve_mapping_bruteforce(p)
    s_full.check(p)
    s_red.check(p)
    s_bf.check(p)
    assert s_full.n_assigned == s_bf.n_assigned
    assert s_red.n_assigned == s_bf.n_assigned


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_maxflow_exact_when_fanout_slack(seed):
    rng = np.random.default_rng(seed)
    p = _random_problem(rng, n_src=6, n_dest=8, m=3, n=2,
                        density=0.5, fanout_slack=True)
    s_mf = max_flow_assignment(p)
    s_ilp = solve_mapping_reduced_ilp(p)
    s_mf.check(p)
    assert s_mf.n_assigned == s_ilp.n_assigned == min(p.n_dest,
                                                      p.n_engines * p.n_caps)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_greedy_feasible_and_bounded(seed):
    rng = np.random.default_rng(seed)
    p = _random_problem(rng, n_src=5, n_dest=6, m=2, n=2,
                        density=0.5, fanout_slack=False)
    s_g = solve_mapping_greedy(p)
    s_g.check(p)                       # always feasible
    s_opt = solve_mapping_reduced_ilp(p)
    assert s_g.n_assigned <= s_opt.n_assigned


def test_capacity_binds():
    """More neurons than M*N capacitors -> exactly M*N assigned."""
    rng = np.random.default_rng(1)
    conn = np.ones((3, 10), dtype=bool)
    p = MappingProblem(n_dest=10, n_engines=2, n_caps=2, conn=conn,
                       fanout=np.full(3, 10))
    s = solve_mapping(p, method="reduced_ilp")
    s.check(p)
    assert s.n_assigned == 4
    assert s.objective == 6


def test_fanout_binds():
    """A source with fanout limit 2 caps its destinations' assignments."""
    conn = np.ones((1, 5), dtype=bool)
    p = MappingProblem(n_dest=5, n_engines=5, n_caps=5, conn=conn,
                       fanout=np.asarray([2]))
    s = solve_mapping(p, method="full_ilp")
    s.check(p)
    assert s.n_assigned == 2


def test_auto_method_selects_and_solves():
    rng = np.random.default_rng(2)
    w = rng.normal(size=(20, 30))
    w[np.abs(w) < 0.8] = 0
    p = MappingProblem.from_weights(w, n_engines=4, n_caps=8)
    s = solve_mapping(p)
    s.check(p)
    assert s.n_assigned == 30  # capacity 32 >= 30, fanout slack


def test_ilp_load_balances_rows():
    """The ILP objective (max assignments) with capacity constraints spreads
    neurons across engines — dispatch rows (B_i) stay near optimal."""
    rng = np.random.default_rng(3)
    w = (rng.random((8, 16)) < 0.9).astype(float)
    p = MappingProblem.from_weights(w, n_engines=4, n_caps=4)
    s = solve_mapping(p, method="reduced_ilp")
    s.check(p)
    loads = np.bincount(s.engine[s.engine >= 0], minlength=4)
    assert loads.max() <= 4
    assert s.n_assigned == 16


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_cross_solver_property(seed):
    """Every solver on the same random problem: greedy, reduced ILP and
    (under fan-out slack) max-flow all pass ``check``, and the exact ILP
    never assigns fewer neurons than the greedy heuristic."""
    rng = np.random.default_rng(seed)
    slack = bool(seed % 2)
    p = _random_problem(rng, n_src=5, n_dest=7, m=2, n=3,
                        density=0.5, fanout_slack=slack)
    s_g = solve_mapping_greedy(p)
    s_ilp = solve_mapping_reduced_ilp(p)
    s_g.check(p)
    s_ilp.check(p)
    assert s_g.n_assigned <= s_ilp.n_assigned
    if slack:
        s_mf = max_flow_assignment(p)
        s_mf.check(p)
        assert s_mf.n_assigned == s_ilp.n_assigned


def test_check_raises_mapping_error_not_assert():
    """Regression: solution validation used ``assert`` — stripped under
    ``python -O``, so a corrupt mapping would sail into the memory builders.
    ``check`` must raise a real :class:`MappingError`."""
    conn = np.ones((2, 4), dtype=bool)
    p = MappingProblem(n_dest=4, n_engines=2, n_caps=2, conn=conn,
                       fanout=np.full(2, 4))
    s = solve_mapping(p, method="reduced_ilp")
    s.check(p)
    # same capacitor twice on one engine -> capacitor-reuse violation
    bad = dataclasses.replace(
        s, capacitor=np.where(s.engine >= 0,
                              np.zeros_like(s.capacitor), s.capacitor))
    with pytest.raises(MappingError):
        bad.check(p)
    # lie about the assignment count
    bad2 = dataclasses.replace(s, n_assigned=s.n_assigned + 1)
    with pytest.raises(MappingError):
        bad2.check(p)


def test_maxflow_without_slack_raises():
    rng = np.random.default_rng(7)
    p = _random_problem(rng, n_src=4, n_dest=6, m=2, n=2,
                        density=0.6, fanout_slack=False)
    with pytest.raises(MappingError, match="slack"):
        max_flow_assignment(p)


# ------------------------------------------------------------- autotuner

def test_candidate_grids_same_capacity_and_default():
    from repro.core.energy import ACCEL_2
    grids = candidate_grids(ACCEL_2)
    cap = ACCEL_2.n_engines * ACCEL_2.n_caps
    assert (ACCEL_2.n_engines, ACCEL_2.n_caps) in grids
    assert all(m * n == cap and m > 1 and n > 1 for m, n in grids)
    assert len(set(grids)) == len(grids)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_autotune_never_regresses_rounds(seed):
    """The winning grid's rounds-per-timestep is never worse than the
    default grid's (the default is always a scored candidate)."""
    from repro.core.energy import AcceleratorSpec
    rng = np.random.default_rng(seed)
    spec = AcceleratorSpec("tune", n_cores=2, n_engines=4, n_caps=8,
                           weight_mem_bytes=1 << 20)
    n_mid = int(rng.integers(8, 60))
    w1 = rng.normal(size=(10, n_mid)) * (rng.random((10, n_mid)) < 0.5)
    w2 = rng.normal(size=(n_mid, 6)) * (rng.random((n_mid, 6)) < 0.6)
    res = autotune_grid([w1, w2], spec)
    assert res.best.feasible
    assert res.best.rounds_per_timestep <= res.default.rounds_per_timestep
    assert res.best.key <= res.default.key
    # scoreboard is sorted best-first and includes every candidate grid
    assert [s.key for s in res.scores] == sorted(s.key for s in res.scores)
    got = {(s.n_engines, s.n_caps) for s in res.scores}
    assert (spec.n_engines, spec.n_caps) in got


def test_autotune_infeasible_everywhere_raises():
    from repro.core.energy import AcceleratorSpec
    rng = np.random.default_rng(0)
    tiny = AcceleratorSpec("tiny", n_cores=1, n_engines=4, n_caps=4,
                           weight_mem_bytes=2)
    w = rng.normal(size=(12, 12))
    with pytest.raises(MappingError, match="no feasible grid"):
        autotune_grid([w], tiny)
