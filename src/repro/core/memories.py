"""Memory-based event control (paper §III-C, Fig. 4).

Per MX-NEURACORE, three memories steer each received event (a source-neuron
index) to the right A-SYN / A-NEURON engines:

  MEM_E    — event FIFO; each entry is a source-neuron index N_i.
  MEM_E2A  — row per source neuron: (B_i, A_i) = (#rows in MEM_S&N for N_i,
             start address of those rows).
  MEM_S&N  — row = one dispatch *cycle* worth of work: for each of the M
             A-NEURON engines, (NI_j valid bit, virtual-neuron index k_j of
             width log2(N), weight address into the A-SYN SRAM).  A source
             connected to more destinations than one row can carry (at most
             one per engine per cycle — each engine integrates one synapse
             per clock) occupies B_i consecutive rows.

The ILP mapping determines which engine/capacitor serves each destination
neuron; the row count B_i for source i is therefore
``max_j |{dest of i assigned to engine j}|`` — the ILP's load-balancing
directly minimizes dispatch cycles.

``dispatch_simulate`` is the cycle-level model: it reproduces the paper's
MEM_S&N-utilization-vs-time-step curves (Figs 6-7), counts controller cycles
and engine operations for the energy model, and — crucially — is proven
equivalent to the dense reference computation (spikes @ W) in tests.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.mapping.ilp import MappingProblem, MappingSolution


@dataclasses.dataclass
class MemTables:
    """Bit-level content of the three control memories + A-SYN weight SRAM."""

    # MEM_E2A: per source neuron
    e2a_count: np.ndarray   # B_i  — rows in MEM_S&N
    e2a_addr: np.ndarray    # A_i  — start row
    # MEM_S&N: R rows x M engines
    sn_valid: np.ndarray    # bool [R, M]   — NI_j
    sn_virt: np.ndarray     # int  [R, M]   — virtual-neuron (capacitor) index
    sn_waddr: np.ndarray    # int  [R, M]   — weight address in A-SYN SRAM
    # A-SYN weight SRAM (per engine, addressed by sn_waddr)
    weight_mem: np.ndarray  # f32  [M, W]
    # bookkeeping
    n_engines: int
    n_caps: int
    mapping: MappingSolution
    n_weight_words: int = 0  # A-SYN words actually allocated (across engines);
                             # after compress_weight_words: words this table
                             # newly contributes to the shared dictionary
    word_bits: int = 8       # stored A-SYN word width (sign-magnitude C2C
                             # ladder words; 2/4/8) — prices SRAM bytes
    # physical per-engine word slots (len of each engine's allocation;
    # invariant under cross-layer compression — pointer-table entries)
    engine_words: np.ndarray | None = None          # int [M]
    # cross-round/cross-layer synapse compression (arXiv:2112.07019):
    # weight_ptr[j, a] indexes the model-shared weight_dict; set by
    # compress_weight_words, and always satisfies
    # weight_mem[j, a] == weight_dict[weight_ptr[j, a]] on allocated slots
    weight_ptr: np.ndarray | None = None            # i32 [M, W]
    weight_dict: np.ndarray | None = None           # f32 [K], shared object

    @property
    def n_rows(self) -> int:
        return self.sn_valid.shape[0]

    def bits_per_row(self) -> int:
        """Row width per Fig. 4: M valid bits + M*log2(N) virtual indices +
        M*ceil(log2(W)) weight addresses."""
        m = self.n_engines
        virt_bits = max(int(np.ceil(np.log2(max(self.n_caps, 2)))), 1)
        waddr_bits = max(int(np.ceil(np.log2(max(self.weight_mem.shape[1], 2)))), 1)
        return m * (1 + virt_bits + waddr_bits)

    def inverse_map(self) -> np.ndarray:
        """(engine, capacitor) -> destination-neuron index (-1 = free)."""
        sol = self.mapping
        inv = -np.ones((self.n_engines, self.n_caps), dtype=np.int64)
        for i in range(len(sol.engine)):
            if sol.engine[i] >= 0:
                inv[sol.engine[i], sol.capacitor[i]] = i
        return inv

    def dense_weights(self, n_dest: int) -> np.ndarray:
        """Replay the tables into a dense ``[n_src, n_dest]`` matrix: the
        effective synaptic weight each source event deposits on each assigned
        destination.  This is what the batched engine executes — derived from
        the memory *content*, not from the original weight matrix, so table
        corruption shows up as an equivalence failure."""
        inv = self.inverse_map()
        n_src = len(self.e2a_count)
        w = np.zeros((n_src, n_dest), dtype=np.float32)
        for m in range(n_src):
            a, b = int(self.e2a_addr[m]), int(self.e2a_count[m])
            for r in range(a, a + b):
                for j in np.nonzero(self.sn_valid[r])[0]:
                    i = int(inv[j, int(self.sn_virt[r, j])])
                    w[m, i] += self.weight_mem[j, int(self.sn_waddr[r, j])]
        return w

    def _replay_indices(self):
        """Shared COO replay walk: ``(src, dest_local, engine, waddr)`` per
        stored synapse, in :meth:`dense_weights` accumulation order."""
        used = self.e2a_count.sum()
        if used == 0:
            z = np.zeros(0, dtype=np.int64)
            return z, z, z, z
        # build_event_memories lays rows out contiguously in source order
        starts = np.concatenate([[0], np.cumsum(self.e2a_count)[:-1]])
        if not (self.e2a_addr == starts).all():
            raise ValueError(
                "replay_coo requires source-ordered contiguous MEM_S&N rows")
        row_src = np.repeat(np.arange(len(self.e2a_count)), self.e2a_count)
        rr, jj = np.nonzero(self.sn_valid[: len(row_src)])
        inv = self.inverse_map()
        dest = inv[jj, self.sn_virt[rr, jj]]
        return row_src[rr], dest, jj, self.sn_waddr[rr, jj]

    def replay_coo(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Replay the tables into COO triplets ``(src, dest_local, weight)``
        — one per stored synapse — in :meth:`dense_weights` accumulation
        order.  O(rows x engines) work and memory: for shared-weight (conv)
        layers this is the replay path that never materializes the
        ``n_src x n_dest`` dense matrix.  Like ``dense_weights`` it is
        derived from the memory *content*, so table corruption still shows
        up as an equivalence failure."""
        src, dest, jj, waddr = self._replay_indices()
        vals = self.weight_mem[jj, waddr]
        return src, dest, vals.astype(np.float32)

    def replay_coo_ptr(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """:meth:`replay_coo` through the compression indirection:
        ``(src, dest_local, widx)`` where ``widx`` indexes the model-shared
        :attr:`weight_dict` — ``weight_dict[widx]`` equals
        ``replay_coo()``'s values bit for bit.  The engine gathers the
        dictionary on device under jit (see
        :func:`repro.engine.batched_run.pack_model`)."""
        if self.weight_ptr is None:
            raise ValueError("tables are not compressed: run "
                             "compress_weight_words first")
        src, dest, jj, waddr = self._replay_indices()
        return src, dest, self.weight_ptr[jj, waddr].astype(np.int64)

    def alloc_words(self) -> np.ndarray:
        """Per-engine allocated A-SYN word-slot counts: recorded by
        :func:`build_event_memories`; derived from the referenced addresses
        for hand-built tables."""
        if self.engine_words is not None:
            return np.asarray(self.engine_words, dtype=np.int64)
        counts = np.zeros(self.n_engines, dtype=np.int64)
        rr, jj = np.nonzero(self.sn_valid)
        np.maximum.at(counts, jj, self.sn_waddr[rr, jj] + 1)
        return counts

    def to_jax(self, pad_src: int | None = None,
               pad_rows: int | None = None) -> "PackedTables":
        """Pack the three control memories into padded int32 device arrays.

        ``pad_src`` / ``pad_rows`` extend MEM_E2A / MEM_S&N to a static size
        so tables from different rounds or layers can be stacked; padding
        sources have B_i = 0 and padding rows have no valid entries.
        """
        import jax.numpy as jnp

        s = len(self.e2a_count) if pad_src is None else int(pad_src)
        r = self.n_rows if pad_rows is None else int(pad_rows)
        assert s >= len(self.e2a_count) and r >= self.n_rows

        def pad1(x, n):
            return np.pad(np.asarray(x, dtype=np.int32), (0, n - len(x)))

        def pad2(x, n):
            x = np.asarray(x, dtype=np.int32)
            return np.pad(x, ((0, n - x.shape[0]), (0, 0)))

        return PackedTables(
            e2a_count=jnp.asarray(pad1(self.e2a_count, s)),
            e2a_addr=jnp.asarray(pad1(self.e2a_addr, s)),
            sn_valid=jnp.asarray(pad2(self.sn_valid, r)),
            sn_virt=jnp.asarray(pad2(self.sn_virt, r)),
            sn_waddr=jnp.asarray(pad2(self.sn_waddr, r)),
            weight_mem=jnp.asarray(self.weight_mem),
            n_engines=self.n_engines,
            n_caps=self.n_caps,
            n_rows=self.n_rows,
            row_bits=self.bits_per_row(),
        )


@dataclasses.dataclass
class PackedTables:
    """:class:`MemTables` as a JAX pytree: padded int32 arrays ready to ship
    through ``jit``/``scan``/``shard_map``.  Static table geometry rides in
    the treedef so retracing only happens when the geometry changes."""

    e2a_count: jax.Array    # i32 [S_pad]
    e2a_addr: jax.Array     # i32 [S_pad]
    sn_valid: jax.Array     # i32 [R_pad, M] (0/1)
    sn_virt: jax.Array      # i32 [R_pad, M]
    sn_waddr: jax.Array     # i32 [R_pad, M]
    weight_mem: jax.Array   # f32 [M, W]
    n_engines: int = dataclasses.field(metadata=dict(static=True), default=0)
    n_caps: int = dataclasses.field(metadata=dict(static=True), default=0)
    n_rows: int = dataclasses.field(metadata=dict(static=True), default=0)
    row_bits: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def row_bytes(self) -> int:
        return (self.row_bits + 7) // 8

    def stats_vectors(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-source (rows, cycles, MACs) contributed by one event — the
        dot-product vectors behind the batched :class:`DispatchStats`.
        Cached: the tables are static after packing, so the device-to-host
        pulls happen once, not per ``run_batched`` call."""
        cached = self.__dict__.get("_stats_vectors")
        if cached is None:
            count = np.asarray(self.e2a_count, dtype=np.int64)
            addr = np.asarray(self.e2a_addr, dtype=np.int64)
            valid = np.asarray(self.sn_valid, dtype=np.int64)
            row_ops = valid.sum(axis=1)
            cum = np.concatenate([[0], np.cumsum(row_ops)])
            ops = cum[addr + count] - cum[addr]
            cached = (count, np.maximum(count, 1), ops)
            self.__dict__["_stats_vectors"] = cached
        return cached


jax.tree_util.register_dataclass(
    PackedTables,
    data_fields=["e2a_count", "e2a_addr", "sn_valid", "sn_virt", "sn_waddr",
                 "weight_mem"],
    meta_fields=["n_engines", "n_caps", "n_rows", "row_bits"])


def build_event_memories(w: np.ndarray, sol: MappingSolution,
                         n_engines: int, n_caps: int,
                         share_ids: np.ndarray | None = None,
                         dedup: bool = False,
                         word_bits: int = 8) -> MemTables:
    """Construct MEM_E2A / MEM_S&N / weight SRAM from a pruned weight matrix
    ``w[n_src, n_dest]`` and an ILP mapping solution.

    ``share_ids`` (int64 ``[n_src, n_dest]``, -1 = no synapse) enables the
    shared-weight indirection used for convolutions: synapses carrying the
    same id within one engine point their MEM_S&N weight address at a single
    A-SYN SRAM word (one stored kernel tap, many rows reading it), instead
    of each synapse allocating its own word.  ``None`` keeps the dense
    layout: one SRAM word per synapse, bit-identical to the pre-conv path.

    ``dedup`` generalizes the sharing from taps to *values* (the synapse
    compression of arXiv:2112.07019): any two synapses on the same engine
    whose quantized words are bit-identical share one A-SYN word, whatever
    layer structure produced them.  Replay is unchanged bit for bit — the
    merged words are exactly equal — while ``n_weight_words`` (and the
    weight-address field width, hence MEM_S&N row bytes) shrinks.

    ``word_bits`` records the stored word width (the layer's quantization
    bit-width) so downstream SRAM accounting prices words at their actual
    size instead of a fixed byte.
    """
    n_src, n_dest = w.shape
    e2a_count = np.zeros(n_src, dtype=np.int64)
    e2a_addr = np.zeros(n_src, dtype=np.int64)
    rows_valid, rows_virt, rows_waddr = [], [], []
    # per-engine weight SRAM allocation (next free address per engine)
    w_next = np.zeros(n_engines, dtype=np.int64)
    w_entries: list[list[float]] = [[] for _ in range(n_engines)]
    # per-engine share-id -> allocated SRAM address
    shared_addr: list[dict[int, int]] = [{} for _ in range(n_engines)]
    # per-engine quantized word value -> allocated SRAM address (dedup)
    value_addr: list[dict[float, int]] = [{} for _ in range(n_engines)]

    def alloc(j: int, m: int, i: int) -> int:
        """SRAM address in engine j for synapse (m, i): fresh word unless
        the synapse's share id — or, under ``dedup``, its exact quantized
        value — already has one on this engine."""
        v = float(w[m, i])
        sid = -1 if share_ids is None else int(share_ids[m, i])
        if sid >= 0 and sid in shared_addr[j]:
            addr = shared_addr[j][sid]
            if w_entries[j][addr] != v:
                raise ValueError(
                    f"share id {sid} maps to conflicting weight values "
                    f"({w_entries[j][addr]} vs {v}) on engine {j}")
            return addr
        if dedup and v in value_addr[j]:
            addr = value_addr[j][v]
            if sid >= 0:
                shared_addr[j][sid] = addr
            return addr
        addr = int(w_next[j])
        w_entries[j].append(v)
        w_next[j] += 1
        if sid >= 0:
            shared_addr[j][sid] = addr
        if dedup:
            value_addr[j][v] = addr
        return addr

    for m in range(n_src):
        dests = np.nonzero(w[m])[0]
        dests = dests[sol.engine[dests] >= 0]          # unassigned are dropped
        # group by engine; B_m = max per-engine count
        per_engine: list[list[int]] = [[] for _ in range(n_engines)]
        for i in dests:
            per_engine[sol.engine[i]].append(int(i))
        b = max((len(g) for g in per_engine), default=0)
        e2a_addr[m] = len(rows_valid)
        e2a_count[m] = b
        for r in range(b):
            valid = np.zeros(n_engines, dtype=bool)
            virt = np.zeros(n_engines, dtype=np.int64)
            waddr = np.zeros(n_engines, dtype=np.int64)
            for j in range(n_engines):
                if r < len(per_engine[j]):
                    i = per_engine[j][r]
                    valid[j] = True
                    virt[j] = sol.capacitor[i]
                    waddr[j] = alloc(j, m, i)
            rows_valid.append(valid)
            rows_virt.append(virt)
            rows_waddr.append(waddr)

    wmax = max(int(w_next.max()), 1)
    weight_mem = np.zeros((n_engines, wmax), dtype=np.float32)
    for j in range(n_engines):
        if w_entries[j]:
            weight_mem[j, : len(w_entries[j])] = np.array(w_entries[j], dtype=np.float32)

    r = max(len(rows_valid), 1)
    return MemTables(
        e2a_count=e2a_count,
        e2a_addr=e2a_addr,
        sn_valid=np.array(rows_valid, dtype=bool).reshape(r if rows_valid else 1, n_engines) if rows_valid else np.zeros((1, n_engines), dtype=bool),
        sn_virt=np.array(rows_virt, dtype=np.int64).reshape(-1, n_engines) if rows_virt else np.zeros((1, n_engines), dtype=np.int64),
        sn_waddr=np.array(rows_waddr, dtype=np.int64).reshape(-1, n_engines) if rows_waddr else np.zeros((1, n_engines), dtype=np.int64),
        weight_mem=weight_mem,
        n_engines=n_engines,
        n_caps=n_caps,
        mapping=sol,
        n_weight_words=int(sum(len(e) for e in w_entries)),
        engine_words=w_next.copy(),
        word_bits=int(word_bits),
    )


@dataclasses.dataclass(frozen=True)
class WeightCompression:
    """Accounting for the shared-dictionary synapse compression
    (arXiv:2112.07019 applied to the A-SYN SRAM).

    Physical model: each engine's A-SYN becomes a *pointer table* (one
    ``ptr_bits``-wide entry per allocated word slot) into a single
    chip-shared dictionary of unique quantized words.  Three allocation
    levels are reported:

      synapse_words — one word per stored synapse (no sharing at all; what
                      the dense pre-conv layout allocates)
      slot_words    — per-engine slots after tap/value dedup (= pointer
                      entries; ``build_event_memories`` allocation)
      dict_words    — unique words K in the cross-round/cross-layer shared
                      dictionary
    """

    synapse_words: int
    slot_words: int
    dict_words: int
    ptr_bits: int
    # total bits of the dictionary payload: each unique word is priced at the
    # widest word_bits of the tables that reference it (0 = legacy 8-bit)
    dict_bits_total: int = 0

    @property
    def dict_bytes(self) -> int:
        """Dictionary payload bytes at the stored word widths (legacy
        tables without ``dict_bits_total``: 8-bit words -> 1 byte each)."""
        bits = self.dict_bits_total or self.dict_words * 8
        return (bits + 7) // 8

    @property
    def ptr_bytes(self) -> int:
        return (self.slot_words * self.ptr_bits + 7) // 8

    @property
    def compressed_bytes(self) -> int:
        return self.dict_bytes + self.ptr_bytes

    @property
    def ratio(self) -> float:
        """Word-count compression vs the per-synapse layout."""
        return self.synapse_words / max(self.dict_words, 1)

    def as_dict(self) -> dict:
        return {"synapse_words": self.synapse_words,
                "slot_words": self.slot_words,
                "dict_words": self.dict_words,
                "ptr_bits": self.ptr_bits,
                "dict_bits_total": self.dict_bits_total,
                "dict_bytes": self.dict_bytes,
                "ptr_bytes": self.ptr_bytes,
                "compressed_bytes": self.compressed_bytes,
                "ratio": self.ratio}


def compress_weight_words(tables: "list[MemTables]") -> WeightCompression:
    """Deduplicate identical quantized A-SYN words across engines, rounds,
    and layers behind one shared dictionary.

    Walks the given tables in order (map_model passes every round of every
    layer), assigns each distinct word value a dictionary index at first
    sight, and attaches to each table: ``weight_ptr`` (the per-slot
    indirection) and the shared ``weight_dict`` array.  Each table's
    ``n_weight_words`` becomes the number of words it *newly* contributes —
    so ``sum(n_weight_words) == dict_words`` across the model and a layer
    whose words all appeared earlier in the chain costs zero new words.

    Replay stays bit-exact by construction: ``weight_dict[weight_ptr]``
    reproduces ``weight_mem`` on every allocated slot (tested), and no
    MEM_S&N content changes — only the accounting and the engine's replay
    route (:meth:`MemTables.replay_coo_ptr`) go through the indirection.
    """
    index: dict[float, int] = {}
    values: list[float] = []
    value_bits: list[int] = []
    synapse_words = 0
    slot_words = 0
    new_counts: list[int] = []
    ptrs: list[np.ndarray] = []
    for tb in tables:
        words = tb.alloc_words()
        synapse_words += int(tb.sn_valid.sum())
        slot_words += int(words.sum())
        new = 0
        ptr = np.zeros(tb.weight_mem.shape, dtype=np.int32)
        for j in range(tb.n_engines):
            for a in range(int(words[j])):
                v = float(tb.weight_mem[j, a])
                idx = index.get(v)
                if idx is None:
                    idx = len(values)
                    index[v] = idx
                    values.append(v)
                    value_bits.append(tb.word_bits)
                    new += 1
                else:
                    # a shared word must be readable at the widest precision
                    # any referencing table stores it at
                    value_bits[idx] = max(value_bits[idx], tb.word_bits)
                ptr[j, a] = idx
        new_counts.append(new)
        ptrs.append(ptr)
    weight_dict = np.asarray(values, dtype=np.float32)
    for tb, ptr, new in zip(tables, ptrs, new_counts):
        tb.weight_ptr = ptr
        tb.weight_dict = weight_dict
        tb.n_weight_words = new
    k = max(len(values), 1)
    return WeightCompression(
        synapse_words=synapse_words, slot_words=slot_words,
        dict_words=len(values),
        ptr_bits=max(int(np.ceil(np.log2(max(k, 2)))), 1),
        dict_bits_total=int(sum(value_bits)))


@dataclasses.dataclass
class DispatchStats:
    """Per-time-step statistics from the cycle-level simulator."""

    cycles: np.ndarray          # controller cycles spent dispatching, per step
    rows_touched: np.ndarray    # MEM_S&N rows read, per step (Figs 6-7 signal)
    engine_ops: np.ndarray      # synaptic MACs executed, per step
    events: np.ndarray          # events received, per step
    sn_bytes_touched: np.ndarray  # bytes of MEM_S&N traffic per step
    mem_e_peak: int             # peak MEM_E occupancy observed

    @property
    def total_ops(self) -> int:
        # 1 MAC = 2 ops (mul + add), the TOPS convention used by Table II
        return int(self.engine_ops.sum()) * 2

    @property
    def total_cycles(self) -> int:
        return int(self.cycles.sum())

    def merge_round(self, other: "DispatchStats") -> "DispatchStats":
        """Combine stats of two rounds of the same layer: their dispatch
        cycles/rows/ops add (rounds run sequentially) while the event stream
        is shared, so ``events`` stays and MEM_E peaks take the max."""
        return DispatchStats(
            cycles=self.cycles + other.cycles,
            rows_touched=self.rows_touched + other.rows_touched,
            engine_ops=self.engine_ops + other.engine_ops,
            events=self.events,
            sn_bytes_touched=self.sn_bytes_touched + other.sn_bytes_touched,
            mem_e_peak=max(self.mem_e_peak, other.mem_e_peak))


def dispatch_simulate(tables: MemTables, spikes: np.ndarray,
                      n_dest: int,
                      max_events: int | None = None
                      ) -> tuple[np.ndarray, DispatchStats]:
    """Cycle-level event dispatch for a spike train ``spikes[T, n_src]``.

    Returns ``(currents[T, n_dest], stats)`` where ``currents[t, i]`` is the
    synaptic current accumulated into destination neuron i at step t — must
    equal ``spikes[t] @ W`` restricted to assigned neurons (tested).

    ``max_events`` models a finite MEM_E FIFO depth: at most that many
    events are accepted per step, lowest source index first (hardware FIFO
    write order), the rest are dropped before dispatch.  ``stats.events``
    still counts *arrivals*; dispatch work (cycles / rows / MACs / bytes)
    and ``mem_e_peak`` reflect only accepted events — matching the batched
    engine's ``events_from_spikes`` truncation exactly.
    """
    t_steps, n_src = spikes.shape
    currents = np.zeros((t_steps, n_dest), dtype=np.float32)
    cycles = np.zeros(t_steps, dtype=np.int64)
    rows_touched = np.zeros(t_steps, dtype=np.int64)
    engine_ops = np.zeros(t_steps, dtype=np.int64)
    events = np.zeros(t_steps, dtype=np.int64)
    bytes_touched = np.zeros(t_steps, dtype=np.int64)
    row_bytes = (tables.bits_per_row() + 7) // 8
    inv = tables.inverse_map()
    mem_e_peak = 0
    for t in range(t_steps):
        src_idx = np.nonzero(spikes[t])[0]
        events[t] = len(src_idx)
        if max_events is not None:
            src_idx = src_idx[:max_events]
        mem_e_peak = max(mem_e_peak, len(src_idx))
        for m in src_idx:
            b, a = int(tables.e2a_count[m]), int(tables.e2a_addr[m])
            cycles[t] += max(b, 1)  # >=1 cycle to poll MEM_E + read MEM_E2A
            rows_touched[t] += b
            bytes_touched[t] += b * row_bytes
            for r in range(a, a + b):
                valid = tables.sn_valid[r]
                for j in np.nonzero(valid)[0]:
                    k = int(tables.sn_virt[r, j])
                    i = int(inv[j, k])
                    wv = tables.weight_mem[j, int(tables.sn_waddr[r, j])]
                    currents[t, i] += wv
                    engine_ops[t] += 1
    stats = DispatchStats(cycles=cycles, rows_touched=rows_touched,
                          engine_ops=engine_ops, events=events,
                          sn_bytes_touched=bytes_touched, mem_e_peak=mem_e_peak)
    return currents, stats


def mem_sn_utilization(tables: MemTables, spikes: np.ndarray,
                       capacity_rows: int,
                       max_events: int | None = None) -> np.ndarray:
    """Fraction of MEM_S&N rows active per time step (Figs 6-7): rows
    belonging to neurons that spiked at step t over total row capacity.
    ``max_events`` applies the same MEM_E acceptance cap as
    :func:`dispatch_simulate` — dropped events touch no rows."""
    t_steps = spikes.shape[0]
    util = np.zeros(t_steps, dtype=np.float64)
    for t in range(t_steps):
        src_idx = np.nonzero(spikes[t])[0]
        if max_events is not None:
            src_idx = src_idx[:max_events]
        util[t] = tables.e2a_count[src_idx].sum() / max(capacity_rows, 1)
    return util
