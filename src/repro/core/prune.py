"""Unstructured L1 pruning (paper Algorithm 1, step 2).

The accelerator natively supports pruned models: MEM_S&N only stores rows for
surviving connections, so pruning directly shrinks the event-dispatch work and
weight memory.  We implement global and per-layer unstructured magnitude (L1)
pruning as masks, matching torch.nn.utils.prune.l1_unstructured semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def l1_prune_mask(w: jax.Array, amount: float) -> jax.Array:
    """Mask keeping the (1-amount) largest-|w| entries. amount in [0,1)."""
    if amount <= 0.0:
        return jnp.ones_like(w, dtype=bool)
    k = int(round(amount * w.size))
    if k <= 0:
        return jnp.ones_like(w, dtype=bool)
    if k >= w.size:
        return jnp.zeros_like(w, dtype=bool)
    flat = jnp.abs(w).reshape(-1)
    thresh = jnp.sort(flat)[k - 1]
    return jnp.abs(w) > thresh


def prune_pytree(params, amount: float):
    """Per-layer L1-prune every >=2-D float leaf. Returns (pruned, masks)."""

    def leaf(w):
        if hasattr(w, "ndim") and w.ndim >= 2 and jnp.issubdtype(w.dtype, jnp.floating):
            m = l1_prune_mask(w, amount)
            return w * m, m
        return w, None

    pruned_and_masks = jax.tree.map(leaf, params)
    pruned = jax.tree.map(lambda t: t[0], pruned_and_masks, is_leaf=lambda x: isinstance(x, tuple))
    masks = jax.tree.map(lambda t: t[1], pruned_and_masks, is_leaf=lambda x: isinstance(x, tuple))
    return pruned, masks


def sparsity(params) -> float:
    """Fraction of zero entries over all >=2-D float leaves."""
    zeros, total = 0, 0
    for leaf in jax.tree.leaves(params):
        if hasattr(leaf, "ndim") and leaf.ndim >= 2:
            zeros += int(jnp.sum(leaf == 0))
            total += leaf.size
    return zeros / max(total, 1)
