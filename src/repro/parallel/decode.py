"""Sequence-parallel flash-decoding (beyond-paper optimization, §Perf).

Baseline decode replicates the KV cache over the ``model`` axis whenever
n_kv_heads doesn't divide it (GQA kv=4/8 on a 16-way axis) — wasting HBM and
turning cache reads into the memory-roofline bottleneck.  This module shards
the cache **sequence** axis over ``model`` instead and computes attention as
a two-pass online softmax with `psum` combines (flash-decoding):

  pass 1 (local):  m_i = max score over the local seq shard
                   l_i = sum exp(s - m), o_i = sum exp(s - m) v
  combine:         m = psum-max(m_i);  rescale l_i, o_i by exp(m_i - m);
                   l = psum(l_i), o = psum(o_i);  out = o / l

Works for ANY kv-head count, cuts per-device cache bytes by the model-axis
size, and its collective cost is O(B·H·hd) — negligible next to the cache
read it parallelizes.  The new token's K/V is written only by the shard that
owns the slot.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map


def _sp_attention_local(q, ck, cv, slot_pos, pos, window, axis: str):
    """Runs INSIDE shard_map: ck/cv are the local seq shard
    [B, KH, C_loc, hd]; slot_pos [C_loc] absolute positions (-1 invalid)."""
    b, h, hd = q.shape
    kh = ck.shape[1]
    g = h // kh
    qr = q.reshape(b, kh, g, hd)
    s = jnp.einsum("bhgd,bhcd->bhgc", qr,
                   ck.astype(qr.dtype)) / math.sqrt(hd)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        valid &= (pos - slot_pos) < window
    s = jnp.where(valid[None, None, None, :], s.astype(jnp.float32), -jnp.inf)
    m_loc = jnp.max(s, axis=-1)                                  # [b,kh,g]
    m = jax.lax.pmax(m_loc, axis)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(valid[None, None, None, :], p, 0.0)
    l_loc = jnp.sum(p, axis=-1)
    o_loc = jnp.einsum("bhgc,bhcd->bhgd", p.astype(qr.dtype),
                       cv.astype(qr.dtype)).astype(jnp.float32)
    l = jax.lax.psum(l_loc, axis)
    o = jax.lax.psum(o_loc, axis)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, hd).astype(q.dtype)


def make_sp_attention(mesh: Mesh, axis: str = "model",
                      batch_axes=("pod", "data")):
    """Returns an ``attn_impl`` drop-in for transformer_decode_step: the
    cache seq dim arrives sharded over ``axis``; batch over ``batch_axes``.

    The returned function has the same signature as
    ``transformer.decode_attention(q, ck, cv, slot_pos, pos, window)``.
    """
    all_b_axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def attn(q, ck, cv, slot_pos, pos, window):
        if ck.shape[2] % mesh.shape[axis] != 0:
            # cache seq not divisible by the model axis (tiny smoke runs):
            # fall back to the baseline attention
            from repro.models.transformer import decode_attention
            return decode_attention(q, ck, cv, slot_pos, pos, window)
        # shard batch only if it divides the batch shards (long_500k has B=1)
        n_b = 1
        for a in all_b_axes:
            n_b *= mesh.shape[a]
        b_axes = all_b_axes if (n_b and q.shape[0] % n_b == 0) else ()

        def body(q_l, ck_l, cv_l, slot_l, pos_l):
            return _sp_attention_local(q_l, ck_l, cv_l, slot_l, pos_l,
                                       window, axis)

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(b_axes or None), P(b_axes or None, None, axis),
                      P(b_axes or None, None, axis), P(axis), P()),
            out_specs=P(b_axes or None),
        )(q, ck, cv, slot_pos, pos)

    return attn


def sp_cache_update(ck, cv, k_new, v_new, slot, mesh: Mesh,
                    axis: str = "model", batch_axes=("pod", "data")):
    """Write the new token's K/V into the seq-sharded cache: only the owner
    shard performs the update (masked in-place DUS)."""
    b_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    n_shards = mesh.shape[axis]
    c_total = ck.shape[2]
    c_loc = c_total // n_shards

    def body(ck_l, cv_l, k_l, v_l, slot_l):
        idx = jax.lax.axis_index(axis)
        local = slot_l - idx * c_loc
        in_range = (local >= 0) & (local < c_loc)
        safe = jnp.clip(local, 0, c_loc - 1)
        upd_k = jnp.where(in_range, k_l.astype(ck_l.dtype),
                          jax.lax.dynamic_slice(
                              ck_l, (0, 0, safe, 0),
                              (*ck_l.shape[:2], 1, ck_l.shape[3]))[:, :, 0])
        upd_v = jnp.where(in_range, v_l.astype(cv_l.dtype),
                          jax.lax.dynamic_slice(
                              cv_l, (0, 0, safe, 0),
                              (*cv_l.shape[:2], 1, cv_l.shape[3]))[:, :, 0])
        ck2 = jax.lax.dynamic_update_slice(ck_l, upd_k[:, :, None],
                                           (0, 0, safe, 0))
        cv2 = jax.lax.dynamic_update_slice(cv_l, upd_v[:, :, None],
                                           (0, 0, safe, 0))
        return ck2, cv2

    spec_c = P(b_axes or None, None, axis)
    spec_new = P(b_axes or None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(spec_c, spec_c, spec_new, spec_new, P()),
        out_specs=(spec_c, spec_c),
    )(ck, cv, k_new, v_new, slot)
