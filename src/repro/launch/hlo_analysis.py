"""Post-compile HLO analysis: collective-traffic accounting + roofline terms.

``cost_analysis()`` gives HLO FLOPs / bytes; collective bytes are NOT there,
so we parse the optimized HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (per the assignment).
"""

from __future__ import annotations

import dataclasses
import re

# ---- v5e chip constants ----------------------------------------------------
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(sig: str) -> int:
    """Total bytes of all array shapes in an HLO type signature string."""
    total = 0
    for m in _SHAPE_RE.finditer(sig):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in optimized HLO.

    Uses the op's *result* shape (bytes landing on each device), the quantity
    that traverses links under the standard ring-algorithm accounting.
    Handles both plain (`x = f32[..] all-reduce(...)`) and `-start/-done`
    async pairs (counting only the `-start`).
    """
    by_bytes: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    by_count: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        for kind in _COLLECTIVES:
            # match `bf16[...] all-reduce(` or `(...) all-gather-start(`
            if re.search(rf"\b{kind}(-start)?\(", rhs):
                if re.search(rf"\b{kind}-done\(", rhs):
                    break
                # result shape(s) precede the op name in rhs
                sig = rhs.split(kind)[0]
                by_bytes[kind] += _shape_bytes(sig)
                by_count[kind] += 1
                break
    return CollectiveStats(bytes_by_kind=by_bytes, count_by_kind=by_count)


@dataclasses.dataclass
class RooflineTerms:
    """Per-device roofline terms in seconds (assignment §Roofline)."""

    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float             # whole-program (all devices)
    hlo_bytes: float
    coll_bytes: float
    n_devices: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower-bound step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> dict:
        return {**dataclasses.asdict(self), "dominant": self.dominant,
                "step_time_s": self.step_time_s}


def roofline_terms(cost: dict, coll, n_devices: int) -> RooflineTerms:
    """Derive the three terms.

    The compiled module is the per-device SPMD program, so all inputs here
    are per-device.  ``coll`` is anything exposing total collective bytes
    (CollectiveStats.total_bytes or hlo_flops.HloCost.total_coll_bytes).
    """
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    cb = float(getattr(coll, "total_bytes", None)
               or getattr(coll, "total_coll_bytes", 0.0) or 0.0)
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=bytes_ / HBM_BW,
        collective_s=cb / ICI_BW,
        hlo_flops=flops, hlo_bytes=bytes_, coll_bytes=cb,
        n_devices=n_devices)


def model_flops(n_params_active: float, n_tokens: float,
                kind: str) -> float:
    """MODEL_FLOPS = 6·N·D for training, 2·N·D for inference forward."""
    c = 6.0 if kind == "train" else 2.0
    return c * n_params_active * n_tokens
