"""End-to-end system behaviour: the full Algorithm-1 pipeline and the
dry-run/roofline machinery on an emulated multi-device mesh."""

import json
import os
import subprocess
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    pre = (f'import os; os.environ["XLA_FLAGS"] = '
           f'"--xla_force_host_platform_device_count={devices}"\n')
    p = subprocess.run([sys.executable, "-c", pre + script],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=timeout)
    assert p.returncode == 0, (p.stdout[-2000:], p.stderr[-4000:])
    return p.stdout


def test_algorithm1_end_to_end(tmp_path):
    """Train -> prune -> quantize -> ILP map -> execute -> energy report,
    with the accelerator twin bit-exact vs the dense reference."""
    out = _run("""
import jax, numpy as np
from repro.core.accelerator import map_model, reference_forward, run
from repro.core.energy import AcceleratorSpec
from repro.core.prune import prune_pytree
from repro.core.quant import quantize_pytree
from repro.data.events import EventDatasetConfig, event_batches, synthetic_event_dataset
from repro.engine import MLP_MODEL, SNNTrainConfig, train_snn_model
from repro.snn.mlp import SNNConfig

data_cfg = EventDatasetConfig("sys", 10, 10, num_steps=12, base_rate=0.02,
                              signal_rate=0.5)
snn = SNNConfig(layer_sizes=(data_cfg.n_in, 32, 10), num_steps=12)
spikes, labels = synthetic_event_dataset(data_cfg, 8, jax.random.key(0))
params, _ = train_snn_model(MLP_MODEL, snn,
                            event_batches(spikes, labels, 16),
                            SNNTrainConfig(steps=60, log_every=1000),
                            key=jax.random.key(1), log_fn=lambda s: None)
pruned, _ = prune_pytree(params, 0.5)
_, dq = quantize_pytree(pruned)
spec = AcceleratorSpec("sys", 2, 4, 16, 1 << 20)
model = map_model([np.asarray(w) for w in dq], spec, lif=snn.lif)
res = run(model, spikes[0])
ref = reference_forward([l.w_q for l in model.layers], snn.lif, spikes[0])
assert np.array_equal(res.out_spikes, ref)
assert res.energy.tops_per_w > 0
print("OK", res.energy.tops_per_w)
""", devices=1)
    assert "OK" in out


def test_dryrun_machinery_small_mesh(tmp_path):
    """The dry-run path (lower -> compile -> loop-aware analysis) works end
    to end on a small emulated mesh with a smoke-scale config."""
    out = _run("""
import jax
import repro.launch.dryrun as D
from repro.configs.common import ShapeSpec
import repro.configs.internlm2_1_8b as mod

mod.CONFIG = mod.SMOKE
D.SHAPES = dict(D.SHAPES)
D.SHAPES["train_4k"] = ShapeSpec("train_4k", 64, 8, "train")

mesh = jax.make_mesh((4, 2), ("data", "model"))
compiled, lowered, meta = D.lower_cell("internlm2_1_8b", "train_4k", mesh)
rec = D.analyze(compiled, lowered, meta, 8)
assert rec["roofline"]["compute_s"] > 0
assert rec["loop_aware"]["flops"] > 0
raw = rec["cost_analysis_raw"].get("flops", 0.0)
assert rec["loop_aware"]["flops"] > raw, (rec["loop_aware"]["flops"], raw)
print("OK", rec["roofline"]["dominant"])
""", devices=8)
    assert "OK" in out


def test_hlo_flops_analyzer_exact_on_scan():
    """The loop-aware analyzer counts scanned matmul FLOPs exactly (raw
    cost_analysis counts the body once)."""
    out = _run("""
import jax, jax.numpy as jnp
from repro.launch.hlo_flops import analyze_hlo
from repro.parallel.compat import compiled_cost_analysis

def g(a, b):
    def body(x, _):
        return jnp.tanh(x @ b), None
    x, _ = jax.lax.scan(body, a, None, length=11)
    return x

a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
b = jax.ShapeDtypeStruct((128, 128), jnp.float32)
c = jax.jit(g).lower(a, b).compile()
cost = analyze_hlo(c.as_text())
expect = 11 * 2 * 64 * 128 * 128
assert abs(cost.dot_flops - expect) / expect < 1e-6, (cost.dot_flops, expect)
raw = compiled_cost_analysis(c)["flops"]
assert cost.dot_flops > 5 * raw
print("OK")
""", devices=1)
    assert "OK" in out


def test_collective_bytes_counted_with_loop_multiplier():
    """Collectives inside a scanned body are multiplied by the trip count."""
    out = _run("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.hlo_flops import analyze_hlo
from repro.parallel.compat import shard_map

mesh = jax.make_mesh((4,), ("x",))

def f(a):
    def body(x, _):
        y = shard_map(lambda v: jax.lax.psum(v, "x"), mesh=mesh,
                      in_specs=P("x"), out_specs=P())(x)
        return jnp.tanh(x * jnp.mean(y)), None
    x, _ = jax.lax.scan(body, a, None, length=5)
    return x

a = jax.ShapeDtypeStruct((16, 64), jnp.float32)
c = jax.jit(f).lower(a).compile()
cost = analyze_hlo(c.as_text())
assert cost.coll_counts["all-reduce"] >= 5, cost.coll_counts
print("OK", cost.coll_counts)
""", devices=4)
    assert "OK" in out
