"""Observability-cost benchmark: tracing must be (nearly) free and change
nothing.

Measures what attaching a :class:`repro.engine.tracing.FlightRecorder` to
the serving fabric costs, and proves the observer effect is zero — the
gates behind docs/OBSERVABILITY.md's "strictly passive" claim.  Writes
``BENCH_observability.json``.

  PYTHONPATH=src python benchmarks/observability_bench.py [--smoke] \
      [--out BENCH_observability.json] [--spoof-devices 2]

Gates (CI fails loudly on regression):
  * tracer overhead <= 5% wall time (+20 ms absolute floor for timer
    noise on sub-second smoke runs), min-of-N repeats of the same warmed
    scenario replay with the recorder on vs off;
  * ZERO new jit traces with tracing enabled on warmed buckets, and zero
    ``jit_events`` observed by the recorder's probe;
  * a tracer-on replay is bit-exact with a tracer-off replay (metrics and
    every served spike train);
  * two traced replays produce byte-identical ``dump_json()`` and every
    injected fault lands as a typed anomaly;
  * the socket ADMIN ``metrics`` / ``trace`` verbs round-trip the
    schema-locked snapshot and a span trace over a live TCP connection.
"""

from __future__ import annotations

import argparse
import json
import time

from repro.launch._spoof import (assert_spoof_applied,
                                 spoof_devices_from_argv)

_SPOOFED = spoof_devices_from_argv()  # before any jax import in this process

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.engine import (BucketPolicy, FlightRecorder,  # noqa: E402
                          METRIC_KEYS, SCENARIOS, trace_count,
                          run_scenario)
from repro.engine.sharded_run import snn_serve_mesh  # noqa: E402
from repro.launch.serve_snn import build_demo_model  # noqa: E402

# overhead gate: tracing may cost at most 5% of the untraced wall time,
# with a small absolute floor so sub-second smoke runs don't gate on
# scheduler jitter
OVERHEAD_REL = 0.05
OVERHEAD_ABS_S = 0.02

# scenarios exercised; all run on a single device so the bench works on
# any host (device-loss scenarios live in soak_bench)
_SCENARIOS = ("baseline", "adversarial", "slo_shed", "analog_noise",
              "multi_tenant")


def _time_replays(packed, sc, *, recorder_factory, repeats: int) -> float:
    """Min wall seconds over ``repeats`` replays of one scenario (min, not
    mean: the quantity under test is deterministic work, so the minimum is
    the least-noise estimate)."""
    best = float("inf")
    for _ in range(repeats):
        rec = recorder_factory()
        t0 = time.perf_counter()
        run_scenario(packed, sc, recorder=rec)
        best = min(best, time.perf_counter() - t0)
        if rec is not None:
            rec.detach_jit_probe()
    return best


def bench_overhead(packed, *, smoke: bool) -> dict:
    """The ≤5% gate: warmed scenario replays with the recorder on vs
    off."""
    sc = SCENARIOS["adversarial"]
    repeats = 3 if smoke else 5
    run_scenario(packed, sc)     # warm every bucket (compiles excluded)
    off_s = _time_replays(packed, sc, recorder_factory=lambda: None,
                          repeats=repeats)
    on_s = _time_replays(packed, sc, recorder_factory=FlightRecorder,
                         repeats=repeats)
    budget = off_s * (1.0 + OVERHEAD_REL) + OVERHEAD_ABS_S
    assert on_s <= budget, \
        f"tracing overhead gate: {on_s:.3f}s traced vs {off_s:.3f}s " \
        f"untraced (budget {budget:.3f}s)"
    overhead = on_s / off_s - 1.0 if off_s > 0 else 0.0
    print(f"observability/overhead: off {off_s*1e3:.0f} ms | on "
          f"{on_s*1e3:.0f} ms | {overhead*100:+.1f}% (gate "
          f"{OVERHEAD_REL*100:.0f}% + {OVERHEAD_ABS_S*1e3:.0f} ms)")
    return {"scenario": sc.name, "repeats": repeats, "untraced_s": off_s,
            "traced_s": on_s, "overhead_frac": overhead}


def bench_zero_observer_effect(packed) -> list[dict]:
    """Bit-exactness, replay determinism, anomaly typing, and the
    zero-retrace gate, per scenario."""
    rows = []
    for name in _SCENARIOS:
        sc = SCENARIOS[name]
        run_scenario(packed, sc)            # warm this scenario's buckets
        n0 = trace_count()
        rec1, rec2 = FlightRecorder(), FlightRecorder()
        res1, rids1, m1 = run_scenario(packed, sc, recorder=rec1)
        _, _, m2 = run_scenario(packed, sc, recorder=rec2)
        assert trace_count() == n0, \
            f"{name}: tracing added jit traces on warmed buckets"
        assert not rec1.jit_events and not rec2.jit_events, \
            f"{name}: the jit probe saw compiles on warmed buckets"
        rec1.detach_jit_probe()
        rec2.detach_jit_probe()
        assert m1 == m2 and rec1.dump_json() == rec2.dump_json(), \
            f"{name}: traced replay is not deterministic"
        res0, rids0, m0 = run_scenario(packed, sc)   # tracer off
        assert m0 == m1 and rids0 == rids1, \
            f"{name}: tracing changed the served metrics"
        for rid in res0:
            assert np.array_equal(res0[rid].out_spikes,
                                  res1[rid].out_spikes), \
                f"{name}: tracing changed served bits (rid {rid})"
        n_anom = sum(rec1.anomaly_counts.values())
        print(f"observability/{name}: {m1['completed']} served | "
              f"{n_anom} anomalies "
              f"{dict(sorted(rec1.anomaly_counts.items()))} | dump "
              f"{len(rec1.dump_json())} bytes")
        rows.append({"scenario": name, "completed": m1["completed"],
                     "anomalies": dict(sorted(rec1.anomaly_counts.items())),
                     "dump_bytes": len(rec1.dump_json())})
    return rows


def bench_wire_roundtrip(packed) -> dict:
    """ADMIN ``metrics`` and ``trace`` over a live socket: the CI smoke
    job's liveness check for the wire-exported observability surface."""
    from repro.launch.socket_serve import (SpikeClient, SpikeSocketServer,
                                           serving_thread)
    rng = np.random.default_rng(0)
    srv = SpikeSocketServer(
        packed, policy=BucketPolicy(batch_sizes=(2,), time_steps=(8,)))
    host, port = srv.address
    with serving_thread(srv, idle_flush_s=0.05):
        cli = SpikeClient(host, port)
        for _ in range(4):
            cli.send((rng.random((6, packed.n_in)) < 0.2)
                     .astype(np.float32))
        cli.recv_all()
        met = cli.admin({"op": "metrics"})
        last = cli.admin({"op": "trace", "last": True})
        dump = cli.admin({"op": "trace"})
        cli.recv_all()
        cli.close()
    mrep = cli.admin_replies[met]
    assert mrep.get("ok") and set(mrep["metrics"]) == set(METRIC_KEYS), \
        "ADMIN metrics reply is not schema-locked"
    assert mrep["metrics"]["completed"] == 4
    trep = cli.admin_replies[last]
    assert trep.get("ok") and trep["trace"]["completed"], \
        "ADMIN trace last did not return a completed trace"
    kinds = [sp["kind"] for sp in trep["trace"]["spans"]]
    assert kinds[0] == "admit" and "dispatch" in kinds
    drep = cli.admin_replies[dump]
    assert drep.get("ok") and drep["dump"]["n_completed"] == 4
    print(f"observability/wire: metrics({len(mrep['metrics'])} keys) + "
          f"trace({len(kinds)} spans) + dump round-tripped")
    return {"served": 4, "metric_keys": len(mrep["metrics"]),
            "trace_spans": len(kinds),
            "dump_completed": drep["dump"]["n_completed"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="BENCH_observability.json")
    ap.add_argument("--model", default="mlp", choices=["mlp", "conv"])
    ap.add_argument("--spoof-devices", type=int, default=None)
    args = ap.parse_args()
    assert_spoof_applied(_SPOOFED)
    snn_serve_mesh(None)    # parity with sibling benches on spoofed hosts
    packed = build_demo_model(args.model, smoke=args.smoke).pack()
    scenarios = bench_zero_observer_effect(packed)
    overhead = bench_overhead(packed, smoke=args.smoke)
    wire = bench_wire_roundtrip(packed)
    blob = {"bench": "observability", "smoke": args.smoke,
            "model": args.model, "backend": jax.default_backend(),
            "n_devices": len(jax.devices()),
            "overhead_gate_rel": OVERHEAD_REL,
            "overhead_gate_abs_s": OVERHEAD_ABS_S,
            "overhead": overhead, "scenarios": scenarios, "wire": wire}
    with open(args.out, "w") as f:
        json.dump(blob, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
