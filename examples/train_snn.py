"""End-to-end driver: train a MENAGE evaluation model with fault-tolerant
checkpointing, then run the full prune -> quantize -> map -> execute flow.

  --model mlp   (default) the paper's N-MNIST MLP (200/100/40/10) on Accel_1
  --model conv  the spiking CNN (conv->LIF->pool x2 + dense head) on the
                synthetic CIFAR10-DVS stream, lowered layer-spec by layer-spec
                (Conv2d with shared weight-SRAM words) onto Accel_2

  PYTHONPATH=src python examples/train_snn.py [--steps 300] [--model conv]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.menage_paper import (CIFAR_CONV, CIFAR_CONV_DATA,
                                        NMNIST_DATA, NMNIST_SNN)
from repro.core.accelerator import map_model, run
from repro.core.energy import ACCEL_1, ACCEL_2
from repro.core.prune import prune_pytree
from repro.core.quant import quantize_pytree
from repro.data.events import event_batches, synthetic_event_dataset
from repro.engine import BucketPolicy, run_bucketed, trace_count
from repro.snn.conv import conv_snn_forward, layer_specs, train_conv_snn
from repro.snn.mlp import init_snn, snn_forward, snn_loss, train_snn
from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint


def main_conv(args):
    """Conv path: train briefly, prune, lower to Conv2d/SumPool2d/Dense
    specs, map onto Accel_2, and cross-check the two executers."""
    cfg = CIFAR_CONV
    key = jax.random.key(0)
    spikes, labels = synthetic_event_dataset(CIFAR_CONV_DATA, n_per_class=16,
                                             key=key)
    n_test = len(labels) // 5
    train_it = event_batches(spikes[n_test:], labels[n_test:], batch=32)
    params, hist = train_conv_snn(jax.random.key(1), cfg, train_it,
                                  steps=args.steps, log_every=50)
    print(f"conv train: loss={hist[-1][1]:.3f} acc={hist[-1][2]:.2f}")

    counts, _ = conv_snn_forward(
        params, jnp.asarray(spikes[:n_test].swapaxes(0, 1)), cfg)
    acc = float((np.asarray(counts).argmax(-1) == labels[:n_test]).mean())
    print(f"conv test accuracy (before prune/quant): {acc:.3f}")

    pruned, _ = prune_pytree(params, 0.5)
    model = map_model(layer_specs(pruned, cfg), ACCEL_2, lif=cfg.lif)
    for li, layer in enumerate(model.layers):
        print(f"  layer {li}: {layer.n_src}->{layer.n_dest} "
              f"rounds={len(layer.rounds)} sram={layer.sram_bytes}B "
              f"(unique {layer.weight_bytes}B) shared={layer.shared_weights}")
    # serve the test clips through the bucketed engine (bounded jit cache)
    policy = BucketPolicy(batch_sizes=(4,), time_steps=(cfg.num_steps,))
    n0 = trace_count()
    served = run_bucketed(model, list(spikes[:4]), policy=policy)
    res = run(model, spikes[0])
    for b, r in enumerate(served):
        assert (r.out_spikes == run(model, spikes[b]).out_spikes).all(), \
            f"engine diverged from oracle on sample {b}"
    print(f"Accel_2 conv execution: {res.energy.tops_per_w:.2f} TOPS/W "
          f"(oracle == bucketed engine on {len(served)} samples, "
          f"{trace_count() - n0} trace(s))")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/menage_snn_ckpt")
    ap.add_argument("--model", choices=("mlp", "conv"), default="mlp")
    args = ap.parse_args()
    if args.model == "conv":
        return main_conv(args)

    key = jax.random.key(0)
    spikes, labels = synthetic_event_dataset(NMNIST_DATA, n_per_class=32,
                                             key=key)
    n_test = len(labels) // 5
    train_it = event_batches(spikes[n_test:], labels[n_test:], batch=64)

    # resume-aware training
    mgr = CheckpointManager(args.ckpt, keep=2)
    params = init_snn(jax.random.key(1), NMNIST_SNN)
    start = latest_step(args.ckpt) or 0
    if start:
        params = restore_checkpoint(args.ckpt, start, params)
        print(f"resumed from step {start}")
    chunk = 100
    step = start
    while step < args.steps:
        n = min(chunk, args.steps - step)
        params, hist = train_snn(key, NMNIST_SNN, train_it, steps=n,
                                 params=params)
        step += n
        mgr.save_async(step, params)
        print(f"step {step}: loss={hist[-1][1]:.3f} acc={hist[-1][2]:.2f}")
    mgr.wait()

    # eval
    counts, _ = snn_forward(params,
                            jnp.asarray(spikes[:n_test].swapaxes(0, 1)),
                            NMNIST_SNN)
    acc = float((np.asarray(counts).argmax(-1) == labels[:n_test]).mean())
    print(f"test accuracy (before prune/quant): {acc:.3f}")

    pruned, _ = prune_pytree(params, 0.5)
    _, dq = quantize_pytree(pruned)
    counts, _ = snn_forward(dq, jnp.asarray(spikes[:n_test].swapaxes(0, 1)),
                            NMNIST_SNN)
    acc_pq = float((np.asarray(counts).argmax(-1) == labels[:n_test]).mean())
    print(f"test accuracy (after prune+quant):  {acc_pq:.3f} "
          f"(paper: 94.75% -> 94.1%)")

    model = map_model([np.asarray(w) for w in dq], ACCEL_1,
                      lif=NMNIST_SNN.lif)
    res = run(model, spikes[0])
    print(f"Accel_1 execution: {res.energy.tops_per_w:.2f} TOPS/W "
          f"(paper Table II: 3.4)")


if __name__ == "__main__":
    main()
