"""MENAGE core: the paper's contribution as composable JAX modules.

Layers:
  lif          — discrete-time LIF + surrogate gradient (A-NEURON math)
  layers       — map_model layer specs: Dense / Conv2d / SumPool2d lowering
  quant        — 8-bit symmetric quantization + ideal C2C ladder model
  prune        — unstructured L1 pruning
  mapping      — the ILP (eqs. 3-7): exact HiGHS solvers, max-flow fast path, greedy
  memories     — MEM_E / MEM_E2A / MEM_S&N bit-level model + dispatch simulator
  energy       — calibrated Table-II energy model
  accelerator  — end-to-end software twin (map_model / run / reference_forward)
  noise        — analog non-ideality perturbations
"""

from repro.core.layers import Conv2d, Dense, SumPool2d, as_layer_spec  # noqa: F401
from repro.core.lif import LIFParams, lif_step, lif_rollout, rate_encode, spike_fn  # noqa: F401
from repro.core.quant import QuantizedTensor, quantize_symmetric, c2c_ladder_value  # noqa: F401
from repro.core.prune import l1_prune_mask, prune_pytree, sparsity  # noqa: F401
from repro.core.energy import ACCEL_1, ACCEL_2, AcceleratorSpec, energy_model  # noqa: F401
from repro.core.accelerator import map_model, run, reference_forward  # noqa: F401
