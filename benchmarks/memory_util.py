"""Figs 6-7 reproduction: MEM_S&N utilization per time step while processing
one input image, per layer, for Accel_1/N-MNIST and Accel_2/CIFAR10-DVS —
plus the conv lowering on the same CIFAR10-DVS stream.

Models are built as :mod:`repro.core.layers` specs (the post-conv model
path) and executed through the bucketed batched engine
(:func:`repro.engine.run_bucketed`), whose per-step utilization is tested
bit-exact against the cycle-level oracle — so this bench rides the serving
path instead of the Python-loop simulator.

  PYTHONPATH=src python benchmarks/memory_util.py [--smoke]
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from energy import _prepare  # noqa: E402  (benchmarks/ is not a package)
from repro.configs.menage_paper import (CIFAR_CONV, CIFAR_CONV_DATA,
                                        CIFAR_DATA, CIFAR_SNN, NMNIST_DATA,
                                        NMNIST_SNN)
from repro.core.accelerator import map_model
from repro.core.energy import ACCEL_1, ACCEL_2
from repro.core.layers import Dense
from repro.core.lif import LIFParams
from repro.data.events import (EventDatasetConfig, event_batches,
                               synthetic_event_dataset)
from repro.engine import (SNNTrainConfig, model_for, run_bucketed,
                          train_snn_model)
from repro.snn.conv import ConvSNNConfig, layer_specs
from repro.snn.mlp import SNNConfig


def _spark(values, width: int = 40) -> str:
    chars = " .:-=+*#%@"
    v = np.asarray(values, dtype=float)
    if len(v) > width:
        idx = np.linspace(0, len(v) - 1, width).astype(int)
        v = v[idx]
    hi = v.max() or 1.0
    return "".join(chars[int(min(x / hi, 1.0) * (len(chars) - 1))] for x in v)


def measure(spec, data_cfg, snn_cfg, train_steps=15, image: int = 0):
    """Dense path: train/prune/quantize, wrap the matrices as Dense specs,
    map, and serve the image through the bucketed engine."""
    key = jax.random.key(0)
    weights, spikes = _prepare(data_cfg, snn_cfg, train_steps, key)
    model = map_model([Dense(w=w) for w in weights], spec, lif=snn_cfg.lif)
    res = run_bucketed(model, [spikes[image]])[0]
    return res.util, res.stats


def measure_conv(spec, data_cfg, conv_cfg, train_steps=10, image: int = 0):
    """Conv path: train the spiking CNN, prune, lower to Conv2d/SumPool2d/
    Dense specs (shared A-SYN words), serve through the bucketed engine."""
    from repro.core.prune import prune_pytree
    key = jax.random.key(0)
    spikes, labels = synthetic_event_dataset(data_cfg, n_per_class=4, key=key)
    it = event_batches(spikes, labels, batch=8)
    params, _ = train_snn_model(model_for(conv_cfg), conv_cfg, it,
                                SNNTrainConfig(steps=train_steps, lr=1e-3,
                                               log_every=1000),
                                key=key, log_fn=lambda s: None)
    pruned, _ = prune_pytree(params, 0.5)
    model = map_model(layer_specs(pruned, conv_cfg), spec, lif=conv_cfg.lif)
    res = run_bucketed(model, [spikes[image]])[0]
    return res.util, res.stats


def report(tag: str, utils):
    for li, u in enumerate(utils):
        print(f"memutil/{tag}/L{li},avg={u.mean():.4f},"
              f"peak={u.max():.4f},trace={_spark(u)}")
    # the paper's headline observation: avg utilization stays low, spikes
    # at busy steps
    avg = float(np.mean([u.mean() for u in utils]))
    peak = float(np.max([u.max() for u in utils]))
    print(f"memutil/{tag},avg={avg:.4f},peak={peak:.4f},"
          f"peak_over_avg={peak/max(avg,1e-9):.1f}x")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configs + few train steps (CI drift guard)")
    args = ap.parse_args()
    if args.smoke:
        data = EventDatasetConfig("memutil-smoke", 10, 10, num_steps=16)
        snn = SNNConfig(layer_sizes=(data.n_in, 48, 10),
                        lif=LIFParams(beta=0.9, threshold=1.0), num_steps=16)
        utils, _ = measure(ACCEL_1, data, snn, train_steps=3)
        report("smoke-mlp", utils)
        conv_data = EventDatasetConfig("memutil-smoke-dvs", 6, 6,
                                       num_steps=12, base_rate=0.03,
                                       signal_rate=0.5)
        conv = ConvSNNConfig(in_shape=(2, 6, 6), conv_channels=(3,),
                             kernel_size=3, stride=1, padding=1, pool=2,
                             lif=LIFParams(beta=0.9, threshold=1.0),
                             num_steps=12)
        utils, _ = measure_conv(ACCEL_1, conv_data, conv, train_steps=2)
        report("smoke-conv", utils)
        return
    for spec, dc, sc, tag in [(ACCEL_1, NMNIST_DATA, NMNIST_SNN, "nmnist"),
                              (ACCEL_2, CIFAR_DATA, CIFAR_SNN, "cifar10dvs")]:
        utils, _ = measure(spec, dc, sc)
        report(tag, utils)
    utils, _ = measure_conv(ACCEL_2, CIFAR_CONV_DATA, CIFAR_CONV)
    report("cifar10dvs-conv", utils)


if __name__ == "__main__":
    main()
