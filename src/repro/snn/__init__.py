from repro.snn.mlp import SNNConfig, init_snn, snn_forward, snn_loss, train_snn  # noqa: F401
from repro.snn.conv import (ConvSNNConfig, conv_snn_forward, conv_snn_loss,  # noqa: F401
                            init_conv_snn, layer_specs, train_conv_snn)
