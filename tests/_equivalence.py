"""The oracle-vs-engine equivalence contract, as one shared checker.

Every suite that claims "``run_batched`` == ``run``" — the property tests,
the golden-fixture replays, and the overflow-propagation test — asserts
through this function, so a new :class:`DispatchStats` field or result
surface gets covered everywhere by updating one place.
"""

from __future__ import annotations

import numpy as np

from repro.core.accelerator import run_batch
from repro.engine import batched_run as br

STAT_FIELDS = ("cycles", "rows_touched", "engine_ops", "events",
               "sn_bytes_touched")


def assert_oracle_engine_equivalent(model, spikes: np.ndarray,
                                    max_events: int | None = None,
                                    tag: str = ""):
    """Bit-exact equivalence of ``run_batched(model, spikes)`` vs the
    batched oracle per sample: output spikes, every DispatchStats field,
    MEM_S&N utilization, and overflow — under the same MEM_E cap."""
    res = br.run_batched(model, spikes, max_events=max_events)
    for b, oracle in enumerate(run_batch(model, spikes,
                                         max_events=max_events)):
        ctx = f"{tag} sample {b}"
        np.testing.assert_array_equal(res.out_spikes[b], oracle.out_spikes,
                                      err_msg=f"{ctx} spikes")
        for li, (bs, os_) in enumerate(zip(res.sample_stats(b),
                                           oracle.per_layer_stats)):
            for f in STAT_FIELDS:
                np.testing.assert_array_equal(
                    getattr(bs, f), getattr(os_, f),
                    err_msg=f"{ctx} layer {li} {f}")
            assert bs.mem_e_peak == os_.mem_e_peak, \
                f"{ctx} layer {li} mem_e_peak"
        for li in range(len(model.layers)):
            np.testing.assert_array_equal(
                res.per_layer_util[li][b], oracle.per_layer_util[li],
                err_msg=f"{ctx} layer {li} util")
            np.testing.assert_array_equal(
                res.overflow[li][b], oracle.overflow[li],
                err_msg=f"{ctx} layer {li} overflow")
    return res


def assert_engine_results_equal(a, b, tag: str = ""):
    """Bit-exact equality of two :class:`BatchedRunResult` surfaces — the
    sharded-vs-single-device contract (``run_sharded == run_batched``), plus
    per-sample EnergyReport agreement when both carry an AcceleratorSpec."""
    np.testing.assert_array_equal(a.out_spikes, b.out_spikes,
                                  err_msg=f"{tag} spikes")
    assert len(a.per_layer_stats) == len(b.per_layer_stats), tag
    for li, (sa, sb) in enumerate(zip(a.per_layer_stats, b.per_layer_stats)):
        for f in STAT_FIELDS:
            np.testing.assert_array_equal(getattr(sa, f), getattr(sb, f),
                                          err_msg=f"{tag} layer {li} {f}")
        np.testing.assert_array_equal(sa.mem_e_peak, sb.mem_e_peak,
                                      err_msg=f"{tag} layer {li} mem_e_peak")
    for li in range(len(a.per_layer_util)):
        np.testing.assert_array_equal(a.per_layer_util[li],
                                      b.per_layer_util[li],
                                      err_msg=f"{tag} layer {li} util")
        np.testing.assert_array_equal(a.overflow[li], b.overflow[li],
                                      err_msg=f"{tag} layer {li} overflow")
    if a.spec is not None and a.per_layer_stats:
        for s in range(a.out_spikes.shape[0]):
            ea, eb = a.sample_energy(s), b.sample_energy(s)
            assert ea == eb, f"{tag} sample {s} energy: {ea} != {eb}"
