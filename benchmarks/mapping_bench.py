"""ILP mapping benchmark (§III-D): solver runtime + optimality gap of the
greedy heuristic vs the exact solvers across layer sizes; dispatch-cycle
benefit of ILP load-balancing (the quantity the mapping actually optimizes).

Layers are built as :mod:`repro.core.layers` specs — the post-conv-support
model path — so the bench measures exactly what ``map_model`` solves,
including a shared-weight conv case (one A-SYN word, many MEM_S&N rows).

  PYTHONPATH=src python benchmarks/mapping_bench.py [--smoke]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.layers import Conv2d, Dense, LayerSpec
from repro.core.mapping import (MappingProblem, solve_mapping_greedy,
                                solve_mapping_reduced_ilp)
from repro.core.memories import build_event_memories


def dense_spec(n_src: int, n_dest: int, density: float, seed: int = 0) -> Dense:
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(n_src, n_dest)).astype(np.float32)
    w[rng.random(w.shape) > density] = 0
    return Dense(w=w)


def conv_spec(c_in: int, side: int, c_out: int, k: int, density: float,
              seed: int = 0) -> Conv2d:
    rng = np.random.default_rng(seed)
    kern = rng.normal(size=(c_out, c_in, k, k)).astype(np.float32)
    kern[rng.random(kern.shape) > density] = 0
    return Conv2d(kernel=kern, in_shape=(c_in, side, side), stride=1,
                  padding=1)


def bench_one(spec: LayerSpec, m: int, n: int, tag: str,
              fanout_slack: float | None = 0.9,
              time_limit: float = 5.0) -> dict:
    """Solve one layer spec's mapping with the reduced ILP and the greedy
    heuristic; compare assignments, runtime, and resulting MEM_S&N rows
    (dispatch cycles — what the ILP load-balances)."""
    w = np.asarray(spec.unroll())
    share = spec.share_ids()
    fanout = None
    if fanout_slack is not None and share is None:
        fanout = np.maximum((w != 0).sum(1) * fanout_slack, 1).astype(int)
    p = MappingProblem.from_weights(w, m, n, fanout=fanout)

    t0 = time.perf_counter()
    s_ilp = solve_mapping_reduced_ilp(p, time_limit=time_limit)
    t_ilp = time.perf_counter() - t0
    t0 = time.perf_counter()
    s_gr = solve_mapping_greedy(p)
    t_gr = time.perf_counter() - t0

    # dispatch-cycle quality: total MEM_S&N rows (cycles) per solution
    rows_ilp = build_event_memories(w, s_ilp, m, n, share_ids=share).n_rows
    rows_gr = build_event_memories(w, s_gr, m, n, share_ids=share).n_rows
    return {
        "size": f"{tag}_{spec.n_src}x{spec.n_dest}_M{m}N{n}",
        "ilp_assigned": s_ilp.n_assigned, "greedy_assigned": s_gr.n_assigned,
        "ilp_ms": t_ilp * 1e3, "greedy_ms": t_gr * 1e3,
        "ilp_rows": rows_ilp, "greedy_rows": rows_gr,
    }


def cases(smoke: bool):
    if smoke:
        yield bench_one(dense_spec(64, 40, 0.5), 10, 16, "dense")
        yield bench_one(conv_spec(2, 6, 3, 3, 0.6), 10, 16, "conv",
                        fanout_slack=None)
        return
    yield bench_one(dense_spec(64, 40, 0.5), 10, 16, "dense")
    yield bench_one(dense_spec(128, 64, 0.5, seed=1), 10, 16, "dense")
    yield bench_one(dense_spec(200, 100, 0.4, seed=2), 20, 32, "dense")
    yield bench_one(conv_spec(2, 8, 4, 3, 0.6), 10, 16, "conv",
                    fanout_slack=None)
    yield bench_one(conv_spec(4, 10, 8, 3, 0.5, seed=1), 20, 32, "conv",
                    fanout_slack=None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two small cases (CI drift guard)")
    args = ap.parse_args()
    for r in cases(args.smoke):
        gap = r["ilp_assigned"] - r["greedy_assigned"]
        print(f"mapping/{r['size']},ilp_ms={r['ilp_ms']:.1f},"
              f"greedy_ms={r['greedy_ms']:.1f},"
              f"assigned_gap={gap},"
              f"rows_ilp={r['ilp_rows']},rows_greedy={r['greedy_rows']}")


if __name__ == "__main__":
    main()
